"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (ragged and tile-aligned) and dtypes; the
kernel/oracle agreement here is THE correctness signal for everything the
Rust runtime later executes through the *_pallas artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lowrank_matmul as K
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=80)
RANKS = st.integers(min_value=1, max_value=8)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _mk(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


def _tols(dtype):
    # bf16: the kernel accumulates in f32 (MXU convention) while the
    # oracle accumulates in bf16, so per-element deviations of a few ulp
    # of bf16 (≈ 1/128 relative) are expected over 64-term dot products.
    return dict(rtol=6e-2, atol=0.25) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(b=DIMS, n=DIMS, m=DIMS, r=RANKS, seed=SEEDS)
def test_lowrank_linear_matches_ref(b, n, m, r, seed):
    rng = np.random.default_rng(seed)
    x, w = _mk(rng, b, n), _mk(rng, m, n)
    ba, v = _mk(rng, m, r), _mk(rng, n, r)
    np.testing.assert_allclose(
        K.lowrank_linear(x, w, ba, v),
        ref.lowrank_linear_ref(x, w, ba, v), rtol=2e-3, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(b=DIMS, n=DIMS, m=DIMS, r=RANKS, seed=SEEDS)
def test_grad_b_matches_ref(b, n, m, r, seed):
    rng = np.random.default_rng(seed)
    dy, x, v = _mk(rng, b, m), _mk(rng, b, n), _mk(rng, n, r)
    np.testing.assert_allclose(
        K.lowrank_linear_grad_b(dy, x, v),
        ref.lowrank_linear_grad_b_ref(dy, x, v), rtol=5e-3, atol=5e-3)


@settings(max_examples=25, deadline=None)
@given(b=DIMS, n=DIMS, m=DIMS, r=RANKS, seed=SEEDS)
def test_grad_x_matches_ref(b, n, m, r, seed):
    rng = np.random.default_rng(seed)
    dy, w = _mk(rng, b, m), _mk(rng, m, n)
    ba, v = _mk(rng, m, r), _mk(rng, n, r)
    np.testing.assert_allclose(
        K.lowrank_linear_grad_x(dy, w, ba, v),
        ref.lowrank_linear_grad_x_ref(dy, w, ba, v), rtol=5e-3, atol=5e-3)


@settings(max_examples=20, deadline=None)
@given(n=DIMS, m=DIMS, r=RANKS, seed=SEEDS)
def test_lift_add_matches_ref(n, m, r, seed):
    rng = np.random.default_rng(seed)
    t, ba, v = _mk(rng, m, n), _mk(rng, m, r), _mk(rng, n, r)
    np.testing.assert_allclose(
        K.lift_add(t, ba, v), ref.lift_add_ref(t, ba, v), rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(n=DIMS, m=DIMS, r=RANKS, seed=SEEDS)
def test_project_gradient_matches_ref(n, m, r, seed):
    rng = np.random.default_rng(seed)
    g, v = _mk(rng, m, n), _mk(rng, n, r)
    np.testing.assert_allclose(
        K.project_gradient(g, v), ref.project_gradient_ref(g, v),
        rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("shape", [(128, 256, 128, 8), (256, 128, 384, 4)])
def test_tile_aligned_shapes_exact_path(shape):
    """Tile-aligned shapes take the no-padding fast path."""
    b, n, m, r = shape
    rng = np.random.default_rng(7)
    x, w = _mk(rng, b, n), _mk(rng, m, n)
    ba, v = _mk(rng, m, r), _mk(rng, n, r)
    np.testing.assert_allclose(
        K.lowrank_linear(x, w, ba, v),
        ref.lowrank_linear_ref(x, w, ba, v), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_support(dtype):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(32, 64)), dtype)
    w = jnp.asarray(rng.normal(size=(48, 64)), dtype)
    ba = jnp.asarray(rng.normal(size=(48, 4)), dtype)
    v = jnp.asarray(rng.normal(size=(64, 4)), dtype)
    got = K.lowrank_linear(x, w, ba, v)
    want = ref.lowrank_linear_ref(x, w, ba, v)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tols(dtype))


def test_custom_vjp_routes_gradients_to_x_and_b_only():
    rng = np.random.default_rng(13)
    x = _mk(rng, 16, 24)
    w = _mk(rng, 20, 24)
    ba = _mk(rng, 20, 3)
    v = _mk(rng, 24, 3)

    def loss_k(x, w, ba, v):
        return jnp.sum(jnp.tanh(K.lowrank_linear_layer(x, w, ba, v)))

    def loss_r(x, w, ba, v):
        return jnp.sum(jnp.tanh(ref.lowrank_linear_ref(x, w, ba, v)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(x, w, ba, v)
    gr = jax.grad(loss_r, argnums=(0, 2))(x, w, ba, v)
    np.testing.assert_allclose(gk[0], gr[0], rtol=2e-3, atol=2e-3)  # dx
    np.testing.assert_allclose(gk[2], gr[1], rtol=2e-3, atol=2e-3)  # dB
    assert float(jnp.abs(gk[1]).max()) == 0.0  # W frozen
    assert float(jnp.abs(gk[3]).max()) == 0.0  # V frozen


def test_fused_never_materializes_weff_same_as_unfused():
    """Algebraic identity x(W + BVᵀ)ᵀ = xWᵀ + (xV)Bᵀ holds in f32."""
    rng = np.random.default_rng(17)
    x, w = _mk(rng, 40, 56), _mk(rng, 32, 56)
    ba, v = _mk(rng, 32, 4), _mk(rng, 56, 4)
    unfused = x @ (w + ba @ v.T).T
    fused = K.lowrank_linear(x, w, ba, v)
    np.testing.assert_allclose(fused, unfused, rtol=5e-3, atol=5e-3)


def test_grad_b_is_what_algorithm1_needs():
    """dB from the kernel equals the eq. (8) gradient computed by jax
    autodiff on the unfused parameterization."""
    rng = np.random.default_rng(19)
    x, w = _mk(rng, 24, 32), _mk(rng, 28, 32)
    ba, v = _mk(rng, 28, 2), _mk(rng, 32, 2)

    def f(b):
        return 0.5 * jnp.sum((x @ (w + b @ v.T).T) ** 2)

    g_auto = jax.grad(f)(ba)
    y = ref.lowrank_linear_ref(x, w, ba, v)
    g_kernel = K.lowrank_linear_grad_b(y, x, v)  # dy = y for ½‖y‖²
    np.testing.assert_allclose(g_kernel, g_auto, rtol=5e-3, atol=5e-3)
