"""L2 correctness: model shapes, gradient semantics, estimator identities."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.ModelConfig("tiny", vocab=64, d_model=32, n_layers=2, n_heads=2,
                     d_ff=48, seq_len=16, rank=4)
TINY_CLF = dataclasses.replace(TINY, causal=False, num_classes=4, name="tinyclf")


def _setup(cfg, seed=0):
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    bs = M.zero_bs(cfg)
    vs = M.identity_vs(cfg, jax.random.PRNGKey(seed + 1))
    return params, bs, vs


def _tokens(cfg, batch, extra=0, seed=3):
    n = batch * (cfg.seq_len + extra)
    return (jnp.arange(n, dtype=jnp.int32).reshape(batch, -1) * 31 + seed) % cfg.vocab


def test_param_count_matches_init():
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == M.param_count(TINY)


def test_lm_loss_is_finite_and_near_log_vocab_at_init():
    params, bs, vs = _setup(TINY)
    tokens = _tokens(TINY, 4, extra=1)
    loss = float(M.lm_loss(TINY, params, bs, vs, tokens))
    assert np.isfinite(loss)
    # random init ⇒ loss ≈ ln(vocab)
    assert abs(loss - np.log(TINY.vocab)) < 1.0


def test_lm_grad_step_shapes():
    params, bs, vs = _setup(TINY)
    tokens = _tokens(TINY, 4, extra=1)
    loss, dbs, dfull = M.lm_grad_step(TINY, params, bs, vs, tokens)
    assert np.isfinite(float(loss))
    for name, (m, n) in TINY.matrix_shapes():
        assert dbs[name].shape == (m, TINY.rank)
    assert dfull["embed"].shape == params["embed"].shape
    assert dfull["norm_final"].shape == params["norm_final"].shape


def test_db_equals_projected_full_gradient():
    """Theorem 1's proof identity: ∇_B F(Θ + BVᵀ)|_{B=0} = ∇_Θ F(Θ)·V.
    Check on one matrix by comparing dB against dW·V from full autodiff."""
    cfg = TINY
    params, bs, vs = _setup(cfg)
    tokens = _tokens(cfg, 2, extra=1)
    name = "layer0.wq"

    _, dbs, _ = M.lm_grad_step(cfg, params, bs, vs, tokens)

    def loss_wrt_w(w):
        p = dict(params)
        p[name] = w
        return M.lm_loss(cfg, p, bs, vs, tokens)

    dw = jax.grad(loss_wrt_w)(params[name])
    np.testing.assert_allclose(dbs[name], dw @ vs[name], rtol=1e-4, atol=1e-5)


def test_sgd_on_b_reduces_lm_loss():
    """A few Algorithm-1 inner steps in the sampled subspace must reduce
    the loss on a fixed batch."""
    cfg = TINY
    params, bs, vs = _setup(cfg)
    tokens = _tokens(cfg, 4, extra=1)
    l0, dbs, dfull = M.lm_grad_step(cfg, params, bs, vs, tokens)
    lr = 0.5
    for _ in range(5):
        loss, dbs, dfull = M.lm_grad_step(cfg, params, bs, vs, tokens)
        bs = {k: bs[k] - lr * dbs[k] for k in bs}
    l1, _, _ = M.lm_grad_step(cfg, params, bs, vs, tokens)
    assert float(l1) < float(l0), f"{float(l1)} !< {float(l0)}"


def test_lift_equivalence():
    """Θ_{t+1} = Θ_t + B Vᵀ gives the same loss as keeping (B, V)."""
    cfg = TINY
    params, bs, vs = _setup(cfg)
    tokens = _tokens(cfg, 2, extra=1)
    # random non-zero B
    bs = {k: jax.random.normal(jax.random.PRNGKey(9), b.shape, jnp.float32) * 0.01
          for k, b in bs.items()}
    loss_b = M.lm_loss(cfg, params, bs, vs, tokens)
    lifted = dict(params)
    for name, _ in cfg.matrix_shapes():
        lifted[name] = params[name] + bs[name] @ vs[name].T
    loss_lift = M.lm_eval_loss(cfg, lifted, tokens)
    np.testing.assert_allclose(float(loss_b), float(loss_lift), rtol=1e-5)


def test_pallas_and_jnp_paths_agree_on_lm_loss():
    cfg_j = TINY
    cfg_p = dataclasses.replace(TINY, use_pallas=True)
    params, bs, vs = _setup(cfg_j)
    bs = {k: jax.random.normal(jax.random.PRNGKey(4), b.shape, jnp.float32) * 0.02
          for k, b in bs.items()}
    tokens = _tokens(cfg_j, 2, extra=1)
    lj = float(M.lm_loss(cfg_j, params, bs, vs, tokens))
    lp = float(M.lm_loss(cfg_p, params, bs, vs, tokens))
    np.testing.assert_allclose(lj, lp, rtol=1e-4)


def test_pallas_and_jnp_paths_agree_on_gradients():
    cfg_j = TINY
    cfg_p = dataclasses.replace(TINY, use_pallas=True)
    params, bs, vs = _setup(cfg_j)
    tokens = _tokens(cfg_j, 2, extra=1)
    _, dbs_j, dfull_j = M.lm_grad_step(cfg_j, params, bs, vs, tokens)
    _, dbs_p, dfull_p = M.lm_grad_step(cfg_p, params, bs, vs, tokens)
    for k in dbs_j:
        np.testing.assert_allclose(dbs_j[k], dbs_p[k], rtol=5e-3, atol=1e-5)
    np.testing.assert_allclose(dfull_j["embed"], dfull_p["embed"],
                               rtol=5e-3, atol=1e-5)


def test_causal_mask_blocks_future_tokens():
    """Perturbing a future input token must not change earlier logits."""
    cfg = TINY
    params, bs, vs = _setup(cfg)
    tokens = _tokens(cfg, 1, extra=1)

    h1 = M._backbone(cfg, params, bs, vs, tokens[:, :-1])
    tok2 = tokens.at[0, -2].set((tokens[0, -2] + 7) % cfg.vocab)
    h2 = M._backbone(cfg, params, bs, vs, tok2[:, :-1])
    # positions strictly before the perturbed one are unchanged
    np.testing.assert_allclose(h1[0, : cfg.seq_len - 2], h2[0, : cfg.seq_len - 2],
                               rtol=1e-5, atol=1e-6)


def test_clf_zo_antithetic_symmetry():
    """σ → 0 ⇒ both ZO losses converge to the unperturbed loss; the
    difference divided by 2σ converges to the directional derivative."""
    cfg = TINY_CLF
    params, bs, vs = _setup(cfg)
    tokens = _tokens(cfg, 4)
    labels = jnp.asarray([0, 1, 2, 3], jnp.int32)
    zs = {nm: jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(5), i),
                                (m, cfg.rank), jnp.float32)
          for i, (nm, (m, n)) in enumerate(cfg.matrix_shapes())}
    zh = jnp.zeros_like(params["head"])
    base = float(M.clf_loss(cfg, params, bs, vs, tokens, labels))
    lp, lm_ = M.clf_zo_lowrank(cfg, params, zs, vs, zh, 1e-4, tokens, labels)
    assert abs(float(lp) - base) < 1e-2
    assert abs(float(lm_) - base) < 1e-2

    # directional derivative via autodiff on B
    def loss_b(bvals):
        return M.clf_loss(cfg, params, bvals, vs, tokens, labels)

    g = jax.grad(loss_b)(bs)
    dd = sum(float(jnp.vdot(g[k], zs[k])) for k in zs)
    fd = (float(lp) - float(lm_)) / (2 * 1e-4)
    np.testing.assert_allclose(fd, dd, rtol=2e-2, atol=1e-4)


def test_clf_eval_counts_correct():
    cfg = TINY_CLF
    params, _, _ = _setup(cfg)
    tokens = _tokens(cfg, 8)
    labels = jnp.zeros((8,), jnp.int32)
    loss_sum, correct = M.clf_eval(cfg, params, tokens, labels)
    assert 0 <= int(correct) <= 8
    assert float(loss_sum) > 0


def test_clf_ipa_full_vs_lowrank_grad_consistency():
    """LowRank-IPA dB must equal (full IPA dW)·V at B = 0."""
    cfg = TINY_CLF
    params, bs, vs = _setup(cfg)
    tokens = _tokens(cfg, 4)
    labels = jnp.asarray([0, 1, 2, 3], jnp.int32)
    _, full_grads = M.clf_ipa_full_grad(cfg, params, tokens, labels)
    _, dbs, dhead = M.clf_ipa_lowrank_grad(cfg, params, bs, vs, tokens, labels)
    for name, _ in cfg.matrix_shapes():
        np.testing.assert_allclose(dbs[name], full_grads[name] @ vs[name],
                                   rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(dhead, full_grads["head"], rtol=1e-5, atol=1e-7)
