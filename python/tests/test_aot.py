"""AOT contract tests: manifests are consistent, HLO text parses back
through the XLA client, goldens round-trip."""

import os

import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "INDEX.txt")),
    reason="artifacts not built (run `make artifacts`)")


def _artifacts():
    with open(os.path.join(ART, "INDEX.txt")) as f:
        return [l.strip() for l in f if l.strip()]


def _manifest(name):
    inputs, outputs, meta = [], [], {}
    with open(os.path.join(ART, f"{name}.manifest.txt")) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            if parts[0] == "input":
                inputs.append((parts[2], parts[3], parts[4]))
            elif parts[0] == "output":
                outputs.append((parts[2], parts[3], parts[4]))
            elif len(parts) >= 3 and parts[1] == "=":
                meta[parts[0]] = " ".join(parts[2:])
    return inputs, outputs, meta


def test_index_lists_all_expected_artifacts():
    names = _artifacts()
    for required in ["lm_grad_s", "lm_grad_m", "lm_grad_l", "lm_eval_s",
                     "lm_grad_s_pallas", "clf_ipa_grad", "clf_ipa_lowrank_grad",
                     "clf_zo_lowrank", "clf_zo_full", "clf_eval"]:
        assert required in names, f"missing artifact {required}"


@pytest.mark.parametrize("name", _artifacts() if os.path.exists(os.path.join(ART, "INDEX.txt")) else [])
def test_manifest_counts_consistent(name):
    inputs, outputs, meta = _manifest(name)
    assert len(inputs) == int(meta["num_inputs"])
    assert len(outputs) == int(meta["num_outputs"])
    for _, dt, shape in inputs + outputs:
        assert dt in ("f32", "i32")
        if shape != "scalar":
            dims = [int(d) for d in shape.split("x")]
            assert all(d > 0 for d in dims)


@pytest.mark.parametrize("name", ["lm_grad_s", "clf_eval", "clf_zo_lowrank"])
def test_hlo_text_parses_and_has_right_arity(name):
    with open(os.path.join(ART, f"{name}.hlo.txt")) as f:
        text = f.read()
    assert "ENTRY" in text
    inputs, _, _ = _manifest(name)
    # every parameter index appears in the HLO entry computation
    for i in range(len(inputs)):
        assert f"parameter({i})" in text, f"parameter({i}) missing in {name}"


def test_golden_files_match_manifest_shapes():
    name = "lm_grad_s"
    inputs, outputs, _ = _manifest(name)
    gdir = os.path.join(ART, "golden", name)
    for i, (_, dt, shape) in enumerate(inputs):
        path = os.path.join(gdir, f"in_{i:03d}.bin")
        assert os.path.exists(path)
        n_el = 1 if shape == "scalar" else int(np.prod([int(d) for d in shape.split("x")]))
        assert os.path.getsize(path) == 4 * n_el  # f32/i32 both 4B
    for i, (_, dt, shape) in enumerate(outputs):
        path = os.path.join(gdir, f"out_{i:03d}.bin")
        assert os.path.exists(path)


def test_golden_loss_is_reasonable():
    """The recorded loss output of lm_grad_s ≈ ln(vocab) at random init."""
    inputs, outputs, meta = _manifest("lm_grad_s")
    gdir = os.path.join(ART, "golden", "lm_grad_s")
    loss = np.fromfile(os.path.join(gdir, "out_000.bin"), np.float32)
    vocab = int(meta["vocab"])
    assert abs(float(loss[0]) - np.log(vocab)) < 1.5


def test_pallas_and_jnp_goldens_agree():
    """lm_grad_s and lm_grad_s_pallas were built from identical inputs;
    their recorded losses and gradients must agree."""
    g1 = os.path.join(ART, "golden", "lm_grad_s")
    g2 = os.path.join(ART, "golden", "lm_grad_s_pallas")
    l1 = np.fromfile(os.path.join(g1, "out_000.bin"), np.float32)
    l2 = np.fromfile(os.path.join(g2, "out_000.bin"), np.float32)
    np.testing.assert_allclose(l1, l2, rtol=1e-4)
    # first B-gradient output
    d1 = np.fromfile(os.path.join(g1, "out_001.bin"), np.float32)
    d2 = np.fromfile(os.path.join(g2, "out_001.bin"), np.float32)
    np.testing.assert_allclose(d1, d2, rtol=5e-3, atol=1e-5)
