"""L2: JAX model graphs for the paper's two training regimes.

* ``lm_*`` — a LLaMA-style decoder-only causal LM (RMSNorm, rotary
  attention, SwiGLU) whose attention/MLP weight matrices carry the
  paper's low-rank reparameterization W_eff = W + B·Vᵀ. The IPA train
  step differentiates **w.r.t. the auxiliary B only** for those matrices
  (Algorithm 1, eq. 8); embeddings and norms train full-rank (the GaLore
  convention the paper's pretraining experiments follow).
* ``clf_*`` — an encoder classifier (mean-pool head) for the RoBERTa
  fine-tuning experiments; the LR family trains it with the antithetic
  two-point ZO estimator of Example 3(ii), evaluated entirely inside the
  graph: loss(Θ + σZVᵀ) and loss(Θ − σZVᵀ) share one lowering, so the
  run-time never builds a backward graph (the paper's Vanilla-LR memory
  advantage).

Every matrix multiply on the reparameterized path routes through the L1
Pallas kernels when ``config.use_pallas`` is set; otherwise through the
identical pure-jnp oracle (``kernels.ref``). AOT lowering (aot.py) emits
both variants at the small scale so the Rust runtime can certify that the
kernel path and the oracle path agree end to end.

Model scales are CPU-proxy versions of the paper's LLaMA-20M/60M/100M
(DESIGN.md §2): same architecture family, shrunk dims.
"""

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.lowrank_matmul import lowrank_linear_layer

# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    rank: int
    causal: bool = True
    num_classes: int = 0  # 0 ⇒ LM (tied head); >0 ⇒ classifier
    use_pallas: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def matrix_shapes(self) -> List[Tuple[str, Tuple[int, int]]]:
        """The reparameterized (m, n) weight matrices, in layer order.
        Convention: forward is y = x·Wᵀ, so W is (out, in)."""
        d, f = self.d_model, self.d_ff
        shapes = []
        for l in range(self.n_layers):
            for nm, shp in [
                ("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)), ("wo", (d, d)),
                ("w1", (f, d)), ("w3", (f, d)), ("w2", (d, f)),
            ]:
                shapes.append((f"layer{l}.{nm}", shp))
        return shapes


# CPU-proxy scales for the paper's LLaMA-20M/60M/100M (DESIGN.md §2).
LM_SCALES: Dict[str, ModelConfig] = {
    "s": ModelConfig("llama-s", vocab=4096, d_model=128, n_layers=3, n_heads=4,
                     d_ff=384, seq_len=64, rank=8),
    "m": ModelConfig("llama-m", vocab=4096, d_model=192, n_layers=4, n_heads=4,
                     d_ff=576, seq_len=64, rank=8),
    "l": ModelConfig("llama-l", vocab=4096, d_model=256, n_layers=6, n_heads=4,
                     d_ff=768, seq_len=64, rank=8),
}

# RoBERTa-large proxy for the fine-tuning experiments (Table 1–3, Fig 6).
CLF_CONFIG = ModelConfig("clf", vocab=4096, d_model=128, n_layers=3, n_heads=4,
                         d_ff=384, seq_len=32, rank=4, causal=False,
                         num_classes=8)


def param_count(cfg: ModelConfig) -> int:
    n = cfg.vocab * cfg.d_model  # embedding (tied head for LM)
    for _, (m, k) in cfg.matrix_shapes():
        n += m * k
    n += cfg.n_layers * 2 * cfg.d_model + cfg.d_model  # norms
    if cfg.num_classes:
        n += cfg.num_classes * cfg.d_model
    return n


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    """Initialize. Layout (dict, insertion-ordered — the AOT manifest
    records the exact flatten order):
      embed (vocab, d), matrices {name: (m, n)}, norms, [head]."""
    keys = jax.random.split(key, 4 + len(cfg.matrix_shapes()))
    params: Dict[str, Any] = {}
    params["embed"] = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                        jnp.float32) * 0.02
    for i, (name, (m, n)) in enumerate(cfg.matrix_shapes()):
        params[name] = jax.random.normal(keys[1 + i], (m, n), jnp.float32) \
            * (2.0 / (m + n)) ** 0.5
    for l in range(cfg.n_layers):
        params[f"layer{l}.norm_attn"] = jnp.ones((cfg.d_model,), jnp.float32)
        params[f"layer{l}.norm_mlp"] = jnp.ones((cfg.d_model,), jnp.float32)
    params["norm_final"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.num_classes:
        params["head"] = jax.random.normal(keys[-1],
                                           (cfg.num_classes, cfg.d_model),
                                           jnp.float32) * 0.02
    return params


def zero_bs(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """B = 0 for every reparameterized matrix (inner-loop reset)."""
    return {name: jnp.zeros((m, cfg.rank), jnp.float32)
            for name, (m, n) in cfg.matrix_shapes()}


def identity_vs(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """Gaussian V draws (for python-side testing; at run time Rust
    samples V with the paper's optimal laws)."""
    vs = {}
    for i, (name, (m, n)) in enumerate(cfg.matrix_shapes()):
        k = jax.random.fold_in(key, i)
        vs[name] = jax.random.normal(k, (n, cfg.rank), jnp.float32) \
            / jnp.sqrt(cfg.rank * 1.0)
    return vs


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def _rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _rotary(x, seq_len, head_dim):
    """Rotary position embedding over the last axis (pairs)."""
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    angles = jnp.einsum("s,h->sh", t, freqs)  # (seq, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast over (batch, heads, seq, half)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _lowrank_matmul(cfg: ModelConfig, x2d, w, b, v):
    """y = x·W_effᵀ routed through the Pallas kernel or the jnp oracle."""
    if cfg.use_pallas:
        return lowrank_linear_layer(x2d, w, b, v)
    return ref.lowrank_linear_ref(x2d, w, b, v)


def _attention(cfg, h, params, bs, vs, layer):
    """Multi-head attention; every projection is low-rank-reparameterized."""
    bsz, seq, d = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    x2d = h.reshape(bsz * seq, d)

    def proj(nm):
        name = f"layer{layer}.{nm}"
        return _lowrank_matmul(cfg, x2d, params[name], bs[name], vs[name])

    q = proj("wq").reshape(bsz, seq, nh, hd).transpose(0, 2, 1, 3)
    k = proj("wk").reshape(bsz, seq, nh, hd).transpose(0, 2, 1, 3)
    v_ = proj("wv").reshape(bsz, seq, nh, hd).transpose(0, 2, 1, 3)
    if cfg.causal:
        q = _rotary(q, seq, hd)
        k = _rotary(k, seq, hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    if cfg.causal:
        mask = jnp.tril(jnp.ones((seq, seq), bool))
        scores = jnp.where(mask, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v_)
    out2d = out.transpose(0, 2, 1, 3).reshape(bsz * seq, d)
    name = f"layer{layer}.wo"
    y = _lowrank_matmul(cfg, out2d, params[name], bs[name], vs[name])
    return y.reshape(bsz, seq, d)


def _mlp(cfg, h, params, bs, vs, layer):
    bsz, seq, d = h.shape
    x2d = h.reshape(bsz * seq, d)

    def mm(nm, inp):
        name = f"layer{layer}.{nm}"
        return _lowrank_matmul(cfg, inp, params[name], bs[name], vs[name])

    gate = jax.nn.silu(mm("w1", x2d))
    up = mm("w3", x2d)
    y = mm("w2", gate * up)
    return y.reshape(bsz, seq, d)


def _backbone(cfg: ModelConfig, params, bs, vs, tokens):
    """Token ids (batch, seq) → hidden states (batch, seq, d)."""
    h = params["embed"][tokens]
    for l in range(cfg.n_layers):
        h = h + _attention(cfg, _rmsnorm(h, params[f"layer{l}.norm_attn"]),
                           params, bs, vs, l)
        h = h + _mlp(cfg, _rmsnorm(h, params[f"layer{l}.norm_mlp"]),
                     params, bs, vs, l)
    return _rmsnorm(h, params["norm_final"])


# ---------------------------------------------------------------------------
# LM: causal-language-model loss and the IPA train step
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, params, bs, vs, tokens):
    """Mean next-token cross-entropy. tokens: (batch, seq_len+1) int32."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    h = _backbone(cfg, params, bs, vs, inputs)
    logits = h @ params["embed"].T  # tied head
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_grad_step(cfg: ModelConfig, params, bs, vs, tokens):
    """(loss, dB for every matrix, d_embed, d_norms) — the LowRank-IPA
    estimator of eq. (8): ∂/∂B with W, V frozen; embeddings and norms get
    full-rank IPA gradients."""
    full_names = ["embed"] + [f"layer{l}.norm_attn" for l in range(cfg.n_layers)] \
        + [f"layer{l}.norm_mlp" for l in range(cfg.n_layers)] + ["norm_final"]

    def loss_fn(trainable):
        p = dict(params)
        for nm in full_names:
            p[nm] = trainable["full"][nm]
        return lm_loss(cfg, p, trainable["bs"], vs, tokens)

    trainable = {"full": {nm: params[nm] for nm in full_names}, "bs": bs}
    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    return loss, grads["bs"], grads["full"]


def lm_eval_loss(cfg: ModelConfig, params, tokens):
    """Eval loss at the lifted point (B already folded into params)."""
    bs = zero_bs(cfg)
    vs = {name: jnp.zeros((n, cfg.rank), jnp.float32)
          for name, (m, n) in cfg.matrix_shapes()}
    return lm_loss(cfg, params, bs, vs, tokens)


# ---------------------------------------------------------------------------
# Classifier: IPA + two-point ZO (LR family)
# ---------------------------------------------------------------------------


def clf_logits(cfg: ModelConfig, params, bs, vs, tokens):
    h = _backbone(cfg, params, bs, vs, tokens)
    pooled = jnp.mean(h, axis=1)  # (batch, d)
    return pooled @ params["head"].T


def clf_loss(cfg: ModelConfig, params, bs, vs, tokens, labels):
    logits = clf_logits(cfg, params, bs, vs, tokens)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def clf_ipa_full_grad(cfg: ModelConfig, params, tokens, labels):
    """Vanilla IPA (full backprop): loss + full gradients for all
    reparameterizable matrices and the head."""
    names = [nm for nm, _ in cfg.matrix_shapes()] + ["head"]
    bs, vs = zero_bs(cfg), {name: jnp.zeros((n, cfg.rank), jnp.float32)
                            for name, (m, n) in cfg.matrix_shapes()}

    def loss_fn(sub):
        p = dict(params)
        p.update(sub)
        return clf_loss(cfg, p, bs, vs, tokens, labels)

    sub = {nm: params[nm] for nm in names}
    loss, grads = jax.value_and_grad(loss_fn)(sub)
    return loss, grads


def clf_ipa_lowrank_grad(cfg: ModelConfig, params, bs, vs, tokens, labels):
    """LowRank-IPA: loss + (dB per matrix, d_head)."""

    def loss_fn(trainable):
        p = dict(params)
        p["head"] = trainable["head"]
        return clf_loss(cfg, p, trainable["bs"], vs, tokens, labels)

    trainable = {"bs": bs, "head": params["head"]}
    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    return loss, grads["bs"], grads["head"]


def clf_zo_lowrank(cfg: ModelConfig, params, zs, vs, z_head, sigma, tokens, labels):
    """LowRank-LR (Example 3(ii)): evaluate the two antithetic points
    W_eff = Θ ± σ·Z·Vᵀ *inside the graph* (B = ±σZ) and return both
    losses; Rust forms the estimator (F⁺ − F⁻)/(2σ)·ZVᵀ. The head is
    perturbed full-rank (it is tiny). No backward graph exists here."""

    def at(sign):
        bs = {nm: sign * sigma * z for nm, z in zs.items()}
        p = dict(params)
        p["head"] = params["head"] + sign * sigma * z_head
        return clf_loss(cfg, p, bs, vs, tokens, labels)

    return at(1.0), at(-1.0)


def clf_zo_full(cfg: ModelConfig, params, zs_full, z_head, sigma, tokens, labels):
    """Vanilla LR: full-rank antithetic perturbation Θ ± σZ on every
    matrix and the head (MeZO-style)."""
    vs = {name: jnp.zeros((n, cfg.rank), jnp.float32)
          for name, (m, n) in cfg.matrix_shapes()}
    bs0 = zero_bs(cfg)

    def at(sign):
        p = dict(params)
        for nm, z in zs_full.items():
            p[nm] = params[nm] + sign * sigma * z
        p["head"] = params["head"] + sign * sigma * z_head
        return clf_loss(cfg, p, bs0, vs, tokens, labels)

    return at(1.0), at(-1.0)


def clf_eval(cfg: ModelConfig, params, tokens, labels):
    """(summed loss, correct count) at the lifted point."""
    bs = zero_bs(cfg)
    vs = {name: jnp.zeros((n, cfg.rank), jnp.float32)
          for name, (m, n) in cfg.matrix_shapes()}
    logits = clf_logits(cfg, params, bs, vs, tokens)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss_sum = jnp.sum(logz - gold)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
    return loss_sum, correct
