"""Pure-jnp oracles for the Pallas kernels (L1 correctness contract).

Every Pallas kernel in this package has a reference implementation here;
pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis
and asserts allclose between kernel and oracle. The oracles are also the
fallback path the L2 model uses for shapes that don't tile cleanly.
"""

import jax.numpy as jnp  # noqa: F401  (kept for dtype helpers in callers)


def lowrank_linear_ref(x, w, b_aux, v):
    """y = x·Wᵀ + (x·V)·Bᵀ — the fused low-rank linear layer.

    The reparameterized weight is W_eff = W + B·Vᵀ (paper §4.1); the fused
    form never materializes W_eff:

        x·W_effᵀ = x·Wᵀ + x·(B Vᵀ)ᵀ = x·Wᵀ + (x·V)·Bᵀ.

    Shapes: x (batch, n), w (m, n), b_aux (m, r), v (n, r) → (batch, m).
    """
    return x @ w.T + (x @ v) @ b_aux.T


def lowrank_linear_grad_b_ref(dy, x, v):
    """∂loss/∂B = dyᵀ·(x·V) — the Algorithm 1 inner-step gradient.

    Shapes: dy (batch, m), x (batch, n), v (n, r) → (m, r).
    """
    return dy.T @ (x @ v)


def lowrank_linear_grad_x_ref(dy, w, b_aux, v):
    """∂loss/∂x = dy·W + (dy·B)·Vᵀ.

    Shapes: dy (batch, m), w (m, n), b_aux (m, r), v (n, r) → (batch, n).
    """
    return dy @ w + (dy @ b_aux) @ v.T


def lift_add_ref(theta, b_aux, v):
    """Θ + B·Vᵀ — the outer-iteration lift (Algorithm 1 line 8).

    Shapes: theta (m, n), b_aux (m, r), v (n, r) → (m, n).
    """
    return theta + b_aux @ v.T


def project_gradient_ref(g, v):
    """(G·V)·Vᵀ — project a full gradient onto span(V) and lift back
    (the LowRank-IPA estimator ĝ·P of Theorem 1's proof).

    Shapes: g (m, n), v (n, r) → (m, n).
    """
    return (g @ v) @ v.T
