"""L1 Pallas kernels for the low-rank estimator's compute hot-spots.

Hardware adaptation (DESIGN.md §2): the paper's CUDA-implied hot path is
re-thought for the TPU memory hierarchy. Each kernel tiles its *output*
into (TILE_B × TILE_M) VMEM blocks; the contracted dimension rides along
inside the block (full-K panels) so the MXU sees resident operands and
no partial-sum traffic returns to HBM. The rank-r factors (V, B) are tiny
(n·r, m·r) and are broadcast to every grid cell — exactly the paper's
memory story: the low-rank path adds O(r·(m+n)) to a kernel that already
streams O(m·n).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers the same kernel
logic to portable HLO (see /opt/xla-example/README.md). Real-TPU
efficiency is estimated analytically in DESIGN.md §6.

Every public function pads ragged shapes up to the tile grid and slices
the result back, so callers may use arbitrary shapes; the pure-jnp
oracles in ``ref.py`` define the numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-tile edges. 128 matches both the MXU systolic edge and the lane
# count; 8 is the f32 sublane count. Tiles are clamped to the (padded)
# problem size so tiny test shapes stay legal.
TILE_B = 128
TILE_M = 128

_INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls.


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _grid_sizes(batch, m):
    tb = min(TILE_B, batch) if batch % TILE_B else TILE_B
    tb = TILE_B if batch % TILE_B == 0 else batch  # pad path handles rest
    return tb


# ---------------------------------------------------------------------------
# fused low-rank linear: y = x·Wᵀ + (x·V)·Bᵀ
# ---------------------------------------------------------------------------


def _lowrank_linear_kernel(x_ref, w_ref, b_ref, v_ref, o_ref):
    # x_ref: (TB, n) — a batch tile with the full contracted dim resident.
    # w_ref: (TM, n) — an output-feature tile of W.
    # v_ref: (n, r), b_ref: (TM, r) — the rank-r factors.
    x = x_ref[...]
    base = jax.lax.dot_general(
        x, w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    xv = jax.lax.dot_general(
        x, v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    low = jax.lax.dot_general(
        xv, b_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = (base + low).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def lowrank_linear(x, w, b_aux, v):
    """Fused y = x·Wᵀ + (x·V)·Bᵀ. Shapes: x (B, n), w (m, n), b_aux (m, r),
    v (n, r) → (B, m). Arbitrary shapes accepted (padded to the tile grid).
    """
    batch, n = x.shape
    m, n2 = w.shape
    assert n == n2, f"x/w contraction mismatch: {n} vs {n2}"
    assert b_aux.shape[0] == m and v.shape[0] == n and b_aux.shape[1] == v.shape[1]

    xp = _pad_to(x, 0, TILE_B)
    wp = _pad_to(w, 0, TILE_M)
    bp = _pad_to(b_aux, 0, TILE_M)
    bp_, mp_ = xp.shape[0], wp.shape[0]
    grid = (bp_ // TILE_B, mp_ // TILE_M)

    out = pl.pallas_call(
        _lowrank_linear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, n), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_M, n), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_M, v.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((n, v.shape[1]), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, TILE_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp_, mp_), x.dtype),
        interpret=_INTERPRET,
    )(xp, wp, bp, v)
    return out[:batch, :m]


# ---------------------------------------------------------------------------
# backward w.r.t. B: dB = dyᵀ·(x·V)
# ---------------------------------------------------------------------------


def _grad_b_kernel(dy_ref, x_ref, v_ref, o_ref):
    # dy_ref: (batch, TM); x_ref: (batch, n); v_ref: (n, r) → o (TM, r)
    xv = jax.lax.dot_general(
        x_ref[...], v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jax.lax.dot_general(
        dy_ref[...], xv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@jax.jit
def lowrank_linear_grad_b(dy, x, v):
    """dB = dyᵀ·(x·V). Shapes: dy (B, m), x (B, n), v (n, r) → (m, r)."""
    batch, m = dy.shape
    _, n = x.shape
    r = v.shape[1]
    dyp = _pad_to(dy, 1, TILE_M)
    mp_ = dyp.shape[1]
    grid = (mp_ // TILE_M,)
    out = pl.pallas_call(
        _grad_b_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, TILE_M), lambda j: (0, j)),
            pl.BlockSpec((batch, n), lambda j: (0, 0)),
            pl.BlockSpec((n, r), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, r), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp_, r), dy.dtype),
        interpret=_INTERPRET,
    )(dyp, x, v)
    return out[:m]


# ---------------------------------------------------------------------------
# backward w.r.t. x: dx = dy·W + (dy·B)·Vᵀ
# ---------------------------------------------------------------------------


def _grad_x_kernel(dy_ref, w_ref, b_ref, v_ref, o_ref):
    # dy_ref: (TB, m); w_ref: (m, TN); b_ref: (m, r); v_ref: (TN, r)
    dy = dy_ref[...]
    base = jax.lax.dot_general(
        dy, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    dyb = jax.lax.dot_general(
        dy, b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    low = jax.lax.dot_general(
        dyb, v_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = (base + low).astype(o_ref.dtype)


@jax.jit
def lowrank_linear_grad_x(dy, w, b_aux, v):
    """dx = dy·W + (dy·B)·Vᵀ. Shapes: dy (B, m), w (m, n), b_aux (m, r),
    v (n, r) → (B, n)."""
    batch, m = dy.shape
    _, n = w.shape
    r = v.shape[1]
    dyp = _pad_to(dy, 0, TILE_B)
    wp = _pad_to(w, 1, TILE_M)
    vp = _pad_to(v, 0, TILE_M)
    bp_, np_ = dyp.shape[0], wp.shape[1]
    grid = (bp_ // TILE_B, np_ // TILE_M)
    out = pl.pallas_call(
        _grad_x_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, m), lambda i, j: (i, 0)),
            pl.BlockSpec((m, TILE_M), lambda i, j: (0, j)),
            pl.BlockSpec((m, r), lambda i, j: (0, 0)),
            pl.BlockSpec((TILE_M, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, TILE_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp_, np_), dy.dtype),
        interpret=_INTERPRET,
    )(dyp, wp, b_aux, vp)
    return out[:batch, :n]


# ---------------------------------------------------------------------------
# lift: Θ + B·Vᵀ
# ---------------------------------------------------------------------------


def _lift_kernel(t_ref, b_ref, v_ref, o_ref):
    low = jax.lax.dot_general(
        b_ref[...], v_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = (t_ref[...] + low).astype(o_ref.dtype)


@jax.jit
def lift_add(theta, b_aux, v):
    """Θ + B·Vᵀ (Algorithm 1 line 8). Shapes: theta (m, n), b_aux (m, r),
    v (n, r) → (m, n)."""
    m, n = theta.shape
    r = v.shape[1]
    tp = _pad_to(_pad_to(theta, 0, TILE_B), 1, TILE_M)
    bp = _pad_to(b_aux, 0, TILE_B)
    vp = _pad_to(v, 0, TILE_M)
    mp_, np_ = tp.shape
    grid = (mp_ // TILE_B, np_ // TILE_M)
    out = pl.pallas_call(
        _lift_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, TILE_M), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_B, r), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_M, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, TILE_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp_, np_), theta.dtype),
        interpret=_INTERPRET,
    )(tp, bp, vp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# gradient projection: (G·V)·Vᵀ
# ---------------------------------------------------------------------------


def _project_kernel(g_ref, v_ref, vt_ref, o_ref):
    gv = jax.lax.dot_general(
        g_ref[...], v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jax.lax.dot_general(
        gv, vt_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@jax.jit
def project_gradient(g, v):
    """(G·V)·Vᵀ — the LowRank-IPA projection ĝ·P without forming P.
    Shapes: g (m, n), v (n, r) → (m, n)."""
    m, n = g.shape
    r = v.shape[1]
    gp = _pad_to(g, 0, TILE_B)
    vp = _pad_to(v, 0, TILE_M)  # pad rows for the second (n-tiled) use
    mp_ = gp.shape[0]
    np_ = vp.shape[0]
    gp = _pad_to(gp, 1, TILE_M)
    grid = (mp_ // TILE_B, np_ // TILE_M)
    out = pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, r), lambda i, j: (0, 0)),
            pl.BlockSpec((TILE_M, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, TILE_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp_, np_), g.dtype),
        interpret=_INTERPRET,
    )(gp[:, :n], v, vp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# custom-VJP wrapper: the L2 model's low-rank linear layer
# ---------------------------------------------------------------------------


@jax.custom_vjp
def lowrank_linear_layer(x, w, b_aux, v):
    """Differentiable fused low-rank linear. Gradients flow to x and
    b_aux only (W is the frozen base weight, V is the sampled projector —
    both are non-trainable within an inner step, per Algorithm 1)."""
    return lowrank_linear(x, w, b_aux, v)


def _layer_fwd(x, w, b_aux, v):
    y = lowrank_linear(x, w, b_aux, v)
    return y, (x, w, b_aux, v)


def _layer_bwd(res, dy):
    x, w, b_aux, v = res
    dx = lowrank_linear_grad_x(dy, w, b_aux, v)
    db = lowrank_linear_grad_b(dy, x, v)
    # W and V receive zero cotangents: they are frozen inputs.
    return dx, jnp.zeros_like(w), db, jnp.zeros_like(v)


lowrank_linear_layer.defvjp(_layer_fwd, _layer_bwd)
