"""AOT pipeline: lower every L2 entry point to HLO text + manifest +
golden vectors. Runs once at build time (`make artifacts`); the Rust
runtime is self-contained afterwards.

Interchange format is HLO **text** — jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which the image's xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

For every artifact we emit:
  artifacts/<name>.hlo.txt       the computation
  artifacts/<name>.manifest.txt  `key = value` lines: inputs/outputs in
                                 exact parameter order (name dtype shape)
  artifacts/golden/<name>/       raw little-endian binaries of one
                                 example input/output set (small
                                 artifacts only) for the Rust
                                 integration test.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_entries(tree, prefix):
    """Flatten a pytree into (name, leaf) pairs in jax's flatten order."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves_with_path:
        name = prefix + jax.tree_util.keystr(path)
        out.append((name.replace("'", ""), leaf))
    return out


def _dtype_tag(x):
    return {"float32": "f32", "int32": "i32"}[str(x.dtype)]


class ArtifactWriter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.index = []
        os.makedirs(out_dir, exist_ok=True)
        os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    def emit(self, name, fn, example_args, arg_names, meta=None, golden=True):
        """Lower fn(*example_args), write hlo + manifest (+ golden)."""
        print(f"[aot] lowering {name} ...", flush=True)
        jitted = jax.jit(fn)
        lowered = jitted.lower(*example_args)
        hlo = to_hlo_text(lowered)
        with open(os.path.join(self.out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(hlo)

        # manifest: inputs in flatten order
        entries = []
        for arg, aname in zip(example_args, arg_names):
            entries.extend(_leaf_entries(arg, aname))
        outputs = jitted(*example_args)
        out_entries = _leaf_entries(outputs, "out")

        lines = [f"artifact = {name}"]
        for k, v in (meta or {}).items():
            lines.append(f"{k} = {v}")
        lines.append(f"num_inputs = {len(entries)}")
        lines.append(f"num_outputs = {len(out_entries)}")
        for i, (nm, leaf) in enumerate(entries):
            shape = "x".join(str(d) for d in leaf.shape) or "scalar"
            lines.append(f"input {i} {nm} {_dtype_tag(leaf)} {shape}")
        for i, (nm, leaf) in enumerate(out_entries):
            shape = "x".join(str(d) for d in np.asarray(leaf).shape) or "scalar"
            lines.append(f"output {i} {nm} {_dtype_tag(np.asarray(leaf))} {shape}")
        with open(os.path.join(self.out_dir, f"{name}.manifest.txt"), "w") as f:
            f.write("\n".join(lines) + "\n")

        if golden:
            gdir = os.path.join(self.out_dir, "golden", name)
            os.makedirs(gdir, exist_ok=True)
            for i, (_, leaf) in enumerate(entries):
                np.asarray(leaf).astype(np.asarray(leaf).dtype).tofile(
                    os.path.join(gdir, f"in_{i:03d}.bin"))
            for i, (_, leaf) in enumerate(out_entries):
                np.asarray(leaf).tofile(os.path.join(gdir, f"out_{i:03d}.bin"))
        self.index.append(name)
        print(f"[aot]   {name}: {len(entries)} inputs, {len(out_entries)} outputs,"
              f" {len(hlo)//1024} KiB hlo", flush=True)


# ---------------------------------------------------------------------------
# example-input builders (deterministic seeds so goldens are reproducible)
# ---------------------------------------------------------------------------


def lm_example(cfg, batch):
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    bs = M.zero_bs(cfg)
    vs = M.identity_vs(cfg, jax.random.PRNGKey(1))
    tokens = (jnp.arange(batch * (cfg.seq_len + 1), dtype=jnp.int32)
              .reshape(batch, cfg.seq_len + 1) * 40499 % cfg.vocab)
    return params, bs, vs, tokens


def clf_example(cfg, batch):
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    bs = M.zero_bs(cfg)
    vs = M.identity_vs(cfg, jax.random.PRNGKey(3))
    tokens = (jnp.arange(batch * cfg.seq_len, dtype=jnp.int32)
              .reshape(batch, cfg.seq_len) * 40503 % cfg.vocab)
    labels = (jnp.arange(batch, dtype=jnp.int32) * 7) % cfg.num_classes
    return params, bs, vs, tokens, labels


def zo_zs(cfg, key):
    zs = {}
    for i, (name, (m, n)) in enumerate(cfg.matrix_shapes()):
        zs[name] = jax.random.normal(jax.random.fold_in(key, i), (m, cfg.rank),
                                     jnp.float32)
    return zs


def zo_zs_full(cfg, key):
    zs = {}
    for i, (name, (m, n)) in enumerate(cfg.matrix_shapes()):
        zs[name] = jax.random.normal(jax.random.fold_in(key, i), (m, n),
                                     jnp.float32)
    return zs


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

LM_TRAIN_BATCH = 8
LM_EVAL_BATCH = 8
CLF_TRAIN_BATCH = 16
CLF_EVAL_BATCH = 64


def dump_init(out_dir, tag, params):
    """Write initial parameters as raw binaries, in the same flatten
    order the artifacts' `params` argument uses. The Rust trainers load
    these as Θ₀ so both languages agree on initialization exactly."""
    d = os.path.join(out_dir, "init", tag)
    os.makedirs(d, exist_ok=True)
    entries = _leaf_entries(params, "params")
    lines = []
    for i, (nm, leaf) in enumerate(entries):
        np.asarray(leaf).tofile(os.path.join(d, f"p_{i:03d}.bin"))
        shape = "x".join(str(s) for s in leaf.shape) or "scalar"
        lines.append(f"param {i} {nm} {_dtype_tag(leaf)} {shape}")
    with open(os.path.join(d, "params.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def build_all(out_dir):
    w = ArtifactWriter(out_dir)

    # ---- LM artifacts (pretraining, IPA family) per scale --------------
    for scale, cfg in M.LM_SCALES.items():
        params, bs, vs, tokens = lm_example(cfg, LM_TRAIN_BATCH)
        dump_init(out_dir, scale, params)
        meta = dict(model=cfg.name, scale=scale, d_model=cfg.d_model,
                    n_layers=cfg.n_layers, d_ff=cfg.d_ff, vocab=cfg.vocab,
                    seq_len=cfg.seq_len, rank=cfg.rank,
                    batch=LM_TRAIN_BATCH, params=M.param_count(cfg))
        w.emit(f"lm_grad_{scale}",
               functools.partial(M.lm_grad_step, cfg),
               (params, bs, vs, tokens),
               ("params", "bs", "vs", "tokens"),
               meta=meta, golden=(scale == "s"))
        ev_tokens = tokens[:LM_EVAL_BATCH]
        w.emit(f"lm_eval_{scale}",
               functools.partial(M.lm_eval_loss, cfg),
               (params, ev_tokens),
               ("params", "tokens"),
               meta=meta, golden=(scale == "s"))

    # Pallas-kernel variant at the small scale: proves the L1 kernels
    # lower into the same artifact pipeline and match the oracle path.
    cfg_p = dataclasses_replace(M.LM_SCALES["s"], use_pallas=True)
    params, bs, vs, tokens = lm_example(cfg_p, LM_TRAIN_BATCH)
    w.emit("lm_grad_s_pallas",
           functools.partial(M.lm_grad_step, cfg_p),
           (params, bs, vs, tokens),
           ("params", "bs", "vs", "tokens"),
           meta=dict(model="llama-s+pallas", rank=cfg_p.rank), golden=True)

    # ---- Classifier artifacts (fine-tuning) ----------------------------
    cfg = M.CLF_CONFIG
    params, bs, vs, tokens, labels = clf_example(cfg, CLF_TRAIN_BATCH)
    dump_init(out_dir, "clf", params)
    meta = dict(model=cfg.name, d_model=cfg.d_model, n_layers=cfg.n_layers,
                d_ff=cfg.d_ff, vocab=cfg.vocab, seq_len=cfg.seq_len,
                rank=cfg.rank, num_classes=cfg.num_classes,
                batch=CLF_TRAIN_BATCH, params=M.param_count(cfg))

    w.emit("clf_ipa_grad",
           functools.partial(M.clf_ipa_full_grad, cfg),
           (params, tokens, labels),
           ("params", "tokens", "labels"), meta=meta)

    w.emit("clf_ipa_lowrank_grad",
           functools.partial(M.clf_ipa_lowrank_grad, cfg),
           (params, bs, vs, tokens, labels),
           ("params", "bs", "vs", "tokens", "labels"), meta=meta)

    zs = zo_zs(cfg, jax.random.PRNGKey(4))
    z_head = jax.random.normal(jax.random.PRNGKey(5),
                               (cfg.num_classes, cfg.d_model), jnp.float32)
    sigma = jnp.float32(1e-3)
    w.emit("clf_zo_lowrank",
           functools.partial(M.clf_zo_lowrank, cfg),
           (params, zs, vs, z_head, sigma, tokens, labels),
           ("params", "zs", "vs", "z_head", "sigma", "tokens", "labels"),
           meta=meta)

    zs_full = zo_zs_full(cfg, jax.random.PRNGKey(6))
    w.emit("clf_zo_full",
           functools.partial(M.clf_zo_full, cfg),
           (params, zs_full, z_head, sigma, tokens, labels),
           ("params", "zs_full", "z_head", "sigma", "tokens", "labels"),
           meta=meta)

    ev_tokens = (jnp.arange(CLF_EVAL_BATCH * cfg.seq_len, dtype=jnp.int32)
                 .reshape(CLF_EVAL_BATCH, cfg.seq_len) * 40503 % cfg.vocab)
    ev_labels = (jnp.arange(CLF_EVAL_BATCH, dtype=jnp.int32) * 3) % cfg.num_classes
    w.emit("clf_eval",
           functools.partial(M.clf_eval, cfg),
           (params, ev_tokens, ev_labels),
           ("params", "tokens", "labels"), meta=meta)

    with open(os.path.join(out_dir, "INDEX.txt"), "w") as f:
        f.write("\n".join(w.index) + "\n")
    print(f"[aot] wrote {len(w.index)} artifacts to {out_dir}")


def dataclasses_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out)


if __name__ == "__main__":
    main()
