//! All-reduce cost at lifted-gradient sizes: the in-process pairing
//! tree vs the real multi-process comm collectives (2- and 4-rank ring
//! and tree over Unix-domain sockets on this host).
//!
//! Payload sizes follow the low-rank story — dB is m·r, so the wire
//! carries the LLaMA-proxy lifted gradients (m·r for the `s`/`m`/`l`
//! scale shapes) plus a 1M-element full-gradient reference point.
//! Reports median per-op latency, effective MB/s (2·(w−1)/w of the
//! payload each way per rank), and the per-step overhead next to the
//! `train_step` numbers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use lowrank_sge::bench_util::{bench, fmt_time, log_csv, report};
use lowrank_sge::comm::{Algorithm, CommConfig, Communicator, TransportKind};
use lowrank_sge::coordinator::allreduce_mean_with;
use lowrank_sge::kernel::KernelPool;

static DIR_COUNTER: AtomicUsize = AtomicUsize::new(0);

fn fresh_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lowrank_bench_allreduce_{}_{}",
        std::process::id(),
        DIR_COUNTER.fetch_add(1, Ordering::SeqCst)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn payload(rank: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| ((rank * 31 + i) as f32).sin() * 1e-3).collect()
}

/// In-process baseline: one pairing-tree mean over `world` shards.
fn bench_in_process(world: usize, len: usize, label: &str) {
    let pool = KernelPool::new(world.min(4));
    let mut grads: Vec<Vec<f32>> = (0..world).map(|r| payload(r, len)).collect();
    let stats = bench(3, 15, || {
        allreduce_mean_with(&pool, &mut grads);
        std::hint::black_box(&grads);
    });
    let name = format!("inproc_tree_{label}_w{world}");
    report(&name, &stats);
    log_csv("allreduce.csv", &name, &stats);
}

/// Multi-process: `world` communicator threads over Unix sockets, each
/// timing the same all-reduce; rank 0's stats are reported.
fn bench_comm(world: usize, len: usize, label: &str, algo: Algorithm) {
    let dir = fresh_dir();
    let stats = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let dir = dir.clone();
                scope.spawn(move || {
                    let cfg = CommConfig {
                        world,
                        rank: Some(rank),
                        transport: TransportKind::default_for_host(),
                        rdzv_dir: dir,
                        timeout: Duration::from_secs(60),
                        algo,
                    };
                    let mut comm = Communicator::connect(&cfg).expect("bench communicator");
                    let mut data = payload(rank, len);
                    bench(3, 15, || {
                        comm.allreduce_sum_with(algo, &mut data).unwrap();
                        std::hint::black_box(&data);
                    })
                })
            })
            .collect();
        let mut all = handles.into_iter().map(|h| h.join().expect("bench rank"));
        let rank0 = all.next().expect("world >= 1");
        for _ in all {} // join the rest
        rank0
    });
    // ring moves 2·(w−1)/w of the payload per rank each way; report
    // that as the effective bandwidth of the reduce
    let bytes = 4.0 * len as f64 * 2.0 * (world as f64 - 1.0) / world as f64;
    let mbps = bytes / stats.median_s / 1e6;
    let name = format!("comm_{}_{label}_w{world}", algo.name());
    report(&name, &stats);
    println!(
        "    {name}: {:.1} MB/s effective, {} per-step overhead vs in-process",
        mbps,
        fmt_time(stats.median_s)
    );
    log_csv("allreduce.csv", &name, &stats);
}

fn main() {
    println!("== all-reduce: in-process tree vs multi-process ring/tree ==");
    // (label, elements): lifted-gradient m·r at the LLaMA-proxy scale
    // shapes (d_model 128/192/256 × rank 16), and a 1M full-grad point
    let sizes: &[(&str, usize)] = &[
        ("lifted_s_2k", 128 * 16),
        ("lifted_m_3k", 192 * 16),
        ("lifted_l_4k", 256 * 16),
        ("lifted_stack_64k", 16 * 256 * 16),
        ("full_1m", 1_000_000),
    ];
    for &(label, len) in sizes {
        println!("-- {label}: {len} f32 ({} KiB) --", 4 * len / 1024);
        for world in [2usize, 4] {
            bench_in_process(world, len, label);
            bench_comm(world, len, label, Algorithm::Ring);
            bench_comm(world, len, label, Algorithm::Tree);
        }
    }
    println!("(context: compare per-step overhead against `cargo bench --bench train_step`)");
}
