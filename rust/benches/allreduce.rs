//! All-reduce cost at lifted-gradient sizes: the in-process pairing
//! tree vs the real multi-process comm collectives (2- and 4-rank ring
//! and tree over Unix-domain sockets on this host), in both wire
//! dtypes, plus the trainer's slot pipeline vs the serial per-slot
//! loop.
//!
//! Payload sizes follow the low-rank story — dB is m·r, so the wire
//! carries the LLaMA-proxy lifted gradients (m·r for the `s`/`m`/`l`
//! scale shapes) plus a 1M-element full-gradient reference point.
//! Reports median per-op latency and effective MB/s (2·(w−1)/w of the
//! *logical* f32 payload each way per rank — so the bf16 lane, moving
//! half the bytes for the same payload, should report ≈ 2× the MB/s of
//! f32 on the ring; the acceptance bar is ≥ 1.5×). The slot-pipeline
//! section times one step's worth of dB slots reduced serially vs
//! through `Collective::allreduce_mean_slots`, where slot k's chunk
//! reduce overlaps slot k+1's ring exchange.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use lowrank_sge::bench_util::{bench, fmt_time, log_csv, report, JsonReport};
use lowrank_sge::comm::{Algorithm, CommConfig, Communicator, TransportKind, WireDtype};
use lowrank_sge::coordinator::{allreduce_mean_with, Collective};
use lowrank_sge::kernel::simd::{self, SimdMode};
use lowrank_sge::kernel::KernelPool;

static DIR_COUNTER: AtomicUsize = AtomicUsize::new(0);

fn fresh_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lowrank_bench_allreduce_{}_{}",
        std::process::id(),
        DIR_COUNTER.fetch_add(1, Ordering::SeqCst)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn payload(rank: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| ((rank * 31 + i) as f32).sin() * 1e-3).collect()
}

fn bench_config(
    world: usize,
    rank: usize,
    dir: std::path::PathBuf,
    algo: Algorithm,
    dtype: WireDtype,
) -> CommConfig {
    CommConfig {
        world,
        rank: Some(rank),
        transport: TransportKind::default_for_host(),
        rdzv_dir: dir,
        timeout: Duration::from_secs(60),
        algo,
        wire_dtype: dtype,
        run_token: None,
    }
}

/// In-process baseline: one pairing-tree mean over `world` shards.
fn bench_in_process(world: usize, len: usize, label: &str, json: &mut JsonReport) {
    let pool = KernelPool::new(world.min(4));
    let mut grads: Vec<Vec<f32>> = (0..world).map(|r| payload(r, len)).collect();
    let stats = bench(3, 15, || {
        allreduce_mean_with(&pool, &mut grads);
        std::hint::black_box(&grads);
    });
    let name = format!("inproc_tree_{label}_w{world}");
    report(&name, &stats);
    log_csv("allreduce.csv", &name, &stats);
    json.entry(&name, len, &stats, None);
}

/// Multi-process: `world` communicator threads over Unix sockets, each
/// timing the same all-reduce; rank 0's stats are reported. Returns the
/// effective MB/s (logical f32 payload volume over median time).
fn bench_comm(
    world: usize,
    len: usize,
    label: &str,
    algo: Algorithm,
    dtype: WireDtype,
    json: &mut JsonReport,
) -> f64 {
    let dir = fresh_dir();
    let stats = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let dir = dir.clone();
                scope.spawn(move || {
                    let cfg = bench_config(world, rank, dir, algo, dtype);
                    let mut comm = Communicator::connect(&cfg).expect("bench communicator");
                    let mut data = payload(rank, len);
                    bench(3, 15, || {
                        comm.allreduce_sum_with(algo, &mut data).unwrap();
                        std::hint::black_box(&data);
                    })
                })
            })
            .collect();
        let mut all = handles.into_iter().map(|h| h.join().expect("bench rank"));
        let rank0 = all.next().expect("world >= 1");
        for _ in all {} // join the rest
        rank0
    });
    // ring moves 2·(w−1)/w of the logical payload per rank each way;
    // report that as the effective bandwidth of the reduce (the bf16
    // lane moves half the *bytes* for the same payload, so its MB/s
    // here directly shows the compression win)
    let bytes = 4.0 * len as f64 * 2.0 * (world as f64 - 1.0) / world as f64;
    let mbps = bytes / stats.median_s / 1e6;
    let name = format!("comm_{}_{}_{label}_w{world}", algo.name(), dtype.name());
    report(&name, &stats);
    println!(
        "    {name}: {:.1} MB/s effective, {} per-step overhead vs in-process",
        mbps,
        fmt_time(stats.median_s)
    );
    log_csv("allreduce.csv", &name, &stats);
    json.entry(&name, len, &stats, Some(mbps));
    mbps
}

/// One training step's collectives: `n_slots` dB-sized slots, reduced
/// serially (`allreduce_mean_shards` per slot) vs through the slot
/// pipeline (`allreduce_mean_slots` — chunk reduce overlapped with the
/// next slot's ring exchange). Ring is forced so the phase overlap is
/// what's measured; rank 0's medians are compared.
fn bench_slot_pipeline(
    world: usize,
    n_slots: usize,
    len: usize,
    dtype: WireDtype,
    json: &mut JsonReport,
) {
    let run = |pipelined: bool| -> lowrank_sge::bench_util::BenchStats {
        let dir = fresh_dir();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..world)
                .map(|rank| {
                    let dir = dir.clone();
                    scope.spawn(move || {
                        let cfg = bench_config(world, rank, dir, Algorithm::Ring, dtype);
                        let comm = Communicator::connect(&cfg).expect("bench communicator");
                        let mut collective = Collective::Comm(comm);
                        let mut slots: Vec<Vec<Vec<f32>>> = (0..n_slots)
                            .map(|k| vec![payload(rank * n_slots + k, len)])
                            .collect();
                        bench(2, 9, || {
                            if pipelined {
                                collective.allreduce_mean_slots(&mut slots).unwrap();
                            } else {
                                for g in slots.iter_mut() {
                                    collective.allreduce_mean_shards(g).unwrap();
                                }
                            }
                            std::hint::black_box(&slots);
                        })
                    })
                })
                .collect();
            let mut all = handles.into_iter().map(|h| h.join().expect("bench rank"));
            let rank0 = all.next().expect("world >= 1");
            for _ in all {}
            rank0
        })
    };
    let serial = run(false);
    let pipelined = run(true);
    let name_s = format!("slots_serial_{}_w{world}_{n_slots}x{len}", dtype.name());
    let name_p = format!("slots_pipelined_{}_w{world}_{n_slots}x{len}", dtype.name());
    report(&name_s, &serial);
    report(&name_p, &pipelined);
    println!(
        "    overlap win ({} slots × {len} f32, {}, w{world}): serial {} → pipelined {} \
         ({:.2}× speedup)",
        n_slots,
        dtype.name(),
        fmt_time(serial.median_s),
        fmt_time(pipelined.median_s),
        serial.median_s / pipelined.median_s
    );
    log_csv("allreduce.csv", &name_s, &serial);
    log_csv("allreduce.csv", &name_p, &pipelined);
    json.entry(&name_s, n_slots * len, &serial, None);
    json.entry(&name_p, n_slots * len, &pipelined, None);
}

/// The bf16 convert lane feeding the wire codec: round-trip MB/s of the
/// batch kernels under the forced-scalar emulation vs the dispatched
/// vector backend (`kernel::simd` — same bits either way, so the
/// speedup is pure throughput).
fn bench_bf16_convert(json: &mut JsonReport) {
    println!("== bf16 convert lane: forced-scalar vs SIMD (1M elements) ==");
    let len = 1_000_000usize;
    let src: Vec<f32> = (0..len).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
    let mut lanes = vec![0u16; len];
    let mut widened = vec![0.0f32; len];
    let bytes = 4.0 * len as f64;
    let prev = simd::mode();
    let mut mbps = [[0.0f64; 2]; 2];
    for (i, (mode, tag)) in
        [(SimdMode::Scalar, "scalar"), (SimdMode::Auto, "simd")].into_iter().enumerate()
    {
        simd::set_mode(mode);
        let backend = simd::active_backend();
        let q = bench(3, 15, || {
            simd::f32_to_bf16_batch(&src, &mut lanes);
            std::hint::black_box(&lanes);
        });
        let w = bench(3, 15, || {
            simd::bf16_to_f32_batch(&lanes, &mut widened);
            std::hint::black_box(&widened);
        });
        mbps[i] = [bytes / q.median_s / 1e6, bytes / w.median_s / 1e6];
        for (dir, stats, rate) in
            [("quantize", &q, mbps[i][0]), ("widen", &w, mbps[i][1])]
        {
            let name = format!("bf16_{dir}_1m_{tag}");
            report(&name, stats);
            println!("    {name}: {rate:.1} MB/s [{backend}]");
            log_csv("allreduce.csv", &name, stats);
            json.entry(&name, len, stats, Some(rate));
        }
    }
    simd::set_mode(prev);
    println!(
        "    SIMD speedup: quantize {:.2}x, widen {:.2}x (acceptance bar: >= 2x)",
        mbps[1][0] / mbps[0][0],
        mbps[1][1] / mbps[0][1]
    );
}

fn main() {
    let mut json = JsonReport::new("allreduce");
    bench_bf16_convert(&mut json);
    println!("== all-reduce: in-process tree vs multi-process ring/tree, f32 vs bf16 wire ==");
    // (label, elements): lifted-gradient m·r at the LLaMA-proxy scale
    // shapes (d_model 128/192/256 × rank 16), and a 1M full-grad point
    let sizes: &[(&str, usize)] = &[
        ("lifted_s_2k", 128 * 16),
        ("lifted_m_3k", 192 * 16),
        ("lifted_l_4k", 256 * 16),
        ("lifted_stack_64k", 16 * 256 * 16),
        ("full_1m", 1_000_000),
    ];
    for &(label, len) in sizes {
        println!("-- {label}: {len} f32 ({} KiB) --", 4 * len / 1024);
        for world in [2usize, 4] {
            bench_in_process(world, len, label, &mut json);
            let ring_f32 = bench_comm(world, len, label, Algorithm::Ring, WireDtype::F32, &mut json);
            let ring_bf16 =
                bench_comm(world, len, label, Algorithm::Ring, WireDtype::Bf16, &mut json);
            println!(
                "    ring bf16/f32 bandwidth: {:.2}x (acceptance bar: >= 1.5x)",
                ring_bf16 / ring_f32
            );
            bench_comm(world, len, label, Algorithm::Tree, WireDtype::F32, &mut json);
            bench_comm(world, len, label, Algorithm::Tree, WireDtype::Bf16, &mut json);
        }
    }
    println!("== slot pipeline: serial per-slot loop vs overlapped exchange/reduce ==");
    // one step of the `l`-scale proxy: 16 reparameterized matrices,
    // m·r = 4096 each — small enough that wire latency (not bandwidth)
    // dominates, which is exactly what the overlap hides — plus the
    // 64k stacked point where both lanes matter
    for world in [2usize, 4] {
        for dtype in [WireDtype::F32, WireDtype::Bf16] {
            bench_slot_pipeline(world, 16, 256 * 16, dtype, &mut json);
            bench_slot_pipeline(world, 8, 16 * 256 * 16, dtype, &mut json);
        }
    }
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write bench JSON: {e}"),
    }
    println!("(context: compare per-step overhead against `cargo bench --bench train_step`)");
}
