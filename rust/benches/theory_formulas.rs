//! Closed-form theory evaluation cost (these run inside training-time
//! diagnostics, so they must be trivially cheap) plus a correctness
//! spot-print of the §5 formulas at paper dimensions.

use lowrank_sge::bench_util::{bench, log_csv, report};
use lowrank_sge::estimator::theory;

fn main() {
    println!("-- closed forms at (n, r) = (1024, 128) --");
    let (n, r) = (1024usize, 128usize);
    let (txi, tth) = (3.7, 1.2);
    println!("  MSE_F          = {:.4}", theory::mse_full_rank(txi));
    println!("  MSE_iso (c=1)  = {:.4}", theory::mse_isotropic_exact(n, r, 1.0, txi, tth));
    println!("  MSE_G   (c=1)  = {:.4}", theory::mse_gaussian_exact(n, r, 1.0, txi, tth));
    println!("  Thm2 floor     = {:.1}", theory::thm2_floor(n, r, 1.0));
    println!("  eq14 bound     = {:.4}", theory::mse_upper_bound_eq14(n, r, 1.0, txi, tth));

    let spectrum: Vec<f64> = (0..n).map(|i| 2.0f64.powi(-((i / 64) as i32))).collect();
    let stats = bench(3, 30, || {
        std::hint::black_box(theory::phi_min(&spectrum, r, 1.0));
    });
    report("phi_min_n1024_r128", &stats);
    log_csv("theory.csv", "phi_min_n1024_r128", &stats);

    let stats = bench(3, 100, || {
        std::hint::black_box(theory::mse_gaussian_exact(n, r, 1.0, txi, tth));
    });
    report("mse_gaussian_exact", &stats);
    log_csv("theory.csv", "mse_gaussian_exact", &stats);

    let stats = bench(3, 30, || {
        std::hint::black_box(theory::mse_dependent_min(&spectrum, r, 1.0, tth));
    });
    report("mse_dependent_min_n1024", &stats);
    log_csv("theory.csv", "mse_dependent_min_n1024", &stats);
}
