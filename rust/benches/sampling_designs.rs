//! Fixed-size unequal-probability design cost (Algorithm 4 step 3):
//! systematic vs Sampford vs conditional-Poisson, plus the water-filling
//! solver.

use lowrank_sge::bench_util::{bench, log_csv, report};
use lowrank_sge::rng::Rng;
use lowrank_sge::sampling::{
    conditional_poisson_calibrate, optimal_inclusion, sample_conditional_poisson,
    sample_sampford, sample_systematic, DEFAULT_SIGMA_FLOOR,
};

fn skewed_sigma(n: usize) -> Vec<f64> {
    (0..n).map(|i| 1.5f64.powi(-(i as i32))).collect()
}

fn main() {
    println!("-- √σ water-filling (Theorem 3, eq. 17) --");
    for &(n, r) in &[(128usize, 8usize), (1024, 128), (4096, 128)] {
        let sigma = skewed_sigma(n);
        let stats = bench(2, 20, || {
            std::hint::black_box(optimal_inclusion(&sigma, r, DEFAULT_SIGMA_FLOOR));
        });
        let name = format!("waterfill_n{n}_r{r}");
        report(&name, &stats);
        log_csv("sampling.csv", &name, &stats);
    }

    println!("-- fixed-size π-ps designs (one draw) --");
    for &(n, r) in &[(128usize, 8usize), (1024, 64usize)] {
        let sigma = skewed_sigma(n);
        let pi = optimal_inclusion(&sigma, r, DEFAULT_SIGMA_FLOOR).pi;
        let mut rng = Rng::new(1);

        let stats = bench(2, 20, || {
            std::hint::black_box(sample_systematic(&pi, r, &mut rng));
        });
        let name = format!("systematic_n{n}_r{r}");
        report(&name, &stats);
        log_csv("sampling.csv", &name, &stats);

        let stats = bench(2, 10, || {
            std::hint::black_box(sample_sampford(&pi, r, &mut rng));
        });
        let name = format!("sampford_n{n}_r{r}");
        report(&name, &stats);
        log_csv("sampling.csv", &name, &stats);

        let design = conditional_poisson_calibrate(&pi, r);
        let stats = bench(2, 10, || {
            std::hint::black_box(sample_conditional_poisson(&design, &mut rng));
        });
        let name = format!("cps_draw_n{n}_r{r}");
        report(&name, &stats);
        log_csv("sampling.csv", &name, &stats);

        let stats = bench(1, 3, || {
            std::hint::black_box(conditional_poisson_calibrate(&pi, r));
        });
        let name = format!("cps_calibrate_n{n}_r{r}");
        report(&name, &stats);
        log_csv("sampling.csv", &name, &stats);
    }
}
