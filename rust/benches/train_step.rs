//! End-to-end per-step latency through the PJRT artifacts — the
//! Table 3 measurement at proxy scale, plus the pretraining step cost
//! per scale. Opens with the estimator-engine steady-state allocation
//! counter (a counting global allocator asserting the LowRank-LR step
//! loop is heap-allocation-free after warm-up) and a serial-vs-parallel
//! comparison of the kernel-substrate step work (lift fan-out, DDP
//! all-reduce) that needs no artifacts; the artifact sections skip
//! gracefully when missing.

use lowrank_sge::bench_util::{bench, engine_fixture, log_csv, report, CountingAlloc, JsonReport};
use lowrank_sge::coordinator::{
    allreduce_mean_with, FinetuneConfig, FinetuneMethod, FinetuneTrainer, PretrainConfig,
    PretrainTrainer, SubspaceSet,
};
use lowrank_sge::estimator::engine::{GradEstimator, GradSignal, MethodShape};
use lowrank_sge::kernel::KernelPool;
use lowrank_sge::model::ParamStore;
use lowrank_sge::optim::AdamConfig;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::rng::Rng;
use lowrank_sge::runtime::Runtime;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Steady-state allocations per LowRank-LR engine step (synthetic
/// 3-matrix + head problem, serial pool). Asserts the zero-allocation
/// contract the engine documents — same fixture and counter as
/// `tests/engine_alloc.rs`, at pretraining-like shapes.
fn engine_alloc_steady_state() {
    println!("-- estimator engine: steady-state allocations per step --");
    lowrank_sge::kernel::set_global_threads(1);
    let dims = [(384usize, 384usize, 16usize), (384, 128, 8), (128, 384, 8)];
    let head_len = 128usize;
    let (mut store, slots) = engine_fixture(&dims, head_len);
    let sub = SubspaceSet::from_slots(slots, ProjectorKind::Stiefel, 1.0);
    let mut engine = GradEstimator::new(
        MethodShape::LowRankLr,
        1e-2,
        Some(sub),
        Vec::new(),
        Vec::new(),
        Some((dims.len(), head_len, AdamConfig::default())),
    );
    let mut rng = Rng::new(11);
    engine.subspace.as_mut().unwrap().resample(&mut rng);
    let mut step_once = |step: u64, engine: &mut GradEstimator, store: &mut ParamStore| {
        engine.draw_perturbations(&mut rng);
        let fp = 0.8 + (step as f32) * 0.003;
        let fm = 0.7 - (step as f32) * 0.002;
        engine
            .step(store, GradSignal::Antithetic { f_plus: fp, f_minus: fm }, 1e-3)
            .unwrap();
    };
    for step in 0..3 {
        step_once(step, &mut engine, &mut store); // warm-up
    }
    let steps = 50u64;
    let before = CountingAlloc::count();
    for step in 3..3 + steps {
        step_once(step, &mut engine, &mut store);
    }
    let delta = CountingAlloc::count() - before;
    println!(
        "lowrank_lr_engine_step: {delta} heap allocations over {steps} steps \
         ({:.2} per step)",
        delta as f64 / steps as f64
    );
    assert_eq!(delta, 0, "LowRank-LR steady-state step loop must not allocate");
}

fn main() -> anyhow::Result<()> {
    let mut json = JsonReport::new("train_step");
    engine_alloc_steady_state();

    // Kernel-substrate step costs (no artifacts needed): the per-step
    // pieces the trainers run on the pool, serial vs parallel.
    println!("-- per-step kernel work: serial vs 4-thread pool --");
    for threads in [1usize, 4] {
        let pool = KernelPool::new(threads);

        // lift fan-out proxy: 8 slots of 384×384 rank-16, Θ += B·Vᵀ
        let slots = 8usize;
        let (m, n, r) = (384usize, 384usize, 16usize);
        let b: Vec<f32> = (0..m * r).map(|i| (i as f32) * 1e-4).collect();
        let v: Vec<f32> = (0..n * r).map(|i| (i as f32) * 1e-4 - 0.1).collect();
        let mut thetas: Vec<Vec<f32>> = vec![vec![0.0f32; m * n]; slots];
        let stats = bench(2, 10, || {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for theta in thetas.iter_mut() {
                let (b, v) = (&b, &v);
                tasks.push(Box::new(move || {
                    lowrank_sge::kernel::serial::gemm_nt(1.0f32, b, v, theta, m, n, r)
                }));
            }
            pool.run(tasks);
            std::hint::black_box(&thetas);
        });
        let name = format!("lift_fanout_{slots}x{m}x{n}_r{r}_t{threads}");
        report(&name, &stats);
        log_csv("train_step.csv", &name, &stats);
        json.entry(&name, slots * m * n, &stats, None);

        // DDP all-reduce: 4 worker shards of 1M f32, fixed pairing tree
        let mut grads: Vec<Vec<f32>> =
            (0..4).map(|w| (0..1_000_000).map(|i| ((w * 7 + i) as f32) * 1e-6).collect()).collect();
        let stats = bench(2, 10, || {
            allreduce_mean_with(&pool, &mut grads);
            std::hint::black_box(&grads);
        });
        let name = format!("allreduce_4x1M_t{threads}");
        report(&name, &stats);
        log_csv("train_step.csv", &name, &stats);
        json.entry(&name, 4_000_000, &stats, Some(16e6 / stats.median_s / 1e6));
    }

    // the same lift fan-out under the forced-scalar lane emulation vs
    // the dispatched vector core (serial pool isolates the SIMD win;
    // the bits are identical either way — fixed-lane contract)
    println!("-- lift fan-out: forced-scalar vs SIMD (serial pool) --");
    {
        use lowrank_sge::kernel::simd::{self, SimdMode};
        let pool = KernelPool::new(1);
        let slots = 8usize;
        let (m, n, r) = (384usize, 384usize, 16usize);
        let b: Vec<f32> = (0..m * r).map(|i| (i as f32) * 1e-4).collect();
        let v: Vec<f32> = (0..n * r).map(|i| (i as f32) * 1e-4 - 0.1).collect();
        let mut thetas: Vec<Vec<f32>> = vec![vec![0.0f32; m * n]; slots];
        let prev = simd::mode();
        let mut med = [0.0f64; 2];
        for (i, (mode, tag)) in
            [(SimdMode::Scalar, "scalar"), (SimdMode::Auto, "simd")].into_iter().enumerate()
        {
            simd::set_mode(mode);
            let backend = simd::active_backend();
            let stats = bench(2, 10, || {
                let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                for theta in thetas.iter_mut() {
                    let (b, v) = (&b, &v);
                    tasks.push(Box::new(move || {
                        lowrank_sge::kernel::serial::gemm_nt(1.0f32, b, v, theta, m, n, r)
                    }));
                }
                pool.run(tasks);
                std::hint::black_box(&thetas);
            });
            let name = format!("lift_fanout_{slots}x{m}x{n}_r{r}_{tag}");
            report(&name, &stats);
            println!("{:>60}", format!("[{backend}]"));
            log_csv("train_step.csv", &name, &stats);
            json.entry(&name, slots * m * n, &stats, None);
            med[i] = stats.median_s;
        }
        simd::set_mode(prev);
        println!(
            "{:>60}",
            format!("SIMD speedup over forced-scalar: {:.2}x", med[0] / med[1])
        );
    }

    // fresh Haar draw vs warm-started tracked refresh at LLaMA-proxy
    // projector shapes (serial pool isolates the algorithmic win). The
    // warm path replaces the n×r Gaussian + full QR with a rank-1 kick
    // and an r×r Cholesky-QR — same Theorem-2 frame property, n+r
    // normal draws instead of n·r.
    println!("-- subspace resample: fresh QR vs warm-started tracking --");
    {
        use lowrank_sge::linalg::Mat;
        use lowrank_sge::projection::{sample_batch, track_batch};
        lowrank_sge::kernel::set_global_threads(1);
        let mut worst_speedup = f64::INFINITY;
        for (tag, dims) in [
            ("8x384_r16", vec![(384usize, 16usize); 8]),
            ("4x2048_r64", vec![(2048usize, 64usize); 4]),
        ] {
            let elems: usize = dims.iter().map(|&(n, r)| n * r).sum();
            let mut rng = Rng::new(42);
            let fresh = bench(2, 10, || {
                std::hint::black_box(sample_batch(
                    ProjectorKind::Stiefel,
                    &dims,
                    1.0,
                    None,
                    &mut rng,
                ));
            });
            let name = format!("resample_fresh_{tag}");
            report(&name, &fresh);
            log_csv("train_step.csv", &name, &fresh);
            json.entry(&name, elems, &fresh, None);

            let mut rng = Rng::new(42);
            let mut frames: Vec<Option<Mat>> = (0..dims.len()).map(|_| None).collect();
            // seed the frames with the one full draw every tracked run
            // pays, then time the steady-state warm refresh
            std::hint::black_box(track_batch(&dims, 1.0, &mut frames, true, &mut rng));
            let warm = bench(2, 10, || {
                std::hint::black_box(track_batch(&dims, 1.0, &mut frames, false, &mut rng));
            });
            let name = format!("resample_warm_{tag}");
            report(&name, &warm);
            log_csv("train_step.csv", &name, &warm);
            json.entry(&name, elems, &warm, None);

            let speedup = fresh.median_s / warm.median_s;
            println!("{:>60}", format!("warm-start speedup: {speedup:.2}x"));
            worst_speedup = worst_speedup.min(speedup);
        }
        assert!(
            worst_speedup >= 2.0,
            "warm-started resample must be ≥ 2x faster than a fresh draw \
             (got {worst_speedup:.2}x)"
        );
    }

    // rank-controller payoff: the subspace step work (Adam on B + lift)
    // before and after shrinking every slot to half rank, with the
    // released state visible in the live-bytes ledger.
    println!("-- rank shrink: step cost and state before/after --");
    {
        lowrank_sge::kernel::set_global_threads(1);
        let dims = [(384usize, 384usize, 16usize), (384, 128, 8), (128, 384, 8)];
        let (mut store, slots) = engine_fixture(&dims, 128);
        let mut sub = SubspaceSet::from_slots(slots, ProjectorKind::Stiefel, 1.0);
        let mut rng = Rng::new(7);
        sub.resample(&mut rng);
        let mut med = [0.0f64; 2];
        let mut live = [0usize; 2];
        for (i, tag) in ["full_rank", "half_rank"].into_iter().enumerate() {
            if i == 1 {
                // boundary discipline: lift first (B spent), then shrink
                sub.lift(&mut store)?;
                for s in 0..dims.len() {
                    let r = sub.slots[s].r;
                    sub.shrink_slot_rank(s, (r / 2).max(1))?;
                }
                sub.resample(&mut rng);
            }
            let grads: Vec<Vec<f32>> =
                sub.slots.iter().map(|s| vec![0.01f32; s.m * s.r]).collect();
            let stats = bench(2, 10, || {
                sub.adam_step_all(&grads, 1e-3);
                sub.lift(&mut store).unwrap();
                std::hint::black_box(&sub);
            });
            let name = format!("subspace_step_{tag}");
            report(&name, &stats);
            log_csv("train_step.csv", &name, &stats);
            json.entry(&name, sub.b_elements(), &stats, None);
            println!(
                "{:>60}",
                format!(
                    "B elems {}  optimizer state {} B  live {} B",
                    sub.b_elements(),
                    sub.optimizer_state_bytes(),
                    CountingAlloc::live_bytes()
                )
            );
            med[i] = stats.median_s;
            live[i] = CountingAlloc::live_bytes();
        }
        println!(
            "{:>60}",
            format!(
                "post-shrink: step {:.2}x faster, {} B released",
                med[0] / med[1],
                live[0].saturating_sub(live[1])
            )
        );
        assert!(med[1] < med[0], "half-rank subspace step must be cheaper than full-rank");
    }

    let dir = artifacts_dir();
    if !dir.join("INDEX.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts` first; skipping");
        if let Ok(path) = json.write() {
            println!("wrote {}", path.display());
        }
        return Ok(());
    }
    let mut rt = Runtime::new(&dir)?;

    println!("-- Table 3 shape: fine-tune per-step wall clock (proxy) --");
    for method in [
        FinetuneMethod::VanillaIpa,
        FinetuneMethod::LowRankIpa(ProjectorKind::Stiefel),
        FinetuneMethod::VanillaLr,
        FinetuneMethod::LowRankLr(ProjectorKind::Stiefel),
    ] {
        let mut cfg = FinetuneConfig::quick("sst2", method);
        cfg.steps = 12;
        cfg.k_interval = 6;
        let mut trainer = FinetuneTrainer::new(&mut rt, &dir, cfg)?;
        let res = trainer.run()?;
        let mean = res.log.mean_step_time(2).unwrap_or(f64::NAN);
        println!("{:<28} {:.4} s/step", method.name(), mean);
        let stats = lowrank_sge::bench_util::BenchStats {
            iters: res.log.records.len() - 2,
            mean_s: mean,
            median_s: mean,
            min_s: mean,
            max_s: mean,
        };
        let name = format!("finetune_{}", method.name());
        log_csv("train_step.csv", &name, &stats);
        json.entry(&name, res.log.records.len(), &stats, None);
    }

    println!("-- pretrain step cost per scale (Stiefel LowRank-IPA) --");
    for scale in ["s", "m", "l"] {
        let mut cfg = PretrainConfig::quick(scale, ProjectorKind::Stiefel);
        cfg.steps = 8;
        cfg.k_interval = 4;
        cfg.eval_every = 0;
        let mut trainer = PretrainTrainer::new(&mut rt, &dir, cfg)?;
        let res = trainer.run()?;
        let mean = res.log.mean_step_time(2).unwrap_or(f64::NAN);
        println!("llama-{scale:<24} {:.4} s/step", mean);
        let stats = lowrank_sge::bench_util::BenchStats {
            iters: res.log.records.len() - 2,
            mean_s: mean,
            median_s: mean,
            min_s: mean,
            max_s: mean,
        };
        let name = format!("pretrain_{scale}");
        log_csv("train_step.csv", &name, &stats);
        json.entry(&name, res.log.records.len(), &stats, None);
    }

    println!("-- raw artifact execute latency (lm_grad_s) --");
    let art = rt.load("lm_grad_s")?;
    let inputs = rt.golden_inputs(&art)?;
    let stats = bench(2, 10, || {
        std::hint::black_box(art.execute(&inputs).unwrap());
    });
    report("execute_lm_grad_s", &stats);
    log_csv("train_step.csv", "execute_lm_grad_s", &stats);
    json.entry("execute_lm_grad_s", 1, &stats, None);

    let art_p = rt.load("lm_grad_s_pallas")?;
    let stats_p = bench(2, 10, || {
        std::hint::black_box(art_p.execute(&inputs).unwrap());
    });
    report("execute_lm_grad_s_pallas", &stats_p);
    log_csv("train_step.csv", "execute_lm_grad_s_pallas", &stats_p);
    json.entry("execute_lm_grad_s_pallas", 1, &stats_p, None);
    println!(
        "pallas/jnp latency ratio: {:.2}×",
        stats_p.median_s / stats.median_s
    );
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write bench JSON: {e}"),
    }
    Ok(())
}
