//! End-to-end per-step latency through the PJRT artifacts — the
//! Table 3 measurement at proxy scale, plus the pretraining step cost
//! per scale. Skips gracefully when artifacts are missing.

use lowrank_sge::bench_util::{bench, log_csv, report};
use lowrank_sge::coordinator::{FinetuneConfig, FinetuneMethod, FinetuneTrainer, PretrainConfig, PretrainTrainer};
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::runtime::Runtime;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    if !dir.join("INDEX.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts` first; skipping");
        return Ok(());
    }
    let mut rt = Runtime::new(&dir)?;

    println!("-- Table 3 shape: fine-tune per-step wall clock (proxy) --");
    for method in [
        FinetuneMethod::VanillaIpa,
        FinetuneMethod::LowRankIpa(ProjectorKind::Stiefel),
        FinetuneMethod::VanillaLr,
        FinetuneMethod::LowRankLr(ProjectorKind::Stiefel),
    ] {
        let mut cfg = FinetuneConfig::quick("sst2", method);
        cfg.steps = 12;
        cfg.k_interval = 6;
        let mut trainer = FinetuneTrainer::new(&mut rt, &dir, cfg)?;
        let res = trainer.run()?;
        let mean = res.log.mean_step_time(2).unwrap_or(f64::NAN);
        println!("{:<28} {:.4} s/step", method.name(), mean);
        log_csv(
            "train_step.csv",
            &format!("finetune_{}", method.name()),
            &lowrank_sge::bench_util::BenchStats {
                iters: res.log.records.len() - 2,
                mean_s: mean,
                median_s: mean,
                min_s: mean,
                max_s: mean,
            },
        );
    }

    println!("-- pretrain step cost per scale (Stiefel LowRank-IPA) --");
    for scale in ["s", "m", "l"] {
        let mut cfg = PretrainConfig::quick(scale, ProjectorKind::Stiefel);
        cfg.steps = 8;
        cfg.k_interval = 4;
        cfg.eval_every = 0;
        let mut trainer = PretrainTrainer::new(&mut rt, &dir, cfg)?;
        let res = trainer.run()?;
        let mean = res.log.mean_step_time(2).unwrap_or(f64::NAN);
        println!("llama-{scale:<24} {:.4} s/step", mean);
        log_csv(
            "train_step.csv",
            &format!("pretrain_{scale}"),
            &lowrank_sge::bench_util::BenchStats {
                iters: res.log.records.len() - 2,
                mean_s: mean,
                median_s: mean,
                min_s: mean,
                max_s: mean,
            },
        );
    }

    println!("-- raw artifact execute latency (lm_grad_s) --");
    let art = rt.load("lm_grad_s")?;
    let inputs = rt.golden_inputs(&art)?;
    let stats = bench(2, 10, || {
        std::hint::black_box(art.execute(&inputs).unwrap());
    });
    report("execute_lm_grad_s", &stats);
    log_csv("train_step.csv", "execute_lm_grad_s", &stats);

    let art_p = rt.load("lm_grad_s_pallas")?;
    let stats_p = bench(2, 10, || {
        std::hint::black_box(art_p.execute(&inputs).unwrap());
    });
    report("execute_lm_grad_s_pallas", &stats_p);
    log_csv("train_step.csv", "execute_lm_grad_s_pallas", &stats_p);
    println!(
        "pallas/jnp latency ratio: {:.2}×",
        stats_p.median_s / stats.median_s
    );
    Ok(())
}
