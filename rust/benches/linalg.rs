//! Dense-linalg hot paths: GEMM (the toy-experiment inner loop), QR
//! (Stiefel draws), Jacobi eigensolver (Algorithm 4 setup), f32 lift —
//! plus the serial-vs-parallel comparison of the shared kernel
//! substrate (same bits at every thread count; see `kernel` docs).

use lowrank_sge::bench_util::{bench, log_csv, report, JsonReport};
use lowrank_sge::kernel::simd::{self, SimdMode};
use lowrank_sge::kernel::{self, KernelPool};
use lowrank_sge::linalg::{matmul, matmul_tn, sym_eig, thin_qr, Mat};
use lowrank_sge::model::lift_into;
use lowrank_sge::rng::Rng;

fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

fn main() {
    let mut json = JsonReport::new("linalg");
    println!("-- kernel GEMM: serial vs parallel (1024x1024x64, f64) --");
    // the acceptance shape: C (1024×64) = A (1024×1024) · B (1024×64)
    let (m, k, n) = (1024usize, 1024usize, 64usize);
    let a = rand_mat(m, k, 40);
    let b = rand_mat(k, n, 41);
    let mut medians = Vec::new();
    for threads in [1usize, 2, 4] {
        let pool = KernelPool::new(threads);
        let mut c = vec![0.0f64; m * n];
        let stats = bench(2, 10, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            kernel::gemm_nn(&pool, &a.data, &b.data, &mut c, m, k, n);
            std::hint::black_box(&c);
        });
        let name = format!("gemm_kernel_{m}x{k}x{n}_t{threads}");
        report(&name, &stats);
        let flops = 2.0 * (m * k * n) as f64;
        println!("{:>60}", format!("≈ {:.2} GFLOP/s", flops / stats.median_s / 1e9));
        log_csv("linalg.csv", &name, &stats);
        json.entry(&name, m * k * n, &stats, None);
        medians.push((threads, stats.median_s));
    }
    if let (Some(&(_, serial)), Some(&(_, par4))) = (medians.first(), medians.last()) {
        println!(
            "{:>60}",
            format!("4-thread speedup over serial: {:.2}x", serial / par4)
        );
    }

    println!("-- f32 GEMM: forced-scalar vs SIMD (same bits, fixed-lane contract) --");
    {
        let (m, k, n) = (1024usize, 1024usize, 64usize);
        let mut rng = Rng::new(42);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let pool = KernelPool::new(1); // serial: isolates the vector-core speedup
        let flops = 2.0 * (m * k * n) as f64;
        let prev = simd::mode();
        let mut med = [0.0f64; 2];
        for (i, (mode, tag)) in
            [(SimdMode::Scalar, "scalar"), (SimdMode::Auto, "simd")].into_iter().enumerate()
        {
            simd::set_mode(mode);
            let backend = simd::active_backend();
            let mut c = vec![0.0f32; m * n];
            let stats = bench(2, 10, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                kernel::gemm_nn(&pool, &a, &b, &mut c, m, k, n);
                std::hint::black_box(&c);
            });
            let name = format!("gemm_f32_{m}x{k}x{n}_{tag}");
            report(&name, &stats);
            println!(
                "{:>60}",
                format!("≈ {:.2} GFLOP/s [{backend}]", flops / stats.median_s / 1e9)
            );
            log_csv("linalg.csv", &name, &stats);
            json.entry(&name, m * k * n, &stats, None);
            med[i] = stats.median_s;
        }
        simd::set_mode(prev);
        println!(
            "{:>60}",
            format!("SIMD speedup over forced-scalar: {:.2}x", med[0] / med[1])
        );
    }

    println!("-- f64 GEMM (toy-experiment inner loop) --");
    for &n in &[64usize, 128, 256] {
        let a = rand_mat(n, n, 1);
        let b = rand_mat(n, n, 2);
        let stats = bench(2, 10, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let name = format!("gemm_{n}x{n}x{n}");
        report(&name, &stats);
        let flops = 2.0 * (n as f64).powi(3);
        println!("{:>60}", format!("≈ {:.2} GFLOP/s", flops / stats.median_s / 1e9));
        log_csv("linalg.csv", &name, &stats);
        json.entry(&name, n * n * n, &stats, None);
    }

    println!("-- thin QR (one Haar–Stiefel draw at paper dims) --");
    for &(n, r) in &[(128usize, 8usize), (1024, 128), (4096, 128)] {
        let g = rand_mat(n, r, 3);
        let stats = bench(2, 10, || {
            std::hint::black_box(thin_qr(&g));
        });
        let name = format!("thin_qr_{n}x{r}");
        report(&name, &stats);
        log_csv("linalg.csv", &name, &stats);
        json.entry(&name, n * r, &stats, None);
    }

    println!("-- symmetric Jacobi eigensolver (Σ decomposition) --");
    for &n in &[32usize, 64, 128] {
        let g = rand_mat(n, n, 4);
        let s = matmul_tn(&g, &g);
        let stats = bench(1, 5, || {
            std::hint::black_box(sym_eig(&s));
        });
        let name = format!("sym_eig_{n}");
        report(&name, &stats);
        log_csv("linalg.csv", &name, &stats);
        json.entry(&name, n * n, &stats, None);
    }

    println!("-- f32 lift Θ += B·Vᵀ (once per K steps) --");
    for &(m, n, r) in &[(128usize, 128usize, 8usize), (384, 128, 8), (1024, 1024, 128)] {
        let b: Vec<f32> = (0..m * r).map(|i| i as f32 * 1e-3).collect();
        let v: Vec<f32> = (0..n * r).map(|i| i as f32 * 1e-3).collect();
        let mut theta = vec![0.0f32; m * n];
        let stats = bench(2, 10, || {
            lift_into(&mut theta, &b, &v, m, n, r);
            std::hint::black_box(&theta);
        });
        let name = format!("lift_{m}x{n}_r{r}");
        report(&name, &stats);
        log_csv("linalg.csv", &name, &stats);
        // throughput of the written Θ bytes — the lift is store-bound
        json.entry(&name, m * n, &stats, Some(4.0 * (m * n) as f64 / stats.median_s / 1e6));
    }
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write bench JSON: {e}"),
    }
}
