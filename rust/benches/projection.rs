//! Projection-sampler cost at the dimensions the trainers actually use
//! (the per-outer-iteration cost the lazy update amortizes by 1/K).

use lowrank_sge::bench_util::{bench, log_csv, report};
use lowrank_sge::linalg::Mat;
use lowrank_sge::projection::{build_sampler, ProjectorKind};
use lowrank_sge::rng::Rng;

fn main() {
    println!("-- projection sampler cost (one V draw) --");
    let cases = [
        (128usize, 8usize),  // llama-s attn
        (384, 8),            // llama-s mlp
        (1024, 128),         // paper's RoBERTa-scale (d=1024, r=128)
        (4096, 128),         // paper's MLP width
    ];
    for kind in [
        ProjectorKind::Gaussian,
        ProjectorKind::Stiefel,
        ProjectorKind::Coordinate,
    ] {
        for &(n, r) in &cases {
            let mut sampler = build_sampler(kind, n, r, 1.0, None);
            let mut rng = Rng::new(1);
            let stats = bench(2, 12, || {
                std::hint::black_box(sampler.sample(&mut rng));
            });
            let name = format!("{}_n{}_r{}", kind.name(), n, r);
            report(&name, &stats);
            log_csv("projection.csv", &name, &stats);
        }
    }

    // dependent sampler: split construction (eig + water-filling, once
    // per Σ refresh) from per-draw cost
    println!("-- dependent sampler (Algorithm 4) --");
    for &n in &[64usize, 128, 256] {
        let r = 8;
        let mut rng = Rng::new(2);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let sigma = lowrank_sge::linalg::matmul_tn(&g, &g);
        let stats = bench(1, 5, || {
            std::hint::black_box(build_sampler(ProjectorKind::Dependent, n, r, 1.0, Some(&sigma)));
        });
        let name = format!("dependent_build_n{n}_r{r}");
        report(&name, &stats);
        log_csv("projection.csv", &name, &stats);

        let mut sampler = build_sampler(ProjectorKind::Dependent, n, r, 1.0, Some(&sigma));
        let stats = bench(2, 12, || {
            std::hint::black_box(sampler.sample(&mut rng));
        });
        let name = format!("dependent_draw_n{n}_r{r}");
        report(&name, &stats);
        log_csv("projection.csv", &name, &stats);
    }
}
