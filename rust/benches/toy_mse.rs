//! Figures 2–5 regeneration cost: one-shot estimator throughput on the
//! toy problem (the inner loop of the MSE harness), driven through the
//! shared estimator engine.

use lowrank_sge::bench_util::{bench, log_csv, report};
use lowrank_sge::estimator::engine::{MethodShape, OracleEngine};
use lowrank_sge::estimator::toy::ToyProblem;
use lowrank_sge::projection::{ProjectionSampler, StiefelSampler};
use lowrank_sge::rng::Rng;

fn main() {
    let problem = ToyProblem::paper_default(1);
    let w = problem.eval_point(2);
    let mut rng = Rng::new(3);
    let r = 4usize;
    let sigma = 1e-2;

    println!("-- one-shot estimator cost (m=n=100, o=30, r=4) --");
    for (name, shape) in [
        ("ipa_full_rank", MethodShape::FullIpa),
        ("ipa_lowrank_stiefel", MethodShape::LowRankIpa),
        ("lr_full_rank_2pt", MethodShape::FullLr),
        ("lr_lowrank_stiefel_2pt", MethodShape::LowRankLr),
    ] {
        let sampler: Option<Box<dyn ProjectionSampler + Send + Sync>> = if shape.is_low_rank() {
            Some(Box::new(StiefelSampler::new(problem.n, r, 1.0)))
        } else {
            None
        };
        let mut engine = OracleEngine::new(shape, problem.m, problem.n, r, sampler);
        let stats = bench(5, 50, || {
            let a = problem.sample_a(&mut rng);
            std::hint::black_box(engine.step(&problem, &w, &a, &mut rng, sigma));
        });
        report(name, &stats);
        log_csv("toy_mse.csv", name, &stats);
    }

    println!("-- Σ estimation (dependent-sampler warm-up) --");
    let stats = bench(1, 3, || {
        let mut r2 = Rng::new(7);
        std::hint::black_box(problem.sigma_xi_empirical(
            &w,
            &mut r2,
            200,
            lowrank_sge::estimator::Family::Ipa,
            1e-2,
        ));
    });
    report("sigma_xi_200_warmup", &stats);
    log_csv("toy_mse.csv", "sigma_xi_200_warmup", &stats);
}
