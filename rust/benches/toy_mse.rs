//! Figures 2–5 regeneration cost: one-shot estimator throughput on the
//! toy problem (the inner loop of the MSE harness).

use lowrank_sge::bench_util::{bench, log_csv, report};
use lowrank_sge::estimator::toy::{project_lift, ToyProblem};
use lowrank_sge::projection::{ProjectionSampler, StiefelSampler};
use lowrank_sge::rng::Rng;

fn main() {
    let problem = ToyProblem::paper_default(1);
    let w = problem.eval_point(2);
    let mut rng = Rng::new(3);

    println!("-- one-shot estimator cost (m=n=100, o=30, r=4) --");
    let stats = bench(5, 50, || {
        let a = problem.sample_a(&mut rng);
        std::hint::black_box(problem.ipa_estimate(&w, &a));
    });
    report("ipa_full_rank", &stats);
    log_csv("toy_mse.csv", "ipa_full_rank", &stats);

    let mut sampler = StiefelSampler::new(problem.n, 4, 1.0);
    let stats = bench(5, 50, || {
        let a = problem.sample_a(&mut rng);
        let v = sampler.sample(&mut rng);
        let g = problem.ipa_estimate(&w, &a);
        std::hint::black_box(project_lift(&g, &v));
    });
    report("ipa_lowrank_stiefel", &stats);
    log_csv("toy_mse.csv", "ipa_lowrank_stiefel", &stats);

    let stats = bench(5, 50, || {
        let a = problem.sample_a(&mut rng);
        std::hint::black_box(problem.lr_estimate(&w, &a, &mut rng, 1e-2));
    });
    report("lr_full_rank_2pt", &stats);
    log_csv("toy_mse.csv", "lr_full_rank_2pt", &stats);

    let stats = bench(5, 50, || {
        let a = problem.sample_a(&mut rng);
        let v = sampler.sample(&mut rng);
        std::hint::black_box(problem.lowrank_lr_estimate(&w, &a, &mut rng, 1e-2, &v));
    });
    report("lr_lowrank_stiefel_2pt", &stats);
    log_csv("toy_mse.csv", "lr_lowrank_stiefel_2pt", &stats);

    println!("-- Σ estimation (dependent-sampler warm-up) --");
    let stats = bench(1, 3, || {
        let mut r2 = Rng::new(7);
        std::hint::black_box(problem.sigma_xi_empirical(
            &w,
            &mut r2,
            200,
            lowrank_sge::estimator::Family::Ipa,
            1e-2,
        ));
    });
    report("sigma_xi_200_warmup", &stats);
    log_csv("toy_mse.csv", "sigma_xi_200_warmup", &stats);
}
