//! Checkpoint save/restore throughput at pretrain-scale tensor counts.
//!
//! The cost model that matters for picking `--save-every`: a checkpoint
//! is ~2× the parameter bytes (Θ + subspace + two Adam moment buffers),
//! and the save sits on the training critical path (the leader writes at
//! the step barrier). This measures full commits — codec + CRC + temp
//! dir + rename + LATEST — and verified loads, per scale.

use lowrank_sge::bench_util::{bench, log_csv, report};
use lowrank_sge::ckpt::{load_checkpoint, save_checkpoint, ResumeSpec, StateDict};
use lowrank_sge::rng::Rng;

/// A synthetic "model": `tensors` f32 matrices of rows×cols plus nested
/// Adam moments, mimicking the params + subspace groups of a pretrain
/// checkpoint.
fn synthetic_groups(tensors: usize, rows: usize, cols: usize) -> Vec<(String, StateDict)> {
    let mut rng = Rng::new(42);
    let mut params = StateDict::new();
    let mut opt = StateDict::new();
    for i in 0..tensors {
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        params.put_f32(format!("params[t{i}]"), vec![rows, cols], data.clone());
        opt.put_f32(format!("adam[t{i}].m"), vec![rows, cols], data.clone());
        opt.put_f32(format!("adam[t{i}].v"), vec![rows, cols], data);
        opt.put_u64s(format!("adam[t{i}].t"), &[1000 + i as u64]);
    }
    let mut rng_state = StateDict::new();
    rng_state.put_u64s("xoshiro_state", &[1, 2, 3, 4]);
    vec![
        ("params".to_string(), params),
        ("opt".to_string(), opt),
        ("rng".to_string(), rng_state),
    ]
}

fn main() {
    let root = std::env::temp_dir().join("lowrank_sge_ckpt_io_bench");
    let _ = std::fs::remove_dir_all(&root);

    // (tag, tensors, rows, cols): llama-s proxy … llama-100M-ish counts
    let cases = [
        ("s_14x256x128", 14usize, 256usize, 128usize),
        ("m_32x512x256", 32, 512, 256),
        ("l_48x1024x512", 48, 1024, 512),
    ];
    for (tag, tensors, rows, cols) in cases {
        let groups = synthetic_groups(tensors, rows, cols);
        let named: Vec<(&str, StateDict)> =
            groups.iter().map(|(n, sd)| (n.as_str(), sd.clone())).collect();
        let bytes: usize = groups.iter().map(|(_, sd)| sd.payload_bytes()).sum();
        let mb = bytes as f64 / (1024.0 * 1024.0);
        let dir = root.join(tag);

        let mut step = 0u64;
        let stats = bench(1, 8, || {
            step += 1;
            save_checkpoint(&dir, step, &[], &named, 2).unwrap();
        });
        let name = format!("ckpt_save_{tag}");
        report(&name, &stats);
        println!("    {:>10.1} MB  {:>8.1} MB/s (keep-last 2, full commit)", mb, stats.per_second(mb));
        log_csv("ckpt_io.csv", &name, &stats);

        let stats = bench(1, 8, || {
            let ckpt = load_checkpoint(&dir, ResumeSpec::Latest).unwrap();
            assert_eq!(ckpt.group_names().len(), 3);
        });
        let name = format!("ckpt_load_{tag}");
        report(&name, &stats);
        println!("    {:>10.1} MB  {:>8.1} MB/s (CRC-verified load)", mb, stats.per_second(mb));
        log_csv("ckpt_io.csv", &name, &stats);
    }
    let _ = std::fs::remove_dir_all(&root);
}
