//! Optimizer stack for Algorithm 1 (low-rank gradient descent with lazy
//! update).
//!
//! * [`adam`] — Adam "specifically adapted for subspace training"
//!   (paper §6.2.2): moment buffers live on the m×r auxiliary variable B
//!   (and on the full-rank trainables), which is exactly where the
//!   paper's optimizer-state memory saving comes from.
//! * [`sgd`] — plain SGD with optional momentum (the toy/finetune
//!   inner-loop default).
//! * [`schedule`] — cosine annealing with linear warmup (paper §6.2.2:
//!   warmup 1000, cycle 100k; scaled down in the proxy configs).
//! * [`clip`] — global-norm gradient clipping at 1.0 (paper §6.2.2).
//! * [`lazy`] — the outer/inner lazy-update state machine: reuse one
//!   sampled subspace V for K inner steps, then lift and resample. Also
//!   home of the online per-layer [`RankController`], which watches the
//!   measured lift residuals and shrinks a slot's rank when the trend
//!   decays — B, V, Adam moments, and engine scratch re-layout in place.

mod adam;
mod clip;
mod lazy;
mod schedule;
mod sgd;

pub use adam::{Adam, AdamConfig};
pub use clip::{clip_global_norm, global_norm};
pub use lazy::{LazyAction, LazyUpdateController, RankAdaptConfig, RankController, RankDecision};
pub use schedule::{CosineSchedule, LrSchedule};
pub use sgd::Sgd;
