//! Adam (Kingma & Ba 2014) with decoupled weight decay, operating on
//! flat f32 slices — one instance per named tensor. In subspace training
//! the B-tensors are m×r, so the two moment buffers cost O(mr) instead
//! of O(mn): the optimizer-state column of Table 2.

/// Hyperparameters (paper §6.2.2: β₁ = 0.9, β₂ = 0.999, wd = 0.05).
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

impl AdamConfig {
    pub fn paper_pretrain() -> Self {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.05 }
    }
}

/// Adam state for one tensor.
#[derive(Clone, Debug)]
pub struct Adam {
    cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(len: usize, cfg: AdamConfig) -> Self {
        Adam { cfg, m: vec![0.0; len], v: vec![0.0; len], t: 0 }
    }

    /// Bytes of optimizer state held (for the memory accounting).
    pub fn state_bytes(&self) -> usize {
        8 * self.m.len()
    }

    /// Reset moments (used when the subspace is resampled: the old
    /// moments live in the old V's coordinates and are meaningless in
    /// the new subspace — the paper's "subproblem reset interval").
    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }

    /// One update: param ← param − lr·( m̂/(√v̂+ε) + wd·param ).
    pub fn step(&mut self, param: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(param.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let wd = self.cfg.weight_decay;
        for i in 0..param.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            param[i] -= lr * (mhat / (vhat.sqrt() + self.cfg.eps) + wd * param[i]);
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Re-layout for a rank shrink of a row-major `[rows, old_cols]`
    /// tensor: keep the leading `new_cols` moment columns of each row,
    /// drop the rest, and release the tail capacity so the shrink shows
    /// up in measured memory, not just [`Self::state_bytes`]. `t` is
    /// kept — callers shrinking at a lazy-update boundary reset moments
    /// right after anyway, but mid-window shrinks stay well-defined.
    pub fn shrink_cols(&mut self, rows: usize, old_cols: usize, new_cols: usize) {
        assert_eq!(self.m.len(), rows * old_cols, "moment layout mismatch");
        assert!(new_cols <= old_cols, "shrink_cols cannot grow");
        for buf in [&mut self.m, &mut self.v] {
            for row in 1..rows {
                buf.copy_within(row * old_cols..row * old_cols + new_cols, row * new_cols);
            }
            buf.truncate(rows * new_cols);
            buf.shrink_to_fit();
        }
    }

    /// Resize the moment buffers to `len` elements (zero-filled),
    /// keeping the hyperparameters. Used when a checkpoint restores a
    /// slot at a different (shrunk) rank than the freshly-constructed
    /// optimizer — the restored moments overwrite the zeros right after.
    pub fn resize(&mut self, len: usize) {
        for buf in [&mut self.m, &mut self.v] {
            buf.clear();
            buf.resize(len, 0.0);
            buf.shrink_to_fit();
        }
    }
}

/// Checkpointing: both moment buffers plus the bias-correction step
/// counter `t`. The hyperparameters are *not* saved — they come from the
/// run config, so a resume can legitimately adjust e.g. weight decay.
impl crate::ckpt::Checkpointable for Adam {
    fn state_dict(&self) -> crate::ckpt::StateDict {
        let mut sd = crate::ckpt::StateDict::new();
        sd.put_f32("m", vec![self.m.len()], self.m.clone());
        sd.put_f32("v", vec![self.v.len()], self.v.clone());
        sd.put_u64s("t", &[self.t]);
        sd
    }

    fn load_state(&mut self, sd: &crate::ckpt::StateDict) -> anyhow::Result<()> {
        let m = sd.f32("m")?;
        let v = sd.f32("v")?;
        if m.len() != self.m.len() || v.len() != self.v.len() {
            anyhow::bail!(
                "adam state length mismatch: checkpoint ({}, {}), optimizer expects {}",
                m.len(),
                v.len(),
                self.m.len()
            );
        }
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        self.t = sd.u64("t")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar reference implementation for cross-checking.
    fn reference_adam(g_seq: &[f32], lr: f32, cfg: AdamConfig, x0: f32) -> f32 {
        let (mut m, mut v, mut x) = (0.0f32, 0.0f32, x0);
        for (t, &g) in g_seq.iter().enumerate() {
            let t = (t + 1) as i32;
            m = cfg.beta1 * m + (1.0 - cfg.beta1) * g;
            v = cfg.beta2 * v + (1.0 - cfg.beta2) * g * g;
            let mhat = m / (1.0 - cfg.beta1.powi(t));
            let vhat = v / (1.0 - cfg.beta2.powi(t));
            x -= lr * (mhat / (vhat.sqrt() + cfg.eps) + cfg.weight_decay * x);
        }
        x
    }

    #[test]
    fn matches_scalar_reference() {
        let cfg = AdamConfig { weight_decay: 0.01, ..Default::default() };
        let mut opt = Adam::new(1, cfg);
        let mut x = [0.5f32];
        let gs = [0.3, -0.1, 0.7, 0.2, -0.5];
        for &g in &gs {
            opt.step(&mut x, &[g], 1e-2);
        }
        let want = reference_adam(&gs, 1e-2, cfg, 0.5);
        assert!((x[0] - want).abs() < 1e-6, "{} vs {want}", x[0]);
    }

    #[test]
    fn first_step_size_is_lr() {
        // classic Adam property: |Δx| ≈ lr on step 1 regardless of g scale
        for &g in &[1e-6f32, 1.0, 1e4] {
            let mut opt = Adam::new(1, AdamConfig::default());
            let mut x = [0.0f32];
            opt.step(&mut x, &[g], 0.01);
            assert!((x[0].abs() - 0.01).abs() < 1e-4, "g={g}: step {}", x[0]);
        }
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize ½Σ(x_i − a_i)²
        let a: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * 0.1).collect();
        let mut x = vec![0.0f32; 16];
        let mut opt = Adam::new(16, AdamConfig::default());
        for _ in 0..2000 {
            let g: Vec<f32> = x.iter().zip(&a).map(|(xi, ai)| xi - ai).collect();
            opt.step(&mut x, &g, 0.01);
        }
        for (xi, ai) in x.iter().zip(&a) {
            assert!((xi - ai).abs() < 1e-2, "{xi} vs {ai}");
        }
    }

    #[test]
    fn reset_clears_moments() {
        let mut opt = Adam::new(4, AdamConfig::default());
        let mut x = vec![0.0f32; 4];
        opt.step(&mut x, &[1.0; 4], 0.1);
        assert_eq!(opt.steps_taken(), 1);
        opt.reset();
        assert_eq!(opt.steps_taken(), 0);
        // after reset, behaves like fresh: first step ≈ lr again
        let mut y = vec![0.0f32; 4];
        opt.step(&mut y, &[123.0; 4], 0.1);
        assert!((y[0].abs() - 0.1).abs() < 1e-3);
    }

    #[test]
    fn checkpoint_roundtrip_resumes_bitwise() {
        use crate::ckpt::Checkpointable;
        let cfg = AdamConfig { weight_decay: 0.01, ..Default::default() };
        let mut warm = Adam::new(8, cfg);
        let mut x = vec![0.25f32; 8];
        for k in 0..13 {
            let g: Vec<f32> = (0..8).map(|i| ((k * 8 + i) as f32).sin()).collect();
            warm.step(&mut x, &g, 3e-3);
        }
        let sd = warm.state_dict();

        let mut resumed = Adam::new(8, cfg);
        resumed.load_state(&sd).unwrap();
        assert_eq!(resumed.steps_taken(), 13);
        let mut x2 = x.clone();
        for k in 13..20 {
            let g: Vec<f32> = (0..8).map(|i| ((k * 8 + i) as f32).sin()).collect();
            warm.step(&mut x, &g, 3e-3);
            resumed.step(&mut x2, &g, 3e-3);
        }
        for (a, b) in x.iter().zip(&x2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // wrong-size state is rejected, not truncated
        let mut small = Adam::new(4, cfg);
        assert!(small.load_state(&sd).is_err());
    }

    #[test]
    fn state_bytes_counts_two_f32_buffers() {
        let opt = Adam::new(100, AdamConfig::default());
        assert_eq!(opt.state_bytes(), 800);
    }

    #[test]
    fn weight_decay_shrinks_params_without_gradient() {
        let cfg = AdamConfig { weight_decay: 0.1, ..Default::default() };
        let mut opt = Adam::new(1, cfg);
        let mut x = [1.0f32];
        for _ in 0..10 {
            opt.step(&mut x, &[0.0], 0.1);
        }
        assert!(x[0] < 1.0 && x[0] > 0.8);
    }
}
