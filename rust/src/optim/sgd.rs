//! SGD with optional heavy-ball momentum (flat-slice form, matching the
//! [`super::Adam`] interface).

#[derive(Clone, Debug)]
pub struct Sgd {
    momentum: f32,
    buf: Option<Vec<f32>>,
}

impl Sgd {
    pub fn new(len: usize, momentum: f32) -> Self {
        assert!((0.0..1.0).contains(&momentum), "momentum in [0,1)");
        let buf = if momentum > 0.0 { Some(vec![0.0; len]) } else { None };
        Sgd { momentum, buf }
    }

    pub fn reset(&mut self) {
        if let Some(b) = &mut self.buf {
            b.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.buf.as_ref().map_or(0, |b| 4 * b.len())
    }

    pub fn step(&mut self, param: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(param.len(), grad.len());
        match &mut self.buf {
            None => {
                for (p, g) in param.iter_mut().zip(grad) {
                    *p -= lr * g;
                }
            }
            Some(buf) => {
                let mu = self.momentum;
                for i in 0..param.len() {
                    buf[i] = mu * buf[i] + grad[i];
                    param[i] -= lr * buf[i];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let mut opt = Sgd::new(2, 0.0);
        let mut x = [1.0f32, 2.0];
        opt.step(&mut x, &[0.5, -0.5], 0.1);
        assert_eq!(x, [0.95, 2.05]);
        assert_eq!(opt.state_bytes(), 0);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(1, 0.9);
        let mut x = [0.0f32];
        opt.step(&mut x, &[1.0], 1.0); // v=1, x=-1
        opt.step(&mut x, &[1.0], 1.0); // v=1.9, x=-2.9
        assert!((x[0] + 2.9).abs() < 1e-6);
        assert_eq!(opt.state_bytes(), 4);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = Sgd::new(1, 0.9);
        let mut x = [5.0f32];
        for _ in 0..300 {
            let g = x[0] - 2.0;
            opt.step(&mut x, &[g], 0.05);
        }
        assert!((x[0] - 2.0).abs() < 1e-3);
    }

    #[test]
    fn reset_zeroes_momentum() {
        let mut opt = Sgd::new(1, 0.5);
        let mut x = [0.0f32];
        opt.step(&mut x, &[1.0], 1.0);
        opt.reset();
        let mut y = [0.0f32];
        opt.step(&mut y, &[1.0], 1.0);
        assert_eq!(y[0], -1.0); // no leftover momentum
    }
}
