//! Global-norm gradient clipping (paper §6.2.2: clip at 1.0), applied
//! jointly across all trainable tensors of a step.

/// √(Σ over all tensors of Σ g²).
pub fn global_norm(grads: &[&[f32]]) -> f32 {
    grads
        .iter()
        .map(|g| g.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>())
        .sum::<f64>()
        .sqrt() as f32
}

/// Scale every gradient by min(1, max_norm/‖g‖). Returns the pre-clip
/// norm (logged by the trainers).
pub fn clip_global_norm(grads: &mut [&mut [f32]], max_norm: f32) -> f32 {
    let views: Vec<&[f32]> = grads.iter().map(|g| &**g).collect();
    let norm = global_norm(&views);
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for x in g.iter_mut() {
                *x *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_across_tensors() {
        let a = [3.0f32];
        let b = [4.0f32];
        assert!((global_norm(&[&a, &b]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn no_clip_when_below_threshold() {
        let mut a = vec![0.3f32, 0.4];
        let pre = clip_global_norm(&mut [&mut a], 1.0);
        assert!((pre - 0.5).abs() < 1e-6);
        assert_eq!(a, vec![0.3, 0.4]);
    }

    #[test]
    fn clips_to_exact_norm() {
        let mut a = vec![3.0f32];
        let mut b = vec![4.0f32];
        let pre = clip_global_norm(&mut [&mut a, &mut b], 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post = global_norm(&[&a, &b]);
        assert!((post - 1.0).abs() < 1e-6);
        // direction preserved
        assert!((a[0] / b[0] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn zero_gradient_is_noop() {
        let mut a = vec![0.0f32; 4];
        let pre = clip_global_norm(&mut [&mut a], 1.0);
        assert_eq!(pre, 0.0);
        assert!(a.iter().all(|&x| x == 0.0));
    }
}
