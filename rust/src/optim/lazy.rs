//! The lazy-update state machine (paper §4.2, Algorithm 1) and the
//! online per-layer rank controller.
//!
//! One outer iteration = sample V, run K inner steps on B in span(V),
//! then lift Θ ← Θ + B_K·Vᵀ and reset. [`LazyUpdateController`] tells
//! the trainer what to do at each global step; the trainer stays a
//! flat loop.
//!
//! [`RankController`] rides the same boundaries: AdaRankGrad (see
//! PAPERS.md) shows the gradients' effective rank shrinks
//! monotonically during training, so a slot's provisioned rank r_i is
//! increasingly over-sized. At every lift the trainer feeds the
//! controller the measured per-slot RMS lift residuals
//! ([`crate::coordinator::SubspaceSet::lift_residuals`], all-reduced
//! across ranks first so every rank sees identical inputs); once a
//! slot has a full observation window, a decaying residual trend
//! triggers a shrink to ⌊r·factor⌋ (floored at `min_rank`), which the
//! trainer applies as an in-place re-layout of B, V, the Adam moments,
//! and the engine scratch. Decisions are a pure function of (config,
//! observation sequence), so identical inputs ⇒ identical rank
//! schedules on every rank and across resumes — the controller
//! checkpoints its observation history for exactly that reason.
//!
//! The decision *log* additionally carries a `mse {…}` context column:
//! the quality probe's latest Theorem-2-normalized variance gauge for
//! the slot ([`crate::obs::quality`], NaN before the first probe).
//! This is observability only — decisions remain a function of the
//! lift-residual sequence alone, so enabling or disabling the probes
//! never changes a rank schedule.

/// What the trainer must do *before* the gradient step at a given
/// global step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LazyAction {
    /// First step of an outer iteration: lift the previous B (unless
    /// this is step 0), reset B ← 0, resample V, reset B-optimizer.
    ResampleSubspace,
    /// Plain inner step: keep the current subspace.
    InnerStep,
}

/// Tracks the outer/inner structure. `k_interval` is the paper's K
/// ("lazy update interval" = 50 in fine-tuning, "subproblem reset
/// interval" = 200 in pretraining).
#[derive(Clone, Copy, Debug)]
pub struct LazyUpdateController {
    k_interval: u64,
}

impl LazyUpdateController {
    pub fn new(k_interval: u64) -> Self {
        assert!(k_interval >= 1, "K must be ≥ 1");
        LazyUpdateController { k_interval }
    }

    pub fn k_interval(&self) -> u64 {
        self.k_interval
    }

    /// Action before executing global step `step` (0-based).
    pub fn action(&self, step: u64) -> LazyAction {
        if step % self.k_interval == 0 {
            LazyAction::ResampleSubspace
        } else {
            LazyAction::InnerStep
        }
    }

    /// Does a lift happen when *finishing* step `step`? (Exactly the
    /// steps after which the next action is a resample; the final lift
    /// at training end is the trainer's job.)
    pub fn lifts_after(&self, step: u64) -> bool {
        (step + 1) % self.k_interval == 0
    }

    /// Outer-iteration index t of a global step.
    pub fn outer_index(&self, step: u64) -> u64 {
        step / self.k_interval
    }

    /// Inner-step index k within the outer iteration.
    pub fn inner_index(&self, step: u64) -> u64 {
        step % self.k_interval
    }
}

/// Rank-adaptation hyperparameters (CLI: `--rank-adapt` + friends).
#[derive(Clone, Copy, Debug)]
pub struct RankAdaptConfig {
    /// Never shrink below this rank.
    pub min_rank: usize,
    /// Lift observations per decision (≥ 2: the trend compares the
    /// window's first half against its second half).
    pub window: usize,
    /// Shrink when mean(recent half) < decay · mean(first half). The
    /// default 0.7 asks for a clear downward trend; tests force
    /// always-shrink with large values.
    pub decay: f64,
    /// New rank = max(min_rank, ⌊r · factor⌋) (at least one column off).
    pub factor: f64,
}

impl Default for RankAdaptConfig {
    fn default() -> Self {
        RankAdaptConfig { min_rank: 2, window: 4, decay: 0.7, factor: 0.75 }
    }
}

/// Outcome of one controller evaluation for one slot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RankDecision {
    /// Not enough observations in the window yet.
    Pending,
    /// Window full, trend not decayed (or already at `min_rank`):
    /// `ratio` = mean(recent)/mean(first) for the log line.
    Keep { ratio: f64 },
    /// Shrink this slot to `to`.
    Shrink { to: usize, ratio: f64 },
}

/// Online per-layer rank controller (module docs). Deterministic:
/// decisions depend only on the config and the observed residual
/// sequence, never on wall clock, thread count, or rank.
#[derive(Clone, Debug)]
pub struct RankController {
    cfg: RankAdaptConfig,
    /// Residuals observed since each slot's last decision.
    hist: Vec<Vec<f64>>,
}

impl RankController {
    pub fn new(cfg: RankAdaptConfig, n_slots: usize) -> Self {
        assert!(cfg.window >= 2, "rank-adapt window must be ≥ 2");
        assert!(cfg.min_rank >= 1, "min_rank must be ≥ 1");
        assert!(cfg.factor > 0.0 && cfg.factor < 1.0, "factor must be in (0, 1)");
        RankController { cfg, hist: vec![Vec::new(); n_slots] }
    }

    pub fn cfg(&self) -> RankAdaptConfig {
        self.cfg
    }

    /// Feed one lift's residuals (slot order, already identical on
    /// every rank) and the current active ranks; returns one decision
    /// per slot. A slot that decides (Keep or Shrink) starts a fresh
    /// window.
    pub fn observe(&mut self, residuals: &[f64], ranks: &[usize]) -> Vec<RankDecision> {
        assert_eq!(residuals.len(), self.hist.len(), "one residual per slot");
        assert_eq!(ranks.len(), self.hist.len(), "one rank per slot");
        let w = self.cfg.window;
        residuals
            .iter()
            .zip(ranks)
            .zip(self.hist.iter_mut())
            .map(|((&res, &r), hist)| {
                hist.push(res);
                if hist.len() < w {
                    return RankDecision::Pending;
                }
                let half = w / 2;
                let first: f64 = hist[..half].iter().sum::<f64>() / half as f64;
                let recent: f64 =
                    hist[w - half..].iter().sum::<f64>() / half as f64;
                hist.clear();
                let ratio = if first > 0.0 { recent / first } else { 1.0 };
                let target = ((r as f64 * self.cfg.factor).floor() as usize)
                    .min(r.saturating_sub(1))
                    .max(self.cfg.min_rank);
                if recent < self.cfg.decay * first && target < r {
                    RankDecision::Shrink { to: target, ratio }
                } else {
                    RankDecision::Keep { ratio }
                }
            })
            .collect()
    }
}

/// Checkpointing: the per-slot observation windows. Without them a
/// resumed run would restart its windows mid-flight and could take a
/// different rank schedule than the uninterrupted run — breaking the
/// bitwise resume contract.
impl crate::ckpt::Checkpointable for RankController {
    fn state_dict(&self) -> crate::ckpt::StateDict {
        let mut sd = crate::ckpt::StateDict::new();
        sd.put_u64s("slots", &[self.hist.len() as u64]);
        for (i, h) in self.hist.iter().enumerate() {
            sd.put_f64_bits(format!("hist[{i}]"), h);
        }
        sd
    }

    fn load_state(&mut self, sd: &crate::ckpt::StateDict) -> anyhow::Result<()> {
        let want = 1 + self.hist.len();
        if sd.len() != want {
            anyhow::bail!("rank controller checkpoint has {} tensors, expected {want}", sd.len());
        }
        let slots = sd.u64("slots")? as usize;
        if slots != self.hist.len() {
            anyhow::bail!(
                "rank controller checkpoint has {slots} slots, controller has {}",
                self.hist.len()
            );
        }
        let mut staged = Vec::with_capacity(slots);
        for i in 0..slots {
            staged.push(sd.f64_bits(&format!("hist[{i}]"))?);
        }
        self.hist = staged;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k1_resamples_every_step() {
        let c = LazyUpdateController::new(1);
        for s in 0..5 {
            assert_eq!(c.action(s), LazyAction::ResampleSubspace);
            assert!(c.lifts_after(s));
        }
    }

    #[test]
    fn schedule_structure_k3() {
        let c = LazyUpdateController::new(3);
        let actions: Vec<bool> = (0..9)
            .map(|s| c.action(s) == LazyAction::ResampleSubspace)
            .collect();
        assert_eq!(actions, vec![true, false, false, true, false, false, true, false, false]);
        let lifts: Vec<bool> = (0..9).map(|s| c.lifts_after(s)).collect();
        assert_eq!(lifts, vec![false, false, true, false, false, true, false, false, true]);
    }

    #[test]
    fn indices_consistent() {
        let c = LazyUpdateController::new(4);
        assert_eq!(c.outer_index(0), 0);
        assert_eq!(c.outer_index(7), 1);
        assert_eq!(c.inner_index(7), 3);
        assert_eq!(c.outer_index(8), 2);
        assert_eq!(c.inner_index(8), 0);
    }

    #[test]
    fn every_step_has_exactly_one_lift_per_k_steps() {
        let c = LazyUpdateController::new(50);
        let lifts = (0..500).filter(|&s| c.lifts_after(s)).count();
        assert_eq!(lifts, 10);
    }

    #[test]
    fn controller_shrinks_on_a_decaying_trend_only() {
        let cfg = RankAdaptConfig { min_rank: 2, window: 4, decay: 0.7, factor: 0.75 };
        let mut ctl = RankController::new(cfg, 2);
        // slot 0 decays hard, slot 1 is flat
        let seq = [(1.0, 1.0), (1.0, 1.0), (0.1, 1.0), (0.1, 1.0)];
        let mut last = Vec::new();
        for (a, b) in seq {
            last = ctl.observe(&[a, b], &[8, 8]);
        }
        assert_eq!(last[0], RankDecision::Shrink { to: 6, ratio: 0.1 });
        assert!(matches!(last[1], RankDecision::Keep { .. }));
        // windows restart after a decision
        assert_eq!(ctl.observe(&[0.0, 0.0], &[6, 8]), vec![
            RankDecision::Pending,
            RankDecision::Pending
        ]);
    }

    #[test]
    fn controller_respects_the_min_rank_floor() {
        let cfg = RankAdaptConfig { min_rank: 3, window: 2, decay: 10.0, factor: 0.5 };
        let mut ctl = RankController::new(cfg, 1);
        // decay = 10 forces "shrink if possible" every window
        ctl.observe(&[1.0], &[8]);
        assert_eq!(ctl.observe(&[1.0], &[8]), vec![RankDecision::Shrink { to: 4, ratio: 1.0 }]);
        ctl.observe(&[1.0], &[4]);
        assert_eq!(ctl.observe(&[1.0], &[4]), vec![RankDecision::Shrink { to: 3, ratio: 1.0 }]);
        // at the floor: target == r → Keep, never Shrink-to-same
        ctl.observe(&[1.0], &[3]);
        assert!(matches!(ctl.observe(&[1.0], &[3])[0], RankDecision::Keep { .. }));
    }

    #[test]
    fn controller_checkpoint_resumes_the_same_decision_sequence() {
        use crate::ckpt::Checkpointable;
        let cfg = RankAdaptConfig { min_rank: 2, window: 4, decay: 0.8, factor: 0.75 };
        let residuals: Vec<[f64; 2]> =
            (0..12).map(|k| [1.0 / (k + 1) as f64, 0.9 + 0.01 * k as f64]).collect();
        let ranks = [8usize, 8];

        // uninterrupted reference
        let mut full = RankController::new(cfg, 2);
        let want: Vec<_> = residuals.iter().map(|r| full.observe(r, &ranks)).collect();

        // interrupt mid-window (step 6 is not a multiple of window)
        let mut first = RankController::new(cfg, 2);
        for r in &residuals[..6] {
            first.observe(r, &ranks);
        }
        let sd = first.state_dict();
        let mut resumed = RankController::new(cfg, 2);
        resumed.load_state(&sd).unwrap();
        let got: Vec<_> = residuals[6..].iter().map(|r| resumed.observe(r, &ranks)).collect();
        assert_eq!(got, want[6..].to_vec());

        // wrong slot count is rejected
        let mut other = RankController::new(cfg, 3);
        assert!(other.load_state(&sd).is_err());
    }
}
