//! The lazy-update state machine (paper §4.2, Algorithm 1).
//!
//! One outer iteration = sample V, run K inner steps on B in span(V),
//! then lift Θ ← Θ + B_K·Vᵀ and reset. The controller tells the trainer
//! what to do at each global step; the trainer stays a flat loop.

/// What the trainer must do *before* the gradient step at a given
/// global step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LazyAction {
    /// First step of an outer iteration: lift the previous B (unless
    /// this is step 0), reset B ← 0, resample V, reset B-optimizer.
    ResampleSubspace,
    /// Plain inner step: keep the current subspace.
    InnerStep,
}

/// Tracks the outer/inner structure. `k_interval` is the paper's K
/// ("lazy update interval" = 50 in fine-tuning, "subproblem reset
/// interval" = 200 in pretraining).
#[derive(Clone, Copy, Debug)]
pub struct LazyUpdateController {
    k_interval: u64,
}

impl LazyUpdateController {
    pub fn new(k_interval: u64) -> Self {
        assert!(k_interval >= 1, "K must be ≥ 1");
        LazyUpdateController { k_interval }
    }

    pub fn k_interval(&self) -> u64 {
        self.k_interval
    }

    /// Action before executing global step `step` (0-based).
    pub fn action(&self, step: u64) -> LazyAction {
        if step % self.k_interval == 0 {
            LazyAction::ResampleSubspace
        } else {
            LazyAction::InnerStep
        }
    }

    /// Does a lift happen when *finishing* step `step`? (Exactly the
    /// steps after which the next action is a resample; the final lift
    /// at training end is the trainer's job.)
    pub fn lifts_after(&self, step: u64) -> bool {
        (step + 1) % self.k_interval == 0
    }

    /// Outer-iteration index t of a global step.
    pub fn outer_index(&self, step: u64) -> u64 {
        step / self.k_interval
    }

    /// Inner-step index k within the outer iteration.
    pub fn inner_index(&self, step: u64) -> u64 {
        step % self.k_interval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k1_resamples_every_step() {
        let c = LazyUpdateController::new(1);
        for s in 0..5 {
            assert_eq!(c.action(s), LazyAction::ResampleSubspace);
            assert!(c.lifts_after(s));
        }
    }

    #[test]
    fn schedule_structure_k3() {
        let c = LazyUpdateController::new(3);
        let actions: Vec<bool> = (0..9)
            .map(|s| c.action(s) == LazyAction::ResampleSubspace)
            .collect();
        assert_eq!(actions, vec![true, false, false, true, false, false, true, false, false]);
        let lifts: Vec<bool> = (0..9).map(|s| c.lifts_after(s)).collect();
        assert_eq!(lifts, vec![false, false, true, false, false, true, false, false, true]);
    }

    #[test]
    fn indices_consistent() {
        let c = LazyUpdateController::new(4);
        assert_eq!(c.outer_index(0), 0);
        assert_eq!(c.outer_index(7), 1);
        assert_eq!(c.inner_index(7), 3);
        assert_eq!(c.outer_index(8), 2);
        assert_eq!(c.inner_index(8), 0);
    }

    #[test]
    fn every_step_has_exactly_one_lift_per_k_steps() {
        let c = LazyUpdateController::new(50);
        let lifts = (0..500).filter(|&s| c.lifts_after(s)).count();
        assert_eq!(lifts, 10);
    }
}
