//! Learning-rate schedules. Paper §6.2.2: cosine annealing with a
//! 100k-step cycle and 1000 warmup steps (scaled down proportionally in
//! the proxy configs).

/// A learning-rate schedule.
pub trait LrSchedule {
    fn lr(&self, step: u64) -> f32;
}

/// Linear warmup to `base_lr`, then cosine decay to `min_lr` over
/// `total_steps`.
#[derive(Clone, Copy, Debug)]
pub struct CosineSchedule {
    pub base_lr: f32,
    pub min_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
}

impl CosineSchedule {
    pub fn new(base_lr: f32, warmup_steps: u64, total_steps: u64) -> Self {
        assert!(total_steps > warmup_steps, "cycle shorter than warmup");
        CosineSchedule { base_lr, min_lr: base_lr * 0.1, warmup_steps, total_steps }
    }

    /// Constant schedule (warmup 0, no decay) — used by the finetune
    /// experiments which fix lr = 1e-6 (paper §6.2.1).
    pub fn constant(lr: f32) -> Self {
        CosineSchedule { base_lr: lr, min_lr: lr, warmup_steps: 0, total_steps: u64::MAX }
    }
}

impl LrSchedule for CosineSchedule {
    fn lr(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        if self.total_steps == u64::MAX {
            return self.base_lr;
        }
        let t = (step - self.warmup_steps).min(self.total_steps - self.warmup_steps) as f32;
        let horizon = (self.total_steps - self.warmup_steps) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t / horizon).cos());
        self.min_lr + (self.base_lr - self.min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = CosineSchedule::new(1.0, 10, 100);
        assert!((s.lr(0) - 0.1).abs() < 1e-6);
        assert!((s.lr(4) - 0.5).abs() < 1e-6);
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = CosineSchedule::new(1.0, 10, 100);
        assert!((s.lr(10) - 1.0).abs() < 1e-6);
        let mid = s.lr(55);
        assert!(mid < 1.0 && mid > 0.1);
        assert!((s.lr(100) - 0.1).abs() < 1e-3);
        // past the horizon it stays at min
        assert!((s.lr(10_000) - 0.1).abs() < 1e-3);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = CosineSchedule::new(3e-3, 100, 10_000);
        let mut prev = f32::INFINITY;
        for step in (100..10_000).step_by(500) {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-9, "lr increased at {step}");
            prev = lr;
        }
    }

    #[test]
    fn constant_schedule_is_flat() {
        let s = CosineSchedule::constant(1e-6);
        for step in [0u64, 1, 1000, 1_000_000] {
            assert_eq!(s.lr(step), 1e-6);
        }
    }
}
