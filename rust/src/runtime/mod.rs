//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and
//! execute them from the L3 hot path. Python is never invoked here.
//!
//! * [`manifest`] — parses the `key = value` manifests aot.py writes;
//!   the manifest is the binding contract between L2 and L3 (input
//!   order, dtypes, shapes). The runtime refuses to execute on any
//!   mismatch — fail fast, not wrong numerics.
//! * [`tensor`] — [`HostTensor`], the host-side f32/i32 value type that
//!   crosses the PJRT boundary.
//! * [`client`] — [`Runtime`], a caching loader
//!   (HLO text → `HloModuleProto` → compile → `PjRtLoadedExecutable`)
//!   plus the typed `execute` entry point.

mod client;
pub mod manifest;
mod tensor;

/// API-compatible stand-in for the `xla` crate when the `pjrt` feature
/// is off (the default): literals work, PJRT execution errors cleanly.
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_stub;

pub use client::{LoadedArtifact, Runtime};
pub use manifest::{ArtifactManifest, DType, TensorSpec};
pub use tensor::HostTensor;
