//! Build-time stub for the `xla` PJRT bindings.
//!
//! The default build compiles without the `xla` crate (it links the
//! PJRT C API and is not available in hermetic environments). This
//! module mirrors exactly the API surface the runtime uses:
//!
//! * [`Literal`] is **fully functional** (host-side reshape/readback),
//!   so `HostTensor` conversions — and their unit tests — work in every
//!   build;
//! * the PJRT entry points ([`PjRtClient::cpu`] and everything behind
//!   it) return a clear "built without PJRT support" error. All
//!   artifact-driven tests and experiments gate on
//!   `artifacts/INDEX.txt` and skip cleanly in this configuration.
//!
//! Building with `--features pjrt` switches `xla::…` back to the real
//! crate, which must then be provided (e.g. a `[patch]`/path dependency
//! on a local `xla-rs` checkout with the PJRT plugin installed).

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "built without PJRT support: rebuild with `--features pjrt` (requires the `xla` crate \
     and a PJRT plugin) to execute artifacts";

/// Host-side literal: dims + typed payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    F32 { dims: Vec<i64>, data: Vec<f32> },
    I32 { dims: Vec<i64>, data: Vec<i32> },
}

/// Element types crossing the literal boundary (f32/i32, matching the
/// artifact contract).
pub trait NativeElem: Copy {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeElem for f32 {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal {
        Literal::F32 { dims, data }
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            Literal::I32 { .. } => bail!("literal holds i32, requested f32"),
        }
    }
}

impl NativeElem for i32 {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal {
        Literal::I32 { dims, data }
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            Literal::F32 { .. } => bail!("literal holds f32, requested i32"),
        }
    }
}

impl Literal {
    pub fn vec1<T: NativeElem>(data: &[T]) -> Literal {
        T::wrap(vec![data.len() as i64], data.to_vec())
    }

    fn num_elements(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.num_elements().max(1) || dims.iter().any(|&d| d < 0)
        {
            bail!(
                "cannot reshape {} elements to {dims:?}",
                self.num_elements()
            );
        }
        let mut out = self.clone();
        match &mut out {
            Literal::F32 { dims: d, .. } | Literal::I32 { dims: d, .. } => {
                *d = dims.to_vec();
            }
        }
        Ok(out)
    }

    pub fn to_vec<T: NativeElem>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        bail!("stub literal is never a tuple ({UNAVAILABLE})")
    }
}

/// PJRT client stub — construction fails, everything else is
/// unreachable in practice but type-checks the runtime.
#[derive(Clone, Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeElem>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        bail!(UNAVAILABLE)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        bail!(UNAVAILABLE)
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_readback() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 2]).is_err());
        // scalar: 1 element to rank 0
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn pjrt_paths_error_loudly() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT"), "{err}");
    }
}
