//! Artifact manifest parsing.
//!
//! aot.py writes one `<name>.manifest.txt` per artifact:
//!
//! ```text
//! artifact = lm_grad_s
//! model = llama-s
//! ...
//! num_inputs = 72
//! num_outputs = 30
//! input 0 params[embed] f32 4096x128
//! ...
//! output 0 out f32 scalar
//! ```
//!
//! The manifest is deliberately a trivial line format: Rust needs no
//! serde dependency and any mismatch is loud.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element type of a tensor crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype tag {other:?}"),
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

/// One input or output slot.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub index: usize,
    pub name: String,
    pub dtype: DType,
    /// Empty for scalars.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn byte_len(&self) -> usize {
        4 * self.num_elements()
    }
}

/// Parsed manifest for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub name: String,
    pub meta: HashMap<String, String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect()
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut meta = HashMap::new();
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.is_empty() {
                continue;
            }
            match parts[0] {
                "input" | "output" => {
                    if parts.len() != 5 {
                        bail!("line {}: malformed tensor line {line:?}", lineno + 1);
                    }
                    let spec = TensorSpec {
                        index: parts[1].parse().context("bad index")?,
                        name: parts[2].to_string(),
                        dtype: DType::parse(parts[3])?,
                        shape: parse_shape(parts[4])?,
                    };
                    if parts[0] == "input" {
                        inputs.push(spec);
                    } else {
                        outputs.push(spec);
                    }
                }
                key if parts.len() >= 3 && parts[1] == "=" => {
                    meta.insert(key.to_string(), parts[2..].join(" "));
                }
                _ => bail!("line {}: unrecognized manifest line {line:?}", lineno + 1),
            }
        }
        let name = meta
            .get("artifact")
            .context("manifest missing `artifact =` line")?
            .clone();
        // consistency checks
        let ni: usize = meta
            .get("num_inputs")
            .context("missing num_inputs")?
            .parse()?;
        let no: usize = meta
            .get("num_outputs")
            .context("missing num_outputs")?
            .parse()?;
        if inputs.len() != ni || outputs.len() != no {
            bail!(
                "manifest {name}: counts disagree (inputs {} vs {ni}, outputs {} vs {no})",
                inputs.len(),
                outputs.len()
            );
        }
        for (i, spec) in inputs.iter().enumerate() {
            if spec.index != i {
                bail!("manifest {name}: input {i} has index {}", spec.index);
            }
        }
        for (i, spec) in outputs.iter().enumerate() {
            if spec.index != i {
                bail!("manifest {name}: output {i} has index {}", spec.index);
            }
        }
        Ok(ArtifactManifest { name, meta, inputs, outputs })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        Self::parse(&text)
    }

    /// Meta value parsed as integer.
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .with_context(|| format!("missing meta key {key}"))?
            .parse()
            .with_context(|| format!("meta key {key} not an integer"))
    }

    /// Index of the first input whose name starts with `prefix`.
    pub fn input_index(&self, prefix: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name.starts_with(prefix))
    }

    /// All input indices whose names start with `prefix`, in order.
    pub fn input_indices(&self, prefix: &str) -> Vec<usize> {
        self.inputs
            .iter()
            .filter(|s| s.name.starts_with(prefix))
            .map(|s| s.index)
            .collect()
    }

    /// All output indices whose names start with `prefix`, in order.
    pub fn output_indices(&self, prefix: &str) -> Vec<usize> {
        self.outputs
            .iter()
            .filter(|s| s.name.starts_with(prefix))
            .map(|s| s.index)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact = demo
model = tiny
num_inputs = 3
num_outputs = 2
input 0 params[embed] f32 64x32
input 1 tokens i32 4x17
input 2 sigma f32 scalar
output 0 out[0] f32 scalar
output 1 out[1] f32 64x32
";

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "demo");
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.outputs.len(), 2);
        assert_eq!(m.inputs[0].shape, vec![64, 32]);
        assert_eq!(m.inputs[1].dtype, DType::I32);
        assert_eq!(m.inputs[2].shape, Vec::<usize>::new());
        assert_eq!(m.inputs[2].num_elements(), 1);
        assert_eq!(m.meta["model"], "tiny");
    }

    #[test]
    fn rejects_count_mismatch() {
        let bad = SAMPLE.replace("num_inputs = 3", "num_inputs = 4");
        assert!(ArtifactManifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_out_of_order_indices() {
        let bad = SAMPLE.replace("input 1 tokens", "input 2 tokens");
        assert!(ArtifactManifest::parse(&bad).is_err());
    }

    #[test]
    fn prefix_lookup() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.input_index("tokens"), Some(1));
        assert_eq!(m.input_indices("params"), vec![0]);
        assert_eq!(m.output_indices("out"), vec![0, 1]);
        assert_eq!(m.input_index("nope"), None);
    }

    #[test]
    fn byte_len_is_4x_elements() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.inputs[0].byte_len(), 64 * 32 * 4);
        assert_eq!(m.inputs[2].byte_len(), 4);
    }
}
