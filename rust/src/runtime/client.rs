//! The caching artifact loader + typed executor.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::ArtifactManifest;
use super::tensor::HostTensor;

// Default builds route `xla::…` to the in-crate stub; `--features pjrt`
// resolves it to the real bindings from the extern prelude.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// One compiled artifact: manifest + PJRT executable.
pub struct LoadedArtifact {
    pub manifest: ArtifactManifest,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    /// Wall time spent compiling (for the perf log).
    pub compile_time_s: f64,
}

impl LoadedArtifact {
    /// Execute with manifest validation. Inputs must match the manifest
    /// slot-for-slot; outputs come back in manifest order.
    ///
    /// Inputs go through `execute_b` with Rust-owned `PjRtBuffer`s
    /// rather than the crate's literal-based `execute`: the latter's C
    /// wrapper `release()`s the device buffers it creates per input and
    /// never frees them — a ~5 MB/step leak at our artifact sizes that
    /// OOMs a long training run (see EXPERIMENTS.md §Perf). The buffer
    /// path also skips one host-side literal copy per input.
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "artifact {}: {} inputs given, manifest wants {}",
                self.manifest.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        let mut buffers = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.manifest.inputs) {
            t.check_spec(spec)
                .with_context(|| format!("artifact {}", self.manifest.name))?;
            let buf = match t {
                HostTensor::F32 { shape, data } => {
                    self.client.buffer_from_host_buffer(data.as_slice(), shape, None)?
                }
                HostTensor::I32 { shape, data } => {
                    self.client.buffer_from_host_buffer(data.as_slice(), shape, None)?
                }
            };
            buffers.push(buf);
        }
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        let root = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "artifact {}: {} outputs returned, manifest wants {}",
                self.manifest.name,
                parts.len(),
                self.manifest.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.manifest.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }
}

/// Caching loader over an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, Arc<LoadedArtifact>>,
}

impl Runtime {
    /// CPU PJRT client over `dir` (usually `artifacts/`).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.join("INDEX.txt").exists() {
            bail!(
                "artifact directory {dir:?} has no INDEX.txt — run `make artifacts` first"
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names listed in INDEX.txt.
    pub fn available(&self) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(self.dir.join("INDEX.txt"))?;
        Ok(text.lines().map(|l| l.trim().to_string()).filter(|l| !l.is_empty()).collect())
    }

    /// Load (compile) an artifact, memoized.
    pub fn load(&mut self, name: &str) -> Result<Arc<LoadedArtifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let manifest = ArtifactManifest::load(&self.dir.join(format!("{name}.manifest.txt")))?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let compile_time_s = t0.elapsed().as_secs_f64();
        let art = Arc::new(LoadedArtifact {
            manifest,
            exe,
            client: self.client.clone(),
            compile_time_s,
        });
        self.cache.insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Golden-vector inputs for an artifact (written by aot.py).
    pub fn golden_inputs(&self, art: &LoadedArtifact) -> Result<Vec<HostTensor>> {
        let gdir = self.dir.join("golden").join(&art.manifest.name);
        art.manifest
            .inputs
            .iter()
            .map(|spec| {
                HostTensor::from_bin_file(&gdir.join(format!("in_{:03}.bin", spec.index)), spec)
            })
            .collect()
    }

    /// Golden-vector outputs.
    pub fn golden_outputs(&self, art: &LoadedArtifact) -> Result<Vec<HostTensor>> {
        let gdir = self.dir.join("golden").join(&art.manifest.name);
        art.manifest
            .outputs
            .iter()
            .map(|spec| {
                HostTensor::from_bin_file(&gdir.join(format!("out_{:03}.bin", spec.index)), spec)
            })
            .collect()
    }
}
