//! Host-side tensors crossing the PJRT boundary.
//!
//! Since the estimator-engine refactor the payload is **shared,
//! copy-on-write**: both variants back their data with an
//! `Arc<Vec<_>>`, so `clone()` is a reference-count bump and the
//! trainers' per-step input staging (`params`, `bs[...]`, `vs[...]`,
//! `zs[...]`, tokens) is zero-copy in steady state. Mutation goes
//! through [`Arc::make_mut`]: unique owners mutate in place (the hot
//! path — staged clones are dropped right after `execute`), shared
//! owners get a private copy first, so value semantics are unchanged.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::{DType, TensorSpec};

// Default builds route `xla::…` to the in-crate stub; `--features pjrt`
// resolves it to the real bindings from the extern prelude.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// A host tensor (row-major), f32 or i32 — the only element types the
/// artifact contract uses. Cloning shares the payload (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Arc<Vec<f32>> },
    I32 { shape: Vec<usize>, data: Arc<Vec<i32>> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::F32 { shape, data: Arc::new(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::I32 { shape, data: Arc::new(data) }
    }

    /// Wrap an already-shared f32 payload without copying — the staging
    /// path trainers use to splice live (B, V, Z) buffers into an
    /// artifact input list.
    pub fn f32_shared(shape: Vec<usize>, data: Arc<Vec<f32>>) -> Self {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::F32 { shape, data }
    }

    /// Wrap an already-shared i32 payload without copying.
    pub fn i32_shared(shape: Vec<usize>, data: Arc<Vec<i32>>) -> Self {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: Arc::new(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product::<usize>().max(1);
        HostTensor::F32 { shape, data: Arc::new(vec![0.0; n]) }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn num_elements(&self) -> usize {
        self.shape().iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data.as_slice()),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Mutable f32 view (copy-on-write: unique owners mutate in place;
    /// a tensor whose payload is still staged elsewhere is unshared
    /// first, preserving value semantics).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(Arc::make_mut(data).as_mut_slice()),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Share the f32 payload (reference-count bump, no copy).
    pub fn f32_arc(&self) -> Result<Arc<Vec<f32>>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data.clone()),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data.as_slice()),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar f32 value (shape [] or [1]).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("not a scalar: {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Validate against a manifest slot.
    pub fn check_spec(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!(
                "input {} ({}): dtype {} != manifest {}",
                spec.index,
                spec.name,
                self.dtype().tag(),
                spec.dtype.tag()
            );
        }
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "input {} ({}): shape {:?} != manifest {:?}",
                spec.index,
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        Ok(())
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        if dims.is_empty() {
            // scalar: reshape a 1-element vector to rank 0
            lit.reshape(&[]).context("reshape to scalar")
        } else {
            lit.reshape(&dims).context("reshape literal")
        }
    }

    /// Read back from an XLA literal with a known spec.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        let t = match spec.dtype {
            DType::F32 => {
                HostTensor::F32 { shape: spec.shape.clone(), data: Arc::new(lit.to_vec::<f32>()?) }
            }
            DType::I32 => {
                HostTensor::I32 { shape: spec.shape.clone(), data: Arc::new(lit.to_vec::<i32>()?) }
            }
        };
        if t.num_elements() != spec.num_elements() {
            bail!(
                "output {} ({}): element count {} != manifest {}",
                spec.index,
                spec.name,
                t.num_elements(),
                spec.num_elements()
            );
        }
        Ok(t)
    }

    /// Load from a raw little-endian binary (the aot.py golden format).
    pub fn from_bin_file(path: &std::path::Path, spec: &TensorSpec) -> Result<Self> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != spec.byte_len() {
            bail!(
                "{path:?}: {} bytes, manifest says {} ({})",
                bytes.len(),
                spec.byte_len(),
                spec.name
            );
        }
        Ok(match spec.dtype {
            DType::F32 => {
                let data = bytes
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                HostTensor::F32 { shape: spec.shape.clone(), data: Arc::new(data) }
            }
            DType::I32 => {
                let data = bytes
                    .chunks_exact(4)
                    .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                HostTensor::I32 { shape: spec.shape.clone(), data: Arc::new(data) }
            }
        })
    }

    /// Max |a − b| against another f32 tensor.
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            bail!("length mismatch {} vs {}", a.len(), b.len());
        }
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(dtype: DType, shape: Vec<usize>) -> TensorSpec {
        TensorSpec { index: 0, name: "t".into(), dtype, shape }
    }

    #[test]
    fn shape_data_consistency_enforced() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.num_elements(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_data_len() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(2.5);
        assert_eq!(t.scalar().unwrap(), 2.5);
        assert_eq!(t.num_elements(), 1);
    }

    #[test]
    fn check_spec_catches_mismatches() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert!(t.check_spec(&spec(DType::F32, vec![2, 3])).is_ok());
        assert!(t.check_spec(&spec(DType::F32, vec![3, 2])).is_err());
        assert!(t.check_spec(&spec(DType::I32, vec![2, 3])).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let s = spec(DType::F32, vec![2, 2]);
        let back = HostTensor::from_literal(&lit, &s).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_and_scalar() {
        let t = HostTensor::i32(vec![3], vec![7, -1, 2]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec(DType::I32, vec![3])).unwrap();
        assert_eq!(t, back);

        let s = HostTensor::scalar_f32(-0.5);
        let lit = s.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec(DType::F32, vec![])).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn clone_shares_payload_and_mutation_unshares() {
        let a = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        let mut b = a.clone();
        // staged clone: same allocation, no copy
        assert_eq!(a.as_f32().unwrap().as_ptr(), b.as_f32().unwrap().as_ptr());
        // copy-on-write: mutating the clone leaves the original intact
        b.as_f32_mut().unwrap()[0] = 9.0;
        assert_eq!(a.as_f32().unwrap()[0], 1.0);
        assert_eq!(b.as_f32().unwrap()[0], 9.0);
        assert_ne!(a.as_f32().unwrap().as_ptr(), b.as_f32().unwrap().as_ptr());
        // unique owner mutates in place (the steady-state hot path)
        let p = b.as_f32().unwrap().as_ptr();
        b.as_f32_mut().unwrap()[1] = 7.0;
        assert_eq!(b.as_f32().unwrap().as_ptr(), p);
    }

    #[test]
    fn shared_constructors_wrap_without_copy() {
        let buf = Arc::new(vec![1.0f32, 2.0, 3.0]);
        let t = HostTensor::f32_shared(vec![3], buf.clone());
        assert_eq!(t.as_f32().unwrap().as_ptr(), buf.as_ptr());
        assert_eq!(t.f32_arc().unwrap().as_ptr(), buf.as_ptr());
        let ibuf = Arc::new(vec![1i32, 2]);
        let it = HostTensor::i32_shared(vec![2], ibuf.clone());
        assert_eq!(it.as_i32().unwrap().as_ptr(), ibuf.as_ptr());
        assert!(it.f32_arc().is_err());
    }

    #[test]
    fn bin_file_roundtrip() {
        let dir = std::env::temp_dir().join("lowrank_sge_test_bin");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let data = vec![1.5f32, -2.25, 0.0];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let s = spec(DType::F32, vec![3]);
        let t = HostTensor::from_bin_file(&path, &s).unwrap();
        assert_eq!(t.as_f32().unwrap(), data.as_slice());
    }
}
