//! On-disk layout of a checkpoint directory:
//!
//! ```text
//! <ckpt-dir>/
//!   LATEST              # `latest = <step>` (key = value dialect)
//!   step-0000001200/    # one committed checkpoint
//!     MANIFEST
//!     params.tsr
//!     subspace.tsr
//!     ...
//!   .tmp-step-…         # in-flight write (renamed into place on commit)
//! ```
//!
//! Commits are atomic at the directory level: shards and MANIFEST are
//! written into a temp dir which is `rename(2)`d to its final name, so a
//! crash mid-save never leaves a half-readable `step-*` directory, and
//! `LATEST` is itself updated via write-temp-then-rename.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Zero-padded so lexicographic order == numeric order.
pub fn step_dir_name(step: u64) -> String {
    format!("step-{step:010}")
}

/// Inverse of [`step_dir_name`]; `None` for foreign directory names.
pub fn parse_step_dir(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("step-")?;
    if digits.len() != 10 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// What `--resume` asked for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResumeSpec {
    /// Follow the `LATEST` pointer (falling back to the highest
    /// committed step if the pointer is missing).
    Latest,
    /// A specific committed step.
    Step(u64),
}

impl ResumeSpec {
    pub fn parse(s: &str) -> Result<Self> {
        if s.eq_ignore_ascii_case("latest") {
            return Ok(ResumeSpec::Latest);
        }
        match s.parse::<u64>() {
            Ok(step) => Ok(ResumeSpec::Step(step)),
            Err(_) => bail!("bad --resume value {s:?} (want `latest` or a step number)"),
        }
    }
}

impl std::fmt::Display for ResumeSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResumeSpec::Latest => write!(f, "latest"),
            ResumeSpec::Step(s) => write!(f, "{s}"),
        }
    }
}

/// Path helpers over one checkpoint root.
#[derive(Clone, Debug)]
pub struct Layout {
    root: PathBuf,
}

impl Layout {
    pub fn new(root: impl AsRef<Path>) -> Self {
        Layout { root: root.as_ref().to_path_buf() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn step_dir(&self, step: u64) -> PathBuf {
        self.root.join(step_dir_name(step))
    }

    pub fn tmp_dir(&self, step: u64) -> PathBuf {
        self.root.join(format!(".tmp-{}", step_dir_name(step)))
    }

    pub fn latest_path(&self) -> PathBuf {
        self.root.join("LATEST")
    }

    /// Committed steps (directories with a MANIFEST), ascending.
    pub fn list_steps(&self) -> Result<Vec<u64>> {
        let mut steps = Vec::new();
        let entries = match std::fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(_) => return Ok(steps), // no directory yet == no checkpoints
        };
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(step) = parse_step_dir(name) else { continue };
            if entry.path().join(super::manifest::MANIFEST_FILE).is_file() {
                steps.push(step);
            }
        }
        steps.sort_unstable();
        Ok(steps)
    }

    /// Read the `LATEST` pointer, if present and well-formed.
    pub fn read_latest(&self) -> Result<Option<u64>> {
        let path = self.latest_path();
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        for line in text.lines() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if let ["latest", "=", v] = parts.as_slice() {
                let step = v
                    .parse::<u64>()
                    .with_context(|| format!("{path:?}: bad step {v:?}"))?;
                return Ok(Some(step));
            }
        }
        bail!("{path:?} has no `latest = <step>` line");
    }

    /// Atomically point `LATEST` at `step`.
    pub fn write_latest(&self, step: u64) -> Result<()> {
        let tmp = self.root.join(".LATEST.tmp");
        std::fs::write(&tmp, format!("latest = {step}\n"))
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, self.latest_path()).context("committing LATEST pointer")?;
        Ok(())
    }

    /// Resolve a resume spec against the committed checkpoints.
    pub fn resolve(&self, spec: ResumeSpec) -> Result<u64> {
        let steps = self.list_steps()?;
        match spec {
            ResumeSpec::Step(step) => {
                if !steps.contains(&step) {
                    bail!(
                        "no committed checkpoint at step {step} under {:?} (have: {steps:?})",
                        self.root
                    );
                }
                Ok(step)
            }
            ResumeSpec::Latest => {
                if let Some(step) = self.read_latest()? {
                    if steps.contains(&step) {
                        return Ok(step);
                    }
                    // stale pointer (e.g. pruned by hand): fall back
                }
                steps.last().copied().with_context(|| {
                    format!("no committed checkpoints under {:?}", self.root)
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_dir_names_roundtrip_and_sort() {
        assert_eq!(step_dir_name(0), "step-0000000000");
        assert_eq!(step_dir_name(1200), "step-0000001200");
        assert_eq!(parse_step_dir("step-0000001200"), Some(1200));
        assert_eq!(parse_step_dir("step-12"), None);
        assert_eq!(parse_step_dir("other"), None);
        assert!(step_dir_name(9) < step_dir_name(10));
        assert!(step_dir_name(999) < step_dir_name(1000));
    }

    #[test]
    fn resume_spec_parses() {
        assert_eq!(ResumeSpec::parse("latest").unwrap(), ResumeSpec::Latest);
        assert_eq!(ResumeSpec::parse("LATEST").unwrap(), ResumeSpec::Latest);
        assert_eq!(ResumeSpec::parse("400").unwrap(), ResumeSpec::Step(400));
        assert!(ResumeSpec::parse("-3").is_err());
        assert!(ResumeSpec::parse("soonish").is_err());
    }

    #[test]
    fn latest_pointer_roundtrip() {
        let root = std::env::temp_dir().join("lowrank_sge_layout_test");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let layout = Layout::new(&root);
        assert_eq!(layout.read_latest().unwrap(), None);
        layout.write_latest(77).unwrap();
        assert_eq!(layout.read_latest().unwrap(), Some(77));
        layout.write_latest(154).unwrap();
        assert_eq!(layout.read_latest().unwrap(), Some(154));
        assert!(layout.list_steps().unwrap().is_empty()); // pointer only, no dirs
    }

    #[test]
    fn list_steps_ignores_foreign_and_manifestless_dirs() {
        let root = std::env::temp_dir().join("lowrank_sge_layout_list_test");
        let _ = std::fs::remove_dir_all(&root);
        let layout = Layout::new(&root);
        assert!(layout.list_steps().unwrap().is_empty()); // missing root ok
        for (step, with_manifest) in [(5u64, true), (10, false), (2, true)] {
            let d = layout.step_dir(step);
            std::fs::create_dir_all(&d).unwrap();
            if with_manifest {
                std::fs::write(d.join(super::super::manifest::MANIFEST_FILE), "x").unwrap();
            }
        }
        std::fs::create_dir_all(root.join("not-a-step")).unwrap();
        assert_eq!(layout.list_steps().unwrap(), vec![2, 5]);
    }
}
