//! Fully-async checkpointing: the whole `save_checkpoint` call runs on
//! a background IO thread, double-buffered against live trainer state.
//!
//! The trainer snapshots its state dicts — an `Arc` bump per tensor,
//! since every `HostTensor` payload is copy-on-write
//! ([`crate::runtime::HostTensor`]) — hands them to
//! [`AsyncCheckpointer::submit`], and keeps stepping immediately. The
//! first post-snapshot mutation of a shared tensor unshares it
//! (`Arc::make_mut`), so the writer always serializes the exact bytes
//! of the save-point state while the optimizer moves on.
//!
//! At most one save is in flight: `submit` joins the previous one
//! first, so a failed write surfaces **at the next save**, and
//! [`AsyncCheckpointer::drain`] joins at shutdown so the last save both
//! completes and reports its error before the run returns. The write
//! itself is the unchanged atomic pipeline of [`super::writer`] —
//! kernel-pool shard staging, temp-dir + rename commit, `LATEST`,
//! retention — so the bytes on disk are identical to a synchronous
//! save.

use std::path::PathBuf;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::state::StateDict;
use super::writer::save_checkpoint;

struct Pending {
    step: u64,
    handle: JoinHandle<Result<PathBuf>>,
}

/// Owns the (at most one) in-flight background checkpoint write.
#[derive(Default)]
pub struct AsyncCheckpointer {
    pending: Option<Pending>,
}

impl AsyncCheckpointer {
    pub fn new() -> Self {
        AsyncCheckpointer { pending: None }
    }

    /// Step number of the save currently in flight, if any.
    pub fn in_flight(&self) -> Option<u64> {
        self.pending.as_ref().map(|p| p.step)
    }

    /// Queue one checkpoint write on a background thread. Joins (and
    /// surfaces the error of) any previous in-flight save first, so the
    /// trainer is never more than one checkpoint ahead of durable
    /// state. `groups` are the snapshotted state dicts — building them
    /// is an `Arc` bump per tensor, so the trainer-side cost of a save
    /// is O(tensor count), not O(bytes).
    pub fn submit(
        &mut self,
        root: PathBuf,
        step: u64,
        meta: Vec<(String, String)>,
        groups: Vec<(String, StateDict)>,
        keep_last: usize,
    ) -> Result<()> {
        self.drain()?;
        let handle = std::thread::Builder::new()
            .name(format!("ckpt-writer-{step}"))
            .spawn(move || {
                let _span = crate::obs::span("ckpt", "async_save");
                crate::obs::metrics::CKPT_SAVES.add(1);
                let meta_refs: Vec<(&str, String)> =
                    meta.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
                let group_refs: Vec<(&str, StateDict)> =
                    groups.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
                save_checkpoint(&root, step, &meta_refs, &group_refs, keep_last)
            })
            .context("spawning the checkpoint writer thread")?;
        self.pending = Some(Pending { step, handle });
        Ok(())
    }

    /// Non-blocking error probe: if the in-flight save has already
    /// finished, join it now and surface its result; if it is still
    /// running (or there is none), return `Ok(())` immediately. This
    /// lets a scheduler interleaving many sessions detect a failed
    /// background write on the *failing* session's next slice instead
    /// of stalling every tenant behind a blocking `drain`.
    pub fn poll(&mut self) -> Result<()> {
        if self.pending.as_ref().is_some_and(|p| p.handle.is_finished()) {
            return self.drain();
        }
        Ok(())
    }

    /// Join the in-flight save (if any), surfacing its error — called
    /// by `submit` before queueing the next save and by the trainers at
    /// shutdown, so no write failure is ever silently dropped.
    pub fn drain(&mut self) -> Result<()> {
        if let Some(p) = self.pending.take() {
            let res = p
                .handle
                .join()
                .map_err(|_| anyhow!("checkpoint writer thread panicked (step {})", p.step))?;
            res.with_context(|| format!("async checkpoint save at step {}", p.step))?;
        }
        Ok(())
    }
}

impl Drop for AsyncCheckpointer {
    /// Last-resort join: a trainer that errors out mid-run still waits
    /// for the writer (no torn temp state left behind by a racing
    /// process exit); the error — already surfaced to the caller path
    /// that mattered — is only logged here.
    fn drop(&mut self) {
        if let Err(e) = self.drain() {
            eprintln!("warning: background checkpoint write failed during shutdown: {e:#}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::layout::ResumeSpec;
    use super::super::writer::load_checkpoint;
    use super::*;

    fn toy_groups() -> Vec<(String, StateDict)> {
        let mut a = StateDict::new();
        a.put_f32("w", vec![2], vec![1.5, -2.5]);
        let mut b = StateDict::new();
        b.put_u64s("state", &[7, 8, 9, 10]);
        vec![("params".to_string(), a), ("rng".to_string(), b)]
    }

    fn fresh_root(tag: &str) -> PathBuf {
        let root = std::env::temp_dir().join(format!("lowrank_sge_async_writer_{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        root
    }

    #[test]
    fn submit_then_drain_commits_a_loadable_checkpoint() {
        let root = fresh_root("roundtrip");
        let mut w = AsyncCheckpointer::new();
        w.submit(
            root.clone(),
            12,
            vec![("trainer".to_string(), "pretrain".to_string())],
            toy_groups(),
            0,
        )
        .unwrap();
        assert_eq!(w.in_flight(), Some(12));
        w.drain().unwrap();
        assert_eq!(w.in_flight(), None);
        let ckpt = load_checkpoint(&root, ResumeSpec::Latest).unwrap();
        assert_eq!(ckpt.step, 12);
        assert_eq!(ckpt.meta_str("trainer"), Some("pretrain"));
        assert_eq!(ckpt.group("params").unwrap().f32("w").unwrap(), &[1.5, -2.5]);
    }

    #[test]
    fn back_to_back_submits_keep_at_most_one_in_flight() {
        let root = fresh_root("pipeline");
        let mut w = AsyncCheckpointer::new();
        for step in [10u64, 20, 30] {
            w.submit(root.clone(), step, Vec::new(), toy_groups(), 0).unwrap();
        }
        w.drain().unwrap();
        for step in [10u64, 20, 30] {
            assert_eq!(load_checkpoint(&root, ResumeSpec::Step(step)).unwrap().step, step);
        }
    }

    #[test]
    fn write_failure_surfaces_at_the_next_interaction() {
        let root = fresh_root("failure");
        // make the root unusable: a plain file where the dir should go
        std::fs::write(&root, b"not a directory").unwrap();
        let mut w = AsyncCheckpointer::new();
        w.submit(root.clone(), 5, Vec::new(), toy_groups(), 0).unwrap();
        let err = format!("{:#}", w.drain().unwrap_err());
        assert!(err.contains("step 5"), "{err}");
        // the checkpointer is reusable after surfacing the error
        let _ = std::fs::remove_file(&root);
        w.submit(root.clone(), 6, Vec::new(), toy_groups(), 0).unwrap();
        w.drain().unwrap();
        assert_eq!(load_checkpoint(&root, ResumeSpec::Latest).unwrap().step, 6);
    }

    #[test]
    fn snapshot_isolation_mutating_after_submit_does_not_corrupt_the_save() {
        use crate::runtime::HostTensor;
        let root = fresh_root("cow");
        // the trainer pattern: live tensor and snapshot share one
        // Arc-backed payload …
        let mut live = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let mut snap = StateDict::new();
        snap.put_tensor("w", live.clone());
        let mut w = AsyncCheckpointer::new();
        w.submit(root.clone(), 1, Vec::new(), vec![("g".to_string(), snap)], 0).unwrap();
        // … and the first post-snapshot mutation unshares (Arc::make_mut)
        // instead of racing the writer
        for x in live.as_f32_mut().unwrap() {
            *x = -9.0;
        }
        w.drain().unwrap();
        let ckpt = load_checkpoint(&root, ResumeSpec::Latest).unwrap();
        assert_eq!(ckpt.group("g").unwrap().f32("w").unwrap(), &[1.0, 2.0, 3.0]);
    }
}
