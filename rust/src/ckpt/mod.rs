//! `ckpt` — sharded checkpoint + resume for every trainer.
//!
//! Long-horizon training (Algorithm 1 runs for thousands of lazy-update
//! steps) needs durable state: a crash at step 9 999 must cost at most
//! `save_every` steps, and a resumed run must continue on the *same*
//! trajectory — which means round-tripping not just Θ but the subspace
//! state (B, V), every Adam moment, and the RNG stream position
//! bit-exactly.
//!
//! * [`crc32`] — dependency-free CRC-32 (IEEE), the shard integrity check.
//! * [`codec`] — the versioned binary tensor codec (`LRCK` magic +
//!   header + f32/i32 payloads + trailing CRC-32).
//! * [`state`] — [`StateDict`] and the [`Checkpointable`] capture/restore
//!   trait, implemented by [`crate::model::ParamStore`],
//!   [`crate::optim::Adam`], [`crate::coordinator::SubspaceSet`], and
//!   [`crate::rng::Rng`].
//! * [`manifest`] — the per-step `MANIFEST` in the same `key = value`
//!   dialect as [`crate::runtime::manifest`].
//! * [`layout`] — `ckpt/<step>/` naming, the `LATEST` pointer,
//!   [`ResumeSpec`] (`latest` or a step number).
//! * [`writer`] — atomic commit (temp dir + rename), full-verification
//!   load, and retention of the newest K checkpoints.
//! * [`async_writer`] — [`AsyncCheckpointer`]: the whole save on a
//!   background IO thread, double-buffered against live trainer state
//!   via the `Arc`-backed copy-on-write tensors; errors surface at the
//!   next save or at shutdown, the trainer never blocks on IO.
//!
//! Trainers drive this through `--save-every N --ckpt-dir D` and
//! `--resume [latest|<step>]`. In a multi-process `launch` run only the
//! leader rank writes — enforced by the [`crate::coordinator::Collective`]
//! leader gate and the trainers' `save_state` guard, with every rank
//! crossing the same save barrier (the barrier aligns step counts;
//! async saves become durable at the writer's next drain).
//!
//! The `comm` wire format ([`crate::comm::wire`]) reuses this module's
//! framing discipline (magic + dtype + CRC-32) and [`crc32`]
//! implementation, so gradient payloads on the wire are self-validating
//! exactly like checkpoint shards on disk.

pub mod async_writer;
pub mod codec;
pub mod crc32;
pub mod layout;
pub mod manifest;
pub mod state;
pub mod writer;

pub use async_writer::AsyncCheckpointer;
pub use layout::{Layout, ResumeSpec};
pub use manifest::CkptManifest;
pub use state::{Checkpointable, StateDict};
pub use writer::{load_checkpoint, save_checkpoint, CkptOptions, LoadedCheckpoint};
