//! The per-step `MANIFEST` — the same trivial `key = value` line dialect
//! as [`crate::runtime::manifest`], plus one `group` line per shard:
//!
//! ```text
//! format = lowrank-sge-ckpt
//! version = 1
//! step = 1200
//! trainer = pretrain
//! scale = s
//! num_groups = 4
//! group params params.tsr 0x1a2b3c4d 14
//! group subspace subspace.tsr 0x99aa55ee 37
//! ...
//! ```
//!
//! A checkpoint is only valid if the MANIFEST parses, every listed shard
//! exists, and every shard's CRC matches both its own trailer and the
//! value recorded here.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const FORMAT_TAG: &str = "lowrank-sge-ckpt";
pub const MANIFEST_VERSION: u32 = 1;
pub const MANIFEST_FILE: &str = "MANIFEST";

/// One shard entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupEntry {
    pub name: String,
    pub file: String,
    pub crc32: u32,
    pub tensors: usize,
}

/// Parsed per-step manifest.
#[derive(Clone, Debug)]
pub struct CkptManifest {
    pub step: u64,
    /// Trainer-supplied key/value metadata (trainer kind, scale, …).
    pub meta: BTreeMap<String, String>,
    pub groups: Vec<GroupEntry>,
}

/// Group names become file stems: keep them path-safe.
pub fn validate_group_name(name: &str) -> Result<()> {
    if name.is_empty()
        || !name
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '-')
    {
        bail!("invalid checkpoint group name {name:?} (want [a-z0-9_-]+)");
    }
    Ok(())
}

impl CkptManifest {
    pub fn new(step: u64) -> Self {
        CkptManifest { step, meta: BTreeMap::new(), groups: Vec::new() }
    }

    pub fn render(&self) -> String {
        let mut lines = Vec::new();
        lines.push(format!("format = {FORMAT_TAG}"));
        lines.push(format!("version = {MANIFEST_VERSION}"));
        lines.push(format!("step = {}", self.step));
        for (k, v) in &self.meta {
            lines.push(format!("{k} = {v}"));
        }
        lines.push(format!("num_groups = {}", self.groups.len()));
        for g in &self.groups {
            lines.push(format!("group {} {} {:#010x} {}", g.name, g.file, g.crc32, g.tensors));
        }
        lines.join("\n") + "\n"
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut meta = BTreeMap::new();
        let mut groups = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.is_empty() {
                continue;
            }
            match parts[0] {
                "group" => {
                    if parts.len() != 5 {
                        bail!("MANIFEST line {}: malformed group line {line:?}", lineno + 1);
                    }
                    let crc_str = parts[3];
                    let crc32 = u32::from_str_radix(
                        crc_str.strip_prefix("0x").unwrap_or(crc_str),
                        16,
                    )
                    .with_context(|| format!("MANIFEST line {}: bad crc", lineno + 1))?;
                    groups.push(GroupEntry {
                        name: parts[1].to_string(),
                        file: parts[2].to_string(),
                        crc32,
                        tensors: parts[4]
                            .parse()
                            .with_context(|| format!("MANIFEST line {}: bad count", lineno + 1))?,
                    });
                }
                key if parts.len() >= 3 && parts[1] == "=" => {
                    meta.insert(key.to_string(), parts[2..].join(" "));
                }
                _ => bail!("MANIFEST line {}: unrecognized line {line:?}", lineno + 1),
            }
        }
        match meta.remove("format") {
            Some(tag) if tag == FORMAT_TAG => {}
            other => bail!("not a checkpoint MANIFEST (format tag {other:?})"),
        }
        let version: u32 = meta
            .remove("version")
            .context("MANIFEST missing version")?
            .parse()
            .context("MANIFEST version not an integer")?;
        if version != MANIFEST_VERSION {
            bail!("unsupported checkpoint MANIFEST version {version}");
        }
        let step: u64 = meta
            .remove("step")
            .context("MANIFEST missing step")?
            .parse()
            .context("MANIFEST step not an integer")?;
        let num_groups: usize = meta
            .remove("num_groups")
            .context("MANIFEST missing num_groups")?
            .parse()
            .context("MANIFEST num_groups not an integer")?;
        if groups.len() != num_groups {
            bail!("MANIFEST lists {} groups but num_groups = {num_groups}", groups.len());
        }
        for g in &groups {
            validate_group_name(&g.name)?;
        }
        Ok(CkptManifest { step, meta, groups })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint MANIFEST {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parsing {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CkptManifest {
        let mut m = CkptManifest::new(1200);
        m.meta.insert("trainer".into(), "pretrain".into());
        m.meta.insert("scale".into(), "s".into());
        m.groups.push(GroupEntry {
            name: "params".into(),
            file: "params.tsr".into(),
            crc32: 0x1A2B_3C4D,
            tensors: 14,
        });
        m.groups.push(GroupEntry {
            name: "rng".into(),
            file: "rng.tsr".into(),
            crc32: 0xFFFF_0000,
            tensors: 1,
        });
        m
    }

    #[test]
    fn render_parse_roundtrip() {
        let m = sample();
        let back = CkptManifest::parse(&m.render()).unwrap();
        assert_eq!(back.step, 1200);
        assert_eq!(back.meta.get("trainer").map(String::as_str), Some("pretrain"));
        assert_eq!(back.meta.get("scale").map(String::as_str), Some("s"));
        assert_eq!(back.groups, m.groups);
    }

    #[test]
    fn rejects_wrong_format_and_count_mismatch() {
        let text = sample().render();
        assert!(CkptManifest::parse(&text.replace(FORMAT_TAG, "other")).is_err());
        assert!(CkptManifest::parse(&text.replace("num_groups = 2", "num_groups = 3")).is_err());
        assert!(CkptManifest::parse("junk line\n").is_err());
    }

    #[test]
    fn group_names_are_validated() {
        assert!(validate_group_name("params").is_ok());
        assert!(validate_group_name("full_slots-2").is_ok());
        assert!(validate_group_name("").is_err());
        assert!(validate_group_name("../evil").is_err());
        assert!(validate_group_name("Caps").is_err());
    }
}
