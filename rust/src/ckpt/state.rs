//! [`StateDict`] — the ordered, named tensor map a component serializes
//! itself into — and [`Checkpointable`], the capture/restore contract
//! every piece of training state implements ([`crate::model::ParamStore`],
//! [`crate::optim::Adam`], `SubspaceSet`, [`crate::rng::Rng`]).
//!
//! Payloads are restricted to the codec's f32/i32 dtypes; wider values
//! (u64 step counters, f64 projector entries) are carried losslessly as
//! (lo, hi) i32 word pairs so every restore is bit-exact.

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

/// An ordered set of named tensors. Insertion order is the on-disk
/// order, names must be unique within a dict.
#[derive(Clone, Debug, Default)]
pub struct StateDict {
    entries: Vec<(String, HostTensor)>,
}

/// Pack u64 words as (lo, hi) i32 pairs — the codec's only integer type.
fn u64s_to_i32s(xs: &[u64]) -> Vec<i32> {
    let mut out = Vec::with_capacity(2 * xs.len());
    for &x in xs {
        out.push((x & 0xFFFF_FFFF) as u32 as i32);
        out.push((x >> 32) as u32 as i32);
    }
    out
}

fn i32s_to_u64s(xs: &[i32]) -> Result<Vec<u64>> {
    if xs.len() % 2 != 0 {
        bail!("u64-encoded tensor has odd length {}", xs.len());
    }
    Ok(xs
        .chunks_exact(2)
        .map(|p| (p[0] as u32 as u64) | ((p[1] as u32 as u64) << 32))
        .collect())
}

impl StateDict {
    pub fn new() -> Self {
        StateDict::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[(String, HostTensor)] {
        &self.entries
    }

    /// Build from raw entries (the codec's decode path); names must be
    /// unique.
    pub fn from_entries(entries: Vec<(String, HostTensor)>) -> Result<Self> {
        for (i, (name, _)) in entries.iter().enumerate() {
            if entries[..i].iter().any(|(n, _)| n == name) {
                bail!("duplicate tensor name {name:?} in state dict");
            }
        }
        Ok(StateDict { entries })
    }

    /// Insert a tensor; panics on duplicate names (a serialization bug,
    /// not a runtime condition).
    pub fn put_tensor(&mut self, name: impl Into<String>, t: HostTensor) {
        let name = name.into();
        assert!(
            !self.entries.iter().any(|(n, _)| *n == name),
            "duplicate state-dict entry {name:?}"
        );
        self.entries.push((name, t));
    }

    pub fn put_f32(&mut self, name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) {
        self.put_tensor(name, HostTensor::f32(shape, data));
    }

    pub fn put_i32(&mut self, name: impl Into<String>, shape: Vec<usize>, data: Vec<i32>) {
        self.put_tensor(name, HostTensor::i32(shape, data));
    }

    /// Store u64 words losslessly (i32 tensor of length 2n).
    pub fn put_u64s(&mut self, name: impl Into<String>, xs: &[u64]) {
        let data = u64s_to_i32s(xs);
        self.put_i32(name, vec![data.len()], data);
    }

    /// Store f64 values losslessly via their IEEE-754 bit patterns.
    pub fn put_f64_bits(&mut self, name: impl Into<String>, xs: &[f64]) {
        let bits: Vec<u64> = xs.iter().map(|x| x.to_bits()).collect();
        self.put_u64s(name, &bits);
    }

    pub fn tensor(&self, name: &str) -> Result<&HostTensor> {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
            .with_context(|| format!("state dict missing tensor {name:?}"))
    }

    pub fn f32(&self, name: &str) -> Result<&[f32]> {
        self.tensor(name)?
            .as_f32()
            .with_context(|| format!("tensor {name:?}"))
    }

    pub fn i32(&self, name: &str) -> Result<&[i32]> {
        self.tensor(name)?
            .as_i32()
            .with_context(|| format!("tensor {name:?}"))
    }

    pub fn u64s(&self, name: &str) -> Result<Vec<u64>> {
        i32s_to_u64s(self.i32(name)?).with_context(|| format!("tensor {name:?}"))
    }

    /// Single u64 scalar (length-1 u64 tensor).
    pub fn u64(&self, name: &str) -> Result<u64> {
        let xs = self.u64s(name)?;
        if xs.len() != 1 {
            bail!("tensor {name:?}: expected 1 u64, got {}", xs.len());
        }
        Ok(xs[0])
    }

    pub fn f64_bits(&self, name: &str) -> Result<Vec<f64>> {
        Ok(self.u64s(name)?.into_iter().map(f64::from_bits).collect())
    }

    /// Merge another dict's entries under `prefix` (nesting, e.g. per-slot
    /// optimizer state: `adam[layer0.wq].m`).
    pub fn merge_prefixed(&mut self, prefix: &str, other: StateDict) {
        for (name, t) in other.entries {
            self.put_tensor(format!("{prefix}{name}"), t);
        }
    }

    /// Inverse of [`merge_prefixed`]: the sub-dict of entries under
    /// `prefix`, with the prefix stripped.
    pub fn extract_prefixed(&self, prefix: &str) -> StateDict {
        let entries = self
            .entries
            .iter()
            .filter_map(|(n, t)| {
                n.strip_prefix(prefix).map(|rest| (rest.to_string(), t.clone()))
            })
            .collect();
        StateDict { entries }
    }

    /// Total payload bytes (4 per element).
    pub fn payload_bytes(&self) -> usize {
        self.entries.iter().map(|(_, t)| 4 * t.num_elements()).sum()
    }
}

/// Capture/restore contract. `load_state` must reject shape or length
/// mismatches instead of silently truncating — a checkpoint from a
/// different model or config is an error, not a warm start.
pub trait Checkpointable {
    /// Serialize the full mutable state into named tensors.
    fn state_dict(&self) -> StateDict;

    /// Restore from a captured dict; bit-exact inverse of `state_dict`.
    fn load_state(&mut self, sd: &StateDict) -> Result<()>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_packing_roundtrips_extremes() {
        let xs = [0u64, 1, u64::MAX, 0xDEAD_BEEF_CAFE_F00D, 1 << 63];
        let packed = u64s_to_i32s(&xs);
        assert_eq!(packed.len(), 10);
        assert_eq!(i32s_to_u64s(&packed).unwrap(), xs.to_vec());
        assert!(i32s_to_u64s(&packed[..3]).is_err());
    }

    #[test]
    fn f64_bits_roundtrip_is_bit_exact() {
        let xs = [0.0f64, -0.0, 1.5e-300, f64::MAX, f64::NEG_INFINITY, f64::NAN];
        let mut sd = StateDict::new();
        sd.put_f64_bits("x", &xs);
        let back = sd.f64_bits("x").unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn prefix_merge_and_extract_invert() {
        let mut inner = StateDict::new();
        inner.put_f32("m", vec![2], vec![1.0, 2.0]);
        inner.put_u64s("t", &[7]);
        let mut outer = StateDict::new();
        outer.put_f32("w", vec![1], vec![0.5]);
        outer.merge_prefixed("adam[q].", inner);
        assert_eq!(outer.len(), 3);
        let sub = outer.extract_prefixed("adam[q].");
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.f32("m").unwrap(), &[1.0, 2.0]);
        assert_eq!(sub.u64("t").unwrap(), 7);
        assert!(outer.extract_prefixed("nope.").is_empty());
    }

    #[test]
    fn missing_and_duplicate_names_are_errors() {
        let mut sd = StateDict::new();
        sd.put_f32("a", vec![1], vec![0.0]);
        assert!(sd.tensor("b").is_err());
        assert!(StateDict::from_entries(vec![
            ("x".into(), HostTensor::f32(vec![1], vec![0.0])),
            ("x".into(), HostTensor::f32(vec![1], vec![0.0])),
        ])
        .is_err());
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn put_panics_on_duplicate() {
        let mut sd = StateDict::new();
        sd.put_f32("a", vec![1], vec![0.0]);
        sd.put_f32("a", vec![1], vec![1.0]);
    }
}
