//! The versioned binary tensor-group codec.
//!
//! One shard file holds one [`StateDict`] (a named tensor group):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"LRCK"
//! 4       4     version u32 LE (currently 1)
//! 8       4     tensor count u32 LE
//! --- per tensor, in order ---
//!         4     name length u32 LE
//!         n     name bytes (UTF-8)
//!         1     dtype tag (0 = f32, 1 = i32)
//!         4     rank u32 LE
//!         4·r   dims u32 LE each
//!         4·∏d  payload, little-endian 4-byte elements
//! --- trailer ---
//!         4     CRC-32 (IEEE) of every preceding byte
//! ```
//!
//! Everything is length-prefixed and bounds-checked, so truncation,
//! bit-rot, or a wrong file all fail loudly — never load garbage into a
//! training run.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::crc32::crc32;
use super::state::StateDict;
use crate::runtime::HostTensor;

pub const MAGIC: [u8; 4] = *b"LRCK";
pub const VERSION: u32 = 1;

/// Sanity caps: a header field past these is corruption, not data.
const MAX_NAME_LEN: usize = 4096;
const MAX_RANK: usize = 8;

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Serialize a tensor group to bytes (with trailing CRC).
pub fn encode_group(sd: &StateDict) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + sd.payload_bytes() + 64 * sd.len());
    out.extend_from_slice(&MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, sd.len() as u32);
    for (name, t) in sd.entries() {
        put_u32(&mut out, name.len() as u32);
        out.extend_from_slice(name.as_bytes());
        let shape = t.shape();
        match t {
            HostTensor::F32 { .. } => out.push(0u8),
            HostTensor::I32 { .. } => out.push(1u8),
        }
        put_u32(&mut out, shape.len() as u32);
        for &d in shape {
            put_u32(&mut out, d as u32);
        }
        match t {
            HostTensor::F32 { data, .. } => {
                for v in data.iter() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            HostTensor::I32 { data, .. } => {
                for v in data.iter() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    out
}

/// Bounds-checked cursor over the encoded bytes.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!(
                "truncated checkpoint shard: need {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.bytes.len() - self.pos
            );
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

/// Decode a tensor group, verifying magic, version, structure, and CRC.
pub fn decode_group(bytes: &[u8]) -> Result<StateDict> {
    if bytes.len() < MAGIC.len() + 4 + 4 + 4 {
        bail!("truncated checkpoint shard: {} bytes is below the minimum header", bytes.len());
    }
    let (body, trailer) = bytes.split_at(bytes.len() - 4);
    let stored_crc = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let actual_crc = crc32(body);
    if stored_crc != actual_crc {
        bail!(
            "CRC32 mismatch in checkpoint shard: stored {stored_crc:#010x}, \
             computed {actual_crc:#010x} — the file is corrupted or truncated"
        );
    }
    let mut cur = Cursor { bytes: body, pos: 0 };
    if cur.take(4)? != &MAGIC[..] {
        bail!("bad magic: not a lowrank-sge checkpoint shard");
    }
    let version = cur.u32()?;
    if version != VERSION {
        bail!("unsupported checkpoint shard version {version} (expected {VERSION})");
    }
    let count = cur.u32()? as usize;
    let mut entries = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let name_len = cur.u32()? as usize;
        if name_len > MAX_NAME_LEN {
            bail!("corrupt shard: tensor name length {name_len}");
        }
        let name = std::str::from_utf8(cur.take(name_len)?)
            .context("tensor name is not UTF-8")?
            .to_string();
        let dtype = cur.u8()?;
        let rank = cur.u32()? as usize;
        if rank > MAX_RANK {
            bail!("corrupt shard: tensor {name:?} rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(cur.u32()? as usize);
        }
        let n_elem = shape.iter().product::<usize>().max(1);
        let payload = cur.take(4 * n_elem)?;
        let t = match dtype {
            0 => HostTensor::f32(
                shape,
                payload
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
            ),
            1 => HostTensor::i32(
                shape,
                payload
                    .chunks_exact(4)
                    .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
            ),
            other => bail!("corrupt shard: tensor {name:?} has unknown dtype tag {other}"),
        };
        entries.push((name, t));
    }
    if cur.pos != body.len() {
        bail!(
            "corrupt shard: {} trailing bytes after the last tensor",
            body.len() - cur.pos
        );
    }
    StateDict::from_entries(entries)
}

/// Write a group shard to `path`; returns the CRC-32 recorded in the
/// trailer (also stored in the step MANIFEST for cross-checking).
pub fn write_group(path: &Path, sd: &StateDict) -> Result<u32> {
    let bytes = encode_group(sd);
    let crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    std::fs::write(path, &bytes).with_context(|| format!("writing shard {path:?}"))?;
    Ok(crc)
}

/// Read and verify a group shard. When `expected_crc` is given (from the
/// MANIFEST) it must match the trailer as well.
pub fn read_group(path: &Path, expected_crc: Option<u32>) -> Result<StateDict> {
    let bytes = std::fs::read(path).with_context(|| format!("reading shard {path:?}"))?;
    if let Some(want) = expected_crc {
        if bytes.len() >= 4 {
            let got = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
            if got != want {
                bail!(
                    "shard {path:?}: trailer CRC {got:#010x} disagrees with \
                     MANIFEST {want:#010x} — shard and manifest are from different commits"
                );
            }
        }
    }
    decode_group(&bytes).with_context(|| format!("decoding shard {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_dict() -> StateDict {
        let mut sd = StateDict::new();
        sd.put_f32("w", vec![2, 3], vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE, 3e38, -0.0]);
        sd.put_i32("tokens", vec![4], vec![i32::MIN, -1, 0, i32::MAX]);
        sd.put_u64s("t", &[u64::MAX, 42]);
        sd.put_f32("scalar", vec![], vec![7.25]);
        sd
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let sd = sample_dict();
        let bytes = encode_group(&sd);
        let back = decode_group(&bytes).unwrap();
        assert_eq!(back.len(), sd.len());
        for ((n0, t0), (n1, t1)) in sd.entries().iter().zip(back.entries()) {
            assert_eq!(n0, n1);
            assert_eq!(t0, t1);
        }
        assert_eq!(back.u64s("t").unwrap(), vec![u64::MAX, 42]);
    }

    #[test]
    fn nan_payloads_survive_bitwise() {
        let mut sd = StateDict::new();
        let weird = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0];
        sd.put_f32("x", vec![4], weird.clone());
        let back = decode_group(&encode_group(&sd)).unwrap();
        for (a, b) in weird.iter().zip(back.f32("x").unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let bytes = encode_group(&sample_dict());
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(decode_group(&bad).is_err(), "flip at byte {i} not detected");
        }
    }

    #[test]
    fn truncation_is_detected_at_every_length() {
        let bytes = encode_group(&sample_dict());
        for cut in 0..bytes.len() {
            assert!(decode_group(&bytes[..cut]).is_err(), "truncation to {cut} not detected");
        }
    }

    #[test]
    fn trailing_garbage_is_detected() {
        let mut bytes = encode_group(&sample_dict());
        bytes.extend_from_slice(&[0u8; 8]);
        assert!(decode_group(&bytes).is_err());
    }

    #[test]
    fn wrong_magic_and_version_rejected() {
        let sd = sample_dict();
        let mut bytes = encode_group(&sd);
        bytes[0] = b'X';
        // fix up the CRC so the magic check (not the CRC) fires
        let n = bytes.len();
        let crc = crate::ckpt::crc32::crc32(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_group(&bytes).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn file_roundtrip_and_manifest_crc_cross_check() {
        let dir = std::env::temp_dir().join("lowrank_sge_codec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.tsr");
        let sd = sample_dict();
        let crc = write_group(&path, &sd).unwrap();
        assert!(read_group(&path, Some(crc)).is_ok());
        let err = read_group(&path, Some(crc ^ 1)).unwrap_err().to_string();
        assert!(err.contains("MANIFEST"), "{err}");
        assert!(read_group(&path, None).is_ok());
    }

    #[test]
    fn empty_dict_roundtrips() {
        let sd = StateDict::new();
        let back = decode_group(&encode_group(&sd)).unwrap();
        assert!(back.is_empty());
    }
}
