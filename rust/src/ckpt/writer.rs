//! Save/load of whole checkpoints: shard each tensor group through the
//! codec into a temp dir, commit with a single rename, advance `LATEST`,
//! and prune old steps down to the retention budget.
//!
//! Group shards are independent files, so serialization + CRC + write
//! of the groups fan out across the kernel pool ([`save_checkpoint`]):
//! the encode/IO of one group overlaps the others', while the atomic
//! temp-dir+rename commit — and the bytes of every shard — stay exactly
//! as the serial writer produced them.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::codec;
use super::layout::{Layout, ResumeSpec};
use super::manifest::{validate_group_name, CkptManifest, GroupEntry, MANIFEST_FILE};
use super::state::StateDict;

/// Checkpoint policy carried by trainer configs. `Default` disables
/// checkpointing entirely, so existing construction sites opt in
/// explicitly.
#[derive(Clone, Debug, Default)]
pub struct CkptOptions {
    /// Save every N optimizer steps (0 = never).
    pub save_every: u64,
    /// Checkpoint root directory; required for saving or resuming.
    pub dir: Option<PathBuf>,
    /// Resume target, honored once at the start of `run()`.
    pub resume: Option<ResumeSpec>,
    /// Keep only the newest K committed steps (0 = keep all).
    pub keep_last: usize,
}

impl CkptOptions {
    /// Whether a save fires after completing `step` (1-based barrier:
    /// `step + 1` optimizer steps are done).
    pub fn should_save(&self, step: u64) -> bool {
        self.save_every > 0 && self.dir.is_some() && (step + 1) % self.save_every == 0
    }
}

/// A fully verified, in-memory checkpoint.
#[derive(Debug)]
pub struct LoadedCheckpoint {
    pub step: u64,
    pub meta: BTreeMap<String, String>,
    groups: Vec<(String, StateDict)>,
}

impl LoadedCheckpoint {
    pub fn group(&self, name: &str) -> Result<&StateDict> {
        self.groups
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, sd)| sd)
            .with_context(|| format!("checkpoint has no group {name:?}"))
    }

    pub fn group_names(&self) -> Vec<&str> {
        self.groups.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).map(String::as_str)
    }

    /// Bail unless checkpoint metadata `key` equals `want` — the guard
    /// against restoring a checkpoint into the wrong trainer/model.
    pub fn expect_meta(&self, key: &str, want: &str) -> Result<()> {
        match self.meta_str(key) {
            Some(got) if got == want => Ok(()),
            Some(got) => bail!(
                "checkpoint {key} mismatch: checkpoint has {got:?}, this run wants {want:?}"
            ),
            None => bail!("checkpoint MANIFEST missing {key:?}"),
        }
    }
}

/// Write one checkpoint atomically. `meta` lands in the MANIFEST as
/// `key = value` lines; `groups` become one shard file each. Returns the
/// committed step directory.
pub fn save_checkpoint(
    root: &Path,
    step: u64,
    meta: &[(&str, String)],
    groups: &[(&str, StateDict)],
    keep_last: usize,
) -> Result<PathBuf> {
    let reserved = ["format", "version", "step", "num_groups"];
    for (k, v) in meta {
        if reserved.contains(k) {
            bail!("checkpoint meta key {k:?} is reserved");
        }
        // the MANIFEST line dialect splits on whitespace: a value must
        // be non-empty, single-spaced text or it cannot round-trip —
        // catch that at save time, not at the first resume
        let normalized = v.split_whitespace().collect::<Vec<_>>().join(" ");
        if v.is_empty() || normalized != *v {
            bail!(
                "checkpoint meta value for {k:?} must be non-empty single-spaced text, got {v:?}"
            );
        }
    }
    for (i, (name, _)) in groups.iter().enumerate() {
        validate_group_name(name)?;
        if groups[..i].iter().any(|(n, _)| n == name) {
            bail!("duplicate checkpoint group {name:?}");
        }
    }
    let layout = Layout::new(root);
    std::fs::create_dir_all(root).with_context(|| format!("creating {root:?}"))?;

    // stage into a temp dir …
    let tmp = layout.tmp_dir(step);
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp).with_context(|| format!("clearing stale {tmp:?}"))?;
    }
    std::fs::create_dir_all(&tmp)?;
    let mut manifest = CkptManifest::new(step);
    for (k, v) in meta {
        manifest.meta.insert((*k).to_string(), v.clone());
    }
    // Stage every group shard through the kernel pool: encode + CRC +
    // write are per-group and independent, so they overlap. Results are
    // collected in group order, so the MANIFEST (and every shard's
    // bytes) are identical to a serial write.
    let mut shard_results: Vec<Option<Result<u32>>> = groups.iter().map(|_| None).collect();
    {
        let pool = crate::kernel::global();
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(groups.len());
        for ((name, sd), slot) in groups.iter().zip(shard_results.iter_mut()) {
            let path = tmp.join(format!("{name}.tsr"));
            tasks.push(Box::new(move || *slot = Some(codec::write_group(&path, sd))));
        }
        pool.run(tasks);
    }
    for ((name, sd), result) in groups.iter().zip(shard_results) {
        let crc32 = result
            .expect("pool ran every shard task")
            .with_context(|| format!("writing checkpoint group {name:?}"))?;
        manifest.groups.push(GroupEntry {
            name: (*name).to_string(),
            file: format!("{name}.tsr"),
            crc32,
            tensors: sd.len(),
        });
    }
    std::fs::write(tmp.join(MANIFEST_FILE), manifest.render())?;

    // flush shard + MANIFEST data to disk *before* the rename becomes
    // durable, so a power cut cannot commit a directory of empty files
    for g in &manifest.groups {
        sync_file(&tmp.join(&g.file))?;
    }
    sync_file(&tmp.join(MANIFEST_FILE))?;
    sync_dir(&tmp)?;

    // … commit with one rename, then advance LATEST and prune.
    let final_dir = layout.step_dir(step);
    if final_dir.exists() {
        std::fs::remove_dir_all(&final_dir)
            .with_context(|| format!("replacing existing {final_dir:?}"))?;
    }
    std::fs::rename(&tmp, &final_dir)
        .with_context(|| format!("committing checkpoint {final_dir:?}"))?;
    layout.write_latest(step)?;
    sync_dir(root)?;
    prune(&layout, keep_last, step)?;
    Ok(final_dir)
}

fn sync_file(path: &Path) -> Result<()> {
    std::fs::File::open(path)
        .and_then(|f| f.sync_all())
        .with_context(|| format!("fsync {path:?}"))
}

/// Durably record directory entries (renames, new files). Directory
/// fsync is a POSIX-ism; elsewhere it is a no-op.
fn sync_dir(path: &Path) -> Result<()> {
    #[cfg(unix)]
    {
        std::fs::File::open(path)
            .and_then(|f| f.sync_all())
            .with_context(|| format!("fsync dir {path:?}"))?;
    }
    #[cfg(not(unix))]
    {
        let _ = path;
    }
    Ok(())
}

/// Remove committed steps beyond the newest `keep_last` (0 = keep all).
/// `protect` is never removed regardless of ordering.
fn prune(layout: &Layout, keep_last: usize, protect: u64) -> Result<()> {
    if keep_last == 0 {
        return Ok(());
    }
    let steps = layout.list_steps()?;
    if steps.len() <= keep_last {
        return Ok(());
    }
    for &step in &steps[..steps.len() - keep_last] {
        if step == protect {
            continue;
        }
        let dir = layout.step_dir(step);
        std::fs::remove_dir_all(&dir).with_context(|| format!("pruning {dir:?}"))?;
    }
    Ok(())
}

/// Load and fully verify one checkpoint (manifest + every shard CRC).
///
/// `ResumeSpec::Step(n)` is strict: that step loads or the call fails.
/// `ResumeSpec::Latest` is resilient: if the newest committed step is
/// unreadable (e.g. torn by a crash mid-write on a filesystem that
/// reordered the commit), it walks back to the newest *loadable* step,
/// warning about each one skipped, and only fails when none remain.
pub fn load_checkpoint(root: &Path, spec: ResumeSpec) -> Result<LoadedCheckpoint> {
    let layout = Layout::new(root);
    match spec {
        ResumeSpec::Step(_) => {
            let step = layout.resolve(spec)?;
            load_step(&layout, step)
        }
        ResumeSpec::Latest => {
            let steps = layout.list_steps()?;
            if steps.is_empty() {
                bail!("no committed checkpoints under {root:?}");
            }
            // honor the LATEST pointer first (an operator may have
            // re-pointed it to roll back), then newest → oldest
            let mut order: Vec<u64> = steps.iter().rev().copied().collect();
            if let Ok(Some(pointed)) = layout.read_latest() {
                if let Some(pos) = order.iter().position(|&s| s == pointed) {
                    order.remove(pos);
                    order.insert(0, pointed);
                }
            }
            let mut last_err = None;
            for &step in &order {
                match load_step(&layout, step) {
                    Ok(ckpt) => {
                        if last_err.is_some() {
                            eprintln!(
                                "warning: fell back to checkpoint step {step} \
                                 (preferred ones were unreadable)"
                            );
                        }
                        return Ok(ckpt);
                    }
                    Err(e) => {
                        eprintln!("warning: checkpoint step {step} unreadable: {e:#}");
                        last_err = Some(e);
                    }
                }
            }
            Err(last_err.expect("non-empty steps implies at least one error"))
                .context("every committed checkpoint failed verification")
        }
    }
}

/// Load and fully verify one specific committed step.
fn load_step(layout: &Layout, step: u64) -> Result<LoadedCheckpoint> {
    let dir = layout.step_dir(step);
    let manifest = CkptManifest::load(&dir.join(MANIFEST_FILE))?;
    if manifest.step != step {
        bail!(
            "checkpoint {dir:?}: MANIFEST says step {} but directory names step {step}",
            manifest.step
        );
    }
    let mut groups = Vec::with_capacity(manifest.groups.len());
    for g in &manifest.groups {
        let sd = codec::read_group(&dir.join(&g.file), Some(g.crc32))
            .with_context(|| format!("checkpoint group {:?}", g.name))?;
        if sd.len() != g.tensors {
            bail!(
                "checkpoint group {:?}: {} tensors on disk, MANIFEST says {}",
                g.name,
                sd.len(),
                g.tensors
            );
        }
        groups.push((g.name.clone(), sd));
    }
    Ok(LoadedCheckpoint { step, meta: manifest.meta, groups })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_groups() -> Vec<(&'static str, StateDict)> {
        let mut a = StateDict::new();
        a.put_f32("w", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = StateDict::new();
        b.put_u64s("state", &[11, 22, 33, 44]);
        vec![("params", a), ("rng", b)]
    }

    fn fresh_root(tag: &str) -> PathBuf {
        let root = std::env::temp_dir().join(format!("lowrank_sge_writer_{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        root
    }

    #[test]
    fn save_load_roundtrip_with_meta() {
        let root = fresh_root("roundtrip");
        let meta = [("trainer", "pretrain".to_string()), ("scale", "s".to_string())];
        save_checkpoint(&root, 40, &meta, &toy_groups(), 0).unwrap();
        let ckpt = load_checkpoint(&root, ResumeSpec::Latest).unwrap();
        assert_eq!(ckpt.step, 40);
        assert_eq!(ckpt.meta_str("trainer"), Some("pretrain"));
        assert!(ckpt.expect_meta("scale", "s").is_ok());
        assert!(ckpt.expect_meta("scale", "m").is_err());
        assert!(ckpt.expect_meta("nope", "x").is_err());
        assert_eq!(ckpt.group("params").unwrap().f32("w").unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ckpt.group("rng").unwrap().u64s("state").unwrap(), vec![11, 22, 33, 44]);
        assert!(ckpt.group("missing").is_err());
    }

    #[test]
    fn latest_follows_newest_and_specific_steps_load() {
        let root = fresh_root("latest");
        for step in [10u64, 20, 30] {
            save_checkpoint(&root, step, &[], &toy_groups(), 0).unwrap();
        }
        assert_eq!(load_checkpoint(&root, ResumeSpec::Latest).unwrap().step, 30);
        assert_eq!(load_checkpoint(&root, ResumeSpec::Step(20)).unwrap().step, 20);
        assert!(load_checkpoint(&root, ResumeSpec::Step(25)).is_err());
    }

    #[test]
    fn retention_keeps_only_last_k() {
        let root = fresh_root("retention");
        for step in [10u64, 20, 30, 40, 50] {
            save_checkpoint(&root, step, &[], &toy_groups(), 2).unwrap();
        }
        let layout = Layout::new(&root);
        assert_eq!(layout.list_steps().unwrap(), vec![40, 50]);
        assert_eq!(load_checkpoint(&root, ResumeSpec::Latest).unwrap().step, 50);
        assert!(load_checkpoint(&root, ResumeSpec::Step(10)).is_err());
    }

    #[test]
    fn corrupted_shard_is_rejected_with_crc_error() {
        let root = fresh_root("corrupt");
        save_checkpoint(&root, 5, &[], &toy_groups(), 0).unwrap();
        let shard = Layout::new(&root).step_dir(5).join("params.tsr");
        let mut bytes = std::fs::read(&shard).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&shard, &bytes).unwrap();
        let err = format!("{:#}", load_checkpoint(&root, ResumeSpec::Latest).unwrap_err());
        assert!(err.contains("CRC32"), "{err}");
    }

    #[test]
    fn latest_walks_back_past_an_unreadable_newest_step() {
        let root = fresh_root("fallback");
        save_checkpoint(&root, 10, &[], &toy_groups(), 0).unwrap();
        save_checkpoint(&root, 20, &[], &toy_groups(), 0).unwrap();
        // tear the newest commit (as a crash mid-write would)
        let shard = Layout::new(&root).step_dir(20).join("params.tsr");
        std::fs::write(&shard, b"torn").unwrap();
        let ckpt = load_checkpoint(&root, ResumeSpec::Latest).unwrap();
        assert_eq!(ckpt.step, 10);
        // explicit step selection stays strict
        assert!(load_checkpoint(&root, ResumeSpec::Step(20)).is_err());
    }

    #[test]
    fn truncated_shard_is_rejected() {
        let root = fresh_root("truncate");
        save_checkpoint(&root, 5, &[], &toy_groups(), 0).unwrap();
        let shard = Layout::new(&root).step_dir(5).join("rng.tsr");
        let bytes = std::fs::read(&shard).unwrap();
        std::fs::write(&shard, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load_checkpoint(&root, ResumeSpec::Latest).is_err());
    }

    #[test]
    fn stale_tmp_dirs_do_not_block_saving() {
        let root = fresh_root("staletmp");
        let layout = Layout::new(&root);
        std::fs::create_dir_all(layout.tmp_dir(9)).unwrap();
        std::fs::write(layout.tmp_dir(9).join("junk"), "x").unwrap();
        save_checkpoint(&root, 9, &[], &toy_groups(), 0).unwrap();
        assert!(!layout.tmp_dir(9).exists());
        let ckpt = load_checkpoint(&root, ResumeSpec::Step(9)).unwrap();
        assert_eq!(ckpt.group_names(), vec!["params", "rng"]);
    }

    #[test]
    fn reserved_meta_and_bad_group_names_rejected() {
        let root = fresh_root("reserved");
        let err = save_checkpoint(&root, 1, &[("step", "9".into())], &toy_groups(), 0);
        assert!(err.is_err());
        let mut sd = StateDict::new();
        sd.put_f32("x", vec![1], vec![0.0]);
        assert!(save_checkpoint(&root, 1, &[], &[("Bad Name", sd)], 0).is_err());
        // values that cannot round-trip through the MANIFEST dialect are
        // rejected at save time
        assert!(save_checkpoint(&root, 1, &[("task", "".into())], &toy_groups(), 0).is_err());
        assert!(save_checkpoint(&root, 1, &[("task", "a  b".into())], &toy_groups(), 0).is_err());
        assert!(save_checkpoint(&root, 1, &[("task", "a b".into())], &toy_groups(), 0).is_ok());
    }

    #[test]
    fn latest_honors_a_rolled_back_pointer() {
        let root = fresh_root("pointer");
        save_checkpoint(&root, 10, &[], &toy_groups(), 0).unwrap();
        save_checkpoint(&root, 20, &[], &toy_groups(), 0).unwrap();
        // operator rolls back by re-pointing LATEST at the older step
        Layout::new(&root).write_latest(10).unwrap();
        assert_eq!(load_checkpoint(&root, ResumeSpec::Latest).unwrap().step, 10);
        // a stale pointer at a pruned step falls through to the newest
        Layout::new(&root).write_latest(999).unwrap();
        assert_eq!(load_checkpoint(&root, ResumeSpec::Latest).unwrap().step, 20);
    }
}
