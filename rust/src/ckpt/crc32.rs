//! CRC-32 (IEEE 802.3 polynomial, reflected) — the integrity check of
//! the checkpoint codec. Table-driven, no dependencies; matches the
//! ubiquitous zlib/`cksum -o 3` convention so shards can be verified
//! with standard tooling.

/// Reflected IEEE polynomial.
const POLY: u32 = 0xEDB8_8320;

/// 256-entry lookup table, built at first use.
fn table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// Streaming CRC-32 state.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        let mut c = self.state;
        for &b in bytes {
            c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the classic check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(37) {
            c.update(chunk);
        }
        assert_eq!(c.finalize(), crc32(&data));
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let mut data = b"checkpoint payload".to_vec();
        let base = crc32(&data);
        for i in 0..data.len() {
            data[i] ^= 0x01;
            assert_ne!(crc32(&data), base, "flip at byte {i} went undetected");
            data[i] ^= 0x01;
        }
    }
}
