//! Instance-dependent optimal sampler (Algorithm 4).
//!
//! Given Σ = Σ_ξ + Σ_Θ (known or estimated from warm-up gradients):
//!
//! 1. spectral-decompose Σ = Q diag(σ) Qᵀ (Jacobi, [`crate::linalg`]);
//! 2. water-fill the optimal inclusion probabilities π* (Theorem 3 /
//!    eq. 17, [`crate::sampling::optimal_inclusion`]);
//! 3. draw J, |J| = r, with Pr(i ∈ J) = π*_i via a fixed-size
//!    unequal-probability design;
//! 4. emit V = Q_J · diag(√(c/π*_i)) — the 1/π* reweighting restores
//!    E[VVᵀ] = cI (Proposition 3) while E[QᵀP²Q] = c² diag(1/π*) attains
//!    Φ_min.
//!
//! The eigendecomposition and water-filling are done **once at
//! construction** and reused for every draw — in training, the lazy
//! update (Algorithm 1) refreshes Σ only once per outer step, so this
//! amortization mirrors the paper's cost model.

use super::ProjectionSampler;
use crate::linalg::{sym_eig, Mat};
use crate::rng::Rng;
use crate::sampling::{
    conditional_poisson_calibrate, optimal_inclusion, sample_conditional_poisson,
    sample_sampford, sample_systematic, sample_tille, CpsDesign, FixedSizeDesign,
};

#[derive(Clone)]
pub struct DependentSampler {
    n: usize,
    r: usize,
    c: f64,
    /// Eigenvectors of Σ (columns).
    q: Mat,
    /// Eigenvalues of Σ, descending.
    sigma: Vec<f64>,
    /// Optimal inclusion probabilities aligned with `sigma`.
    pi: Vec<f64>,
    design: FixedSizeDesign,
    cps: Option<CpsDesign>,
}

impl DependentSampler {
    /// Build from a symmetric PSD Σ estimate with the default
    /// (systematic) design.
    pub fn new(sigma_mat: &Mat, r: usize, c: f64) -> Self {
        Self::with_design(sigma_mat, r, c, FixedSizeDesign::Systematic)
    }

    pub fn with_design(sigma_mat: &Mat, r: usize, c: f64, design: FixedSizeDesign) -> Self {
        assert!(sigma_mat.is_square(), "Σ must be square");
        let n = sigma_mat.rows;
        assert!(r >= 1 && r <= n, "rank r={r} out of range for n={n}");
        assert!(c > 0.0, "c must be positive");
        let eig = sym_eig(sigma_mat);
        let sol = optimal_inclusion(&eig.values, r, crate::sampling::DEFAULT_SIGMA_FLOOR);
        let cps = match design {
            FixedSizeDesign::ConditionalPoisson => {
                Some(conditional_poisson_calibrate(&sol.pi, r))
            }
            _ => None,
        };
        DependentSampler { n, r, c, q: eig.q, sigma: eig.values, pi: sol.pi, design, cps }
    }

    /// The water-filled inclusion probabilities π* (descending-σ order).
    pub fn inclusion_probabilities(&self) -> &[f64] {
        &self.pi
    }

    /// Eigenvalues σ (descending).
    pub fn spectrum(&self) -> &[f64] {
        &self.sigma
    }

    /// Eigenbasis Q of the Σ estimate.
    pub fn eigenbasis(&self) -> &Mat {
        &self.q
    }

    /// Φ_min/c² — the Theorem 3 optimal objective for this instance.
    pub fn phi_min_over_c2(&self) -> f64 {
        self.sigma
            .iter()
            .zip(&self.pi)
            .map(|(&s, &p)| if p > 0.0 { s / p } else { 0.0 })
            .sum()
    }

    fn draw_subset(&self, rng: &mut Rng) -> Vec<usize> {
        match self.design {
            FixedSizeDesign::Systematic => sample_systematic(&self.pi, self.r, rng),
            FixedSizeDesign::Sampford => sample_sampford(&self.pi, self.r, rng),
            FixedSizeDesign::Tille => sample_tille(&self.pi, self.r, rng),
            FixedSizeDesign::ConditionalPoisson => {
                sample_conditional_poisson(self.cps.as_ref().unwrap(), rng)
            }
        }
    }
}

impl ProjectionSampler for DependentSampler {
    fn sample(&mut self, rng: &mut Rng) -> Mat {
        let j = self.draw_subset(rng);
        debug_assert_eq!(j.len(), self.r);
        // V[:, k] = √(c/π*_{j_k}) · q_{j_k}
        let mut v = Mat::zeros(self.n, self.r);
        for (k, &jk) in j.iter().enumerate() {
            let w = (self.c / self.pi[jk]).sqrt();
            for i in 0..self.n {
                v.set(i, k, w * self.q.get(i, jk));
            }
        }
        v
    }

    fn dim(&self) -> usize {
        self.n
    }

    fn rank(&self) -> usize {
        self.r
    }

    fn scale_c(&self) -> f64 {
        self.c
    }

    fn name(&self) -> &'static str {
        "dependent"
    }

    fn clone_box(&self) -> Box<dyn ProjectionSampler + Send + Sync> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_tn, transpose};
    use crate::projection::{empirical_moments, projector_matrix};

    /// A non-flat PSD Σ with a known eigenbasis (diagonal in a rotated
    /// frame to exercise the eigensolver path).
    fn test_sigma(n: usize) -> (Mat, Vec<f64>) {
        let vals: Vec<f64> = (0..n).map(|i| 2.0f64.powi(-(i as i32))).collect();
        // rotate by a Householder reflector H = I − 2uuᵀ
        let u: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).sqrt()).collect();
        let norm_sq: f64 = u.iter().map(|x| x * x).sum();
        let h = Mat::from_fn(n, n, |i, j| {
            let d = if i == j { 1.0 } else { 0.0 };
            d - 2.0 * u[i] * u[j] / norm_sq
        });
        let lam = Mat::diag(&vals);
        let sig = matmul(&matmul(&h, &lam), &transpose(&h));
        (sig, vals)
    }

    #[test]
    fn mean_projector_is_c_identity() {
        let (sig, _) = test_sigma(8);
        for design in [
            FixedSizeDesign::Systematic,
            FixedSizeDesign::Sampford,
            FixedSizeDesign::ConditionalPoisson,
            FixedSizeDesign::Tille,
        ] {
            let mut s = DependentSampler::with_design(&sig, 3, 1.0, design);
            let mut rng = Rng::new(51);
            let m = empirical_moments(&mut s, &mut rng, 20_000);
            let err = m.mean_p.max_abs_diff(&Mat::eye(8));
            assert!(err < 0.06, "{}: ‖Ē[P] − I‖ = {err}", design.name());
        }
    }

    #[test]
    fn second_moment_diagonal_in_eigenbasis_matches_prop3() {
        let (sig, _) = test_sigma(6);
        let mut s = DependentSampler::new(&sig, 2, 1.0);
        let pi = s.inclusion_probabilities().to_vec();
        let q = s.eigenbasis().clone();
        let mut rng = Rng::new(53);
        let trials = 30_000;
        let mut acc = Mat::zeros(6, 6);
        for _ in 0..trials {
            let p = projector_matrix(&s.sample(&mut rng));
            let p2 = matmul(&p, &p);
            acc.axpy_inplace(1.0 / trials as f64, &p2);
        }
        // rotate into eigenbasis: QᵀĒ[P²]Q ≈ diag(1/π*)
        let rot = matmul(&matmul_tn(&q, &acc), &q);
        for i in 0..6 {
            let expect = 1.0 / pi[i];
            let got = rot.get(i, i);
            assert!(
                (got - expect).abs() / expect < 0.1,
                "diag[{i}]: got {got}, expect {expect}"
            );
            for j in 0..6 {
                if i != j {
                    assert!(rot.get(i, j).abs() < 0.25, "off-diag ({i},{j}) = {}", rot.get(i, j));
                }
            }
        }
    }

    #[test]
    fn objective_attains_phi_min() {
        // tr(Σ Ē[P²]) should converge to Φ_min = c² Σ σ_i/π*_i.
        let (sig, _) = test_sigma(6);
        let mut s = DependentSampler::new(&sig, 2, 1.0);
        let phi_min = s.phi_min_over_c2();
        let mut rng = Rng::new(57);
        let trials = 30_000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let p = projector_matrix(&s.sample(&mut rng));
            let p2 = matmul(&p, &p);
            acc += crate::linalg::trace_product(&sig, &p2) / trials as f64;
        }
        assert!((acc - phi_min).abs() / phi_min < 0.05, "tr(ΣĒP²)={acc} vs Φ_min={phi_min}");
    }

    #[test]
    fn dependent_beats_stiefel_on_skewed_spectrum() {
        // Theorem 3: anisotropic optimum ≤ isotropic value tr(Σ)·n/r.
        let (sig, vals) = test_sigma(8);
        let s = DependentSampler::new(&sig, 2, 1.0);
        let phi_dep = s.phi_min_over_c2();
        let phi_iso: f64 = vals.iter().sum::<f64>() * 8.0 / 2.0;
        assert!(
            phi_dep < 0.9 * phi_iso,
            "dependent {phi_dep} should beat isotropic {phi_iso} on skewed σ"
        );
    }

    #[test]
    fn low_rank_sigma_gives_full_saturation_prop4() {
        // rank(Σ) = 2 ≤ r = 3 ⇒ Φ_min = tr(Σ) (Proposition 4).
        let n = 7;
        let mut diag = vec![0.0; n];
        diag[0] = 5.0;
        diag[1] = 1.0;
        let sig = Mat::diag(&diag);
        let s = DependentSampler::new(&sig, 3, 1.0);
        let phi = s.phi_min_over_c2();
        assert!((phi - 6.0).abs() < 1e-6, "Φ_min = {phi}, want tr(Σ) = 6");
    }

    #[test]
    fn sample_has_rank_r_and_orthogonal_columns() {
        let (sig, _) = test_sigma(9);
        let mut s = DependentSampler::new(&sig, 4, 1.0);
        let mut rng = Rng::new(61);
        let v = s.sample(&mut rng);
        let gram = matmul_tn(&v, &v);
        // columns are orthogonal (distinct eigenvectors) with norms c/π
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert!(gram.get(i, j).abs() < 1e-9);
                } else {
                    assert!(gram.get(i, i) >= 1.0 - 1e-9); // c/π ≥ c = 1
                }
            }
        }
    }
}
