//! Haar–Stiefel sampler (Algorithm 2) — the paper's instance-independent
//! optimal projector.
//!
//! Draw G with i.i.d. N(0,1) entries, thin-QR it, fix the sign ambiguity
//! (D = diag(sgn diag R)), and rescale by α = √(cn/r). The result
//! satisfies, almost surely, the Theorem 2 optimality condition
//! VᵀV = (cn/r)·I_r, and by Haar invariance E[VVᵀ] = c·I_n
//! (Proposition 2(i)).

use super::ProjectionSampler;
use crate::linalg::{thin_qr, Mat};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct StiefelSampler {
    n: usize,
    r: usize,
    c: f64,
    alpha: f64,
}

impl StiefelSampler {
    pub fn new(n: usize, r: usize, c: f64) -> Self {
        assert!(r >= 1 && r <= n, "rank r={r} out of range for n={n}");
        assert!(c > 0.0, "c must be positive");
        StiefelSampler { n, r, c, alpha: (c * n as f64 / r as f64).sqrt() }
    }

    /// α = √(cn/r), the rescaling from the Stiefel frame to V.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

impl ProjectionSampler for StiefelSampler {
    fn sample(&mut self, rng: &mut Rng) -> Mat {
        // G ~ N(0,1)^{n×r}
        let mut g = Mat::zeros(self.n, self.r);
        for x in &mut g.data {
            *x = rng.normal();
        }
        // thin QR; our thin_qr already applies the sign fix of Alg 2 step 3
        let q = thin_qr(&g).q;
        q.scaled(self.alpha)
    }

    fn dim(&self) -> usize {
        self.n
    }

    fn rank(&self) -> usize {
        self.r
    }

    fn scale_c(&self) -> f64 {
        self.c
    }

    fn name(&self) -> &'static str {
        "stiefel"
    }

    fn clone_box(&self) -> Box<dyn ProjectionSampler + Send + Sync> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_tn};
    use crate::projection::tests::check_mean_isotropy;
    use crate::projection::{empirical_moments, projector_matrix};

    #[test]
    fn gram_is_exactly_scaled_identity() {
        // Theorem 2's a.s. optimality condition, to near machine precision.
        let (n, r, c) = (30, 5, 1.0);
        let mut s = StiefelSampler::new(n, r, c);
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let v = s.sample(&mut rng);
            let gram = matmul_tn(&v, &v);
            let target = Mat::eye(r).scaled(c * n as f64 / r as f64);
            assert!(gram.max_abs_diff(&target) < 1e-9);
        }
    }

    #[test]
    fn tr_p2_attains_thm2_floor_exactly() {
        // tr(P²) = n²c²/r almost surely (not just in expectation).
        let (n, r, c) = (16, 4, 0.7);
        let mut s = StiefelSampler::new(n, r, c);
        let mut rng = Rng::new(13);
        let floor = (n * n) as f64 * c * c / r as f64;
        for _ in 0..10 {
            let p = projector_matrix(&s.sample(&mut rng));
            let p2 = matmul(&p, &p);
            assert!((p2.trace() - floor).abs() < 1e-8);
        }
    }

    #[test]
    fn mean_projector_is_c_identity() {
        let mut s = StiefelSampler::new(10, 3, 1.0);
        check_mean_isotropy(&mut s, 20_000, 0.05);
        let mut s2 = StiefelSampler::new(10, 3, 0.3); // weak unbiasedness c<1
        check_mean_isotropy(&mut s2, 20_000, 0.05);
    }

    #[test]
    fn second_moment_is_c2_n_over_r_identity() {
        // E[P²] = (c²n/r)·I for the Haar law (isotropy + a.s. trace).
        let (n, r, c) = (8, 2, 1.0);
        let mut s = StiefelSampler::new(n, r, c);
        let mut rng = Rng::new(17);
        let m = empirical_moments(&mut s, &mut rng, 20_000);
        let target = Mat::eye(n).scaled(c * c * n as f64 / r as f64);
        assert!(m.mean_p2.max_abs_diff(&target) < 0.15, "Ē[P²] deviates");
    }

    #[test]
    fn haar_rotation_invariance_of_column_span() {
        // first-column direction should be uniform on the sphere: its
        // coordinates have mean 0 and variance 1/n.
        let n = 12;
        let mut s = StiefelSampler::new(n, 2, 1.0);
        let mut rng = Rng::new(23);
        let trials = 30_000;
        let mut mean = vec![0.0; n];
        let mut var = vec![0.0; n];
        let alpha = s.alpha();
        for _ in 0..trials {
            let v = s.sample(&mut rng);
            for i in 0..n {
                let u = v.get(i, 0) / alpha; // unit-frame coordinate
                mean[i] += u / trials as f64;
                var[i] += u * u / trials as f64;
            }
        }
        for i in 0..n {
            assert!(mean[i].abs() < 0.02, "mean[{i}]={}", mean[i]);
            assert!((var[i] - 1.0 / n as f64).abs() < 0.01, "var[{i}]={}", var[i]);
        }
    }

    #[test]
    fn alpha_scales_with_c() {
        let s1 = StiefelSampler::new(100, 4, 1.0);
        let s2 = StiefelSampler::new(100, 4, 0.04); // c = r/n
        assert!((s1.alpha() - 5.0).abs() < 1e-12);
        assert!((s2.alpha() - 1.0).abs() < 1e-12);
    }
}
