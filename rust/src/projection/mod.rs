//! Random projection samplers — the paper's §5 contribution.
//!
//! Each sampler draws V ∈ ℝ^{n×r} from a law in the admissible class 𝒟
//! (Definition 3): E[VVᵀ] = c·I_n, rank ≤ r. Four laws are provided:
//!
//! | law | paper ref | optimality |
//! |-----|-----------|------------|
//! | [`GaussianSampler`]    | Remark 1 baseline (Chen et al. 2024) | none — MSE_G = ((n+r+1)/r)tr Σ_ξ + ((n+1)/r)tr Σ_Θ |
//! | [`StiefelSampler`]     | Algorithm 2 | instance-independent optimum (Thm 2): VᵀV = (cn/r)I a.s. |
//! | [`CoordinateSampler`]  | Algorithm 3 | instance-independent optimum (Thm 2) |
//! | [`DependentSampler`]   | Algorithm 4 | instance-dependent optimum (Thm 3): E[QᵀP²Q] = c²diag(1/π*) |
//!
//! Training code treats a sampler as a policy object: the HLO artifacts
//! take V as a runtime input, so swapping laws never recompiles anything.
//! Multi-matrix draws go through [`sample_batch`], which forks one child
//! RNG stream per request and fans the draws out across the
//! [`crate::kernel`] pool — bitwise-deterministic in the thread count.
//! The [`tracking`] module amortizes the Stiefel resample: it keeps the
//! previous frame and applies a rank-1 tilt + Cholesky-QR refresh
//! (same VᵀV = (cn/r)·I guarantee, no fresh n×r Gaussian QR), falling
//! back to a full Haar draw on a fixed schedule; [`track_batch`] is its
//! `sample_batch`-shaped, equally thread-count-invariant entry point.

mod gaussian;
mod stiefel;
mod coordinate;
mod dependent;
pub mod tracking;

pub use coordinate::CoordinateSampler;
pub use dependent::DependentSampler;
pub use gaussian::GaussianSampler;
pub use stiefel::StiefelSampler;
pub use tracking::{fresh_frame, track_batch, tracked_update};

use crate::linalg::{matmul_nt, Mat};
use crate::rng::Rng;

/// Which projector law to use (CLI/config-facing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectorKind {
    Gaussian,
    Stiefel,
    Coordinate,
    Dependent,
}

impl ProjectorKind {
    pub fn name(&self) -> &'static str {
        match self {
            ProjectorKind::Gaussian => "gaussian",
            ProjectorKind::Stiefel => "stiefel",
            ProjectorKind::Coordinate => "coordinate",
            ProjectorKind::Dependent => "dependent",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" => Some(ProjectorKind::Gaussian),
            "stiefel" | "haar" | "haar-stiefel" => Some(ProjectorKind::Stiefel),
            "coordinate" | "coord" => Some(ProjectorKind::Coordinate),
            "dependent" | "instance-dependent" | "optimal" => Some(ProjectorKind::Dependent),
            _ => None,
        }
    }
}

/// A law over projection matrices V ∈ ℝ^{n×r}.
///
/// Every law is **draw-stateless**: `sample` takes `&mut self` only for
/// object-safety/scratch reasons — a draw is a pure function of the
/// sampler's immutable configuration (including any precomputed
/// eigenstructure) and the `rng` stream. [`clone_box`] relies on this:
/// a clone produces the identical draw sequence from the same stream,
/// which is what lets the MSE harness fan independent replications out
/// across the kernel pool with one sampler clone per rep.
///
/// [`clone_box`]: ProjectionSampler::clone_box
pub trait ProjectionSampler {
    /// Draw one V.
    fn sample(&mut self, rng: &mut Rng) -> Mat;
    /// Ambient dimension n.
    fn dim(&self) -> usize;
    /// Rank budget r.
    fn rank(&self) -> usize;
    /// Weak-unbiasedness scale c in E[VVᵀ] = cI.
    fn scale_c(&self) -> f64;
    /// Human-readable law name.
    fn name(&self) -> &'static str;
    /// Clone into a fresh boxed sampler — same law, same precomputation
    /// (the Dependent law's O(n³) eigendecomposition is *not* redone).
    fn clone_box(&self) -> Box<dyn ProjectionSampler + Send + Sync>;
}

/// P = VVᵀ (n×n).
pub fn projector_matrix(v: &Mat) -> Mat {
    matmul_nt(v, v)
}

/// Draw V and flatten it to f32 row-major — the form the PJRT artifacts
/// consume. The f64→f32 rounding happens exactly once, here.
pub fn sample_f32(sampler: &mut dyn ProjectionSampler, rng: &mut Rng) -> Vec<f32> {
    sampler.sample(rng).data.iter().map(|&x| x as f32).collect()
}

/// Draw one V per `(n, r)` request, fanned out across the kernel pool.
///
/// Each draw runs on an independent child stream forked from `rng` in
/// request order, so the output is a pure function of the parent stream
/// and the request list — **identical at every thread count** (the
/// subspace resample determinism test pins this). `sigma` is required
/// for (and only consumed by) [`ProjectorKind::Dependent`]; note that
/// each Dependent draw builds its own sampler — and therefore repeats
/// the O(n³) eigendecomposition of Σ — so callers with many same-shape
/// Dependent draws should construct one [`DependentSampler`] directly
/// and sample from it instead.
pub fn sample_batch(
    kind: ProjectorKind,
    dims: &[(usize, usize)],
    c: f64,
    sigma: Option<&Mat>,
    rng: &mut Rng,
) -> Vec<Mat> {
    // fork all child streams first: this is the only part that touches
    // the (inherently sequential) parent stream
    let mut children: Vec<Rng> = (0..dims.len()).map(|i| rng.fork(i as u64 + 1)).collect();
    let mut out: Vec<Mat> = vec![Mat::zeros(0, 0); dims.len()];
    let pool = crate::kernel::global();
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for ((slot, child), &(n, r)) in out.iter_mut().zip(children.iter_mut()).zip(dims) {
        tasks.push(Box::new(move || {
            let mut sampler = build_sampler(kind, n, r, c, sigma);
            *slot = sampler.sample(child);
        }));
    }
    pool.run(tasks);
    out
}

/// Monte-Carlo diagnostics for a sampler: empirical Ē[P] and Ē[P²]
/// (used by tests to certify admissibility and optimality conditions).
pub struct ProjectorMoments {
    pub mean_p: Mat,
    pub mean_p2: Mat,
}

pub fn empirical_moments(
    sampler: &mut dyn ProjectionSampler,
    rng: &mut Rng,
    trials: usize,
) -> ProjectorMoments {
    let n = sampler.dim();
    let mut mean_p = Mat::zeros(n, n);
    let mut mean_p2 = Mat::zeros(n, n);
    for _ in 0..trials {
        let v = sampler.sample(rng);
        let p = projector_matrix(&v);
        let p2 = crate::linalg::matmul(&p, &p);
        mean_p.axpy_inplace(1.0 / trials as f64, &p);
        mean_p2.axpy_inplace(1.0 / trials as f64, &p2);
    }
    ProjectorMoments { mean_p, mean_p2 }
}

/// Build a sampler by kind. `sigma` is required for (and only for)
/// [`ProjectorKind::Dependent`].
pub fn build_sampler(
    kind: ProjectorKind,
    n: usize,
    r: usize,
    c: f64,
    sigma: Option<&Mat>,
) -> Box<dyn ProjectionSampler + Send + Sync> {
    match kind {
        ProjectorKind::Gaussian => Box::new(GaussianSampler::new(n, r, c)),
        ProjectorKind::Stiefel => Box::new(StiefelSampler::new(n, r, c)),
        ProjectorKind::Coordinate => Box::new(CoordinateSampler::new(n, r, c)),
        ProjectorKind::Dependent => {
            let sigma = sigma.expect("DependentSampler requires a Σ estimate");
            Box::new(DependentSampler::new(sigma, r, c))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared admissibility check: ‖Ē[P] − cI‖_max small after `trials`.
    pub(super) fn check_mean_isotropy(
        sampler: &mut dyn ProjectionSampler,
        trials: usize,
        tol: f64,
    ) {
        let mut rng = Rng::new(777);
        let m = empirical_moments(sampler, &mut rng, trials);
        let n = sampler.dim();
        let target = Mat::eye(n).scaled(sampler.scale_c());
        let err = m.mean_p.max_abs_diff(&target);
        assert!(err < tol, "{}: ‖Ē[P] − cI‖_max = {err} > {tol}", sampler.name());
    }

    #[test]
    fn builder_produces_all_kinds() {
        let sigma = Mat::eye(6);
        for kind in [
            ProjectorKind::Gaussian,
            ProjectorKind::Stiefel,
            ProjectorKind::Coordinate,
            ProjectorKind::Dependent,
        ] {
            let mut s = build_sampler(kind, 6, 2, 1.0, Some(&sigma));
            let mut rng = Rng::new(1);
            let v = s.sample(&mut rng);
            assert_eq!((v.rows, v.cols), (6, 2));
            assert_eq!(s.dim(), 6);
            assert_eq!(s.rank(), 2);
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [
            ProjectorKind::Gaussian,
            ProjectorKind::Stiefel,
            ProjectorKind::Coordinate,
            ProjectorKind::Dependent,
        ] {
            assert_eq!(ProjectorKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ProjectorKind::parse("haar"), Some(ProjectorKind::Stiefel));
        assert_eq!(ProjectorKind::parse("nope"), None);
    }

    #[test]
    fn sample_batch_is_thread_count_invariant() {
        let _guard = crate::kernel::pool::global_test_guard();
        let prev_threads = crate::kernel::global_threads();
        let dims = [(12usize, 3usize), (8, 2), (20, 5)];
        let mut draws = Vec::new();
        for threads in [1usize, 4] {
            crate::kernel::set_global_threads(threads);
            let mut rng = Rng::new(99);
            draws.push(sample_batch(ProjectorKind::Stiefel, &dims, 1.0, None, &mut rng));
        }
        // restore the configured size for the rest of the suite
        crate::kernel::set_global_threads(prev_threads);
        for (a, b) in draws[0].iter().zip(&draws[1]) {
            assert_eq!((a.rows, a.cols), (b.rows, b.cols));
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // shapes follow the request list
        assert_eq!((draws[0][0].rows, draws[0][0].cols), (12, 3));
        assert_eq!((draws[0][2].rows, draws[0][2].cols), (20, 5));
    }

    #[test]
    fn sample_f32_matches_f64_draw() {
        let mut s1 = StiefelSampler::new(10, 3, 1.0);
        let mut s2 = StiefelSampler::new(10, 3, 1.0);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let v64 = s1.sample(&mut r1);
        let v32 = sample_f32(&mut s2, &mut r2);
        for (a, b) in v64.data.iter().zip(&v32) {
            assert!((*a as f32 - b).abs() == 0.0);
        }
    }
}
