//! Warm-started Stiefel subspace tracking — the amortized resample.
//!
//! Algorithm 1 redraws the whole projector V at every lazy-update
//! boundary: an n×r Gaussian panel plus a full Householder thin-QR per
//! slot ([`super::StiefelSampler`]). SubTrack++ and AdaRankGrad (see
//! PAPERS.md/SNIPPETS.md) observe that the gradient subspace moves
//! slowly between boundaries, so most of that work re-derives a frame
//! almost identical to the previous one. This module keeps the previous
//! unit frame Q ∈ St(n, r) and refreshes it in place:
//!
//! 1. **Low-rank correction** — draw one ambient direction u ∈ ℝⁿ and
//!    one coefficient row g ∈ ℝʳ (n + r normals, vs n·r for a fresh
//!    draw) and tilt the frame: Y = Q + η·û·gᵀ. The rank-1 kick rotates
//!    the subspace by O(η) in a Haar-random plane each refresh, so the
//!    frames random-walk over the Grassmannian between full redraws.
//! 2. **Cheap re-orthogonalization of the r×r factor** — Cholesky-QR:
//!    G = YᵀY (r×r), L = chol(G), Q⁺ = Y·L⁻ᵀ. Two O(n·r²) streaming
//!    passes over Y plus O(r³) on the small factor; no Householder
//!    panel walk and no n×r Gaussian generation. Q⁺ is orthonormal to
//!    machine precision (cond(Y) = O(1) by construction, so the usual
//!    Cholesky-QR squared-conditioning caveat has no teeth here), hence
//!    V = α·Q⁺ with α = √(cn/r) satisfies the Theorem-2 a.s. condition
//!    VᵀV = (cn/r)·I_r exactly — the tracked law stays inside the
//!    admissible class 𝒟 slot-for-slot.
//!
//! A tracked refresh is *not* a fresh Haar draw — consecutive frames
//! are correlated by design. To keep the Haar-mixing/unbiasedness story
//! honest, callers fall back to a full fresh draw every
//! `--track-refresh T` outer iterations ([`track_batch`]'s `full`
//! flag); [`fresh_frame`] consumes the child stream exactly like
//! [`super::StiefelSampler::sample`], so a `T = 1` schedule reproduces
//! the untracked trajectory bit for bit.
//!
//! Determinism contract: [`track_batch`] mirrors
//! [`super::sample_batch`] — one child stream forked per slot in slot
//! order, draws fanned out across the kernel pool — so the bytes are a
//! pure function of the parent stream, never of the thread count.

use crate::linalg::{thin_qr, Mat};
use crate::rng::Rng;

/// Tilt strength η of the rank-1 correction. Principal angles move by
/// O(η) per refresh: large enough that T tracked refreshes explore, a
/// small enough perturbation that Y = Q + η·û·gᵀ stays far from rank
/// deficient (σ_min(Y) ≥ 1 on the (r−1)-dim subspace orthogonal to g).
pub const TRACK_ETA: f64 = 0.5;

/// Non-panicking lower Cholesky: `None` when a pivot falls below the
/// positivity floor (numerically rank-deficient Y — callers fall back
/// to a fresh draw instead of aborting a training run).
fn chol_lower(a: &Mat) -> Option<Mat> {
    let r = a.rows;
    let mut l = Mat::zeros(r, r);
    for i in 0..r {
        for j in 0..=i {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if !(s > 1e-12) {
                    return None;
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Some(l)
}

/// Inverse of a lower-triangular matrix by forward substitution
/// (columns of L⁻¹ solve L·x = e_j). `None` on a zero diagonal.
fn invert_lower(l: &Mat) -> Option<Mat> {
    let r = l.rows;
    let mut inv = Mat::zeros(r, r);
    for j in 0..r {
        let d = l.get(j, j);
        if d == 0.0 || !d.is_finite() {
            return None;
        }
        inv.set(j, j, 1.0 / d);
        for i in (j + 1)..r {
            let mut s = 0.0;
            for k in j..i {
                s -= l.get(i, k) * inv.get(k, j);
            }
            inv.set(i, j, s / l.get(i, i));
        }
    }
    Some(inv)
}

/// One warm-started refresh of the unit frame `prev` ∈ St(n, r).
///
/// Consumes n + r normals from `rng` (ambient direction first, then the
/// coefficient row). Returns the new unit frame and the scaled
/// projector V = √(cn/r)·Q⁺, or `None` if the corrected panel is
/// numerically rank-deficient (probability ~0; callers fresh-draw).
pub fn tracked_update(prev: &Mat, c: f64, rng: &mut Rng) -> Option<(Mat, Mat)> {
    let (n, r) = (prev.rows, prev.cols);
    // rank-1 Gaussian kick: û·gᵀ with û uniform on the sphere
    let mut u = rng.normal_vec(n);
    let norm = u.iter().map(|x| x * x).sum::<f64>().sqrt();
    if !norm.is_finite() || norm <= 0.0 {
        return None;
    }
    for x in u.iter_mut() {
        *x /= norm;
    }
    let g = rng.normal_vec(r);
    // Y = Q + η·û·gᵀ — first O(n·r) pass
    let mut y = prev.clone();
    for (i, ui) in u.iter().enumerate() {
        let eta_ui = TRACK_ETA * ui;
        for (yij, gj) in y.data[i * r..(i + 1) * r].iter_mut().zip(&g) {
            *yij += eta_ui * gj;
        }
    }
    // Gram G = YᵀY — the r×r factor everything else works on
    let mut gram = Mat::zeros(r, r);
    for i in 0..n {
        let row = &y.data[i * r..(i + 1) * r];
        for j in 0..r {
            let yj = row[j];
            for (k, yk) in row.iter().enumerate().skip(j) {
                gram.data[j * r + k] += yj * yk;
            }
        }
    }
    for j in 0..r {
        for k in (j + 1)..r {
            let s = gram.get(j, k);
            gram.set(k, j, s);
        }
    }
    let l = chol_lower(&gram)?;
    let linv = invert_lower(&l)?;
    // Q⁺ = Y·L⁻ᵀ: q_i[j] = Σ_{k≤j} y_i[k]·L⁻¹[j,k] — second O(n·r²) pass
    let mut q = Mat::zeros(n, r);
    for i in 0..n {
        let yrow = &y.data[i * r..(i + 1) * r];
        let qrow = &mut q.data[i * r..(i + 1) * r];
        for (j, qj) in qrow.iter_mut().enumerate() {
            let lrow = &linv.data[j * r..j * r + j + 1];
            let mut s = 0.0;
            for (yk, lk) in yrow[..=j].iter().zip(lrow) {
                s += yk * lk;
            }
            *qj = s;
        }
    }
    let alpha = (c * n as f64 / r as f64).sqrt();
    let v = q.scaled(alpha);
    Some((q, v))
}

/// Fresh Haar draw, returning both the unit frame and the scaled V.
///
/// Consumes the stream exactly like [`super::StiefelSampler::sample`]
/// (n·r normals in row-major order, then thin-QR with the
/// positive-diagonal sign fix), so a full-refresh tick produces the
/// same V bits the untracked sampler would — pinned by tests.
pub fn fresh_frame(n: usize, r: usize, c: f64, rng: &mut Rng) -> (Mat, Mat) {
    let mut g = Mat::zeros(n, r);
    for x in g.data.iter_mut() {
        *x = rng.normal();
    }
    let q = thin_qr(&g).q;
    let alpha = (c * n as f64 / r as f64).sqrt();
    let v = q.scaled(alpha);
    (q, v)
}

/// Batch refresh — the tracked counterpart of [`super::sample_batch`].
///
/// One child stream is forked from `rng` per slot, in slot order, and
/// the per-slot refreshes fan out across the kernel pool: the output is
/// a pure function of the parent stream and the request list,
/// **identical at every thread count**. A slot falls back to a fresh
/// draw when `full` is set (the every-T Haar refresh), when it has no
/// frame yet (first resample, or restored without one), when its frame
/// shape disagrees with `dims` (stale after an external re-layout), or
/// when the tracked update reports numerical rank deficiency.
///
/// `frames[i]` is updated in place to the new unit frame; the returned
/// Mats are the scaled projectors V = √(cn/r)·Q.
pub fn track_batch(
    dims: &[(usize, usize)],
    c: f64,
    frames: &mut [Option<Mat>],
    full: bool,
    rng: &mut Rng,
) -> Vec<Mat> {
    assert_eq!(dims.len(), frames.len(), "one frame cell per dim request");
    // fork all child streams first: this is the only part that touches
    // the (inherently sequential) parent stream
    let mut children: Vec<Rng> = (0..dims.len()).map(|i| rng.fork(i as u64 + 1)).collect();
    let mut out: Vec<Mat> = vec![Mat::zeros(0, 0); dims.len()];
    let pool = crate::kernel::global();
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (((slot, frame), child), &(n, r)) in
        out.iter_mut().zip(frames.iter_mut()).zip(children.iter_mut()).zip(dims)
    {
        tasks.push(Box::new(move || {
            let tracked = if full {
                None
            } else {
                frame
                    .as_ref()
                    .filter(|q| q.rows == n && q.cols == r)
                    .and_then(|q| tracked_update(q, c, child))
            };
            let (q, v) = tracked.unwrap_or_else(|| fresh_frame(n, r, c, child));
            *frame = Some(q);
            *slot = v;
        }));
    }
    pool.run(tasks);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_tn, orthonormality_defect};
    use crate::projection::{ProjectionSampler, StiefelSampler};

    fn gram_defect(v: &Mat, c: f64) -> f64 {
        // max |VᵀV − (cn/r)I| entry
        let gram = matmul_tn(v, v);
        let target = c * v.rows as f64 / v.cols as f64;
        let mut worst = 0.0f64;
        for i in 0..gram.rows {
            for j in 0..gram.cols {
                let want = if i == j { target } else { 0.0 };
                worst = worst.max((gram.get(i, j) - want).abs());
            }
        }
        worst
    }

    #[test]
    fn tracked_updates_keep_the_theorem_2_condition() {
        let (n, r, c) = (96usize, 8usize, 1.0f64);
        let mut rng = Rng::new(7);
        let (mut q, v) = fresh_frame(n, r, c, &mut rng);
        assert!(gram_defect(&v, c) < 1e-6);
        for _ in 0..32 {
            let (q2, v) = tracked_update(&q, c, &mut rng).expect("well-conditioned update");
            assert!(gram_defect(&v, c) < 1e-6, "VᵀV drifted off (cn/r)·I");
            assert!(orthonormality_defect(&q2) < 1e-9);
            q = q2;
        }
    }

    #[test]
    fn tracked_update_moves_the_subspace() {
        // the rank-1 kick must rotate the projector P = QQᵀ — a pure
        // in-span rotation would leave the estimator's subspace frozen
        let (n, r, c) = (40usize, 4usize, 1.0f64);
        let mut rng = Rng::new(3);
        let (q, _) = fresh_frame(n, r, c, &mut rng);
        let (q2, _) = tracked_update(&q, c, &mut rng).unwrap();
        let p1 = crate::linalg::matmul_nt(&q, &q);
        let p2 = crate::linalg::matmul_nt(&q2, &q2);
        assert!(p1.max_abs_diff(&p2) > 1e-3, "projector did not move");
    }

    #[test]
    fn fresh_frame_matches_the_stiefel_sampler_bitwise() {
        let (n, r, c) = (24usize, 5usize, 2.0f64);
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let (_, v) = fresh_frame(n, r, c, &mut a);
        let want = StiefelSampler::new(n, r, c).sample(&mut b);
        assert_eq!(v, want, "full-refresh draw must equal the untracked sampler");
    }

    #[test]
    fn degenerate_gram_is_rejected_not_propagated() {
        let a = Mat::from_rows(2, 2, &[1.0, 1.0, 1.0, 1.0]); // singular
        assert!(chol_lower(&a).is_none());
        let l = Mat::from_rows(2, 2, &[1.0, 0.0, 3.0, 2.0]);
        let inv = invert_lower(&l).unwrap();
        // L·L⁻¹ = I
        let prod = crate::linalg::matmul(&l, &inv);
        assert!(prod.max_abs_diff(&Mat::eye(2)) < 1e-14);
    }

    #[test]
    fn track_batch_full_tick_equals_sample_batch() {
        let dims = [(16usize, 3usize), (12, 4)];
        let c = 1.5;
        let mut frames = vec![None, None];
        let mut a = Rng::new(42);
        let vs = track_batch(&dims, c, &mut frames, true, &mut a);
        let mut b = Rng::new(42);
        let want = crate::projection::sample_batch(
            crate::projection::ProjectorKind::Stiefel,
            &dims,
            c,
            None,
            &mut b,
        );
        assert_eq!(vs, want);
        for (frame, &(n, r)) in frames.iter().zip(&dims) {
            let f = frame.as_ref().unwrap();
            assert_eq!((f.rows, f.cols), (n, r));
        }
    }
}
