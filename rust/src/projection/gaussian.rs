//! Gaussian projection baseline (Remark 1; Chen et al. 2024, He et al.
//! 2024b).
//!
//! V has i.i.d. N(0, c/r) entries, so E[VVᵀ] = cI_n — admissible, but it
//! does **not** satisfy Theorem 2's optimality condition VᵀV = (cn/r)I
//! (the Gram matrix of a Gaussian V is Wishart-distributed, not a scaled
//! identity), and its second moment E[P²] = c²·(n+r+1)/r·I is strictly
//! larger than the Stiefel/coordinate optimum c²·n/r·I whenever n > r−1…
//! which is exactly the gap the paper's Figures 2–5 display.

use super::ProjectionSampler;
use crate::linalg::Mat;
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct GaussianSampler {
    n: usize,
    r: usize,
    c: f64,
    sd: f64,
}

impl GaussianSampler {
    pub fn new(n: usize, r: usize, c: f64) -> Self {
        assert!(r >= 1 && r <= n, "rank r={r} out of range for n={n}");
        assert!(c > 0.0, "c must be positive");
        GaussianSampler { n, r, c, sd: (c / r as f64).sqrt() }
    }
}

impl ProjectionSampler for GaussianSampler {
    fn sample(&mut self, rng: &mut Rng) -> Mat {
        let mut v = Mat::zeros(self.n, self.r);
        for x in &mut v.data {
            *x = self.sd * rng.normal();
        }
        v
    }

    fn dim(&self) -> usize {
        self.n
    }

    fn rank(&self) -> usize {
        self.r
    }

    fn scale_c(&self) -> f64 {
        self.c
    }

    fn name(&self) -> &'static str {
        "gaussian"
    }

    fn clone_box(&self) -> Box<dyn ProjectionSampler + Send + Sync> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::tests::check_mean_isotropy;
    use crate::projection::{empirical_moments, projector_matrix};

    #[test]
    fn mean_projector_is_c_identity() {
        let mut s = GaussianSampler::new(8, 3, 1.0);
        check_mean_isotropy(&mut s, 30_000, 0.05);
        let mut s2 = GaussianSampler::new(8, 3, 0.4);
        check_mean_isotropy(&mut s2, 30_000, 0.05);
    }

    #[test]
    fn second_moment_matches_wishart_formula() {
        // E[P²] = c²(n+r+1)/r · I for V_ij ~ N(0, c/r).
        let (n, r, c) = (6, 2, 1.0);
        let mut s = GaussianSampler::new(n, r, c);
        let mut rng = Rng::new(99);
        let m = empirical_moments(&mut s, &mut rng, 60_000);
        let expect = c * c * (n as f64 + r as f64 + 1.0) / r as f64;
        let tr = m.mean_p2.trace() / n as f64;
        assert!(
            (tr - expect).abs() / expect < 0.05,
            "tr Ē[P²]/n = {tr}, wishart predicts {expect}"
        );
    }

    #[test]
    fn gram_is_not_scaled_identity() {
        // certifies Gaussian violates Thm 2's a.s. condition VᵀV=(cn/r)I
        let mut s = GaussianSampler::new(20, 4, 1.0);
        let mut rng = Rng::new(3);
        let v = s.sample(&mut rng);
        let gram = crate::linalg::matmul_tn(&v, &v);
        let target = Mat::eye(4).scaled(20.0 / 4.0);
        assert!(gram.max_abs_diff(&target) > 0.1);
    }

    #[test]
    fn tr_p2_exceeds_thm2_floor() {
        let (n, r, c) = (12, 3, 1.0);
        let mut s = GaussianSampler::new(n, r, c);
        let mut rng = Rng::new(5);
        let m = empirical_moments(&mut s, &mut rng, 20_000);
        let floor = (n * n) as f64 * c * c / r as f64; // Thm 2 optimum
        let got = m.mean_p2.trace();
        assert!(got > 1.2 * floor, "Gaussian tr E[P²]={got} should exceed floor {floor}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut s = GaussianSampler::new(5, 2, 1.0);
        let v1 = s.sample(&mut Rng::new(42));
        let v2 = s.sample(&mut Rng::new(42));
        assert_eq!(v1, v2);
    }

    #[test]
    fn projector_rank_at_most_r() {
        let mut s = GaussianSampler::new(10, 2, 1.0);
        let mut rng = Rng::new(7);
        let p = projector_matrix(&s.sample(&mut rng));
        let e = crate::linalg::sym_eig(&p);
        // eigenvalues 3..n must vanish
        for &lam in &e.values[2..] {
            assert!(lam.abs() < 1e-9, "rank leak: λ={lam}");
        }
    }
}
