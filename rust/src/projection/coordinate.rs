//! Coordinate-axis sampler (Algorithm 3) — the discrete instance-
//! independent optimum.
//!
//! Select r of the n coordinates uniformly without replacement, stack the
//! corresponding standard basis vectors, rescale by α = √(cn/r). Like the
//! Haar–Stiefel law it satisfies VᵀV = (cn/r)I almost surely and
//! E[VVᵀ] = cI (Proposition 2(ii)) — but each draw touches only r rows,
//! so sampling is O(r) instead of O(nr²): the cheap choice in the
//! training hot loop.

use super::ProjectionSampler;
use crate::linalg::Mat;
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct CoordinateSampler {
    n: usize,
    r: usize,
    c: f64,
    alpha: f64,
}

impl CoordinateSampler {
    pub fn new(n: usize, r: usize, c: f64) -> Self {
        assert!(r >= 1 && r <= n, "rank r={r} out of range for n={n}");
        assert!(c > 0.0, "c must be positive");
        CoordinateSampler { n, r, c, alpha: (c * n as f64 / r as f64).sqrt() }
    }

    /// Draw just the selected coordinate set J (|J| = r, sorted) — used
    /// by callers that exploit the sparsity of V directly.
    pub fn sample_support(&self, rng: &mut Rng) -> Vec<usize> {
        let mut j = rng.sample_without_replacement(self.n, self.r);
        j.sort_unstable();
        j
    }
}

impl ProjectionSampler for CoordinateSampler {
    fn sample(&mut self, rng: &mut Rng) -> Mat {
        let j = self.sample_support(rng);
        let mut v = Mat::zeros(self.n, self.r);
        for (k, &jk) in j.iter().enumerate() {
            v.set(jk, k, self.alpha);
        }
        v
    }

    fn dim(&self) -> usize {
        self.n
    }

    fn rank(&self) -> usize {
        self.r
    }

    fn scale_c(&self) -> f64 {
        self.c
    }

    fn name(&self) -> &'static str {
        "coordinate"
    }

    fn clone_box(&self) -> Box<dyn ProjectionSampler + Send + Sync> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_tn};
    use crate::projection::tests::check_mean_isotropy;
    use crate::projection::projector_matrix;

    #[test]
    fn gram_is_exactly_scaled_identity() {
        let (n, r, c) = (25, 6, 1.0);
        let mut s = CoordinateSampler::new(n, r, c);
        let mut rng = Rng::new(31);
        for _ in 0..20 {
            let v = s.sample(&mut rng);
            let gram = matmul_tn(&v, &v);
            let target = Mat::eye(r).scaled(c * n as f64 / r as f64);
            assert!(gram.max_abs_diff(&target) < 1e-12);
        }
    }

    #[test]
    fn projector_is_diagonal_with_alpha_sq_on_support() {
        let (n, r, c) = (10, 3, 1.0);
        let mut s = CoordinateSampler::new(n, r, c);
        let mut rng = Rng::new(37);
        let v = s.sample(&mut rng);
        let p = projector_matrix(&v);
        let alpha_sq = c * n as f64 / r as f64;
        let mut on_support = 0;
        for i in 0..n {
            for j in 0..n {
                let val = p.get(i, j);
                if i == j && val.abs() > 1e-12 {
                    assert!((val - alpha_sq).abs() < 1e-12);
                    on_support += 1;
                } else if i != j {
                    assert!(val.abs() < 1e-12, "off-diagonal leak at ({i},{j})");
                }
            }
        }
        assert_eq!(on_support, r);
    }

    #[test]
    fn mean_projector_is_c_identity() {
        let mut s = CoordinateSampler::new(12, 4, 1.0);
        check_mean_isotropy(&mut s, 30_000, 0.05);
    }

    #[test]
    fn tr_p2_attains_thm2_floor_exactly() {
        let (n, r, c) = (18, 3, 0.5);
        let mut s = CoordinateSampler::new(n, r, c);
        let mut rng = Rng::new(41);
        let floor = (n * n) as f64 * c * c / r as f64;
        for _ in 0..10 {
            let p = projector_matrix(&s.sample(&mut rng));
            let p2 = matmul(&p, &p);
            assert!((p2.trace() - floor).abs() < 1e-9);
        }
    }

    #[test]
    fn support_is_distinct_and_sorted() {
        let s = CoordinateSampler::new(15, 5, 1.0);
        let mut rng = Rng::new(43);
        for _ in 0..100 {
            let j = s.sample_support(&mut rng);
            assert_eq!(j.len(), 5);
            for w in j.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(*j.last().unwrap() < 15);
        }
    }
}
