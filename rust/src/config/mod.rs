//! Configuration system: a dependency-free TOML-subset parser for
//! experiment files plus a small CLI argument helper.
//!
//! The framework reads `key = value` config files with `[section]`
//! headers (strings, integers, floats, booleans) — enough to express
//! every experiment in `configs/` — and merges `--key value` CLI
//! overrides on top.

mod args;
mod parser;

pub use args::ArgMap;
pub use parser::{ConfigFile, Value};
