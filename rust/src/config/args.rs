//! Minimal CLI argument helper: `--key value` and `--flag` pairs after
//! the subcommand, with typed accessors mirroring [`super::ConfigFile`].

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct ArgMap {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl ArgMap {
    /// Parse `--key value` / `--flag` tokens. A token starting with
    /// `--` followed by another `--token` (or nothing) is a flag.
    pub fn parse(tokens: &[String]) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            let Some(key) = t.strip_prefix("--") else {
                bail!("unexpected positional argument {t:?}");
            };
            if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                values.insert(key.to_string(), tokens[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(ArgMap { values, flags })
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.values.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Three-way lookup for options that work both bare and with a
    /// value (e.g. `--resume` ≡ `--resume latest`, `--resume 400`):
    /// `None` when absent, `Some(None)` for a bare flag, `Some(Some(v))`
    /// when a value was given.
    pub fn flag_or_value(&self, key: &str) -> Option<Option<&str>> {
        if let Some(v) = self.values.get(key) {
            return Some(Some(v.as_str()));
        }
        if self.has_flag(key) {
            return Some(None);
        }
        None
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.values
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.values
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.values
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.values
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// `--threads N` — kernel pool size, shared by every subcommand;
    /// this is the single place the flag is parsed. `default` is the
    /// config-file fallback (0 where no config key exists). 0 leaves
    /// the pool at its current size (initially `LOWRANK_THREADS` if
    /// set, else the machine's available parallelism). Results are
    /// bitwise identical at any value (see [`crate::kernel`]).
    pub fn threads_or(&self, default: usize) -> usize {
        self.usize_or("threads", default)
    }

    /// `--comm-dtype f32|bf16` — wire dtype of the comm collectives,
    /// shared by every rank-aware subcommand; this is the single place
    /// the flag is parsed. `None` when absent (the
    /// `LOWRANK_COMM_DTYPE` env contract, default f32, then decides);
    /// a bad value is a loud error, never a silent f32 fallback.
    pub fn comm_dtype(&self) -> Result<Option<crate::comm::WireDtype>> {
        self.get("comm-dtype")
            .map(crate::comm::WireDtype::parse)
            .transpose()
    }

    /// `--trace-out <path>` — Chrome `trace_event` JSON export of the
    /// observability spans; `None` (tracing off) when absent. Shared by
    /// every rank-aware subcommand; this is the single place the flag
    /// is parsed. In a launch world each rank writes
    /// `<stem>.rank<r>.json` and the leader merges after the final
    /// barrier (see [`crate::obs`]).
    pub fn trace_out(&self) -> Option<&str> {
        self.get("trace-out")
    }

    /// `--metrics-out <path>` — JSONL export of the metrics registry
    /// (one snapshot object per rank); `None` (metrics off) when
    /// absent. The leader writes all ranks' snapshots, gathered over
    /// the collective.
    pub fn metrics_out(&self) -> Option<&str> {
        self.get("metrics-out")
    }

    /// `--monitor-addr <host:port>` — read-only TCP status endpoint
    /// serving newline-delimited JSON snapshots of the metrics registry
    /// ([`crate::obs::monitor::serve_status`]); `None` (no endpoint)
    /// when absent. In a launch world every rank shares argv, so only
    /// the leader binds (avoiding a port collision).
    pub fn monitor_addr(&self) -> Option<&str> {
        self.get("monitor-addr")
    }

    /// `--stall-timeout <ms>` — watchdog threshold: flag this rank as
    /// stalled when no heartbeat watermark advances for this many
    /// milliseconds ([`crate::obs::monitor::start_watchdog`]). 0 (the
    /// default) leaves the watchdog off.
    pub fn stall_timeout_ms(&self) -> u64 {
        self.u64_or("stall-timeout", 0)
    }

    /// `--probe-every <K>` — estimator-quality probe cadence: every K
    /// steps one rotating subspace slot gets a paired probe
    /// ([`crate::obs::quality`]). 0 (the default) disables the rotating
    /// probes; the lazy-update-boundary gauges still run whenever
    /// metrics are enabled.
    pub fn probe_every(&self) -> u64 {
        self.u64_or("probe-every", 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_pairs_and_flags() {
        let a = ArgMap::parse(&toks("--steps 100 --quick --lr 2e-3")).unwrap();
        assert_eq!(a.u64_or("steps", 0), 100);
        assert!(a.has_flag("quick"));
        assert!((a.f64_or("lr", 0.0) - 2e-3).abs() < 1e-12);
        assert_eq!(a.str_or("sampler", "stiefel"), "stiefel");
    }

    #[test]
    fn trailing_flag_ok() {
        let a = ArgMap::parse(&toks("--verbose")).unwrap();
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn rejects_positional() {
        assert!(ArgMap::parse(&toks("oops --x 1")).is_err());
    }

    #[test]
    fn defaults_on_bad_parse() {
        let a = ArgMap::parse(&toks("--steps abc")).unwrap();
        assert_eq!(a.u64_or("steps", 9), 9);
    }

    #[test]
    fn threads_defaults_to_auto() {
        let a = ArgMap::parse(&toks("--threads 4")).unwrap();
        assert_eq!(a.threads_or(0), 4);
        let b = ArgMap::parse(&toks("--steps 5")).unwrap();
        assert_eq!(b.threads_or(0), 0);
        assert_eq!(b.threads_or(2), 2); // config-file fallback wins
    }

    #[test]
    fn comm_dtype_parses_and_rejects() {
        let a = ArgMap::parse(&toks("--comm-dtype bf16")).unwrap();
        assert_eq!(a.comm_dtype().unwrap(), Some(crate::comm::WireDtype::Bf16));
        let b = ArgMap::parse(&toks("--steps 5")).unwrap();
        assert_eq!(b.comm_dtype().unwrap(), None);
        let c = ArgMap::parse(&toks("--comm-dtype fp8")).unwrap();
        assert!(c.comm_dtype().is_err());
    }

    #[test]
    fn obs_outputs_parse() {
        let a = ArgMap::parse(&toks("--trace-out t.json --metrics-out m.jsonl")).unwrap();
        assert_eq!(a.trace_out(), Some("t.json"));
        assert_eq!(a.metrics_out(), Some("m.jsonl"));
        let b = ArgMap::parse(&toks("--steps 5")).unwrap();
        assert_eq!(b.trace_out(), None);
        assert_eq!(b.metrics_out(), None);
    }

    #[test]
    fn monitor_flags_parse() {
        let a = ArgMap::parse(&toks(
            "--monitor-addr 127.0.0.1:7777 --stall-timeout 2000 --probe-every 4",
        ))
        .unwrap();
        assert_eq!(a.monitor_addr(), Some("127.0.0.1:7777"));
        assert_eq!(a.stall_timeout_ms(), 2000);
        assert_eq!(a.probe_every(), 4);
        let b = ArgMap::parse(&toks("--steps 5")).unwrap();
        assert_eq!(b.monitor_addr(), None);
        assert_eq!(b.stall_timeout_ms(), 0);
        assert_eq!(b.probe_every(), 0);
    }

    #[test]
    fn flag_or_value_three_way() {
        let a = ArgMap::parse(&toks("--resume --ckpt-dir runs/ck")).unwrap();
        assert_eq!(a.flag_or_value("resume"), Some(None));
        assert_eq!(a.flag_or_value("ckpt-dir"), Some(Some("runs/ck")));
        assert_eq!(a.flag_or_value("absent"), None);
        let b = ArgMap::parse(&toks("--resume 400")).unwrap();
        assert_eq!(b.flag_or_value("resume"), Some(Some("400")));
    }
}
