//! TOML-subset config parser.
//!
//! Supported grammar (one statement per line):
//!   [section]
//!   key = "string" | 123 | 4.5 | true | false | bare-word
//!   # comment
//!
//! Keys are addressed as "section.key" (keys before any section header
//! live at the root as "key").

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str) -> Value {
        let raw = raw.trim();
        if (raw.starts_with('"') && raw.ends_with('"') && raw.len() >= 2)
            || (raw.starts_with('\'') && raw.ends_with('\'') && raw.len() >= 2)
        {
            return Value::Str(raw[1..raw.len() - 1].to_string());
        }
        if raw == "true" {
            return Value::Bool(true);
        }
        if raw == "false" {
            return Value::Bool(false);
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Value::Int(i);
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Value::Float(f);
        }
        Value::Str(raw.to_string())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed config file.
#[derive(Clone, Debug, Default)]
pub struct ConfigFile {
    values: BTreeMap<String, Value>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.len() < 3 {
                    bail!("line {}: malformed section header {raw:?}", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {raw:?}", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if values.insert(key.clone(), Value::parse(v)).is_some() {
                bail!("line {}: duplicate key {key}", lineno + 1);
            }
        }
        Ok(ConfigFile { values })
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// String value with no default (for keys like `pretrain.ckpt_dir`
    /// where absence means "feature off").
    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// Non-negative integer (counts: threads, workers, …); negative
    /// values clamp to the default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.as_i64())
            .and_then(|i| usize::try_from(i).ok())
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "pretrain-fig7"
seed = 42

[train]
steps = 100
lr = 2e-3
sampler = stiefel
clip = 1.0
use_ddp = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.get("name"), Some(&Value::Str("pretrain-fig7".into())));
        assert_eq!(c.get("seed"), Some(&Value::Int(42)));
        assert_eq!(c.get("train.steps"), Some(&Value::Int(100)));
        assert_eq!(c.get("train.lr"), Some(&Value::Float(2e-3)));
        // bare words parse as strings
        assert_eq!(c.str_or("train.sampler", "?"), "stiefel");
        assert_eq!(c.bool_or("train.use_ddp", false), true);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = ConfigFile::parse("a = 1").unwrap();
        assert_eq!(c.i64_or("missing", 7), 7);
        assert_eq!(c.f64_or("a", 0.0), 1.0); // int coerces to float
        assert_eq!(c.str_or("missing", "x"), "x");
        assert_eq!(c.str_opt("missing"), None);
        let d = ConfigFile::parse("[pretrain]\nckpt_dir = \"runs/ck\"").unwrap();
        assert_eq!(d.str_opt("pretrain.ckpt_dir"), Some("runs/ck"));
    }

    #[test]
    fn usize_or_clamps_negatives_to_default() {
        let c = ConfigFile::parse("threads = 4\nbad = -2").unwrap();
        assert_eq!(c.usize_or("threads", 0), 4);
        assert_eq!(c.usize_or("bad", 1), 1);
        assert_eq!(c.usize_or("missing", 7), 7);
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(ConfigFile::parse("a = 1\na = 2").is_err());
        assert!(ConfigFile::parse("just words").is_err());
        assert!(ConfigFile::parse("[unclosed").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let c = ConfigFile::parse("# only a comment\n\nx = 3 # trailing\n").unwrap();
        assert_eq!(c.i64_or("x", 0), 3);
    }
}
