//! Matrix products and norms — thin f64 wrappers over the
//! [`crate::kernel`] substrate.
//!
//! Since the kernel refactor this module owns no GEMM loops of its own:
//! `matmul`/`matmul_tn`/`matmul_nt`/`matvec` all delegate to the shared
//! Scalar-generic blocked kernels, which run on the global
//! [`crate::kernel::KernelPool`] over the [`crate::kernel::simd`]
//! vector core (4-wide f64 lanes here) and are bitwise-deterministic
//! across thread counts and SIMD backends — `fro_inner` and the GEMM
//! dot panels inherit the fixed-lane accumulation order. The kernels
//! are branchless over the data — the old
//! `if aik == 0.0 { continue; }` zero-skip silently swallowed NaN/Inf
//! coming from B (0·NaN must be NaN); the regression tests below pin
//! the fixed behavior.

use super::Mat;
use crate::kernel;

/// C = A · B (blocked GEMM on the kernel pool).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_acc(a, b, &mut c);
    c
}

/// C += A · B without allocating. C must be m×n and pre-initialized.
pub fn matmul_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    kernel::auto::gemm_nn(&a.data, &b.data, &mut c.data, a.rows, a.cols, b.cols);
}

/// C = A · B into a pre-allocated (zeroed here) output.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    c.data.iter_mut().for_each(|v| *v = 0.0);
    matmul_acc(a, b, c);
}

/// Aᵀ as a new matrix.
pub fn transpose(a: &Mat) -> Mat {
    let mut t = Mat::zeros(a.cols, a.rows);
    for i in 0..a.rows {
        for j in 0..a.cols {
            t.data[j * a.rows + i] = a.data[i * a.cols + j];
        }
    }
    t
}

/// C = Aᵀ · B without materializing Aᵀ.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let mut c = Mat::zeros(a.cols, b.cols);
    kernel::auto::gemm_tn(&a.data, &b.data, &mut c.data, a.rows, a.cols, b.cols);
    c
}

/// C = A · Bᵀ without materializing Bᵀ.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    let mut c = Mat::zeros(a.rows, b.rows);
    kernel::auto::gemm_nt(1.0, &a.data, &b.data, &mut c.data, a.rows, b.rows, a.cols);
    c
}

/// Frobenius inner product ⟨A, B⟩ = tr(AᵀB) (deterministic chunked
/// reduction on the kernel pool).
pub fn fro_inner(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    kernel::auto::dot(&a.data, &b.data)
}

/// tr(A·B) for square A·B without forming the product.
pub fn trace_product(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.cols, b.rows);
    assert_eq!(a.rows, b.cols, "trace_product needs square A·B");
    // tr(AB) = Σ_{i,k} A_{ik} B_{ki}
    let mut s = 0.0;
    for i in 0..a.rows {
        for k in 0..a.cols {
            s += a.data[i * a.cols + k] * b.data[k * b.cols + i];
        }
    }
    s
}

/// Spectral norm ‖A‖₂ (largest singular value) by power iteration on AᵀA.
pub fn spectral_norm(a: &Mat, iters: usize) -> f64 {
    let n = a.cols;
    if a.data.iter().all(|&v| v == 0.0) {
        return 0.0;
    }
    // deterministic start: normalized row-sum vector perturbed to avoid
    // landing exactly in a null space.
    let mut v: Vec<f64> = (0..n).map(|j| 1.0 + (j as f64) * 1e-3).collect();
    let mut norm = (v.iter().map(|x| x * x).sum::<f64>()).sqrt();
    v.iter_mut().for_each(|x| *x /= norm);
    let mut sigma_sq = 0.0;
    for _ in 0..iters {
        // w = Av ; v' = Aᵀw — both through the kernel GEMV paths
        let w = matvec(a, &v);
        let mut v2 = matvec_t(a, &w);
        norm = (v2.iter().map(|x| x * x).sum::<f64>()).sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        sigma_sq = norm; // ‖AᵀAv‖ → λ_max(AᵀA) as v converges
        v2.iter_mut().for_each(|x| *x /= norm);
        v = v2;
    }
    sigma_sq.sqrt()
}

/// A · v for a vector v (GEMM with n = 1).
pub fn matvec(a: &Mat, v: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, v.len());
    let mut out = vec![0.0; a.rows];
    kernel::auto::gemm_nn(&a.data, v, &mut out, a.rows, a.cols, 1);
    out
}

/// Aᵀ · v for a vector v (transposed GEMM with n = 1).
pub fn matvec_t(a: &Mat, v: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, v.len());
    let mut out = vec![0.0; a.cols];
    kernel::auto::gemm_tn(&a.data, v, &mut out, a.rows, a.cols, 1);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn arb(rows: usize, cols: usize, seed: u64) -> Mat {
        // lightweight LCG so linalg tests don't depend on rng module
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        Mat::from_fn(rows, cols, |_, _| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as f64) / (u32::MAX as f64) - 0.5
        })
    }

    #[test]
    fn blocked_matmul_matches_naive_rect() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (70, 65, 130), (128, 64, 96)] {
            let a = arb(m, k, 7);
            let b = arb(k, n, 11);
            let c = matmul(&a, &b);
            let cn = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&cn) < 1e-10, "mismatch at {m}x{k}x{n}");
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        let a = arb(40, 33, 3);
        let b = arb(40, 21, 5);
        let c1 = matmul_tn(&a, &b);
        let c2 = matmul(&transpose(&a), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-10);

        let d = arb(17, 33, 9);
        let e1 = matmul_nt(&a, &d); // 40x33 · (17x33)ᵀ
        let e2 = matmul(&a, &transpose(&d));
        assert!(e1.max_abs_diff(&e2) < 1e-10);
    }

    #[test]
    fn trace_product_matches_full_product() {
        let a = arb(12, 8, 1);
        let b = arb(8, 12, 2);
        let t1 = trace_product(&a, &b);
        let t2 = matmul(&a, &b).trace();
        assert!((t1 - t2).abs() < 1e-10);
    }

    #[test]
    fn fro_inner_is_trace_of_atb() {
        let a = arb(9, 7, 4);
        let b = arb(9, 7, 6);
        let t = matmul_tn(&a, &b).trace();
        assert!((fro_inner(&a, &b) - t).abs() < 1e-10);
    }

    #[test]
    fn spectral_norm_of_diag_is_max_entry() {
        let d = Mat::diag(&[0.5, 3.0, 2.0]);
        let s = spectral_norm(&d, 100);
        assert!((s - 3.0).abs() < 1e-8, "got {s}");
    }

    #[test]
    fn spectral_norm_bounded_by_fro() {
        let a = arb(30, 20, 8);
        let s = spectral_norm(&a, 200);
        assert!(s <= a.fro_norm() + 1e-9);
        assert!(s >= a.fro_norm() / (20f64).sqrt() - 1e-9);
    }

    #[test]
    fn matvec_consistency() {
        let a = arb(6, 4, 10);
        let v: Vec<f64> = (0..4).map(|i| i as f64 + 1.0).collect();
        let b = Mat { rows: 4, cols: 1, data: v.clone() };
        let full = matmul(&a, &b);
        assert_eq!(matvec(&a, &v), full.data);

        let w: Vec<f64> = (0..6).map(|i| i as f64 - 2.0).collect();
        let wt = matvec_t(&a, &w);
        let full_t = matmul_tn(&a, &Mat { rows: 6, cols: 1, data: w });
        assert_eq!(wt, full_t.data);
    }

    #[test]
    fn zero_matrix_spectral_norm_is_zero() {
        assert_eq!(spectral_norm(&Mat::zeros(5, 5), 50), 0.0);
    }

    #[test]
    fn nan_in_b_propagates_through_zero_rows_of_a() {
        // Regression: the pre-kernel GEMM skipped `aik == 0.0` terms, so
        // a zero row of A masked NaN/Inf in B. 0·NaN = NaN and
        // 0·∞ = NaN must reach C in every variant.
        let a = Mat::from_rows(2, 2, &[0.0, 0.0, 1.0, 1.0]);
        let b = Mat::from_rows(2, 3, &[1.0, f64::NAN, 2.0, 3.0, 4.0, f64::INFINITY]);

        let c = matmul(&a, &b);
        assert!(!c.get(0, 0).is_nan(), "finite column stays finite");
        assert!(c.get(0, 1).is_nan(), "matmul dropped 0·NaN");
        assert!(c.get(0, 2).is_nan(), "matmul dropped 0·Inf");
        assert!(c.get(1, 1).is_nan());

        // Aᵀ has a zero column ⇒ zero coefficients hit B's NaN column.
        let at = Mat::from_rows(2, 2, &[0.0, 1.0, 0.0, 1.0]);
        let ct = matmul_tn(&at, &b);
        assert!(ct.get(0, 1).is_nan(), "matmul_tn dropped 0·NaN");
        assert!(ct.get(0, 2).is_nan(), "matmul_tn dropped 0·Inf");

        // nt: B row with NaN against zero A row.
        let bn = Mat::from_rows(2, 2, &[f64::NAN, 1.0, 2.0, 3.0]);
        let cn = matmul_nt(&a, &bn); // 2×2 · (2×2)ᵀ
        assert!(cn.get(0, 0).is_nan(), "matmul_nt dropped 0·NaN");
        assert!(cn.get(1, 0).is_nan());
    }
}
