//! Matrix products and norms.
//!
//! `matmul` is a cache-blocked, k-innermost GEMM — the single hot path of
//! the rust-side estimator stack (toy experiments run millions of
//! `m×n · n×r` products). The blocking mirrors the L1 Pallas kernel's
//! BlockSpec schedule: a tile of A and a panel of B stay resident while a
//! C tile accumulates.

use super::Mat;

/// Cache-block edge (f64 elements). 64×64×8B = 32 KB per tile, three tiles
/// comfortably fit in a 256 KB L2.
const BLOCK: usize = 64;

/// C = A · B (blocked GEMM).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C += A · B without allocating. C must be m×n and pre-initialized.
pub fn matmul_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut c.data[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        // innermost j loop: contiguous in both B and C,
                        // auto-vectorizes.
                        for j in j0..j1 {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }
}

/// C = A · B into a pre-allocated (zeroed here) output.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    c.data.iter_mut().for_each(|v| *v = 0.0);
    matmul_acc(a, b, c);
}

/// Aᵀ as a new matrix.
pub fn transpose(a: &Mat) -> Mat {
    let mut t = Mat::zeros(a.cols, a.rows);
    for i in 0..a.rows {
        for j in 0..a.cols {
            t.data[j * a.rows + i] = a.data[i * a.cols + j];
        }
    }
    t
}

/// C = Aᵀ · B without materializing Aᵀ.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    // (AᵀB)_{ij} = Σ_k A_{ki} B_{kj}; iterate k outer so both reads stream.
    for kk in 0..k {
        let arow = &a.data[kk * m..(kk + 1) * m];
        let brow = &b.data[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aki = arow[i];
            if aki == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aki * brow[j];
            }
        }
    }
    c
}

/// C = A · Bᵀ without materializing Bᵀ.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut s = 0.0;
            for kk in 0..k {
                s += arow[kk] * brow[kk];
            }
            crow[j] = s;
        }
    }
    c
}

/// Frobenius inner product ⟨A, B⟩ = tr(AᵀB).
pub fn fro_inner(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum()
}

/// tr(A·B) for square A·B without forming the product.
pub fn trace_product(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.cols, b.rows);
    assert_eq!(a.rows, b.cols, "trace_product needs square A·B");
    // tr(AB) = Σ_{i,k} A_{ik} B_{ki}
    let mut s = 0.0;
    for i in 0..a.rows {
        for k in 0..a.cols {
            s += a.data[i * a.cols + k] * b.data[k * b.cols + i];
        }
    }
    s
}

/// Spectral norm ‖A‖₂ (largest singular value) by power iteration on AᵀA.
pub fn spectral_norm(a: &Mat, iters: usize) -> f64 {
    let n = a.cols;
    if a.data.iter().all(|&v| v == 0.0) {
        return 0.0;
    }
    // deterministic start: normalized row-sum vector perturbed to avoid
    // landing exactly in a null space.
    let mut v: Vec<f64> = (0..n).map(|j| 1.0 + (j as f64) * 1e-3).collect();
    let mut norm = (v.iter().map(|x| x * x).sum::<f64>()).sqrt();
    v.iter_mut().for_each(|x| *x /= norm);
    let mut sigma_sq = 0.0;
    for _ in 0..iters {
        // w = Av ; v' = Aᵀw
        let mut w = vec![0.0; a.rows];
        for i in 0..a.rows {
            let arow = a.row(i);
            let mut s = 0.0;
            for j in 0..n {
                s += arow[j] * v[j];
            }
            w[i] = s;
        }
        let mut v2 = vec![0.0; n];
        for i in 0..a.rows {
            let arow = a.row(i);
            let wi = w[i];
            for j in 0..n {
                v2[j] += arow[j] * wi;
            }
        }
        norm = (v2.iter().map(|x| x * x).sum::<f64>()).sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        sigma_sq = norm; // ‖AᵀAv‖ → λ_max(AᵀA) as v converges
        v2.iter_mut().for_each(|x| *x /= norm);
        v = v2;
    }
    sigma_sq.sqrt()
}

/// A · v for a vector v.
pub fn matvec(a: &Mat, v: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, v.len());
    (0..a.rows)
        .map(|i| a.row(i).iter().zip(v).map(|(x, y)| x * y).sum())
        .collect()
}

/// Aᵀ · v for a vector v.
pub fn matvec_t(a: &Mat, v: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, v.len());
    let mut out = vec![0.0; a.cols];
    for i in 0..a.rows {
        let arow = a.row(i);
        let vi = v[i];
        for j in 0..a.cols {
            out[j] += arow[j] * vi;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn arb(rows: usize, cols: usize, seed: u64) -> Mat {
        // lightweight LCG so linalg tests don't depend on rng module
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        Mat::from_fn(rows, cols, |_, _| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as f64) / (u32::MAX as f64) - 0.5
        })
    }

    #[test]
    fn blocked_matmul_matches_naive_rect() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (70, 65, 130), (128, 64, 96)] {
            let a = arb(m, k, 7);
            let b = arb(k, n, 11);
            let c = matmul(&a, &b);
            let cn = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&cn) < 1e-10, "mismatch at {m}x{k}x{n}");
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        let a = arb(40, 33, 3);
        let b = arb(40, 21, 5);
        let c1 = matmul_tn(&a, &b);
        let c2 = matmul(&transpose(&a), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-10);

        let d = arb(17, 33, 9);
        let e1 = matmul_nt(&a, &d); // 40x33 · (17x33)ᵀ
        let e2 = matmul(&a, &transpose(&d));
        assert!(e1.max_abs_diff(&e2) < 1e-10);
    }

    #[test]
    fn trace_product_matches_full_product() {
        let a = arb(12, 8, 1);
        let b = arb(8, 12, 2);
        let t1 = trace_product(&a, &b);
        let t2 = matmul(&a, &b).trace();
        assert!((t1 - t2).abs() < 1e-10);
    }

    #[test]
    fn fro_inner_is_trace_of_atb() {
        let a = arb(9, 7, 4);
        let b = arb(9, 7, 6);
        let t = matmul_tn(&a, &b).trace();
        assert!((fro_inner(&a, &b) - t).abs() < 1e-10);
    }

    #[test]
    fn spectral_norm_of_diag_is_max_entry() {
        let d = Mat::diag(&[0.5, 3.0, 2.0]);
        let s = spectral_norm(&d, 100);
        assert!((s - 3.0).abs() < 1e-8, "got {s}");
    }

    #[test]
    fn spectral_norm_bounded_by_fro() {
        let a = arb(30, 20, 8);
        let s = spectral_norm(&a, 200);
        assert!(s <= a.fro_norm() + 1e-9);
        assert!(s >= a.fro_norm() / (20f64).sqrt() - 1e-9);
    }

    #[test]
    fn matvec_consistency() {
        let a = arb(6, 4, 10);
        let v: Vec<f64> = (0..4).map(|i| i as f64 + 1.0).collect();
        let b = Mat { rows: 4, cols: 1, data: v.clone() };
        let full = matmul(&a, &b);
        assert_eq!(matvec(&a, &v), full.data);

        let w: Vec<f64> = (0..6).map(|i| i as f64 - 2.0).collect();
        let wt = matvec_t(&a, &w);
        let full_t = matmul_tn(&a, &Mat { rows: 6, cols: 1, data: w });
        assert_eq!(wt, full_t.data);
    }

    #[test]
    fn zero_matrix_spectral_norm_is_zero() {
        assert_eq!(spectral_norm(&Mat::zeros(5, 5), 50), 0.0);
    }
}
