//! Cholesky factorization (lower-triangular), used to sample the toy
//! problem's correlated Gaussian data A ~ N(μ, Σ_A): A = μ + L·z with
//! Σ_A = LLᵀ.

use super::Mat;

/// Lower-triangular L with A = L·Lᵀ. Panics if `a` is not (numerically)
/// symmetric positive definite.
pub fn cholesky(a: &Mat) -> Mat {
    assert!(a.is_square(), "cholesky requires a square matrix");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                assert!(s > 0.0, "matrix not positive definite at pivot {i} (s={s})");
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::{matmul, transpose};

    #[test]
    fn reconstructs_spd_matrix() {
        // AR(1) covariance, ρ = 0.6
        let n = 12;
        let a = Mat::from_fn(n, n, |i, j| 0.6f64.powi((i as i32 - j as i32).abs()));
        let l = cholesky(&a);
        let rec = matmul(&l, &transpose(&l));
        assert!(rec.max_abs_diff(&a) < 1e-10);
        // L is lower triangular
        for i in 0..n {
            for j in (i + 1)..n {
                assert_eq!(l.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn identity_factors_to_identity() {
        let l = cholesky(&Mat::eye(5));
        assert!(l.max_abs_diff(&Mat::eye(5)) < 1e-14);
    }

    #[test]
    #[should_panic(expected = "not positive definite")]
    fn rejects_indefinite() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, −1
        cholesky(&a);
    }
}
