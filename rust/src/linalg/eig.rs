//! Symmetric eigendecomposition by the cyclic Jacobi method.
//!
//! Algorithm 4 (instance-dependent sampler) needs the full spectral
//! decomposition Σ = Q diag(σ) Qᵀ of the (symmetric PSD) second-moment
//! matrix. Jacobi is the right tool here: Σ is small (n = per-layer input
//! dim), the method is unconditionally stable, and it delivers orthogonal
//! eigenvectors to machine precision — which the sampler's isotropy
//! constraint E[P] = cI relies on exactly.

use super::Mat;
use crate::kernel;

/// Result of [`sym_eig`]: `a ≈ q · diag(values) · qᵀ`, eigenvalues sorted
/// in **descending** order (σ₁ ≥ … ≥ σ_n, the paper's convention).
pub struct EigDecomp {
    /// Eigenvalues, descending.
    pub values: Vec<f64>,
    /// n×n orthogonal matrix; column j is the eigenvector of `values[j]`.
    pub q: Mat,
}

/// Cyclic Jacobi eigensolver for a symmetric matrix.
///
/// Panics if `a` is not square; symmetry is enforced by averaging
/// (A+Aᵀ)/2 so tiny asymmetries from accumulation don't bite.
pub fn sym_eig(a: &Mat) -> EigDecomp {
    assert!(a.is_square(), "sym_eig requires a square matrix");
    let n = a.rows;
    // symmetrized working copy
    let mut m = Mat::from_fn(n, n, |i, j| 0.5 * (a.get(i, j) + a.get(j, i)));
    let mut q = Mat::eye(n);

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apq = m.get(p, r);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(r, r);
                // rotation angle: tan(2θ) = 2apq / (app − aqq)
                let theta = 0.5 * (2.0 * apq).atan2(app - aqq);
                let (s, c) = theta.sin_cos();
                // apply Jᵀ M J where J rotates the (p, r) plane — the
                // column/row sweeps are the kernel's plane-rotation
                // primitives (strided for columns, contiguous for rows)
                kernel::rot_cols_strided(&mut m.data, n, p, r, n, c, s);
                {
                    let (lo, hi) = m.data.split_at_mut(r * n);
                    let rowp = &mut lo[p * n..(p + 1) * n];
                    let rowr = &mut hi[..n];
                    kernel::rot_rows(rowp, rowr, c, s);
                }
                kernel::rot_cols_strided(&mut q.data, n, p, r, n, c, s);
            }
        }
    }

    // extract, sort descending, permute eigenvector columns to match
    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
    idx.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let qs = Mat::from_fn(n, n, |i, j| q.get(i, idx[j]));
    EigDecomp { values, q: qs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::{matmul, matmul_tn, transpose};

    fn arb_sym(n: usize, seed: u64) -> Mat {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(9);
        let g = Mat::from_fn(n, n, |_, _| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as f64) / (u32::MAX as f64) - 0.5
        });
        // GᵀG is symmetric PSD
        matmul_tn(&g, &g)
    }

    #[test]
    fn reconstruction() {
        for &n in &[1, 2, 5, 17, 40] {
            let a = arb_sym(n, n as u64);
            let e = sym_eig(&a);
            let lam = Mat::diag(&e.values);
            let rec = matmul(&matmul(&e.q, &lam), &transpose(&e.q));
            assert!(rec.max_abs_diff(&a) < 1e-8 * (1.0 + a.fro_norm()), "n={n}");
        }
    }

    #[test]
    fn eigenvectors_orthogonal() {
        let a = arb_sym(23, 3);
        let e = sym_eig(&a);
        let qtq = matmul_tn(&e.q, &e.q);
        assert!(qtq.max_abs_diff(&Mat::eye(23)) < 1e-10);
    }

    #[test]
    fn eigenvalues_sorted_descending_and_nonnegative_for_psd() {
        let a = arb_sym(15, 7);
        let e = sym_eig(&a);
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        for &v in &e.values {
            assert!(v > -1e-9, "PSD matrix produced negative eigenvalue {v}");
        }
    }

    #[test]
    fn diagonal_matrix_eigenvalues_are_entries() {
        let a = Mat::diag(&[3.0, 1.0, 4.0, 1.5]);
        let e = sym_eig(&a);
        let expect = vec![4.0, 3.0, 1.5, 1.0];
        for (got, want) in e.values.iter().zip(&expect) {
            assert!((got - want).abs() < 1e-12);
        }
    }

    #[test]
    fn trace_preserved() {
        let a = arb_sym(19, 13);
        let e = sym_eig(&a);
        let sum: f64 = e.values.iter().sum();
        assert!((sum - a.trace()).abs() < 1e-9 * (1.0 + a.trace().abs()));
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
        // eigenvector of 3 is ±(1,1)/√2
        let v0 = e.q.col(0);
        assert!((v0[0].abs() - (0.5f64).sqrt()).abs() < 1e-10);
        assert!((v0[0] - v0[1]).abs() < 1e-10);
    }
}
