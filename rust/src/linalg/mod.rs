//! Dense f64 linear algebra (row-major [`Mat`]) — a thin layer over the
//! [`crate::kernel`] compute substrate.
//!
//! The paper's samplers and theory need: blocked GEMM (everything),
//! Householder thin-QR with sign correction (Algorithm 2, Haar–Stiefel),
//! a symmetric eigensolver (Algorithm 4, spectral decomposition of Σ),
//! and Frobenius/spectral norms (Proposition 1, eq. 12). We implement all
//! of it here rather than pulling a BLAS/LAPACK dependency: the estimator
//! stack must be auditable and deterministic across platforms.
//!
//! Since the kernel refactor this module owns **no dense loops of its
//! own**: GEMM/AXPY/scale/reductions live once in [`crate::kernel`]
//! (shared with the f32 training path) and run on the global kernel
//! pool; the QR panel updates and Jacobi sweeps use the kernel's
//! strided panel/rotation primitives, which are serial (the
//! factorizations' outer structure is inherently sequential). Either
//! way, results are bitwise-deterministic in the thread count.

mod ops;
mod qr;
mod eig;
mod chol;

pub use ops::*;
pub use qr::{orthonormality_defect, thin_qr, QrFactors};
pub use eig::{sym_eig, EigDecomp};
pub use chol::cholesky;

/// Dense row-major f64 matrix.
///
/// Row-major is the layout the training stack (f32 tensors fed to PJRT)
/// uses as well, so index arithmetic is uniform across the crate.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Matrix from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = d[i];
        }
        m
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Extract column `j` as a vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Is this matrix square?
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Main diagonal.
    pub fn diagonal(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.get(i, i)).collect()
    }

    /// Trace (sum of the main diagonal); requires square.
    pub fn trace(&self) -> f64 {
        assert!(self.is_square(), "trace of non-square matrix");
        (0..self.rows).map(|i| self.get(i, i)).sum()
    }

    /// Squared Frobenius norm ‖A‖_F².
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum()
    }

    /// Frobenius norm ‖A‖_F.
    pub fn fro_norm(&self) -> f64 {
        self.fro_norm_sq().sqrt()
    }

    /// Max |entry| difference against another matrix (for tests).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// In-place scale by a scalar (kernel substrate).
    pub fn scale_inplace(&mut self, s: f64) {
        crate::kernel::auto::scale(&mut self.data, s);
    }

    /// Return a scaled copy.
    pub fn scaled(&self, s: f64) -> Mat {
        let mut m = self.clone();
        m.scale_inplace(s);
        m
    }

    /// self += s * other (axpy, kernel substrate).
    pub fn axpy_inplace(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        crate::kernel::auto::axpy(s, &other.data, &mut self.data);
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_trace_is_n() {
        assert_eq!(Mat::eye(7).trace(), 7.0);
    }

    #[test]
    fn from_fn_indexing() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.get(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn fro_norm_matches_manual() {
        let m = Mat::from_rows(2, 2, &[3.0, 0.0, 0.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn axpy_and_sub() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let mut b = Mat::zeros(2, 2);
        b.axpy_inplace(2.0, &a);
        assert_eq!(b.get(1, 1), 8.0);
        let d = b.sub(&a);
        assert_eq!(d.data, a.data);
    }

    #[test]
    fn diag_builds_diagonal() {
        let d = Mat::diag(&[1.0, 2.0, 3.0]);
        assert_eq!(d.trace(), 6.0);
        assert_eq!(d.get(0, 1), 0.0);
        assert_eq!(d.diagonal(), vec![1.0, 2.0, 3.0]);
    }
}
