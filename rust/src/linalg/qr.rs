//! Householder thin-QR factorization with the sign correction from
//! Algorithm 2 (Haar–Stiefel sampler).
//!
//! Given G ∈ ℝ^{n×r} (n ≥ r), produce Q ∈ ℝ^{n×r} with orthonormal
//! columns and upper-triangular R ∈ ℝ^{r×r} with **positive diagonal**.
//! The positive-diagonal normalization removes the QR sign ambiguity:
//! only then is Q exactly Haar-distributed on the Stiefel manifold when G
//! has i.i.d. Gaussian entries (Stewart 1980; paper Algorithm 2, step 3).

use super::{ops, Mat};
use crate::kernel;

/// Result of [`thin_qr`].
pub struct QrFactors {
    /// n×r, orthonormal columns, QᵀQ = I_r.
    pub q: Mat,
    /// r×r upper triangular with non-negative diagonal.
    pub r: Mat,
}

/// Thin QR via Householder reflections; O(n r²).
pub fn thin_qr(g: &Mat) -> QrFactors {
    let (n, r) = (g.rows, g.cols);
    assert!(n >= r, "thin_qr requires n >= r (got {n} < {r})");
    // Work on a copy that becomes R in its upper triangle while we store
    // the Householder vectors in the lower part (classic compact scheme).
    let mut a = g.clone();
    // Householder vectors (each of length n, but zero above its pivot).
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(r);

    for k in 0..r {
        // Build the reflector for column k, rows k..n.
        let mut norm_sq = 0.0;
        for i in k..n {
            let x = a.get(i, k);
            norm_sq += x * x;
        }
        let norm = norm_sq.sqrt();
        let mut v = vec![0.0; n];
        if norm > 0.0 {
            let akk = a.get(k, k);
            let alpha = if akk >= 0.0 { -norm } else { norm };
            v[k] = akk - alpha;
            for i in (k + 1)..n {
                v[i] = a.get(i, k);
            }
            let vnorm_sq: f64 = v[k..].iter().map(|x| x * x).sum();
            if vnorm_sq > 0.0 {
                // Apply H = I − 2vvᵀ/‖v‖² to the panel A[k.., k..]:
                // w = Aᵀv, scale by 2/‖v‖², then the rank-1 downdate —
                // both through the kernel's strided panel primitives.
                let mut w = vec![0.0; r - k];
                kernel::gemv_t_strided(&a.data, r, k, k, n - k, r - k, &v[k..], &mut w);
                for wj in &mut w {
                    *wj = 2.0 * *wj / vnorm_sq;
                }
                kernel::ger_sub_strided(&mut a.data, r, k, k, n - k, r - k, &v[k..], &w);
            }
        }
        vs.push(v);
    }

    // R = upper triangle of the transformed A.
    let mut rmat = Mat::zeros(r, r);
    for i in 0..r {
        for j in i..r {
            rmat.set(i, j, a.get(i, j));
        }
    }

    // Q = H_0 H_1 … H_{r-1} · [I_r; 0]  (apply reflectors in reverse to
    // the thin identity).
    let mut q = Mat::zeros(n, r);
    for i in 0..r {
        q.set(i, i, 1.0);
    }
    for k in (0..r).rev() {
        let v = &vs[k];
        let vnorm_sq: f64 = v[k..].iter().map(|x| x * x).sum();
        if vnorm_sq == 0.0 {
            continue;
        }
        // Same panel update, applied to all r columns of Q.
        let mut w = vec![0.0; r];
        kernel::gemv_t_strided(&q.data, r, k, 0, n - k, r, &v[k..], &mut w);
        for wj in &mut w {
            *wj = 2.0 * *wj / vnorm_sq;
        }
        kernel::ger_sub_strided(&mut q.data, r, k, 0, n - k, r, &v[k..], &w);
    }

    // Sign fix (Algorithm 2 step 3): D = diag(sgn(diag(R))), Q ← QD, R ← DR.
    for k in 0..r {
        if rmat.get(k, k) < 0.0 {
            for i in 0..n {
                let val = -q.get(i, k);
                q.set(i, k, val);
            }
            for j in k..r {
                let val = -rmat.get(k, j);
                rmat.set(k, j, val);
            }
        }
    }

    QrFactors { q, r: rmat }
}

/// Orthonormality defect ‖QᵀQ − I‖_F (test/diagnostic helper).
pub fn orthonormality_defect(q: &Mat) -> f64 {
    let gram = ops::matmul_tn(q, q);
    gram.sub(&Mat::eye(q.cols)).fro_norm()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::{matmul, transpose};

    fn arb(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(5);
        Mat::from_fn(rows, cols, |_, _| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as f64) / (u32::MAX as f64) - 0.5
        })
    }

    #[test]
    fn qr_reconstructs_input() {
        for &(n, r) in &[(5, 3), (20, 7), (64, 8), (100, 4), (6, 6)] {
            let g = arb(n, r, n as u64 * 31 + r as u64);
            let f = thin_qr(&g);
            let rec = matmul(&f.q, &f.r);
            assert!(rec.max_abs_diff(&g) < 1e-9, "reconstruction failed at {n}x{r}");
        }
    }

    #[test]
    fn q_has_orthonormal_columns() {
        let g = arb(50, 10, 17);
        let f = thin_qr(&g);
        assert!(orthonormality_defect(&f.q) < 1e-10);
    }

    #[test]
    fn r_is_upper_triangular_with_positive_diagonal() {
        let g = arb(30, 6, 23);
        let f = thin_qr(&g);
        for i in 0..6 {
            assert!(f.r.get(i, i) > 0.0, "diag({i}) = {}", f.r.get(i, i));
            for j in 0..i {
                assert!(f.r.get(i, j).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn square_qr_gives_orthogonal_q() {
        let g = arb(12, 12, 29);
        let f = thin_qr(&g);
        let qtq = matmul(&transpose(&f.q), &f.q);
        assert!(qtq.max_abs_diff(&Mat::eye(12)) < 1e-10);
    }

    #[test]
    fn qr_of_orthonormal_input_is_identity_r() {
        // Q of a previous QR is orthonormal; its QR must give R = I.
        let g = arb(25, 5, 41);
        let q = thin_qr(&g).q;
        let f2 = thin_qr(&q);
        assert!(f2.r.max_abs_diff(&Mat::eye(5)) < 1e-9);
    }

    #[test]
    fn rank_deficient_column_handled() {
        // second column = 2 × first column → R[1,1] ≈ 0, no NaNs.
        let mut g = Mat::zeros(8, 2);
        for i in 0..8 {
            g.set(i, 0, (i + 1) as f64);
            g.set(i, 1, 2.0 * (i + 1) as f64);
        }
        let f = thin_qr(&g);
        assert!(f.q.data.iter().all(|v| v.is_finite()));
        assert!(f.r.get(1, 1).abs() < 1e-9);
        assert!(matmul(&f.q, &f.r).max_abs_diff(&g) < 1e-9);
    }
}
