//! Deterministic RNG substrate.
//!
//! Everything random in the crate — projection sampling, toy-problem
//! noise, corpus generation, parameter init — flows through this module
//! so every experiment is reproducible from a single `u64` seed. We use
//! xoshiro256++ (Blackman–Vigna) seeded via SplitMix64, polar Box–Muller
//! for normals, and a bounded-rejection Zipf sampler for the corpus.

mod normal;
mod zipf;

pub use normal::NormalSource;
pub use zipf::Zipf;

/// SplitMix64 step — used to expand a user seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 (SplitMix64 expansion; never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-worker RNGs in the
    /// DDP data pipeline): hash the parent's next output with a stream
    /// id. Forking advances the parent, so every rank of a distributed
    /// run must fork the full global stream set in the same order to
    /// stay in lockstep (see `BatchProducer::spawn_lm_slice`).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xD1B54A32D192ED03);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) — Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal (polar Box–Muller via the shared cache-less path).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        normal::sample_polar(self)
    }

    /// Vector of n i.i.d. N(0,1).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample r distinct indices from [0, n) uniformly without
    /// replacement (partial Fisher–Yates; O(n) memory, O(r) swaps).
    pub fn sample_without_replacement(&mut self, n: usize, r: usize) -> Vec<usize> {
        assert!(r <= n, "cannot sample {r} from {n} without replacement");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..r {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(r);
        idx
    }

    /// Raw xoshiro256++ state (for checkpointing).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild from a captured state (bit-exact stream continuation).
    pub fn from_state(s: [u64; 4]) -> Self {
        assert!(s.iter().any(|&x| x != 0), "xoshiro state must not be all-zero");
        Rng { s }
    }

    /// Categorical draw from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Checkpointing: the xoshiro state vector *is* the stream position —
/// restoring it continues the exact sequence the saved run would have
/// produced.
impl crate::ckpt::Checkpointable for Rng {
    fn state_dict(&self) -> crate::ckpt::StateDict {
        let mut sd = crate::ckpt::StateDict::new();
        sd.put_u64s("xoshiro_state", &self.s);
        sd
    }

    fn load_state(&mut self, sd: &crate::ckpt::StateDict) -> anyhow::Result<()> {
        let s = sd.u64s("xoshiro_state")?;
        if s.len() != 4 {
            anyhow::bail!("rng state has {} words, expected 4", s.len());
        }
        if s.iter().all(|&x| x == 0) {
            anyhow::bail!("rng state is all-zero (invalid xoshiro state)");
        }
        self.s = [s[0], s[1], s[2], s[3]];
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_with_correct_mean() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_across_buckets() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 7];
        let n = 140_000;
        for _ in 0..n {
            counts[r.below(7) as usize] += 1;
        }
        let expect = n as f64 / 7.0;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
            m4 += x * x * x * x;
        }
        let nf = n as f64;
        assert!((m1 / nf).abs() < 0.01);
        assert!((m2 / nf - 1.0).abs() < 0.02);
        assert!((m4 / nf - 3.0).abs() < 0.15, "kurtosis {}", m4 / nf);
    }

    #[test]
    fn swr_returns_distinct_sorted_ok() {
        let mut r = Rng::new(17);
        for _ in 0..200 {
            let mut s = r.sample_without_replacement(20, 8);
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
            assert!(*s.last().unwrap() < 20);
        }
    }

    #[test]
    fn swr_marginals_uniform() {
        let mut r = Rng::new(19);
        let (n, k, trials) = (10, 3, 60_000);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in r.sample_without_replacement(n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 6.0 * expect.sqrt(), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(29);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[r.categorical(&w)] += 1;
        }
        for i in 0..3 {
            let expect = n as f64 * w[i] / 10.0;
            assert!((counts[i] as f64 - expect).abs() < 6.0 * expect.sqrt());
        }
    }

    #[test]
    fn checkpoint_roundtrip_continues_stream_bitwise() {
        use crate::ckpt::Checkpointable;
        let mut a = Rng::new(99);
        for _ in 0..57 {
            a.next_u64(); // advance to a mid-stream position
        }
        let sd = a.state_dict();
        let reference: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let mut b = Rng::new(0); // arbitrary state, fully overwritten
        b.load_state(&sd).unwrap();
        let resumed: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_eq!(reference, resumed);
    }

    #[test]
    fn state_accessors_roundtrip() {
        let mut a = Rng::new(7);
        a.next_u64();
        let mut b = Rng::from_state(a.state());
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut parent = Rng::new(31);
        let mut c1 = parent.fork(0);
        let mut c2 = parent.fork(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
