//! Zipf-distributed integer sampling for the synthetic corpus.
//!
//! The paper pretrains on OpenWebText; our substitute corpus (DESIGN.md
//! §2) needs realistic unigram skew. We precompute the normalized CDF of
//! p(k) ∝ k^(−s) over a finite vocabulary and invert it by binary search —
//! O(log V) per draw, exact.

use super::Rng;

/// Zipf(s) over ranks 1..=n (returned 0-indexed: 0..n).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "empty support");
        assert!(s > 0.0, "exponent must be positive");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn support(&self) -> usize {
        self.cdf.len()
    }

    /// Draw a 0-indexed rank.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // first index with cdf[i] >= u
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of rank k (0-indexed).
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one() {
        let z = Zipf::new(100, 1.1);
        let total: f64 = (0..100).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rank_one_is_most_frequent() {
        let z = Zipf::new(50, 1.0);
        let mut rng = Rng::new(7);
        let mut counts = vec![0usize; 50];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[1]);
        assert!(counts[1] > counts[5]);
        // empirical frequency of rank 1 ≈ pmf(0)
        let f0 = counts[0] as f64 / 50_000.0;
        assert!((f0 - z.pmf(0)).abs() < 0.01, "f0={f0} pmf={}", z.pmf(0));
    }

    #[test]
    fn heavier_tail_for_smaller_s() {
        let z_light = Zipf::new(1000, 2.0);
        let z_heavy = Zipf::new(1000, 0.8);
        // heavier tail ⇒ less mass on top rank
        assert!(z_heavy.pmf(0) < z_light.pmf(0));
    }

    #[test]
    fn samples_within_support() {
        let z = Zipf::new(10, 1.5);
        let mut rng = Rng::new(9);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 10);
        }
    }
}
