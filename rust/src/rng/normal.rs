//! Standard-normal sampling (Marsaglia polar method).
//!
//! The Gaussian baseline projector and the ZO perturbations Z ~ N(0, I)
//! draw millions of normals per experiment; the polar method needs no
//! transcendental `sin`/`cos` and accepts ~78.5% of candidate pairs.

use super::Rng;

/// One N(0,1) draw (discards the paired deviate — keeping `Rng` stateless
/// w.r.t. caching makes `fork()` semantics exact).
#[inline]
pub(super) fn sample_polar(rng: &mut Rng) -> f64 {
    loop {
        let u = 2.0 * rng.uniform() - 1.0;
        let v = 2.0 * rng.uniform() - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            return u * (-2.0 * s.ln() / s).sqrt();
        }
    }
}

/// A reusable source of N(mean, sd²) values.
#[derive(Clone, Debug)]
pub struct NormalSource {
    pub mean: f64,
    pub sd: f64,
}

impl NormalSource {
    pub fn standard() -> Self {
        NormalSource { mean: 0.0, sd: 1.0 }
    }

    pub fn new(mean: f64, sd: f64) -> Self {
        assert!(sd >= 0.0, "negative standard deviation");
        NormalSource { mean, sd }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.mean + self.sd * sample_polar(rng)
    }

    pub fn sample_vec(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifted_scaled_moments() {
        let mut rng = Rng::new(101);
        let src = NormalSource::new(2.0, 3.0);
        let n = 100_000;
        let xs = src.sample_vec(&mut rng, n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
        assert!((var - 9.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn tail_mass_roughly_gaussian() {
        let mut rng = Rng::new(103);
        let src = NormalSource::standard();
        let n = 200_000;
        let beyond2 = (0..n).filter(|_| src.sample(&mut rng).abs() > 2.0).count();
        let frac = beyond2 as f64 / n as f64;
        // P(|Z|>2) ≈ 0.0455
        assert!((frac - 0.0455).abs() < 0.004, "frac={frac}");
    }
}
