//! Figures 2–5: MSE versus sample size on the toy quadratic matrix
//! regression (paper §6.1; m = n = 100, o = 30).
//!
//! * Figures 2 (LR) and 3 (IPA): *independent* setting — Gaussian vs
//!   Stiefel vs Coordinate at several values of c (the bias–variance
//!   trade-off: c < 1 curves plateau at the bias floor).
//! * Figures 4 (LR) and 5 (IPA): *dependent* setting — adds the
//!   Algorithm-4 sampler, which sits uniformly below the independent
//!   laws.
//!
//! Each curve's replications fan out across the kernel pool (see
//! [`mse_curve`]): one pre-forked child stream + engine per rep, so the
//! CSV this harness writes is bitwise identical at any `--threads`.

use std::io::Write;

use anyhow::Result;

use crate::estimator::mse::{mse_curve, EstimatorSpec, MseCurve, MseCurveConfig};
use crate::estimator::toy::ToyProblem;
use crate::estimator::Family;
use crate::projection::ProjectorKind;

/// Harness options.
#[derive(Clone, Debug)]
pub struct ToyMseOptions {
    pub family: Family,
    /// false → Figures 2/3 (independent laws); true → Figures 4/5
    /// (adds the dependent sampler).
    pub dependent: bool,
    pub c_grid: Vec<f64>,
    pub rank: usize,
    pub sample_sizes: Vec<usize>,
    pub reps: usize,
    pub seed: u64,
}

impl ToyMseOptions {
    pub fn paper(family: Family, dependent: bool) -> Self {
        ToyMseOptions {
            family,
            dependent,
            c_grid: vec![0.1, 0.4, 0.7, 1.0],
            rank: 4,
            sample_sizes: vec![10, 20, 50, 100, 200, 500],
            reps: 30,
            seed: 2026,
        }
    }

    pub fn quick(family: Family, dependent: bool) -> Self {
        ToyMseOptions {
            c_grid: vec![0.4, 1.0],
            sample_sizes: vec![10, 50, 200],
            reps: 8,
            ..Self::paper(family, dependent)
        }
    }
}

fn specs_for(dependent: bool) -> Vec<EstimatorSpec> {
    let mut v = vec![
        EstimatorSpec::FullRank,
        EstimatorSpec::LowRank(ProjectorKind::Gaussian),
        EstimatorSpec::LowRank(ProjectorKind::Stiefel),
        EstimatorSpec::LowRank(ProjectorKind::Coordinate),
    ];
    if dependent {
        v.push(EstimatorSpec::LowRank(ProjectorKind::Dependent));
    }
    v
}

/// Run the harness: prints paper-style series, writes one CSV.
pub fn run(opts: &ToyMseOptions, out_csv: &std::path::Path) -> Result<Vec<MseCurve>> {
    let problem = ToyProblem::paper_default(opts.seed);
    let w = problem.eval_point(opts.seed + 1);
    let fig = match (opts.family, opts.dependent) {
        (Family::Lr, false) => "Figure 2",
        (Family::Ipa, false) => "Figure 3",
        (Family::Lr, true) => "Figure 4",
        (Family::Ipa, true) => "Figure 5",
    };
    println!("== {fig}: toy MSE vs samples ({} family, {} setting) ==",
        opts.family.name(),
        if opts.dependent { "dependent" } else { "independent" });
    println!("   m=n={}, o={}, r={}, reps={}", problem.m, problem.o, opts.rank, opts.reps);

    let mut curves = Vec::new();
    for &c in &opts.c_grid {
        for spec in specs_for(opts.dependent) {
            // full-rank baseline is c-independent: only run it once
            if spec == EstimatorSpec::FullRank && c != *opts.c_grid.last().unwrap() {
                continue;
            }
            let cfg = MseCurveConfig {
                family: opts.family,
                spec,
                c,
                r: opts.rank,
                sample_sizes: opts.sample_sizes.clone(),
                reps: opts.reps,
                seed: opts.seed,
                zo_sigma: 1e-2,
                warmup: 300,
            };
            let curve = mse_curve(&problem, &w, &cfg);
            let pts: Vec<String> = curve
                .points
                .iter()
                .map(|(n, m)| format!("N={n}:{m:.3e}"))
                .collect();
            println!("  c={c:<4} {:<22} {}", curve.label, pts.join("  "));
            curves.push(curve);
        }
    }

    let mut f = std::fs::File::create(out_csv)?;
    writeln!(f, "family,label,c,samples,mse")?;
    for curve in &curves {
        for (n, m) in &curve.points {
            writeln!(f, "{},{},{},{},{}", opts.family.name(), curve.label, curve.c, n, m)?;
        }
    }
    println!("  wrote {}", out_csv.display());
    Ok(curves)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_expected_curve_count() {
        let opts = ToyMseOptions {
            reps: 3,
            sample_sizes: vec![5, 20],
            c_grid: vec![1.0],
            ..ToyMseOptions::quick(Family::Ipa, true)
        };
        let dir = std::env::temp_dir().join("lowrank_sge_toymse_test");
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("fig.csv");
        let curves = run(&opts, &csv).unwrap();
        // 1 c-value × (full + gaussian + stiefel + coordinate + dependent)
        assert_eq!(curves.len(), 5);
        let text = std::fs::read_to_string(&csv).unwrap();
        assert!(text.lines().count() > 5);
    }
}
