//! Experiment harnesses — one per table/figure of the paper's §6 (the
//! per-experiment index lives in DESIGN.md §4).
//!
//! | harness | regenerates |
//! |---------|-------------|
//! | [`toy_mse`]   | Figures 2–5 (toy MSE vs samples, LR/IPA × independent/dependent) |
//! | [`finetune`]  | Table 1 (accuracy) + Figure 6 (loss curves) + Table 3 (per-step time) |
//! | [`memory`]    | Table 2 (peak-memory accounting) |
//! | [`pretrain`]  | Figures 7–9 (Stiefel vs Gaussian LowRank-IPA loss curves per scale) |
//!
//! Every harness prints the paper-style rows/series to stdout and writes
//! CSV series under `results/`.

pub mod ablation;
pub mod diagnostics;
pub mod finetune;
pub mod memory;
pub mod pretrain;
pub mod toy_mse;

use std::path::PathBuf;

/// Default results directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}
