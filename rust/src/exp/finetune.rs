//! Table 1 (accuracy on six tasks), Figure 6 (Stiefel-vs-Gaussian loss
//! curves) and Table 3 (per-step wall-clock) from the fine-tuning
//! trainer.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::{FinetuneConfig, FinetuneMethod, FinetuneTrainer};
use crate::data::TASKS;
use crate::projection::ProjectorKind;
use crate::runtime::Runtime;

#[derive(Clone, Debug)]
pub struct FinetuneOptions {
    pub steps: u64,
    pub k_interval: u64,
    pub seed: u64,
    pub tasks: Vec<String>,
    pub ipa_lr: f32,
    pub zo_lr: f32,
}

impl FinetuneOptions {
    pub fn paper() -> Self {
        FinetuneOptions {
            steps: 400,
            k_interval: 50,
            seed: 2026,
            tasks: TASKS.iter().map(|t| t.name.to_string()).collect(),
            ipa_lr: 1e-3,
            zo_lr: 2e-3,
        }
    }

    pub fn quick() -> Self {
        FinetuneOptions { steps: 60, k_interval: 20, tasks: vec!["sst2".into(), "trec".into()], ..Self::paper() }
    }
}

/// Table 1: the 6-method × N-task accuracy matrix. Also writes the
/// Figure-6 loss curves (Stiefel vs Gaussian LowRank-LR per task) and
/// the Table-3 per-step timings measured from the same runs.
pub fn run(
    rt: &mut Runtime,
    artifacts_dir: &Path,
    opts: &FinetuneOptions,
    results_dir: &Path,
) -> Result<()> {
    let methods = FinetuneMethod::table1_rows();
    println!("== Table 1: fine-tuning accuracy (%) over {} steps ==", opts.steps);
    print!("{:<24}", "method");
    for t in &opts.tasks {
        print!("{t:>8}");
    }
    println!();

    let mut acc_csv = std::fs::File::create(results_dir.join("table1_accuracy.csv"))?;
    writeln!(acc_csv, "method,task,accuracy,steps")?;
    let mut time_rows: Vec<(String, f64)> = Vec::new();

    for method in &methods {
        print!("{:<24}", method.name());
        let mut times = Vec::new();
        for task in &opts.tasks {
            let cfg = FinetuneConfig {
                task: task.clone(),
                method: *method,
                steps: opts.steps,
                k_interval: opts.k_interval,
                ipa_lr: opts.ipa_lr,
                zo_lr: opts.zo_lr,
                sigma: 1e-2,
                c: 1.0,
                seed: opts.seed,
                eval_examples: 256,
                threads: 0,
                ckpt: Default::default(),
                track_refresh: 0,
            };
            let mut trainer = FinetuneTrainer::new(rt, artifacts_dir, cfg)?;
            let res = trainer.run()?;
            print!("{:>8.1}", res.accuracy * 100.0);
            std::io::stdout().flush()?;
            writeln!(acc_csv, "{},{},{},{}", method.name(), task, res.accuracy, opts.steps)?;
            if let Some(t) = res.log.mean_step_time(3) {
                times.push(t);
            }
            // Figure 6 inputs: per-task loss curves for the LR samplers
            if matches!(
                method,
                FinetuneMethod::LowRankLr(ProjectorKind::Stiefel)
                    | FinetuneMethod::LowRankLr(ProjectorKind::Gaussian)
            ) {
                res.log.write_csv(
                    &results_dir.join(format!("fig6_{}_{}.csv", task, method.name())),
                )?;
            }
        }
        println!();
        if !times.is_empty() {
            time_rows.push((
                method.name(),
                times.iter().sum::<f64>() / times.len() as f64,
            ));
        }
    }

    // Table 3: per-step wall-clock (paper: vanilla IPA 0.784s, LowRank-
    // IPA 0.787s, vanilla LR 0.468s, LowRank-LR 0.493s — at GPU scale;
    // here the proxy-scale analogue, same ordering claim: LR < IPA).
    println!("== Table 3: per-step wall-clock time (s, proxy scale) ==");
    let mut t3 = std::fs::File::create(results_dir.join("table3_time.csv"))?;
    writeln!(t3, "method,step_time_s")?;
    for (name, t) in &time_rows {
        println!("{name:<24} {t:>10.4}");
        writeln!(t3, "{name},{t}")?;
    }
    println!(
        "  wrote {} and {}",
        results_dir.join("table1_accuracy.csv").display(),
        results_dir.join("table3_time.csv").display()
    );
    Ok(())
}

/// Figure 6 standalone: Stiefel vs Gaussian LowRank-LR training-loss
/// series on every task (longer horizon than the Table-1 pass).
pub fn run_curves(
    rt: &mut Runtime,
    artifacts_dir: &Path,
    opts: &FinetuneOptions,
    results_dir: &Path,
) -> Result<()> {
    println!("== Figure 6: Stiefel vs Gaussian LowRank-LR loss curves ==");
    for task in &opts.tasks {
        for kind in [ProjectorKind::Stiefel, ProjectorKind::Gaussian] {
            let cfg = FinetuneConfig {
                task: task.clone(),
                method: FinetuneMethod::LowRankLr(kind),
                steps: opts.steps,
                k_interval: opts.k_interval,
                ipa_lr: opts.ipa_lr,
                zo_lr: opts.zo_lr,
                sigma: 1e-2,
                c: 1.0,
                seed: opts.seed,
                eval_examples: 128,
                threads: 0,
                ckpt: Default::default(),
                track_refresh: 0,
            };
            let mut trainer = FinetuneTrainer::new(rt, artifacts_dir, cfg)?;
            let res = trainer.run()?;
            let path = results_dir.join(format!("fig6_{}_{}.csv", task, kind.name()));
            res.log.write_csv(&path)?;
            println!(
                "  {task:<6} {:<22} final-loss {:.4}  acc {:.3}  → {}",
                format!("{}-lowrank-lr", kind.name()),
                res.log.tail_mean_loss(10).unwrap_or(f32::NAN),
                res.accuracy,
                path.display()
            );
        }
    }
    Ok(())
}
