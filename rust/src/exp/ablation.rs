//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **K (lazy-update interval)** — the exploration/exploitation knob of
//!   §4.2: K = 1 resamples every step (max exploration, max projection
//!   variance and per-step QR cost), large K over-commits to one
//!   subspace.
//! * **c (weak-unbiasedness scale)** — Remark 1's bias/variance dial.
//! * **projector law** — the headline comparison, at matched budget.
//! * **subspace tracking** — fresh Haar draw every resample vs the
//!   warm-started tracked refresh (`--track-refresh`): same Theorem-2
//!   guarantee, cheaper boundary; the cells show the loss is on par
//!   while the resample cost drops.
//!
//! Each cell is a short pretraining run from identical Θ₀/data; the
//! reported metric is the tail-mean training loss.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::{PretrainConfig, PretrainTrainer};
use crate::projection::ProjectorKind;
use crate::runtime::Runtime;

#[derive(Clone, Debug)]
pub struct AblationOptions {
    pub steps: u64,
    pub seed: u64,
    pub k_grid: Vec<u64>,
    pub c_grid: Vec<f64>,
}

impl Default for AblationOptions {
    fn default() -> Self {
        AblationOptions {
            steps: 100,
            seed: 2026,
            k_grid: vec![1, 5, 25, 100],
            c_grid: vec![0.5, 1.0],
        }
    }
}

fn one_run(
    rt: &mut Runtime,
    dir: &Path,
    sampler: ProjectorKind,
    k: u64,
    c: f64,
    track_refresh: u64,
    opts: &AblationOptions,
) -> Result<(f32, f64)> {
    let cfg = PretrainConfig {
        scale: "s".into(),
        sampler,
        c,
        k_interval: k,
        steps: opts.steps,
        lr: 2e-3,
        warmup: 5,
        clip: 1.0,
        weight_decay: 0.05,
        seed: opts.seed,
        workers: 1,
        eval_every: 0,
        eval_batches: 1,
        threads: 0,
        ckpt: Default::default(),
        track_refresh,
        rank_adapt: None,
    };
    let mut t = PretrainTrainer::new(rt, dir, cfg)?;
    let res = t.run()?;
    Ok((
        res.log.tail_mean_loss(10).unwrap_or(f32::NAN),
        res.log.mean_step_time(3).unwrap_or(f64::NAN),
    ))
}

pub fn run(
    rt: &mut Runtime,
    artifacts_dir: &Path,
    opts: &AblationOptions,
    out_csv: &Path,
) -> Result<()> {
    let mut f = std::fs::File::create(out_csv)?;
    writeln!(f, "axis,sampler,k,c,track,tail_loss,step_s")?;

    println!("== ablation: lazy-update interval K (Stiefel, c=1, {} steps) ==", opts.steps);
    for &k in &opts.k_grid {
        let (loss, step_s) = one_run(rt, artifacts_dir, ProjectorKind::Stiefel, k, 1.0, 0, opts)?;
        println!("  K = {k:<4} tail loss {loss:.4}  step {step_s:.3}s");
        writeln!(f, "k,stiefel,{k},1.0,0,{loss},{step_s}")?;
    }

    println!("== ablation: weak-unbiasedness scale c (Stiefel, K=25) ==");
    for &c in &opts.c_grid {
        let (loss, step_s) = one_run(rt, artifacts_dir, ProjectorKind::Stiefel, 25, c, 0, opts)?;
        println!("  c = {c:<4} tail loss {loss:.4}  step {step_s:.3}s");
        writeln!(f, "c,stiefel,25,{c},0,{loss},{step_s}")?;
    }

    println!("== ablation: projector law (K=25, c=1) ==");
    for kind in [
        ProjectorKind::Stiefel,
        ProjectorKind::Coordinate,
        ProjectorKind::Gaussian,
    ] {
        let (loss, step_s) = one_run(rt, artifacts_dir, kind, 25, 1.0, 0, opts)?;
        println!("  {:<10} tail loss {loss:.4}  step {step_s:.3}s", kind.name());
        writeln!(f, "law,{},25,1.0,0,{loss},{step_s}", kind.name())?;
    }

    println!("== ablation: subspace tracking (Stiefel, K=25, c=1) ==");
    for track in [0u64, 8] {
        let (loss, step_s) = one_run(rt, artifacts_dir, ProjectorKind::Stiefel, 25, 1.0, track, opts)?;
        let label = if track == 0 { "fresh".to_string() } else { format!("tracked/{track}") };
        println!("  {label:<10} tail loss {loss:.4}  step {step_s:.3}s");
        writeln!(f, "track,stiefel,25,1.0,{track},{loss},{step_s}")?;
    }

    println!("  wrote {}", out_csv.display());
    Ok(())
}
