//! Gradient effective-rank diagnostic — the paper's §1 motivating
//! observation ("the gradient of a value matrix with dimensions
//! 1024×1024 typically exhibits only around 10 dominant eigenvalues",
//! after Zhao et al. 2024).
//!
//! We execute the full-BP classifier artifact, pull the exact weight
//! gradients, and report each matrix's singular-value concentration:
//! effective rank (90% / 99% energy) and the dominant-λ count. The
//! claim reproduced: effective rank ≪ min(m, n) for attention/MLP
//! gradients — the premise that makes rank-r projection sensible.

use std::io::Write;

use anyhow::Result;

use crate::linalg::{matmul_tn, sym_eig, Mat};
use crate::runtime::Runtime;

/// Spectrum summary for one gradient matrix.
#[derive(Clone, Debug)]
pub struct RankReport {
    pub name: String,
    pub m: usize,
    pub n: usize,
    /// singular values, descending
    pub singular_values: Vec<f64>,
    pub rank90: usize,
    pub rank99: usize,
    /// #{i : σ_i ≥ 0.1·σ_1} — the "dominant eigenvalues" count.
    pub dominant: usize,
}

/// Singular values of a (f32) gradient via eig(GᵀG).
pub fn gradient_spectrum(g: &[f32], m: usize, n: usize) -> Vec<f64> {
    let g64 = Mat::from_fn(m, n, |i, j| g[i * n + j] as f64);
    let gtg = matmul_tn(&g64, &g64);
    sym_eig(&gtg)
        .values
        .into_iter()
        .map(|l| l.max(0.0).sqrt())
        .collect()
}

/// Effective-rank statistics from a singular-value profile.
pub fn rank_report(name: &str, m: usize, n: usize, sv: Vec<f64>) -> RankReport {
    let total_energy: f64 = sv.iter().map(|s| s * s).sum();
    let mut cum = 0.0;
    let (mut rank90, mut rank99) = (sv.len(), sv.len());
    for (i, s) in sv.iter().enumerate() {
        cum += s * s;
        if rank90 == sv.len() && cum >= 0.90 * total_energy {
            rank90 = i + 1;
        }
        if rank99 == sv.len() && cum >= 0.99 * total_energy {
            rank99 = i + 1;
        }
    }
    let s1 = sv.first().copied().unwrap_or(0.0);
    let dominant = sv.iter().filter(|&&s| s >= 0.1 * s1).count();
    RankReport { name: name.to_string(), m, n, singular_values: sv, rank90, rank99, dominant }
}

/// Run the diagnostic on the full-BP classifier gradients.
pub fn run(rt: &mut Runtime, out_csv: &std::path::Path) -> Result<Vec<RankReport>> {
    println!("== gradient effective-rank (paper §1 motivating observation) ==");
    let art = rt.load("clf_ipa_grad")?;
    let inputs = rt.golden_inputs(&art)?;
    let out = art.execute(&inputs)?;

    let mut reports = Vec::new();
    let mut f = std::fs::File::create(out_csv)?;
    writeln!(f, "matrix,m,n,rank90,rank99,dominant,sigma1")?;
    println!(
        "{:<16} {:>9} {:>7} {:>7} {:>9}  (min(m,n))",
        "matrix", "shape", "rank90", "rank99", "dominant"
    );
    for (oi, spec) in art.manifest.outputs.iter().enumerate() {
        let Some(name) = spec.name.strip_prefix("out[1][").and_then(|s| s.strip_suffix(']'))
        else {
            continue;
        };
        if spec.shape.len() != 2 {
            continue;
        }
        let (m, n) = (spec.shape[0], spec.shape[1]);
        let sv = gradient_spectrum(out[oi].as_f32()?, m, n);
        let rep = rank_report(name, m, n, sv);
        println!(
            "{:<16} {:>4}x{:<4} {:>7} {:>7} {:>9}  ({})",
            rep.name,
            m,
            n,
            rep.rank90,
            rep.rank99,
            rep.dominant,
            m.min(n)
        );
        writeln!(
            f,
            "{},{},{},{},{},{},{}",
            rep.name, m, n, rep.rank90, rep.rank99, rep.dominant,
            rep.singular_values.first().unwrap_or(&0.0)
        )?;
        reports.push(rep);
    }

    // the headline: average rank90 / min-dim across attention+MLP
    let avg_frac: f64 = reports
        .iter()
        .map(|r| r.rank90 as f64 / r.m.min(r.n) as f64)
        .sum::<f64>()
        / reports.len().max(1) as f64;
    println!(
        "mean rank90/min(m,n) = {:.3} → gradients are effectively low-rank: {}",
        avg_frac,
        if avg_frac < 0.35 { "CONFIRMED" } else { "not confirmed" }
    );
    println!("  wrote {}", out_csv.display());
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectrum_of_exact_rank_one_matrix() {
        // G = u·vᵀ has a single nonzero singular value ‖u‖·‖v‖.
        let (m, n) = (6, 5);
        let u: Vec<f32> = (1..=m as i32).map(|i| i as f32).collect();
        let v: Vec<f32> = (1..=n as i32).map(|i| (i as f32) * 0.5).collect();
        let mut g = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                g[i * n + j] = u[i] * v[j];
            }
        }
        let sv = gradient_spectrum(&g, m, n);
        let nu = (u.iter().map(|x| (x * x) as f64).sum::<f64>()).sqrt();
        let nv = (v.iter().map(|x| (x * x) as f64).sum::<f64>()).sqrt();
        assert!((sv[0] - nu * nv).abs() / (nu * nv) < 1e-6);
        for &s in &sv[1..] {
            assert!(s < 1e-6 * sv[0]);
        }
        let rep = rank_report("r1", m, n, sv);
        assert_eq!(rep.rank90, 1);
        assert_eq!(rep.rank99, 1);
        assert_eq!(rep.dominant, 1);
    }

    #[test]
    fn full_rank_identity_has_flat_spectrum() {
        let n = 8;
        let mut g = vec![0.0f32; n * n];
        for i in 0..n {
            g[i * n + i] = 1.0;
        }
        let rep = rank_report("eye", n, n, gradient_spectrum(&g, n, n));
        assert_eq!(rep.dominant, n);
        assert!(rep.rank90 >= (0.9 * n as f64) as usize);
    }
}
