//! Figures 7–9: LLaMA-proxy pretraining, Stiefel vs Gaussian
//! LowRank-IPA at three scales (paper §6.2.2).
//!
//! The paper's claim: Stiefel LowRank-IPA sits below Gaussian
//! LowRank-IPA in both training and evaluation loss, at every scale,
//! with the gap widening over training. The harness runs both samplers
//! from the same Θ₀/data seed and writes the train/eval series.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::{PretrainConfig, PretrainTrainer};
use crate::projection::ProjectorKind;
use crate::runtime::Runtime;

#[derive(Clone, Debug)]
pub struct PretrainOptions {
    pub scale: String,
    pub steps: u64,
    pub k_interval: u64,
    pub lr: f32,
    pub seed: u64,
    pub workers: usize,
    pub eval_every: u64,
}

impl PretrainOptions {
    pub fn paper(scale: &str) -> Self {
        PretrainOptions {
            scale: scale.to_string(),
            steps: 300,
            k_interval: 25,
            lr: 2e-3,
            seed: 2026,
            workers: 1,
            eval_every: 25,
        }
    }

    pub fn quick(scale: &str) -> Self {
        PretrainOptions { steps: 60, k_interval: 15, eval_every: 20, ..Self::paper(scale) }
    }
}

/// Which paper figure a scale maps to.
pub fn figure_name(scale: &str) -> &'static str {
    match scale {
        "s" => "Figure 7 (LLaMA-20M proxy)",
        "m" => "Figure 8 (LLaMA-60M proxy)",
        "l" => "Figure 9 (LLaMA-100M proxy)",
        _ => "pretrain figure",
    }
}

pub fn run(
    rt: &mut Runtime,
    artifacts_dir: &Path,
    opts: &PretrainOptions,
    results_dir: &Path,
) -> Result<()> {
    println!("== {}: Stiefel vs Gaussian LowRank-IPA ==", figure_name(&opts.scale));
    let mut summary = std::fs::File::create(
        results_dir.join(format!("pretrain_{}_summary.csv", opts.scale)),
    )?;
    writeln!(summary, "sampler,final_train_loss,tail_train_loss,final_eval_loss,mean_step_s")?;

    let mut results = Vec::new();
    for kind in [ProjectorKind::Stiefel, ProjectorKind::Gaussian] {
        let cfg = PretrainConfig {
            scale: opts.scale.clone(),
            sampler: kind,
            c: 1.0,
            k_interval: opts.k_interval,
            steps: opts.steps,
            lr: opts.lr,
            warmup: (opts.steps / 20).max(2),
            clip: 1.0,
            weight_decay: 0.05,
            seed: opts.seed,
            workers: opts.workers,
            eval_every: opts.eval_every,
            eval_batches: 2,
            threads: 0,
            ckpt: Default::default(),
            // paper-figure fidelity: every resample is a fresh draw,
            // ranks stay fixed at the manifest values
            track_refresh: 0,
            rank_adapt: None,
        };
        let mut trainer = PretrainTrainer::new(rt, artifacts_dir, cfg)?;
        let res = trainer.run()?;
        let tail = res.log.tail_mean_loss(10).unwrap_or(f32::NAN);
        let step_s = res.log.mean_step_time(3).unwrap_or(f64::NAN);
        println!(
            "  {:<9} tail-train {:.4}  final-eval {:?}  step {:.3}s  (B elems {} vs params {})",
            kind.name(),
            tail,
            res.final_eval_loss,
            step_s,
            res.b_elements,
            res.params_elements
        );
        res.log.write_csv(&results_dir.join(format!(
            "pretrain_{}_{}_train.csv",
            opts.scale,
            kind.name()
        )))?;
        res.log.write_eval_csv(&results_dir.join(format!(
            "pretrain_{}_{}_eval.csv",
            opts.scale,
            kind.name()
        )))?;
        writeln!(
            summary,
            "{},{},{},{},{}",
            kind.name(),
            res.log.final_train_loss().unwrap_or(f32::NAN),
            tail,
            res.final_eval_loss.unwrap_or(f32::NAN),
            step_s
        )?;
        results.push((kind, tail, res.final_eval_loss));
    }

    // the paper's headline contrast
    if let [(_, stiefel_tail, _), (_, gaussian_tail, _)] = results.as_slice() {
        let verdict = if stiefel_tail < gaussian_tail { "REPRODUCED" } else { "NOT reproduced" };
        println!(
            "  paper claim (Stiefel < Gaussian): {verdict}  ({stiefel_tail:.4} vs {gaussian_tail:.4})"
        );
    }
    Ok(())
}
