//! Table 2: peak-memory profile of the four fine-tuning methods.
//!
//! Evaluated twice: (a) the analytical inventory at **true RoBERTa-large
//! dimensions** (the paper's setting — we cannot measure GPU peaks here,
//! DESIGN.md §2), and (b) the same inventory at our proxy scale, where
//! the artifact-driven runs actually execute. The reproduced claim is
//! the ordering and ratio structure; paper absolutes are printed
//! alongside for comparison.

use std::io::Write;

use anyhow::Result;

use crate::model::{MemoryBreakdown, MemoryModel, TrainMethod};
use crate::obs::TrackedAlloc;

/// Measured heap peak (MB) of actually materializing the proxy
/// inventory: bracket with the tracked allocator's peak gauge, allocate
/// every component as a real zeroed buffer, read the high-water delta.
/// Returns `None` when [`TrackedAlloc`] is not this process's global
/// allocator (library tests) — the table prints `-` there.
fn measured_proxy_peak_mb(bd: &MemoryBreakdown) -> Option<f64> {
    if !TrackedAlloc::installed() {
        return None;
    }
    TrackedAlloc::reset_peak();
    let base = TrackedAlloc::peak_bytes();
    let components =
        [bd.weights, bd.gradients, bd.optimizer_state, bd.activations, bd.perturbations, bd.logits];
    let mut bufs: Vec<Vec<u8>> = Vec::new();
    for &c in &components {
        if c > 0 {
            bufs.push(vec![0u8; c]);
        }
    }
    let peak = TrackedAlloc::peak_bytes();
    drop(bufs);
    Some(peak.saturating_sub(base) as f64 / (1 << 20) as f64)
}

/// Paper Table 2 (GB).
pub const PAPER_GB: [(TrainMethod, f64); 4] = [
    (TrainMethod::VanillaIpa, 16.7),
    (TrainMethod::LowRankIpa, 14.3),
    (TrainMethod::VanillaLr, 5.49),
    (TrainMethod::LowRankLr, 3.83),
];

pub fn run(out_csv: &std::path::Path) -> Result<Vec<(TrainMethod, f64)>> {
    println!("== Table 2: memory profile (RoBERTa-large fine-tuning) ==");
    let model = MemoryModel::roberta_large();
    println!(
        "   dims: L={} d={} ff={} vocab={} batch={} seq={} r={}",
        model.layers, model.d_model, model.d_ff, model.vocab, model.batch, model.seq, model.rank
    );
    println!(
        "{:<14} {:>10} {:>10} {:>9} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "method", "model(GB)", "paper(GB)", "weights", "grads", "optim", "acts", "perturb", "logits"
    );

    let mut rows = Vec::new();
    let mut f = std::fs::File::create(out_csv)?;
    writeln!(
        f,
        "method,scope,total_gb,weights_gb,grads_gb,optim_gb,acts_gb,perturb_gb,logits_gb,\
         paper_gb,measured_mb"
    )?;
    let gb = |x: usize| x as f64 / (1 << 30) as f64;
    for (method, paper) in PAPER_GB {
        let bd = model.breakdown(method);
        println!(
            "{:<14} {:>10.2} {:>10.2} {:>9.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            method.name(),
            bd.total_gb(),
            paper,
            gb(bd.weights),
            gb(bd.gradients),
            gb(bd.optimizer_state),
            gb(bd.activations),
            gb(bd.perturbations),
            gb(bd.logits)
        );
        writeln!(
            f,
            "{},roberta-large,{},{},{},{},{},{},{},{},",
            method.name(),
            bd.total_gb(),
            gb(bd.weights),
            gb(bd.gradients),
            gb(bd.optimizer_state),
            gb(bd.activations),
            gb(bd.perturbations),
            gb(bd.logits),
            paper
        )?;
        rows.push((method, bd.total_gb()));
    }

    // the proxy-scale inventory (what our artifact runs actually carry),
    // with a measured column beside the analytical one: the tracked
    // allocator's peak delta while the same inventory is materialized
    println!("-- proxy scale (clf artifacts): analytical vs measured --");
    println!("{:<14} {:>12} {:>12}", "method", "model(MB)", "measured(MB)");
    let proxy = MemoryModel::clf_proxy();
    for (method, _) in PAPER_GB {
        let bd = proxy.breakdown(method);
        let mb = bd.total() as f64 / (1 << 20) as f64;
        let measured = measured_proxy_peak_mb(&bd);
        println!(
            "{:<14} {:>12.2} {:>12}",
            method.name(),
            mb,
            measured.map(|m| format!("{m:.2}")).unwrap_or_else(|| "-".to_string())
        );
        writeln!(
            f,
            "{},clf-proxy,{},{},{},{},{},{},{},,{}",
            method.name(),
            bd.total_gb(),
            gb(bd.weights),
            gb(bd.gradients),
            gb(bd.optimizer_state),
            gb(bd.activations),
            gb(bd.perturbations),
            gb(bd.logits),
            measured.map(|m| format!("{m:.3}")).unwrap_or_default()
        )?;
    }
    // process-wide ledger footer: what this very run actually held
    if TrackedAlloc::installed() {
        println!(
            "  process: heap live {:.1} MB, peak {:.1} MB (tracked allocator); VmHWM {} MB",
            TrackedAlloc::live_bytes() as f64 / 1e6,
            TrackedAlloc::peak_bytes() as f64 / 1e6,
            crate::obs::alloc::vm_hwm_kb().unwrap_or(0) / 1024
        );
    } else {
        println!("  process: tracked allocator not installed (measured column unavailable)");
    }
    println!("  wrote {}", out_csv.display());
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_reproduces_ordering() {
        let dir = std::env::temp_dir().join("lowrank_sge_mem_test");
        std::fs::create_dir_all(&dir).unwrap();
        let rows = run(&dir.join("table2.csv")).unwrap();
        assert_eq!(rows.len(), 4);
        for w in rows.windows(2) {
            assert!(w[0].1 > w[1].1, "ordering violated: {rows:?}");
        }
    }
}
