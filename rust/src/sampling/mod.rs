//! Survey-sampling substrate for the instance-dependent projector.
//!
//! Algorithm 4 of the paper needs two things this module provides:
//!
//! 1. the **optimal inclusion probabilities** π* of Theorem 3 (eq. 17) —
//!    a √σ water-filling with saturation at 1 ([`inclusion`]);
//! 2. a **fixed-size unequal-probability sampling design** realizing
//!    Pr(i ∈ J) = π*_i with |J| = r exactly ([`designs`]): the paper cites
//!    conditional Poisson (Hájek 1964), Sampford (1967) and Tillé-style
//!    sequential schemes; we implement conditional Poisson (exact, via
//!    elementary-symmetric-polynomial DP), Sampford (rejective), and
//!    systematic PPS (Madow) as the fast default.

mod inclusion;
mod designs;
mod tille;

pub use inclusion::{optimal_inclusion, phi_min_over_c2, InclusionSolution, DEFAULT_SIGMA_FLOOR};
pub use designs::{
    conditional_poisson_calibrate, sample_conditional_poisson, sample_sampford,
    sample_sampford_bounded, sample_sampford_with_fallback, sample_systematic, CpsDesign,
    FixedSizeDesign, SampfordRejected, SAMPFORD_MAX_ATTEMPTS,
};
pub use tille::sample_tille;
