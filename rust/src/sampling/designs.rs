//! Fixed-size unequal-probability sampling designs (π-ps designs).
//!
//! Algorithm 4 step 3: "Sample a random subset J ⊂ {1,…,n} of fixed size
//! |J| = r such that Pr(i ∈ J) = π*_i, using any fixed-size
//! unequal-probability design (e.g., conditional Poisson, Sampford, or
//! Tillé's elimination)." We provide three:
//!
//! * **Conditional Poisson / rejective sampling** (Hájek 1964) — exact,
//!   implemented with the elementary-symmetric-polynomial DP both for the
//!   sequential sampler and for calibrating working weights so that the
//!   *conditional* inclusion probabilities hit the targets (Deville &
//!   Tillé 1998 fixed point).
//! * **Sampford's method** (1967) — rejective two-phase scheme; exact
//!   π-ps, simple, but the acceptance rate degrades as r → n.
//! * **Systematic PPS** (Madow) — exact marginals, O(n), the default in
//!   the training hot loop (order is randomized each draw to break joint
//!   inclusion artifacts).

use crate::rng::Rng;

/// Which fixed-size design to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FixedSizeDesign {
    ConditionalPoisson,
    Sampford,
    Systematic,
    Tille,
}

impl FixedSizeDesign {
    pub fn name(&self) -> &'static str {
        match self {
            FixedSizeDesign::ConditionalPoisson => "conditional-poisson",
            FixedSizeDesign::Sampford => "sampford",
            FixedSizeDesign::Systematic => "systematic",
            FixedSizeDesign::Tille => "tille",
        }
    }
}

fn validate_pi(pi: &[f64], r: usize) {
    let sum: f64 = pi.iter().sum();
    assert!(
        (sum - r as f64).abs() < 1e-6,
        "inclusion probabilities must sum to r: Σπ = {sum}, r = {r}"
    );
    for &p in pi {
        assert!(p > 0.0 && p <= 1.0 + 1e-9, "π_i must lie in (0,1], got {p}");
    }
}

// ---------------------------------------------------------------------------
// Systematic PPS (Madow)
// ---------------------------------------------------------------------------

/// Systematic π-ps sampling: cumulate π in a random order and take the r
/// points {u, u+1, …, u+r−1} for u ~ U(0,1). Exact fixed size, exact
/// first-order inclusion probabilities, O(n).
pub fn sample_systematic(pi: &[f64], r: usize, rng: &mut Rng) -> Vec<usize> {
    validate_pi(pi, r);
    let n = pi.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let u = rng.uniform();
    let mut selected = Vec::with_capacity(r);
    let mut cum = 0.0;
    let mut next_point = u;
    for &i in &order {
        let lo = cum;
        cum += pi[i];
        // select once for every integer-offset point in [lo, cum)
        while next_point < cum && selected.len() < r {
            debug_assert!(next_point >= lo - 1e-12);
            selected.push(i);
            next_point += 1.0;
        }
    }
    // guard against fp shortfall on the last unit
    while selected.len() < r {
        selected.push(order[n - 1]);
    }
    selected.sort_unstable();
    selected
}

// ---------------------------------------------------------------------------
// Sampford
// ---------------------------------------------------------------------------

/// Default rejection budget for [`sample_sampford_bounded`]. Generous —
/// typical (n, r) regimes accept within a handful of attempts — but
/// finite, so a degenerate target can never spin a training run forever.
pub const SAMPFORD_MAX_ATTEMPTS: usize = 10_000;

/// Sampford's rejective π-ps design with a bounded retry budget. Units
/// with π_i = 1 are forced into the sample and the scheme runs on the
/// remainder. Returns `Err` if no draw is accepted within
/// `max_attempts` — Sampford's acceptance rate degrades sharply as
/// r → n, so a bad (n, r) pair is a recoverable condition, not a panic.
pub fn sample_sampford_bounded(
    pi: &[f64],
    r: usize,
    rng: &mut Rng,
    max_attempts: usize,
) -> Result<Vec<usize>, SampfordRejected> {
    validate_pi(pi, r);
    let n = pi.len();
    let mut forced: Vec<usize> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    for i in 0..n {
        if pi[i] >= 1.0 - 1e-12 {
            forced.push(i);
        } else {
            free.push(i);
        }
    }
    let r_free = r - forced.len();
    if r_free == 0 {
        forced.sort_unstable();
        return Ok(forced);
    }
    // residual targets on the free units sum to r_free
    let p: Vec<f64> = free.iter().map(|&i| pi[i]).collect();
    let rf = r_free as f64;
    let w_first: Vec<f64> = p.iter().map(|&x| x / rf).collect();
    let w_rest: Vec<f64> = p.iter().map(|&x| x / (1.0 - x)).collect();

    for _ in 0..max_attempts {
        let mut draw: Vec<usize> = Vec::with_capacity(r_free);
        draw.push(rng.categorical(&w_first));
        for _ in 1..r_free {
            draw.push(rng.categorical(&w_rest));
        }
        let mut sorted = draw.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() == r_free {
            let mut out: Vec<usize> = forced;
            out.extend(sorted.into_iter().map(|k| free[k]));
            out.sort_unstable();
            return Ok(out);
        }
    }
    Err(SampfordRejected { n, r, attempts: max_attempts })
}

/// Every attempt in a [`sample_sampford_bounded`] call was rejected.
#[derive(Clone, Copy, Debug)]
pub struct SampfordRejected {
    pub n: usize,
    pub r: usize,
    pub attempts: usize,
}

impl std::fmt::Display for SampfordRejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Sampford rejection sampling exhausted {} attempts (n = {}, r = {}; \
             acceptance degrades as r → n)",
            self.attempts, self.n, self.r
        )
    }
}

impl std::error::Error for SampfordRejected {}

/// [`sample_sampford`] with an explicit retry budget (exposed for
/// tests and callers that want a tighter cap). Callers hitting the
/// fallback repeatedly should switch to [`conditional_poisson_calibrate`]
/// + [`sample_conditional_poisson`] directly: the fallback re-calibrates
/// on every draw, whereas a held [`CpsDesign`] amortizes that cost.
pub fn sample_sampford_with_fallback(
    pi: &[f64],
    r: usize,
    rng: &mut Rng,
    max_attempts: usize,
) -> Vec<usize> {
    match sample_sampford_bounded(pi, r, rng, max_attempts) {
        Ok(s) => s,
        Err(err) => {
            // loud once per process: the design silently changing would
            // invalidate any per-design analysis of the run
            static WARN_ONCE: std::sync::Once = std::sync::Once::new();
            WARN_ONCE.call_once(|| {
                eprintln!(
                    "warning: {err}; falling back to the calibrated conditional-Poisson \
                     design (same first-order inclusion probabilities) for this and any \
                     further exhausted draws"
                );
            });
            let design = conditional_poisson_calibrate(pi, r);
            sample_conditional_poisson(&design, rng)
        }
    }
}

/// Sampford's design with the production failure policy: bounded
/// rejection retries, then fall back to the calibrated
/// conditional-Poisson design (same first-order inclusion probabilities,
/// no rejection loop) so one degenerate (n, r) pair cannot kill a
/// long-running training job.
pub fn sample_sampford(pi: &[f64], r: usize, rng: &mut Rng) -> Vec<usize> {
    sample_sampford_with_fallback(pi, r, rng, SAMPFORD_MAX_ATTEMPTS)
}

// ---------------------------------------------------------------------------
// Conditional Poisson (rejective) with exact DP
// ---------------------------------------------------------------------------

/// Calibrated conditional-Poisson design: working weights `w` such that
/// the size-r conditional inclusion probabilities equal the targets.
#[derive(Clone, Debug)]
pub struct CpsDesign {
    /// Working weights w_i = p_i/(1−p_i) of the underlying Poisson design.
    pub weights: Vec<f64>,
    /// Target inclusion probabilities (forced units have π = 1).
    pub target_pi: Vec<f64>,
    /// Sample size.
    pub r: usize,
    forced: Vec<usize>,
    free: Vec<usize>,
}

/// Elementary symmetric polynomials e_0..e_r of `w` (DP, O(n·r)).
#[cfg(test)]
fn esp(w: &[f64], r: usize) -> Vec<f64> {
    let mut e = vec![0.0; r + 1];
    e[0] = 1.0;
    for &wi in w {
        for k in (1..=r).rev() {
            e[k] += wi * e[k - 1];
        }
    }
    e
}

/// CPS inclusion probabilities for working weights `w` at size `r`:
/// π_i(w) = w_i · e_{r−1}(w₋ᵢ) / e_r(w). Computed with the
/// "leave-one-out via forward/backward ESP" trick in O(n·r).
fn cps_inclusion(w: &[f64], r: usize) -> Vec<f64> {
    let n = w.len();
    // forward[i] = ESP of w[0..i] (vector of length r+1)
    let mut forward = Vec::with_capacity(n + 1);
    let mut cur = vec![0.0; r + 1];
    cur[0] = 1.0;
    forward.push(cur.clone());
    for &wi in w {
        for k in (1..=r).rev() {
            cur[k] += wi * cur[k - 1];
        }
        forward.push(cur.clone());
    }
    // backward[i] = ESP of w[i..n]
    let mut backward = vec![vec![0.0; r + 1]; n + 1];
    backward[n][0] = 1.0;
    for i in (0..n).rev() {
        let wi = w[i];
        for k in 0..=r {
            backward[i][k] = backward[i + 1][k]
                + if k > 0 { wi * backward[i + 1][k - 1] } else { 0.0 };
        }
    }
    let er = forward[n][r];
    assert!(er > 0.0, "degenerate CPS normalizer");
    // e_{r-1}(w₋ᵢ) = Σ_{a+b=r-1} forward[i][a] · backward[i+1][b]
    (0..n)
        .map(|i| {
            let mut s = 0.0;
            for a in 0..r {
                s += forward[i][a] * backward[i + 1][r - 1 - a];
            }
            w[i] * s / er
        })
        .collect()
}

/// Calibrate working weights so CPS inclusion probabilities match the
/// targets (Deville–Tillé fixed point: w ← w · π_target / π_current).
pub fn conditional_poisson_calibrate(pi: &[f64], r: usize) -> CpsDesign {
    validate_pi(pi, r);
    let n = pi.len();
    let mut forced = Vec::new();
    let mut free = Vec::new();
    for i in 0..n {
        if pi[i] >= 1.0 - 1e-12 {
            forced.push(i);
        } else {
            free.push(i);
        }
    }
    let r_free = r - forced.len();
    let targets: Vec<f64> = free.iter().map(|&i| pi[i]).collect();
    let mut w: Vec<f64> = targets.iter().map(|&p| p / (1.0 - p)).collect();
    if r_free > 0 {
        for _iter in 0..200 {
            let cur = cps_inclusion(&w, r_free);
            let mut max_err = 0.0f64;
            for i in 0..w.len() {
                max_err = max_err.max((cur[i] - targets[i]).abs());
                // multiplicative update; clamp to keep weights positive
                let ratio = (targets[i] / cur[i].max(1e-300)).clamp(1e-6, 1e6);
                w[i] *= ratio;
            }
            if max_err < 1e-12 {
                break;
            }
        }
    }
    CpsDesign { weights: w, target_pi: pi.to_vec(), r, forced, free }
}

/// Draw one sample from a calibrated CPS design using the sequential
/// conditional method: unit i is included with probability
/// w_i · e_{k−1}(w_{i+1..}) / e_k(w_{i..}) given k slots remain.
pub fn sample_conditional_poisson(design: &CpsDesign, rng: &mut Rng) -> Vec<usize> {
    let r_free = design.r - design.forced.len();
    let mut out = design.forced.clone();
    if r_free > 0 {
        let w = &design.weights;
        let n = w.len();
        // backward ESP table over the free units
        let mut backward = vec![vec![0.0; r_free + 1]; n + 1];
        backward[n][0] = 1.0;
        for i in (0..n).rev() {
            for k in 0..=r_free {
                backward[i][k] = backward[i + 1][k]
                    + if k > 0 { w[i] * backward[i + 1][k - 1] } else { 0.0 };
            }
        }
        let mut k = r_free;
        for i in 0..n {
            if k == 0 {
                break;
            }
            // Pr(include i | k slots remain among units i..n)
            let denom = backward[i][k];
            let num = w[i] * backward[i + 1][k - 1];
            let p_inc = if denom > 0.0 { num / denom } else { 1.0 };
            if n - i == k || rng.bernoulli(p_inc) {
                out.push(design.free[i]);
                k -= 1;
            }
        }
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn target_pi() -> (Vec<f64>, usize) {
        // n = 6, r = 3, one saturated unit.
        (vec![1.0, 0.7, 0.5, 0.4, 0.25, 0.15], 3)
    }

    fn check_marginals(
        sampler: impl Fn(&mut Rng) -> Vec<usize>,
        pi: &[f64],
        r: usize,
        trials: usize,
        tol_sigmas: f64,
    ) {
        let mut rng = Rng::new(12345);
        let mut counts = vec![0usize; pi.len()];
        for _ in 0..trials {
            let s = sampler(&mut rng);
            assert_eq!(s.len(), r, "wrong sample size: {s:?}");
            let mut d = s.clone();
            d.dedup();
            assert_eq!(d.len(), r, "duplicate units: {s:?}");
            for i in s {
                counts[i] += 1;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let expect = trials as f64 * pi[i];
            let sd = (trials as f64 * pi[i] * (1.0 - pi[i])).sqrt().max(1.0);
            assert!(
                (c as f64 - expect).abs() < tol_sigmas * sd,
                "unit {i}: got {c}, expect {expect:.1} ± {sd:.1}"
            );
        }
    }

    #[test]
    fn systematic_fixed_size_and_marginals() {
        let (pi, r) = target_pi();
        check_marginals(|rng| sample_systematic(&pi, r, rng), &pi, r, 40_000, 5.0);
    }

    #[test]
    fn sampford_fixed_size_and_marginals() {
        let (pi, r) = target_pi();
        check_marginals(|rng| sample_sampford(&pi, r, rng), &pi, r, 20_000, 5.0);
    }

    #[test]
    fn cps_fixed_size_and_marginals() {
        let (pi, r) = target_pi();
        let design = conditional_poisson_calibrate(&pi, r);
        check_marginals(|rng| sample_conditional_poisson(&design, rng), &pi, r, 20_000, 5.0);
    }

    #[test]
    fn cps_calibration_is_exact_in_expectation() {
        let (pi, r) = target_pi();
        let design = conditional_poisson_calibrate(&pi, r);
        // free-unit targets recovered by the DP inclusion formula
        let free_targets: Vec<f64> = pi.iter().cloned().filter(|&p| p < 1.0).collect();
        let got = cps_inclusion(&design.weights, r - 1);
        for (g, t) in got.iter().zip(&free_targets) {
            assert!((g - t).abs() < 1e-9, "calibrated {g} vs target {t}");
        }
    }

    #[test]
    fn esp_matches_bruteforce() {
        let w = [0.5, 1.5, 2.0, 0.25];
        let e = esp(&w, 3);
        // e1 = Σw, e2 = Σ_{i<j} w_i w_j, e3 = Σ_{i<j<k} ...
        let e1: f64 = w.iter().sum();
        let mut e2 = 0.0;
        let mut e3 = 0.0;
        for i in 0..4 {
            for j in (i + 1)..4 {
                e2 += w[i] * w[j];
                for k in (j + 1)..4 {
                    e3 += w[i] * w[j] * w[k];
                }
            }
        }
        assert!((e[1] - e1).abs() < 1e-12);
        assert!((e[2] - e2).abs() < 1e-12);
        assert!((e[3] - e3).abs() < 1e-12);
    }

    #[test]
    fn equal_pi_reduces_to_srswor_marginals() {
        let pi = vec![0.5; 8];
        let r = 4;
        check_marginals(|rng| sample_systematic(&pi, r, rng), &pi, r, 30_000, 5.0);
        let design = conditional_poisson_calibrate(&pi, r);
        check_marginals(|rng| sample_conditional_poisson(&design, rng), &pi, r, 20_000, 5.0);
    }

    #[test]
    fn all_units_forced_when_r_equals_n() {
        let pi = vec![1.0; 5];
        let mut rng = Rng::new(3);
        assert_eq!(sample_sampford(&pi, 5, &mut rng), vec![0, 1, 2, 3, 4]);
        let d = conditional_poisson_calibrate(&pi, 5);
        assert_eq!(sample_conditional_poisson(&d, &mut rng), vec![0, 1, 2, 3, 4]);
        assert_eq!(sample_systematic(&pi, 5, &mut rng).len(), 5);
    }

    #[test]
    #[should_panic(expected = "sum to r")]
    fn rejects_inconsistent_budget() {
        let mut rng = Rng::new(1);
        sample_systematic(&[0.5, 0.5, 0.5], 2, &mut rng);
    }

    #[test]
    fn sampford_bounded_reports_exhaustion_instead_of_panicking() {
        let (pi, r) = target_pi();
        let mut rng = Rng::new(31);
        let err = sample_sampford_bounded(&pi, r, &mut rng, 0).unwrap_err();
        assert_eq!(err.attempts, 0);
        assert!(err.to_string().contains("Sampford"), "{err}");
        // with a sane budget the same target succeeds
        assert_eq!(sample_sampford_bounded(&pi, r, &mut rng, 1000).unwrap().len(), r);
    }

    #[test]
    fn sampford_falls_back_to_cps_with_correct_marginals() {
        // zero retry budget forces the conditional-Poisson fallback on
        // every draw: the sample size must stay fixed and the marginals
        // exact — the degenerate-(n, r) path keeps training alive with
        // the right distribution.
        let (pi, r) = target_pi();
        check_marginals(
            |rng| sample_sampford_with_fallback(&pi, r, rng, 0),
            &pi,
            r,
            20_000,
            5.0,
        );
    }

    #[test]
    fn sampford_never_panics_on_degenerate_targets() {
        // r = n − 1 with a heavily skewed target: Sampford's acceptance
        // rate collapses (duplicate draws of near-saturated units). The
        // public entry point must still return a valid fixed-size sample.
        let pi = vec![0.999, 0.999, 0.997, 0.005];
        let mut rng = Rng::new(77);
        for _ in 0..50 {
            let s = sample_sampford_with_fallback(&pi, 3, &mut rng, 3);
            assert_eq!(s.len(), 3);
            let mut d = s.clone();
            d.dedup();
            assert_eq!(d.len(), 3, "duplicates in {s:?}");
        }
    }
}
