//! Tillé's elimination procedure (Tillé 1996; cited by Algorithm 4 as
//! "Tillé's elimination") — the third fixed-size π-ps design the paper
//! names.
//!
//! The procedure walks the sample size down from n to r: at stage
//! k (selecting k units out of the survivors) every surviving unit i
//! carries the inclusion probability π_i(k) of the *size-k* design, and
//! one unit is eliminated with probability
//!
//! ```text
//! p_i = 1 − π_i(k) / π_i(k+1)
//! ```
//!
//! (normalized over survivors). The size-k inclusion probabilities are
//! recomputed by the standard Hájek fixed point at each stage so every
//! stage is a proper π-ps problem. The eliminations are sequential and
//! the final survivor set has exactly the target first-order inclusion
//! probabilities.

use crate::rng::Rng;

/// Compute size-k inclusion probabilities proportional to `w`, capped at
/// 1 (the classic πps fixed point: saturate, redistribute, repeat).
fn pips_probabilities(w: &[f64], k: usize) -> Vec<f64> {
    let n = w.len();
    assert!(k <= n);
    let mut pi = vec![0.0; n];
    let mut capped = vec![false; n];
    loop {
        let free_weight: f64 = w
            .iter()
            .zip(&capped)
            .filter(|(_, &c)| !c)
            .map(|(&x, _)| x)
            .sum();
        let k_free = k - capped.iter().filter(|&&c| c).count();
        if free_weight <= 0.0 || k_free == 0 {
            break;
        }
        let mut newly_capped = false;
        for i in 0..n {
            if capped[i] {
                continue;
            }
            let p = k_free as f64 * w[i] / free_weight;
            if p >= 1.0 {
                pi[i] = 1.0;
                capped[i] = true;
                newly_capped = true;
            } else {
                pi[i] = p;
            }
        }
        if !newly_capped {
            break;
        }
    }
    pi
}

/// Draw a fixed-size-r sample with Pr(i ∈ J) = pi_target_i by Tillé's
/// elimination. `pi_target` must lie in (0, 1] and sum to r.
pub fn sample_tille(pi_target: &[f64], r: usize, rng: &mut Rng) -> Vec<usize> {
    let n = pi_target.len();
    let sum: f64 = pi_target.iter().sum();
    assert!(
        (sum - r as f64).abs() < 1e-6,
        "inclusion probabilities must sum to r: Σπ = {sum}, r = {r}"
    );
    for &p in pi_target {
        assert!(p > 0.0 && p <= 1.0 + 1e-9, "π_i must lie in (0,1], got {p}");
    }
    // Use the targets themselves as the size weights: π_i(k) ∝ π_target
    // capped at 1, which reproduces π_target exactly at k = r.
    let mut alive: Vec<usize> = (0..n).collect();
    let mut pi_k1 = vec![1.0; n]; // π_i(n) = 1 for all units
    for k in (r..n).rev() {
        // size-k probabilities over the full population (dead units
        // already have π(k+1) = their elimination state; the recursion
        // only ever eliminates units with π < 1)
        let pi_k = pips_probabilities(pi_target, k);
        // elimination weights over the survivors
        let weights: Vec<f64> = alive
            .iter()
            .map(|&i| (1.0 - pi_k[i] / pi_k1[i]).max(0.0))
            .collect();
        let total: f64 = weights.iter().sum();
        let victim_pos = if total <= 0.0 {
            // degenerate (all saturated): eliminate uniformly among
            // the non-saturated; fall back to uniform if none
            rng.below(alive.len() as u64) as usize
        } else {
            rng.categorical(&weights)
        };
        alive.swap_remove(victim_pos);
        pi_k1 = pi_k;
    }
    alive.sort_unstable();
    alive
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pips_fixed_point_saturates_and_sums() {
        let w = [10.0, 1.0, 1.0, 1.0];
        let pi = pips_probabilities(&w, 2);
        assert!((pi[0] - 1.0).abs() < 1e-12, "dominant unit must saturate");
        let sum: f64 = pi.iter().sum();
        assert!((sum - 2.0).abs() < 1e-9, "Σπ = {sum}");
        // remaining mass split evenly
        for &p in &pi[1..] {
            assert!((p - 1.0 / 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn sample_has_fixed_size_and_valid_units() {
        let pi = [0.9, 0.7, 0.5, 0.4, 0.3, 0.2];
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let s = sample_tille(&pi, 3, &mut rng);
            assert_eq!(s.len(), 3);
            let mut d = s.clone();
            d.dedup();
            assert_eq!(d.len(), 3);
            assert!(*s.last().unwrap() < 6);
        }
    }

    #[test]
    fn marginals_match_targets() {
        let pi = [1.0, 0.7, 0.5, 0.4, 0.25, 0.15];
        let r = 3;
        let trials = 40_000;
        let mut rng = Rng::new(7);
        let mut counts = vec![0usize; pi.len()];
        for _ in 0..trials {
            for i in sample_tille(&pi, r, &mut rng) {
                counts[i] += 1;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let expect = trials as f64 * pi[i];
            let sd = (trials as f64 * pi[i] * (1.0 - pi[i])).sqrt().max(1.0);
            assert!(
                (c as f64 - expect).abs() < 6.0 * sd,
                "unit {i}: got {c}, expect {expect:.0} ± {sd:.0}"
            );
        }
    }

    #[test]
    fn equal_probabilities_reduce_to_srswor() {
        let pi = vec![0.5; 8];
        let mut rng = Rng::new(9);
        let trials = 30_000;
        let mut counts = vec![0usize; 8];
        for _ in 0..trials {
            for i in sample_tille(&pi, 4, &mut rng) {
                counts[i] += 1;
            }
        }
        let expect = trials as f64 * 0.5;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 6.0 * (trials as f64 * 0.25).sqrt());
        }
    }

    #[test]
    fn r_equals_n_returns_everything() {
        let pi = vec![1.0; 5];
        let mut rng = Rng::new(11);
        assert_eq!(sample_tille(&pi, 5, &mut rng), vec![0, 1, 2, 3, 4]);
    }
}
