//! Optimal inclusion probabilities (Theorem 3, eq. 17).
//!
//! Given the spectrum σ₁ ≥ … ≥ σ_n ≥ 0 of Σ and a rank budget r, solve
//!
//! ```text
//! min Σ_i σ_i / π_i   s.t.  0 < π_i ≤ 1,  Σ_i π_i = r
//! ```
//!
//! whose KKT solution is π*_i = min{1, √(σ_i/μ)} with μ chosen so the
//! budget binds. Directions with large σ saturate at 1 (always included);
//! the rest get mass ∝ √σ_i — the paper's "√σ water-filling".
//!
//! Degenerate directions (σ_i = 0) would receive π = 0, which breaks the
//! isotropy constraint E[P] = cI (the reweighting c/π_i is undefined).
//! Following the construction in the paper's Proposition 4 proof — which
//! distributes leftover budget arbitrarily over null directions — we
//! spread any residual budget uniformly across zero-σ directions, and
//! additionally floor σ at `sigma_floor · max σ` so estimated spectra
//! with numerically-zero tails stay usable.

/// Solution of the water-filling problem.
#[derive(Clone, Debug)]
pub struct InclusionSolution {
    /// π*_i aligned with the input σ order.
    pub pi: Vec<f64>,
    /// Number of saturated directions t = #{i : π*_i = 1}.
    pub saturated: usize,
    /// Optimal objective Σ_i σ_i / π*_i (σ after flooring).
    pub objective: f64,
}

/// Relative floor applied to σ before solving (see module docs).
pub const DEFAULT_SIGMA_FLOOR: f64 = 1e-12;

/// Solve eq. (17). `sigma` need not be sorted; ordering is handled
/// internally and the returned π aligns with the input order.
pub fn optimal_inclusion(sigma: &[f64], r: usize, sigma_floor: f64) -> InclusionSolution {
    let n = sigma.len();
    assert!(r >= 1 && r <= n, "rank budget r={r} out of range for n={n}");
    let smax = sigma.iter().cloned().fold(0.0, f64::max);
    // Empirically-estimated spectra carry O(ε) negative eigenvalues from
    // the eigensolver; clamp those, but reject genuinely indefinite input.
    assert!(
        sigma.iter().all(|&s| s >= -1e-9 * smax.max(1.0)),
        "σ must be non-negative (min = {:?})",
        sigma.iter().cloned().fold(f64::INFINITY, f64::min)
    );
    let sigma: Vec<f64> = sigma.iter().map(|&s| s.max(0.0)).collect();
    let sigma = &sigma[..];
    if smax == 0.0 {
        // Flat (all-zero) spectrum: the problem degenerates to the
        // instance-independent case; uniform π = r/n is optimal.
        let pi = vec![r as f64 / n as f64; n];
        return InclusionSolution { pi, saturated: if r == n { n } else { 0 }, objective: 0.0 };
    }
    let floor = sigma_floor * smax;
    let sig: Vec<f64> = sigma.iter().map(|&s| s.max(floor)).collect();

    // Sort indices by σ descending; saturation happens in this order
    // because π*_i is monotone in σ_i.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| sig[b].partial_cmp(&sig[a]).unwrap());

    let sqrt_sig: Vec<f64> = order.iter().map(|&i| sig[i].sqrt()).collect();
    let mut suffix_sum = vec![0.0; n + 1];
    for i in (0..n).rev() {
        suffix_sum[i] = suffix_sum[i + 1] + sqrt_sig[i];
    }

    // Find the smallest t such that the largest uncapped candidate
    // (r − t)·√σ_{t+1} / Σ_{j>t} √σ_j ≤ 1.
    let mut t = 0usize;
    while t < r {
        if t == n {
            break;
        }
        let denom = suffix_sum[t];
        if denom == 0.0 {
            break;
        }
        let cand = (r - t) as f64 * sqrt_sig[t] / denom;
        if cand <= 1.0 + 1e-15 {
            break;
        }
        t += 1;
    }

    let mut pi = vec![0.0; n];
    let denom = suffix_sum[t];
    for (k, &i) in order.iter().enumerate() {
        if k < t {
            pi[i] = 1.0;
        } else if denom > 0.0 {
            pi[i] = ((r - t) as f64 * sqrt_sig[k] / denom).min(1.0);
        }
    }

    // Numerical cleanup: renormalize the uncapped block so Σπ = r exactly.
    let capped_sum: f64 = pi.iter().filter(|&&p| p >= 1.0 - 1e-12).map(|_| 1.0).sum();
    let uncapped_sum: f64 = pi.iter().filter(|&&p| p < 1.0 - 1e-12).sum();
    if uncapped_sum > 0.0 {
        let target = r as f64 - capped_sum;
        let scale = target / uncapped_sum;
        for p in pi.iter_mut() {
            if *p < 1.0 - 1e-12 {
                *p *= scale;
            } else {
                *p = 1.0;
            }
        }
    }

    let objective: f64 = sig
        .iter()
        .zip(&pi)
        .map(|(&s, &p)| if p > 0.0 { s / p } else { 0.0 })
        .sum();
    let saturated = pi.iter().filter(|&&p| p >= 1.0 - 1e-12).count();
    InclusionSolution { pi, saturated, objective }
}

/// Closed-form optimal value Φ_min/c² from eq. (16), for cross-checking
/// the solver: Σ_{sat} σ_i + (Σ_{unsat} √σ_i)² / (r − t).
pub fn phi_min_over_c2(sigma: &[f64], r: usize, sigma_floor: f64) -> f64 {
    let sol = optimal_inclusion(sigma, r, sigma_floor);
    sol.objective
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_feasible(pi: &[f64], r: usize) {
        let sum: f64 = pi.iter().sum();
        assert!((sum - r as f64).abs() < 1e-9, "Σπ = {sum} ≠ {r}");
        for &p in pi {
            assert!(p > 0.0 && p <= 1.0 + 1e-12, "π out of (0,1]: {p}");
        }
    }

    #[test]
    fn flat_spectrum_gives_uniform() {
        let sol = optimal_inclusion(&[2.0; 10], 4, DEFAULT_SIGMA_FLOOR);
        assert_feasible(&sol.pi, 4);
        for &p in &sol.pi {
            assert!((p - 0.4).abs() < 1e-12);
        }
        assert_eq!(sol.saturated, 0);
    }

    #[test]
    fn budget_equals_n_saturates_all() {
        let sol = optimal_inclusion(&[5.0, 1.0, 0.1], 3, DEFAULT_SIGMA_FLOOR);
        assert_feasible(&sol.pi, 3);
        assert_eq!(sol.saturated, 3);
    }

    #[test]
    fn dominant_direction_saturates() {
        // σ = (100, 1, 1, 1), r = 2: direction 1 must be always included.
        let sol = optimal_inclusion(&[100.0, 1.0, 1.0, 1.0], 2, DEFAULT_SIGMA_FLOOR);
        assert_feasible(&sol.pi, 2);
        assert!((sol.pi[0] - 1.0).abs() < 1e-12);
        assert_eq!(sol.saturated, 1);
        // remaining three share the leftover budget equally (equal σ)
        for &p in &sol.pi[1..] {
            assert!((p - 1.0 / 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn uncapped_mass_proportional_to_sqrt_sigma() {
        let sigma = [4.0, 1.0, 0.25, 0.0625];
        let sol = optimal_inclusion(&sigma, 1, DEFAULT_SIGMA_FLOOR);
        assert_feasible(&sol.pi, 1);
        // no saturation at r=1 with this spread: π_i ∝ √σ_i = (2,1,.5,.25)
        let total: f64 = 2.0 + 1.0 + 0.5 + 0.25;
        for (i, w) in [2.0, 1.0, 0.5, 0.25].iter().enumerate() {
            assert!((sol.pi[i] - w / total).abs() < 1e-9, "π={:?}", sol.pi);
        }
    }

    #[test]
    fn objective_matches_closed_form_eq16() {
        let sigma = [9.0, 4.0, 1.0, 0.5, 0.1];
        let r = 3;
        let sol = optimal_inclusion(&sigma, r, 0.0);
        // recompute eq. (16) from the reported saturation set
        let t = sol.saturated;
        let mut sat = 0.0;
        let mut unsat_sqrt = 0.0;
        for (i, &p) in sol.pi.iter().enumerate() {
            if p >= 1.0 - 1e-12 {
                sat += sigma[i];
            } else {
                unsat_sqrt += sigma[i].sqrt();
            }
        }
        let closed = sat + unsat_sqrt * unsat_sqrt / (r - t) as f64;
        assert!((sol.objective - closed).abs() < 1e-9);
    }

    #[test]
    fn solver_beats_uniform_on_nonflat_spectrum() {
        // optimality sanity: Σσ_i/π*_i ≤ Σσ_i/(r/n)
        let sigma = [10.0, 5.0, 2.0, 1.0, 0.5, 0.2, 0.1, 0.05];
        let r = 3;
        let sol = optimal_inclusion(&sigma, r, 0.0);
        let uniform: f64 = sigma.iter().map(|s| s / (r as f64 / 8.0)).sum();
        assert!(sol.objective < uniform);
    }

    #[test]
    fn brute_force_agreement_small_case() {
        // n=3, r=2: grid-search the simplex {π: Σπ=2, 0<π≤1} and compare.
        let sigma = [3.0, 1.0, 0.2];
        let sol = optimal_inclusion(&sigma, 2, 0.0);
        let mut best = f64::INFINITY;
        let steps = 2000;
        for a in 1..steps {
            let p1 = a as f64 / steps as f64;
            for b in 1..steps {
                let p2 = b as f64 / steps as f64;
                let p3 = 2.0 - p1 - p2;
                if p3 <= 0.0 || p3 > 1.0 {
                    continue;
                }
                let obj = sigma[0] / p1 + sigma[1] / p2 + sigma[2] / p3;
                if obj < best {
                    best = obj;
                }
            }
        }
        assert!(sol.objective <= best + 1e-3, "solver {} vs grid {}", sol.objective, best);
    }

    #[test]
    fn zero_directions_get_positive_pi() {
        let sigma = [1.0, 1.0, 0.0, 0.0];
        let sol = optimal_inclusion(&sigma, 3, DEFAULT_SIGMA_FLOOR);
        assert_feasible(&sol.pi, 3);
        // rank(Σ)=2 ≤ r=3 ⇒ positive-σ directions saturate (Prop 4)
        assert!((sol.pi[0] - 1.0).abs() < 1e-9);
        assert!((sol.pi[1] - 1.0).abs() < 1e-9);
        assert!(sol.pi[2] > 0.0 && sol.pi[3] > 0.0);
    }

    #[test]
    fn unsorted_input_handled() {
        let sorted = optimal_inclusion(&[8.0, 2.0, 1.0], 2, 0.0);
        let shuffled = optimal_inclusion(&[1.0, 8.0, 2.0], 2, 0.0);
        assert!((sorted.pi[0] - shuffled.pi[1]).abs() < 1e-12);
        assert!((sorted.pi[1] - shuffled.pi[2]).abs() < 1e-12);
        assert!((sorted.pi[2] - shuffled.pi[0]).abs() < 1e-12);
        assert!((sorted.objective - shuffled.objective).abs() < 1e-12);
    }
}
