//! The §6.1 toy experiment: quadratic matrix regression
//!
//! ```text
//! min_W f(W) = E_{A ~ N(μᵀ, Σ_A)} [ ½ ‖A·W·B − C‖_F² ]
//! ```
//!
//! with A ∈ ℝ^{1×m} a Gaussian row vector, fixed B ∈ ℝ^{n×o} and
//! C ∈ ℝ^{1×o}, decision variable W ∈ ℝ^{m×n} (paper defaults
//! m = n = 100, o = 30). The closed-form gradient
//!
//! ```text
//! ∇f(W) = (Σ_A + μμᵀ)·W·BBᵀ − μ·CBᵀ
//! ```
//!
//! lets the MSE of every estimator be measured exactly — this is the
//! paper's controlled validation of Theorems 2–3.
//!
//! This module owns the *problem* (data law, loss, closed-form gradient,
//! raw IPA estimate — the "estimate" stage's oracle). The four estimator
//! shapes themselves (full/low-rank × IPA/LR) live in exactly one place:
//! [`crate::estimator::engine::OracleEngine`], which drives this oracle
//! through the shared project→estimate→lift pipeline.

use crate::linalg::{cholesky, matmul, matmul_nt, matmul_tn, Mat};
use crate::rng::Rng;

/// Problem instance. The data covariance Σ_A is AR(1) with parameter ρ —
/// a non-flat spectrum so the instance-dependent sampler has structure
/// to exploit (the paper leaves Σ unspecified beyond "Gaussian").
pub struct ToyProblem {
    pub m: usize,
    pub n: usize,
    pub o: usize,
    /// Mean of A (column vector, length m).
    pub mu: Vec<f64>,
    /// Covariance of A (m×m).
    pub sigma_a: Mat,
    /// Fixed right factor B (n×o).
    pub b: Mat,
    /// Fixed target C (1×o).
    pub c_mat: Mat,
    /// Cholesky factor of Σ_A for sampling.
    chol_a: Mat,
    /// Cached BBᵀ (n×n).
    bbt: Mat,
    /// Cached μ·CBᵀ (m×n).
    mu_cbt: Mat,
    /// Cached Σ_A + μμᵀ (m×m).
    second_moment_a: Mat,
}

impl ToyProblem {
    /// Paper configuration: m = n = 100, o = 30.
    pub fn paper_default(seed: u64) -> Self {
        Self::new(100, 100, 30, 0.5, seed)
    }

    /// Small instance for fast tests.
    pub fn small(seed: u64) -> Self {
        Self::new(20, 20, 6, 0.5, seed)
    }

    pub fn new(m: usize, n: usize, o: usize, rho: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // μ: standard normal entries (fixed once per instance)
        let mu = rng.normal_vec(m);
        // Σ_A: AR(1), unit diagonal
        let sigma_a = Mat::from_fn(m, m, |i, j| rho.powi((i as i32 - j as i32).abs()));
        let chol_a = cholesky(&sigma_a);
        // B, C: i.i.d. standard normal, fixed
        let b = Mat::from_fn(n, o, |_, _| rng.normal());
        let c_mat = Mat::from_fn(1, o, |_, _| rng.normal());

        let bbt = matmul_nt(&b, &b);
        let mu_mat = Mat { rows: m, cols: 1, data: mu.clone() };
        let cbt = matmul_nt(&c_mat, &b); // 1×n
        let mu_cbt = matmul(&mu_mat, &cbt); // m×n
        let mut second_moment_a = sigma_a.clone();
        for i in 0..m {
            for j in 0..m {
                let v = second_moment_a.get(i, j) + mu[i] * mu[j];
                second_moment_a.set(i, j, v);
            }
        }
        ToyProblem { m, n, o, mu, sigma_a, b, c_mat, chol_a, bbt, mu_cbt, second_moment_a }
    }

    /// Draw one data sample A ~ N(μᵀ, Σ_A) as a length-m row.
    pub fn sample_a(&self, rng: &mut Rng) -> Vec<f64> {
        let z = rng.normal_vec(self.m);
        let mut a = self.mu.clone();
        // a += L·z (L lower triangular)
        for i in 0..self.m {
            let lrow = self.chol_a.row(i);
            let mut s = 0.0;
            for k in 0..=i {
                s += lrow[k] * z[k];
            }
            a[i] += s;
        }
        a
    }

    /// Sample-path loss ½‖AWB − C‖².
    pub fn loss(&self, w: &Mat, a: &[f64]) -> f64 {
        let r = self.residual(w, a);
        0.5 * r.iter().map(|x| x * x).sum::<f64>()
    }

    /// Residual AWB − C as a length-o row.
    fn residual(&self, w: &Mat, a: &[f64]) -> Vec<f64> {
        // aw = A·W (1×n)
        let aw = crate::linalg::matvec_t(w, a);
        // awb = aw·B (1×o)
        let awb = crate::linalg::matvec_t(&self.b, &aw);
        awb.iter().zip(self.c_mat.row(0)).map(|(x, c)| x - c).collect()
    }

    /// Exact gradient ∇f(W) = (Σ_A + μμᵀ)·W·BBᵀ − μ·CBᵀ (m×n).
    pub fn true_gradient(&self, w: &Mat) -> Mat {
        let wbbt = matmul(w, &self.bbt);
        let mut g = matmul(&self.second_moment_a, &wbbt);
        g.axpy_inplace(-1.0, &self.mu_cbt);
        g
    }

    /// Full-rank IPA estimator ĝ = Aᵀ·(AWB − C)·Bᵀ (m×n) — the IPA
    /// family's raw oracle the engine projects and lifts.
    pub fn ipa_estimate(&self, w: &Mat, a: &[f64]) -> Mat {
        let mut out = Mat::zeros(self.m, self.n);
        self.ipa_estimate_into(w, a, &mut out);
        out
    }

    /// [`ipa_estimate`](Self::ipa_estimate) into a preallocated m×n
    /// workspace (the engine's steady-state entry point).
    pub fn ipa_estimate_into(&self, w: &Mat, a: &[f64], out: &mut Mat) {
        assert_eq!((out.rows, out.cols), (self.m, self.n));
        let res = self.residual(w, a); // 1×o
        // d = res·Bᵀ (1×n)
        let d = crate::linalg::matvec(&self.b, &res);
        // outer product aᵀ·d
        for i in 0..self.m {
            let row = out.row_mut(i);
            for (o, dj) in row.iter_mut().zip(&d) {
                *o = a[i] * dj;
            }
        }
    }

    /// Data-noise second moment Σ_ξ = E[(ĝ−g)ᵀ(ĝ−g)] (n×n), estimated
    /// from `n_samples` warm-up draws of the given family's full-rank
    /// estimator — this is the "roughly estimated from a small set of
    /// warm-up samples" input to the instance-dependent design (§5.2).
    /// The draws run through the engine's full-rank pipeline.
    pub fn sigma_xi_empirical(
        &self,
        w: &Mat,
        rng: &mut Rng,
        n_samples: usize,
        family: super::Family,
        zo_sigma: f64,
    ) -> Mat {
        let g = self.true_gradient(w);
        let mut engine = super::engine::OracleEngine::new(
            super::engine::MethodShape::of(family, false),
            self.m,
            self.n,
            0,
            None,
        );
        let mut acc = Mat::zeros(self.n, self.n);
        for _ in 0..n_samples {
            let a = self.sample_a(rng);
            let ghat = engine.step(self, w, &a, rng, zo_sigma);
            let delta = ghat.sub(&g);
            // acc += δᵀδ
            let dtd = matmul_tn(&delta, &delta);
            acc.axpy_inplace(1.0 / n_samples as f64, &dtd);
        }
        acc
    }

    /// Signal second moment Σ_Θ = g(Θ)ᵀ g(Θ) (n×n), exact.
    pub fn sigma_theta(&self, w: &Mat) -> Mat {
        let g = self.true_gradient(w);
        matmul_tn(&g, &g)
    }

    /// Σ = Σ_ξ + Σ_Θ — the instance weight of §5.2.
    pub fn sigma_total(
        &self,
        w: &Mat,
        rng: &mut Rng,
        warmup: usize,
        family: super::Family,
        zo_sigma: f64,
    ) -> Mat {
        let mut s = self.sigma_xi_empirical(w, rng, warmup, family, zo_sigma);
        let st = self.sigma_theta(w);
        s.axpy_inplace(1.0, &st);
        s
    }

    /// A deterministic, reproducible evaluation point W (not the optimum:
    /// gradients must be non-zero for the MSE study to be informative).
    pub fn eval_point(&self, seed: u64) -> Mat {
        let mut rng = Rng::new(seed ^ 0xABCD);
        Mat::from_fn(self.m, self.n, |_, _| 0.3 * rng.normal())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::Family;
    use crate::linalg::transpose;

    #[test]
    fn true_gradient_matches_finite_differences() {
        let p = ToyProblem::small(1);
        let w = p.eval_point(2);
        let g = p.true_gradient(&w);
        // central differences on f(W) = E[loss] computed in closed form:
        // f(W) = ½ tr(BᵀWᵀ(Σ+μμᵀ)WB) − CBᵀWᵀμ + ½‖C‖² + ½tr(…) const.
        // Instead of deriving f, check ⟨g, D⟩ ≈ (f(W+hD) − f(W−hD))/2h
        // with f estimated by heavy Monte Carlo — use common random
        // numbers for variance reduction.
        let mut rng = Rng::new(3);
        let d = Mat::from_fn(p.m, p.n, |_, _| rng.normal());
        let h = 1e-5;
        let mut wp = w.clone();
        wp.axpy_inplace(h, &d);
        let mut wm = w.clone();
        wm.axpy_inplace(-h, &d);
        let n_mc = 4000;
        let mut diff = 0.0;
        let mut rng2 = Rng::new(77);
        for _ in 0..n_mc {
            let a = p.sample_a(&mut rng2);
            diff += (p.loss(&wp, &a) - p.loss(&wm, &a)) / (2.0 * h);
        }
        diff /= n_mc as f64;
        let inner = crate::linalg::fro_inner(&g, &d);
        let rel = (diff - inner).abs() / inner.abs().max(1.0);
        assert!(rel < 0.05, "directional derivative mismatch: mc={diff}, exact={inner}");
    }

    #[test]
    fn ipa_estimator_is_unbiased() {
        let p = ToyProblem::small(5);
        let w = p.eval_point(6);
        let g = p.true_gradient(&w);
        let mut rng = Rng::new(7);
        let n_mc = 20_000;
        let mut mean = Mat::zeros(p.m, p.n);
        for _ in 0..n_mc {
            let a = p.sample_a(&mut rng);
            mean.axpy_inplace(1.0 / n_mc as f64, &p.ipa_estimate(&w, &a));
        }
        let rel = mean.sub(&g).fro_norm() / g.fro_norm();
        assert!(rel < 0.05, "IPA bias: rel err {rel}");
    }

    #[test]
    fn ipa_estimate_into_matches_allocating_form() {
        let p = ToyProblem::small(9);
        let w = p.eval_point(10);
        let mut rng = Rng::new(11);
        let a = p.sample_a(&mut rng);
        let fresh = p.ipa_estimate(&w, &a);
        let mut out = Mat::zeros(p.m, p.n);
        out.data.iter_mut().for_each(|x| *x = 7.0); // stale workspace
        p.ipa_estimate_into(&w, &a, &mut out);
        for (x, y) in fresh.data.iter().zip(&out.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn sigma_xi_is_symmetric_psd() {
        let p = ToyProblem::small(19);
        let w = p.eval_point(20);
        let mut rng = Rng::new(21);
        let sxi = p.sigma_xi_empirical(&w, &mut rng, 300, Family::Ipa, 1e-2);
        // symmetric
        let sym_err = sxi.sub(&transpose(&sxi)).fro_norm();
        assert!(sym_err < 1e-9);
        // PSD: all eigenvalues ≥ −ε
        let e = crate::linalg::sym_eig(&sxi);
        for &lam in &e.values {
            assert!(lam > -1e-8, "negative eigenvalue {lam}");
        }
    }

    #[test]
    fn gradient_vanishes_at_optimum() {
        // Solve the quadratic exactly in the rank-deficient-free small
        // case via gradient descent and confirm ∇f → 0.
        let p = ToyProblem::small(23);
        let mut w = p.eval_point(24);
        for _ in 0..4000 {
            let g = p.true_gradient(&w);
            w.axpy_inplace(-2e-3, &g);
        }
        let gnorm = p.true_gradient(&w).fro_norm();
        assert!(gnorm < 1e-3, "gradient at optimum: {gnorm}");
    }
}
