//! Closed-form MSE theory from §5 of the paper.
//!
//! Every formula the paper states is implemented here and cross-checked
//! against Monte-Carlo simulation in the test suites of [`super::toy`]
//! and `rust/tests/theory_vs_simulation.rs`. All quantities are for the
//! low-rank estimator ĝ = ĝ_classical · P, P = VVᵀ, E[P] = c·I_n.

use crate::linalg::{trace_product, Mat};
use crate::sampling::optimal_inclusion;

/// Proposition 1 decomposition of the MSE into its three parts:
/// tr(Σ_ξ E[P²]) + tr(Σ_Θ E[P² − c²I]) + (1−c)²·tr Σ_Θ.
#[derive(Clone, Copy, Debug)]
pub struct MseBreakdown {
    /// tr(Σ_ξ E[P²]) — intrinsic IPA/LR variance through the projector.
    pub classical_variance: f64,
    /// tr(Σ_Θ E[P² − c²I]) — variance induced by the random projection.
    pub projection_variance: f64,
    /// (1−c)²·tr Σ_Θ — scalar bias from weak unbiasedness.
    pub scalar_bias: f64,
}

impl MseBreakdown {
    pub fn total(&self) -> f64 {
        self.classical_variance + self.projection_variance + self.scalar_bias
    }
}

/// Proposition 1 evaluated with an explicit second-moment matrix E[P²].
pub fn mse_decomposition(
    sigma_xi: &Mat,
    sigma_theta: &Mat,
    e_p2: &Mat,
    c: f64,
) -> MseBreakdown {
    let n = sigma_xi.rows;
    assert_eq!(sigma_xi.rows, sigma_xi.cols);
    assert_eq!(sigma_theta.rows, n);
    assert_eq!(e_p2.rows, n);
    let classical_variance = trace_product(sigma_xi, e_p2);
    let shifted = {
        let mut m = e_p2.clone();
        for i in 0..n {
            let v = m.get(i, i) - c * c;
            m.set(i, i, v);
        }
        m
    };
    let projection_variance = trace_product(sigma_theta, &shifted);
    let scalar_bias = (1.0 - c) * (1.0 - c) * sigma_theta.trace();
    MseBreakdown { classical_variance, projection_variance, scalar_bias }
}

/// MSE of the full-rank classical estimator (Remark 1, first baseline):
/// MSE_F = tr(Σ_ξ).
pub fn mse_full_rank(tr_sigma_xi: f64) -> f64 {
    tr_sigma_xi
}

/// Theorem 2: the smallest achievable tr(E[P²]) over the admissible
/// class — n²c²/r.
pub fn thm2_floor(n: usize, r: usize, c: f64) -> f64 {
    (n * n) as f64 * c * c / r as f64
}

/// Exact MSE of an **isotropic-optimal** projector (Stiefel/coordinate,
/// Algorithms 2–3). These laws satisfy P² = (cn/r)·P almost surely, so
/// E[P²] = (c²n/r)·I exactly and
///
///   MSE = (c²n/r)·tr Σ_ξ + (c²n/r − 2c + 1)·tr Σ_Θ.
pub fn mse_isotropic_exact(n: usize, r: usize, c: f64, tr_sxi: f64, tr_sth: f64) -> f64 {
    let k = c * c * n as f64 / r as f64;
    k * tr_sxi + (k - 2.0 * c + 1.0) * tr_sth
}

/// Exact MSE of the **Gaussian** projector with V_ij ~ N(0, c/r)
/// (Remark 1, second baseline): E[P²] = c²(n+r+1)/r · I (Wishart second
/// moment), hence
///
///   MSE_G = c²(n+r+1)/r·tr Σ_ξ + (c²(n+r+1)/r − 2c + 1)·tr Σ_Θ,
///
/// which at c = 1 reduces to the paper's
/// MSE_G = ((n+r+1)/r)·tr Σ_ξ + ((n+1)/r)·tr Σ_Θ.
pub fn mse_gaussian_exact(n: usize, r: usize, c: f64, tr_sxi: f64, tr_sth: f64) -> f64 {
    let k = c * c * (n + r + 1) as f64 / r as f64;
    k * tr_sxi + (k - 2.0 * c + 1.0) * tr_sth
}

/// Equation (14): the uniform (spectral-norm) upper bound on the MSE of
/// the isotropic-optimal estimator:
/// (c²n/r)‖Σ_ξ‖₂ + (1 − 2c + c²n/r)‖Σ_Θ‖₂.
pub fn mse_upper_bound_eq14(
    n: usize,
    r: usize,
    c: f64,
    spec_sxi: f64,
    spec_sth: f64,
) -> f64 {
    let k = c * c * n as f64 / r as f64;
    k * spec_sxi + (1.0 - 2.0 * c + k) * spec_sth
}

/// Theorem 3: Φ_min = c²·[Σ_{sat} σ_i + (Σ_{unsat} √σ_i)²/(r−t)], the
/// optimal value of tr(Σ E[P²]) over the admissible class, computed via
/// the water-filling solver.
pub fn phi_min(sigma_spectrum: &[f64], r: usize, c: f64) -> f64 {
    let sol = optimal_inclusion(sigma_spectrum, r, crate::sampling::DEFAULT_SIGMA_FLOOR);
    c * c * sol.objective
}

/// Minimal MSE under the optimal instance-dependent projector (§5.2):
/// MSE_min = Φ_min + (1 − 2c)·tr Σ_Θ, where the spectrum is that of
/// Σ = Σ_ξ + Σ_Θ.
pub fn mse_dependent_min(
    sigma_spectrum: &[f64],
    r: usize,
    c: f64,
    tr_sigma_theta: f64,
) -> f64 {
    phi_min(sigma_spectrum, r, c) + (1.0 - 2.0 * c) * tr_sigma_theta
}

/// Proposition 4 predicate: with c = 1 and rank(Σ) ≤ r the dependent
/// optimum matches the full-rank MSE: MSE_min = tr(Σ_ξ).
pub fn prop4_matches_full_rank(sigma_spectrum: &[f64], r: usize, rank_tol: f64) -> bool {
    let smax = sigma_spectrum.iter().cloned().fold(0.0, f64::max);
    let rank = sigma_spectrum.iter().filter(|&&s| s > rank_tol * smax).count();
    rank <= r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_reduces_to_remark1_at_c1() {
        let (n, r) = (100, 4);
        let (txi, tth) = (3.0, 7.0);
        let got = mse_gaussian_exact(n, r, 1.0, txi, tth);
        let want = (n + r + 1) as f64 / r as f64 * txi + (n + 1) as f64 / r as f64 * tth;
        assert!((got - want).abs() < 1e-9);
    }

    #[test]
    fn isotropic_beats_gaussian_everywhere() {
        // Theorem 2 ⇒ for every (n, r, c) the isotropic-optimal MSE is
        // below the Gaussian MSE (strictly, since n+r+1 > n for r ≥ 1).
        for &(n, r) in &[(50, 2), (100, 4), (64, 16), (10, 9)] {
            for &c in &[0.1, 0.5, 1.0] {
                let iso = mse_isotropic_exact(n, r, c, 1.0, 1.0);
                let gau = mse_gaussian_exact(n, r, c, 1.0, 1.0);
                assert!(iso < gau, "iso {iso} !< gauss {gau} at n={n} r={r} c={c}");
            }
        }
    }

    #[test]
    fn remark1_small_c_limit() {
        // c = r/n: MSE = (r/n)trΣ_ξ + (1 − 2r/n + r/n)trΣ_Θ
        //             = (r/n)trΣ_ξ + (1 − r/n)trΣ_Θ  (trace version)
        let (n, r) = (100usize, 4usize);
        let c = r as f64 / n as f64;
        let got = mse_isotropic_exact(n, r, c, 1.0, 1.0);
        let want = c * 1.0 + (1.0 - c) * 1.0;
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn strong_unbiased_isotropic_formula() {
        // c = 1: MSE = (n/r)trΣ_ξ + (n/r − 1)trΣ_Θ
        let (n, r) = (60usize, 5usize);
        let got = mse_isotropic_exact(n, r, 1.0, 2.0, 3.0);
        let want = 12.0 * 2.0 + 11.0 * 3.0;
        assert!((got - want).abs() < 1e-9);
    }

    #[test]
    fn decomposition_consistency_with_isotropic_closed_form() {
        // E[P²] = (c²n/r)I plugged into Prop 1 must equal the closed form.
        let (n, r, c) = (20usize, 4usize, 0.6);
        let sxi = Mat::from_fn(n, n, |i, j| if i == j { 0.5 + i as f64 * 0.01 } else { 0.0 });
        let sth = Mat::from_fn(n, n, |i, j| if i == j { 1.0 / (1 + i) as f64 } else { 0.0 });
        let e_p2 = Mat::eye(n).scaled(c * c * n as f64 / r as f64);
        let d = mse_decomposition(&sxi, &sth, &e_p2, c);
        let closed = mse_isotropic_exact(n, r, c, sxi.trace(), sth.trace());
        assert!((d.total() - closed).abs() < 1e-9);
        assert!(d.scalar_bias > 0.0 && d.projection_variance > 0.0);
    }

    #[test]
    fn phi_min_flat_spectrum_equals_thm2_value() {
        // flat σ ⇒ Φ_min = c²·σ·n²/r = σ · (Thm 2 floor)
        let n = 30;
        let r = 6;
        let c = 1.0;
        let sigma = vec![2.5; n];
        let got = phi_min(&sigma, r, c);
        let want = 2.5 * thm2_floor(n, r, c);
        assert!((got - want).abs() / want < 1e-9);
    }

    #[test]
    fn prop4_dependent_matches_full_rank_when_rank_leq_r() {
        // rank(Σ) = 3 ≤ r = 4, c = 1: MSE_min = tr(Σ_ξ).
        let mut spec = vec![0.0; 50];
        spec[0] = 4.0;
        spec[1] = 2.0;
        spec[2] = 1.0; // tr Σ = 7
        assert!(prop4_matches_full_rank(&spec, 4, 1e-9));
        // Split Σ = Σ_ξ + Σ_Θ with tr Σ_Θ = 3 ⇒ tr Σ_ξ = 4.
        let mse = mse_dependent_min(&spec, 4, 1.0, 3.0);
        assert!((mse - 4.0).abs() < 1e-6, "MSE_min = {mse}, want tr Σ_ξ = 4");
    }

    #[test]
    fn dependent_never_worse_than_isotropic() {
        // Φ_min ≤ tr(Σ)·(c²n/r) since uniform π = r/n is feasible.
        let spec: Vec<f64> = (0..40).map(|i| 1.0 / (1 + i) as f64).collect();
        let tr: f64 = spec.iter().sum();
        for &r in &[1usize, 4, 10, 39] {
            let dep = phi_min(&spec, r, 1.0);
            let iso = tr * 40.0 / r as f64;
            assert!(dep <= iso + 1e-9, "r={r}: dep {dep} > iso {iso}");
        }
    }

    #[test]
    fn eq14_dominates_exact_mse_for_isotropic_law() {
        // the spectral bound must upper-bound the trace-exact MSE when
        // Σ's are scaled so ‖Σ‖₂·n ≥ tr Σ (always true).
        let (n, r, c) = (25usize, 5usize, 0.8);
        let sxi_spec = 0.9; // ‖Σ_ξ‖₂
        let sth_spec = 0.4;
        // worst-case trace: tr ≤ n·‖·‖₂
        let exact = mse_isotropic_exact(n, r, c, sxi_spec, sth_spec);
        let bound = mse_upper_bound_eq14(n, r, c, sxi_spec, sth_spec);
        // with tr = ‖·‖₂ (rank-one Σ) the bound and exact differ only in
        // the Σ_Θ coefficient: (1−2c+c²n/r) vs (c²n/r−2c+1) — identical.
        assert!((exact - bound).abs() < 1e-12);
    }

    #[test]
    fn bias_variance_tradeoff_in_c() {
        // Variance terms shrink with c², bias grows as (1−c)²: the MSE
        // at fixed (n, r) is convex in c with interior optimum when
        // tr Σ_Θ > 0. Check the optimum lands strictly inside (0, 1).
        let (n, r) = (100usize, 4usize);
        let (txi, tth) = (1.0, 1.0);
        let f = |c: f64| mse_isotropic_exact(n, r, c, txi, tth);
        // closed-form optimum: d/dc [c²k₀(txi+tth) − 2c·tth] = 0
        // with k₀ = n/r ⇒ c* = tth / (k₀(txi+tth))
        let k0 = n as f64 / r as f64;
        let c_star = tth / (k0 * (txi + tth));
        assert!(c_star > 0.0 && c_star < 1.0);
        assert!(f(c_star) < f(1.0) && f(c_star) < f(0.01));
    }
}
