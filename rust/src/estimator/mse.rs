//! Monte-Carlo MSE harness — regenerates the paper's Figures 2–5.
//!
//! For a fixed evaluation point W, the harness draws i.i.d. one-shot
//! estimates ĝ₁, ĝ₂, …, maintains the running mean ḡ_N, and records
//! ‖ḡ_N − g(W)‖_F² at each requested sample size N, averaged over
//! independent replications. With weak unbiasedness (c < 1) the curves
//! plateau at the bias floor (1−c)²‖g‖_F² as N grows — the
//! bias–variance trade-off the paper's §6.1 figures display.
//!
//! Estimates are formed by [`OracleEngine`] — the shared Algorithm-1
//! pipeline — and whole replications fan out across the kernel pool:
//! every rep runs on its own pre-forked child stream with its own
//! engine (and sampler clone), so the curves are **bitwise identical**
//! to the serial rep loop at any thread count.

use super::engine::{MethodShape, OracleEngine};
use super::toy::ToyProblem;
use super::Family;
use crate::linalg::Mat;
use crate::projection::{build_sampler, ProjectionSampler, ProjectorKind};
use crate::rng::Rng;

/// What estimator to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EstimatorSpec {
    /// Classical full-rank IPA/LR (Remark 1's first baseline).
    FullRank,
    /// Low-rank estimator with the given projector law.
    LowRank(ProjectorKind),
}

impl EstimatorSpec {
    pub fn label(&self) -> String {
        match self {
            EstimatorSpec::FullRank => "full-rank".to_string(),
            EstimatorSpec::LowRank(k) => format!("lowrank-{}", k.name()),
        }
    }
}

/// Configuration of one MSE-versus-samples curve.
#[derive(Clone, Debug)]
pub struct MseCurveConfig {
    pub family: Family,
    pub spec: EstimatorSpec,
    /// Weak-unbiasedness scale c (Definition 1).
    pub c: f64,
    /// Projection rank r.
    pub r: usize,
    /// Sample sizes N at which the running-mean MSE is recorded.
    pub sample_sizes: Vec<usize>,
    /// Independent replications to average over.
    pub reps: usize,
    pub seed: u64,
    /// ZO perturbation scale σ for the LR family.
    pub zo_sigma: f64,
    /// Warm-up draws for the instance-dependent Σ estimate.
    pub warmup: usize,
}

impl MseCurveConfig {
    pub fn default_for(family: Family, spec: EstimatorSpec, c: f64) -> Self {
        MseCurveConfig {
            family,
            spec,
            c,
            r: 4,
            sample_sizes: vec![10, 20, 50, 100, 200, 500],
            reps: 40,
            seed: 2026,
            zo_sigma: 1e-2,
            warmup: 200,
        }
    }
}

/// One computed curve.
#[derive(Clone, Debug)]
pub struct MseCurve {
    pub label: String,
    pub c: f64,
    /// (N, averaged MSE of the N-sample mean estimator).
    pub points: Vec<(usize, f64)>,
}

/// Compute an MSE curve on the toy problem at evaluation point `w`.
pub fn mse_curve(problem: &ToyProblem, w: &Mat, cfg: &MseCurveConfig) -> MseCurve {
    let g = problem.true_gradient(w);
    let scaled_truth = g.clone(); // compare against the *true* gradient,
                                  // so weak unbiasedness shows as bias.
    let n_max = *cfg.sample_sizes.iter().max().expect("empty sample_sizes");
    let mut rng = Rng::new(cfg.seed);

    // Projector prototype. The Dependent law estimates Σ = Σ_ξ + Σ_Θ
    // from warm-up draws first — consuming the parent stream exactly as
    // the serial harness always did, before any rep stream is forked.
    let shape = MethodShape::of(cfg.family, matches!(cfg.spec, EstimatorSpec::LowRank(_)));
    let proto: Option<Box<dyn ProjectionSampler + Send + Sync>> = match cfg.spec {
        EstimatorSpec::LowRank(kind) => {
            let sigma = if kind == ProjectorKind::Dependent {
                Some(problem.sigma_total(w, &mut rng, cfg.warmup, cfg.family, cfg.zo_sigma))
            } else {
                None
            };
            Some(build_sampler(kind, problem.n, cfg.r, cfg.c, sigma.as_ref()))
        }
        EstimatorSpec::FullRank => None,
    };

    // Fork every replication stream from the parent in rep order — the
    // identical parent-stream consumption of a serial rep loop — then
    // fan the reps out across the kernel pool. Each task builds its own
    // engine from a clone of the prototype sampler (so live workspaces
    // are bounded by the pool width, not the rep count); every law is
    // draw-stateless, so a clone draws exactly what a shared sampler
    // would: curves are bitwise identical at any thread count.
    let rep_rngs: Vec<Rng> = (0..cfg.reps).map(|rep| rng.fork(rep as u64)).collect();
    let mut partials: Vec<Vec<f64>> = vec![vec![0.0f64; cfg.sample_sizes.len()]; cfg.reps];
    let pool = crate::kernel::global();
    {
        let scaled_truth = &scaled_truth;
        let proto = &proto;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(cfg.reps);
        for (mut rep_rng, out) in rep_rngs.into_iter().zip(partials.iter_mut()) {
            tasks.push(Box::new(move || {
                let mut engine = OracleEngine::new(
                    shape,
                    problem.m,
                    problem.n,
                    cfg.r,
                    proto.as_ref().map(|s| s.clone_box()),
                );
                let mut mean = Mat::zeros(problem.m, problem.n);
                let mut next_ckpt = 0usize;
                for t in 1..=n_max {
                    let a = problem.sample_a(&mut rep_rng);
                    let est = engine.step(problem, w, &a, &mut rep_rng, cfg.zo_sigma);
                    // running mean: ḡ_t = ḡ_{t−1} + (ĝ_t − ḡ_{t−1})/t
                    let inv_t = 1.0 / t as f64;
                    for (m_v, e_v) in mean.data.iter_mut().zip(&est.data) {
                        *m_v += (e_v - *m_v) * inv_t;
                    }
                    while next_ckpt < cfg.sample_sizes.len()
                        && cfg.sample_sizes[next_ckpt] == t
                    {
                        out[next_ckpt] += mean.sub(scaled_truth).fro_norm_sq();
                        next_ckpt += 1;
                    }
                }
            }));
        }
        pool.run(tasks);
    }
    // Combine rep partials in rep order — bitwise the serial
    // rep-by-rep accumulation.
    let mut sums = vec![0.0f64; cfg.sample_sizes.len()];
    for p in &partials {
        for (s, v) in sums.iter_mut().zip(p) {
            *s += *v;
        }
    }

    let points = cfg
        .sample_sizes
        .iter()
        .zip(&sums)
        .map(|(&n, &s)| (n, s / cfg.reps as f64))
        .collect();
    MseCurve { label: format!("{}-{}", cfg.spec.label(), cfg.family.name()), c: cfg.c, points }
}

/// One-shot (N = 1) MSE of an estimator — used by tests to compare
/// against the §5 closed forms.
pub fn one_shot_mse(problem: &ToyProblem, w: &Mat, cfg: &MseCurveConfig, draws: usize) -> f64 {
    let mut c2 = cfg.clone();
    c2.sample_sizes = vec![1];
    c2.reps = draws;
    mse_curve(problem, w, &c2).points[0].1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(family: Family, spec: EstimatorSpec, c: f64) -> MseCurveConfig {
        MseCurveConfig {
            family,
            spec,
            c,
            r: 4,
            sample_sizes: vec![5, 25, 125],
            reps: 24,
            seed: 99,
            zo_sigma: 1e-2,
            warmup: 120,
        }
    }

    #[test]
    fn unbiased_curves_decay_roughly_as_one_over_n() {
        let p = ToyProblem::small(31);
        let w = p.eval_point(32);
        let cfg = small_cfg(Family::Ipa, EstimatorSpec::FullRank, 1.0);
        let curve = mse_curve(&p, &w, &cfg);
        let (n0, m0) = curve.points[0];
        let (n2, m2) = curve.points[2];
        let ratio = m0 / m2;
        let expect = n2 as f64 / n0 as f64; // 25×
        assert!(
            ratio > expect * 0.4 && ratio < expect * 2.5,
            "MSE decay ratio {ratio}, expected ≈ {expect}"
        );
    }

    #[test]
    fn weakly_biased_curve_plateaus_at_bias_floor() {
        let p = ToyProblem::small(33);
        let w = p.eval_point(34);
        let c = 0.3;
        let cfg = small_cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Stiefel), c);
        let curve = mse_curve(&p, &w, &cfg);
        let g = p.true_gradient(&w);
        let floor = (1.0 - c) * (1.0 - c) * g.fro_norm_sq();
        let last = curve.points.last().unwrap().1;
        assert!(
            last > 0.6 * floor,
            "biased curve fell below its bias floor: {last} < {floor}"
        );
        // and the floor dominates the tail (variance mostly averaged out)
        assert!(last < 3.0 * floor, "tail {last} ≫ floor {floor}");
    }

    #[test]
    fn stiefel_one_shot_mse_matches_closed_form() {
        // exact check of Prop 1 + Thm 2 via simulation (IPA family)
        let p = ToyProblem::small(35);
        let w = p.eval_point(36);
        let mut rng = Rng::new(37);
        let sxi = p.sigma_xi_empirical(&w, &mut rng, 3000, Family::Ipa, 1e-2);
        let sth = p.sigma_theta(&w);
        let (n, r, c) = (p.n, 4usize, 1.0);
        let predicted = crate::estimator::theory::mse_isotropic_exact(
            n, r, c, sxi.trace(), sth.trace(),
        );
        let cfg = small_cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Stiefel), c);
        let measured = one_shot_mse(&p, &w, &cfg, 3000);
        let rel = (measured - predicted).abs() / predicted;
        assert!(rel < 0.15, "one-shot MSE {measured} vs closed form {predicted} (rel {rel})");
    }

    #[test]
    fn gaussian_one_shot_mse_exceeds_stiefel() {
        // the Fig 2/3 ordering at matched (c, r): Gaussian > Stiefel.
        let p = ToyProblem::small(39);
        let w = p.eval_point(40);
        let cfg_g = small_cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Gaussian), 1.0);
        let cfg_s = small_cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Stiefel), 1.0);
        let mse_g = one_shot_mse(&p, &w, &cfg_g, 2500);
        let mse_s = one_shot_mse(&p, &w, &cfg_s, 2500);
        assert!(
            mse_g > 1.1 * mse_s,
            "Gaussian one-shot MSE {mse_g} should exceed Stiefel {mse_s}"
        );
    }

    #[test]
    fn dependent_one_shot_mse_below_stiefel() {
        // the Fig 4/5 ordering: Dependent < independent (Stiefel).
        let p = ToyProblem::small(41);
        let w = p.eval_point(42);
        let cfg_d = small_cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Dependent), 1.0);
        let cfg_s = small_cfg(Family::Ipa, EstimatorSpec::LowRank(ProjectorKind::Stiefel), 1.0);
        let mse_d = one_shot_mse(&p, &w, &cfg_d, 2500);
        let mse_s = one_shot_mse(&p, &w, &cfg_s, 2500);
        assert!(
            mse_d < mse_s,
            "Dependent one-shot MSE {mse_d} should be below Stiefel {mse_s}"
        );
    }
}
