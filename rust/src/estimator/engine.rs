//! The estimator engine — the crate's **single** implementation of the
//! paper's Algorithm-1 step pipeline:
//!
//! ```text
//!   project  →  estimate  →  lift  →  update
//! ```
//!
//! draw a projector V (and, for the LR/ZO family, a perturbation Z),
//! obtain the raw gradient signal (a closed-form oracle on the toy
//! problem, artifact outputs in training), lift the low-rank estimate
//! back to the ambient space, and apply the update. Before this module
//! existed the pipeline was implemented three times — `estimator/toy.rs`
//! for §6.1, `coordinator/finetune.rs` for Table 1, and
//! `coordinator/pretrain.rs` for Figures 7–9 — each with its own
//! per-step allocation churn. Both instantiations here own preallocated
//! workspaces, so the steady-state step loop reuses every buffer:
//!
//! * [`GradEstimator`] — the f32, artifact-driven engine the finetune
//!   and pretrain trainers route through. One [`GradEstimator::step`]
//!   covers all four method shapes ([`MethodShape`]); the LowRank-LR
//!   and LowRank-IPA paths are heap-allocation-free after warm-up on a
//!   serial pool (the `engine_alloc` test and `train_step` bench pin
//!   this down), and the parallel fan-out stages its disjoint store
//!   views through a reusable [`crate::model::MutManyScratch`].
//! * [`OracleEngine`] — the f64, oracle-driven engine behind the §6.1
//!   MSE study ([`super::mse`]): the same four shapes forming one-shot
//!   estimates against [`ToyProblem`]'s closed-form gradient.
//!
//! Both run every dense op through [`crate::kernel`], so the bitwise
//! serial ≡ parallel guarantee of the substrate lifts to whole training
//! trajectories and MSE curves.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::toy::ToyProblem;
use super::Family;
use crate::coordinator::{FullSlot, MatrixSlot, SubspaceSet};
use crate::kernel;
use crate::linalg::{matmul, Mat};
use crate::model::ParamStore;
use crate::optim::{Adam, AdamConfig};
use crate::projection::ProjectionSampler;
use crate::rng::Rng;

/// The four estimator shapes of Algorithm 1 (paper Examples 1–3):
/// {IPA, LR} × {full-rank, low-rank}.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodShape {
    /// Full-rank pathwise gradient (backprop), plain optimizer step.
    FullIpa,
    /// Rank-r reparameterization W = Θ + B·Vᵀ; dB from the estimate
    /// source, subspace optimizer on B (Example 1 projected).
    LowRankIpa,
    /// Full-rank antithetic two-point ZO (Example 2), SGD on Θ.
    FullLr,
    /// Rank-r antithetic ZO over σ·Z·Vᵀ (Example 3(ii)), subspace
    /// optimizer on B with ĝ_B = scale·Z, Θ kept lifted.
    LowRankLr,
}

impl MethodShape {
    pub fn of(family: Family, low_rank: bool) -> MethodShape {
        match (family, low_rank) {
            (Family::Ipa, false) => MethodShape::FullIpa,
            (Family::Ipa, true) => MethodShape::LowRankIpa,
            (Family::Lr, false) => MethodShape::FullLr,
            (Family::Lr, true) => MethodShape::LowRankLr,
        }
    }

    pub fn family(&self) -> Family {
        match self {
            MethodShape::FullIpa | MethodShape::LowRankIpa => Family::Ipa,
            MethodShape::FullLr | MethodShape::LowRankLr => Family::Lr,
        }
    }

    pub fn is_low_rank(&self) -> bool {
        matches!(self, MethodShape::LowRankIpa | MethodShape::LowRankLr)
    }

    /// LR/ZO family — the shapes that draw per-step perturbations.
    pub fn is_lr(&self) -> bool {
        self.family() == Family::Lr
    }

    pub fn name(&self) -> &'static str {
        match self {
            MethodShape::FullIpa => "full-ipa",
            MethodShape::LowRankIpa => "lowrank-ipa",
            MethodShape::FullLr => "full-lr",
            MethodShape::LowRankLr => "lowrank-lr",
        }
    }
}

/// (G·V)·Vᵀ — project a gradient onto span(V) and lift back: the
/// low-rank estimator's defining map, O(mnr), never forming P = VVᵀ.
pub fn project_lift(g: &Mat, v: &Mat) -> Mat {
    assert_eq!(
        g.cols, v.rows,
        "project_lift: G is {}x{}, V is {}x{}",
        g.rows, g.cols, v.rows, v.cols
    );
    let gv = matmul(g, v); // m×r
    let mut out = Mat::zeros(g.rows, v.rows);
    kernel::auto::gemm_nt(1.0f64, &gv.data, &v.data, &mut out.data, g.rows, v.rows, v.cols);
    out
}

// ---------------------------------------------------------------------------
// f32 trainer engine
// ---------------------------------------------------------------------------

/// A full-rank ZO perturbation target (FullLr): one parameter tensor
/// perturbed by σ·Z and updated by Θ ← Θ − lr·scale·Z. (The tensor's
/// name lives in the `ParamStore` spec at `param_pos`.)
pub struct ZoTarget {
    pub param_pos: usize,
    pub m: usize,
    pub n: usize,
}

/// The distinguished classifier-head channel of the finetune trainer:
/// full-rank, with its own Adam moments and its own per-step Z draw
/// (drawn *before* the slot Z's — the canonical stream order).
pub struct HeadChannel {
    pub param_pos: usize,
    pub adam: Adam,
    /// Per-step perturbation; stays all-zero for the IPA shapes (the
    /// artifacts still take a `z_head` input there).
    z: Arc<Vec<f32>>,
    /// Scaled-gradient scratch g = scale·z.
    g: Vec<f32>,
}

impl HeadChannel {
    /// Share the head Z buffer for zero-copy input staging.
    pub fn z_arc(&self) -> Arc<Vec<f32>> {
        self.z.clone()
    }
}

/// Per-step gradient signal from the estimate source (artifact outputs
/// in training, synthetic values in tests/benches).
pub enum GradSignal<'a> {
    /// LR family: the two antithetic forward losses F(Θ±σΔ).
    Antithetic { f_plus: f32, f_minus: f32 },
    /// IPA family: per-slot gradient views — subspace dB's first (in
    /// slot order), then the full-rank dΘ's (in `ipa_full` order) —
    /// plus the optional head gradient. `grad_norm` short-circuits the
    /// engine's norm when the caller already computed it (pretrain's
    /// global-norm clip).
    Grads {
        loss: f32,
        slots: &'a [&'a [f32]],
        head: Option<&'a [f32]>,
        grad_norm: Option<f32>,
    },
}

/// What one engine step reports back to the trainer's metrics log.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f32,
    pub grad_norm: f32,
}

/// The f32 Algorithm-1 pipeline object: owns the subspace state
/// (B, V, Adam per matrix), the full-rank channels, and every per-step
/// scratch buffer, and exposes one [`step`](Self::step) covering all
/// four [`MethodShape`]s. Perturbation buffers are `Arc`-backed so the
/// trainers stage them into artifact inputs without copying.
pub struct GradEstimator {
    pub shape: MethodShape,
    /// ZO perturbation scale σ (LR shapes).
    pub sigma: f32,
    /// Low-rank (B, V, Adam) state — `Some` for the low-rank shapes.
    pub subspace: Option<SubspaceSet>,
    /// Full-rank ZO targets (FullLr shape).
    pub full_lr: Vec<ZoTarget>,
    /// Full-rank IPA gradient targets with their Adam moments
    /// (FullIpa: every trainable; LowRankIpa: embeddings/norms).
    pub ipa_full: Vec<FullSlot>,
    /// Optional head channel (finetune).
    pub head: Option<HeadChannel>,
    /// Per-slot perturbation draws, reused every step (LR shapes).
    z: Vec<Arc<Vec<f32>>>,
    /// Per-slot scaled-gradient scratch (LowRankLr).
    g: Vec<Vec<f32>>,
    /// Per-slot previous-B scratch for the Θ delta push (LowRankLr).
    b_prev: Vec<Vec<f32>>,
    /// Cached store positions of the LowRankLr slot fan-out.
    lr_positions: Vec<usize>,
    /// Cached store positions of the `ipa_full` fan-out.
    ipa_positions: Vec<usize>,
    /// Reusable view-staging workspace for the parallel fan-out
    /// ([`ParamStore::f32_mut_many_with`]) — no per-step Vec churn.
    mut_many_scratch: crate::model::MutManyScratch,
}

impl GradEstimator {
    /// Assemble an engine. `head` is `(store position, element count,
    /// Adam config)` for the finetune head channel.
    pub fn new(
        shape: MethodShape,
        sigma: f32,
        subspace: Option<SubspaceSet>,
        full_lr: Vec<ZoTarget>,
        ipa_full: Vec<FullSlot>,
        head: Option<(usize, usize, AdamConfig)>,
    ) -> Self {
        let (z, g, b_prev, lr_positions) = match shape {
            MethodShape::LowRankLr => {
                let sub = subspace.as_ref().expect("LowRankLr engine needs a subspace");
                (
                    sub.slots.iter().map(|s| Arc::new(vec![0.0f32; s.m * s.r])).collect(),
                    sub.slots.iter().map(|s| vec![0.0f32; s.m * s.r]).collect(),
                    sub.slots.iter().map(|s| vec![0.0f32; s.m * s.r]).collect(),
                    sub.slots.iter().map(|s| s.param_pos).collect(),
                )
            }
            MethodShape::FullLr => (
                full_lr.iter().map(|t| Arc::new(vec![0.0f32; t.m * t.n])).collect(),
                Vec::new(),
                Vec::new(),
                Vec::new(),
            ),
            _ => (Vec::new(), Vec::new(), Vec::new(), Vec::new()),
        };
        let ipa_positions = ipa_full.iter().map(|f| f.param_pos).collect();
        let head = head.map(|(param_pos, len, cfg)| HeadChannel {
            param_pos,
            adam: Adam::new(len, cfg),
            z: Arc::new(vec![0.0f32; len]),
            g: vec![0.0f32; len],
        });
        GradEstimator {
            shape,
            sigma,
            subspace,
            full_lr,
            ipa_full,
            head,
            z,
            g,
            b_prev,
            lr_positions,
            ipa_positions,
            mut_many_scratch: crate::model::MutManyScratch::new(),
        }
    }

    /// Share slot `i`'s perturbation buffer for zero-copy staging.
    pub fn z_arc(&self, i: usize) -> Arc<Vec<f32>> {
        self.z[i].clone()
    }

    /// Share the head perturbation buffer for zero-copy staging.
    pub fn head_z_arc(&self) -> Arc<Vec<f32>> {
        self.head.as_ref().expect("engine has no head channel").z_arc()
    }

    /// Apply a rank-controller shrink to subspace slot `i`: re-layout
    /// the slot's (B, V, Adam, frame, staging pads) through
    /// [`SubspaceSet::shrink_slot_rank`], then re-size this engine's own
    /// per-slot LR scratch (Z, g, B_prev — present for the LowRankLr
    /// shape, empty otherwise) to the new m·r footprint, releasing the
    /// tail capacity so the shrink shows up in measured memory.
    pub fn shrink_slot_rank(&mut self, i: usize, new_r: usize) -> Result<()> {
        let sub = self.subspace.as_mut().context("engine has no subspace to shrink")?;
        sub.shrink_slot_rank(i, new_r)?;
        let len = sub.slots[i].m * sub.slots[i].r;
        if let Some(z) = self.z.get_mut(i) {
            let z = Arc::make_mut(z);
            z.clear();
            z.resize(len, 0.0);
            z.shrink_to_fit();
        }
        for buf in [self.g.get_mut(i), self.b_prev.get_mut(i)].into_iter().flatten() {
            buf.clear();
            buf.resize(len, 0.0);
            buf.shrink_to_fit();
        }
        Ok(())
    }

    /// Run one estimator-quality probe against subspace slot `i`: feed
    /// the projected gradient `db` (`[m, r]`, e.g. the trainer's staged
    /// reduced dB) and a probe direction `u` (same layout, drawn from
    /// the dedicated probe stream) through
    /// [`crate::obs::quality::probe_slot`] with the slot's live frame V
    /// and the subspace's weak-unbiasedness scale c. Read-only — no
    /// training state, no trainer RNG, no kernel pool — so calling it
    /// (or not) never changes what is trained. Returns `None` when the
    /// engine has no subspace, `i` is out of range, or the buffers do
    /// not match the slot's active `[m, r]` layout (e.g. a stale stage
    /// across a rank shrink).
    pub fn probe_quality(
        &self,
        i: usize,
        db: &[f32],
        u: &[f32],
    ) -> Option<crate::obs::quality::SlotProbe> {
        let sub = self.subspace.as_ref()?;
        let slot = sub.slots.get(i)?;
        let len = slot.m * slot.r;
        if db.len() != len || u.len() != len || slot.v.len() != slot.n * slot.r {
            return None;
        }
        Some(crate::obs::quality::probe_slot(
            db,
            slot.v.as_slice(),
            slot.m,
            slot.n,
            slot.r,
            sub.c,
            u,
        ))
    }

    /// Draw the per-step perturbations in place (LR shapes; a no-op for
    /// the IPA shapes, whose head Z stays zero). Stream order is the
    /// canonical one the pre-engine trainers used: head Z first, then
    /// one buffer per slot in slot order. Buffers are unshared by the
    /// time this runs (staged clones die right after `execute`), so the
    /// fill is in-place and allocation-free in steady state.
    pub fn draw_perturbations(&mut self, rng: &mut Rng) {
        if !self.shape.is_lr() {
            return;
        }
        let _span = crate::obs::span("engine", "draw_perturbations");
        if let Some(h) = &mut self.head {
            for zi in Arc::make_mut(&mut h.z).iter_mut() {
                *zi = rng.normal() as f32;
            }
        }
        for z in &mut self.z {
            for zi in Arc::make_mut(z).iter_mut() {
                *zi = rng.normal() as f32;
            }
        }
    }

    /// One Algorithm-1 update: consume the step's gradient signal and
    /// apply the shape's optimizer update to `store`. Per-matrix work
    /// fans out across the kernel pool (bitwise equal to serial); on a
    /// single-thread pool the LowRank-LR path runs inline without
    /// boxing tasks, keeping the steady-state loop heap-allocation-free.
    pub fn step(
        &mut self,
        store: &mut ParamStore,
        signal: GradSignal<'_>,
        lr: f32,
    ) -> Result<StepStats> {
        // one span per engine step, named by shape — the "update" phase
        // of the trainers' step breakdown (disabled: one relaxed load,
        // no clock, no heap — the engine_alloc contract is untouched)
        let _span = crate::obs::span("engine", self.shape.name());
        match self.shape {
            MethodShape::FullIpa => {
                let GradSignal::Grads { loss, slots, grad_norm, .. } = signal else {
                    bail!("FullIpa step expects per-slot gradients");
                };
                if slots.len() != self.ipa_full.len() {
                    bail!(
                        "FullIpa step got {} gradients for {} slots",
                        slots.len(),
                        self.ipa_full.len()
                    );
                }
                let mut norm_sq = 0f64;
                for (fslot, g) in self.ipa_full.iter_mut().zip(slots) {
                    norm_sq += g.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
                    fslot.adam.step(store.f32_mut(fslot.param_pos)?, g, lr);
                }
                let grad_norm = grad_norm.unwrap_or_else(|| norm_sq.sqrt() as f32);
                Ok(StepStats { loss, grad_norm })
            }

            MethodShape::LowRankIpa => {
                let GradSignal::Grads { loss, slots, head, grad_norm } = signal else {
                    bail!("LowRankIpa step expects per-slot gradients");
                };
                let sub = self.subspace.as_mut().context("LowRankIpa engine has no subspace")?;
                let n_sub = sub.slots.len();
                if slots.len() != n_sub + self.ipa_full.len() {
                    bail!(
                        "LowRankIpa step got {} gradients for {} subspace + {} full slots",
                        slots.len(),
                        n_sub,
                        self.ipa_full.len()
                    );
                }
                // grad norm over the dB's only (the finetune metric) —
                // skipped entirely when the caller already computed one
                // (pretrain passes its global-norm clip result).
                let grad_norm = grad_norm.unwrap_or_else(|| {
                    let mut norm_sq = 0f64;
                    for g in &slots[..n_sub] {
                        norm_sq += g.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
                    }
                    norm_sq.sqrt() as f32
                });
                // per-slot Adam steps fan out across the kernel pool
                sub.adam_step_all(&slots[..n_sub], lr);
                // full-rank channels (embeddings/norms), same fan-out
                if !self.ipa_full.is_empty() {
                    let fgrads = &slots[n_sub..];
                    let pool = kernel::global();
                    if pool.threads() == 1 {
                        for (fslot, g) in self.ipa_full.iter_mut().zip(fgrads) {
                            fslot.adam.step(store.f32_mut(fslot.param_pos)?, g, lr);
                        }
                    } else {
                        let ipa_full = &mut self.ipa_full;
                        store.f32_mut_many_with(
                            &self.ipa_positions,
                            &mut self.mut_many_scratch,
                            |params: &mut Vec<&mut [f32]>| {
                                let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                                    Vec::with_capacity(ipa_full.len());
                                for ((fslot, p), g) in
                                    ipa_full.iter_mut().zip(params.drain(..)).zip(fgrads)
                                {
                                    tasks.push(Box::new(move || fslot.adam.step(p, g, lr)));
                                }
                                pool.run(tasks);
                            },
                        )?;
                    }
                }
                if let Some(h) = &mut self.head {
                    if let Some(gh) = head {
                        h.adam.step(store.f32_mut(h.param_pos)?, gh, lr);
                    }
                }
                Ok(StepStats { loss, grad_norm })
            }

            MethodShape::LowRankLr => {
                let GradSignal::Antithetic { f_plus, f_minus } = signal else {
                    bail!("LowRankLr step expects antithetic losses");
                };
                let scale = (f_plus - f_minus) / (2.0 * self.sigma);
                let sub = self.subspace.as_mut().context("LowRankLr engine has no subspace")?;
                // ĝ_B = scale·Z; Adam step on B, then push the *delta*
                // into Θ so Θ stays the lifted point. Slots touch
                // disjoint (B, Adam, Θ, scratch) tuples, so the whole
                // update fans out across the kernel pool.
                let pool = kernel::global();
                if pool.threads() == 1 {
                    for (((slot, z), g), bp) in sub
                        .slots
                        .iter_mut()
                        .zip(self.z.iter())
                        .zip(self.g.iter_mut())
                        .zip(self.b_prev.iter_mut())
                    {
                        let theta = store.f32_mut(slot.param_pos)?;
                        lowrank_lr_slot_update(slot, z.as_slice(), g, bp, theta, scale, lr);
                    }
                } else {
                    let (zs, gs, bps) = (&self.z, &mut self.g, &mut self.b_prev);
                    store.f32_mut_many_with(
                        &self.lr_positions,
                        &mut self.mut_many_scratch,
                        |thetas: &mut Vec<&mut [f32]>| {
                            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                                Vec::with_capacity(sub.slots.len());
                            for ((((slot, theta), z), g), bp) in sub
                                .slots
                                .iter_mut()
                                .zip(thetas.drain(..))
                                .zip(zs.iter())
                                .zip(gs.iter_mut())
                                .zip(bps.iter_mut())
                            {
                                tasks.push(Box::new(move || {
                                    lowrank_lr_slot_update(
                                        slot,
                                        z.as_slice(),
                                        g,
                                        bp,
                                        theta,
                                        scale,
                                        lr,
                                    )
                                }));
                            }
                            pool.run(tasks);
                        },
                    )?;
                }
                if let Some(h) = &mut self.head {
                    for (gi, zi) in h.g.iter_mut().zip(h.z.iter()) {
                        *gi = scale * *zi;
                    }
                    h.adam.step(store.f32_mut(h.param_pos)?, &h.g, lr);
                }
                Ok(StepStats { loss: (f_plus + f_minus) * 0.5, grad_norm: scale.abs() })
            }

            MethodShape::FullLr => {
                let GradSignal::Antithetic { f_plus, f_minus } = signal else {
                    bail!("FullLr step expects antithetic losses");
                };
                let scale = (f_plus - f_minus) / (2.0 * self.sigma);
                // MeZO-style SGD: Θ ← Θ − lr·scale·Z (kernel AXPY)
                let pool = kernel::global();
                let alpha = -(lr * scale);
                for (target, z) in self.full_lr.iter().zip(self.z.iter()) {
                    kernel::axpy(&pool, alpha, z.as_slice(), store.f32_mut(target.param_pos)?);
                }
                if let Some(h) = &mut self.head {
                    kernel::axpy(&pool, alpha, h.z.as_slice(), store.f32_mut(h.param_pos)?);
                }
                Ok(StepStats { loss: (f_plus + f_minus) * 0.5, grad_norm: scale.abs() })
            }
        }
    }
}

/// One LowRank-LR slot update, allocation-free: g ← scale·z, Adam on B,
/// Θ += (B_new − B_old)·Vᵀ through the serial GEMM body (parallelism
/// stays one level deep — the slot fan-out above this call).
fn lowrank_lr_slot_update(
    slot: &mut MatrixSlot,
    z: &[f32],
    g: &mut [f32],
    b_prev: &mut [f32],
    theta: &mut [f32],
    scale: f32,
    lr: f32,
) {
    for (gi, zi) in g.iter_mut().zip(z) {
        *gi = scale * *zi;
    }
    b_prev.copy_from_slice(slot.b.as_slice());
    slot.adam.step(Arc::make_mut(&mut slot.b), g, lr);
    // reuse g as the B delta (the gradient is spent)
    for (d, (bn, bo)) in g.iter_mut().zip(slot.b.iter().zip(b_prev.iter())) {
        *d = *bn - *bo;
    }
    kernel::serial::gemm_nt(1.0f32, g, slot.v.as_slice(), theta, slot.m, slot.n, slot.r);
}

// ---------------------------------------------------------------------------
// f64 oracle engine (§6.1 toy study)
// ---------------------------------------------------------------------------

/// The f64 instantiation of the pipeline: one-shot estimates against the
/// toy problem's closed-form gradient oracle, with every intermediate
/// (Z draw, lifted direction, antithetic points, projection, estimate)
/// living in preallocated workspaces.
pub struct OracleEngine {
    pub shape: MethodShape,
    m: usize,
    n: usize,
    r: usize,
    sampler: Option<Box<dyn ProjectionSampler + Send + Sync>>,
    /// Current projector draw V (n×r).
    v: Mat,
    /// Perturbation draw Z (m×r low-rank, m×n full-rank).
    z: Mat,
    /// Lifted perturbation direction Z·Vᵀ (m×n).
    dir: Mat,
    /// Antithetic evaluation points W ± σΔ.
    wp: Mat,
    wm: Mat,
    /// Raw full-rank IPA estimate ĝ (m×n).
    ghat: Mat,
    /// Projection scratch ĝ·V (m×r).
    gv: Mat,
    /// The step's estimate (m×n).
    est: Mat,
}

impl OracleEngine {
    /// Build an engine for an m×n decision variable. `r` and `sampler`
    /// are consumed by the low-rank shapes only (`r` ignored, `sampler`
    /// unused otherwise).
    pub fn new(
        shape: MethodShape,
        m: usize,
        n: usize,
        r: usize,
        sampler: Option<Box<dyn ProjectionSampler + Send + Sync>>,
    ) -> Self {
        assert!(
            !shape.is_low_rank() || sampler.is_some(),
            "low-rank shape {} needs a projection sampler",
            shape.name()
        );
        let empty = || Mat::zeros(0, 0);
        let (z, dir) = match shape {
            MethodShape::LowRankLr => (Mat::zeros(m, r), Mat::zeros(m, n)),
            MethodShape::FullLr => (Mat::zeros(m, n), empty()),
            _ => (empty(), empty()),
        };
        let (wp, wm) = if shape.is_lr() {
            (Mat::zeros(m, n), Mat::zeros(m, n))
        } else {
            (empty(), empty())
        };
        let (ghat, gv) = if shape == MethodShape::LowRankIpa {
            (Mat::zeros(m, n), Mat::zeros(m, r))
        } else {
            (empty(), empty())
        };
        OracleEngine {
            shape,
            m,
            n,
            r,
            sampler,
            v: empty(),
            z,
            dir,
            wp,
            wm,
            ghat,
            gv,
            est: Mat::zeros(m, n),
        }
    }

    /// Rank budget r (0 for the full-rank shapes).
    pub fn rank(&self) -> usize {
        self.r
    }

    /// One project→estimate→lift pass: form this step's estimate at
    /// evaluation point `w` for the data draw `a`, consuming (V, Z)
    /// draws from `rng` in the canonical order (V before Z). Returns a
    /// view of the workspace estimate — valid until the next step.
    pub fn step(
        &mut self,
        problem: &ToyProblem,
        w: &Mat,
        a: &[f64],
        rng: &mut Rng,
        zo_sigma: f64,
    ) -> &Mat {
        match self.shape {
            MethodShape::FullIpa => {
                problem.ipa_estimate_into(w, a, &mut self.est);
            }
            MethodShape::LowRankIpa => {
                self.v = self.sampler.as_mut().expect("sampler").sample(rng);
                problem.ipa_estimate_into(w, a, &mut self.ghat);
                // est = (ĝ·V)·Vᵀ
                for x in &mut self.gv.data {
                    *x = 0.0;
                }
                kernel::auto::gemm_nn(
                    &self.ghat.data,
                    &self.v.data,
                    &mut self.gv.data,
                    self.m,
                    self.n,
                    self.r,
                );
                for x in &mut self.est.data {
                    *x = 0.0;
                }
                kernel::auto::gemm_nt(
                    1.0f64,
                    &self.gv.data,
                    &self.v.data,
                    &mut self.est.data,
                    self.m,
                    self.n,
                    self.r,
                );
            }
            MethodShape::FullLr => {
                for zi in &mut self.z.data {
                    *zi = rng.normal();
                }
                self.wp.data.copy_from_slice(&w.data);
                self.wp.axpy_inplace(zo_sigma, &self.z);
                self.wm.data.copy_from_slice(&w.data);
                self.wm.axpy_inplace(-zo_sigma, &self.z);
                let scale =
                    (problem.loss(&self.wp, a) - problem.loss(&self.wm, a)) / (2.0 * zo_sigma);
                for (e, zi) in self.est.data.iter_mut().zip(&self.z.data) {
                    *e = *zi * scale;
                }
            }
            MethodShape::LowRankLr => {
                self.v = self.sampler.as_mut().expect("sampler").sample(rng);
                for zi in &mut self.z.data {
                    *zi = rng.normal();
                }
                // Δ = Z·Vᵀ, the rank-r perturbation direction
                for x in &mut self.dir.data {
                    *x = 0.0;
                }
                kernel::auto::gemm_nt(
                    1.0f64,
                    &self.z.data,
                    &self.v.data,
                    &mut self.dir.data,
                    self.m,
                    self.n,
                    self.r,
                );
                self.wp.data.copy_from_slice(&w.data);
                self.wp.axpy_inplace(zo_sigma, &self.dir);
                self.wm.data.copy_from_slice(&w.data);
                self.wm.axpy_inplace(-zo_sigma, &self.dir);
                let scale =
                    (problem.loss(&self.wp, a) - problem.loss(&self.wm, a)) / (2.0 * zo_sigma);
                for (e, di) in self.est.data.iter_mut().zip(&self.dir.data) {
                    *e = *di * scale;
                }
            }
        }
        &self.est
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::projection::{projector_matrix, StiefelSampler};

    #[test]
    fn project_lift_equals_g_times_p() {
        let mut rng = Rng::new(17);
        let g = Mat::from_fn(7, 9, |_, _| rng.normal());
        let mut s = StiefelSampler::new(9, 3, 1.0);
        let v = s.sample(&mut rng);
        let fast = project_lift(&g, &v);
        let p = projector_matrix(&v);
        let slow = matmul(&g, &p);
        assert!(fast.max_abs_diff(&slow) < 1e-9);
    }

    #[test]
    fn shape_table_is_consistent() {
        for (family, low_rank, want) in [
            (Family::Ipa, false, MethodShape::FullIpa),
            (Family::Ipa, true, MethodShape::LowRankIpa),
            (Family::Lr, false, MethodShape::FullLr),
            (Family::Lr, true, MethodShape::LowRankLr),
        ] {
            let s = MethodShape::of(family, low_rank);
            assert_eq!(s, want);
            assert_eq!(s.family(), family);
            assert_eq!(s.is_low_rank(), low_rank);
            assert_eq!(s.is_lr(), family == Family::Lr);
        }
    }

    #[test]
    fn probe_quality_reads_the_live_frame() {
        // An engine wrapped around an exact Theorem-2 frame must probe
        // at the optimum; malformed probes return None instead of
        // panicking mid-run.
        let (m, n, r, c) = (4usize, 12usize, 2usize, 1.0f64);
        let s = (c * n as f64 / r as f64).sqrt() as f32;
        let mut v = vec![0.0f32; n * r];
        for j in 0..r {
            v[j * r + j] = s;
        }
        let slot = MatrixSlot {
            name: "w".into(),
            m,
            n,
            r,
            r_max: r,
            b_input: usize::MAX,
            v_input: usize::MAX,
            db_output: usize::MAX,
            param_pos: 0,
            b: Arc::new(vec![0.0; m * r]),
            v: Arc::new(v),
            adam: crate::optim::Adam::new(m * r, AdamConfig::default()),
            frame: None,
            stage_b: None,
            stage_v: None,
        };
        let sub = SubspaceSet::from_slots(
            vec![slot],
            crate::projection::ProjectorKind::Stiefel,
            c,
        );
        let engine = GradEstimator::new(
            MethodShape::LowRankIpa,
            0.0,
            Some(sub),
            Vec::new(),
            Vec::new(),
            None,
        );
        let db: Vec<f32> = (0..m * r).map(|k| (k as f32 * 0.3).sin()).collect();
        let u: Vec<f32> = (0..m * r).map(|k| (k as f32 * 0.7).cos()).collect();
        let p = engine.probe_quality(0, &db, &u).expect("probe");
        assert!(p.sentinel.abs() < 1e-6, "sentinel {}", p.sentinel);
        assert!((p.mse_ratio - 1.0).abs() < 1e-6, "mse_ratio {}", p.mse_ratio);
        assert!(engine.probe_quality(0, &db[..m * r - 1], &u).is_none());
        assert!(engine.probe_quality(1, &db, &u).is_none());
    }

    #[test]
    fn oracle_lr_2pt_estimator_is_unbiased_for_quadratic() {
        // For a quadratic sample path the antithetic 2-point ZO estimator
        // is exactly unbiased (no O(σ²) smoothing bias).
        let p = ToyProblem::small(9);
        let w = p.eval_point(10);
        let g = p.true_gradient(&w);
        let mut rng = Rng::new(11);
        let mut engine = OracleEngine::new(MethodShape::FullLr, p.m, p.n, 0, None);
        let n_mc = 60_000;
        let mut mean = Mat::zeros(p.m, p.n);
        for _ in 0..n_mc {
            let a = p.sample_a(&mut rng);
            let est = engine.step(&p, &w, &a, &mut rng, 1e-2);
            mean.axpy_inplace(1.0 / n_mc as f64, est);
        }
        // O(mn/N) relative variance: the tolerance is statistical.
        let rel = mean.sub(&g).fro_norm() / g.fro_norm();
        assert!(rel < 0.25, "LR bias: rel err {rel}");
    }

    #[test]
    fn oracle_lowrank_ipa_weakly_unbiased_with_c() {
        // E[ĝ·P] = c·g — check at c = 0.5 through the engine pipeline.
        let p = ToyProblem::small(13);
        let w = p.eval_point(14);
        let g = p.true_gradient(&w);
        let c = 0.5;
        let sampler = Box::new(StiefelSampler::new(p.n, 4, c));
        let mut engine = OracleEngine::new(MethodShape::LowRankIpa, p.m, p.n, 4, Some(sampler));
        let mut rng = Rng::new(15);
        let n_mc = 20_000;
        let mut mean = Mat::zeros(p.m, p.n);
        for _ in 0..n_mc {
            let a = p.sample_a(&mut rng);
            let est = engine.step(&p, &w, &a, &mut rng, 1e-2);
            mean.axpy_inplace(1.0 / n_mc as f64, est);
        }
        let target = g.scaled(c);
        let rel = mean.sub(&target).fro_norm() / target.fro_norm();
        assert!(rel < 0.1, "LowRank-IPA weak-unbiasedness rel err {rel}");
    }

    #[test]
    fn oracle_engine_matches_inline_reference_bitwise() {
        // The engine's workspace-reusing arithmetic must be bit-for-bit
        // the pre-refactor per-step allocation style.
        let p = ToyProblem::small(21);
        let w = p.eval_point(22);
        let sampler = Box::new(StiefelSampler::new(p.n, 3, 1.0));
        let mut engine = OracleEngine::new(MethodShape::LowRankLr, p.m, p.n, 3, Some(sampler));
        let mut rng_e = Rng::new(77);
        let mut rng_r = Rng::new(77);
        let sigma = 1e-2;
        for _ in 0..5 {
            let a_e = p.sample_a(&mut rng_e);
            let est = engine.step(&p, &w, &a_e, &mut rng_e, sigma).clone();

            // reference: fresh allocations, old-style ops
            let a_r = p.sample_a(&mut rng_r);
            assert_eq!(a_e, a_r);
            let mut s = StiefelSampler::new(p.n, 3, 1.0);
            let v = s.sample(&mut rng_r);
            let z = Mat::from_fn(p.m, 3, |_, _| rng_r.normal());
            let zvt = crate::linalg::matmul_nt(&z, &v);
            let mut wp = w.clone();
            wp.axpy_inplace(sigma, &zvt);
            let mut wm = w.clone();
            wm.axpy_inplace(-sigma, &zvt);
            let scale = (p.loss(&wp, &a_r) - p.loss(&wm, &a_r)) / (2.0 * sigma);
            let want = zvt.scaled(scale);
            for (x, y) in est.data.iter().zip(&want.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
