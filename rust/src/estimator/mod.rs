//! Stochastic gradient estimators and their MSE theory (paper §3–§5)
//! plus the §6.1 toy experiment.
//!
//! * [`engine`] — **the** Algorithm-1 pipeline: the single
//!   project→estimate→lift→update implementation behind every method
//!   shape. [`engine::GradEstimator`] (f32, preallocated workspaces,
//!   zero-copy staging) is what the finetune and pretrain trainers step;
//!   [`engine::OracleEngine`] (f64) is the same pipeline against the toy
//!   problem's closed-form oracle.
//! * [`theory`] — every closed form the paper derives: the Proposition 1
//!   MSE decomposition, the Theorem 2 floor `n²c²/r`, the exact MSE of
//!   isotropic-optimal and Gaussian projectors, Remark 1's baselines,
//!   Theorem 3's Φ_min, Proposition 4's full-rank-matching condition and
//!   the eq. (14) uniform bound.
//! * [`toy`] — the quadratic matrix-regression problem (19): data law,
//!   loss, closed-form gradient, and the raw IPA oracle the engine
//!   drives.
//! * [`mse`] — the Monte-Carlo harness that regenerates Figures 2–5
//!   (MSE versus sample size for each projector law and each c),
//!   fanning independent replications across the kernel pool.

pub mod engine;
pub mod mse;
pub mod theory;
pub mod toy;

/// Which classical gradient-estimation family (paper §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Infinitesimal perturbation analysis — pathwise gradients
    /// (backpropagation in NN training).
    Ipa,
    /// Likelihood-ratio / score-function — here the antithetic two-point
    /// ZO instance of Example 2.
    Lr,
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Ipa => "ipa",
            Family::Lr => "lr",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ipa" => Some(Family::Ipa),
            "lr" | "zo" => Some(Family::Lr),
            _ => None,
        }
    }
}
