//! Stochastic gradient estimators and their MSE theory (paper §3–§5)
//! plus the §6.1 toy experiment.
//!
//! * [`theory`] — every closed form the paper derives: the Proposition 1
//!   MSE decomposition, the Theorem 2 floor `n²c²/r`, the exact MSE of
//!   isotropic-optimal and Gaussian projectors, Remark 1's baselines,
//!   Theorem 3's Φ_min, Proposition 4's full-rank-matching condition and
//!   the eq. (14) uniform bound.
//! * [`toy`] — the quadratic matrix-regression problem (19) with its
//!   closed-form gradient, IPA and two-point-LR estimators, and their
//!   low-rank projections.
//! * [`mse`] — the Monte-Carlo harness that regenerates Figures 2–5
//!   (MSE versus sample size for each projector law and each c).

pub mod mse;
pub mod theory;
pub mod toy;

/// Which classical gradient-estimation family (paper §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Infinitesimal perturbation analysis — pathwise gradients
    /// (backpropagation in NN training).
    Ipa,
    /// Likelihood-ratio / score-function — here the antithetic two-point
    /// ZO instance of Example 2.
    Lr,
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Ipa => "ipa",
            Family::Lr => "lr",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ipa" => Some(Family::Ipa),
            "lr" | "zo" => Some(Family::Lr),
            _ => None,
        }
    }
}
