//! The `lowrank-sge serve` daemon: accept loop, per-connection
//! handlers, and the session scheduler.
//!
//! Threading contract: connection handler threads touch *only* the
//! [`JobTable`] mutex (submit / status / cancel / fetch / shutdown).
//! The scheduler runs on the caller's thread and is the sole owner of
//! the [`Runtime`], the [`BaseModelCache`], and every live session —
//! trainer state never crosses threads, and the table mutex is held
//! only for short bookkeeping sections, never across a training step.
//!
//! Scheduling is round-robin fair: one optimizer step per active
//! session per pass over the shared kernel pool, with the pool's
//! per-job task tag ([`crate::kernel::pool::set_task_job`]) set around
//! each slice so pool metrics split per tenant. Because every session
//! owns all of its mutable state, interleaving changes nothing about
//! any job's trajectory — a single-job serve run is bitwise identical
//! to the standalone `finetune` subcommand at the same seed (pinned by
//! `tests/serve_session.rs`).
//!
//! Failure isolation: a session whose step, eval, or background
//! checkpoint write ([`TrainSession::poll_saves`]) fails transitions
//! *that job* to `failed` with the error text reported over the status
//! verb; its neighbors keep stepping.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{Context, Result};

use super::base_cache::BaseModelCache;
use super::job::{JobSpec, JobState, JobTable};
use super::proto::{self, Request, Response};
use crate::comm::transport::Conn;
use crate::coordinator::{FinetuneSession, SessionStatus, TrainSession};
use crate::model::ParamStore;
use crate::runtime::Runtime;

/// Daemon configuration (`lowrank-sge serve` flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`host:port`; port 0 binds ephemerally — the bound
    /// address is announced on stdout).
    pub addr: String,
    pub artifacts_dir: PathBuf,
    /// Per-job checkpoint directories live at `<ckpt_root>/job-<id>`.
    pub ckpt_root: PathBuf,
    /// Sessions stepped concurrently (round-robin width).
    pub max_active: usize,
    /// Admission cap on open (queued + running) jobs.
    pub max_open: usize,
    /// Heap budget for admission (bytes, 0 = unlimited), read from the
    /// tracked-allocator ledger at submit time.
    pub mem_budget_bytes: usize,
    /// Concurrent client-connection cap.
    pub max_conns: usize,
    /// Per-connection idle read timeout (ms).
    pub idle_ms: u64,
    /// Kernel pool size (0 = leave the global pool as it is).
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            artifacts_dir: PathBuf::from("artifacts"),
            ckpt_root: PathBuf::from("serve-ckpt"),
            max_active: 2,
            max_open: 8,
            mem_budget_bytes: 0,
            max_conns: 16,
            idle_ms: 30_000,
            threads: 0,
        }
    }
}

/// What a completed daemon run did (returned after graceful shutdown).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeReport {
    pub done: usize,
    pub failed: usize,
    pub cancelled: usize,
}

fn lock(table: &Mutex<JobTable>) -> MutexGuard<'_, JobTable> {
    table.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run the daemon to completion: bind, announce the address on stdout
/// (`[serve] listening on <addr>`), accept job-plane connections, and
/// schedule sessions until a `shutdown` verb drains the queue. Blocks
/// the calling thread (which owns all training state).
pub fn run_serve(cfg: ServeConfig) -> Result<ServeReport> {
    run_serve_with(cfg, None)
}

/// [`run_serve`] with an optional channel announcing the bound
/// address — the integration tests bind port 0 on a background thread
/// and need the ephemeral port back.
pub fn run_serve_with(
    cfg: ServeConfig,
    bound_tx: Option<std::sync::mpsc::Sender<std::net::SocketAddr>>,
) -> Result<ServeReport> {
    if cfg.threads > 0 {
        crate::kernel::set_global_threads(cfg.threads);
    }
    let mut rt = Runtime::new(&cfg.artifacts_dir)?;
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding the serve endpoint on {}", cfg.addr))?;
    let bound = listener.local_addr().context("reading the serve endpoint address")?;
    println!("[serve] listening on {bound}");
    if let Some(tx) = bound_tx {
        let _ = tx.send(bound);
    }

    let table = Arc::new(Mutex::new(JobTable::new(cfg.max_open, cfg.mem_budget_bytes)));
    let shutdown = Arc::new(AtomicBool::new(false));
    let conns = Arc::new(AtomicUsize::new(0));

    {
        let table = table.clone();
        let shutdown = shutdown.clone();
        let conns = conns.clone();
        let (max_conns, idle_ms) = (cfg.max_conns.max(1), cfg.idle_ms.max(1));
        std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(listener, table, shutdown, conns, max_conns, idle_ms))
            .context("spawning the serve accept thread")?;
    }

    scheduler_loop(&mut rt, &cfg, &table, &shutdown)
}

/// Accept connections until shutdown; over-cap clients get one `err`
/// line and an immediate close — never a handler thread.
fn accept_loop(
    listener: TcpListener,
    table: Arc<Mutex<JobTable>>,
    shutdown: Arc<AtomicBool>,
    conns: Arc<AtomicUsize>,
    max_conns: usize,
    idle_ms: u64,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // the listener is non-blocking for the shutdown poll;
                // accepted streams must block (with the idle timeout)
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let conn = Conn::Tcp(stream);
                let _ = conn.set_timeouts(Duration::from_millis(idle_ms));
                if conns.load(Ordering::SeqCst) >= max_conns {
                    let reply = Response::Err("connection cap reached".to_string());
                    let _ = proto::send_msg(&conn, 0, &reply.format());
                    continue; // dropped
                }
                conns.fetch_add(1, Ordering::SeqCst);
                let table = table.clone();
                let shutdown = shutdown.clone();
                let conns2 = conns.clone();
                let spawned = std::thread::Builder::new().name("serve-conn".into()).spawn(
                    move || {
                        conn_loop(&conn, &table, &shutdown);
                        conns2.fetch_sub(1, Ordering::SeqCst);
                    },
                );
                if spawned.is_err() {
                    conns.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Serve one client: request/reply lines until EOF or the idle read
/// timeout (the connection's io timeout) trips.
fn conn_loop(conn: &Conn, table: &Mutex<JobTable>, shutdown: &AtomicBool) {
    loop {
        let (seq, line) = match proto::recv_msg(conn) {
            Ok(m) => m,
            Err(_) => return, // EOF, idle timeout, or garbage: close
        };
        let reply = match Request::parse(&line) {
            Ok(req) => handle_request(req, table, shutdown),
            Err(e) => Response::Err(format!("{e:#}")),
        };
        if proto::send_msg(conn, seq, &reply.format()).is_err() {
            return;
        }
    }
}

/// The verb switch. Touches only the job table — never training state.
fn handle_request(req: Request, table: &Mutex<JobTable>, shutdown: &AtomicBool) -> Response {
    match req {
        Request::Ping => Response::Ok(vec![("pong".to_string(), "1".to_string())]),
        Request::Submit(fields) => {
            if shutdown.load(Ordering::SeqCst) {
                return Response::Err("daemon is draining".to_string());
            }
            let spec = match JobSpec::from_fields(&fields) {
                Ok(s) => s,
                Err(e) => return Response::Err(format!("{e:#}")),
            };
            match lock(table).submit(spec) {
                Ok(id) => Response::Ok(vec![
                    ("job".to_string(), id.to_string()),
                    ("state".to_string(), JobState::Queued.name().to_string()),
                ]),
                Err(e) => Response::Err(format!("{e:#}")),
            }
        }
        Request::Status { job } => job_reply(table, job, false),
        Request::Fetch { job } => job_reply(table, job, true),
        Request::Cancel { job } => match lock(table).request_cancel(job) {
            Ok(state) => Response::Ok(vec![
                ("job".to_string(), job.to_string()),
                ("state".to_string(), state.name().to_string()),
            ]),
            Err(e) => Response::Err(format!("{e:#}")),
        },
        Request::Shutdown => {
            shutdown.store(true, Ordering::SeqCst);
            let mut t = lock(table);
            t.cancel_queued();
            let draining = t.open_count();
            Response::Ok(vec![("draining".to_string(), draining.to_string())])
        }
    }
}

/// `status` / `fetch` reply for one job. `fetch` additionally insists
/// the job is terminal — polling clients use `status`.
fn job_reply(table: &Mutex<JobTable>, id: u64, terminal_only: bool) -> Response {
    let t = lock(table);
    let Some(job) = t.get(id) else {
        return Response::Err(format!("no job {id}"));
    };
    if terminal_only && job.state.is_open() {
        return Response::Err(format!("job {id} is still {}", job.state.name()));
    }
    let mut fields = vec![
        ("job".to_string(), job.id.to_string()),
        ("state".to_string(), job.state.name().to_string()),
        ("step".to_string(), job.steps_done.to_string()),
        ("total".to_string(), job.spec.steps.to_string()),
    ];
    if let Some(dir) = &job.ckpt_dir {
        fields.push(("ckpt_dir".to_string(), dir.display().to_string()));
    }
    if let Some(s) = &job.summary {
        if let Some(m) = s.metric {
            fields.push(("metric".to_string(), format!("{m}")));
        }
        if let Some(l) = s.tail_loss {
            fields.push(("tail_loss".to_string(), format!("{l}")));
        }
    }
    if let Some(e) = &job.error {
        fields.push(("error".to_string(), e.clone()));
    }
    Response::Ok(fields)
}

/// Mark a job terminal.
fn finish_job(table: &Mutex<JobTable>, id: u64, state: JobState, error: Option<String>) {
    let mut t = lock(table);
    if let Some(job) = t.get_mut(id) {
        job.state = state;
        job.error = error;
    }
}

/// The scheduler: admit queued jobs up to `max_active`, then
/// round-robin one step per session per pass until a shutdown drain
/// completes. Owns the runtime, the base cache, and every session.
fn scheduler_loop(
    rt: &mut Runtime,
    cfg: &ServeConfig,
    table: &Arc<Mutex<JobTable>>,
    shutdown: &AtomicBool,
) -> Result<ServeReport> {
    let mut cache = BaseModelCache::new();
    let mut active: Vec<(u64, FinetuneSession)> = Vec::new();
    let mut report = ServeReport::default();
    loop {
        // Admission: queued → constructed session (base checkout is a
        // CoW clone of the cached master). A construction failure fails
        // the job, never the daemon.
        while active.len() < cfg.max_active.max(1) {
            let Some(id) = lock(table).next_queued() else { break };
            let (spec, dir) = {
                let mut t = lock(table);
                let job = match t.get_mut(id) {
                    Some(j) if j.state == JobState::Queued => j,
                    _ => continue, // cancelled between peek and claim
                };
                job.state = JobState::Running;
                let dir = cfg.ckpt_root.join(format!("job-{id}"));
                job.ckpt_dir = Some(dir.clone());
                (job.spec.clone(), dir)
            };
            let built = checkout_base(rt, &mut cache, &cfg.artifacts_dir, &spec).and_then(
                |base| {
                    FinetuneSession::with_base(
                        rt,
                        &cfg.artifacts_dir,
                        spec.to_config(Some(dir)),
                        Some(base),
                    )
                },
            );
            match built {
                Ok(session) => active.push((id, session)),
                Err(e) => {
                    finish_job(table, id, JobState::Failed, Some(format!("{e:#}")));
                    report.failed += 1;
                }
            }
        }

        let draining = shutdown.load(Ordering::SeqCst);
        if draining {
            lock(table).cancel_queued();
        }
        if active.is_empty() {
            if draining {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
            continue;
        }

        // One fair pass: a single step per session, pool work tagged
        // with the job id for per-tenant metrics attribution.
        let mut idx = 0;
        while idx < active.len() {
            let id = active[idx].0;
            if lock(table).get(id).is_some_and(|j| j.cancel_requested) {
                // Drop tears the session down; its AsyncCheckpointer
                // drains on Drop so no torn checkpoint is left behind.
                active.remove(idx);
                finish_job(table, id, JobState::Cancelled, None);
                report.cancelled += 1;
                continue;
            }
            let session = &mut active[idx].1;
            crate::kernel::pool::set_task_job(Some(id));
            let stepped = session.poll_saves().and_then(|()| session.step());
            crate::kernel::pool::set_task_job(None);
            match stepped {
                Ok(SessionStatus::Running) => {
                    let (done, _) = session.progress();
                    if let Some(job) = lock(table).get_mut(id) {
                        job.steps_done = done;
                    }
                    idx += 1;
                }
                Ok(SessionStatus::StepsExhausted) => {
                    crate::kernel::pool::set_task_job(Some(id));
                    let finished = session.finish();
                    crate::kernel::pool::set_task_job(None);
                    match finished {
                        Ok(summary) => {
                            {
                                let mut t = lock(table);
                                if let Some(job) = t.get_mut(id) {
                                    job.steps_done = summary.steps_done;
                                    job.summary = Some(summary);
                                    job.state = JobState::Done;
                                }
                            }
                            report.done += 1;
                        }
                        Err(e) => {
                            finish_job(table, id, JobState::Failed, Some(format!("{e:#}")));
                            report.failed += 1;
                        }
                    }
                    active.remove(idx);
                }
                Err(e) => {
                    finish_job(table, id, JobState::Failed, Some(format!("{e:#}")));
                    report.failed += 1;
                    active.remove(idx);
                }
            }
        }
    }
    Ok(report)
}

/// Load (or reuse) the base model for `spec` and hand out a CoW
/// checkout. Mirrors the artifact-manifest choice inside
/// `FinetuneTrainer::with_base`, so the checkout is exactly the store
/// the standalone path would construct.
fn checkout_base(
    rt: &mut Runtime,
    cache: &mut BaseModelCache,
    artifacts_dir: &std::path::Path,
    spec: &JobSpec,
) -> Result<ParamStore> {
    let key = spec.base_key();
    cache.checkout(key, || {
        let art = rt.load(key)?;
        ParamStore::load_init(artifacts_dir, "clf", &art.manifest)
    })
}
