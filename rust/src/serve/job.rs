//! Job table: specs, states, and admission control for the serve
//! daemon.
//!
//! Connection handler threads mutate only this table (behind the
//! daemon's mutex); the scheduler thread owns the actual sessions and
//! reconciles against it. Admission is checked at submit time: a
//! bounded open-job queue plus a memory budget read from the tracked
//! allocator's live-bytes ledger ([`crate::obs::TrackedAlloc`]) —
//! rejected submissions get a reason over the wire, never a silent
//! drop.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::ckpt::CkptOptions;
use crate::coordinator::{FinetuneConfig, FinetuneMethod, SessionSummary};
use crate::obs::TrackedAlloc;

/// One fine-tune job request — the wire-visible subset of
/// [`FinetuneConfig`], with the same defaults as the standalone
/// `finetune` subcommand so `submit task=… steps=…` and
/// `lowrank-sge finetune --task … --steps …` describe the same run.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub task: String,
    pub method: FinetuneMethod,
    pub steps: u64,
    pub k_interval: u64,
    pub ipa_lr: f32,
    pub zo_lr: f32,
    pub sigma: f32,
    pub c: f64,
    pub seed: u64,
    pub eval_examples: usize,
    pub track_refresh: u64,
    /// Checkpoint cadence inside the job's own directory (0 = never).
    pub save_every: u64,
    pub keep_last: usize,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            task: "sst2".to_string(),
            method: FinetuneMethod::LowRankLr(crate::projection::ProjectorKind::Stiefel),
            steps: 300,
            k_interval: 50,
            ipa_lr: 1e-3,
            zo_lr: 2e-3,
            sigma: 1e-2,
            c: 1.0,
            seed: 2026,
            eval_examples: 256,
            track_refresh: 0,
            save_every: 0,
            keep_last: 0,
        }
    }
}

impl JobSpec {
    /// Interpret raw `submit` fields over the defaults. Unknown keys
    /// are a loud error — a typoed flag must not silently train the
    /// default config.
    pub fn from_fields(fields: &[(String, String)]) -> Result<JobSpec> {
        let mut spec = JobSpec::default();
        for (k, v) in fields {
            let ctx = || format!("bad submit field {k}={v}");
            match k.as_str() {
                "task" => spec.task = v.clone(),
                "method" => spec.method = FinetuneMethod::parse(v)?,
                "steps" => spec.steps = v.parse().with_context(ctx)?,
                "k" => spec.k_interval = v.parse().with_context(ctx)?,
                "ipa-lr" => spec.ipa_lr = v.parse().with_context(ctx)?,
                "zo-lr" => spec.zo_lr = v.parse().with_context(ctx)?,
                "sigma" => spec.sigma = v.parse().with_context(ctx)?,
                "c" => spec.c = v.parse().with_context(ctx)?,
                "seed" => spec.seed = v.parse().with_context(ctx)?,
                "eval-examples" => spec.eval_examples = v.parse().with_context(ctx)?,
                "track-refresh" => spec.track_refresh = v.parse().with_context(ctx)?,
                "save-every" => spec.save_every = v.parse().with_context(ctx)?,
                "keep-last" => spec.keep_last = v.parse().with_context(ctx)?,
                other => bail!("unknown submit field {other:?}"),
            }
        }
        Ok(spec)
    }

    /// The wire fields describing this spec (inverse of
    /// [`JobSpec::from_fields`]).
    pub fn to_fields(&self) -> Vec<(String, String)> {
        vec![
            ("task".to_string(), self.task.clone()),
            ("method".to_string(), self.method.name()),
            ("steps".to_string(), self.steps.to_string()),
            ("k".to_string(), self.k_interval.to_string()),
            ("ipa-lr".to_string(), self.ipa_lr.to_string()),
            ("zo-lr".to_string(), self.zo_lr.to_string()),
            ("sigma".to_string(), self.sigma.to_string()),
            ("c".to_string(), self.c.to_string()),
            ("seed".to_string(), self.seed.to_string()),
            ("eval-examples".to_string(), self.eval_examples.to_string()),
            ("track-refresh".to_string(), self.track_refresh.to_string()),
            ("save-every".to_string(), self.save_every.to_string()),
            ("keep-last".to_string(), self.keep_last.to_string()),
        ]
    }

    /// The trainer config this job runs as. `threads: 0` — the daemon
    /// sizes the shared kernel pool once; tenants never resize it.
    pub fn to_config(&self, ckpt_dir: Option<PathBuf>) -> FinetuneConfig {
        FinetuneConfig {
            task: self.task.clone(),
            method: self.method,
            steps: self.steps,
            k_interval: self.k_interval,
            ipa_lr: self.ipa_lr,
            zo_lr: self.zo_lr,
            sigma: self.sigma,
            c: self.c,
            seed: self.seed,
            eval_examples: self.eval_examples,
            threads: 0,
            ckpt: CkptOptions {
                save_every: self.save_every,
                dir: ckpt_dir,
                resume: None,
                keep_last: self.keep_last,
            },
            track_refresh: self.track_refresh,
        }
    }

    /// Cache key of the base model this job starts from: the gradient
    /// artifact whose manifest orders the parameter store — two jobs
    /// with the same key share one cached `ParamStore` copy-on-write
    /// (mirrors the artifact choice in `FinetuneTrainer::with_base`).
    pub fn base_key(&self) -> &'static str {
        match self.method {
            FinetuneMethod::ZeroShot => "clf_eval",
            FinetuneMethod::VanillaLr => "clf_zo_full",
            FinetuneMethod::LowRankLr(_) => "clf_zo_lowrank",
            FinetuneMethod::VanillaIpa => "clf_ipa_grad",
            FinetuneMethod::LowRankIpa(_) => "clf_ipa_lowrank_grad",
        }
    }
}

/// Lifecycle of one job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Still consuming (or about to consume) scheduler slots?
    pub fn is_open(self) -> bool {
        matches!(self, JobState::Queued | JobState::Running)
    }
}

/// One tracked job.
#[derive(Debug)]
pub struct Job {
    pub id: u64,
    pub spec: JobSpec,
    pub state: JobState,
    pub steps_done: u64,
    /// Per-job failure, isolated from neighbors (a failed async
    /// checkpoint write lands here via the session's `poll_saves`).
    pub error: Option<String>,
    pub summary: Option<SessionSummary>,
    pub cancel_requested: bool,
    /// This job's private checkpoint directory (`<root>/job-<id>`).
    pub ckpt_dir: Option<PathBuf>,
}

/// All jobs the daemon has seen, plus the admission limits.
pub struct JobTable {
    jobs: Vec<Job>,
    next_id: u64,
    /// Open-job cap (queued + running) enforced at submit.
    pub max_open: usize,
    /// Heap budget in bytes (0 = unlimited): submissions are rejected
    /// while the tracked allocator's live bytes sit at or above it.
    pub mem_budget_bytes: usize,
}

impl JobTable {
    pub fn new(max_open: usize, mem_budget_bytes: usize) -> Self {
        JobTable { jobs: Vec::new(), next_id: 1, max_open: max_open.max(1), mem_budget_bytes }
    }

    /// Admission-checked submit against the live allocator ledger.
    pub fn submit(&mut self, spec: JobSpec) -> Result<u64> {
        self.submit_with_live(spec, TrackedAlloc::live_bytes())
    }

    /// [`JobTable::submit`] with an injectable live-bytes reading (the
    /// admission tests pin the rejection path without having to inflate
    /// the real heap).
    pub fn submit_with_live(&mut self, spec: JobSpec, live_bytes: usize) -> Result<u64> {
        let open = self.open_count();
        if open >= self.max_open {
            bail!("queue full ({open} open jobs, cap {})", self.max_open);
        }
        if self.mem_budget_bytes > 0 && live_bytes >= self.mem_budget_bytes {
            bail!(
                "memory budget exhausted (live {live_bytes} B >= budget {} B)",
                self.mem_budget_bytes
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.push(Job {
            id,
            spec,
            state: JobState::Queued,
            steps_done: 0,
            error: None,
            summary: None,
            cancel_requested: false,
            ckpt_dir: None,
        });
        Ok(id)
    }

    pub fn open_count(&self) -> usize {
        self.jobs.iter().filter(|j| j.state.is_open()).count()
    }

    pub fn get(&self, id: u64) -> Option<&Job> {
        self.jobs.iter().find(|j| j.id == id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Job> {
        self.jobs.iter_mut().find(|j| j.id == id)
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    /// Oldest queued job id, if any (FIFO admission to the scheduler).
    pub fn next_queued(&self) -> Option<u64> {
        self.jobs.iter().find(|j| j.state == JobState::Queued).map(|j| j.id)
    }

    /// Flag a job for cancellation. Queued jobs cancel immediately;
    /// running jobs are torn down by the scheduler at the next slice.
    pub fn request_cancel(&mut self, id: u64) -> Result<JobState> {
        let job = self.get_mut(id).with_context(|| format!("no job {id}"))?;
        match job.state {
            JobState::Queued => {
                job.state = JobState::Cancelled;
                Ok(JobState::Cancelled)
            }
            JobState::Running => {
                job.cancel_requested = true;
                Ok(JobState::Running)
            }
            done => Ok(done), // already terminal: idempotent no-op
        }
    }

    /// Cancel every still-queued job (shutdown drain).
    pub fn cancel_queued(&mut self) {
        for j in &mut self.jobs {
            if j.state == JobState::Queued {
                j.state = JobState::Cancelled;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_fields_round_trip() {
        let mut spec = JobSpec::default();
        spec.steps = 8;
        spec.seed = 7;
        spec.save_every = 4;
        let back = JobSpec::from_fields(&spec.to_fields()).unwrap();
        assert_eq!(back, spec);
        assert!(JobSpec::from_fields(&[("stepz".to_string(), "8".to_string())]).is_err());
        assert!(JobSpec::from_fields(&[("steps".to_string(), "eight".to_string())]).is_err());
    }

    #[test]
    fn admission_rejects_on_queue_and_memory() {
        let mut t = JobTable::new(2, 1000);
        let a = t.submit_with_live(JobSpec::default(), 0).unwrap();
        let b = t.submit_with_live(JobSpec::default(), 0).unwrap();
        assert_eq!((a, b), (1, 2));
        // queue cap
        let err = t.submit_with_live(JobSpec::default(), 0).unwrap_err().to_string();
        assert!(err.contains("queue full"), "{err}");
        // terminal jobs free their slots
        t.get_mut(a).unwrap().state = JobState::Done;
        // memory budget
        let err = t.submit_with_live(JobSpec::default(), 2000).unwrap_err().to_string();
        assert!(err.contains("memory budget"), "{err}");
        assert!(t.submit_with_live(JobSpec::default(), 500).is_ok());
    }

    #[test]
    fn cancel_semantics_per_state() {
        let mut t = JobTable::new(8, 0);
        let q = t.submit_with_live(JobSpec::default(), 0).unwrap();
        assert_eq!(t.request_cancel(q).unwrap(), JobState::Cancelled);
        assert_eq!(t.get(q).unwrap().state, JobState::Cancelled);
        let r = t.submit_with_live(JobSpec::default(), 0).unwrap();
        t.get_mut(r).unwrap().state = JobState::Running;
        assert_eq!(t.request_cancel(r).unwrap(), JobState::Running);
        assert!(t.get(r).unwrap().cancel_requested);
        assert!(t.request_cancel(99).is_err());
    }
}
