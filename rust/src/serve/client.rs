//! Thin client for the serve daemon's job plane: one connection per
//! call, request/reply over the framed text protocol. Used by the
//! `lowrank-sge job` subcommand, the integration tests, and the CI
//! smoke script.

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::job::JobSpec;
use super::proto::{self, Request, Response};
use crate::comm::transport::Conn;

/// Dial `addr` (bare `host:port` or an explicit `tcp://` / `unix://`
/// address) and exchange one request for one reply.
pub fn request(addr: &str, req: &Request, timeout: Duration) -> Result<Response> {
    let target =
        if addr.contains("://") { addr.to_string() } else { format!("tcp://{addr}") };
    let conn = Conn::connect(&target, Instant::now() + timeout, timeout)
        .with_context(|| format!("connecting to the serve daemon at {addr}"))?;
    proto::send_msg(&conn, 0, &req.format())?;
    let (_, line) = proto::recv_msg(&conn)?;
    Response::parse(&line)
}

/// Submit a job; returns its id.
pub fn submit(addr: &str, spec: &JobSpec, timeout: Duration) -> Result<u64> {
    let fields = request(addr, &Request::Submit(spec.to_fields()), timeout)?.into_ok()?;
    fields
        .iter()
        .find(|(k, _)| k == "job")
        .and_then(|(_, v)| v.parse().ok())
        .context("submit reply is missing the job id")
}

/// One status snapshot (`state`, `step`, `total`, …) for a job.
pub fn status(addr: &str, job: u64, timeout: Duration) -> Result<Vec<(String, String)>> {
    request(addr, &Request::Status { job }, timeout)?.into_ok()
}

/// Final result fields of a terminal job (errors while still running).
pub fn fetch(addr: &str, job: u64, timeout: Duration) -> Result<Vec<(String, String)>> {
    request(addr, &Request::Fetch { job }, timeout)?.into_ok()
}

/// Request cancellation; returns the state observed at the daemon.
pub fn cancel(addr: &str, job: u64, timeout: Duration) -> Result<String> {
    let fields = request(addr, &Request::Cancel { job }, timeout)?.into_ok()?;
    Ok(field(&fields, "state").unwrap_or("unknown").to_string())
}

/// Ask the daemon to drain and exit.
pub fn shutdown(addr: &str, timeout: Duration) -> Result<()> {
    request(addr, &Request::Shutdown, timeout)?.into_ok().map(|_| ())
}

/// Poll `status` until the job leaves the open states; returns the
/// terminal snapshot. `deadline` bounds the whole wait.
pub fn wait(
    addr: &str,
    job: u64,
    poll: Duration,
    deadline: Instant,
) -> Result<Vec<(String, String)>> {
    loop {
        let fields = status(addr, job, poll.max(Duration::from_millis(100)))?;
        match field(&fields, "state") {
            Some("queued") | Some("running") => {}
            Some(_) => return Ok(fields),
            None => bail!("status reply for job {job} is missing the state field"),
        }
        if Instant::now() >= deadline {
            bail!("timed out waiting for job {job} to finish");
        }
        std::thread::sleep(poll);
    }
}

/// Field lookup in a reply's `key=value` list.
pub fn field<'a>(fields: &'a [(String, String)], key: &str) -> Option<&'a str> {
    fields.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}
