//! Shared base-model substrate: one loaded `ParamStore` per artifact
//! key, handed out as copy-on-write checkouts.
//!
//! The paper's memory argument is exactly what makes multi-tenancy
//! work: per-job training state is O((m+n)·r), so the base parameters
//! are the only big object. The cache keeps one master store per key
//! and every checkout is [`ParamStore::cow_clone`] — an `Arc` bump per
//! tensor. A tenant's first divergent write to a tensor unshares just
//! that tensor (`Arc::make_mut`), so N jobs on one base keep the
//! payloads unduplicated until they actually diverge (asserted against
//! the tracked-allocator ledger in `tests/serve_session.rs`).

use std::collections::HashMap;

use anyhow::Result;

use crate::model::ParamStore;

/// Master stores keyed by the gradient-artifact name
/// ([`super::job::JobSpec::base_key`]). Owned by the scheduler thread;
/// no interior locking needed.
#[derive(Default)]
pub struct BaseModelCache {
    entries: HashMap<String, ParamStore>,
}

impl BaseModelCache {
    pub fn new() -> Self {
        BaseModelCache { entries: HashMap::new() }
    }

    /// A copy-on-write checkout of the base model under `key`, loading
    /// (and retaining) the master on first use.
    pub fn checkout(
        &mut self,
        key: &str,
        load: impl FnOnce() -> Result<ParamStore>,
    ) -> Result<ParamStore> {
        if !self.entries.contains_key(key) {
            let store = load()?;
            self.entries.insert(key.to_string(), store);
        }
        Ok(self.entries[key].cow_clone())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    /// Number of distinct masters resident.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{DType, HostTensor, TensorSpec};

    fn toy_store(fill: f32) -> ParamStore {
        let spec = TensorSpec {
            index: 0,
            name: "params[w]".to_string(),
            dtype: DType::F32,
            shape: vec![4],
        };
        let t = HostTensor::f32(vec![4], vec![fill; 4]);
        ParamStore::from_parts(vec![spec], vec![t]).unwrap()
    }

    #[test]
    fn checkout_loads_once_and_shares_payloads() {
        let mut cache = BaseModelCache::new();
        let mut loads = 0;
        let a = cache
            .checkout("k", || {
                loads += 1;
                Ok(toy_store(1.0))
            })
            .unwrap();
        let b = cache
            .checkout("k", || {
                loads += 1;
                Ok(toy_store(2.0))
            })
            .unwrap();
        assert_eq!(loads, 1, "second checkout must reuse the master");
        assert_eq!(cache.len(), 1);
        // both checkouts alias the master's payload until a write
        assert_eq!(a.f32(0).unwrap(), b.f32(0).unwrap());
        assert!(std::ptr::eq(
            a.f32(0).unwrap().as_ptr(),
            b.f32(0).unwrap().as_ptr()
        ));
        // divergent write unshares the writer only
        let mut b = b;
        b.f32_mut(0).unwrap()[0] = 9.0;
        assert_eq!(a.f32(0).unwrap()[0], 1.0);
        assert_eq!(b.f32(0).unwrap()[0], 9.0);
    }
}
