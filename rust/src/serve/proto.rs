//! Job-submission wire protocol: line-oriented verbs carried in the
//! comm layer's CRC-framed codec.
//!
//! Every message is one UTF-8 text line (`verb key=value …`) smuggled
//! through a single [`crate::comm::wire`] data frame — one byte per
//! f32 element, the same trick `comm-check` uses for its CRC gather —
//! so the serve plane inherits the transport's framing, CRC
//! verification, metrics accounting, and timeout-guarded reads without
//! a second codec. Messages are capped at one frame
//! ([`MAX_MSG_BYTES`]); a job submission is a few hundred bytes.
//!
//! Values are percent-escaped ([`esc`]/[`unesc`]) so paths and error
//! reasons survive the space-separated field syntax.
//!
//! Verbs (client → daemon): `submit key=value …`, `status job=N`,
//! `cancel job=N`, `fetch job=N`, `shutdown`, `ping`. Replies
//! (daemon → client): `ok key=value …` or `err reason=…`.

use anyhow::{bail, Context, Result};

use crate::comm::transport::Conn;
use crate::comm::wire::{self, Kind, WireDtype};

/// One frame per message: text longer than this is a protocol error.
pub const MAX_MSG_BYTES: usize = wire::MAX_DATA_ELEMS;

/// Send one text message as a single data frame.
pub fn send_msg(conn: &Conn, seq: u64, text: &str) -> Result<()> {
    if text.is_empty() {
        bail!("serve protocol messages cannot be empty");
    }
    if text.len() > MAX_MSG_BYTES {
        bail!("serve message of {} bytes exceeds the {MAX_MSG_BYTES}-byte cap", text.len());
    }
    let payload: Vec<f32> = text.bytes().map(f32::from).collect();
    wire::send_frame(conn, Kind::Data, seq, 0, &payload, WireDtype::F32)
}

/// Receive one text message (returns the sender's sequence number).
pub fn recv_msg(conn: &Conn) -> Result<(u64, String)> {
    let f = wire::recv_frame(conn)?;
    if f.kind != Kind::Data {
        bail!("unexpected {:?} frame on a serve connection", f.kind);
    }
    if f.part != 0 {
        bail!("multi-part serve message (part {}) — messages are single-frame", f.part);
    }
    let mut bytes = Vec::with_capacity(f.payload.len());
    for &v in &f.payload {
        if !(0.0..=255.0).contains(&v) || v.fract() != 0.0 {
            bail!("serve message payload is not byte-valued ({v})");
        }
        bytes.push(v as u8);
    }
    let text = String::from_utf8(bytes).context("serve message is not UTF-8")?;
    Ok((f.seq, text))
}

/// Escape a field value: `%`, `=`, space, and newline become `%XX`.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '%' => out.push_str("%25"),
            '=' => out.push_str("%3d"),
            ' ' => out.push_str("%20"),
            '\n' => out.push_str("%0a"),
            _ => out.push(c),
        }
    }
    out
}

/// Inverse of [`esc`]; unknown escapes are a loud error.
pub fn unesc(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c != '%' {
            out.push(c);
            continue;
        }
        let hex: String = it.by_ref().take(2).collect();
        match hex.as_str() {
            "25" => out.push('%'),
            "3d" => out.push('='),
            "20" => out.push(' '),
            "0a" => out.push('\n'),
            other => bail!("bad escape %{other} in serve field value"),
        }
    }
    Ok(out)
}

/// Parse `key=value …` tokens (values unescaped).
fn parse_fields(toks: &[&str]) -> Result<Vec<(String, String)>> {
    let mut fields = Vec::with_capacity(toks.len());
    for tok in toks {
        let (k, v) = tok
            .split_once('=')
            .with_context(|| format!("serve field {tok:?} is not key=value"))?;
        fields.push((k.to_string(), unesc(v)?));
    }
    Ok(fields)
}

fn format_fields(out: &mut String, fields: &[(String, String)]) {
    for (k, v) in fields {
        out.push(' ');
        out.push_str(k);
        out.push('=');
        out.push_str(&esc(v));
    }
}

fn job_id(fields: &[(String, String)]) -> Result<u64> {
    let v = fields
        .iter()
        .find(|(k, _)| k == "job")
        .map(|(_, v)| v.as_str())
        .context("missing job=N field")?;
    v.parse().with_context(|| format!("bad job id {v:?}"))
}

/// A client request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// `submit key=value …` — raw spec fields, interpreted by
    /// [`super::job::JobSpec::from_fields`].
    Submit(Vec<(String, String)>),
    Status { job: u64 },
    Cancel { job: u64 },
    Fetch { job: u64 },
    /// Drain: finish running jobs, cancel queued ones, exit.
    Shutdown,
    Ping,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let toks: Vec<&str> = line.split_whitespace().collect();
        let Some((&verb, rest)) = toks.split_first() else {
            bail!("empty serve request");
        };
        Ok(match verb {
            "submit" => Request::Submit(parse_fields(rest)?),
            "status" => Request::Status { job: job_id(&parse_fields(rest)?)? },
            "cancel" => Request::Cancel { job: job_id(&parse_fields(rest)?)? },
            "fetch" => Request::Fetch { job: job_id(&parse_fields(rest)?)? },
            "shutdown" => Request::Shutdown,
            "ping" => Request::Ping,
            other => bail!("unknown serve verb {other:?}"),
        })
    }

    pub fn format(&self) -> String {
        match self {
            Request::Submit(fields) => {
                let mut out = String::from("submit");
                format_fields(&mut out, fields);
                out
            }
            Request::Status { job } => format!("status job={job}"),
            Request::Cancel { job } => format!("cancel job={job}"),
            Request::Fetch { job } => format!("fetch job={job}"),
            Request::Shutdown => "shutdown".to_string(),
            Request::Ping => "ping".to_string(),
        }
    }
}

/// A daemon reply line.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Ok(Vec<(String, String)>),
    Err(String),
}

impl Response {
    pub fn parse(line: &str) -> Result<Response> {
        let toks: Vec<&str> = line.split_whitespace().collect();
        let Some((&verb, rest)) = toks.split_first() else {
            bail!("empty serve response");
        };
        match verb {
            "ok" => Ok(Response::Ok(parse_fields(rest)?)),
            "err" => {
                let fields = parse_fields(rest)?;
                let reason = fields
                    .into_iter()
                    .find(|(k, _)| k == "reason")
                    .map(|(_, v)| v)
                    .unwrap_or_else(|| "unspecified".to_string());
                Ok(Response::Err(reason))
            }
            other => bail!("unknown serve response {other:?}"),
        }
    }

    pub fn format(&self) -> String {
        match self {
            Response::Ok(fields) => {
                let mut out = String::from("ok");
                format_fields(&mut out, fields);
                out
            }
            Response::Err(reason) => format!("err reason={}", esc(reason)),
        }
    }

    /// Field lookup on an `ok` reply.
    pub fn field(&self, key: &str) -> Option<&str> {
        match self {
            Response::Ok(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
            }
            Response::Err(_) => None,
        }
    }

    /// Unwrap into the ok fields, turning `err` into an error.
    pub fn into_ok(self) -> Result<Vec<(String, String)>> {
        match self {
            Response::Ok(fields) => Ok(fields),
            Response::Err(reason) => bail!("serve request rejected: {reason}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_round_trips() {
        let s = "a b=c%d\ne";
        assert_eq!(unesc(&esc(s)).unwrap(), s);
        assert!(!esc(s).contains(' '));
        assert!(unesc("%zz").is_err());
    }

    #[test]
    fn request_lines_round_trip() {
        let reqs = [
            Request::Submit(vec![
                ("task".to_string(), "sst2".to_string()),
                ("method".to_string(), "stiefel-lowrank-lr".to_string()),
                ("dir".to_string(), "/tmp/with space".to_string()),
            ]),
            Request::Status { job: 7 },
            Request::Cancel { job: 1 },
            Request::Fetch { job: 42 },
            Request::Shutdown,
            Request::Ping,
        ];
        for r in reqs {
            assert_eq!(Request::parse(&r.format()).unwrap(), r);
        }
        assert!(Request::parse("frobnicate job=1").is_err());
        assert!(Request::parse("status").is_err()); // missing job=
    }

    #[test]
    fn response_lines_round_trip() {
        let ok = Response::Ok(vec![
            ("job".to_string(), "3".to_string()),
            ("state".to_string(), "running".to_string()),
        ]);
        let back = Response::parse(&ok.format()).unwrap();
        assert_eq!(back.field("state"), Some("running"));
        let err = Response::Err("queue full (4 open jobs)".to_string());
        match Response::parse(&err.format()).unwrap() {
            Response::Err(reason) => assert_eq!(reason, "queue full (4 open jobs)"),
            other => panic!("expected err, got {other:?}"),
        }
    }

    #[test]
    fn messages_round_trip_over_a_socket_pair() {
        use crate::comm::transport::Conn;
        use std::time::{Duration, Instant};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let conn = Conn::Tcp(s);
            conn.set_timeouts(Duration::from_secs(5)).unwrap();
            let (seq, text) = recv_msg(&conn).unwrap();
            assert_eq!(seq, 9);
            send_msg(&conn, seq, &format!("ok echo={}", esc(&text))).unwrap();
        });
        let conn = Conn::connect(
            &format!("tcp://{addr}"),
            Instant::now() + Duration::from_secs(5),
            Duration::from_secs(5),
        )
        .unwrap();
        send_msg(&conn, 9, "status job=3").unwrap();
        let (_, reply) = recv_msg(&conn).unwrap();
        assert_eq!(
            Response::parse(&reply).unwrap().field("echo"),
            Some("status job=3")
        );
        t.join().unwrap();
    }
}
