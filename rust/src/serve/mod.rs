//! Multi-tenant fine-tune service: the `lowrank-sge serve` daemon and
//! its job plane.
//!
//! The paper's memory headline (low-rank estimation shrinks per-job
//! training state to O((m+n)·r)) is what makes one box able to run
//! many concurrent fine-tune jobs: the base model's `ParamStore` is
//! the only big object, and it is shared copy-on-write. This module
//! turns the batch reproduction into that service, built by
//! refactoring rather than bolting on:
//!
//! * **Sessions** — the daemon schedules the same
//!   [`crate::coordinator::TrainSession`] objects the standalone
//!   subcommands drive (their step loops were lifted into
//!   `begin`/`step_once`/`finish_run` seams), so a single-job serve
//!   run checkpoints bitwise identically to `lowrank-sge finetune` at
//!   the same seed.
//! * **[`proto`]** — submit / status / cancel / fetch / shutdown verbs
//!   as text lines carried in the comm layer's CRC-framed,
//!   timeout-guarded codec ([`crate::comm::wire`]).
//! * **[`job`]** — the job table and admission control: a bounded
//!   open-job queue plus a live-heap budget read from the
//!   tracked-allocator ledger; rejections carry a reason.
//! * **[`base_cache`]** — one loaded base model per artifact key,
//!   checked out per job as [`crate::model::ParamStore::cow_clone`]
//!   (an `Arc` bump per tensor; first divergent write unshares).
//! * **[`daemon`]** — accept loop + per-connection handlers (capped,
//!   idle-timed like the hardened [`crate::obs::monitor`] endpoint)
//!   feeding a single scheduler thread that round-robins one step per
//!   session per pass over the shared kernel pool, with per-job pool
//!   task attribution and per-session failure isolation (a failed
//!   async checkpoint write fails that job only).
//! * **[`client`]** — the one-shot request helper behind
//!   `lowrank-sge job …`.

pub mod base_cache;
pub mod client;
pub mod daemon;
pub mod job;
pub mod proto;

pub use base_cache::BaseModelCache;
pub use daemon::{run_serve, run_serve_with, ServeConfig, ServeReport};
pub use job::{Job, JobSpec, JobState, JobTable};
pub use proto::{Request, Response};
