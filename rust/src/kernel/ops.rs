//! The Scalar-generic compute core: blocked GEMM (`nn`/`tn`/`nt`),
//! AXPY/scale, deterministic reductions, and the strided panel/rotation
//! primitives the factorizations need. Every dense loop in the crate
//! routes through here — exactly once per operation, for both `f32` and
//! `f64` — and bottoms out in the [`Scalar`] row primitives backed by
//! the runtime-dispatched vector core in [`super::simd`].
//!
//! # Determinism contract
//!
//! Results are **bitwise identical** at any thread count and on every
//! SIMD backend (AVX, NEON, scalar emulation):
//!
//! * Element-parallel loops — the j-innermost GEMM `nn`/`tn` updates,
//!   AXPY, scale, add — compute each output element from the same
//!   operands in the same order regardless of vector width, so
//!   vectorizing them is order-preserving for free. GEMM parallelizes
//!   over disjoint row blocks of C; each output element is accumulated
//!   by exactly one task in k-ascending order, so the partitioning
//!   cannot change a single bit.
//! * Dot-like reductions — [`gemm_nt`] rows, [`dot`], `fro_inner` —
//!   accumulate in the **canonical fixed-lane order**: W interleaved
//!   partial sums (element `i` goes to lane `i mod W`), W fixed per
//!   dtype ([`Scalar::LANES`]: 8 for f32, 4 for f64, never derived
//!   from hardware vector width or thread count), the ragged tail
//!   folded scalar-wise, the lanes combined by a fixed pairwise tree.
//!   [`super::simd::lane_dot_scalar`] *is* the definition; the AVX and
//!   NEON paths reproduce it bit-for-bit. This replaced the strictly
//!   sequential per-chunk order of the pre-SIMD kernels — a one-time,
//!   documented change of canonical bits (the `tests/engine_golden.rs`
//!   references are expressed through the same helper).
//! * Long reductions additionally split the input into fixed
//!   [`REDUCE_CHUNK`]-sized chunks (a function of the length only),
//!   compute per-chunk fixed-lane partials, and combine them with a
//!   fixed-shape pairwise tree ([`tree_reduce`]).
//!
//! The kernels are **branchless** over the data: no zero-skip
//! shortcuts, so NaN/Inf propagate exactly as IEEE arithmetic dictates
//! (the old `linalg` GEMM silently dropped NaNs in B behind an
//! `a == 0.0` skip; the regression tests in `linalg::ops` pin the fix).
//! No FMA contraction anywhere: every multiply-add is two roundings on
//! every backend, or the scalar emulation could not match the vector
//! paths bitwise.

use super::pool::KernelPool;
use super::scalar::Scalar;

/// Row-granularity quantum of the GEMM partitioning: every parallel
/// task owns a multiple of this many rows of C (see [`rows_per_task`]).
/// Fixed — never derived from the thread count — so the task set is a
/// pure function of the problem shape.
pub const ROW_BLOCK: usize = 32;

/// Cache tile edge for the k/j blocking inside one GEMM task. 64×64×8 B
/// = 32 KB per f64 tile — the same budget the old `linalg` GEMM used.
const TILE: usize = 64;

/// Elements per reduction chunk; partials are combined by a fixed-shape
/// tree, so this must never depend on the thread count.
pub const REDUCE_CHUNK: usize = 4096;

/// Elements per task for elementwise ops.
const ELEM_CHUNK: usize = 16384;

/// Minimum multiply-add count (m·k·n) before a GEMM is worth queueing
/// on the pool; below this the dispatch overhead (boxed closures,
/// queue mutex, latch) dwarfs the arithmetic, and the toy-MSE hot path
/// runs millions of such small products. The determinism tests use
/// shapes above this bound so they exercise the parallel path.
const PAR_GEMM_MIN_WORK: usize = 1 << 16;

/// Rows of C per parallel task: a [`ROW_BLOCK`] multiple sized so each
/// task carries a worthwhile amount of arithmetic — tall-skinny shapes
/// (e.g. a mat-vec with n = 1) would otherwise shred into hundreds of
/// micro-tasks whose dispatch cost dwarfs their work. A pure function
/// of the shape, so the partitioning stays thread-count-independent.
fn rows_per_task(k: usize, n: usize) -> usize {
    const TASK_MIN_WORK: usize = PAR_GEMM_MIN_WORK / 4;
    let per_row = (k * n).max(1);
    let min_rows = TASK_MIN_WORK.div_ceil(per_row);
    min_rows.div_ceil(ROW_BLOCK).max(1) * ROW_BLOCK
}

// ---------------------------------------------------------------------------
// Serial per-block bodies. These define the canonical element-wise
// accumulation order; the parallel drivers below only decide which rows
// each task owns.
// ---------------------------------------------------------------------------

/// `c` (rows×n) += `a` (rows×k) · `b` (k×n); k-innermost, tiled.
fn gemm_nn_rows<T: Scalar>(a: &[T], b: &[T], c: &mut [T], rows: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(TILE) {
        let k1 = (k0 + TILE).min(k);
        for j0 in (0..n).step_by(TILE) {
            let j1 = (j0 + TILE).min(n);
            for i in 0..rows {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    // innermost j: contiguous in B and C, element-parallel
                    T::fma_row(&mut crow[j0..j1], aik, &brow[j0..j1]);
                }
            }
        }
    }
}

/// Rows `i0 .. i0+rows` of C (m×n) += (Aᵀ·B) with A stored k×m, B k×n;
/// `c` is the row-block slice. k-outermost so both reads stream.
#[allow(clippy::too_many_arguments)]
fn gemm_tn_rows<T: Scalar>(
    a: &[T],
    b: &[T],
    c: &mut [T],
    i0: usize,
    rows: usize,
    k: usize,
    m: usize,
    n: usize,
) {
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..rows {
            let aki = arow[i0 + i];
            let crow = &mut c[i * n..(i + 1) * n];
            T::fma_row(crow, aki, brow);
        }
    }
}

/// `c` (rows×n) += α·(`a` (rows×k) · `b`ᵀ) with `b` stored n×k.
fn gemm_nt_rows<T: Scalar>(
    alpha: T,
    a: &[T],
    b: &[T],
    c: &mut [T],
    rows: usize,
    n: usize,
    k: usize,
) {
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            // canonical fixed-lane reduction — see the module header
            let s = T::lane_dot(arow, brow);
            crow[j] += alpha * s;
        }
    }
}

/// Strictly serial entry points (identical math, one thread). The
/// coordinator's per-slot fan-out uses these inside its own pool tasks
/// so parallelism stays one level deep by construction.
pub mod serial {
    use super::*;

    /// C += A·B, row-major; A m×k, B k×n, C m×n.
    pub fn gemm_nn<T: Scalar>(a: &[T], b: &[T], c: &mut [T], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k, "gemm_nn: A is not {m}x{k}");
        assert_eq!(b.len(), k * n, "gemm_nn: B is not {k}x{n}");
        assert_eq!(c.len(), m * n, "gemm_nn: C is not {m}x{n}");
        gemm_nn_rows(a, b, c, m, k, n);
    }

    /// C += Aᵀ·B; A stored k×m, B k×n, C m×n.
    pub fn gemm_tn<T: Scalar>(a: &[T], b: &[T], c: &mut [T], k: usize, m: usize, n: usize) {
        assert_eq!(a.len(), k * m, "gemm_tn: A is not {k}x{m}");
        assert_eq!(b.len(), k * n, "gemm_tn: B is not {k}x{n}");
        assert_eq!(c.len(), m * n, "gemm_tn: C is not {m}x{n}");
        gemm_tn_rows(a, b, c, 0, m, k, m, n);
    }

    /// C += α·A·Bᵀ; A m×k, B n×k, C m×n.
    pub fn gemm_nt<T: Scalar>(
        alpha: T,
        a: &[T],
        b: &[T],
        c: &mut [T],
        m: usize,
        n: usize,
        k: usize,
    ) {
        assert_eq!(a.len(), m * k, "gemm_nt: A is not {m}x{k}");
        assert_eq!(b.len(), n * k, "gemm_nt: B is not {n}x{k}");
        assert_eq!(c.len(), m * n, "gemm_nt: C is not {m}x{n}");
        gemm_nt_rows(alpha, a, b, c, m, n, k);
    }
}

// ---------------------------------------------------------------------------
// Parallel drivers: row-block partitioning over the pool.
// ---------------------------------------------------------------------------

/// C += A·B across the pool; A m×k, B k×n, C m×n, all row-major.
pub fn gemm_nn<T: Scalar>(
    pool: &KernelPool,
    a: &[T],
    b: &[T],
    c: &mut [T],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_nn: A is not {m}x{k}");
    assert_eq!(b.len(), k * n, "gemm_nn: B is not {k}x{n}");
    assert_eq!(c.len(), m * n, "gemm_nn: C is not {m}x{n}");
    if m == 0 || n == 0 {
        return;
    }
    // single-task and small problems skip the queue entirely — the
    // toy-MSE hot path runs millions of small GEMMs and must stay
    // allocation-free (the serial body computes identical bits)
    if pool.threads() == 1 || m <= ROW_BLOCK || m * k * n <= PAR_GEMM_MIN_WORK {
        gemm_nn_rows(a, b, c, m, k, n);
        return;
    }
    let rpt = rows_per_task(k, n);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (blk, c_rows) in c.chunks_mut(rpt * n).enumerate() {
        let i0 = blk * rpt;
        let rows = c_rows.len() / n;
        let a_rows = &a[i0 * k..(i0 + rows) * k];
        tasks.push(Box::new(move || gemm_nn_rows(a_rows, b, c_rows, rows, k, n)));
    }
    pool.run(tasks);
}

/// C += Aᵀ·B across the pool; A stored k×m, B k×n, C m×n.
pub fn gemm_tn<T: Scalar>(
    pool: &KernelPool,
    a: &[T],
    b: &[T],
    c: &mut [T],
    k: usize,
    m: usize,
    n: usize,
) {
    assert_eq!(a.len(), k * m, "gemm_tn: A is not {k}x{m}");
    assert_eq!(b.len(), k * n, "gemm_tn: B is not {k}x{n}");
    assert_eq!(c.len(), m * n, "gemm_tn: C is not {m}x{n}");
    if m == 0 || n == 0 {
        return;
    }
    if pool.threads() == 1 || m <= ROW_BLOCK || m * k * n <= PAR_GEMM_MIN_WORK {
        gemm_tn_rows(a, b, c, 0, m, k, m, n);
        return;
    }
    let rpt = rows_per_task(k, n);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (blk, c_rows) in c.chunks_mut(rpt * n).enumerate() {
        let i0 = blk * rpt;
        let rows = c_rows.len() / n;
        tasks.push(Box::new(move || gemm_tn_rows(a, b, c_rows, i0, rows, k, m, n)));
    }
    pool.run(tasks);
}

/// C += α·A·Bᵀ across the pool; A m×k, B n×k, C m×n. `alpha = ONE`
/// reproduces the plain accumulate bit-for-bit (`1·x ≡ x` in IEEE).
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt<T: Scalar>(
    pool: &KernelPool,
    alpha: T,
    a: &[T],
    b: &[T],
    c: &mut [T],
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_nt: A is not {m}x{k}");
    assert_eq!(b.len(), n * k, "gemm_nt: B is not {n}x{k}");
    assert_eq!(c.len(), m * n, "gemm_nt: C is not {m}x{n}");
    if m == 0 || n == 0 {
        return;
    }
    if pool.threads() == 1 || m <= ROW_BLOCK || m * n * k <= PAR_GEMM_MIN_WORK {
        gemm_nt_rows(alpha, a, b, c, m, n, k);
        return;
    }
    let rpt = rows_per_task(k, n);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (blk, c_rows) in c.chunks_mut(rpt * n).enumerate() {
        let i0 = blk * rpt;
        let rows = c_rows.len() / n;
        let a_rows = &a[i0 * k..(i0 + rows) * k];
        tasks.push(Box::new(move || gemm_nt_rows(alpha, a_rows, b, c_rows, rows, n, k)));
    }
    pool.run(tasks);
}

// ---------------------------------------------------------------------------
// Elementwise ops.
// ---------------------------------------------------------------------------

/// y += α·x, elementwise across the pool.
pub fn axpy<T: Scalar>(pool: &KernelPool, alpha: T, x: &[T], y: &mut [T]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    if pool.threads() == 1 || y.len() <= ELEM_CHUNK {
        T::fma_row(y, alpha, x);
        return;
    }
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (yc, xc) in y.chunks_mut(ELEM_CHUNK).zip(x.chunks(ELEM_CHUNK)) {
        tasks.push(Box::new(move || T::fma_row(yc, alpha, xc)));
    }
    pool.run(tasks);
}

/// y += x, elementwise across the pool (the all-reduce combine step;
/// kept separate from [`axpy`] so the sum is a plain `+`, matching the
/// historical accumulate exactly).
pub fn add_assign<T: Scalar>(pool: &KernelPool, y: &mut [T], x: &[T]) {
    assert_eq!(x.len(), y.len(), "add_assign length mismatch");
    if pool.threads() == 1 || y.len() <= ELEM_CHUNK {
        T::add_row(y, x);
        return;
    }
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (yc, xc) in y.chunks_mut(ELEM_CHUNK).zip(x.chunks(ELEM_CHUNK)) {
        tasks.push(Box::new(move || T::add_row(yc, xc)));
    }
    pool.run(tasks);
}

/// x *= α, elementwise across the pool.
pub fn scale<T: Scalar>(pool: &KernelPool, x: &mut [T], alpha: T) {
    if pool.threads() == 1 || x.len() <= ELEM_CHUNK {
        T::scale_row(x, alpha);
        return;
    }
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for xc in x.chunks_mut(ELEM_CHUNK) {
        tasks.push(Box::new(move || T::scale_row(xc, alpha)));
    }
    pool.run(tasks);
}

// ---------------------------------------------------------------------------
// Deterministic reductions.
// ---------------------------------------------------------------------------

/// Sum a set of equal-length vectors into `xs[0]` with the
/// stride-doubling pairing tree (`xs[i] += xs[i+gap]` for gap = 1, 2,
/// 4, …), each pairwise add chunked across the pool. The tree shape is
/// a pure function of `xs.len()` alone, so the sum is bitwise identical
/// at any thread count — this is the combine order shared by the
/// in-process DDP all-reduce and the cross-process `comm` collectives.
///
/// `xs[1..]` are used as scratch (inner tree nodes hold partial sums
/// afterwards); callers must not read them after the reduce.
pub fn tree_sum_vecs<T: Scalar>(pool: &KernelPool, xs: &mut [Vec<T>]) {
    let n = xs.len();
    if n <= 1 {
        return;
    }
    let len = xs[0].len();
    for x in xs.iter() {
        assert_eq!(x.len(), len, "tree_sum_vecs length mismatch");
    }
    let mut gap = 1;
    while gap < n {
        let mut i = 0;
        while i + gap < n {
            let (left, right) = xs.split_at_mut(i + gap);
            add_assign(pool, &mut left[i], &right[0]);
            i += 2 * gap;
        }
        gap *= 2;
    }
}

/// Fixed-shape pairwise tree sum: the combine order is a pure function
/// of `xs.len()`, never of who computed the entries.
pub fn tree_reduce<T: Scalar>(xs: &[T]) -> T {
    match xs.len() {
        0 => T::ZERO,
        1 => xs[0],
        len => {
            let mid = len / 2;
            tree_reduce(&xs[..mid]) + tree_reduce(&xs[mid..])
        }
    }
}

/// ⟨x, y⟩ with the chunked-partials + fixed-tree reduction order. The
/// serial path computes the identical chunk partials in the identical
/// order, so the result is thread-count-independent to the bit.
pub fn dot<T: Scalar>(pool: &KernelPool, x: &[T], y: &[T]) -> T {
    assert_eq!(x.len(), y.len(), "dot length mismatch");
    if x.is_empty() {
        return T::ZERO;
    }
    // single-chunk inputs reduce inline, allocation-free — identical to
    // the chunked path (one partial, sequential within the chunk)
    if x.len() <= REDUCE_CHUNK {
        return chunk_dot(x, y);
    }
    let nchunks = x.len().div_ceil(REDUCE_CHUNK);
    let mut partials = vec![T::ZERO; nchunks];
    if pool.threads() == 1 {
        // same chunk partials in the same order, without boxing tasks
        for ((p, xc), yc) in partials
            .iter_mut()
            .zip(x.chunks(REDUCE_CHUNK))
            .zip(y.chunks(REDUCE_CHUNK))
        {
            *p = chunk_dot(xc, yc);
        }
    } else {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for ((p, xc), yc) in partials
            .iter_mut()
            .zip(x.chunks(REDUCE_CHUNK))
            .zip(y.chunks(REDUCE_CHUNK))
        {
            tasks.push(Box::new(move || *p = chunk_dot(xc, yc)));
        }
        pool.run(tasks);
    }
    tree_reduce(&partials)
}

/// One reduction chunk's partial ⟨x, y⟩ in the canonical fixed-lane
/// order — the order every backend (serial, pooled, AVX, NEON) shares.
fn chunk_dot<T: Scalar>(x: &[T], y: &[T]) -> T {
    T::lane_dot(x, y)
}

/// Σᵢ x[i]·y[i] in the canonical fixed-lane accumulation order
/// (W = [`Scalar::LANES`] interleaved partials, scalar tail, fixed
/// pairwise lane combine — see the module header). This is the helper
/// golden references use to state dot-like results in canonical bits
/// without going through the blocked kernels.
pub fn lane_dot<T: Scalar>(x: &[T], y: &[T]) -> T {
    assert_eq!(x.len(), y.len(), "lane_dot length mismatch");
    T::lane_dot(x, y)
}

/// Σ xᵢ² with the same deterministic reduction as [`dot`].
pub fn sum_sq<T: Scalar>(pool: &KernelPool, x: &[T]) -> T {
    dot(pool, x, x)
}

/// Global-pool entry points that touch the process-global pool only
/// when the problem is large enough to parallelize. The small-op hot
/// path — toy-MSE sweeps run millions of tiny GEMMs and inner
/// products — stays free of the global `RwLock`, `Arc` traffic, and
/// heap allocation; results are bit-identical to the pooled path
/// either way.
pub mod auto {
    use super::*;
    use crate::kernel::pool::global;

    /// C += A·B; A m×k, B k×n, C m×n.
    pub fn gemm_nn<T: Scalar>(a: &[T], b: &[T], c: &mut [T], m: usize, k: usize, n: usize) {
        if m <= ROW_BLOCK || m * k * n <= PAR_GEMM_MIN_WORK {
            assert_eq!(a.len(), m * k, "gemm_nn: A is not {m}x{k}");
            assert_eq!(b.len(), k * n, "gemm_nn: B is not {k}x{n}");
            assert_eq!(c.len(), m * n, "gemm_nn: C is not {m}x{n}");
            gemm_nn_rows(a, b, c, m, k, n);
        } else {
            super::gemm_nn(&global(), a, b, c, m, k, n);
        }
    }

    /// C += Aᵀ·B; A stored k×m, B k×n, C m×n.
    pub fn gemm_tn<T: Scalar>(a: &[T], b: &[T], c: &mut [T], k: usize, m: usize, n: usize) {
        if m <= ROW_BLOCK || m * k * n <= PAR_GEMM_MIN_WORK {
            assert_eq!(a.len(), k * m, "gemm_tn: A is not {k}x{m}");
            assert_eq!(b.len(), k * n, "gemm_tn: B is not {k}x{n}");
            assert_eq!(c.len(), m * n, "gemm_tn: C is not {m}x{n}");
            gemm_tn_rows(a, b, c, 0, m, k, m, n);
        } else {
            super::gemm_tn(&global(), a, b, c, k, m, n);
        }
    }

    /// C += α·A·Bᵀ; A m×k, B n×k, C m×n.
    pub fn gemm_nt<T: Scalar>(
        alpha: T,
        a: &[T],
        b: &[T],
        c: &mut [T],
        m: usize,
        n: usize,
        k: usize,
    ) {
        if m <= ROW_BLOCK || m * n * k <= PAR_GEMM_MIN_WORK {
            assert_eq!(a.len(), m * k, "gemm_nt: A is not {m}x{k}");
            assert_eq!(b.len(), n * k, "gemm_nt: B is not {n}x{k}");
            assert_eq!(c.len(), m * n, "gemm_nt: C is not {m}x{n}");
            gemm_nt_rows(alpha, a, b, c, m, n, k);
        } else {
            super::gemm_nt(&global(), alpha, a, b, c, m, n, k);
        }
    }

    /// y += α·x.
    pub fn axpy<T: Scalar>(alpha: T, x: &[T], y: &mut [T]) {
        if y.len() <= ELEM_CHUNK {
            assert_eq!(x.len(), y.len(), "axpy length mismatch");
            T::fma_row(y, alpha, x);
        } else {
            super::axpy(&global(), alpha, x, y);
        }
    }

    /// x *= α.
    pub fn scale<T: Scalar>(x: &mut [T], alpha: T) {
        if x.len() <= ELEM_CHUNK {
            T::scale_row(x, alpha);
        } else {
            super::scale(&global(), x, alpha);
        }
    }

    /// ⟨x, y⟩ (deterministic chunked reduction).
    pub fn dot<T: Scalar>(x: &[T], y: &[T]) -> T {
        if x.len() <= REDUCE_CHUNK {
            assert_eq!(x.len(), y.len(), "dot length mismatch");
            chunk_dot(x, y)
        } else {
            super::dot(&global(), x, y)
        }
    }
}

// ---------------------------------------------------------------------------
// Strided panel primitives (serial — used inside factorization sweeps
// whose outer structure is inherently sequential).
// ---------------------------------------------------------------------------

/// w[j] = Σᵢ x[i] · A[i0+i, j0+j] over a row-major matrix with leading
/// dimension `ld` — the strided panel Aᵀx of a Householder update.
#[allow(clippy::too_many_arguments)]
pub fn gemv_t_strided<T: Scalar>(
    a: &[T],
    ld: usize,
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
    x: &[T],
    w: &mut [T],
) {
    assert_eq!(x.len(), rows, "gemv_t_strided: x length");
    assert_eq!(w.len(), cols, "gemv_t_strided: w length");
    for wj in w.iter_mut() {
        *wj = T::ZERO;
    }
    for (i, &xi) in x.iter().enumerate() {
        let arow = &a[(i0 + i) * ld + j0..(i0 + i) * ld + j0 + cols];
        T::fma_row(w, xi, arow);
    }
}

/// A[i0+i, j0+j] −= x[i] · w[j] — strided rank-1 panel update.
#[allow(clippy::too_many_arguments)]
pub fn ger_sub_strided<T: Scalar>(
    a: &mut [T],
    ld: usize,
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
    x: &[T],
    w: &[T],
) {
    assert_eq!(x.len(), rows, "ger_sub_strided: x length");
    assert_eq!(w.len(), cols, "ger_sub_strided: w length");
    for (i, &xi) in x.iter().enumerate() {
        let arow = &mut a[(i0 + i) * ld + j0..(i0 + i) * ld + j0 + cols];
        // fnma, not fma with −xi: negating xi would flip a NaN's sign bit
        T::fnma_row(arow, xi, w);
    }
}

/// Plane rotation of two contiguous rows: (x, y) ← (c·x + s·y, c·y − s·x).
pub fn rot_rows<T: Scalar>(x: &mut [T], y: &mut [T], c: T, s: T) {
    assert_eq!(x.len(), y.len(), "rot_rows length mismatch");
    T::rot_span(x, y, c, s);
}

/// Plane rotation of two strided columns of a row-major matrix:
/// (A[·,p], A[·,q]) ← (c·A[·,p] + s·A[·,q], c·A[·,q] − s·A[·,p]).
pub fn rot_cols_strided<T: Scalar>(
    a: &mut [T],
    ld: usize,
    p: usize,
    q: usize,
    rows: usize,
    c: T,
    s: T,
) {
    assert!(p < ld && q < ld, "rot_cols_strided: column out of stride");
    for i in 0..rows {
        let xp = a[i * ld + p];
        let xq = a[i * ld + q];
        a[i * ld + p] = c * xp + s * xq;
        a[i * ld + q] = c * xq - s * xp;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arb<T: Scalar>(len: usize, seed: u64) -> Vec<T> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(13);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                T::from_f64(((s >> 33) as f64) / (u32::MAX as f64) - 0.5)
            })
            .collect()
    }

    fn naive_nn(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_nn_matches_naive_on_ragged_shapes() {
        let pool = KernelPool::new(3);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 3, 2), (70, 65, 33), (97, 31, 53)] {
            let a: Vec<f64> = arb(m * k, 1);
            let b: Vec<f64> = arb(k * n, 2);
            let mut c = vec![0.0; m * n];
            gemm_nn(&pool, &a, &b, &mut c, m, k, n);
            let want = naive_nn(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-10, "{m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn tn_and_nt_consistent_with_nn() {
        let pool = KernelPool::new(2);
        let (m, k, n) = (37usize, 19usize, 23usize);
        let a: Vec<f64> = arb(m * k, 3);
        let b: Vec<f64> = arb(k * n, 4);
        // tn: feed Aᵀ explicitly
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        let mut c_tn = vec![0.0; m * n];
        gemm_tn(&pool, &at, &b, &mut c_tn, k, m, n);
        // nt: feed Bᵀ explicitly
        let mut bt = vec![0.0; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        let mut c_nt = vec![0.0; m * n];
        gemm_nt(&pool, 1.0f64, &a, &bt, &mut c_nt, m, n, k);
        let want = naive_nn(&a, &b, m, k, n);
        for i in 0..m * n {
            assert!((c_tn[i] - want[i]).abs() < 1e-10);
            assert!((c_nt[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn serial_equals_parallel_bitwise() {
        let (m, k, n) = (101usize, 43usize, 29usize); // primes: ragged blocks
        let a: Vec<f32> = arb(m * k, 7);
        let b: Vec<f32> = arb(k * n, 8);
        let mut c_serial = vec![0.0f32; m * n];
        serial::gemm_nn(&a, &b, &mut c_serial, m, k, n);
        for threads in [1usize, 2, 4, 7] {
            let pool = KernelPool::new(threads);
            let mut c = vec![0.0f32; m * n];
            gemm_nn(&pool, &a, &b, &mut c, m, k, n);
            for (x, y) in c.iter().zip(&c_serial) {
                assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn gemm_nt_alpha_scales() {
        let pool = KernelPool::new(1);
        let a = vec![1.0f32, 0.0, 0.0, 1.0]; // 2×2 identity
        let b = vec![1.0f32, 2.0, 3.0, 4.0]; // 2×2
        let mut c = vec![0.0f32; 4];
        gemm_nt(&pool, -2.0f32, &a, &b, &mut c, 2, 2, 2);
        // C = −2·A·Bᵀ = −2·Bᵀ
        assert_eq!(c, vec![-2.0, -6.0, -4.0, -8.0]);
    }

    #[test]
    fn dot_is_thread_count_independent_bitwise() {
        let x: Vec<f64> = arb(3 * REDUCE_CHUNK + 777, 11);
        let y: Vec<f64> = arb(3 * REDUCE_CHUNK + 777, 12);
        let reference = dot(&KernelPool::new(1), &x, &y);
        for threads in [2usize, 4, 7] {
            let got = dot(&KernelPool::new(threads), &x, &y);
            assert_eq!(got.to_bits(), reference.to_bits(), "threads={threads}");
        }
        // sanity vs plain sum
        let plain: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((reference - plain).abs() < 1e-9);
    }

    #[test]
    fn tree_reduce_fixed_shape() {
        assert_eq!(tree_reduce::<f64>(&[]), 0.0);
        assert_eq!(tree_reduce(&[4.0f64]), 4.0);
        assert_eq!(tree_reduce(&[1.0f64, 2.0, 3.0, 4.0, 5.0]), 15.0);
    }

    #[test]
    fn axpy_scale_add_assign_elementwise() {
        let pool = KernelPool::new(2);
        let x: Vec<f32> = arb(ELEM_CHUNK * 2 + 5, 21);
        let mut y: Vec<f32> = arb(ELEM_CHUNK * 2 + 5, 22);
        let y0 = y.clone();
        axpy(&pool, 0.5f32, &x, &mut y);
        for i in 0..y.len() {
            assert_eq!(y[i].to_bits(), (y0[i] + 0.5 * x[i]).to_bits());
        }
        add_assign(&pool, &mut y, &x);
        scale(&pool, &mut y, 2.0f32);
        for i in 0..y.len() {
            assert_eq!(y[i].to_bits(), (((y0[i] + 0.5 * x[i]) + x[i]) * 2.0).to_bits());
        }
    }

    #[test]
    fn branchless_core_propagates_nan_through_zeros() {
        let pool = KernelPool::new(2);
        // A row of zeros against a B with a NaN and an Inf: the products
        // 0·NaN and 0·Inf are both NaN and must reach C.
        let a = vec![0.0f64, 0.0];
        let b = vec![1.0f64, f64::NAN, 2.0, 3.0, 4.0, f64::INFINITY];
        let mut c = vec![0.0f64; 3];
        gemm_nn(&pool, &a, &b, &mut c, 1, 2, 3);
        assert!(!c[0].is_nan());
        assert!(c[1].is_nan(), "0·NaN dropped");
        assert!(c[2].is_nan(), "0·Inf dropped");
    }

    #[test]
    fn strided_panel_primitives() {
        // 3×4 matrix, panel at (1,1) of size 2×2
        let mut a: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let x = vec![2.0, 3.0];
        let mut w = vec![0.0; 2];
        gemv_t_strided(&a, 4, 1, 1, 2, 2, &x, &mut w);
        // w[0] = 2·a[1,1] + 3·a[2,1] = 2·5 + 3·9 = 37 ; w[1] = 2·6+3·10 = 42
        assert_eq!(w, vec![37.0, 42.0]);
        ger_sub_strided(&mut a, 4, 1, 1, 2, 2, &x, &w);
        assert_eq!(a[5], 5.0 - 2.0 * 37.0);
        assert_eq!(a[10], 10.0 - 3.0 * 42.0);
        // untouched outside the panel
        assert_eq!(a[0], 0.0);
        assert_eq!(a[4], 4.0);
    }

    #[test]
    fn rotations_are_orthogonal() {
        let theta: f64 = 0.3;
        let (s, c) = theta.sin_cos();
        let mut x = vec![1.0, 0.0];
        let mut y = vec![0.0, 1.0];
        rot_rows(&mut x, &mut y, c, s);
        // norms preserved
        assert!((x[0] * x[0] + y[0] * y[0] - 1.0).abs() < 1e-12);
        let mut m = vec![1.0f64, 0.0, 0.0, 1.0];
        rot_cols_strided(&mut m, 2, 0, 1, 2, c, s);
        assert!((m[0] - c).abs() < 1e-12);
        assert!((m[1] + s).abs() < 1e-12);
    }
}
