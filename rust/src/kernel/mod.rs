//! `kernel` — the crate's single parallel compute substrate.
//!
//! Everything dense in the Rust layer runs through this module, exactly
//! once per operation and generically over [`Scalar`] (`f32`/`f64`):
//!
//! * [`scalar`] — the [`Scalar`] trait binding the two precisions to
//!   one set of kernels.
//! * [`ops`] — blocked GEMM (`nn`/`tn`/`nt`), AXPY/scale,
//!   deterministic chunked reductions, and the strided panel/rotation
//!   primitives used by QR and the Jacobi eigensolver.
//! * [`pool`] — the persistent [`KernelPool`] (`std::thread` +
//!   queue/condvar, no external deps) plus the process-global instance
//!   sized by `--threads` / `LOWRANK_THREADS` (default: available
//!   parallelism).
//!
//! # Determinism guarantee
//!
//! For every operation here, **parallel output is bitwise identical to
//! serial output at any thread count**: GEMM partitions C into fixed
//! row blocks whose per-element accumulation order never changes, and
//! reductions combine fixed-size chunk partials through a fixed-shape
//! tree. Layers above inherit the guarantee — the projection samplers,
//! the per-slot subspace fan-out, and the DDP all-reduce all produce
//! the same bits with `--threads 1` and `--threads 64`. The
//! `tests/kernel_determinism.rs` suite and the CI matrix
//! (`LOWRANK_THREADS` ∈ {1, 4}) pin this down.

pub mod ops;
pub mod pool;
pub mod scalar;

pub use ops::{
    add_assign, auto, axpy, dot, gemm_nn, gemm_nt, gemm_tn, gemv_t_strided, ger_sub_strided,
    rot_cols_strided, rot_rows, scale, serial, sum_sq, tree_reduce, tree_sum_vecs, REDUCE_CHUNK,
    ROW_BLOCK,
};
pub use pool::{global, global_threads, set_global_threads, KernelPool};
pub use scalar::Scalar;
