//! `kernel` — the crate's single parallel compute substrate.
//!
//! Everything dense in the Rust layer runs through this module, exactly
//! once per operation and generically over [`Scalar`] (`f32`/`f64`):
//!
//! * [`scalar`] — the [`Scalar`] trait binding the two precisions to
//!   one set of kernels, including the row primitives the blocked
//!   kernels bottom out in.
//! * [`simd`] — the explicit vector core behind those primitives:
//!   runtime-dispatched AVX/NEON tiles plus a portable scalar
//!   emulation of the same fixed lane layout, selected by
//!   `LOWRANK_SIMD` ∈ {`auto`, `scalar`} (or [`simd::set_mode`]).
//! * [`ops`] — blocked GEMM (`nn`/`tn`/`nt`), AXPY/scale,
//!   deterministic chunked reductions, and the strided panel/rotation
//!   primitives used by QR and the Jacobi eigensolver.
//! * [`pool`] — the persistent [`KernelPool`] (`std::thread` +
//!   queue/condvar, no external deps) plus the process-global instance
//!   sized by `--threads` / `LOWRANK_THREADS` (default: available
//!   parallelism).
//!
//! # Determinism guarantee
//!
//! For every operation here, **output is bitwise identical at any
//! thread count and on every SIMD backend**: GEMM partitions C into
//! fixed row blocks whose per-element accumulation order never
//! changes, reductions accumulate in the canonical fixed-lane order
//! ([`lane_dot`], W = [`Scalar::LANES`] partial sums per dtype) and
//! combine fixed-size chunk partials through a fixed-shape tree.
//! Layers above inherit the guarantee — the projection samplers, the
//! per-slot subspace fan-out, and the DDP all-reduce all produce the
//! same bits with `--threads 1` and `--threads 64`, with
//! `LOWRANK_SIMD=scalar` and `=auto`, on x86_64 and aarch64. The
//! `tests/kernel_determinism.rs` and `tests/simd_lanes.rs` suites and
//! the CI matrix (`LOWRANK_THREADS` ∈ {1, 4} × `LOWRANK_SIMD` ∈
//! {scalar, auto}) pin this down.

pub mod ops;
pub mod pool;
pub mod scalar;
pub mod simd;

pub use ops::{
    add_assign, auto, axpy, dot, gemm_nn, gemm_nt, gemm_tn, gemv_t_strided, ger_sub_strided,
    lane_dot, rot_cols_strided, rot_rows, scale, serial, sum_sq, tree_reduce, tree_sum_vecs,
    REDUCE_CHUNK, ROW_BLOCK,
};
pub use pool::{global, global_threads, set_global_threads, KernelPool};
pub use scalar::Scalar;
