//! Persistent worker pool for the kernel layer — `std::thread` +
//! channel-style queueing, no external dependencies.
//!
//! Design constraints (they shape everything here):
//!
//! * **Determinism.** The pool never influences *what* is computed, only
//!   *when*. Callers split work into tasks whose outputs are disjoint
//!   (row blocks of C, fixed-size reduction chunks), so any execution
//!   order — including fully serial — produces bitwise-identical
//!   results. `threads = 1` runs every task inline on the caller,
//!   which *is* the serial baseline.
//! * **No idle deadlock.** [`KernelPool::run`] is a fork-join scope: the
//!   calling thread helps drain the shared queue before blocking on the
//!   completion latch, so nested `run` calls (a fan-out task that itself
//!   uses the pool) always make progress even when every worker is busy.
//! * **Persistence.** Workers are spawned once and reused; the global
//!   pool lives for the process (size from `--threads` /
//!   `LOWRANK_THREADS`, default: available parallelism).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::obs;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Queue + wakeup state shared between the pool handle and its workers.
struct Shared {
    queue: Mutex<VecDeque<Job>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

/// Completion latch for one `run` scope.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
    }
}

/// Job attribution for pool work: the serve scheduler tags the batches
/// of the session slice it is about to run, so `pool_task_count` /
/// queue-wait series split per tenant in the metrics registry (and
/// Chrome traces group by job). −1 = untagged. Attribution only — the
/// tag never influences scheduling or results.
static CURRENT_JOB: AtomicI64 = AtomicI64::new(-1);

/// Tag subsequent pool batches with a job id (`None` clears the tag).
pub fn set_task_job(job: Option<u64>) {
    CURRENT_JOB.store(job.map_or(-1, |j| j as i64), Ordering::Relaxed);
}

/// The job id subsequent pool batches are attributed to, if any.
pub fn current_task_job() -> Option<u64> {
    let j = CURRENT_JOB.load(Ordering::Relaxed);
    (j >= 0).then_some(j as u64)
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// A fixed-size persistent worker pool. `threads` counts the calling
/// thread: a pool of size N spawns N − 1 workers, and size 1 spawns
/// none (every `run` executes inline — the serial baseline).
pub struct KernelPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl KernelPool {
    /// Build a pool with `threads` total lanes of parallelism
    /// (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("kernel-pool-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawning kernel pool worker thread")
            })
            .collect();
        KernelPool { shared, workers, threads }
    }

    /// Total parallelism (workers + the participating caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a batch of tasks to completion (fork-join). Tasks may borrow
    /// caller state: `run` does not return until every task finished.
    ///
    /// With one task, or on a single-thread pool, tasks execute inline
    /// in order — this is the path the determinism tests compare the
    /// parallel runs against. A panicking task poisons the batch: the
    /// remaining tasks still run, then the first panic payload is
    /// rethrown on the caller (original message intact).
    pub fn run<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        obs::metrics::POOL_TASKS.add(tasks.len() as u64);
        // Per-job attribution series (serve tenants); one name alloc per
        // batch, and only when metrics are on and a job tag is set.
        let job = if obs::metrics::enabled() { current_task_job() } else { None };
        if let Some(j) = job {
            obs::metrics::record_value(&format!("pool_task_count_job{j}"), tasks.len() as f64);
        }
        if self.threads == 1 || tasks.len() == 1 {
            for t in tasks {
                let _span = obs::span("kernel", "task");
                t();
            }
            return;
        }

        // Queue-wait measurement: one timestamp per batch (not per task —
        // keeps the enqueue loop allocation-identical), observed at each
        // task's execution start. `None` when observability is off.
        let enqueued_at = if obs::metrics::enabled() { Some(Instant::now()) } else { None };
        // Queue-wait split per job: the series name is shared by every
        // task closure of the batch (one Arc clone each).
        let job_wait_series: Option<Arc<String>> = match (&enqueued_at, job) {
            (Some(_), Some(j)) => Some(Arc::new(format!("pool_queue_wait_us_job{j}"))),
            _ => None,
        };

        type Payload = Box<dyn std::any::Any + Send>;
        let latch = Arc::new(Latch::new(tasks.len()));
        let first_panic: Arc<Mutex<Option<Payload>>> = Arc::new(Mutex::new(None));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                // SAFETY: every job finishes (latch) before `run`
                // returns, so borrows scoped to 'scope outlive the job.
                let t: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(t) };
                let latch = latch.clone();
                let first_panic = first_panic.clone();
                let job_wait_series = job_wait_series.clone();
                q.push_back(Box::new(move || {
                    if let Some(t0) = enqueued_at {
                        let wait_ns = t0.elapsed().as_nanos() as u64;
                        obs::metrics::POOL_QUEUE_WAIT.observe(wait_ns);
                        if let Some(name) = &job_wait_series {
                            obs::metrics::record_value(name, wait_ns as f64 / 1e3);
                        }
                    }
                    let _span = obs::span("kernel", "task");
                    if let Err(payload) =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(t))
                    {
                        let mut slot = first_panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                    }
                    latch.count_down();
                }));
            }
            self.shared.work_cv.notify_all();
        }

        // Help drain the queue (our own tasks, or a nested scope's)
        // before blocking — this is what makes nested `run` calls safe.
        loop {
            let job = self.shared.queue.lock().unwrap().pop_front();
            match job {
                Some(j) => j(),
                None => break,
            }
        }
        latch.wait();
        let payload = first_panic.lock().unwrap().take();
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pool size when nothing was configured: `LOWRANK_THREADS` if set and
/// ≥ 1, else the machine's available parallelism.
fn default_threads() -> usize {
    std::env::var("LOWRANK_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

static GLOBAL: OnceLock<RwLock<Arc<KernelPool>>> = OnceLock::new();

fn global_cell() -> &'static RwLock<Arc<KernelPool>> {
    GLOBAL.get_or_init(|| RwLock::new(Arc::new(KernelPool::new(default_threads()))))
}

/// The process-wide pool every convenience wrapper uses. Cheap to call
/// (one `Arc` clone).
pub fn global() -> Arc<KernelPool> {
    global_cell().read().unwrap().clone()
}

/// Replace the global pool with one of `threads` lanes (no-op when the
/// size already matches). In-flight users keep the old pool via their
/// `Arc` until they finish — determinism makes the handoff benign.
pub fn set_global_threads(threads: usize) {
    let threads = threads.max(1);
    let mut w = global_cell().write().unwrap();
    if w.threads() != threads {
        *w = Arc::new(KernelPool::new(threads));
    }
}

/// Current global pool size.
pub fn global_threads() -> usize {
    global().threads()
}

/// Serializes tests that assert on the *size* of the global pool (its
/// results are thread-count-independent, but `global_threads()` is not).
#[cfg(test)]
pub(crate) static GLOBAL_TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
pub(crate) fn global_test_guard() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn counting_tasks<'a>(
        counter: &'a AtomicUsize,
        n: usize,
    ) -> Vec<Box<dyn FnOnce() + Send + 'a>> {
        (0..n)
            .map(|_| {
                let b: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
                b
            })
            .collect()
    }

    #[test]
    fn runs_every_task_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = KernelPool::new(threads);
            let counter = AtomicUsize::new(0);
            pool.run(counting_tasks(&counter, 23));
            assert_eq!(counter.load(Ordering::SeqCst), 23, "threads={threads}");
        }
    }

    #[test]
    fn tasks_can_write_disjoint_borrows() {
        let pool = KernelPool::new(4);
        let mut out = vec![0usize; 40];
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in out.chunks_mut(7).enumerate() {
                tasks.push(Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = i * 100 + j;
                    }
                }));
            }
            pool.run(tasks);
        }
        for (idx, &v) in out.iter().enumerate() {
            assert_eq!(v, (idx / 7) * 100 + idx % 7);
        }
    }

    #[test]
    fn nested_run_completes() {
        let pool = Arc::new(KernelPool::new(3));
        let counter = AtomicUsize::new(0);
        {
            let mut outer: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for _ in 0..4 {
                let pool = pool.clone();
                let counter = &counter;
                outer.push(Box::new(move || {
                    pool.run(counting_tasks(counter, 5));
                }));
            }
            pool.run(outer);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn task_panic_rethrows_original_payload() {
        let pool = KernelPool::new(2);
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        tasks.push(Box::new(|| panic!("boom")));
        tasks.push(Box::new(|| {}));
        pool.run(tasks);
    }

    #[test]
    fn global_pool_resizes() {
        let _guard = global_test_guard();
        let prev = global_threads();
        set_global_threads(3);
        assert_eq!(global_threads(), 3);
        set_global_threads(1);
        assert_eq!(global_threads(), 1);
        set_global_threads(0); // clamped
        assert_eq!(global_threads(), 1);
        set_global_threads(prev); // restore for other tests
    }
}
