//! The [`Scalar`] abstraction: the one place where "a float the kernel
//! layer can compute with" is defined.
//!
//! Every dense kernel in [`crate::kernel`] is written once, generically
//! over `Scalar`, and instantiated for `f32` (the training hot path) and
//! `f64` (the estimator/theory stack). Beyond plain IEEE arithmetic and
//! the constants, the trait carries the **row primitives** — the
//! contiguous inner loops every blocked kernel in [`super::ops`] bottoms
//! out in. The generic kernels stay scalar-agnostic; the two instances
//! forward each primitive to the runtime-dispatched vector core in
//! [`super::simd`] (AVX / NEON / portable scalar emulation, all
//! bitwise-identical by the fixed-lane contract documented there).

use std::fmt::Debug;
use std::ops::{Add, AddAssign, Mul, MulAssign, Sub, SubAssign};

use super::simd;

/// An IEEE float the kernel layer operates on (`f32` or `f64`).
pub trait Scalar:
    Copy
    + Debug
    + PartialEq
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
{
    const ZERO: Self;
    const ONE: Self;

    /// The canonical partial-sum width for dot-like reductions: 8 for
    /// f32, 4 for f64. Fixed per dtype — never derived from hardware
    /// vector width or thread count — so the accumulation order (and
    /// therefore every bit of every reduction) is a property of the
    /// dtype alone. See [`super::simd`] for the full contract.
    const LANES: usize;

    /// Lossy conversion from f64 (used by tests and mixed-precision
    /// call sites; f64 → f32 rounds to nearest).
    fn from_f64(x: f64) -> Self;

    /// Widening conversion to f64 (exact for both instances).
    fn to_f64(self) -> f64;

    /// Σᵢ x[i]·y[i] in the canonical fixed-lane order
    /// ([`simd::lane_dot_scalar`]). The one reduction primitive; every
    /// backend (AVX, NEON, scalar emulation) produces identical bits.
    fn lane_dot(x: &[Self], y: &[Self]) -> Self;

    /// c[j] += a·b[j] — the GEMM/AXPY row update (element-parallel, so
    /// vectorization is order-preserving for free).
    fn fma_row(c: &mut [Self], a: Self, b: &[Self]);

    /// c[j] -= a·b[j] — the rank-1-update row (kept as its own
    /// primitive rather than `fma_row` with a negated `a`: negating a
    /// NaN flips its sign bit and would change propagated payloads).
    fn fnma_row(c: &mut [Self], a: Self, b: &[Self]);

    /// y[j] += x[j].
    fn add_row(y: &mut [Self], x: &[Self]);

    /// x[j] *= alpha.
    fn scale_row(x: &mut [Self], alpha: Self);

    /// (x[j], y[j]) ← (c·x[j] + s·y[j], c·y[j] − s·x[j]) — the Givens
    /// rotation over two contiguous rows.
    fn rot_span(x: &mut [Self], y: &mut [Self], c: Self, s: Self);
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const LANES: usize = 8;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline]
    fn lane_dot(x: &[Self], y: &[Self]) -> Self {
        simd::dot_f32(x, y)
    }

    #[inline]
    fn fma_row(c: &mut [Self], a: Self, b: &[Self]) {
        simd::fma_row_f32(c, a, b)
    }

    #[inline]
    fn fnma_row(c: &mut [Self], a: Self, b: &[Self]) {
        simd::fnma_row_f32(c, a, b)
    }

    #[inline]
    fn add_row(y: &mut [Self], x: &[Self]) {
        simd::add_row_f32(y, x)
    }

    #[inline]
    fn scale_row(x: &mut [Self], alpha: Self) {
        simd::scale_row_f32(x, alpha)
    }

    #[inline]
    fn rot_span(x: &mut [Self], y: &mut [Self], c: Self, s: Self) {
        simd::rot_span_f32(x, y, c, s)
    }
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const LANES: usize = 4;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline]
    fn lane_dot(x: &[Self], y: &[Self]) -> Self {
        simd::dot_f64(x, y)
    }

    #[inline]
    fn fma_row(c: &mut [Self], a: Self, b: &[Self]) {
        simd::fma_row_f64(c, a, b)
    }

    #[inline]
    fn fnma_row(c: &mut [Self], a: Self, b: &[Self]) {
        simd::fnma_row_f64(c, a, b)
    }

    #[inline]
    fn add_row(y: &mut [Self], x: &[Self]) {
        simd::add_row_f64(y, x)
    }

    #[inline]
    fn scale_row(x: &mut [Self], alpha: Self) {
        simd::scale_row_f64(x, alpha)
    }

    #[inline]
    fn rot_span(x: &mut [Self], y: &mut [Self], c: Self, s: Self) {
        simd::rot_span_f64(x, y, c, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn generic_fma<T: Scalar>(a: T, b: T, c: T) -> T {
        a * b + c
    }

    #[test]
    fn both_instances_compute() {
        assert_eq!(generic_fma(2.0f32, 3.0, 1.0), 7.0);
        assert_eq!(generic_fma(2.0f64, 3.0, 1.0), 7.0);
        assert_eq!(f32::from_f64(0.5), 0.5f32);
        assert_eq!(1.25f32.to_f64(), 1.25f64);
    }

    #[test]
    fn nan_propagates_through_generic_arithmetic() {
        // the kernel core is branchless exactly so this holds
        let x = generic_fma(f64::ZERO, f64::NAN, 1.0);
        assert!(x.is_nan());
        let y = generic_fma(0.0f32, f32::INFINITY, 1.0);
        assert!(y.is_nan());
    }
}
