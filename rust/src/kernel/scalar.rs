//! The [`Scalar`] abstraction: the one place where "a float the kernel
//! layer can compute with" is defined.
//!
//! Every dense kernel in [`crate::kernel`] is written once, generically
//! over `Scalar`, and instantiated for `f32` (the training hot path) and
//! `f64` (the estimator/theory stack). The bounds are deliberately
//! minimal — plain IEEE arithmetic plus the constants the kernels need —
//! so the generic code monomorphizes to exactly the loops the old
//! hand-rolled per-precision kernels contained.

use std::fmt::Debug;
use std::ops::{Add, AddAssign, Mul, MulAssign, Sub, SubAssign};

/// An IEEE float the kernel layer operates on (`f32` or `f64`).
pub trait Scalar:
    Copy
    + Debug
    + PartialEq
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
{
    const ZERO: Self;
    const ONE: Self;

    /// Lossy conversion from f64 (used by tests and mixed-precision
    /// call sites; f64 → f32 rounds to nearest).
    fn from_f64(x: f64) -> Self;

    /// Widening conversion to f64 (exact for both instances).
    fn to_f64(self) -> f64;
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn generic_fma<T: Scalar>(a: T, b: T, c: T) -> T {
        a * b + c
    }

    #[test]
    fn both_instances_compute() {
        assert_eq!(generic_fma(2.0f32, 3.0, 1.0), 7.0);
        assert_eq!(generic_fma(2.0f64, 3.0, 1.0), 7.0);
        assert_eq!(f32::from_f64(0.5), 0.5f32);
        assert_eq!(1.25f32.to_f64(), 1.25f64);
    }

    #[test]
    fn nan_propagates_through_generic_arithmetic() {
        // the kernel core is branchless exactly so this holds
        let x = generic_fma(f64::ZERO, f64::NAN, 1.0);
        assert!(x.is_nan());
        let y = generic_fma(0.0f32, f32::INFINITY, 1.0);
        assert!(y.is_nan());
    }
}
