//! The explicit vector core under [`super::ops`] — runtime-dispatched
//! `core::arch` intrinsics (AVX/AVX2 on x86_64, NEON on aarch64) plus a
//! portable scalar emulation of the **exact same lane layout**, behind
//! the row-granular primitives on [`Scalar`]. Stable Rust only: no
//! `portable_simd`, no external crates, no FMA contraction anywhere.
//!
//! # The fixed-lane determinism contract
//!
//! Element-parallel primitives (`fma_row`, `fnma_row`, `add_row`,
//! `scale_row`, `rot_span`) compute each output element from its own
//! inputs only, so vectorizing them cannot reorder any accumulation:
//! SIMD ≡ scalar ≡ any thread count, bitwise, for free.
//!
//! Dot-like reductions are different: a W-wide vector accumulator sums
//! element `i` into lane `i % W`, which is a *different* summation
//! order than a plain ascending loop. Rather than forbid that (and
//! lose the vectorization), the kernel defines the lane layout itself
//! as the canonical accumulation order — with W **fixed per dtype**
//! ([`Scalar::LANES`]: 8 for f32, 4 for f64), never derived from the
//! hardware vector width or the thread count:
//!
//! * element `i` of the main body accumulates into lane `i % W`, in
//!   ascending `i`;
//! * the ragged tail (`len % W` elements) is folded scalar-wise into
//!   lanes `0..len % W` **in every backend** — a zero-padded vector
//!   step would flip a `-0.0` lane to `+0.0`;
//! * the W lanes are combined by the fixed pairwise tree
//!   `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))` (W = 8), resp.
//!   `(a0+a1)+(a2+a3)` (W = 4).
//!
//! [`lane_dot_scalar`] *is* that definition; every SIMD path holds the
//! W lanes in registers (one `__m256` on AVX, two `float32x4_t` on
//! NEON) and must reproduce it bit for bit — pinned by
//! `tests/simd_lanes.rs`. No fused multiply-add is ever used: FMA
//! rounds once where mul+add rounds twice, which would break
//! SIMD ≡ scalar. Multiplication operand order also matches the scalar
//! expression everywhere (NaN payload propagation is operand-order
//! dependent on x86).
//!
//! # Dispatch
//!
//! `LOWRANK_SIMD` ∈ {`auto` (default), `scalar`} selects the backend at
//! process level; [`set_mode`] overrides it programmatically so benches
//! can time both paths in one process. Because every backend produces
//! identical bits, the mode is a speed knob, never a results knob —
//! flipping it mid-run is benign by construction. x86_64 without AVX
//! falls back to the scalar emulation (no SSE2 tier); aarch64 NEON is
//! baseline and needs no detection.

use std::sync::atomic::{AtomicU8, Ordering};

use super::scalar::Scalar;

/// Upper bound on [`Scalar::LANES`] (the f32 width).
pub const MAX_LANES: usize = 8;

const MODE_UNSET: u8 = 0;
const MODE_AUTO: u8 = 1;
const MODE_SCALAR: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// Which backend family dispatch may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Use the best available vector backend (AVX / NEON), falling
    /// back to the scalar emulation where none exists.
    Auto,
    /// Force the scalar emulation everywhere.
    Scalar,
}

fn mode_from_env() -> u8 {
    match std::env::var("LOWRANK_SIMD") {
        Err(_) => MODE_AUTO,
        Ok(s) => match s.trim() {
            "" | "auto" => MODE_AUTO,
            "scalar" => MODE_SCALAR,
            other => panic!("LOWRANK_SIMD={other:?}: expected \"auto\" or \"scalar\""),
        },
    }
}

/// The active dispatch mode (`LOWRANK_SIMD`, read once, overridable via
/// [`set_mode`]).
pub fn mode() -> SimdMode {
    let raw = MODE.load(Ordering::Relaxed);
    let raw = if raw == MODE_UNSET {
        // racing initializers read the same env and store the same
        // value, so a plain store is fine
        let fresh = mode_from_env();
        MODE.store(fresh, Ordering::Relaxed);
        fresh
    } else {
        raw
    };
    if raw == MODE_SCALAR {
        SimdMode::Scalar
    } else {
        SimdMode::Auto
    }
}

/// Programmatic override of `LOWRANK_SIMD` (mirrors
/// `kernel::set_global_threads`). Benches use it to time the scalar
/// emulation against the vector backend in one process; results are
/// identical either way — that is the contract this module exists to
/// keep.
pub fn set_mode(m: SimdMode) {
    let raw = match m {
        SimdMode::Auto => MODE_AUTO,
        SimdMode::Scalar => MODE_SCALAR,
    };
    MODE.store(raw, Ordering::Relaxed);
}

#[inline]
fn enabled() -> bool {
    mode() == SimdMode::Auto
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn avx() -> bool {
    enabled() && std::arch::is_x86_feature_detected!("avx")
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2() -> bool {
    enabled() && std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(target_arch = "aarch64")]
#[inline]
fn neon_on() -> bool {
    enabled()
}

/// The vector backend the float primitives currently dispatch to
/// (`"avx"`, `"neon"`, or `"scalar"`) — for bench/test display.
pub fn active_backend() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if avx() {
            return "avx";
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if neon_on() {
            return "neon";
        }
    }
    "scalar"
}

// ---------------------------------------------------------------------------
// the portable emulation — the *definition* of the canonical order
// ---------------------------------------------------------------------------

/// Combine lane accumulators with the fixed pairwise tree (recursive
/// midpoint split — `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))` at W = 8).
fn combine<T: Scalar>(lanes: &[T]) -> T {
    if lanes.len() == 1 {
        lanes[0]
    } else {
        let mid = lanes.len() / 2;
        combine(&lanes[..mid]) + combine(&lanes[mid..])
    }
}

/// The canonical fixed-lane dot product: element `i` into lane
/// `i % W`, scalar tail into lanes `0..len % W`, lanes combined by the
/// fixed pairwise tree. This scalar emulation is the definition every
/// SIMD backend must match bitwise; [`super::ops`] routes all dot-like
/// reductions (`gemm_nt`, `dot`, `fro_inner`) through it.
pub fn lane_dot_scalar<T: Scalar>(x: &[T], y: &[T]) -> T {
    assert_eq!(x.len(), y.len(), "lane_dot length mismatch");
    let w = T::LANES;
    debug_assert!(w >= 1 && w <= MAX_LANES && w.is_power_of_two());
    let mut acc = [T::ZERO; MAX_LANES];
    let main = x.len() - x.len() % w;
    let mut i = 0;
    while i < main {
        for (l, a) in acc[..w].iter_mut().enumerate() {
            *a += x[i + l] * y[i + l];
        }
        i += w;
    }
    for e in main..x.len() {
        acc[e - main] += x[e] * y[e];
    }
    combine(&acc[..w])
}

pub(crate) fn fma_row_scalar<T: Scalar>(c: &mut [T], a: T, b: &[T]) {
    debug_assert_eq!(c.len(), b.len());
    for (ci, bi) in c.iter_mut().zip(b) {
        *ci += a * *bi;
    }
}

pub(crate) fn fnma_row_scalar<T: Scalar>(c: &mut [T], a: T, b: &[T]) {
    debug_assert_eq!(c.len(), b.len());
    for (ci, bi) in c.iter_mut().zip(b) {
        *ci -= a * *bi;
    }
}

pub(crate) fn add_row_scalar<T: Scalar>(y: &mut [T], x: &[T]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += *xi;
    }
}

pub(crate) fn scale_row_scalar<T: Scalar>(x: &mut [T], alpha: T) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

pub(crate) fn rot_span_scalar<T: Scalar>(x: &mut [T], y: &mut [T], c: T, s: T) {
    debug_assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter_mut().zip(y.iter_mut()) {
        let (xv, yv) = (*xi, *yi);
        *xi = c * xv + s * yv;
        *yi = c * yv - s * xv;
    }
}

// ---------------------------------------------------------------------------
// dispatchers (one per primitive per dtype)
// ---------------------------------------------------------------------------

macro_rules! dispatch {
    (x86: $x:expr, neon: $n:expr, scalar: $s:expr) => {{
        #[cfg(target_arch = "x86_64")]
        {
            if avx() {
                return unsafe { $x };
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if neon_on() {
                return unsafe { $n };
            }
        }
        $s
    }};
}

#[inline]
pub(crate) fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len(), "lane_dot length mismatch");
    dispatch!(
        x86: x86::lane_dot_f32(x, y),
        neon: neon::lane_dot_f32(x, y),
        scalar: lane_dot_scalar(x, y)
    )
}

#[inline]
pub(crate) fn dot_f64(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "lane_dot length mismatch");
    dispatch!(
        x86: x86::lane_dot_f64(x, y),
        neon: neon::lane_dot_f64(x, y),
        scalar: lane_dot_scalar(x, y)
    )
}

#[inline]
pub(crate) fn fma_row_f32(c: &mut [f32], a: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    dispatch!(
        x86: x86::fma_row_f32(c, a, b),
        neon: neon::fma_row_f32(c, a, b),
        scalar: fma_row_scalar(c, a, b)
    )
}

#[inline]
pub(crate) fn fma_row_f64(c: &mut [f64], a: f64, b: &[f64]) {
    debug_assert_eq!(c.len(), b.len());
    dispatch!(
        x86: x86::fma_row_f64(c, a, b),
        neon: neon::fma_row_f64(c, a, b),
        scalar: fma_row_scalar(c, a, b)
    )
}

#[inline]
pub(crate) fn fnma_row_f32(c: &mut [f32], a: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    dispatch!(
        x86: x86::fnma_row_f32(c, a, b),
        neon: neon::fnma_row_f32(c, a, b),
        scalar: fnma_row_scalar(c, a, b)
    )
}

#[inline]
pub(crate) fn fnma_row_f64(c: &mut [f64], a: f64, b: &[f64]) {
    debug_assert_eq!(c.len(), b.len());
    dispatch!(
        x86: x86::fnma_row_f64(c, a, b),
        neon: neon::fnma_row_f64(c, a, b),
        scalar: fnma_row_scalar(c, a, b)
    )
}

#[inline]
pub(crate) fn add_row_f32(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    dispatch!(
        x86: x86::add_row_f32(y, x),
        neon: neon::add_row_f32(y, x),
        scalar: add_row_scalar(y, x)
    )
}

#[inline]
pub(crate) fn add_row_f64(y: &mut [f64], x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    dispatch!(
        x86: x86::add_row_f64(y, x),
        neon: neon::add_row_f64(y, x),
        scalar: add_row_scalar(y, x)
    )
}

#[inline]
pub(crate) fn scale_row_f32(x: &mut [f32], alpha: f32) {
    dispatch!(
        x86: x86::scale_row_f32(x, alpha),
        neon: neon::scale_row_f32(x, alpha),
        scalar: scale_row_scalar(x, alpha)
    )
}

#[inline]
pub(crate) fn scale_row_f64(x: &mut [f64], alpha: f64) {
    dispatch!(
        x86: x86::scale_row_f64(x, alpha),
        neon: neon::scale_row_f64(x, alpha),
        scalar: scale_row_scalar(x, alpha)
    )
}

#[inline]
pub(crate) fn rot_span_f32(x: &mut [f32], y: &mut [f32], c: f32, s: f32) {
    debug_assert_eq!(x.len(), y.len());
    dispatch!(
        x86: x86::rot_span_f32(x, y, c, s),
        neon: neon::rot_span_f32(x, y, c, s),
        scalar: rot_span_scalar(x, y, c, s)
    )
}

#[inline]
pub(crate) fn rot_span_f64(x: &mut [f64], y: &mut [f64], c: f64, s: f64) {
    debug_assert_eq!(x.len(), y.len());
    dispatch!(
        x86: x86::rot_span_f64(x, y, c, s),
        neon: neon::rot_span_f64(x, y, c, s),
        scalar: rot_span_scalar(x, y, c, s)
    )
}

// ---------------------------------------------------------------------------
// bf16 ⇄ f32 convert lane (the comm::wire batch kernels)
// ---------------------------------------------------------------------------

/// f32 → bfloat16 bits, truncating with round-to-nearest-even (the
/// hardware convention). Sign and exponent survive exactly; NaNs stay
/// NaN (a mantissa bit is forced so a NaN whose high mantissa bits are
/// zero cannot quiet to ∞). Finite values that round past the largest
/// bf16 saturate to ±∞ — the IEEE behaviour. The canonical scalar;
/// the batch kernels below reproduce it elementwise, bit for bit.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round-to-nearest-even: add 0x7FFF plus the current LSB of the
    // kept mantissa, then truncate
    let round = 0x7FFF + ((bits >> 16) & 1);
    (bits.wrapping_add(round) >> 16) as u16
}

/// bfloat16 bits → f32, exactly (low mantissa bits zero-filled).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Batch [`f32_to_bf16`]: 8 elements per step on AVX2/NEON, elementwise
/// identical to the scalar on every backend.
pub fn f32_to_bf16_batch(src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len(), "f32_to_bf16_batch length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            unsafe { x86::f32_to_bf16_batch(src, dst) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if neon_on() {
            unsafe { neon::f32_to_bf16_batch(src, dst) };
            return;
        }
    }
    for (d, s) in dst.iter_mut().zip(src) {
        *d = f32_to_bf16(*s);
    }
}

/// Batch [`bf16_to_f32`] (exact widening).
pub fn bf16_to_f32_batch(src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "bf16_to_f32_batch length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            unsafe { x86::bf16_to_f32_batch(src, dst) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if neon_on() {
            unsafe { neon::bf16_to_f32_batch(src, dst) };
            return;
        }
    }
    for (d, s) in dst.iter_mut().zip(src) {
        *d = bf16_to_f32(*s);
    }
}

/// Round every element through bf16 and back in place — the
/// quantize-at-source step of the compressed wire lane. Elementwise
/// and order-free, so it is deterministic at any thread count and on
/// every backend.
pub fn quantize_bf16_batch(data: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            unsafe { x86::quantize_bf16_batch(data) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if neon_on() {
            unsafe { neon::quantize_bf16_batch(data) };
            return;
        }
    }
    for v in data.iter_mut() {
        *v = bf16_to_f32(f32_to_bf16(*v));
    }
}

// ---------------------------------------------------------------------------
// x86_64: AVX float tiles (one 256-bit register holds all W lanes) and
// AVX2 integer convert tiles
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn lane_dot_f32(x: &[f32], y: &[f32]) -> f32 {
        let main = x.len() - x.len() % 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < main {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            // mul then add, never FMA: two roundings, same as the
            // scalar emulation
            acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, yv));
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for e in main..x.len() {
            lanes[e - main] += x[e] * y[e];
        }
        super::combine(&lanes)
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn lane_dot_f64(x: &[f64], y: &[f64]) -> f64 {
        let main = x.len() - x.len() % 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < main {
            let xv = _mm256_loadu_pd(x.as_ptr().add(i));
            let yv = _mm256_loadu_pd(y.as_ptr().add(i));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(xv, yv));
            i += 4;
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        for e in main..x.len() {
            lanes[e - main] += x[e] * y[e];
        }
        super::combine(&lanes)
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn fma_row_f32(c: &mut [f32], a: f32, b: &[f32]) {
        let av = _mm256_set1_ps(a);
        let main = c.len() - c.len() % 8;
        let mut i = 0;
        while i < main {
            let cv = _mm256_loadu_ps(c.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            _mm256_storeu_ps(c.as_mut_ptr().add(i), _mm256_add_ps(cv, _mm256_mul_ps(av, bv)));
            i += 8;
        }
        for e in main..c.len() {
            c[e] += a * b[e];
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn fma_row_f64(c: &mut [f64], a: f64, b: &[f64]) {
        let av = _mm256_set1_pd(a);
        let main = c.len() - c.len() % 4;
        let mut i = 0;
        while i < main {
            let cv = _mm256_loadu_pd(c.as_ptr().add(i));
            let bv = _mm256_loadu_pd(b.as_ptr().add(i));
            _mm256_storeu_pd(c.as_mut_ptr().add(i), _mm256_add_pd(cv, _mm256_mul_pd(av, bv)));
            i += 4;
        }
        for e in main..c.len() {
            c[e] += a * b[e];
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn fnma_row_f32(c: &mut [f32], a: f32, b: &[f32]) {
        let av = _mm256_set1_ps(a);
        let main = c.len() - c.len() % 8;
        let mut i = 0;
        while i < main {
            let cv = _mm256_loadu_ps(c.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            _mm256_storeu_ps(c.as_mut_ptr().add(i), _mm256_sub_ps(cv, _mm256_mul_ps(av, bv)));
            i += 8;
        }
        for e in main..c.len() {
            c[e] -= a * b[e];
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn fnma_row_f64(c: &mut [f64], a: f64, b: &[f64]) {
        let av = _mm256_set1_pd(a);
        let main = c.len() - c.len() % 4;
        let mut i = 0;
        while i < main {
            let cv = _mm256_loadu_pd(c.as_ptr().add(i));
            let bv = _mm256_loadu_pd(b.as_ptr().add(i));
            _mm256_storeu_pd(c.as_mut_ptr().add(i), _mm256_sub_pd(cv, _mm256_mul_pd(av, bv)));
            i += 4;
        }
        for e in main..c.len() {
            c[e] -= a * b[e];
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn add_row_f32(y: &mut [f32], x: &[f32]) {
        let main = y.len() - y.len() % 8;
        let mut i = 0;
        while i < main {
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, xv));
            i += 8;
        }
        for e in main..y.len() {
            y[e] += x[e];
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn add_row_f64(y: &mut [f64], x: &[f64]) {
        let main = y.len() - y.len() % 4;
        let mut i = 0;
        while i < main {
            let yv = _mm256_loadu_pd(y.as_ptr().add(i));
            let xv = _mm256_loadu_pd(x.as_ptr().add(i));
            _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_add_pd(yv, xv));
            i += 4;
        }
        for e in main..y.len() {
            y[e] += x[e];
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn scale_row_f32(x: &mut [f32], alpha: f32) {
        let av = _mm256_set1_ps(alpha);
        let main = x.len() - x.len() % 8;
        let mut i = 0;
        while i < main {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_mul_ps(xv, av));
            i += 8;
        }
        for e in main..x.len() {
            x[e] *= alpha;
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn scale_row_f64(x: &mut [f64], alpha: f64) {
        let av = _mm256_set1_pd(alpha);
        let main = x.len() - x.len() % 4;
        let mut i = 0;
        while i < main {
            let xv = _mm256_loadu_pd(x.as_ptr().add(i));
            _mm256_storeu_pd(x.as_mut_ptr().add(i), _mm256_mul_pd(xv, av));
            i += 4;
        }
        for e in main..x.len() {
            x[e] *= alpha;
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn rot_span_f32(x: &mut [f32], y: &mut [f32], c: f32, s: f32) {
        let cv = _mm256_set1_ps(c);
        let sv = _mm256_set1_ps(s);
        let main = x.len() - x.len() % 8;
        let mut i = 0;
        while i < main {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            let nx = _mm256_add_ps(_mm256_mul_ps(cv, xv), _mm256_mul_ps(sv, yv));
            let ny = _mm256_sub_ps(_mm256_mul_ps(cv, yv), _mm256_mul_ps(sv, xv));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), nx);
            _mm256_storeu_ps(y.as_mut_ptr().add(i), ny);
            i += 8;
        }
        for e in main..x.len() {
            let (xv, yv) = (x[e], y[e]);
            x[e] = c * xv + s * yv;
            y[e] = c * yv - s * xv;
        }
    }

    /// # Safety
    /// Caller must have verified AVX support at runtime.
    #[target_feature(enable = "avx")]
    pub unsafe fn rot_span_f64(x: &mut [f64], y: &mut [f64], c: f64, s: f64) {
        let cv = _mm256_set1_pd(c);
        let sv = _mm256_set1_pd(s);
        let main = x.len() - x.len() % 4;
        let mut i = 0;
        while i < main {
            let xv = _mm256_loadu_pd(x.as_ptr().add(i));
            let yv = _mm256_loadu_pd(y.as_ptr().add(i));
            let nx = _mm256_add_pd(_mm256_mul_pd(cv, xv), _mm256_mul_pd(sv, yv));
            let ny = _mm256_sub_pd(_mm256_mul_pd(cv, yv), _mm256_mul_pd(sv, xv));
            _mm256_storeu_pd(x.as_mut_ptr().add(i), nx);
            _mm256_storeu_pd(y.as_mut_ptr().add(i), ny);
            i += 4;
        }
        for e in main..x.len() {
            let (xv, yv) = (x[e], y[e]);
            x[e] = c * xv + s * yv;
            y[e] = c * yv - s * xv;
        }
    }

    /// 8 f32 bit patterns → 8 bf16 values in the low 16 bits of each
    /// u32 lane (RNE + NaN-quieting, the vector form of the scalar
    /// `f32_to_bf16`).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn bf16_round_8(bits: __m256i) -> __m256i {
        let lsb = _mm256_and_si256(_mm256_srli_epi32(bits, 16), _mm256_set1_epi32(1));
        let rounded = _mm256_srli_epi32(
            _mm256_add_epi32(bits, _mm256_add_epi32(lsb, _mm256_set1_epi32(0x7FFF))),
            16,
        );
        // NaN ⇔ (bits & 0x7FFFFFFF) > 0x7F800000; both sides are
        // positive as i32, so the signed compare is exact
        let abs = _mm256_and_si256(bits, _mm256_set1_epi32(0x7FFF_FFFF));
        let is_nan = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7F80_0000));
        let nan16 = _mm256_or_si256(_mm256_srli_epi32(bits, 16), _mm256_set1_epi32(0x0040));
        _mm256_blendv_epi8(rounded, nan16, is_nan)
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn f32_to_bf16_batch(src: &[f32], dst: &mut [u16]) {
        let main = src.len() - src.len() % 16;
        let mut i = 0;
        while i < main {
            let lo = bf16_round_8(_mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i));
            let hi = bf16_round_8(_mm256_loadu_si256(src.as_ptr().add(i + 8) as *const __m256i));
            // every lane is in [0, 0xFFFF], so the signed-saturating
            // pack is exact; permute undoes its 128-bit interleave
            let packed = _mm256_permute4x64_epi64(_mm256_packus_epi32(lo, hi), 0b11011000);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, packed);
            i += 16;
        }
        for e in main..src.len() {
            dst[e] = super::f32_to_bf16(src[e]);
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_to_f32_batch(src: &[u16], dst: &mut [f32]) {
        let main = src.len() - src.len() % 8;
        let mut i = 0;
        while i < main {
            let h = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let w = _mm256_slli_epi32(_mm256_cvtepu16_epi32(h), 16);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, w);
            i += 8;
        }
        for e in main..src.len() {
            dst[e] = super::bf16_to_f32(src[e]);
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_bf16_batch(data: &mut [f32]) {
        let main = data.len() - data.len() % 8;
        let mut i = 0;
        while i < main {
            let bits = _mm256_loadu_si256(data.as_ptr().add(i) as *const __m256i);
            let w = _mm256_slli_epi32(bf16_round_8(bits), 16);
            _mm256_storeu_si256(data.as_mut_ptr().add(i) as *mut __m256i, w);
            i += 8;
        }
        for v in data[main..].iter_mut() {
            *v = super::bf16_to_f32(super::f32_to_bf16(*v));
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON tiles — two 128-bit registers hold the W lanes
// (acc0 = lanes 0..W/2, acc1 = lanes W/2..W), so the layout matches
// the AVX register and the scalar array exactly
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    /// # Safety
    /// NEON is baseline on aarch64; callers gate only on the dispatch
    /// mode.
    pub unsafe fn lane_dot_f32(x: &[f32], y: &[f32]) -> f32 {
        let main = x.len() - x.len() % 8;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < main {
            let x0 = vld1q_f32(x.as_ptr().add(i));
            let y0 = vld1q_f32(y.as_ptr().add(i));
            let x1 = vld1q_f32(x.as_ptr().add(i + 4));
            let y1 = vld1q_f32(y.as_ptr().add(i + 4));
            acc0 = vaddq_f32(acc0, vmulq_f32(x0, y0));
            acc1 = vaddq_f32(acc1, vmulq_f32(x1, y1));
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        for e in main..x.len() {
            lanes[e - main] += x[e] * y[e];
        }
        super::combine(&lanes)
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn lane_dot_f64(x: &[f64], y: &[f64]) -> f64 {
        let main = x.len() - x.len() % 4;
        let mut acc0 = vdupq_n_f64(0.0);
        let mut acc1 = vdupq_n_f64(0.0);
        let mut i = 0;
        while i < main {
            let x0 = vld1q_f64(x.as_ptr().add(i));
            let y0 = vld1q_f64(y.as_ptr().add(i));
            let x1 = vld1q_f64(x.as_ptr().add(i + 2));
            let y1 = vld1q_f64(y.as_ptr().add(i + 2));
            acc0 = vaddq_f64(acc0, vmulq_f64(x0, y0));
            acc1 = vaddq_f64(acc1, vmulq_f64(x1, y1));
            i += 4;
        }
        let mut lanes = [0.0f64; 4];
        vst1q_f64(lanes.as_mut_ptr(), acc0);
        vst1q_f64(lanes.as_mut_ptr().add(2), acc1);
        for e in main..x.len() {
            lanes[e - main] += x[e] * y[e];
        }
        super::combine(&lanes)
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn fma_row_f32(c: &mut [f32], a: f32, b: &[f32]) {
        let av = vdupq_n_f32(a);
        let main = c.len() - c.len() % 4;
        let mut i = 0;
        while i < main {
            let cv = vld1q_f32(c.as_ptr().add(i));
            let bv = vld1q_f32(b.as_ptr().add(i));
            // mul then add, never vfmaq: matches the scalar rounding
            vst1q_f32(c.as_mut_ptr().add(i), vaddq_f32(cv, vmulq_f32(av, bv)));
            i += 4;
        }
        for e in main..c.len() {
            c[e] += a * b[e];
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn fma_row_f64(c: &mut [f64], a: f64, b: &[f64]) {
        let av = vdupq_n_f64(a);
        let main = c.len() - c.len() % 2;
        let mut i = 0;
        while i < main {
            let cv = vld1q_f64(c.as_ptr().add(i));
            let bv = vld1q_f64(b.as_ptr().add(i));
            vst1q_f64(c.as_mut_ptr().add(i), vaddq_f64(cv, vmulq_f64(av, bv)));
            i += 2;
        }
        for e in main..c.len() {
            c[e] += a * b[e];
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn fnma_row_f32(c: &mut [f32], a: f32, b: &[f32]) {
        let av = vdupq_n_f32(a);
        let main = c.len() - c.len() % 4;
        let mut i = 0;
        while i < main {
            let cv = vld1q_f32(c.as_ptr().add(i));
            let bv = vld1q_f32(b.as_ptr().add(i));
            vst1q_f32(c.as_mut_ptr().add(i), vsubq_f32(cv, vmulq_f32(av, bv)));
            i += 4;
        }
        for e in main..c.len() {
            c[e] -= a * b[e];
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn fnma_row_f64(c: &mut [f64], a: f64, b: &[f64]) {
        let av = vdupq_n_f64(a);
        let main = c.len() - c.len() % 2;
        let mut i = 0;
        while i < main {
            let cv = vld1q_f64(c.as_ptr().add(i));
            let bv = vld1q_f64(b.as_ptr().add(i));
            vst1q_f64(c.as_mut_ptr().add(i), vsubq_f64(cv, vmulq_f64(av, bv)));
            i += 2;
        }
        for e in main..c.len() {
            c[e] -= a * b[e];
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn add_row_f32(y: &mut [f32], x: &[f32]) {
        let main = y.len() - y.len() % 4;
        let mut i = 0;
        while i < main {
            let yv = vld1q_f32(y.as_ptr().add(i));
            let xv = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, xv));
            i += 4;
        }
        for e in main..y.len() {
            y[e] += x[e];
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn add_row_f64(y: &mut [f64], x: &[f64]) {
        let main = y.len() - y.len() % 2;
        let mut i = 0;
        while i < main {
            let yv = vld1q_f64(y.as_ptr().add(i));
            let xv = vld1q_f64(x.as_ptr().add(i));
            vst1q_f64(y.as_mut_ptr().add(i), vaddq_f64(yv, xv));
            i += 2;
        }
        for e in main..y.len() {
            y[e] += x[e];
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn scale_row_f32(x: &mut [f32], alpha: f32) {
        let av = vdupq_n_f32(alpha);
        let main = x.len() - x.len() % 4;
        let mut i = 0;
        while i < main {
            let xv = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(x.as_mut_ptr().add(i), vmulq_f32(xv, av));
            i += 4;
        }
        for e in main..x.len() {
            x[e] *= alpha;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn scale_row_f64(x: &mut [f64], alpha: f64) {
        let av = vdupq_n_f64(alpha);
        let main = x.len() - x.len() % 2;
        let mut i = 0;
        while i < main {
            let xv = vld1q_f64(x.as_ptr().add(i));
            vst1q_f64(x.as_mut_ptr().add(i), vmulq_f64(xv, av));
            i += 2;
        }
        for e in main..x.len() {
            x[e] *= alpha;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn rot_span_f32(x: &mut [f32], y: &mut [f32], c: f32, s: f32) {
        let cv = vdupq_n_f32(c);
        let sv = vdupq_n_f32(s);
        let main = x.len() - x.len() % 4;
        let mut i = 0;
        while i < main {
            let xv = vld1q_f32(x.as_ptr().add(i));
            let yv = vld1q_f32(y.as_ptr().add(i));
            let nx = vaddq_f32(vmulq_f32(cv, xv), vmulq_f32(sv, yv));
            let ny = vsubq_f32(vmulq_f32(cv, yv), vmulq_f32(sv, xv));
            vst1q_f32(x.as_mut_ptr().add(i), nx);
            vst1q_f32(y.as_mut_ptr().add(i), ny);
            i += 4;
        }
        for e in main..x.len() {
            let (xv, yv) = (x[e], y[e]);
            x[e] = c * xv + s * yv;
            y[e] = c * yv - s * xv;
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn rot_span_f64(x: &mut [f64], y: &mut [f64], c: f64, s: f64) {
        let cv = vdupq_n_f64(c);
        let sv = vdupq_n_f64(s);
        let main = x.len() - x.len() % 2;
        let mut i = 0;
        while i < main {
            let xv = vld1q_f64(x.as_ptr().add(i));
            let yv = vld1q_f64(y.as_ptr().add(i));
            let nx = vaddq_f64(vmulq_f64(cv, xv), vmulq_f64(sv, yv));
            let ny = vsubq_f64(vmulq_f64(cv, yv), vmulq_f64(sv, xv));
            vst1q_f64(x.as_mut_ptr().add(i), nx);
            vst1q_f64(y.as_mut_ptr().add(i), ny);
            i += 2;
        }
        for e in main..x.len() {
            let (xv, yv) = (x[e], y[e]);
            x[e] = c * xv + s * yv;
            y[e] = c * yv - s * xv;
        }
    }

    /// 4 f32 bit patterns → 4 bf16 values in the low 16 bits of each
    /// u32 lane (RNE + NaN-quieting).
    ///
    /// # Safety
    /// NEON is baseline on aarch64.
    #[inline]
    unsafe fn bf16_round_4(bits: uint32x4_t) -> uint32x4_t {
        let lsb = vandq_u32(vshrq_n_u32(bits, 16), vdupq_n_u32(1));
        let rounded = vshrq_n_u32(vaddq_u32(bits, vaddq_u32(lsb, vdupq_n_u32(0x7FFF))), 16);
        let abs = vandq_u32(bits, vdupq_n_u32(0x7FFF_FFFF));
        let is_nan = vcgtq_u32(abs, vdupq_n_u32(0x7F80_0000));
        let nan16 = vorrq_u32(vshrq_n_u32(bits, 16), vdupq_n_u32(0x0040));
        vbslq_u32(is_nan, nan16, rounded)
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn f32_to_bf16_batch(src: &[f32], dst: &mut [u16]) {
        let main = src.len() - src.len() % 8;
        let mut i = 0;
        while i < main {
            let lo = bf16_round_4(vreinterpretq_u32_f32(vld1q_f32(src.as_ptr().add(i))));
            let hi = bf16_round_4(vreinterpretq_u32_f32(vld1q_f32(src.as_ptr().add(i + 4))));
            vst1q_u16(dst.as_mut_ptr().add(i), vcombine_u16(vmovn_u32(lo), vmovn_u32(hi)));
            i += 8;
        }
        for e in main..src.len() {
            dst[e] = super::f32_to_bf16(src[e]);
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn bf16_to_f32_batch(src: &[u16], dst: &mut [f32]) {
        let main = src.len() - src.len() % 4;
        let mut i = 0;
        while i < main {
            let h = vld1_u16(src.as_ptr().add(i));
            let w = vshlq_n_u32(vmovl_u16(h), 16);
            vst1q_f32(dst.as_mut_ptr().add(i), vreinterpretq_f32_u32(w));
            i += 4;
        }
        for e in main..src.len() {
            dst[e] = super::bf16_to_f32(src[e]);
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    pub unsafe fn quantize_bf16_batch(data: &mut [f32]) {
        let main = data.len() - data.len() % 4;
        let mut i = 0;
        while i < main {
            let bits = vreinterpretq_u32_f32(vld1q_f32(data.as_ptr().add(i)));
            let w = vshlq_n_u32(bf16_round_4(bits), 16);
            vst1q_f32(data.as_mut_ptr().add(i), vreinterpretq_f32_u32(w));
            i += 4;
        }
        for v in data[main..].iter_mut() {
            *v = super::bf16_to_f32(super::f32_to_bf16(*v));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arb_f32(len: usize, seed: u32) -> Vec<f32> {
        let mut s = seed.wrapping_mul(2654435761).wrapping_add(12345);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(1664525).wrapping_add(1013904223);
                ((s >> 8) as f32 / (1 << 24) as f32) * 4.0 - 2.0
            })
            .collect()
    }

    #[test]
    fn dispatch_matches_emulation_on_every_ragged_tail() {
        // whatever backend is active, lane_dot must equal the portable
        // emulation bit for bit — including every tail length 0..8
        for tail in 0..8usize {
            let len = 64 + tail;
            let x = arb_f32(len, 1 + tail as u32);
            let y = arb_f32(len, 100 + tail as u32);
            let want = lane_dot_scalar(&x, &y);
            let got = dot_f32(&x, &y);
            assert_eq!(got.to_bits(), want.to_bits(), "f32 lane_dot diverged at len {len}");
            let xd: Vec<f64> = x.iter().map(|v| *v as f64).collect();
            let yd: Vec<f64> = y.iter().map(|v| *v as f64).collect();
            let want = lane_dot_scalar(&xd, &yd);
            let got = dot_f64(&xd, &yd);
            assert_eq!(got.to_bits(), want.to_bits(), "f64 lane_dot diverged at len {len}");
        }
    }

    #[test]
    fn element_parallel_rows_match_emulation_bitwise() {
        for len in [1usize, 3, 7, 8, 9, 31, 64, 101] {
            let b = arb_f32(len, 7);
            let mut c1 = arb_f32(len, 8);
            let mut c2 = c1.clone();
            fma_row_f32(&mut c1, 0.37, &b);
            fma_row_scalar(&mut c2, 0.37, &b);
            assert_eq!(bits(&c1), bits(&c2), "fma_row len {len}");
            fnma_row_f32(&mut c1, 1.25, &b);
            fnma_row_scalar(&mut c2, 1.25, &b);
            assert_eq!(bits(&c1), bits(&c2), "fnma_row len {len}");
            add_row_f32(&mut c1, &b);
            add_row_scalar(&mut c2, &b);
            assert_eq!(bits(&c1), bits(&c2), "add_row len {len}");
            scale_row_f32(&mut c1, -0.11);
            scale_row_scalar(&mut c2, -0.11);
            assert_eq!(bits(&c1), bits(&c2), "scale_row len {len}");
            let mut y1 = arb_f32(len, 9);
            let mut y2 = y1.clone();
            rot_span_f32(&mut c1, &mut y1, 0.8, 0.6);
            rot_span_scalar(&mut c2, &mut y2, 0.8, 0.6);
            assert_eq!(bits(&c1), bits(&c2), "rot_span x len {len}");
            assert_eq!(bits(&y1), bits(&y2), "rot_span y len {len}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn lane_dot_propagates_nan_and_inf_through_zeros() {
        // the branchless no-zero-skip guarantee must survive
        // vectorization: 0·NaN and 0·Inf poison the sum
        for len in [5usize, 8, 13, 24] {
            for poison in [f32::NAN, f32::INFINITY] {
                let mut x = vec![0.0f32; len];
                let y = vec![1.0f32; len];
                x[len - 1] = poison;
                let mut yz = y.clone();
                yz[len - 1] = 0.0;
                let d = dot_f32(&x, &yz);
                assert!(d.is_nan(), "0·{poison} must poison the dot, got {d}");
                assert!(lane_dot_scalar(&x, &yz).is_nan());
            }
        }
    }

    #[test]
    fn negative_zero_survives_the_scalar_tail_rule() {
        // a -0.0 accumulator lane must not be flipped by a zero-padded
        // tail: (-0.0) + 0.0 would be +0.0. The tail is folded
        // scalar-wise instead, so a dot of all -0.0·positive terms
        // keeps the sign at every ragged length.
        for len in 1..=9usize {
            let x = vec![-0.0f32; len];
            let y = vec![1.0f32; len];
            let d = dot_f32(&x, &y);
            assert_eq!(d.to_bits(), (-0.0f32).to_bits(), "len {len}: got {d}");
        }
    }

    #[test]
    fn bf16_batch_matches_scalar_on_every_length_and_special() {
        let mut vals = arb_f32(67, 3);
        vals.extend_from_slice(&[
            0.0,
            -0.0,
            1.0,
            -1.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(0x7F80_0001), // sneaky NaN: naive truncation quiets it to ∞
            f32::from_bits(0xFF80_0001),
            f32::MIN_POSITIVE / 2.0, // subnormal
            3.4e38,
            1.0 + 2f32.powi(-8), // RNE tie, rounds down
            1.0 + 3.0 * 2f32.powi(-8), // RNE tie, rounds up
        ]);
        for len in 0..vals.len() {
            let src = &vals[..len];
            let mut got = vec![0u16; len];
            f32_to_bf16_batch(src, &mut got);
            for (i, (g, s)) in got.iter().zip(src).enumerate() {
                assert_eq!(*g, f32_to_bf16(*s), "narrow idx {i} of len {len}");
            }
            let mut wide = vec![0.0f32; len];
            bf16_to_f32_batch(&got, &mut wide);
            for (i, (w, g)) in wide.iter().zip(&got).enumerate() {
                assert_eq!(w.to_bits(), bf16_to_f32(*g).to_bits(), "widen idx {i} of len {len}");
            }
            let mut q = src.to_vec();
            quantize_bf16_batch(&mut q);
            for (i, (qv, w)) in q.iter().zip(&wide).enumerate() {
                assert_eq!(qv.to_bits(), w.to_bits(), "quantize idx {i} of len {len}");
            }
        }
    }

    #[test]
    fn mode_override_roundtrips() {
        let before = mode();
        set_mode(SimdMode::Scalar);
        assert_eq!(mode(), SimdMode::Scalar);
        assert_eq!(active_backend(), "scalar");
        set_mode(SimdMode::Auto);
        assert_eq!(mode(), SimdMode::Auto);
        set_mode(before);
    }
}
