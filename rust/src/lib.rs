//! `lowrank_sge` — Optimal Low-Rank Stochastic Gradient Estimation for
//! LLM Training (Li, Ren, Zhang, Chen, Peng; CS.LG 2026), reproduced as a
//! three-layer Rust + JAX + Pallas training framework.
//!
//! # Layer map
//!
//! * **L3 (this crate)** — the run-time system: projection samplers
//!   ([`projection`]), **the estimator engine**
//!   ([`estimator::engine`] — the single owner of Algorithm 1's
//!   project→estimate→lift→update step, with preallocated workspaces;
//!   the f32 [`estimator::engine::GradEstimator`] steps both trainers
//!   allocation-free in steady state, the f64
//!   [`estimator::engine::OracleEngine`] drives the §6.1 MSE study),
//!   the lazy-update optimizer stack ([`optim`]), the PJRT runtime that
//!   executes AOT-compiled JAX/Pallas artifacts ([`runtime`];
//!   `HostTensor` payloads are `Arc`-backed copy-on-write, so input
//!   staging is zero-copy), data pipeline ([`data`]), trainers and the
//!   DDP coordination ([`coordinator`] — artifact wiring around the
//!   engine, with a [`coordinator::Collective`] backend switch between
//!   in-process and multi-process gradient averaging), the sharded
//!   checkpoint/resume subsystem ([`ckpt`]: CRC-verified binary shards
//!   written through the kernel pool, atomic commit, `LATEST` pointer,
//!   retention, bit-exact state round-trip, fully-async background
//!   saves), the MSE theory + toy experiments ([`estimator`]), and the
//!   experiment harnesses ([`exp`]).
//! * **L3 comm layer** — [`comm`]: the multi-process collective
//!   communication subsystem behind `lowrank-sge launch --nproc N`:
//!   file/env rendezvous with atomic rank claims and a per-launch run
//!   token (stale dirs fail loudly), TCP/Unix-socket transport with
//!   timeouts, a CRC-verified wire format in the checkpoint codec's
//!   framing with an f32/bf16 **dtype lane** (`--comm-dtype` — bf16
//!   halves collective bandwidth; contributions round once at the
//!   source, arithmetic stays f32 on the kernel pool), and chunked-ring
//!   + pairing-tree collectives whose combine order is a pure function
//!   of (world, length) — on the f32 lane matching the in-process
//!   all-reduce, so distributed gradients (and checkpoints) are bitwise
//!   identical to the single-process run, and on either lane ring ≡
//!   tree bitwise. The ring is phase-split (exchange / chunk reduce /
//!   gather) so the trainer's slot pipeline
//!   ([`coordinator::Collective::allreduce_mean_slots`]) overlaps slot
//!   k's reduce on the pool with slot k+1's exchange on the sockets.
//! * **L3 observability layer** — [`obs`]: passive tracing + metrics
//!   threaded through every layer above. A span recorder (thread-local
//!   lock-free rings, Chrome `trace_event` export via `--trace-out`)
//!   around kernel-pool tasks, engine step phases, comm collective
//!   phases, and async-ckpt saves; a metrics registry (wire bytes per
//!   dtype lane, pool queue-wait histograms, per-layer lift-residual
//!   norms, per-phase step times) snapshotted as JSONL via
//!   `--metrics-out`, gathered cross-rank to the leader over the
//!   existing `all_gather`; a measured memory ledger
//!   ([`obs::TrackedAlloc`] live/peak bytes + `/proc` VmHWM) beside
//!   the analytical model in `exp memory`; estimator-quality gauges
//!   ([`obs::quality`]: an unbiasedness sentinel and a per-layer
//!   variance/MSE proxy normalized by the Theorem-2 `c·n/r` bound,
//!   probed at the lazy-update boundary and on a `--probe-every`
//!   rotating schedule, exported as `mse_ratio[layer]` /
//!   `bias_sentinel[layer]` series and echoed in the rank-adaptation
//!   decision log); and a run-health monitor ([`obs::monitor`]:
//!   per-phase heartbeat watermarks, a `--stall-timeout` watchdog, a
//!   read-only `--monitor-addr` TCP status endpoint, and a
//!   panic/peer-death postmortem blackbox). Off by default and
//!   **non-perturbing by contract**: disabled instrumentation is one
//!   relaxed atomic load, and enabling it — quality probes included,
//!   which draw from a dedicated forked RNG stream — changes no
//!   trained bit (pinned by `tests/obs_determinism.rs` and
//!   `tests/obs_monitor.rs`).
//! * **L3 compute substrate** — [`kernel`]: the one Scalar-generic
//!   (f32/f64) dense compute layer — blocked GEMM, AXPY/scale,
//!   deterministic reductions, strided panel primitives — running on a
//!   persistent thread pool whose parallel results are **bitwise
//!   identical to serial at any thread count**, over an explicit
//!   8-wide f32 / 4-wide f64 SIMD vector core ([`kernel::simd`]:
//!   runtime-dispatched AVX/NEON tiles with a portable scalar
//!   emulation of the exact same fixed-lane accumulation order, so
//!   serial ≡ parallel ≡ SIMD bitwise on every host; `LOWRANK_SIMD=
//!   scalar` forces the emulation). The same module owns the 8-wide
//!   bf16⇄f32 convert lane behind the comm wire codec. [`linalg`]
//!   (f64 `Mat` ops, QR, Jacobi eig), [`model`] (f32 lift/ZO
//!   tensors), the [`projection`] batch sampler, and the
//!   [`coordinator`] slot fan-out + DDP all-reduce are all thin
//!   layers over it; `--threads N` / `LOWRANK_THREADS` size the pool.
//! * **L3 serve layer** — [`serve`]: the multi-tenant fine-tune
//!   service (`lowrank-sge serve`). Both trainers' step loops are
//!   lifted into the [`coordinator::TrainSession`] seam (construct →
//!   `step()` → `finish()`), and the daemon round-robins those
//!   sessions over the shared kernel pool with per-job task
//!   attribution: a single-job serve run checkpoints bitwise
//!   identically to the standalone subcommand. Jobs arrive over a
//!   framed submit/status/cancel/fetch protocol reusing the comm
//!   layer's CRC codec, pass bounded-queue + tracked-allocator memory
//!   admission, and start from a shared base-model cache whose
//!   checkouts are copy-on-write `ParamStore`s — N tenants share one
//!   copy of the base weights until their first divergent write.
//! * **L2/L1 (python/, build-time only)** — JAX model graphs and Pallas
//!   kernels, lowered once to `artifacts/*.hlo.txt` by `make artifacts`.
//!
//! # Quickstart
//!
//! ```no_run
//! use lowrank_sge::projection::{build_sampler, ProjectorKind};
//! use lowrank_sge::rng::Rng;
//!
//! let mut rng = Rng::new(0);
//! let mut sampler = build_sampler(ProjectorKind::Stiefel, 256, 8, 1.0, None);
//! let v = sampler.sample(&mut rng); // V ∈ ℝ^{256×8}, VᵀV = (n/r)·I
//! assert_eq!((v.rows, v.cols), (256, 8));
//! ```

pub mod bench_util;
pub mod ckpt;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod estimator;
pub mod exp;
pub mod kernel;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod optim;
pub mod projection;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod serve;
