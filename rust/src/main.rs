//! `lowrank-sge` — launcher CLI.
//!
//! ```text
//! lowrank-sge exp toy-mse   [--family ipa|lr] [--mode independent|dependent] [--quick]
//! lowrank-sge exp finetune  [--steps N] [--tasks a,b,c] [--quick]
//! lowrank-sge exp curves    [--steps N] [--quick]            # Figure 6
//! lowrank-sge exp memory                                     # Table 2
//! lowrank-sge exp pretrain  --scale s|m|l [--steps N] [--quick]
//! lowrank-sge exp all       [--quick]
//! lowrank-sge pretrain      --scale s [--sampler stiefel] [--steps N] [--workers W]
//!                           [--threads T] [--save-every N] [--ckpt-dir D]
//!                           [--keep-last K] [--resume [latest|<step>]] …
//! lowrank-sge finetune      --task sst2 --method stiefel-lowrank-lr [--steps N]
//!                           [--threads T] [--save-every N] [--ckpt-dir D]
//!                           [--keep-last K] [--resume [latest|<step>]] …
//! lowrank-sge inspect                                        # list artifacts
//! ```
//!
//! Parallelism: `--threads T` (every subcommand; config keys
//! `pretrain.threads` / `finetune.threads`) sizes the kernel compute
//! pool that all dense math — GEMM, samplers, per-matrix optimizer
//! fan-out, DDP all-reduce — runs on. Default (0): the
//! `LOWRANK_THREADS` env var, else the machine's available
//! parallelism. **Determinism guarantee:** results are bitwise
//! identical at every thread count — `--threads 1` and `--threads 64`
//! produce the same losses, parameters, and checkpoint shards.
//!
//! Checkpointing: `--save-every N --ckpt-dir D` commits the full
//! training state (Θ, subspace B/V, Adam moments, RNG stream) every N
//! steps as CRC-verified shards under `D/step-*/`, keeps the newest
//! `--keep-last` (default 3, 0 = all), and maintains a `LATEST`
//! pointer. `--resume` (bare or `latest`) or `--resume <step>` restores
//! and continues the run.
//!
//! All experiment output lands in `results/` as CSV; see DESIGN.md §4
//! for the experiment ↔ paper-artifact index.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use lowrank_sge::ckpt::{CkptOptions, ResumeSpec};
use lowrank_sge::config::{ArgMap, ConfigFile};
use lowrank_sge::coordinator::{FinetuneConfig, FinetuneMethod, FinetuneTrainer, PretrainConfig, PretrainTrainer};
use lowrank_sge::estimator::Family;
use lowrank_sge::exp;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::runtime::Runtime;

fn artifacts_dir() -> PathBuf {
    std::env::var("LOWRANK_SGE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn usage() -> ! {
    eprintln!(
        "usage: lowrank-sge <exp|pretrain|finetune|inspect> …  (see `rust/src/main.rs` docs)"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    match cmd.as_str() {
        "exp" => {
            let Some(sub) = argv.get(1) else { usage() };
            let args = ArgMap::parse(&argv[2..])?;
            run_exp(sub, &args)
        }
        "pretrain" => {
            let args = ArgMap::parse(&argv[1..])?;
            cmd_pretrain(&args)
        }
        "finetune" => {
            let args = ArgMap::parse(&argv[1..])?;
            cmd_finetune(&args)
        }
        "inspect" => cmd_inspect(),
        _ => usage(),
    }
}

fn run_exp(sub: &str, args: &ArgMap) -> Result<()> {
    let quick = args.has_flag("quick");
    let threads = args.threads_or(0);
    if threads > 0 {
        lowrank_sge::kernel::set_global_threads(threads);
    }
    let results = exp::results_dir();
    match sub {
        "toy-mse" => {
            let family = Family::parse(args.str_or("family", "both"));
            let mode = args.str_or("mode", "both");
            let fams = match family {
                Some(f) => vec![f],
                None => vec![Family::Lr, Family::Ipa],
            };
            let modes: Vec<bool> = match mode {
                "independent" => vec![false],
                "dependent" => vec![true],
                _ => vec![false, true],
            };
            for f in fams {
                for dep in &modes {
                    let mut opts = if quick {
                        exp::toy_mse::ToyMseOptions::quick(f, *dep)
                    } else {
                        exp::toy_mse::ToyMseOptions::paper(f, *dep)
                    };
                    if let Some(r) = args.get("reps") {
                        opts.reps = r.parse().unwrap_or(opts.reps);
                    }
                    let tag = format!(
                        "toy_mse_{}_{}",
                        f.name(),
                        if *dep { "dependent" } else { "independent" }
                    );
                    exp::toy_mse::run(&opts, &results.join(format!("{tag}.csv")))?;
                }
            }
            Ok(())
        }
        "memory" => {
            exp::memory::run(&results.join("table2_memory.csv"))?;
            Ok(())
        }
        "grad-rank" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            exp::diagnostics::run(&mut rt, &results.join("grad_rank.csv"))?;
            Ok(())
        }
        "ablation" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            let mut opts = exp::ablation::AblationOptions::default();
            opts.steps = args.u64_or("steps", if quick { 40 } else { opts.steps });
            exp::ablation::run(&mut rt, &artifacts_dir(), &opts, &results.join("ablation.csv"))
        }
        "finetune" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            let mut opts = if quick {
                exp::finetune::FinetuneOptions::quick()
            } else {
                exp::finetune::FinetuneOptions::paper()
            };
            opts.steps = args.u64_or("steps", opts.steps);
            if let Some(tasks) = args.get("tasks") {
                opts.tasks = tasks.split(',').map(|s| s.trim().to_string()).collect();
            }
            exp::finetune::run(&mut rt, &artifacts_dir(), &opts, &results)
        }
        "curves" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            let mut opts = if quick {
                exp::finetune::FinetuneOptions::quick()
            } else {
                exp::finetune::FinetuneOptions::paper()
            };
            opts.steps = args.u64_or("steps", opts.steps);
            if let Some(tasks) = args.get("tasks") {
                opts.tasks = tasks.split(',').map(|s| s.trim().to_string()).collect();
            }
            exp::finetune::run_curves(&mut rt, &artifacts_dir(), &opts, &results)
        }
        "pretrain" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            let scale = args.str_or("scale", "s").to_string();
            let mut opts = if quick {
                exp::pretrain::PretrainOptions::quick(&scale)
            } else {
                exp::pretrain::PretrainOptions::paper(&scale)
            };
            opts.steps = args.u64_or("steps", opts.steps);
            opts.workers = args.usize_or("workers", opts.workers);
            exp::pretrain::run(&mut rt, &artifacts_dir(), &opts, &results)
        }
        "all" => {
            // the full reproduction suite, in paper order
            for f in [Family::Lr, Family::Ipa] {
                for dep in [false, true] {
                    let opts = if quick {
                        exp::toy_mse::ToyMseOptions::quick(f, dep)
                    } else {
                        exp::toy_mse::ToyMseOptions::paper(f, dep)
                    };
                    let tag = format!(
                        "toy_mse_{}_{}",
                        f.name(),
                        if dep { "dependent" } else { "independent" }
                    );
                    exp::toy_mse::run(&opts, &results.join(format!("{tag}.csv")))?;
                }
            }
            let mut rt = Runtime::new(artifacts_dir())?;
            let fopts = if quick {
                exp::finetune::FinetuneOptions::quick()
            } else {
                exp::finetune::FinetuneOptions::paper()
            };
            exp::finetune::run(&mut rt, &artifacts_dir(), &fopts, &results)?;
            exp::memory::run(&results.join("table2_memory.csv"))?;
            for scale in ["s", "m", "l"] {
                let opts = if quick {
                    exp::pretrain::PretrainOptions::quick(scale)
                } else {
                    exp::pretrain::PretrainOptions::paper(scale)
                };
                exp::pretrain::run(&mut rt, &artifacts_dir(), &opts, &results)?;
            }
            Ok(())
        }
        _ => usage(),
    }
}

fn parse_method(s: &str) -> Result<FinetuneMethod> {
    Ok(match s {
        "zero-shot" => FinetuneMethod::ZeroShot,
        "vanilla-lr" => FinetuneMethod::VanillaLr,
        "vanilla-ipa" => FinetuneMethod::VanillaIpa,
        other => {
            if let Some(kind) = other
                .strip_suffix("-lowrank-lr")
                .and_then(ProjectorKind::parse)
            {
                FinetuneMethod::LowRankLr(kind)
            } else if let Some(kind) = other
                .strip_suffix("-lowrank-ipa")
                .and_then(ProjectorKind::parse)
            {
                FinetuneMethod::LowRankIpa(kind)
            } else {
                bail!("unknown method {other:?} (try stiefel-lowrank-lr, vanilla-ipa, …)")
            }
        }
    })
}

/// Checkpoint policy from CLI + config file (`<section>.save_every`,
/// `<section>.ckpt_dir`, `<section>.keep_last`). `--resume` is CLI-only:
/// bare `--resume` (or `--resume latest`) follows `LATEST`, `--resume
/// <step>` picks a committed step.
fn ckpt_options(args: &ArgMap, file: &ConfigFile, section: &str) -> Result<CkptOptions> {
    let resume = match args.flag_or_value("resume") {
        None => None,
        Some(None) => Some(ResumeSpec::Latest),
        Some(Some(v)) => Some(ResumeSpec::parse(v)?),
    };
    let dir = args
        .get("ckpt-dir")
        .or_else(|| file.str_opt(&format!("{section}.ckpt_dir")))
        .map(PathBuf::from);
    let opts = CkptOptions {
        save_every: args
            .u64_or("save-every", file.i64_or(&format!("{section}.save_every"), 0).max(0) as u64),
        keep_last: args
            .usize_or("keep-last", file.i64_or(&format!("{section}.keep_last"), 3).max(0) as usize),
        dir,
        resume,
    };
    if (opts.save_every > 0 || opts.resume.is_some()) && opts.dir.is_none() {
        bail!("--save-every/--resume need --ckpt-dir (or {section}.ckpt_dir in the config)");
    }
    Ok(opts)
}

fn cmd_pretrain(args: &ArgMap) -> Result<()> {
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    // defaults ← config file (--config path, [pretrain] section) ← CLI
    let file = match args.get("config") {
        Some(p) => ConfigFile::load(std::path::Path::new(p))?,
        None => ConfigFile::default(),
    };
    let sampler = ProjectorKind::parse(
        args.get("sampler")
            .unwrap_or_else(|| file.str_or("pretrain.sampler", "stiefel")),
    )
    .context("bad sampler")?;
    let cfg = PretrainConfig {
        scale: args
            .get("scale")
            .unwrap_or_else(|| file.str_or("pretrain.scale", "s"))
            .to_string(),
        sampler,
        c: args.f64_or("c", file.f64_or("pretrain.c", 1.0)),
        k_interval: args.u64_or("k", file.i64_or("pretrain.k", 25) as u64),
        steps: args.u64_or("steps", file.i64_or("pretrain.steps", 200) as u64),
        lr: args.f32_or("lr", file.f64_or("pretrain.lr", 2e-3) as f32),
        warmup: args.u64_or("warmup", file.i64_or("pretrain.warmup", 10) as u64),
        clip: args.f32_or("clip", file.f64_or("pretrain.clip", 1.0) as f32),
        weight_decay: args.f32_or("wd", file.f64_or("pretrain.wd", 0.05) as f32),
        seed: args.u64_or("seed", file.i64_or("pretrain.seed", 2026) as u64),
        workers: args.usize_or("workers", file.i64_or("pretrain.workers", 1) as usize),
        eval_every: args.u64_or("eval-every", file.i64_or("pretrain.eval_every", 25) as u64),
        eval_batches: args.usize_or("eval-batches", 2),
        threads: args.threads_or(file.usize_or("pretrain.threads", 0)),
        ckpt: ckpt_options(args, &file, "pretrain")?,
    };
    println!(
        "pretrain scale={} sampler={} steps={} K={} workers={} threads={}",
        cfg.scale,
        sampler.name(),
        cfg.steps,
        cfg.k_interval,
        cfg.workers,
        if cfg.threads == 0 { "auto".to_string() } else { cfg.threads.to_string() }
    );
    if let Some(resume) = cfg.ckpt.resume {
        println!("resuming from {resume} in {:?}", cfg.ckpt.dir.as_ref().unwrap());
    }
    if cfg.ckpt.save_every > 0 {
        println!(
            "checkpointing every {} steps to {:?} (keep last {})",
            cfg.ckpt.save_every,
            cfg.ckpt.dir.as_ref().unwrap(),
            cfg.ckpt.keep_last
        );
    }
    let mut trainer = PretrainTrainer::new(&mut rt, &dir, cfg)?;
    let res = trainer.run()?;
    println!(
        "final train loss {:.4} (tail {:.4}); eval {:?}; mean step {:.3}s",
        res.log.final_train_loss().unwrap_or(f32::NAN),
        res.log.tail_mean_loss(10).unwrap_or(f32::NAN),
        res.final_eval_loss,
        res.log.mean_step_time(3).unwrap_or(f64::NAN)
    );
    if let Some(out) = args.get("out-csv") {
        res.log.write_csv(std::path::Path::new(out))?;
        println!("wrote {out}");
    }
    if let Some(ckpt) = args.get("checkpoint") {
        trainer.save_checkpoint(std::path::Path::new(ckpt))?;
        println!("checkpoint saved to {ckpt}");
    }
    Ok(())
}

fn cmd_finetune(args: &ArgMap) -> Result<()> {
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    // defaults ← config file (--config path, [finetune] section) ← CLI
    let file = match args.get("config") {
        Some(p) => ConfigFile::load(std::path::Path::new(p))?,
        None => ConfigFile::default(),
    };
    let method = parse_method(args.str_or("method", "stiefel-lowrank-lr"))?;
    let cfg = FinetuneConfig {
        task: args.str_or("task", "sst2").to_string(),
        method,
        steps: args.u64_or("steps", 300),
        k_interval: args.u64_or("k", 50),
        ipa_lr: args.f32_or("ipa-lr", 1e-3),
        zo_lr: args.f32_or("zo-lr", 2e-3),
        sigma: args.f32_or("sigma", 1e-2),
        c: args.f64_or("c", 1.0),
        seed: args.u64_or("seed", 2026),
        eval_examples: args.usize_or("eval-examples", 256),
        threads: args.threads_or(file.usize_or("finetune.threads", 0)),
        ckpt: ckpt_options(args, &file, "finetune")?,
    };
    println!("finetune task={} method={} steps={}", cfg.task, method.name(), cfg.steps);
    if let Some(resume) = cfg.ckpt.resume {
        println!("resuming from {resume} in {:?}", cfg.ckpt.dir.as_ref().unwrap());
    }
    let mut trainer = FinetuneTrainer::new(&mut rt, &dir, cfg)?;
    let res = trainer.run()?;
    println!(
        "accuracy {:.3}; final loss {:.4}; mean step {:.4}s",
        res.accuracy,
        res.log.tail_mean_loss(10).unwrap_or(f32::NAN),
        res.log.mean_step_time(3).unwrap_or(f64::NAN)
    );
    if let Some(out) = args.get("out-csv") {
        res.log.write_csv(std::path::Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    for name in rt.available()? {
        let art = rt.load(&name)?;
        println!(
            "{name:<22} inputs {:>3}  outputs {:>2}  compile {:.2}s  model {}",
            art.manifest.inputs.len(),
            art.manifest.outputs.len(),
            art.compile_time_s,
            art.manifest.meta.get("model").map(|s| s.as_str()).unwrap_or("-")
        );
    }
    Ok(())
}
