//! `lowrank-sge` — launcher CLI.
//!
//! ```text
//! lowrank-sge exp toy-mse   [--family ipa|lr] [--mode independent|dependent] [--quick]
//! lowrank-sge exp finetune  [--steps N] [--tasks a,b,c] [--quick]
//! lowrank-sge exp curves    [--steps N] [--quick]            # Figure 6
//! lowrank-sge exp memory                                     # Table 2
//! lowrank-sge exp pretrain  --scale s|m|l [--steps N] [--quick]
//! lowrank-sge exp all       [--quick]
//! lowrank-sge pretrain      --scale s [--sampler stiefel] [--steps N] [--workers W]
//!                           [--threads T] [--save-every N] [--ckpt-dir D]
//!                           [--keep-last K] [--resume [latest|<step>]]
//!                           [--track-refresh T] [--rank-adapt]
//!                           [--rank-min R] [--rank-window W] [--rank-decay D]
//!                           [--rank-factor F] [--probe-every K]
//!                           [--monitor-addr H:P] [--stall-timeout MS] …
//! lowrank-sge finetune      --task sst2 --method stiefel-lowrank-lr [--steps N]
//!                           [--threads T] [--save-every N] [--ckpt-dir D]
//!                           [--keep-last K] [--resume [latest|<step>]]
//!                           [--track-refresh T]
//!                           [--monitor-addr H:P] [--stall-timeout MS] …
//! lowrank-sge launch        --nproc N [--transport unix|tcp] [--rdzv-dir D]
//!                           [--comm-timeout-ms T] [--algo ring|tree|auto]
//!                           [--comm-dtype f32|bf16]
//!                           <subcommand …>                   # multi-process DDP
//! lowrank-sge comm-check    [--len N] [--comm-dtype f32|bf16]
//!                           [--fail-rank R] [--trace-out T] [--metrics-out M]
//!                           [--monitor-addr H:P]
//! lowrank-sge serve         [--addr H:P] [--ckpt-root D] [--max-active N]
//!                           [--max-open N] [--mem-budget-mb M] [--max-conns C]
//!                           [--idle-timeout MS] [--threads T]
//!                                                            # multi-tenant daemon
//! lowrank-sge job submit    --addr H:P [--task sst2] [--method m] [--steps N]
//!                           [--seed S] [--save-every N] [--keep-last K] …
//! lowrank-sge job status    --addr H:P --job N   # one snapshot (add --wait to poll)
//! lowrank-sge job cancel    --addr H:P --job N
//! lowrank-sge job fetch     --addr H:P --job N   # final result of a finished job
//! lowrank-sge job shutdown  --addr H:P           # drain running jobs, then exit
//! lowrank-sge inspect                                        # list artifacts
//! ```
//!
//! Multi-tenant serving: `serve` runs a long-lived daemon that accepts
//! fine-tune jobs over a framed TCP protocol (the comm layer's
//! CRC-verified codec) and round-robins their training sessions over
//! the shared kernel pool — the same `TrainSession` objects the
//! standalone `finetune` subcommand drives, so a single-job serve run
//! writes bitwise-identical checkpoints at the same seed. Jobs start
//! from a shared base-model cache handing out copy-on-write
//! `ParamStore`s (N tenants, one copy of the base weights until first
//! divergent write), pass admission control (`--max-open` bounded
//! queue; `--mem-budget-mb` heap budget from the tracked-allocator
//! ledger) with reject reasons on the wire, and checkpoint into
//! isolated `<ckpt-root>/job-<id>/` directories. A failed job —
//! including a failed background checkpoint write — reports `failed`
//! over the status verb without disturbing its neighbors. `job …` is
//! the matching client: submit prints the job id, status/fetch print
//! `key=value` lines, shutdown drains gracefully.
//!
//! Observability (`pretrain`, `finetune`, `comm-check`): `--trace-out
//! <path>` records structured spans (kernel-pool tasks, engine phases,
//! comm collectives, async checkpoint saves, trainer step phases) and
//! exports Chrome `trace_event` JSON for chrome://tracing / Perfetto;
//! `--metrics-out <path>` turns on the metrics registry (wire bytes per
//! dtype lane, pool task counts + queue-wait, per-phase step times,
//! per-layer lift residuals, the measured memory ledger) and writes one
//! JSONL snapshot line per rank — in a `launch` world each rank traces
//! to a rank-scoped sibling file, the leader gathers every rank's
//! metrics over the collective and merges the traces. Both are off by
//! default and non-perturbing: the trained bits are bitwise identical
//! with and without them (pinned by `tests/obs_determinism.rs`).
//!
//! Run health + estimator quality: `--monitor-addr <host:port>` serves
//! newline-delimited JSON status snapshots over read-only TCP (one
//! line per connection: phase watermarks, stall count, metrics
//! registry); in a `launch` world only the leader binds. A
//! `--stall-timeout <ms>` watchdog thread flags ranks whose heartbeat
//! watermark stops advancing, and on panic or peer death a
//! flight-recorder blackbox dumps the last span ring, final metrics
//! snapshot, and comm peer events to `<ckpt-dir>/postmortem.rank<r>.json`.
//! `pretrain --probe-every K` adds estimator-quality probes: every K
//! steps one rotating subspace slot (plus every slot at each
//! lazy-update boundary) gets an unbiasedness sentinel and a
//! variance/MSE gauge normalized by the Theorem-2 `c·n/r` bound,
//! exported as `mse_ratio[layer]` / `bias_sentinel[layer]` series and
//! echoed as a context column in the `[rank-adapt]` decision log
//! (decisions themselves are unchanged). The probes draw from a
//! dedicated forked RNG stream, so trained bytes stay bitwise
//! identical with probing on or off (see [`lowrank_sge::obs`]).
//!
//! Multi-process DDP: `launch --nproc N pretrain …` spawns N ranks of
//! this binary wired into one collective group (env-var rendezvous,
//! Unix or TCP sockets; see [`lowrank_sge::comm`]), prefixes each
//! child's output with `[rank r]`, and propagates the first non-zero
//! exit. `--workers` is the *global* shard count (default: the world
//! size) and must divide evenly across ranks. The cross-process
//! all-reduce uses the same pairing-tree combine order as the
//! in-process path, so `launch --nproc W` with one worker per rank
//! writes the bitwise-identical rank-0 checkpoint as a single-process
//! `--workers W` run. Only the leader rank (rank 0) writes checkpoints
//! and metrics — enforced at runtime. `--comm-dtype bf16` (or
//! `LOWRANK_COMM_DTYPE=bf16`) compresses the all-reduce payloads to
//! bfloat16 on the wire — half the collective bandwidth; reduction
//! arithmetic stays f32, ring ≡ tree stays bitwise, and mixing dtypes
//! across ranks fails loudly at connect. The per-slot collectives are
//! pipelined: slot k's chunk reduce on the kernel pool overlaps slot
//! k+1's ring exchange on the sockets. `comm-check` runs ring and tree
//! all-reduces plus broadcast/barrier/all-gather inside a launch world
//! and verifies every rank got identical bits (in whichever wire dtype
//! is configured); its `--fail-rank R` makes rank R exit 1 before
//! rendezvous — fault injection for the runner's fast-failure path.
//!
//! Parallelism: `--threads T` (every subcommand; config keys
//! `pretrain.threads` / `finetune.threads`) sizes the kernel compute
//! pool that all dense math — GEMM, samplers, per-matrix optimizer
//! fan-out, DDP all-reduce — runs on. Default (0): the
//! `LOWRANK_THREADS` env var, else the machine's available
//! parallelism. The kernels themselves run on an explicit SIMD vector
//! core (AVX/NEON, runtime-dispatched); `LOWRANK_SIMD=scalar` forces
//! the portable lane emulation (default `auto` dispatches the vector
//! tiles). **Determinism guarantee:** results are bitwise identical at
//! every thread count *and* under either SIMD setting — `--threads 1`
//! and `--threads 64`, vector tiles or forced scalar, produce the same
//! losses, parameters, and checkpoint shards, because every backend
//! implements the same fixed-lane accumulation order (see
//! [`lowrank_sge::kernel::simd`]).
//!
//! Subspace tracking + rank adaptation: `--track-refresh T` (config
//! keys `pretrain.track_refresh` / `finetune.track_refresh`)
//! warm-starts the Stiefel resample — the previous frame gets a rank-1
//! tilt + Cholesky-QR refresh instead of a fresh n×r Gaussian QR, with
//! a full Haar redraw every T-th resample; `--track-refresh 0` disables
//! tracking (the paper-exact schedule; finetune's default). The
//! Theorem-2 condition VᵀV = (cn/r)·I holds exactly either way, and
//! both paths keep the bitwise thread-count/world-size invariance.
//! `pretrain --rank-adapt` turns on the online per-layer rank
//! controller: at each lazy-update boundary the all-reduced lift
//! residuals feed a trend test (`--rank-window`, `--rank-decay`), and a
//! decaying slot shrinks to ⌊r·`--rank-factor`⌋ (floored at
//! `--rank-min`) — B, V, Adam moments, engine scratch, and the
//! all-reduce wire all drop to the new footprint in place. Every rank
//! takes the identical decision and logs a `[rank-adapt rN]` line; the
//! decision windows are checkpointed, so resumes replay the same rank
//! schedule.
//!
//! Checkpointing: `--save-every N --ckpt-dir D` commits the full
//! training state (Θ, subspace B/V, Adam moments, RNG stream) every N
//! steps as CRC-verified shards under `D/step-*/`, keeps the newest
//! `--keep-last` (default 3, 0 = all), and maintains a `LATEST`
//! pointer. `--resume` (bare or `latest`) or `--resume <step>` restores
//! and continues the run.
//!
//! All experiment output lands in `results/` as CSV; see DESIGN.md §4
//! for the experiment ↔ paper-artifact index.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use lowrank_sge::ckpt::{CkptOptions, ResumeSpec};
use lowrank_sge::comm::{self, Algorithm, TransportKind, WireDtype};
use lowrank_sge::config::{ArgMap, ConfigFile};
use lowrank_sge::coordinator::{
    Collective, FinetuneConfig, FinetuneMethod, FinetuneTrainer, PretrainConfig, PretrainTrainer,
};
use lowrank_sge::estimator::Family;
use lowrank_sge::exp;
use lowrank_sge::optim::RankAdaptConfig;
use lowrank_sge::projection::ProjectorKind;
use lowrank_sge::runtime::Runtime;

// The measured memory ledger (obs::alloc): every allocation in this
// binary goes through the tracking wrapper, so `exp memory` and the
// trainers report real heap peaks. Disabled-metrics cost is four
// relaxed atomics on a path that already takes a malloc.
#[global_allocator]
static GLOBAL: lowrank_sge::obs::TrackedAlloc = lowrank_sge::obs::TrackedAlloc;

fn artifacts_dir() -> PathBuf {
    std::env::var("LOWRANK_SGE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn usage() -> ! {
    eprintln!(
        "usage: lowrank-sge <exp|pretrain|finetune|serve|job|launch|comm-check|inspect> …  \
         (see `rust/src/main.rs` docs)"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    // `launch` children carry the comm env; only rank-aware subcommands
    // may run under it — N copies of an experiment would race on the
    // same results/ files
    if std::env::var("LOWRANK_COMM_RDZV").is_ok()
        && !matches!(cmd.as_str(), "pretrain" | "comm-check" | "finetune")
    {
        bail!(
            "`{cmd}` is not rank-aware; run it without `launch` \
             (multi-process mode supports pretrain and comm-check)"
        );
    }
    match cmd.as_str() {
        "exp" => {
            let Some(sub) = argv.get(1) else { usage() };
            let args = ArgMap::parse(&argv[2..])?;
            run_exp(sub, &args)
        }
        "pretrain" => {
            let args = ArgMap::parse(&argv[1..])?;
            cmd_pretrain(&args)
        }
        "finetune" => {
            let args = ArgMap::parse(&argv[1..])?;
            cmd_finetune(&args)
        }
        "serve" => {
            let args = ArgMap::parse(&argv[1..])?;
            cmd_serve(&args)
        }
        "job" => {
            let Some(sub) = argv.get(1) else { usage() };
            let args = ArgMap::parse(&argv[2..])?;
            cmd_job(sub, &args)
        }
        "launch" => cmd_launch(&argv[1..]),
        "comm-check" => {
            let args = ArgMap::parse(&argv[1..])?;
            cmd_comm_check(&args)
        }
        "inspect" => cmd_inspect(),
        _ => usage(),
    }
}

/// `launch --nproc N [--transport …] [--rdzv-dir …] [--comm-timeout-ms …]
/// [--algo …] <subcommand …>` — the runner's own flags end at the first
/// non-flag token; everything from there is the child command, passed
/// through verbatim.
fn cmd_launch(argv: &[String]) -> Result<()> {
    let mut opts = comm::LaunchOptions::default();
    let mut i = 0usize;
    let value = |argv: &[String], i: usize, flag: &str| -> Result<String> {
        argv.get(i + 1)
            .cloned()
            .with_context(|| format!("launch: {flag} needs a value"))
    };
    while i < argv.len() {
        match argv[i].as_str() {
            "--nproc" => {
                opts.nproc = value(argv, i, "--nproc")?
                    .parse()
                    .context("launch: --nproc must be a positive integer")?;
                i += 2;
            }
            "--transport" => {
                opts.transport = TransportKind::parse(&value(argv, i, "--transport")?)?;
                i += 2;
            }
            "--rdzv-dir" => {
                opts.rdzv_dir = Some(PathBuf::from(value(argv, i, "--rdzv-dir")?));
                i += 2;
            }
            "--comm-timeout-ms" => {
                opts.timeout_ms = value(argv, i, "--comm-timeout-ms")?
                    .parse()
                    .context("launch: --comm-timeout-ms must be an integer")?;
                i += 2;
            }
            "--algo" => {
                let algo = value(argv, i, "--algo")?;
                Algorithm::parse(&algo)?; // validate before handing to children
                opts.algo = Some(algo);
                i += 2;
            }
            "--comm-dtype" => {
                let dtype = value(argv, i, "--comm-dtype")?;
                WireDtype::parse(&dtype)?; // validate before handing to children
                opts.comm_dtype = Some(dtype);
                i += 2;
            }
            other if other.starts_with("--") => {
                bail!("launch: unknown runner flag {other:?} (child flags go after the subcommand)")
            }
            _ => break,
        }
    }
    let child_args = &argv[i..];
    let code = comm::run_launch(&opts, child_args)?;
    if code != 0 {
        std::process::exit(code);
    }
    Ok(())
}

/// Collective self-test: inside a `launch` world, all-reduce a
/// deterministic per-rank payload with both algorithms, cross-check the
/// results bitwise across ranks, and exercise broadcast + barrier. The
/// ring ≡ tree bitwise check holds in both wire dtypes — under bf16 it
/// pins the compressed-lane determinism contract.
fn cmd_comm_check(args: &ArgMap) -> Result<()> {
    let len = args.usize_or("len", 100_003);
    // fault injection for the launch runner's fast-failure path: the
    // nominated rank dies before it ever touches the rendezvous. The
    // value is validated (numeric, in range) so a typo'd rank is a
    // loud error on every rank, never silently-disabled injection.
    if let Some(spec) = args.get("fail-rank") {
        let fail: usize = spec
            .parse()
            .with_context(|| format!("comm-check: --fail-rank {spec:?} must be a rank index"))?;
        if let Ok(w) = std::env::var("LOWRANK_COMM_WORLD") {
            let world: usize = w.parse().context("LOWRANK_COMM_WORLD must be an integer")?;
            if fail >= world {
                bail!("comm-check: --fail-rank {fail} is out of range for world size {world}");
            }
        }
        let me = std::env::var("LOWRANK_COMM_RANK").ok().and_then(|s| s.parse::<usize>().ok());
        if me == Some(fail) {
            eprintln!("comm-check: rank {fail} failing on request (--fail-rank)");
            std::process::exit(1);
        }
    }
    // comm-check always reports per-phase timing and wire traffic, so
    // the metrics registry is unconditionally on here; --trace-out /
    // --metrics-out additionally export the run
    lowrank_sge::obs::init(args.trace_out(), args.metrics_out());
    lowrank_sge::obs::metrics::set_enabled(true);
    use lowrank_sge::obs::metrics::{STREAM_RECV, STREAM_SENT};
    type PhaseRow = (&'static str, f64, u64, u64);
    let mut phases: Vec<PhaseRow> = Vec::new();
    let mark = |phases: &mut Vec<PhaseRow>, name: &'static str, t0: Instant, s0: u64, r0: u64| {
        phases.push((
            name,
            t0.elapsed().as_secs_f64(),
            STREAM_SENT.get() - s0,
            STREAM_RECV.get() - r0,
        ));
    };
    let probe = || (Instant::now(), STREAM_SENT.get(), STREAM_RECV.get());

    // the override is threaded into connect (same argv on every rank ⇒
    // same lane), so the handshake verifies the lane actually used
    let (t0, s0, r0) = probe();
    let Some(mut comm) = comm::Communicator::from_env_with(args.comm_dtype()?)? else {
        bail!(
            "comm-check needs the launch environment (LOWRANK_COMM_RDZV …); \
             run it as `lowrank-sge launch --nproc N comm-check`"
        );
    };
    mark(&mut phases, "handshake", t0, s0, r0);
    let (rank, world) = (comm.rank(), comm.world());
    let base: Vec<f32> = (0..len)
        .map(|i| {
            let x = (i as u64).wrapping_mul(rank as u64 + 1).wrapping_add(7 * i as u64);
            (x % 1000) as f32 * 1e-3 - 0.25
        })
        .collect();

    let mut ring = base.clone();
    let (t0, s0, r0) = probe();
    comm.allreduce_sum_with(Algorithm::Ring, &mut ring)?;
    mark(&mut phases, "ring-allreduce", t0, s0, r0);
    let mut tree = base.clone();
    let (t0, s0, r0) = probe();
    comm.allreduce_sum_with(Algorithm::Tree, &mut tree)?;
    mark(&mut phases, "tree-allreduce", t0, s0, r0);
    for (i, (r, t)) in ring.iter().zip(&tree).enumerate() {
        if r.to_bits() != t.to_bits() {
            bail!("comm-check FAILED: ring and tree disagree at element {i} ({r} vs {t})");
        }
    }

    // cross-rank bitwise agreement: all-gather every rank's result CRC
    // (carried one byte per f32 — small-integer f32s are exact on every
    // target, unlike a raw from_bits smuggle that could hit NaN quieting)
    let bytes: Vec<u8> = ring.iter().flat_map(|v| v.to_le_bytes()).collect();
    let crc = lowrank_sge::ckpt::crc32::crc32(&bytes);
    let mine: Vec<f32> = crc.to_le_bytes().iter().map(|&b| b as f32).collect();
    let mut gathered = vec![0.0f32; 4 * world];
    let (t0, s0, r0) = probe();
    comm.all_gather(&mine, &mut gathered)?;
    mark(&mut phases, "all-gather", t0, s0, r0);
    for (r, peer_bytes) in gathered.chunks_exact(4).enumerate() {
        let peer_crc = u32::from_le_bytes([
            peer_bytes[0] as u8,
            peer_bytes[1] as u8,
            peer_bytes[2] as u8,
            peer_bytes[3] as u8,
        ]);
        if peer_crc != crc {
            bail!(
                "comm-check FAILED: rank {r} reduced to crc {peer_crc:08x}, \
                 rank {rank} to {crc:08x}"
            );
        }
    }

    // broadcast: everyone must end with rank 0's payload (which every
    // rank can recompute locally — the pattern is a function of rank)
    let expected0: Vec<f32> = (0..len)
        .map(|i| {
            let x = (i as u64).wrapping_add(7 * i as u64);
            (x % 1000) as f32 * 1e-3 - 0.25
        })
        .collect();
    let mut bcast = base.clone();
    let (t0, s0, r0) = probe();
    comm.broadcast(&mut bcast, 0)?;
    mark(&mut phases, "broadcast", t0, s0, r0);
    for (i, (b, e)) in bcast.iter().zip(&expected0).enumerate() {
        if b.to_bits() != e.to_bits() {
            bail!("comm-check FAILED: broadcast element {i} is {b}, expected rank 0's {e}");
        }
    }
    let (t0, s0, r0) = probe();
    comm.barrier()?;
    mark(&mut phases, "barrier", t0, s0, r0);
    println!(
        "comm-check ok rank={rank} world={world} len={len} dtype={} crc={crc:08x} (ring==tree)",
        comm.wire_dtype().name()
    );
    if rank == 0 {
        println!(
            "{:>16} {:>10} {:>10} {:>10} {:>10}",
            "phase", "time(s)", "sent(MB)", "recv(MB)", "MB/s"
        );
        for (name, secs, sent, recv) in &phases {
            let mb = (sent + recv) as f64 / 1e6;
            println!(
                "{name:>16} {secs:>10.4} {:>10.2} {:>10.2} {:>10.1}",
                *sent as f64 / 1e6,
                *recv as f64 / 1e6,
                if *secs > 0.0 { mb / secs } else { 0.0 }
            );
        }
    }
    // --monitor-addr: exercise the live status endpoint in-world — the
    // leader binds it, connects to itself over real TCP, reads one
    // snapshot line, and validates it as JSON; a dead or malformed
    // endpoint fails the check loudly
    if let Some(addr) = args.monitor_addr() {
        use lowrank_sge::obs::monitor;
        monitor::configure(rank, None);
        monitor::stamp(monitor::Phase::Barrier, phases.len() as u64);
        if rank == 0 {
            use std::io::BufRead;
            let bound = monitor::serve_status(addr)
                .with_context(|| format!("binding monitor endpoint on {addr}"))?;
            let stream = std::net::TcpStream::connect(bound)
                .context("connecting to the monitor endpoint")?;
            stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
            let mut line = String::new();
            std::io::BufReader::new(stream)
                .read_line(&mut line)
                .context("reading a monitor snapshot")?;
            let line = line.trim();
            if !monitor::check_json_line(line) {
                bail!("comm-check FAILED: monitor endpoint returned invalid JSON: {line:?}");
            }
            println!("[obs:monitor] endpoint snapshot ok ({} bytes)", line.len());
        }
    }
    // observability epilogue: gather metrics snapshots to the leader,
    // export + merge the Chrome traces (no-op without the flags)
    lowrank_sge::coordinator::export_run_obs(&mut Collective::Comm(comm))?;
    Ok(())
}

fn run_exp(sub: &str, args: &ArgMap) -> Result<()> {
    let quick = args.has_flag("quick");
    let threads = args.threads_or(0);
    if threads > 0 {
        lowrank_sge::kernel::set_global_threads(threads);
    }
    let results = exp::results_dir();
    match sub {
        "toy-mse" => {
            let family = Family::parse(args.str_or("family", "both"));
            let mode = args.str_or("mode", "both");
            let fams = match family {
                Some(f) => vec![f],
                None => vec![Family::Lr, Family::Ipa],
            };
            let modes: Vec<bool> = match mode {
                "independent" => vec![false],
                "dependent" => vec![true],
                _ => vec![false, true],
            };
            for f in fams {
                for dep in &modes {
                    let mut opts = if quick {
                        exp::toy_mse::ToyMseOptions::quick(f, *dep)
                    } else {
                        exp::toy_mse::ToyMseOptions::paper(f, *dep)
                    };
                    if let Some(r) = args.get("reps") {
                        opts.reps = r.parse().unwrap_or(opts.reps);
                    }
                    let tag = format!(
                        "toy_mse_{}_{}",
                        f.name(),
                        if *dep { "dependent" } else { "independent" }
                    );
                    exp::toy_mse::run(&opts, &results.join(format!("{tag}.csv")))?;
                }
            }
            Ok(())
        }
        "memory" => {
            exp::memory::run(&results.join("table2_memory.csv"))?;
            Ok(())
        }
        "grad-rank" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            exp::diagnostics::run(&mut rt, &results.join("grad_rank.csv"))?;
            Ok(())
        }
        "ablation" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            let mut opts = exp::ablation::AblationOptions::default();
            opts.steps = args.u64_or("steps", if quick { 40 } else { opts.steps });
            exp::ablation::run(&mut rt, &artifacts_dir(), &opts, &results.join("ablation.csv"))
        }
        "finetune" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            let mut opts = if quick {
                exp::finetune::FinetuneOptions::quick()
            } else {
                exp::finetune::FinetuneOptions::paper()
            };
            opts.steps = args.u64_or("steps", opts.steps);
            if let Some(tasks) = args.get("tasks") {
                opts.tasks = tasks.split(',').map(|s| s.trim().to_string()).collect();
            }
            exp::finetune::run(&mut rt, &artifacts_dir(), &opts, &results)
        }
        "curves" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            let mut opts = if quick {
                exp::finetune::FinetuneOptions::quick()
            } else {
                exp::finetune::FinetuneOptions::paper()
            };
            opts.steps = args.u64_or("steps", opts.steps);
            if let Some(tasks) = args.get("tasks") {
                opts.tasks = tasks.split(',').map(|s| s.trim().to_string()).collect();
            }
            exp::finetune::run_curves(&mut rt, &artifacts_dir(), &opts, &results)
        }
        "pretrain" => {
            let mut rt = Runtime::new(artifacts_dir())?;
            let scale = args.str_or("scale", "s").to_string();
            let mut opts = if quick {
                exp::pretrain::PretrainOptions::quick(&scale)
            } else {
                exp::pretrain::PretrainOptions::paper(&scale)
            };
            opts.steps = args.u64_or("steps", opts.steps);
            opts.workers = args.usize_or("workers", opts.workers);
            exp::pretrain::run(&mut rt, &artifacts_dir(), &opts, &results)
        }
        "all" => {
            // the full reproduction suite, in paper order
            for f in [Family::Lr, Family::Ipa] {
                for dep in [false, true] {
                    let opts = if quick {
                        exp::toy_mse::ToyMseOptions::quick(f, dep)
                    } else {
                        exp::toy_mse::ToyMseOptions::paper(f, dep)
                    };
                    let tag = format!(
                        "toy_mse_{}_{}",
                        f.name(),
                        if dep { "dependent" } else { "independent" }
                    );
                    exp::toy_mse::run(&opts, &results.join(format!("{tag}.csv")))?;
                }
            }
            let mut rt = Runtime::new(artifacts_dir())?;
            let fopts = if quick {
                exp::finetune::FinetuneOptions::quick()
            } else {
                exp::finetune::FinetuneOptions::paper()
            };
            exp::finetune::run(&mut rt, &artifacts_dir(), &fopts, &results)?;
            exp::memory::run(&results.join("table2_memory.csv"))?;
            for scale in ["s", "m", "l"] {
                let opts = if quick {
                    exp::pretrain::PretrainOptions::quick(scale)
                } else {
                    exp::pretrain::PretrainOptions::paper(scale)
                };
                exp::pretrain::run(&mut rt, &artifacts_dir(), &opts, &results)?;
            }
            Ok(())
        }
        _ => usage(),
    }
}

fn parse_method(s: &str) -> Result<FinetuneMethod> {
    FinetuneMethod::parse(s)
}

/// Checkpoint policy from CLI + config file (`<section>.save_every`,
/// `<section>.ckpt_dir`, `<section>.keep_last`). `--resume` is CLI-only:
/// bare `--resume` (or `--resume latest`) follows `LATEST`, `--resume
/// <step>` picks a committed step.
fn ckpt_options(args: &ArgMap, file: &ConfigFile, section: &str) -> Result<CkptOptions> {
    let resume = match args.flag_or_value("resume") {
        None => None,
        Some(None) => Some(ResumeSpec::Latest),
        Some(Some(v)) => Some(ResumeSpec::parse(v)?),
    };
    let dir = args
        .get("ckpt-dir")
        .or_else(|| file.str_opt(&format!("{section}.ckpt_dir")))
        .map(PathBuf::from);
    let opts = CkptOptions {
        save_every: args
            .u64_or("save-every", file.i64_or(&format!("{section}.save_every"), 0).max(0) as u64),
        keep_last: args
            .usize_or("keep-last", file.i64_or(&format!("{section}.keep_last"), 3).max(0) as usize),
        dir,
        resume,
    };
    if (opts.save_every > 0 || opts.resume.is_some()) && opts.dir.is_none() {
        bail!("--save-every/--resume need --ckpt-dir (or {section}.ckpt_dir in the config)");
    }
    Ok(opts)
}

/// Run-health monitor startup shared by the trainer subcommands: no-op
/// unless `--monitor-addr` or `--stall-timeout` was given. `blackbox_dir`
/// is where a panic/peer-death postmortem would land (the checkpoint
/// dir when one is configured, else the working directory). Only the
/// leader binds the status endpoint — every rank of a launch world
/// shares argv, and two binds of one address would collide.
fn setup_monitor(
    args: &ArgMap,
    rank: usize,
    leader: bool,
    blackbox_dir: Option<&std::path::Path>,
) -> Result<()> {
    use lowrank_sge::obs::monitor;
    let stall = args.stall_timeout_ms();
    let addr = args.monitor_addr();
    if stall == 0 && addr.is_none() {
        return Ok(());
    }
    let cwd = std::path::PathBuf::from(".");
    monitor::configure(rank, Some(blackbox_dir.unwrap_or(&cwd)));
    if stall > 0 {
        monitor::start_watchdog(stall);
    }
    if let Some(a) = addr.filter(|_| leader) {
        let bound = monitor::serve_status(a)
            .with_context(|| format!("binding monitor endpoint on {a}"))?;
        println!("[obs:monitor] status endpoint on {bound}");
    }
    Ok(())
}

fn cmd_pretrain(args: &ArgMap) -> Result<()> {
    // before the collective: the connect handshake should be spanned too
    lowrank_sge::obs::init(args.trace_out(), args.metrics_out());
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    // one rank of a `launch` world, or the classic in-process topology;
    // `--comm-dtype` is threaded into connect so the dtype handshake
    // guards the lane the trainer will actually use
    let collective = Collective::from_env_with_dtype(args.comm_dtype()?)
        .context("joining the comm collective group")?;
    let world = collective.world();
    let leader = collective.is_leader();
    // defaults ← config file (--config path, [pretrain] section) ← CLI
    let file = match args.get("config") {
        Some(p) => ConfigFile::load(std::path::Path::new(p))?,
        None => ConfigFile::default(),
    };
    let sampler = ProjectorKind::parse(
        args.get("sampler")
            .unwrap_or_else(|| file.str_or("pretrain.sampler", "stiefel")),
    )
    .context("bad sampler")?;
    let cfg = PretrainConfig {
        scale: args
            .get("scale")
            .unwrap_or_else(|| file.str_or("pretrain.scale", "s"))
            .to_string(),
        sampler,
        c: args.f64_or("c", file.f64_or("pretrain.c", 1.0)),
        k_interval: args.u64_or("k", file.i64_or("pretrain.k", 25) as u64),
        steps: args.u64_or("steps", file.i64_or("pretrain.steps", 200) as u64),
        lr: args.f32_or("lr", file.f64_or("pretrain.lr", 2e-3) as f32),
        warmup: args.u64_or("warmup", file.i64_or("pretrain.warmup", 10) as u64),
        clip: args.f32_or("clip", file.f64_or("pretrain.clip", 1.0) as f32),
        weight_decay: args.f32_or("wd", file.f64_or("pretrain.wd", 0.05) as f32),
        seed: args.u64_or("seed", file.i64_or("pretrain.seed", 2026) as u64),
        // global shard count; in a launch world it defaults to one
        // worker per rank and must divide across the ranks
        workers: args.usize_or("workers", file.i64_or("pretrain.workers", world as i64) as usize),
        eval_every: args.u64_or("eval-every", file.i64_or("pretrain.eval_every", 25) as u64),
        eval_batches: args.usize_or("eval-batches", 2),
        threads: args.threads_or(file.usize_or("pretrain.threads", 0)),
        ckpt: ckpt_options(args, &file, "pretrain")?,
        track_refresh: args
            .u64_or("track-refresh", file.i64_or("pretrain.track_refresh", 8).max(0) as u64),
        rank_adapt: if args.has_flag("rank-adapt") || file.bool_or("pretrain.rank_adapt", false) {
            let d = RankAdaptConfig::default();
            Some(RankAdaptConfig {
                min_rank: args
                    .usize_or("rank-min", file.i64_or("pretrain.rank_min", d.min_rank as i64) as usize),
                window: args
                    .usize_or("rank-window", file.i64_or("pretrain.rank_window", d.window as i64) as usize),
                decay: args.f64_or("rank-decay", file.f64_or("pretrain.rank_decay", d.decay)),
                factor: args.f64_or("rank-factor", file.f64_or("pretrain.rank_factor", d.factor)),
            })
        } else {
            None
        },
        // quality-probe cadence is an obs flag like --trace-out: CLI
        // only, no config-file key
        probe_every: args.probe_every(),
    };
    setup_monitor(args, collective.rank(), leader, cfg.ckpt.dir.as_deref())?;
    if leader {
        println!(
            "pretrain scale={} sampler={} steps={} K={} workers={} threads={} world={} track={} rank-adapt={}",
            cfg.scale,
            sampler.name(),
            cfg.steps,
            cfg.k_interval,
            cfg.workers,
            if cfg.threads == 0 { "auto".to_string() } else { cfg.threads.to_string() },
            world,
            if cfg.track_refresh == 0 { "off".to_string() } else { cfg.track_refresh.to_string() },
            if cfg.rank_adapt.is_some() { "on" } else { "off" },
        );
        if let Some(resume) = cfg.ckpt.resume {
            println!("resuming from {resume} in {:?}", cfg.ckpt.dir.as_ref().unwrap());
        }
        if cfg.ckpt.save_every > 0 {
            println!(
                "checkpointing every {} steps to {:?} (keep last {})",
                cfg.ckpt.save_every,
                cfg.ckpt.dir.as_ref().unwrap(),
                cfg.ckpt.keep_last
            );
        }
    }
    let resumed = cfg.ckpt.resume.is_some();
    let mut trainer = PretrainTrainer::with_collective(&mut rt, &dir, cfg, collective)?;
    let res = trainer.run()?;
    if leader {
        println!(
            "final train loss {:.4} (tail {:.4}); eval {:?}; mean step {:.3}s",
            res.log.final_train_loss().unwrap_or(f32::NAN),
            res.log.tail_mean_loss(10).unwrap_or(f32::NAN),
            res.final_eval_loss,
            res.log.mean_step_time(3).unwrap_or(f64::NAN)
        );
    }
    // metrics/artifact exports are leader-only shared side effects
    // (every rank holds identical results, exactly one writes)
    if let Some(out) = args.get("out-csv") {
        if leader {
            // a resumed run's log holds only post-resume rows — append,
            // so the earlier series survives (truncate on fresh runs)
            res.log.write_csv_with(std::path::Path::new(out), resumed)?;
            println!("wrote {out}");
        }
    }
    if let Some(ckpt) = args.get("checkpoint") {
        if leader {
            trainer.save_checkpoint(std::path::Path::new(ckpt))?;
            println!("checkpoint saved to {ckpt}");
        }
    }
    Ok(())
}

fn cmd_finetune(args: &ArgMap) -> Result<()> {
    if std::env::var("LOWRANK_COMM_RDZV").is_ok() {
        bail!(
            "finetune is single-process (its batches are not sharded); \
             run it without `launch`, or use `launch … pretrain` for multi-process DDP"
        );
    }
    lowrank_sge::obs::init(args.trace_out(), args.metrics_out());
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    // defaults ← config file (--config path, [finetune] section) ← CLI
    let file = match args.get("config") {
        Some(p) => ConfigFile::load(std::path::Path::new(p))?,
        None => ConfigFile::default(),
    };
    let method = parse_method(args.str_or("method", "stiefel-lowrank-lr"))?;
    let cfg = FinetuneConfig {
        task: args.str_or("task", "sst2").to_string(),
        method,
        steps: args.u64_or("steps", 300),
        k_interval: args.u64_or("k", 50),
        ipa_lr: args.f32_or("ipa-lr", 1e-3),
        zo_lr: args.f32_or("zo-lr", 2e-3),
        sigma: args.f32_or("sigma", 1e-2),
        c: args.f64_or("c", 1.0),
        seed: args.u64_or("seed", 2026),
        eval_examples: args.usize_or("eval-examples", 256),
        threads: args.threads_or(file.usize_or("finetune.threads", 0)),
        ckpt: ckpt_options(args, &file, "finetune")?,
        track_refresh: args
            .u64_or("track-refresh", file.i64_or("finetune.track_refresh", 0).max(0) as u64),
    };
    // single-process: rank 0 is the only (and therefore leader) rank
    setup_monitor(args, 0, true, cfg.ckpt.dir.as_deref())?;
    println!("finetune task={} method={} steps={}", cfg.task, method.name(), cfg.steps);
    if let Some(resume) = cfg.ckpt.resume {
        println!("resuming from {resume} in {:?}", cfg.ckpt.dir.as_ref().unwrap());
    }
    let resumed = cfg.ckpt.resume.is_some();
    let mut trainer = FinetuneTrainer::new(&mut rt, &dir, cfg)?;
    let res = trainer.run()?;
    println!(
        "accuracy {:.3}; final loss {:.4}; mean step {:.4}s",
        res.accuracy,
        res.log.tail_mean_loss(10).unwrap_or(f32::NAN),
        res.log.mean_step_time(3).unwrap_or(f64::NAN)
    );
    if let Some(out) = args.get("out-csv") {
        // append on resume — the log holds only post-resume rows
        res.log.write_csv_with(std::path::Path::new(out), resumed)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// The multi-tenant fine-tune daemon (see [`lowrank_sge::serve`]).
/// Blocks until a `job shutdown` drains the queue.
fn cmd_serve(args: &ArgMap) -> Result<()> {
    lowrank_sge::obs::init(args.trace_out(), args.metrics_out());
    let cfg = lowrank_sge::serve::ServeConfig {
        addr: args.str_or("addr", "127.0.0.1:0").to_string(),
        artifacts_dir: artifacts_dir(),
        ckpt_root: PathBuf::from(args.str_or("ckpt-root", "serve-ckpt")),
        max_active: args.usize_or("max-active", 2).max(1),
        max_open: args.usize_or("max-open", 8).max(1),
        mem_budget_bytes: args.usize_or("mem-budget-mb", 0) << 20,
        max_conns: args.usize_or("max-conns", 16).max(1),
        idle_ms: args.u64_or("idle-timeout", 30_000),
        threads: args.threads_or(0),
    };
    setup_monitor(args, 0, true, Some(&cfg.ckpt_root))?;
    println!(
        "serve max-active={} max-open={} mem-budget-mb={} ckpt-root={:?}",
        cfg.max_active,
        cfg.max_open,
        cfg.mem_budget_bytes >> 20,
        cfg.ckpt_root
    );
    let report = lowrank_sge::serve::run_serve(cfg)?;
    println!(
        "serve done: {} completed, {} failed, {} cancelled",
        report.done, report.failed, report.cancelled
    );
    Ok(())
}

/// Client verbs against a running daemon: `job
/// <submit|status|cancel|fetch|shutdown> --addr H:P …`.
fn cmd_job(sub: &str, args: &ArgMap) -> Result<()> {
    use lowrank_sge::serve::{client, JobSpec};
    let addr = args.get("addr").context("job: --addr <host:port> is required")?;
    let timeout = Duration::from_millis(args.u64_or("timeout-ms", 10_000));
    let job_id = || -> Result<u64> {
        match args.u64_or("job", 0) {
            0 => bail!("job {sub}: --job <id> is required"),
            id => Ok(id),
        }
    };
    match sub {
        "submit" => {
            // pass through exactly the flags the user gave; JobSpec
            // fills the finetune-subcommand defaults for the rest
            let mut fields: Vec<(String, String)> = Vec::new();
            for key in [
                "task",
                "method",
                "steps",
                "k",
                "ipa-lr",
                "zo-lr",
                "sigma",
                "c",
                "seed",
                "eval-examples",
                "track-refresh",
                "save-every",
                "keep-last",
            ] {
                if let Some(v) = args.get(key) {
                    fields.push((key.to_string(), v.to_string()));
                }
            }
            let spec = JobSpec::from_fields(&fields)?;
            let id = client::submit(addr, &spec, timeout)?;
            println!("job={id}");
        }
        "status" => {
            let id = job_id()?;
            let fields = if args.has_flag("wait") {
                let deadline =
                    Instant::now() + Duration::from_millis(args.u64_or("wait-timeout-ms", 600_000));
                client::wait(addr, id, Duration::from_millis(250), deadline)?
            } else {
                client::status(addr, id, timeout)?
            };
            for (k, v) in fields {
                println!("{k}={v}");
            }
        }
        "fetch" => {
            for (k, v) in client::fetch(addr, job_id()?, timeout)? {
                println!("{k}={v}");
            }
        }
        "cancel" => {
            let id = job_id()?;
            let state = client::cancel(addr, id, timeout)?;
            println!("job={id} state={state}");
        }
        "shutdown" => {
            client::shutdown(addr, timeout)?;
            println!("daemon draining");
        }
        other => bail!("unknown job verb {other:?} (submit|status|cancel|fetch|shutdown)"),
    }
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    for name in rt.available()? {
        let art = rt.load(&name)?;
        println!(
            "{name:<22} inputs {:>3}  outputs {:>2}  compile {:.2}s  model {}",
            art.manifest.inputs.len(),
            art.manifest.outputs.len(),
            art.compile_time_s,
            art.manifest.meta.get("model").map(|s| s.as_str()).unwrap_or("-")
        );
    }
    Ok(())
}
