//! LM batcher: a corpus stream packed into `(batch, seq_len+1)` i32
//! next-token batches (inputs = [:, :-1], targets = [:, 1:] inside the
//! artifact). Train/eval splits come from disjoint RNG streams.

use crate::rng::Rng;

use super::corpus::ZipfMarkovCorpus;

pub struct LmBatcher {
    corpus: ZipfMarkovCorpus,
    batch: usize,
    seq_len: usize,
    rng: Rng,
}

impl LmBatcher {
    pub fn new(corpus: ZipfMarkovCorpus, batch: usize, seq_len: usize, rng: Rng) -> Self {
        LmBatcher { corpus, batch, seq_len, rng }
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seq_len + 1)
    }

    /// Next `(batch, seq_len+1)` flat row-major token batch.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let width = self.seq_len + 1;
        let mut out = Vec::with_capacity(self.batch * width);
        for _ in 0..self.batch {
            out.extend(self.corpus.stream(width, &mut self.rng));
        }
        out
    }

    /// A held-out eval set of `n_batches` fixed batches (deterministic:
    /// independent of how many train batches were drawn).
    pub fn eval_batches(&self, n_batches: usize, eval_seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(eval_seed ^ 0x5EED_EA10_u64);
        let width = self.seq_len + 1;
        (0..n_batches)
            .map(|_| {
                let mut out = Vec::with_capacity(self.batch * width);
                for _ in 0..self.batch {
                    out.extend(self.corpus.stream(width, &mut rng));
                }
                out
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> LmBatcher {
        LmBatcher::new(ZipfMarkovCorpus::new(128, 1), 4, 16, Rng::new(7))
    }

    #[test]
    fn batch_has_right_shape_and_range() {
        let mut b = mk();
        let batch = b.next_batch();
        assert_eq!(batch.len(), 4 * 17);
        assert!(batch.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn successive_batches_differ() {
        let mut b = mk();
        let b1 = b.next_batch();
        let b2 = b.next_batch();
        assert_ne!(b1, b2);
    }

    #[test]
    fn eval_batches_deterministic_and_disjoint_from_train() {
        let b = mk();
        let e1 = b.eval_batches(3, 42);
        let e2 = b.eval_batches(3, 42);
        assert_eq!(e1, e2);
        let mut b2 = mk();
        let train = b2.next_batch();
        assert_ne!(e1[0], train);
    }
}
