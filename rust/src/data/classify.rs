//! The six synthetic classification tasks standing in for the paper's
//! fine-tuning benchmarks (Table 1 / Fig 6): SST-2, SST-5, SNLI, MNLI,
//! RTE, TREC — same class counts, graded difficulty.
//!
//! Construction: every (task, class) pair owns a signature token set
//! (deterministic hashes); an example of class k mixes signature tokens
//! (probability = the task's `signal`) with background Zipf noise. The
//! `signal` knob reproduces the paper's difficulty ordering — TREC
//! (topic classification) is easy, MNLI/RTE (entailment) are hard —
//! without importing the actual datasets (DESIGN.md §2).

use crate::rng::{Rng, Zipf};

/// Static description of one task.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub n_classes: usize,
    /// Probability that a token is a class-signature token.
    pub signal: f64,
}

/// The six benchmark stand-ins with the paper's class counts.
pub const TASKS: [TaskSpec; 6] = [
    TaskSpec { name: "sst2", n_classes: 2, signal: 0.22 },
    TaskSpec { name: "sst5", n_classes: 5, signal: 0.10 },
    TaskSpec { name: "snli", n_classes: 3, signal: 0.14 },
    TaskSpec { name: "mnli", n_classes: 3, signal: 0.09 },
    TaskSpec { name: "rte", n_classes: 2, signal: 0.08 },
    TaskSpec { name: "trec", n_classes: 6, signal: 0.28 },
];

/// One labeled example.
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// A materialized task: generator + fixed eval set.
pub struct ClassifyTask {
    pub spec: TaskSpec,
    vocab: usize,
    seq: usize,
    seed: u64,
    background: Zipf,
    signature_size: usize,
}

impl ClassifyTask {
    pub fn new(spec: TaskSpec, vocab: usize, seq: usize, seed: u64) -> Self {
        ClassifyTask {
            spec,
            vocab,
            seq,
            seed,
            background: Zipf::new(vocab, 1.05),
            signature_size: 24,
        }
    }

    pub fn by_name(name: &str, vocab: usize, seq: usize, seed: u64) -> Option<Self> {
        TASKS
            .iter()
            .find(|t| t.name == name)
            .map(|&spec| ClassifyTask::new(spec, vocab, seq, seed))
    }

    /// j-th signature token of a class (fixed pseudo-random function).
    fn signature_token(&self, class: usize, j: usize) -> i32 {
        let mut h = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(class as u64)
            .wrapping_mul(0xD1B54A32D192ED03)
            .wrapping_add(j as u64 + 1);
        h ^= h >> 31;
        h = h.wrapping_mul(0xBF58476D1CE4E5B9);
        h ^= h >> 29;
        (h % self.vocab as u64) as i32
    }

    /// Generate one example of a given class.
    pub fn example_of(&self, class: usize, rng: &mut Rng) -> Example {
        debug_assert!(class < self.spec.n_classes);
        let tokens = (0..self.seq)
            .map(|_| {
                if rng.uniform() < self.spec.signal {
                    let j = rng.below(self.signature_size as u64) as usize;
                    self.signature_token(class, j)
                } else {
                    self.background.sample(rng) as i32
                }
            })
            .collect();
        Example { tokens, label: class as i32 }
    }

    /// A balanced random training batch: flat tokens (batch×seq) +
    /// labels.
    pub fn train_batch(&self, batch: usize, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * self.seq);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let class = rng.below(self.spec.n_classes as u64) as usize;
            let ex = self.example_of(class, rng);
            tokens.extend(ex.tokens);
            labels.push(ex.label);
        }
        (tokens, labels)
    }

    /// Deterministic, balanced eval set of `n` examples.
    pub fn eval_set(&self, n: usize) -> Vec<Example> {
        let mut rng = Rng::new(self.seed ^ 0xE7A1);
        (0..n)
            .map(|i| self.example_of(i % self.spec.n_classes, &mut rng))
            .collect()
    }

    pub fn seq_len(&self) -> usize {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_six_tasks_have_paper_class_counts() {
        let counts: Vec<usize> = TASKS.iter().map(|t| t.n_classes).collect();
        assert_eq!(counts, vec![2, 5, 3, 3, 2, 6]);
    }

    #[test]
    fn examples_have_right_shape_and_label_range() {
        for spec in TASKS {
            let task = ClassifyTask::new(spec, 4096, 32, 1);
            let mut rng = Rng::new(2);
            let (tokens, labels) = task.train_batch(16, &mut rng);
            assert_eq!(tokens.len(), 16 * 32);
            assert_eq!(labels.len(), 16);
            assert!(labels.iter().all(|&l| (l as usize) < spec.n_classes));
            assert!(tokens.iter().all(|&t| (0..4096).contains(&t)));
        }
    }

    #[test]
    fn eval_set_deterministic_and_balanced() {
        let task = ClassifyTask::by_name("snli", 4096, 32, 5).unwrap();
        let e1 = task.eval_set(30);
        let e2 = task.eval_set(30);
        assert_eq!(e1.len(), 30);
        for (a, b) in e1.iter().zip(&e2) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.label, b.label);
        }
        let per_class = e1.iter().filter(|e| e.label == 0).count();
        assert_eq!(per_class, 10);
    }

    #[test]
    fn signature_tokens_separate_classes() {
        // a trivial nearest-signature classifier must beat chance by a
        // wide margin on the easy task — i.e. the tasks are learnable.
        let task = ClassifyTask::by_name("trec", 4096, 32, 9).unwrap();
        let sigs: Vec<std::collections::HashSet<i32>> = (0..6)
            .map(|c| (0..24).map(|j| task.signature_token(c, j)).collect())
            .collect();
        let eval = task.eval_set(120);
        let mut correct = 0;
        for ex in &eval {
            let scores: Vec<usize> = sigs
                .iter()
                .map(|s| ex.tokens.iter().filter(|t| s.contains(t)).count())
                .collect();
            let pred = scores
                .iter()
                .enumerate()
                .max_by_key(|(_, &s)| s)
                .unwrap()
                .0;
            if pred == ex.label as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / eval.len() as f64;
        assert!(acc > 0.8, "trec oracle accuracy only {acc}");
    }

    #[test]
    fn harder_tasks_have_weaker_signal() {
        let sig = |n: &str| TASKS.iter().find(|t| t.name == n).unwrap().signal;
        assert!(sig("trec") > sig("mnli"));
        assert!(sig("sst2") > sig("rte"));
    }

    #[test]
    fn unknown_task_name_rejected() {
        assert!(ClassifyTask::by_name("imdb", 100, 8, 0).is_none());
    }
}
