//! Zipf–Markov synthetic corpus.
//!
//! Token t+1 is drawn from a mixture: with probability `bigram_weight` a
//! deterministic pseudo-random bigram table of the previous token (top-B
//! successors, Zipf-weighted), otherwise the global Zipf unigram. The
//! mixture gives the LM a learnable signal — the loss curve shows the
//! paper-typical fast-drop-then-grind shape — while the Zipf unigram
//! keeps the marginal distribution realistic (s ≈ 1.1, like natural
//! text).

use crate::rng::{Rng, Zipf};

#[derive(Clone)]
pub struct ZipfMarkovCorpus {
    vocab: usize,
    unigram: Zipf,
    successor_pick: Zipf,
    bigram_weight: f64,
    branch: usize,
    table_seed: u64,
}

impl ZipfMarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        ZipfMarkovCorpus {
            vocab,
            unigram: Zipf::new(vocab, 1.1),
            successor_pick: Zipf::new(32, 1.3),
            bigram_weight: 0.75,
            branch: 32,
            table_seed: seed,
        }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The b-th preferred successor of token `prev` — a fixed
    /// pseudo-random function so every stream sees the same bigram
    /// structure (that is what makes it learnable).
    fn successor(&self, prev: usize, b: usize) -> usize {
        let mut h = self
            .table_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(prev as u64)
            .wrapping_mul(0xD1B54A32D192ED03)
            .wrapping_add(b as u64);
        h ^= h >> 31;
        h = h.wrapping_mul(0xBF58476D1CE4E5B9);
        h ^= h >> 29;
        (h % self.vocab as u64) as usize
    }

    /// Next token given the previous one.
    pub fn next_token(&self, prev: usize, rng: &mut Rng) -> usize {
        if rng.uniform() < self.bigram_weight {
            let b = self.successor_pick.sample(rng).min(self.branch - 1);
            self.successor(prev, b)
        } else {
            self.unigram.sample(rng)
        }
    }

    /// Generate a stream of `len` tokens.
    pub fn stream(&self, len: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut prev = self.unigram.sample(rng);
        out.push(prev as i32);
        for _ in 1..len {
            prev = self.next_token(prev, rng);
            out.push(prev as i32);
        }
        out
    }

    /// Render token ids as synthetic "words" (for the text→tokenizer
    /// round-trip): id → base-26 word of 3–7 letters, deterministic.
    pub fn render_word(id: usize) -> String {
        let mut s = String::new();
        let mut x = id as u64 * 2654435761 % 8031810176; // 26^7
        let len = 3 + (id % 5);
        for _ in 0..len {
            s.push((b'a' + (x % 26) as u8) as char);
            x /= 26;
        }
        s
    }

    /// Render a token stream as text.
    pub fn render_text(tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| Self::render_word(t as usize))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_tokens_in_vocab() {
        let c = ZipfMarkovCorpus::new(512, 1);
        let mut rng = Rng::new(2);
        for t in c.stream(5000, &mut rng) {
            assert!((0..512).contains(&(t as usize)));
        }
    }

    #[test]
    fn unigram_marginal_is_skewed() {
        let c = ZipfMarkovCorpus::new(256, 3);
        let mut rng = Rng::new(4);
        let stream = c.stream(60_000, &mut rng);
        let mut counts = vec![0usize; 256];
        for &t in &stream {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // top-16 tokens should hold a large share (Zipf + concentrated bigrams)
        let top16: usize = counts[..16].iter().sum();
        assert!(
            top16 as f64 / stream.len() as f64 > 0.2,
            "marginal not skewed: top16 share {}",
            top16 as f64 / stream.len() as f64
        );
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // empirical bigram entropy must be well below unigram entropy
        let c = ZipfMarkovCorpus::new(128, 5);
        let mut rng = Rng::new(6);
        let stream = c.stream(200_000, &mut rng);
        let mut uni = vec![0f64; 128];
        let mut big = std::collections::HashMap::<(i32, i32), f64>::new();
        let mut prev_counts = vec![0f64; 128];
        for w in stream.windows(2) {
            uni[w[1] as usize] += 1.0;
            *big.entry((w[0], w[1])).or_default() += 1.0;
            prev_counts[w[0] as usize] += 1.0;
        }
        let n = (stream.len() - 1) as f64;
        let h_uni: f64 = uni
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| -(c / n) * (c / n).ln())
            .sum();
        let h_big: f64 = big
            .iter()
            .map(|(&(p, _), &c)| {
                let cond = c / prev_counts[p as usize];
                -(c / n) * cond.ln()
            })
            .sum();
        assert!(
            h_big < 0.8 * h_uni,
            "conditional entropy {h_big:.3} not below unigram {h_uni:.3}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let c = ZipfMarkovCorpus::new(64, 7);
        let s1 = c.stream(100, &mut Rng::new(9));
        let s2 = c.stream(100, &mut Rng::new(9));
        assert_eq!(s1, s2);
    }

    #[test]
    fn words_deterministic_and_lowercase() {
        let w1 = ZipfMarkovCorpus::render_word(42);
        let w2 = ZipfMarkovCorpus::render_word(42);
        assert_eq!(w1, w2);
        assert!(w1.chars().all(|c| c.is_ascii_lowercase()));
        assert!(w1.len() >= 3 && w1.len() <= 7);
        let text = ZipfMarkovCorpus::render_text(&[1, 2, 3]);
        assert_eq!(text.split(' ').count(), 3);
    }
}
