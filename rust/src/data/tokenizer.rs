//! Word-hash tokenizer: whitespace-split words → FNV-1a hash → token id.
//!
//! Stands in for the paper's T5-base tokenizer (DESIGN.md §2): the
//! properties the experiments rely on are (a) deterministic text→id
//! mapping and (b) a fixed vocabulary size matching the model's
//! embedding table — both hold here. Case-folding and punctuation
//! stripping give it the usual normalizing behavior.

#[derive(Clone, Copy, Debug)]
pub struct WordHashTokenizer {
    vocab: usize,
}

impl WordHashTokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab > 1);
        WordHashTokenizer { vocab }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn hash_word(word: &str) -> u64 {
        // FNV-1a
        let mut h: u64 = 0xcbf29ce484222325;
        for b in word.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    pub fn token(&self, word: &str) -> i32 {
        let norm: String = word
            .chars()
            .filter(|c| c.is_alphanumeric())
            .flat_map(|c| c.to_lowercase())
            .collect();
        if norm.is_empty() {
            return 0;
        }
        (Self::hash_word(&norm) % self.vocab as u64) as i32
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|w| self.token(w)).collect()
    }

    /// Encode and pad/truncate to a fixed length (padding with token 0).
    pub fn encode_fixed(&self, text: &str, len: usize) -> Vec<i32> {
        let mut ids = self.encode(text);
        ids.truncate(len);
        while ids.len() < len {
            ids.push(0);
        }
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let t = WordHashTokenizer::new(1000);
        let a = t.encode("the quick brown fox");
        let b = t.encode("the quick brown fox");
        assert_eq!(a, b);
        assert!(a.iter().all(|&id| (0..1000).contains(&id)));
    }

    #[test]
    fn normalization_folds_case_and_punct() {
        let t = WordHashTokenizer::new(4096);
        assert_eq!(t.token("Hello"), t.token("hello"));
        assert_eq!(t.token("hello!"), t.token("hello"));
        assert_eq!(t.token("he,llo"), t.token("hello"));
    }

    #[test]
    fn distinct_words_mostly_distinct_ids() {
        let t = WordHashTokenizer::new(4096);
        let ids: std::collections::HashSet<i32> =
            (0..1000).map(|i| t.token(&format!("word{i}"))).collect();
        assert!(ids.len() > 850, "too many collisions: {} unique", ids.len());
    }

    #[test]
    fn fixed_length_pads_and_truncates() {
        let t = WordHashTokenizer::new(100);
        let short = t.encode_fixed("a b", 5);
        assert_eq!(short.len(), 5);
        assert_eq!(&short[2..], &[0, 0, 0]);
        let long = t.encode_fixed("a b c d e f g", 3);
        assert_eq!(long.len(), 3);
    }

    #[test]
    fn corpus_text_roundtrip_consistent() {
        // rendering corpus tokens to text and re-tokenizing yields a
        // deterministic id stream (not necessarily the same ids — the
        // tokenizer defines its own id space — but stable).
        use crate::data::corpus::ZipfMarkovCorpus;
        let c = ZipfMarkovCorpus::new(256, 11);
        let mut rng = crate::rng::Rng::new(12);
        let toks = c.stream(50, &mut rng);
        let text = ZipfMarkovCorpus::render_text(&toks);
        let t = WordHashTokenizer::new(256);
        let ids1 = t.encode(&text);
        let ids2 = t.encode(&text);
        assert_eq!(ids1.len(), 50);
        assert_eq!(ids1, ids2);
    }
}
