//! Synthetic data pipeline (the OpenWebText/T5-tokenizer substitution —
//! DESIGN.md §2).
//!
//! * [`corpus`] — a Zipf–Markov token-stream generator with realistic
//!   unigram skew and learnable bigram structure, plus a synthetic-word
//!   text renderer.
//! * [`tokenizer`] — a deterministic word-hash tokenizer (text → ids)
//!   closing the text round-trip.
//! * [`batcher`] — packs the stream into `(batch, seq+1)` next-token
//!   prediction batches for the LM artifacts.
//! * [`classify`] — the six synthetic classification tasks standing in
//!   for SST-2 / SST-5 / SNLI / MNLI / RTE / TREC (same class counts,
//!   graded difficulty).

mod batcher;
mod classify;
mod corpus;
mod tokenizer;

pub use batcher::LmBatcher;
pub use classify::{ClassifyTask, Example, TaskSpec, TASKS};
pub use corpus::ZipfMarkovCorpus;
pub use tokenizer::WordHashTokenizer;
