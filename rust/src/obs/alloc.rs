//! The measured memory ledger: an opt-in tracked global allocator plus
//! `/proc/self/status` high-water-mark sampling.
//!
//! [`TrackedAlloc`] is the promotion of the counting allocator that
//! `tests/engine_alloc.rs` introduced (and `bench_util` still
//! re-exports as `CountingAlloc`): besides the exact allocation-event
//! count that pins the engine's zero-allocation contract, it tracks
//! **live bytes** and **peak bytes** with relaxed atomics — a handful
//! of RMW instructions per allocator entry, unconditionally (an
//! allocator cannot consult the metrics enabled flag without biasing
//! the very measurement a disabled run is compared against; the cost
//! is four relaxed atomics on a path that already takes a malloc).
//!
//! Install per binary:
//!
//! ```ignore
//! #[global_allocator]
//! static GLOBAL: lowrank_sge::obs::TrackedAlloc = lowrank_sge::obs::TrackedAlloc;
//! ```
//!
//! The `lowrank-sge` binary installs it, so `exp memory` and the
//! trainers report measured heap peaks; library users that don't
//! install it simply read zeros ([`TrackedAlloc::installed`] gates the
//! reports).
//!
//! [`vm_hwm_kb`]/[`vm_rss_kb`] read the kernel's view — resident-set
//! high-water mark including stacks, code, and allocator slack — the
//! number to put beside the paper's Table 2 GPU peaks.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOC_EVENTS: AtomicUsize = AtomicUsize::new(0);
static LIVE_BYTES: AtomicUsize = AtomicUsize::new(0);
static PEAK_BYTES: AtomicUsize = AtomicUsize::new(0);

/// Tracking wrapper around the system allocator: counts every entry
/// that hands out memory (alloc / alloc_zeroed / realloc — the exact
/// semantics `tests/engine_alloc.rs` pins) and maintains live/peak
/// byte gauges.
pub struct TrackedAlloc;

impl TrackedAlloc {
    /// Total allocator entries (alloc/alloc_zeroed/realloc) so far.
    pub fn count() -> usize {
        ALLOC_EVENTS.load(Ordering::SeqCst)
    }

    /// Bytes currently live (allocated minus freed).
    pub fn live_bytes() -> usize {
        LIVE_BYTES.load(Ordering::Relaxed)
    }

    /// High-water mark of [`Self::live_bytes`] since process start (or
    /// the last [`Self::reset_peak`]).
    pub fn peak_bytes() -> usize {
        PEAK_BYTES.load(Ordering::Relaxed)
    }

    /// Reset the peak to the current live level — scoped measurements
    /// (`exp memory`) bracket a region with `reset_peak` + `peak_bytes`.
    pub fn reset_peak() {
        PEAK_BYTES.store(LIVE_BYTES.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Is the tracked allocator actually the process allocator? Detected
    /// by use: any live allocation implies installation (every binary
    /// allocates long before observing memory).
    pub fn installed() -> bool {
        LIVE_BYTES.load(Ordering::Relaxed) > 0 || ALLOC_EVENTS.load(Ordering::Relaxed) > 0
    }
}

#[inline]
fn on_grow(bytes: usize) {
    let live = LIVE_BYTES.fetch_add(bytes, Ordering::Relaxed) + bytes;
    PEAK_BYTES.fetch_max(live, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for TrackedAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        let p = System.alloc(layout);
        if !p.is_null() {
            on_grow(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_grow(layout.size());
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                on_grow(new_size - layout.size());
            } else {
                LIVE_BYTES.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE_BYTES.fetch_sub(layout.size(), Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
}

/// Parse one `<key>:  <n> kB` line out of `/proc/self/status`.
fn proc_status_kb(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let rest = rest.trim_start_matches(':').trim();
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            return digits.parse().ok();
        }
    }
    None
}

/// Peak resident set size in kB (`VmHWM`), `None` off Linux.
pub fn vm_hwm_kb() -> Option<u64> {
    proc_status_kb("VmHWM")
}

/// Current resident set size in kB (`VmRSS`), `None` off Linux.
pub fn vm_rss_kb() -> Option<u64> {
    proc_status_kb("VmRSS")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Not installed as the test binary's global allocator, so drive the
    // GlobalAlloc impl directly.
    #[test]
    fn ledger_tracks_live_and_peak() {
        let a = TrackedAlloc;
        let layout = Layout::from_size_align(4096, 8).unwrap();
        let live0 = TrackedAlloc::live_bytes();
        let count0 = TrackedAlloc::count();
        unsafe {
            let p = a.alloc(layout);
            assert!(!p.is_null());
            assert!(TrackedAlloc::live_bytes() >= live0 + 4096);
            assert!(TrackedAlloc::peak_bytes() >= live0 + 4096);
            let p2 = a.realloc(p, layout, 8192);
            assert!(!p2.is_null());
            assert!(TrackedAlloc::live_bytes() >= live0 + 8192);
            a.dealloc(p2, Layout::from_size_align(8192, 8).unwrap());
        }
        // grow events: alloc + realloc
        assert_eq!(TrackedAlloc::count() - count0, 2);
        // dealloc returned the live gauge to where it started
        assert_eq!(TrackedAlloc::live_bytes(), live0);
        // a scoped measurement brackets with reset_peak
        TrackedAlloc::reset_peak();
        assert_eq!(TrackedAlloc::peak_bytes(), TrackedAlloc::live_bytes());
    }

    #[test]
    fn proc_status_reads_on_linux() {
        if std::path::Path::new("/proc/self/status").exists() {
            assert!(vm_rss_kb().unwrap_or(0) > 0 || vm_hwm_kb().is_some());
        }
    }
}
