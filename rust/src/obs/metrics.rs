//! The metrics registry: counters, histograms, and value series with a
//! branch-on-disabled hot path, snapshotted as JSONL.
//!
//! Hot-path metrics are `static` atomics ([`Counter`], [`Histogram`]):
//! disabled, an update is one relaxed load; enabled, a handful of
//! relaxed RMWs — never a lock, never an allocation. Cold-path series
//! ([`record_value`] — per-step phase times, per-layer lift-residual
//! norms) go through one mutex-guarded map keyed by name; they fire a
//! few times per training step at most.
//!
//! A [`snapshot_json`] is one JSON object (hand-emitted — the crate
//! has no serde) holding every counter, the histograms, the series
//! stats (count/sum/min/max/last), the measured memory ledger
//! ([`super::alloc`]), and the span-drop count. One snapshot per rank
//! is one line of the `--metrics-out` JSONL file.
//!
//! # Cross-rank gather
//!
//! Snapshots ride the existing f32 `all_gather`: [`encode_snapshot`]
//! smuggles the JSON bytes as small-integer f32s (exact on every
//! target — the `comm-check` CRC idiom) in a fixed [`SNAPSHOT_F32S`]
//! frame, [`decode_snapshot`] recovers the text on the leader. The
//! leader writes one merged JSONL file — line r is rank r's snapshot —
//! plus a per-rank summary table on stdout.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Result};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn the registry on or off (also driven by `obs::init`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is the registry on? One relaxed load — the whole disabled path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A named monotonic counter. Updates are relaxed atomics gated on the
/// global enabled flag.
pub struct Counter {
    name: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str) -> Counter {
        Counter { name, v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// Power-of-two bucket count for [`Histogram`] (bucket i counts
/// observations with `floor(log2(v)) == i`, saturating at the top).
pub const HIST_BUCKETS: usize = 40;

/// A log2-bucketed histogram of u64 observations (nanoseconds on the
/// pool queue-wait path).
pub struct Histogram {
    name: &'static str,
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

#[allow(clippy::declare_interior_mutable_const)]
const ZERO_U64: AtomicU64 = AtomicU64::new(0);

impl Histogram {
    pub const fn new(name: &'static str) -> Histogram {
        Histogram { name, count: ZERO_U64, sum: ZERO_U64, buckets: [ZERO_U64; HIST_BUCKETS] }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        let bucket = (64 - u64::leading_zeros(v.max(1)) as usize - 1).min(HIST_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }
}

// ---- the registry: every hot-path metric in the system ----

/// Framed payload bytes sent on the f32 data lane (`comm::wire`).
pub static WIRE_SENT_F32: Counter = Counter::new("comm.wire_sent_bytes_f32");
/// Framed payload bytes sent on the bf16 data lane.
pub static WIRE_SENT_BF16: Counter = Counter::new("comm.wire_sent_bytes_bf16");
/// Framed bytes sent as control traffic (hello/barrier frames).
pub static WIRE_SENT_CTRL: Counter = Counter::new("comm.wire_sent_bytes_ctrl");
/// Framed payload bytes received on the f32 data lane.
pub static WIRE_RECV_F32: Counter = Counter::new("comm.wire_recv_bytes_f32");
/// Framed payload bytes received on the bf16 data lane.
pub static WIRE_RECV_BF16: Counter = Counter::new("comm.wire_recv_bytes_bf16");
/// Framed bytes received as control traffic.
pub static WIRE_RECV_CTRL: Counter = Counter::new("comm.wire_recv_bytes_ctrl");
/// Raw bytes written to sockets (`comm::transport::Conn::write_all`).
pub static STREAM_SENT: Counter = Counter::new("comm.stream_sent_bytes");
/// Raw bytes read from sockets (`Conn::read_exact`).
pub static STREAM_RECV: Counter = Counter::new("comm.stream_recv_bytes");
/// Comm frames sent / received.
pub static FRAMES_SENT: Counter = Counter::new("comm.frames_sent");
pub static FRAMES_RECV: Counter = Counter::new("comm.frames_recv");
/// Tasks executed by the kernel pool (inline + queued).
pub static POOL_TASKS: Counter = Counter::new("kernel.pool_tasks");
/// Background checkpoint saves submitted.
pub static CKPT_SAVES: Counter = Counter::new("ckpt.saves");

/// Queue wait of pool tasks: enqueue → execution start, nanoseconds.
pub static POOL_QUEUE_WAIT: Histogram = Histogram::new("kernel.queue_wait_ns");

static COUNTERS: &[&Counter] = &[
    &WIRE_SENT_F32,
    &WIRE_SENT_BF16,
    &WIRE_SENT_CTRL,
    &WIRE_RECV_F32,
    &WIRE_RECV_BF16,
    &WIRE_RECV_CTRL,
    &STREAM_SENT,
    &STREAM_RECV,
    &FRAMES_SENT,
    &FRAMES_RECV,
    &POOL_TASKS,
    &CKPT_SAVES,
];

static HISTOGRAMS: &[&Histogram] = &[&POOL_QUEUE_WAIT];

// ---- cold-path value series ----

#[derive(Clone, Copy)]
struct Series {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    last: f64,
}

fn series_map() -> &'static Mutex<BTreeMap<String, Series>> {
    static SERIES: OnceLock<Mutex<BTreeMap<String, Series>>> = OnceLock::new();
    SERIES.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Record one sample of a named series (phase durations, residual
/// norms, losses). Cold path: a mutex and, on the first sample of a
/// name, one allocation — call it per step/phase, not per element.
pub fn record_value(name: &str, v: f64) {
    if !enabled() {
        return;
    }
    let mut map = series_map().lock().unwrap();
    match map.get_mut(name) {
        Some(s) => {
            s.count += 1;
            s.sum += v;
            s.min = s.min.min(v);
            s.max = s.max.max(v);
            s.last = v;
        }
        None => {
            map.insert(name.to_string(), Series { count: 1, sum: v, min: v, max: v, last: v });
        }
    }
}

/// Sum of a series, for end-of-run reports (0.0 if never recorded).
pub fn series_sum(name: &str) -> f64 {
    series_map().lock().unwrap().get(name).map(|s| s.sum).unwrap_or(0.0)
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9}")
    } else {
        "null".to_string()
    }
}

/// One rank's full registry as a single-line JSON object.
pub fn snapshot_json(rank: usize) -> String {
    let mut out = String::with_capacity(1024);
    out.push_str(&format!("{{\"rank\":{rank},\"counters\":{{"));
    for (i, c) in COUNTERS.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":{}", c.name(), c.get()));
    }
    out.push_str("},\"histograms\":{");
    for (i, h) in HISTOGRAMS.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\"{}\":{{\"count\":{},\"sum\":{},\"log2_buckets\":[",
            h.name,
            h.count(),
            h.sum()
        ));
        for (j, b) in h.buckets.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&b.load(Ordering::Relaxed).to_string());
        }
        out.push_str("]}");
    }
    out.push_str("},\"series\":{");
    {
        let map = series_map().lock().unwrap();
        for (i, (name, s)) in map.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{name}\":{{\"count\":{},\"sum\":{},\"min\":{},\"max\":{},\"last\":{}}}",
                s.count,
                fmt_f64(s.sum),
                fmt_f64(s.min),
                fmt_f64(s.max),
                fmt_f64(s.last)
            ));
        }
    }
    out.push_str("},\"mem\":{");
    out.push_str(&format!(
        "\"alloc_events\":{},\"live_bytes\":{},\"peak_bytes\":{},\"vm_hwm_kb\":{},\"vm_rss_kb\":{}",
        super::alloc::TrackedAlloc::count(),
        super::alloc::TrackedAlloc::live_bytes(),
        super::alloc::TrackedAlloc::peak_bytes(),
        super::alloc::vm_hwm_kb().unwrap_or(0),
        super::alloc::vm_rss_kb().unwrap_or(0)
    ));
    out.push_str(&format!("}},\"spans_dropped\":{}}}", super::span::dropped_total()));
    out
}

/// Pull `"key":<number>` out of a snapshot line — enough structure
/// awareness for the leader's summary table (we wrote the JSON, keys
/// are unique within a line).
pub fn json_u64(json: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let at = json.find(&pat)? + pat.len();
    let rest = &json[at..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

// ---- snapshot transport over the f32 all-gather ----

/// Fixed per-rank frame: 4-byte length header + payload, one byte per
/// f32. 32 KiB of JSON is far above a normal snapshot.
pub const SNAPSHOT_F32S: usize = 32 * 1024;

/// Encode a snapshot line for the all-gather. An oversized snapshot
/// would be silently cut at the fixed frame — corrupt JSON on the
/// leader — so it degrades loudly instead: the JSONL line becomes a
/// valid truncation-marker object that keeps the rank (so the leader's
/// per-rank table still lines up) and records how large the real
/// snapshot was, and a `[obs]` warning names the cap to raise.
pub fn encode_snapshot(json: &str) -> Vec<f32> {
    let marker;
    let mut bytes = json.as_bytes();
    let cap = SNAPSHOT_F32S - 4;
    if bytes.len() > cap {
        eprintln!(
            "[obs] metrics snapshot is {} bytes but the all-gather frame caps at {cap}; \
             writing a truncation marker instead of torn JSON (raise SNAPSHOT_F32S or \
             trim the series set)",
            bytes.len()
        );
        let rank = json_u64(json, "rank").unwrap_or(0);
        marker = format!(
            "{{\"rank\":{rank},\"truncated\":true,\"snapshot_bytes\":{}}}",
            bytes.len()
        );
        bytes = marker.as_bytes();
    }
    let mut out = Vec::with_capacity(SNAPSHOT_F32S);
    let len = bytes.len() as u32;
    out.extend(len.to_le_bytes().iter().map(|&b| b as f32));
    out.extend(bytes.iter().map(|&b| b as f32));
    out.resize(SNAPSHOT_F32S, 0.0);
    out
}

/// Decode one rank's frame back to its JSON line.
pub fn decode_snapshot(frame: &[f32]) -> Result<String> {
    if frame.len() != SNAPSHOT_F32S {
        bail!("metrics snapshot frame has {} f32s, expected {SNAPSHOT_F32S}", frame.len());
    }
    let hdr: Vec<u8> = frame[..4].iter().map(|&v| v as u8).collect();
    let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
    if len > SNAPSHOT_F32S - 4 {
        bail!("metrics snapshot length {len} exceeds the frame");
    }
    let bytes: Vec<u8> = frame[4..4 + len].iter().map(|&v| v as u8).collect();
    String::from_utf8(bytes).map_err(|e| anyhow::anyhow!("metrics snapshot is not UTF-8: {e}"))
}

/// The leader's per-rank summary table over gathered snapshot lines.
pub fn summary_table(lines: &[String]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>4} {:>12} {:>12} {:>10} {:>12} {:>10}\n",
        "rank", "sent(MB)", "recv(MB)", "tasks", "peak(MB)", "hwm(MB)"
    ));
    for (r, line) in lines.iter().enumerate() {
        let mb = |k: &str| json_u64(line, k).unwrap_or(0) as f64 / 1e6;
        out.push_str(&format!(
            "{r:>4} {:>12.2} {:>12.2} {:>10} {:>12.2} {:>10.2}\n",
            mb("comm.stream_sent_bytes"),
            mb("comm.stream_recv_bytes"),
            json_u64(line, "kernel.pool_tasks").unwrap_or(0),
            mb("peak_bytes"),
            json_u64(line, "vm_hwm_kb").unwrap_or(0) as f64 / 1e3,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The enabled flag is global; tests that toggle it must not
    /// interleave.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn test_guard() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_counters_do_not_move() {
        let _g = test_guard();
        static C: Counter = Counter::new("test.disabled");
        set_enabled(false);
        C.add(5);
        assert_eq!(C.get(), 0);
        set_enabled(true);
        C.add(5);
        assert_eq!(C.get(), 5);
    }

    #[test]
    fn histogram_buckets_by_log2() {
        let _g = test_guard();
        static H: Histogram = Histogram::new("test.hist");
        set_enabled(true);
        H.observe(1); // bucket 0
        H.observe(1024); // bucket 10
        H.observe(1025); // bucket 10
        H.observe(u64::MAX); // saturates at the top bucket
        assert_eq!(H.count(), 4);
        assert_eq!(H.buckets[0].load(Ordering::Relaxed), 1);
        assert_eq!(H.buckets[10].load(Ordering::Relaxed), 2);
        assert_eq!(H.buckets[HIST_BUCKETS - 1].load(Ordering::Relaxed), 1);
    }

    #[test]
    fn snapshot_roundtrips_through_the_f32_frame() {
        let _g = test_guard();
        set_enabled(true);
        record_value("test.series", 1.5);
        record_value("test.series", 2.5);
        let json = snapshot_json(3);
        assert!(json.contains("\"rank\":3"));
        assert!(json.contains("\"test.series\""));
        let frame = encode_snapshot(&json);
        assert_eq!(frame.len(), SNAPSHOT_F32S);
        let back = decode_snapshot(&frame).unwrap();
        assert_eq!(back, json);
        assert_eq!(json_u64(&back, "rank"), Some(3));
        // oversize degrades to a truncation marker that stays valid
        // JSON and keeps the rank + original size
        let big = format!("{{\"rank\":5,\"pad\":\"{}\"}}", "x".repeat(SNAPSHOT_F32S));
        let frame = encode_snapshot(&big);
        let marker = decode_snapshot(&frame).unwrap();
        assert_eq!(
            marker,
            format!("{{\"rank\":5,\"truncated\":true,\"snapshot_bytes\":{}}}", big.len())
        );
        assert_eq!(json_u64(&marker, "rank"), Some(5));
        assert_eq!(json_u64(&marker, "snapshot_bytes"), Some(big.len() as u64));
    }

    #[test]
    fn summary_table_extracts_rank_rows() {
        let lines = vec![
            "{\"rank\":0,\"counters\":{\"comm.stream_sent_bytes\":2000000,\
             \"comm.stream_recv_bytes\":1000000,\"kernel.pool_tasks\":7},\
             \"mem\":{\"peak_bytes\":5000000,\"vm_hwm_kb\":9000}}"
                .to_string(),
        ];
        let table = summary_table(&lines);
        assert!(table.contains("2.00"), "{table}");
        assert!(table.contains('7'), "{table}");
        assert!(table.contains("9.00"), "{table}");
    }
}
