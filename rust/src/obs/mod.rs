//! Passive observability: structured spans, a metrics registry, and a
//! measured memory ledger — threaded through the kernel pool, the
//! estimator engine, the comm collectives, the async checkpointer, and
//! both trainers.
//!
//! # Design contract: non-perturbing
//!
//! Observation must never change what is trained. Two properties pin
//! that down:
//!
//! 1. **Zero overhead when off.** Every instrumentation point compiles
//!    to a single relaxed atomic load of a global enabled flag; with
//!    `--trace-out`/`--metrics-out` absent nothing else runs — no
//!    allocation, no lock, no clock read. The engine's steady-state
//!    zero-allocation contract (`tests/engine_alloc.rs`) holds with the
//!    subsystem linked in because the disabled path touches no heap.
//! 2. **Bit-identical when on.** Spans and counters only *read* clocks
//!    and byte counts; they never touch the RNG streams, the reduction
//!    orders, or any f32 arithmetic. `tests/obs_determinism.rs` pins
//!    ParamStore bytes bitwise identical with observability on vs off
//!    at thread counts 1 and 4.
//!
//! # Pieces
//!
//! * [`span`] — the span recorder: thread-local lock-free SPSC ring
//!   buffers (one per thread, registered with a global collector on
//!   first use), drained at export into Chrome `trace_event` JSON for
//!   chrome://tracing / Perfetto (`--trace-out <path>`). Overflow is
//!   loud-but-lossy: a full ring drops the span and counts the drop.
//! * [`metrics`] — counters / gauges / histograms: wire bytes per
//!   dtype lane, pool task counts + queue-wait histogram, per-layer
//!   lift-residual norms, per-phase step-time series — snapshotted as
//!   JSONL (`--metrics-out <path>`) and summarized at run end. In a
//!   `launch` world every rank's snapshot is gathered to the leader
//!   over the existing `all_gather` (bytes smuggled as small-integer
//!   f32s, the `comm-check` CRC idiom) and written as one merged file.
//! * [`alloc`] — the measured memory ledger: [`TrackedAlloc`], an
//!   opt-in `#[global_allocator]` (promoted from the counting
//!   allocator `tests/engine_alloc.rs` introduced) tracking allocation
//!   events, live bytes, and peak bytes, plus `/proc/self/status`
//!   VmHWM/VmRSS sampling. `exp memory` prints the measured peaks
//!   beside the analytical model.
//! * [`quality`] — estimator-quality telemetry: the per-slot
//!   unbiasedness sentinel (EMA + z-score drift detection over a probe
//!   direction from a dedicated stream) and the Theorem-2-normalized
//!   `mse_ratio[layer]` variance proxy, computed read-only from the
//!   staged projected gradient and the live frame at every lazy-update
//!   boundary and (with `--probe-every`) on a rotating probe slot.
//! * [`monitor`] — run health: per-phase heartbeat watermarks in an
//!   atomic slab, a stall watchdog (`--stall-timeout`), a read-only
//!   newline-delimited-JSON TCP status endpoint (`--monitor-addr`,
//!   leader rank only), and a postmortem flight-recorder blackbox
//!   (`<ckpt-dir>/postmortem.rank<r>.json` on panic or comm
//!   peer-death).
//!
//! # Multi-rank traces
//!
//! All ranks of a `launch` world share argv, so each rank writes its
//! spans to a rank-scoped sibling of `--trace-out` ([`rank_scoped`]);
//! after a barrier the leader string-merges the per-rank JSON arrays
//! into the requested path ([`span::merge_chrome_traces`] — the ranks
//! share a filesystem because `launch` is a local spawner). Events
//! carry the rank as their Chrome `pid`, so the merged trace shows one
//! process row per rank.

pub mod alloc;
pub mod metrics;
pub mod monitor;
pub mod quality;
pub mod span;

use std::path::{Path, PathBuf};
use std::sync::OnceLock;
use std::time::Instant;

pub use alloc::TrackedAlloc;
pub use span::{span, SpanGuard};

/// Run-wide output paths, set once by `main` from `--trace-out` /
/// `--metrics-out` (or by tests).
#[derive(Default)]
struct ObsConfig {
    trace_out: Option<PathBuf>,
    metrics_out: Option<PathBuf>,
}

static CONFIG: OnceLock<ObsConfig> = OnceLock::new();

/// Enable the subsystem for this process: tracing iff `trace_out` is
/// given, metrics iff `metrics_out` is given. Call once, before the
/// run; later calls keep the first configuration.
pub fn init(trace_out: Option<&str>, metrics_out: Option<&str>) {
    let cfg = ObsConfig {
        trace_out: trace_out.map(PathBuf::from),
        metrics_out: metrics_out.map(PathBuf::from),
    };
    if CONFIG.set(cfg).is_ok() {
        if trace_out.is_some() {
            span::set_enabled(true);
        }
        if metrics_out.is_some() {
            metrics::set_enabled(true);
        }
    }
}

/// The `--trace-out` path, if tracing was enabled with one.
pub fn trace_out() -> Option<PathBuf> {
    CONFIG.get().and_then(|c| c.trace_out.clone())
}

/// The `--metrics-out` path, if metrics were enabled with one.
pub fn metrics_out() -> Option<PathBuf> {
    CONFIG.get().and_then(|c| c.metrics_out.clone())
}

/// Rank-scoped sibling of an output path: `t.json` → `t.rank2.json`.
/// Rank files keep every rank of a `launch` world (same argv on every
/// rank) from clobbering one shared path; the leader merges them.
pub fn rank_scoped(path: &Path, rank: usize) -> PathBuf {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    let ext = path.extension().and_then(|s| s.to_str()).unwrap_or("json");
    path.with_file_name(format!("{stem}.rank{rank}.{ext}"))
}

/// A guard that records both a span (when tracing) and a per-phase
/// duration series sample (when metrics) — the trainers' step-phase
/// breakdown. Disabled, it is two relaxed loads and no clock read.
#[must_use = "a phase measures the scope it is alive for"]
pub struct Phase {
    span: SpanGuard,
    metric: &'static str,
    start: Option<Instant>,
}

/// Open a phase: `cat`/`name` label the span, `metric` names the
/// duration series (e.g. `pretrain.execute_s`).
#[inline]
pub fn phase(cat: &'static str, name: &'static str, metric: &'static str) -> Phase {
    Phase {
        span: span::span(cat, name),
        metric,
        start: if metrics::enabled() { Some(Instant::now()) } else { None },
    }
}

impl Drop for Phase {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            metrics::record_value(self.metric, t0.elapsed().as_secs_f64());
        }
        // span guard drops after, closing the trace event
        let _ = &self.span;
    }
}

/// Write this rank's spans for a `world`-rank run: single-process runs
/// write `path` directly; multi-rank runs write the rank-scoped
/// sibling (the leader merges after a barrier — [`merge_rank_traces`]).
/// Returns the path written, or `None` when tracing is off.
pub fn export_rank_trace(rank: usize, world: usize) -> anyhow::Result<Option<PathBuf>> {
    let Some(path) = trace_out() else { return Ok(None) };
    let out = if world > 1 { rank_scoped(&path, rank) } else { path };
    span::write_chrome_trace(&out, rank)?;
    Ok(Some(out))
}

/// Leader-side merge of every rank's trace file into `--trace-out`
/// proper. Call after a barrier so all rank files are committed; the
/// rank files are removed once merged.
pub fn merge_rank_traces(world: usize) -> anyhow::Result<Option<PathBuf>> {
    let Some(path) = trace_out() else { return Ok(None) };
    if world <= 1 {
        return Ok(Some(path));
    }
    let inputs: Vec<PathBuf> = (0..world).map(|r| rank_scoped(&path, r)).collect();
    span::merge_chrome_traces(&path, &inputs)?;
    for p in &inputs {
        let _ = std::fs::remove_file(p);
    }
    Ok(Some(path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_scoped_inserts_rank_before_extension() {
        assert_eq!(
            rank_scoped(Path::new("/tmp/t.json"), 2),
            PathBuf::from("/tmp/t.rank2.json")
        );
        assert_eq!(rank_scoped(Path::new("m.jsonl"), 0), PathBuf::from("m.rank0.jsonl"));
    }
}
