//! Run-health monitor: per-phase heartbeat watermarks, a stall
//! watchdog, a read-only TCP status endpoint, and a postmortem
//! flight-recorder blackbox.
//!
//! The pieces compose but are independently usable:
//!
//! * **Watermarks** — each rank stamps `(phase, step, monotonic tick)`
//!   into a fixed atomic slab ([`stamp`]) at the trainer's existing
//!   phase points (resample / execute / reduce / update / eval / ckpt /
//!   barrier). A stamp is two relaxed stores; with the monitor
//!   unconfigured it is one relaxed load — the same non-perturbation
//!   contract as the rest of [`crate::obs`] (no RNG, no arithmetic, no
//!   ordering effects; pinned by `tests/obs_determinism.rs`).
//! * **Watchdog** — [`start_watchdog`] spawns one background thread
//!   that flags a stall (`[obs:monitor] stall …` + [`stall_count`])
//!   when no watermark advances within `--stall-timeout` ms. Off by
//!   default; a slow-but-alive rank whose stamps keep arriving under
//!   the timeout is never flagged (pinned by `tests/obs_monitor.rs`).
//! * **Status endpoint** — [`serve_status`] binds `--monitor-addr` and
//!   serves newline-delimited JSON snapshots ([`status_line`]): the
//!   full metrics-registry snapshot (step phase times, per-lane wire
//!   bytes, heap live/peak/VmHWM, per-layer active ranks, residuals,
//!   `mse_ratio`) wrapped in an envelope with the live watermarks and
//!   stall/peer-event state. Read-only: the serving threads never
//!   touch training state beyond the registry mutex.
//! * **Blackbox** — on panic (hook installed by [`configure`]) or on a
//!   comm peer-death ([`note_comm_error`], called from the transport's
//!   error normalizer), [`dump_blackbox`] writes the last span-ring
//!   entries, a final metrics snapshot, the watermark slab, and the
//!   recorded comm peer events to `<dir>/postmortem.rank<r>.json`
//!   before the process dies — enough to reconstruct *where* a run was
//!   when it stopped without re-running it under a tracer.
//!
//! The endpoint binds an explicit caller-chosen address (unlike
//! [`crate::comm::transport::Listener`], which deliberately binds
//! ephemeral rendezvous ports); under `launch` only the leader rank
//! serves it, so one `--monitor-addr` on the command line never
//! collides across ranks.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

/// Trainer phases that stamp heartbeat watermarks. The discriminants
/// index the watermark slab.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Resample = 0,
    Execute = 1,
    Reduce = 2,
    Update = 3,
    Eval = 4,
    Ckpt = 5,
    Barrier = 6,
}

pub const N_PHASES: usize = 7;

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Resample => "resample",
            Phase::Execute => "execute",
            Phase::Reduce => "reduce",
            Phase::Update => "update",
            Phase::Eval => "eval",
            Phase::Ckpt => "ckpt",
            Phase::Barrier => "barrier",
        }
    }

    fn all() -> [Phase; N_PHASES] {
        [
            Phase::Resample,
            Phase::Execute,
            Phase::Reduce,
            Phase::Update,
            Phase::Eval,
            Phase::Ckpt,
            Phase::Barrier,
        ]
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Arm or disarm watermark stamping (also done by [`configure`]).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is the monitor armed? One relaxed load — the whole disabled path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

// The watermark slab: per-phase step and tick (ms since the monitor
// epoch, +1 so 0 means "never stamped"). Relaxed everywhere — the
// watchdog and status readers only need eventually-consistent
// progress evidence, never synchronization.
static WM_STEP: [AtomicU64; N_PHASES] = [const { AtomicU64::new(0) }; N_PHASES];
static WM_TICK: [AtomicU64; N_PHASES] = [const { AtomicU64::new(0) }; N_PHASES];

fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

fn tick_ms() -> u64 {
    epoch().elapsed().as_millis() as u64 + 1
}

/// Stamp this rank's heartbeat watermark for `phase` at `step`.
#[inline]
pub fn stamp(phase: Phase, step: u64) {
    if !enabled() {
        return;
    }
    let t = tick_ms();
    WM_STEP[phase as usize].store(step, Ordering::Relaxed);
    WM_TICK[phase as usize].store(t, Ordering::Relaxed);
}

/// One phase's last-stamped watermark.
#[derive(Clone, Debug)]
pub struct Watermark {
    pub phase: &'static str,
    pub step: u64,
    pub tick_ms: u64,
}

/// The stamped watermarks, in phase order (never-stamped phases are
/// omitted).
pub fn watermarks() -> Vec<Watermark> {
    Phase::all()
        .into_iter()
        .filter_map(|p| {
            let tick = WM_TICK[p as usize].load(Ordering::Relaxed);
            (tick > 0).then(|| Watermark {
                phase: p.name(),
                step: WM_STEP[p as usize].load(Ordering::Relaxed),
                tick_ms: tick,
            })
        })
        .collect()
}

/// The newest watermark tick across all phases (0 = nothing stamped).
fn newest_tick() -> u64 {
    (0..N_PHASES).map(|i| WM_TICK[i].load(Ordering::Relaxed)).max().unwrap_or(0)
}

struct MonitorCfg {
    rank: usize,
    blackbox_dir: Option<PathBuf>,
}

fn cfg_cell() -> &'static OnceLock<MonitorCfg> {
    static CFG: OnceLock<MonitorCfg> = OnceLock::new();
    &CFG
}

/// Configure the monitor for this process: record the rank (stamped
/// into every status line and the blackbox filename), arm watermark
/// stamping, and — when `blackbox_dir` is given — install the panic
/// hook that dumps the flight recorder before the process dies. First
/// call wins (the `obs::init` convention); later calls are no-ops.
pub fn configure(rank: usize, blackbox_dir: Option<&Path>) {
    let _ = cfg_cell().set(MonitorCfg { rank, blackbox_dir: blackbox_dir.map(PathBuf::from) });
    set_enabled(true);
    if blackbox_dir.is_some() {
        install_panic_hook();
    }
}

fn rank() -> usize {
    cfg_cell().get().map(|c| c.rank).unwrap_or(0)
}

// ---------------------------------------------------------------- watchdog

static STALLS: AtomicUsize = AtomicUsize::new(0);

/// Stalls flagged by the watchdog so far (this process).
pub fn stall_count() -> usize {
    STALLS.load(Ordering::Relaxed)
}

/// Spawn the stall watchdog: flags (loudly, and in [`stall_count`])
/// whenever no watermark has advanced within `timeout_ms`. One flag
/// per stall — the counter advances again only after the watermarks
/// do. Idempotent; the thread is detached and dies with the process.
pub fn start_watchdog(timeout_ms: u64) {
    if timeout_ms == 0 {
        return;
    }
    static STARTED: AtomicBool = AtomicBool::new(false);
    if STARTED.swap(true, Ordering::SeqCst) {
        return;
    }
    let poll = Duration::from_millis((timeout_ms / 4).clamp(10, 1000));
    std::thread::Builder::new()
        .name("obs-monitor-watchdog".into())
        .spawn(move || {
            let mut flagged_at: u64 = 0; // newest tick already flagged
            loop {
                std::thread::sleep(poll);
                let newest = newest_tick();
                if newest == 0 {
                    continue; // nothing stamped yet — the run hasn't started
                }
                let now = tick_ms();
                if now.saturating_sub(newest) > timeout_ms {
                    if newest != flagged_at {
                        flagged_at = newest;
                        STALLS.fetch_add(1, Ordering::Relaxed);
                        let wm = watermarks();
                        let last = wm
                            .iter()
                            .max_by_key(|w| w.tick_ms)
                            .map(|w| format!("{} step {}", w.phase, w.step))
                            .unwrap_or_else(|| "?".into());
                        eprintln!(
                            "[obs:monitor] stall: rank {} made no progress for {} ms \
                             (timeout {timeout_ms} ms; last watermark: {last})",
                            rank(),
                            now.saturating_sub(newest),
                        );
                    }
                } else {
                    flagged_at = 0; // progress resumed — re-arm
                }
            }
        })
        .expect("spawning the obs-monitor watchdog thread");
}

// ----------------------------------------------------------- peer events

fn peer_events() -> &'static Mutex<Vec<String>> {
    static EVENTS: Mutex<Vec<String>> = Mutex::new(Vec::new());
    &EVENTS
}

/// Record a comm-layer failure (called from the transport's error
/// normalizer). Peer-death-shaped errors additionally trigger one
/// blackbox dump — the error is about to unwind the whole rank, and
/// the flight recorder must be on disk before it does.
pub fn note_comm_error(msg: &str) {
    {
        let mut ev = peer_events().lock().unwrap_or_else(|e| e.into_inner());
        if ev.len() < 32 {
            ev.push(format!("t={}ms {}", tick_ms(), msg));
        }
    }
    let peer_death = msg.contains("peer");
    if peer_death && cfg_cell().get().is_some_and(|c| c.blackbox_dir.is_some()) {
        static DUMPED: AtomicBool = AtomicBool::new(false);
        if !DUMPED.swap(true, Ordering::SeqCst) {
            let _ = dump_blackbox(&format!("peer-death: {msg}"));
        }
    }
}

// --------------------------------------------------------------- blackbox

/// How many of the newest span-ring entries the blackbox keeps.
pub const BLACKBOX_SPANS: usize = 256;

fn escape(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Install the panic hook that dumps the blackbox (idempotent; chains
/// the previous hook so the normal panic message still prints).
pub fn install_panic_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let reason = format!("panic: {info}");
            if let Some(p) = dump_blackbox(&reason) {
                eprintln!("[obs:monitor] blackbox written to {}", p.display());
            }
            prev(info);
        }));
    });
}

/// Dump the flight recorder: the newest [`BLACKBOX_SPANS`] span-ring
/// entries, a final metrics snapshot, the watermark slab, and the comm
/// peer events, as one JSON object at
/// `<blackbox_dir>/postmortem.rank<r>.json`. Returns the path, or
/// `None` when no blackbox dir is configured or the write fails (a
/// dying process must not die harder because its postmortem failed).
pub fn dump_blackbox(reason: &str) -> Option<PathBuf> {
    let cfg = cfg_cell().get()?;
    let dir = cfg.blackbox_dir.as_ref()?;
    let path = dir.join(format!("postmortem.rank{}.json", cfg.rank));
    let (mut events, _labels) = crate::obs::span::drain_all();
    events.sort_by_key(|(_, e)| e.start_ns);
    let keep = events.len().saturating_sub(BLACKBOX_SPANS);
    let mut out = String::with_capacity(4096);
    out.push_str(&format!("{{\"rank\":{},\"reason\":\"", cfg.rank));
    escape(reason, &mut out);
    out.push_str(&format!("\",\"tick_ms\":{},\"stalls\":{},\"spans\":[", tick_ms(), stall_count()));
    for (k, (tid, ev)) in events[keep..].iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!("{{\"tid\":{tid},\"cat\":\""));
        escape(ev.cat, &mut out);
        out.push_str("\",\"name\":\"");
        escape(ev.name, &mut out);
        out.push_str(&format!("\",\"start_ns\":{},\"dur_ns\":{}}}", ev.start_ns, ev.dur_ns));
    }
    out.push_str("],\"watermarks\":[");
    for (k, w) in watermarks().iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"phase\":\"{}\",\"step\":{},\"tick_ms\":{}}}",
            w.phase, w.step, w.tick_ms
        ));
    }
    out.push_str("],\"peer_events\":[");
    {
        let ev = peer_events().lock().unwrap_or_else(|e| e.into_inner());
        for (k, e) in ev.iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            out.push('"');
            escape(e, &mut out);
            out.push('"');
        }
    }
    out.push_str("],\"metrics\":");
    out.push_str(&crate::obs::metrics::snapshot_json(cfg.rank));
    out.push_str("}\n");
    if std::fs::create_dir_all(dir).is_err() {
        return None;
    }
    match std::fs::write(&path, out) {
        Ok(()) => Some(path),
        Err(e) => {
            eprintln!("[obs:monitor] blackbox write to {} failed: {e}", path.display());
            None
        }
    }
}

// ---------------------------------------------------------- status endpoint

/// One status-endpoint snapshot line: the metrics-registry snapshot
/// wrapped in an envelope with the rank, tick, stall count, watermarks,
/// and recorded peer events. Always a single line of valid JSON.
pub fn status_line() -> String {
    let r = rank();
    let mut out = String::with_capacity(1024);
    out.push_str(&format!(
        "{{\"rank\":{r},\"tick_ms\":{},\"stalls\":{},\"watermarks\":[",
        tick_ms(),
        stall_count()
    ));
    for (k, w) in watermarks().iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"phase\":\"{}\",\"step\":{},\"tick_ms\":{}}}",
            w.phase, w.step, w.tick_ms
        ));
    }
    out.push_str("],\"peer_events\":[");
    {
        let ev = peer_events().lock().unwrap_or_else(|e| e.into_inner());
        for (k, e) in ev.iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            out.push('"');
            escape(e, &mut out);
            out.push('"');
        }
    }
    out.push_str("],\"registry\":");
    out.push_str(&crate::obs::metrics::snapshot_json(r));
    out.push('}');
    out
}

/// Default concurrent-connection cap for the status endpoint.
pub const STATUS_MAX_CONNS: usize = 16;

/// Default per-connection idle budget (ms): a client that sends nothing
/// for this long is disconnected (it can reconnect, or send any byte as
/// a keepalive to reset the clock).
pub const STATUS_IDLE_MS: u64 = 300_000;

/// Connections currently being served (cap accounting + test hook).
static ACTIVE_STATUS_CONNS: AtomicUsize = AtomicUsize::new(0);

/// Number of status connections currently being served.
pub fn active_status_conns() -> usize {
    ACTIVE_STATUS_CONNS.load(Ordering::SeqCst)
}

/// Bind `addr` and serve newline-delimited JSON status snapshots: one
/// [`status_line`] immediately on connect, then one per second until
/// the client hangs up. Returns the bound address (so `addr` may use
/// port 0). Read-only by construction; the accept loop and per-client
/// writers are detached threads that die with the process. Uses the
/// default hardening limits ([`STATUS_MAX_CONNS`], [`STATUS_IDLE_MS`]).
pub fn serve_status(addr: &str) -> Result<SocketAddr> {
    serve_status_with(addr, STATUS_MAX_CONNS, STATUS_IDLE_MS)
}

/// [`serve_status`] with explicit hardening limits, so a stuck or
/// malicious client can neither leak writer threads nor wedge the
/// endpoint:
///
/// * **`max_conns`** — connections above the cap get one
///   `{"error":…}` line and an immediate close, never a thread.
/// * **`idle_ms`** — a connection whose client has sent nothing for
///   this long is closed (0 = no idle limit). Any received byte resets
///   the clock; EOF from the client closes promptly instead of waiting
///   for the next write to fail. A reader that stops draining is
///   already bounded by the 5 s write timeout.
pub fn serve_status_with(addr: &str, max_conns: usize, idle_ms: u64) -> Result<SocketAddr> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding the monitor status endpoint on {addr}"))?;
    let bound = listener.local_addr().context("reading the monitor endpoint address")?;
    std::thread::Builder::new()
        .name("obs-monitor-status".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut stream) = stream else { continue };
                if ACTIVE_STATUS_CONNS.load(Ordering::SeqCst) >= max_conns {
                    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
                    let _ = stream
                        .write_all(b"{\"error\":\"monitor connection cap reached\"}\n");
                    continue; // dropped: no thread spent on over-cap clients
                }
                ACTIVE_STATUS_CONNS.fetch_add(1, Ordering::SeqCst);
                let spawned =
                    std::thread::Builder::new().name("obs-monitor-conn".into()).spawn(move || {
                        status_conn_loop(&mut stream, idle_ms);
                        ACTIVE_STATUS_CONNS.fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    ACTIVE_STATUS_CONNS.fetch_sub(1, Ordering::SeqCst);
                }
            }
        })
        .context("spawning the obs-monitor status thread")?;
    Ok(bound)
}

/// One status connection: write a snapshot, sleep, probe the client.
fn status_conn_loop(stream: &mut TcpStream, idle_ms: u64) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut last_activity = Instant::now();
    let mut probe = [0u8; 64];
    loop {
        let line = status_line();
        if stream.write_all(line.as_bytes()).is_err()
            || stream.write_all(b"\n").is_err()
            || stream.flush().is_err()
        {
            break;
        }
        std::thread::sleep(Duration::from_millis(1000));
        match stream.read(&mut probe) {
            Ok(0) => break, // orderly client shutdown
            Ok(_) => last_activity = Instant::now(),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => break,
        }
        if idle_ms > 0 && last_activity.elapsed() >= Duration::from_millis(idle_ms) {
            break; // silent past the idle budget — reclaim the thread
        }
    }
}

/// Minimal structural JSON check (balanced delimiters outside strings)
/// — enough for the in-world endpoint smoke in `comm-check` and the
/// monitor tests to certify a snapshot line parses, without a JSON
/// dependency.
pub fn check_json_line(s: &str) -> bool {
    let t = s.trim();
    if !(t.starts_with('{') && t.ends_with('}')) {
        return false;
    }
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for c in t.chars() {
        if esc {
            esc = false;
            continue;
        }
        match c {
            '\\' if in_str => esc = true,
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => {
                depth -= 1;
                if depth < 0 {
                    return false;
                }
            }
            _ => {}
        }
    }
    depth == 0 && !in_str
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The slab and enabled flag are process-global; tests that stamp
    /// or toggle must not interleave.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn test_guard() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn stamp_and_watermarks_round_trip() {
        let _g = test_guard();
        set_enabled(true);
        stamp(Phase::Execute, 41);
        stamp(Phase::Update, 41);
        let wm = watermarks();
        let ex = wm.iter().find(|w| w.phase == "execute").expect("execute stamped");
        assert_eq!(ex.step, 41);
        assert!(ex.tick_ms > 0);
        assert!(wm.iter().any(|w| w.phase == "update"));
    }

    #[test]
    fn disabled_stamp_is_a_no_op() {
        let _g = test_guard();
        set_enabled(false);
        let before = WM_TICK[Phase::Ckpt as usize].load(Ordering::Relaxed);
        stamp(Phase::Ckpt, 999);
        assert_eq!(WM_TICK[Phase::Ckpt as usize].load(Ordering::Relaxed), before);
        set_enabled(true);
    }

    #[test]
    fn status_line_is_valid_json() {
        let _g = test_guard();
        set_enabled(true);
        stamp(Phase::Reduce, 3);
        let line = status_line();
        assert!(check_json_line(&line), "{line}");
        assert!(line.contains("\"registry\":{"), "{line}");
        assert!(line.contains("\"watermarks\":["), "{line}");
        assert!(!line.contains('\n'));
    }

    #[test]
    fn json_checker_accepts_and_rejects() {
        assert!(check_json_line("{\"a\":[1,2,{\"b\":\"x]}\"}]}"));
        assert!(!check_json_line("{\"a\":[1,2}"));
        assert!(!check_json_line("[1,2,3]")); // snapshots are objects
        assert!(!check_json_line("{\"a\":\"unterminated}"));
    }

    #[test]
    fn status_endpoint_caps_concurrent_connections() {
        let _g = test_guard();
        let bound = serve_status_with("127.0.0.1:0", 1, 0).unwrap();
        let c1 = std::net::TcpStream::connect(bound).unwrap();
        for _ in 0..200 {
            if active_status_conns() >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(active_status_conns(), 1);
        // over-cap client: one error line, then close — never a thread
        let c2 = std::net::TcpStream::connect(bound).unwrap();
        c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut line = String::new();
        std::io::BufRead::read_line(&mut std::io::BufReader::new(c2), &mut line).unwrap();
        assert!(line.contains("connection cap reached"), "{line}");
        assert!(check_json_line(&line), "{line}");
        // closing the in-cap client frees its slot (EOF probe, ≤ ~1.1 s)
        drop(c1);
        for _ in 0..300 {
            if active_status_conns() == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
        assert_eq!(active_status_conns(), 0);
    }

    #[test]
    fn status_endpoint_disconnects_idle_clients() {
        let _g = test_guard();
        set_enabled(true);
        let bound = serve_status_with("127.0.0.1:0", 4, 50).unwrap();
        let c = std::net::TcpStream::connect(bound).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut r = std::io::BufReader::new(c);
        let mut line = String::new();
        std::io::BufRead::read_line(&mut r, &mut line).unwrap();
        assert!(check_json_line(&line), "{line}");
        // send nothing: the server must hang up on its own (idle budget
        // 50 ms, checked after the 1 s snapshot cadence)
        let mut rest = String::new();
        std::io::Read::read_to_string(&mut r, &mut rest).unwrap();
    }

    #[test]
    fn peer_events_are_bounded_and_reported() {
        let _g = test_guard();
        for i in 0..40 {
            note_comm_error(&format!("test comm error {i}"));
        }
        let ev = peer_events().lock().unwrap_or_else(|e| e.into_inner());
        assert!(ev.len() <= 32);
        drop(ev);
        let line = status_line();
        assert!(line.contains("test comm error"), "{line}");
        assert!(check_json_line(&line), "{line}");
    }
}
