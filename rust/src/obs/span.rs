//! The span recorder: RAII spans into per-thread lock-free ring
//! buffers, exported as Chrome `trace_event` JSON.
//!
//! # Hot path
//!
//! [`span`] with tracing disabled is one relaxed atomic load — no
//! clock read, no thread-local touch, no allocation. Enabled, opening
//! a span reads the monotonic clock once and dropping it pushes one
//! fixed-size [`SpanEvent`] into the calling thread's SPSC ring: the
//! owner thread is the only writer (`head`), the collector the only
//! reader (`tail`, serialized by the registry lock), so a push is two
//! atomic loads, one slot write, one release store — lock-free and
//! wait-free. A full ring is **loud-but-lossy**: the span is dropped
//! and counted, never blocked on (blocking would perturb the very
//! timings being measured), and the drop count is reported at export.
//!
//! Rings register themselves with the global collector on a thread's
//! first recorded span and outlive the thread (the registry holds an
//! `Arc`), so spans recorded on short-lived helpers — pool workers,
//! the ckpt writer, comm sender/receiver threads — survive to the
//! drain.
//!
//! # Export
//!
//! [`write_chrome_trace`] drains every ring and writes a bare JSON
//! array of complete (`"ph":"X"`) events — timestamps in microseconds
//! since the process epoch, `pid` = rank, `tid` = a small per-thread
//! id with `thread_name` metadata. The bare-array form is what makes
//! the leader's cross-rank merge ([`merge_chrome_traces`]) a safe
//! string-level concatenation; chrome://tracing and Perfetto accept
//! both forms.

use std::cell::{OnceCell, UnsafeCell};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

/// Per-thread ring capacity (events). At ~32 bytes/event this is
/// ~256 KiB per observed thread, allocated on the thread's first span.
pub const RING_CAP: usize = 8192;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn span recording on or off (also driven by `obs::init`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is span recording on? One relaxed load — the whole disabled path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process-wide trace epoch (first use).
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// One closed span. Label strings are `&'static str` by design: a
/// recorded event is 4 words, never an allocation.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub cat: &'static str,
    pub name: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
}

const EMPTY_EVENT: SpanEvent = SpanEvent { cat: "", name: "", start_ns: 0, dur_ns: 0 };

/// SPSC ring: the owning thread pushes at `head`, the (lock-serialized)
/// collector pops at `tail`. Indices increase monotonically; the live
/// region is `[tail, head)` taken mod capacity.
struct ThreadRing {
    tid: u64,
    label: String,
    slots: Box<[UnsafeCell<SpanEvent>]>,
    head: AtomicUsize,
    tail: AtomicUsize,
    dropped: AtomicUsize,
}

// Slots in [tail, head) are only read by the collector and only
// written by the owner strictly before the head release-store that
// publishes them — the SPSC discipline makes the cell sharing sound.
unsafe impl Send for ThreadRing {}
unsafe impl Sync for ThreadRing {}

impl ThreadRing {
    fn new(tid: u64, label: String) -> ThreadRing {
        let slots: Vec<UnsafeCell<SpanEvent>> =
            (0..RING_CAP).map(|_| UnsafeCell::new(EMPTY_EVENT)).collect();
        ThreadRing {
            tid,
            label,
            slots: slots.into_boxed_slice(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
        }
    }

    /// Owner-thread push. Full ring: count the drop and move on.
    fn push(&self, ev: SpanEvent) {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        unsafe { *self.slots[head % self.slots.len()].get() = ev };
        self.head.store(head.wrapping_add(1), Ordering::Release);
    }

    /// Collector-side drain (caller holds the registry lock).
    fn drain_into(&self, out: &mut Vec<(u64, SpanEvent)>) {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        while tail != head {
            out.push((self.tid, unsafe { *self.slots[tail % self.slots.len()].get() }));
            tail = tail.wrapping_add(1);
        }
        self.tail.store(tail, Ordering::Release);
    }
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadRing>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<ThreadRing>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LOCAL: OnceCell<Arc<ThreadRing>> = const { OnceCell::new() };
}

fn register_current_thread() -> Arc<ThreadRing> {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let label = std::thread::current()
        .name()
        .map(|n| n.to_string())
        .unwrap_or_else(|| format!("thread-{tid}"));
    let ring = Arc::new(ThreadRing::new(tid, label));
    registry().lock().unwrap().push(ring.clone());
    ring
}

/// Record one closed span on the calling thread's ring (creating and
/// registering the ring on first use).
pub fn record(cat: &'static str, name: &'static str, start_ns: u64, dur_ns: u64) {
    LOCAL.with(|cell| {
        cell.get_or_init(register_current_thread)
            .push(SpanEvent { cat, name, start_ns, dur_ns })
    });
}

/// RAII span: created by [`span`], records on drop. Disabled guards
/// carry no timestamp and drop to nothing.
#[must_use = "a span measures the scope it is alive for"]
pub struct SpanGuard {
    cat: &'static str,
    name: &'static str,
    start_ns: u64,
    armed: bool,
}

/// Open a span labelled `cat`/`name` around the current scope.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { cat, name, start_ns: 0, armed: false };
    }
    SpanGuard { cat, name, start_ns: now_ns(), armed: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            let end = now_ns();
            record(self.cat, self.name, self.start_ns, end.saturating_sub(self.start_ns));
        }
    }
}

/// Drain every registered ring. Returns `(tid, event)` pairs in ring
/// order (sort by `start_ns` for a timeline) plus the per-thread
/// labels; the total drop count is in [`dropped_total`].
pub fn drain_all() -> (Vec<(u64, SpanEvent)>, Vec<(u64, String)>) {
    let rings = registry().lock().unwrap();
    let mut events = Vec::new();
    let mut labels = Vec::new();
    for ring in rings.iter() {
        ring.drain_into(&mut events);
        labels.push((ring.tid, ring.label.clone()));
    }
    (events, labels)
}

/// Total spans lost to ring overflow so far, across all threads.
pub fn dropped_total() -> usize {
    registry().lock().unwrap().iter().map(|r| r.dropped.load(Ordering::Relaxed)).sum()
}

fn escape_json(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Drain all rings and write the events as a bare Chrome `trace_event`
/// JSON array — `pid` is the caller's rank so merged multi-rank traces
/// show one process row per rank. Returns the event count written;
/// ring-overflow drops are reported loudly on stderr.
pub fn write_chrome_trace(path: &Path, pid: usize) -> Result<usize> {
    let (mut events, labels) = drain_all();
    events.sort_by_key(|(_, e)| e.start_ns);
    let mut out = String::with_capacity(64 + 128 * events.len());
    out.push_str("[\n");
    let mut first = true;
    for (tid, label) in &labels {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"args\":{{\"name\":\""
        ));
        escape_json(label, &mut out);
        out.push_str("\"}}");
    }
    for (tid, ev) in &events {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str("{\"name\":\"");
        escape_json(ev.name, &mut out);
        out.push_str("\",\"cat\":\"");
        escape_json(ev.cat, &mut out);
        out.push_str(&format!(
            "\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{pid},\"tid\":{tid}}}",
            ev.start_ns as f64 / 1e3,
            ev.dur_ns as f64 / 1e3
        ));
    }
    out.push_str("\n]\n");
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    f.write_all(out.as_bytes())?;
    let dropped = dropped_total();
    if dropped > 0 {
        eprintln!(
            "obs: {dropped} span(s) dropped to ring overflow — the trace in {} is incomplete",
            path.display()
        );
    }
    Ok(events.len())
}

/// String-merge per-rank bare-array trace files (written by
/// [`write_chrome_trace`]) into one array at `out`. Safe precisely
/// because we wrote the inputs: each is `[` events `]` with no nested
/// top-level brackets outside string-free event objects.
pub fn merge_chrome_traces(out: &Path, inputs: &[PathBuf]) -> Result<()> {
    let mut bodies = Vec::with_capacity(inputs.len());
    for p in inputs {
        let s = std::fs::read_to_string(p)
            .with_context(|| format!("reading rank trace {}", p.display()))?;
        let t = s.trim();
        let Some(inner) = t.strip_prefix('[').and_then(|t| t.strip_suffix(']')) else {
            bail!("rank trace {} is not a bare JSON array", p.display());
        };
        let inner = inner.trim();
        if !inner.is_empty() {
            bodies.push(inner.to_string());
        }
    }
    let mut f = std::fs::File::create(out)
        .with_context(|| format!("creating merged trace {}", out.display()))?;
    writeln!(f, "[\n{}\n]", bodies.join(",\n"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The recorder is global (enabled flag, ring registry); these
    /// tests drain and toggle it, so they must not interleave.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn test_guard() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Minimal JSON syntax checker (objects/arrays/strings/numbers/
    /// literals) — enough to certify the emitted trace parses.
    fn check_json(s: &str) -> std::result::Result<(), String> {
        let b = s.as_bytes();
        let mut i = 0usize;
        fn ws(b: &[u8], i: &mut usize) {
            while *i < b.len() && (b[*i] as char).is_whitespace() {
                *i += 1;
            }
        }
        fn value(b: &[u8], i: &mut usize) -> std::result::Result<(), String> {
            ws(b, i);
            match b.get(*i) {
                Some(b'{') => {
                    *i += 1;
                    ws(b, i);
                    if b.get(*i) == Some(&b'}') {
                        *i += 1;
                        return Ok(());
                    }
                    loop {
                        ws(b, i);
                        string(b, i)?;
                        ws(b, i);
                        if b.get(*i) != Some(&b':') {
                            return Err(format!("expected ':' at {i:?}"));
                        }
                        *i += 1;
                        value(b, i)?;
                        ws(b, i);
                        match b.get(*i) {
                            Some(b',') => *i += 1,
                            Some(b'}') => {
                                *i += 1;
                                return Ok(());
                            }
                            other => return Err(format!("bad object at {i:?}: {other:?}")),
                        }
                    }
                }
                Some(b'[') => {
                    *i += 1;
                    ws(b, i);
                    if b.get(*i) == Some(&b']') {
                        *i += 1;
                        return Ok(());
                    }
                    loop {
                        value(b, i)?;
                        ws(b, i);
                        match b.get(*i) {
                            Some(b',') => *i += 1,
                            Some(b']') => {
                                *i += 1;
                                return Ok(());
                            }
                            other => return Err(format!("bad array at {i:?}: {other:?}")),
                        }
                    }
                }
                Some(b'"') => string(b, i),
                Some(c)
                    if c.is_ascii_digit() || *c == b'-' || *c == b't' || *c == b'f'
                        || *c == b'n' =>
                {
                    while *i < b.len()
                        && matches!(b[*i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E'
                            | b'a'..=b'z')
                    {
                        *i += 1;
                    }
                    Ok(())
                }
                other => Err(format!("bad value at {i:?}: {other:?}")),
            }
        }
        fn string(b: &[u8], i: &mut usize) -> std::result::Result<(), String> {
            if b.get(*i) != Some(&b'"') {
                return Err(format!("expected string at {i:?}"));
            }
            *i += 1;
            while let Some(&c) = b.get(*i) {
                match c {
                    b'\\' => *i += 2,
                    b'"' => {
                        *i += 1;
                        return Ok(());
                    }
                    _ => *i += 1,
                }
            }
            Err("unterminated string".into())
        }
        value(b, &mut i)?;
        ws(b, &mut i);
        if i != b.len() {
            return Err(format!("trailing bytes at {i}"));
        }
        Ok(())
    }

    /// Emit spans from a dedicated thread so concurrent lib tests
    /// cannot interleave events onto the ring under test.
    fn on_thread<T: Send + 'static>(name: &str, f: impl FnOnce() -> T + Send + 'static) -> T {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .unwrap()
            .join()
            .unwrap()
    }

    #[test]
    fn nested_spans_record_containment_and_cross_thread_drain_sees_them() {
        let _g = test_guard();
        set_enabled(true);
        on_thread("obs-nest", || {
            let outer = span("obs-test", "outer");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = span("obs-test", "inner");
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            drop(outer);
        });
        // drain happens on the test thread — cross-thread by design
        let (events, labels) = drain_all();
        let ours: Vec<&SpanEvent> =
            events.iter().map(|(_, e)| e).filter(|e| e.cat == "obs-test").collect();
        let outer = ours.iter().find(|e| e.name == "outer").expect("outer span");
        let inner = ours.iter().find(|e| e.name == "inner").expect("inner span");
        assert!(inner.start_ns >= outer.start_ns);
        assert!(inner.start_ns + inner.dur_ns <= outer.start_ns + outer.dur_ns + 1_000);
        assert!(labels.iter().any(|(_, l)| l == "obs-nest"), "thread label registered");
    }

    #[test]
    fn ring_overflow_is_loud_but_lossy() {
        let _g = test_guard();
        set_enabled(true);
        let dropped = on_thread("obs-overflow", || {
            let before_local = 0usize;
            for _ in 0..RING_CAP + 100 {
                record("obs-overflow", "tick", 0, 1);
            }
            // read this thread's own ring drop count
            LOCAL.with(|cell| {
                cell.get().map(|r| r.dropped.load(Ordering::Relaxed)).unwrap_or(before_local)
            })
        });
        assert!(dropped >= 100, "expected >=100 drops, saw {dropped}");
        assert!(dropped_total() >= dropped);
        // the surviving RING_CAP events are still drainable
        let (events, _) = drain_all();
        let survived = events.iter().filter(|(_, e)| e.cat == "obs-overflow").count();
        assert_eq!(survived, RING_CAP);
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = test_guard();
        on_thread("obs-off", || {
            set_enabled(false);
            let _s = span("obs-disabled", "never");
            drop(_s);
            set_enabled(true);
        });
        let (events, _) = drain_all();
        assert!(events.iter().all(|(_, e)| e.cat != "obs-disabled"));
    }

    #[test]
    fn chrome_trace_is_valid_json_and_merges() {
        let _g = test_guard();
        set_enabled(true);
        on_thread("obs-json", || {
            let _s = span("obs-json", "work \"quoted\"\\slash");
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        let dir = std::env::temp_dir().join(format!("lowrank_obs_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p0 = dir.join("r0.json");
        let n = write_chrome_trace(&p0, 0).unwrap();
        assert!(n >= 1);
        let body = std::fs::read_to_string(&p0).unwrap();
        check_json(&body).unwrap();
        assert!(body.contains("\"ph\":\"X\""));
        assert!(body.contains("thread_name"));
        // merge two rank files (second may be event-free) into one array
        let p1 = dir.join("r1.json");
        write_chrome_trace(&p1, 1).unwrap();
        let merged = dir.join("merged.json");
        merge_chrome_traces(&merged, &[p0, p1]).unwrap();
        let body = std::fs::read_to_string(&merged).unwrap();
        check_json(&body).unwrap();
        assert!(body.contains("\"pid\":0"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
