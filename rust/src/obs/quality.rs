//! Estimator-quality telemetry: is the low-rank gradient estimator
//! actually delivering the paper's statistical guarantees on *this*
//! run?
//!
//! The paper's two claims are unbiasedness (E[lift(proj(G))] = G) and
//! a Theorem-2 MSE bound scaling as `c·n/r`. Both hinge on the frame
//! condition VᵀV = (c·n/r)·I that the samplers construct exactly — but
//! warm-started tracking refreshes (Cholesky-QR drift), rank shrinks,
//! and plain fp accumulation can all erode it silently. This module
//! turns the condition into two per-slot online gauges, computed from
//! the staged projected gradient dB = G·V and the live frame V —
//! read-only, no training state touched, no trainer RNG consumed:
//!
//! * **Unbiasedness sentinel** — the unbiased lift is `(1/c)·dB·Vᵀ`;
//!   re-projecting it through the same frame must reproduce dB exactly
//!   when VᵀV = (c·n/r)·I: `dB·(VᵀV)·r/(c·n) ≡ dB`. The sentinel is
//!   the normalized inner product ⟨lifted-reprojected − dB, U⟩ against
//!   a probe direction U drawn from a **dedicated** probe stream
//!   (never the trainer's RNG — trained bytes are identical with
//!   probing on or off, at any thread count). At an exact frame it is
//!   0 up to rounding; a drifting mean is a bias source by
//!   construction. [`BiasSentinel`] tracks the EMA and flags drift
//!   beyond a z-score threshold with a loud `[obs:quality] bias-drift`
//!   line.
//! * **Variance/MSE proxy** — `mse_ratio = ‖(1/c)·dB·Vᵀ‖² /
//!   ((n/(c·r))·‖dB‖²)`: the lifted gradient energy over what the
//!   Theorem-2-optimal frame would produce (`‖dB·Vᵀ‖² = (c·n/r)·‖dB‖²`
//!   exactly at VᵀV = (c·n/r)·I). Ratio ≈ 1 means the projection is
//!   performing at its optimum; deviation measures frame degradation
//!   inflating (or deflating) the estimator variance. Exported as the
//!   `mse_ratio[layer]` series and joined to the `[rank-adapt]`
//!   decision log as a context column (decisions themselves are driven
//!   by the lift residuals alone — see [`crate::optim::RankController`]).
//!
//! Both gauges are O(m·r² + n·r²) per probe via the trace identity
//! `‖dB·Vᵀ‖² = tr((dBᵀdB)·(VᵀV))` — no m×n buffer is ever formed. The
//! trainers run them at every lazy-update boundary (all slots) and,
//! with `--probe-every N`, every N steps on one rotating slot.

use crate::rng::Rng;

/// One probe's outputs for a single slot. See the module docs for the
/// exact definitions.
#[derive(Clone, Copy, Debug)]
pub struct SlotProbe {
    /// Normalized ⟨reproject(lift(dB)) − dB, U⟩ — 0 at an exact frame.
    pub sentinel: f64,
    /// Lifted-gradient energy over the Theorem-2 optimum — 1 at an
    /// exact frame.
    pub mse_ratio: f64,
}

/// Compute both gauges for one slot. `db` is the projected gradient
/// (row-major `[m, r]`), `v` the live frame (row-major `[n, r]`), `u`
/// the probe direction (`[m, r]`, same layout as `db`), `c` the
/// weak-unbiasedness scale. All accumulation is f64; the inputs are
/// only read.
pub fn probe_slot(
    db: &[f32],
    v: &[f32],
    m: usize,
    n: usize,
    r: usize,
    c: f64,
    u: &[f32],
) -> SlotProbe {
    assert_eq!(db.len(), m * r, "dB must be [m, r]");
    assert_eq!(v.len(), n * r, "V must be [n, r]");
    assert_eq!(u.len(), m * r, "probe direction must match dB");
    let tiny = 1e-300f64;
    // w = VᵀV (r×r) — the frame Gram whose deviation from (c·n/r)·I is
    // exactly what both gauges measure
    let mut w = vec![0.0f64; r * r];
    for row in 0..n {
        let vr = &v[row * r..row * r + r];
        for i in 0..r {
            let vi = vr[i] as f64;
            for j in 0..r {
                w[i * r + j] += vi * vr[j] as f64;
            }
        }
    }
    // g = dBᵀdB (r×r) for the trace identity, plus ‖dB‖² and the
    // sentinel inner product in one pass over the m rows
    let scale = r as f64 / (c * n as f64);
    let mut g = vec![0.0f64; r * r];
    let mut db_sq = 0.0f64;
    let mut u_sq = 0.0f64;
    let mut num = 0.0f64;
    let mut drow = vec![0.0f64; r];
    for row in 0..m {
        let dr = &db[row * r..row * r + r];
        let ur = &u[row * r..row * r + r];
        for i in 0..r {
            let di = dr[i] as f64;
            db_sq += di * di;
            for j in 0..r {
                g[i * r + j] += di * dr[j] as f64;
            }
        }
        // drow = dr · (w·scale): the row of dB re-projected through the
        // lifted estimate; at an exact frame w·scale = I and drow ≡ dr
        for (j, d) in drow.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for (k, &dk) in dr.iter().enumerate() {
                acc += dk as f64 * w[k * r + j];
            }
            *d = acc * scale;
        }
        for j in 0..r {
            let uj = ur[j] as f64;
            u_sq += uj * uj;
            num += (drow[j] - dr[j] as f64) * uj;
        }
    }
    let sentinel = num / ((db_sq * u_sq).sqrt() + tiny);
    // ‖(1/c)·dB·Vᵀ‖² = tr((dBᵀdB)·(VᵀV))/c² over the Theorem-2 value
    // (n/(c·r))·‖dB‖²
    let lift_sq: f64 = g.iter().zip(w.iter()).map(|(a, b)| a * b).sum::<f64>() / (c * c);
    let bound = db_sq * n as f64 / (c * r as f64);
    let mse_ratio = lift_sq / (bound + tiny);
    SlotProbe { sentinel, mse_ratio }
}

/// Online drift detector for the unbiasedness sentinel: exponential
/// moving estimates of the sentinel's mean and variance, flagging when
/// the mean sits further from 0 than `z_threshold` standard errors.
/// The variance floor keeps a perfectly-constant (e.g. exactly zero)
/// series from dividing by zero; `min_obs` suppresses flags before the
/// EMAs have burned in.
#[derive(Clone, Debug)]
pub struct BiasSentinel {
    mean: f64,
    var: f64,
    count: u64,
    alpha: f64,
    z_threshold: f64,
    min_obs: u64,
}

impl Default for BiasSentinel {
    fn default() -> Self {
        BiasSentinel { mean: 0.0, var: 0.0, count: 0, alpha: 0.2, z_threshold: 4.0, min_obs: 8 }
    }
}

impl BiasSentinel {
    pub fn new(alpha: f64, z_threshold: f64, min_obs: u64) -> Self {
        BiasSentinel { mean: 0.0, var: 0.0, count: 0, alpha, z_threshold, min_obs }
    }

    /// Current EMA of the sentinel.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Current z-score of the EMA against its own spread (0 until the
    /// second observation).
    pub fn z(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        // the EMA averages ~1/alpha recent points, so its standard
        // error is sqrt(var·alpha); floor the variance at a fraction of
        // mean² so exactly-repeating drift still scores finitely
        let se = (self.var.max(self.mean * self.mean * 1e-12) * self.alpha).sqrt();
        if se <= 0.0 {
            if self.mean == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            self.mean / se
        }
    }

    /// Fold one sentinel observation in; returns `Some(z)` when the
    /// drift crosses the threshold (the caller logs the loud line).
    pub fn observe(&mut self, x: f64) -> Option<f64> {
        if !x.is_finite() {
            return None;
        }
        self.count += 1;
        if self.count == 1 {
            self.mean = x;
            return None;
        }
        let d = x - self.mean;
        self.mean += self.alpha * d;
        self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d);
        let z = self.z();
        (self.count >= self.min_obs && z.abs() > self.z_threshold).then_some(z)
    }
}

/// Per-run quality-probe state: one [`BiasSentinel`] per slot, the
/// dedicated probe RNG, the rotating `--probe-every` schedule, and the
/// precomputed metric-key strings (`mse_ratio[name]` /
/// `bias_sentinel[name]` — the series the acceptance JSONL carries).
pub struct QualityProbe {
    every: u64,
    rng: Rng,
    names: Vec<String>,
    mse_keys: Vec<String>,
    bias_keys: Vec<String>,
    sentinels: Vec<BiasSentinel>,
    last_mse: Vec<f64>,
    /// Probe-direction scratch, reused across probes.
    u: Vec<f32>,
}

/// Stream-id XOR for the dedicated probe RNG: the probe draws must
/// never touch the trainer/data/task streams, so trained bytes are
/// bitwise identical with probing on or off.
pub const PROBE_STREAM: u64 = 0x9B0B_E5EE;

impl QualityProbe {
    /// `every` = `--probe-every` (0 disables the rotating probe steps;
    /// the lazy-update boundary gauges still run whenever metrics are
    /// enabled). The probe RNG derives from `seed ^ PROBE_STREAM`.
    pub fn new(seed: u64, every: u64, names: Vec<String>) -> Self {
        let mse_keys = names.iter().map(|n| format!("mse_ratio[{n}]")).collect();
        let bias_keys = names.iter().map(|n| format!("bias_sentinel[{n}]")).collect();
        let n = names.len();
        QualityProbe {
            every,
            rng: Rng::new(seed ^ PROBE_STREAM),
            names,
            mse_keys,
            bias_keys,
            sentinels: vec![BiasSentinel::default(); n],
            last_mse: vec![f64::NAN; n],
            u: Vec::new(),
        }
    }

    /// Should any probing run at all this step? Boundary gauges ride
    /// the metrics gate; the rotating probe step additionally needs
    /// `--probe-every`.
    pub fn active(&self) -> bool {
        self.every > 0 || crate::obs::metrics::enabled()
    }

    pub fn n_slots(&self) -> usize {
        self.names.len()
    }

    /// The rotating-slot schedule: `Some(slot)` when `step` is a probe
    /// step (`--probe-every` divides it), rotating over the slots so
    /// every layer is probed in turn.
    pub fn rotating_slot(&self, step: u64) -> Option<usize> {
        if self.every == 0 || self.names.is_empty() || step % self.every != 0 {
            return None;
        }
        Some(((step / self.every) % self.names.len() as u64) as usize)
    }

    /// Draw a fresh probe direction of `len` elements from the
    /// dedicated stream into the reusable scratch.
    pub fn draw_direction(&mut self, len: usize) -> &[f32] {
        self.u.clear();
        self.u.reserve(len);
        for _ in 0..len {
            self.u.push(self.rng.normal() as f32);
        }
        &self.u
    }

    /// Most recent `mse_ratio` for slot `i` (NaN before the first
    /// probe) — the context column the rank-adaptation log prints.
    pub fn last_mse(&self, i: usize) -> f64 {
        self.last_mse.get(i).copied().unwrap_or(f64::NAN)
    }

    /// Fold one probe result in: update the slot's sentinel, export
    /// both series (when metrics are on), and print the loud
    /// `[obs:quality] bias-drift` line on a z-threshold crossing.
    pub fn observe(&mut self, i: usize, step: u64, probe: SlotProbe) {
        self.last_mse[i] = probe.mse_ratio;
        if crate::obs::metrics::enabled() {
            crate::obs::metrics::record_value(&self.mse_keys[i], probe.mse_ratio);
            crate::obs::metrics::record_value(&self.bias_keys[i], probe.sentinel);
        }
        if let Some(z) = self.sentinels[i].observe(probe.sentinel) {
            eprintln!(
                "[obs:quality] bias-drift {}: sentinel ema {:.3e} is z={z:.1} from 0 at step \
                 {step} (mse_ratio {:.3}) — the estimator may be biased (frame degradation?)",
                self.names[i],
                self.sentinels[i].mean(),
                probe.mse_ratio,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an exact Theorem-2 frame V = √(c·n/r)·Q with orthonormal
    /// columns Q (here: r distinct standard basis columns — trivially
    /// orthonormal, no QR needed).
    fn exact_frame(n: usize, r: usize, c: f64) -> Vec<f32> {
        let s = (c * n as f64 / r as f64).sqrt() as f32;
        let mut v = vec![0.0f32; n * r];
        for j in 0..r {
            v[j * r + j] = s; // row j, col j
        }
        v
    }

    #[test]
    fn exact_frame_probes_at_optimum() {
        let (m, n, r, c) = (6usize, 24usize, 3usize, 1.0f64);
        let v = exact_frame(n, r, c);
        let db: Vec<f32> = (0..m * r).map(|k| ((k as f32) * 0.37).sin()).collect();
        let u: Vec<f32> = (0..m * r).map(|k| ((k as f32) * 0.11).cos()).collect();
        let p = probe_slot(&db, &v, m, n, r, c, &u);
        assert!(p.sentinel.abs() < 1e-6, "sentinel {} at an exact frame", p.sentinel);
        assert!((p.mse_ratio - 1.0).abs() < 1e-6, "mse_ratio {} at an exact frame", p.mse_ratio);
    }

    #[test]
    fn degraded_frame_moves_both_gauges() {
        let (m, n, r, c) = (6usize, 24usize, 3usize, 1.0f64);
        let mut v = exact_frame(n, r, c);
        // shrink one frame column by 2x: VᵀV loses (c·n/r) on that
        // diagonal entry — a bias and a variance deficit
        for row in 0..n {
            v[row * r] *= 0.5;
        }
        let db: Vec<f32> = (0..m * r).map(|k| ((k as f32) * 0.37).sin()).collect();
        let u: Vec<f32> = (0..m * r).map(|k| ((k as f32) * 0.11).cos()).collect();
        let p = probe_slot(&db, &v, m, n, r, c, &u);
        assert!(p.sentinel.abs() > 1e-4, "sentinel {} must move", p.sentinel);
        assert!((p.mse_ratio - 1.0).abs() > 1e-3, "mse_ratio {} must move", p.mse_ratio);
    }

    #[test]
    fn weak_unbiasedness_scale_is_honoured() {
        // c != 1: the exact frame carries the c into VᵀV = (c·n/r)·I
        // and both gauges must still sit at the optimum
        let (m, n, r, c) = (5usize, 32usize, 4usize, 2.0f64);
        let v = exact_frame(n, r, c);
        let db: Vec<f32> = (0..m * r).map(|k| 0.1 + k as f32 * 0.01).collect();
        let u: Vec<f32> = (0..m * r).map(|k| 1.0 - k as f32 * 0.02).collect();
        let p = probe_slot(&db, &v, m, n, r, c, &u);
        assert!(p.sentinel.abs() < 1e-6, "sentinel {}", p.sentinel);
        assert!((p.mse_ratio - 1.0).abs() < 1e-6, "mse_ratio {}", p.mse_ratio);
    }

    #[test]
    fn sentinel_flags_persistent_drift_but_not_noise() {
        let mut s = BiasSentinel::default();
        let mut rng = Rng::new(11);
        // zero-mean noise: no flag over a long window
        let mut flagged = false;
        for _ in 0..200 {
            flagged |= s.observe(rng.normal() * 1e-3).is_some();
        }
        assert!(!flagged, "zero-mean sentinel must not flag (z={})", s.z());
        // persistent one-sided drift: must flag
        let mut s = BiasSentinel::default();
        let mut hit = None;
        for k in 0..100 {
            if let Some(z) = s.observe(1e-3 + rng.normal() * 1e-5) {
                hit = Some((k, z));
                break;
            }
        }
        let (k, z) = hit.expect("persistent drift must cross the z threshold");
        assert!(z.abs() > 4.0, "z={z} at obs {k}");
    }

    #[test]
    fn rotating_schedule_covers_every_slot() {
        let q = QualityProbe::new(7, 4, vec!["a".into(), "b".into(), "c".into()]);
        assert_eq!(q.rotating_slot(0), Some(0));
        assert_eq!(q.rotating_slot(1), None);
        assert_eq!(q.rotating_slot(4), Some(1));
        assert_eq!(q.rotating_slot(8), Some(2));
        assert_eq!(q.rotating_slot(12), Some(0));
        let off = QualityProbe::new(7, 0, vec!["a".into()]);
        assert_eq!(off.rotating_slot(0), None);
    }

    #[test]
    fn probe_direction_is_deterministic_per_seed() {
        let mut a = QualityProbe::new(42, 2, vec!["x".into()]);
        let mut b = QualityProbe::new(42, 2, vec!["x".into()]);
        assert_eq!(a.draw_direction(16), b.draw_direction(16));
        let mut c = QualityProbe::new(43, 2, vec!["x".into()]);
        assert_ne!(a.draw_direction(16), c.draw_direction(16));
    }
}
