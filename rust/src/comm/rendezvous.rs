//! File-system rendezvous: how `world` independent processes find each
//! other, agree on ranks, and exchange listener addresses before any
//! socket is connected.
//!
//! The rendezvous root is a shared directory (the `launch` runner
//! creates a fresh one per run and exports it as `LOWRANK_COMM_RDZV`).
//! Three file families live in it:
//!
//! * `claim-<rank>` — rank assignment. A process with an explicit rank
//!   (from `LOWRANK_COMM_RANK`) claims its slot; a process without one
//!   atomically claims the lowest free slot via `create_new` (O_EXCL),
//!   so concurrent joiners can never collide on a rank.
//! * `addr-<rank>` — the claimed rank's listener address (`tcp://…` or
//!   `unix://…`), written to a temp name and renamed so readers never
//!   observe a half-written address. Every process polls until all
//!   `world` addresses exist, then returns the full table.
//! * `run-token` — the liveness stamp. When the joiners share a run
//!   token (`LOWRANK_COMM_TOKEN`, set by the `launch` runner), the
//!   rank-0 claimant publishes it (atomically, create-if-absent) and
//!   every other rank verifies it before trusting any claim or address
//!   file. A directory still populated by a **crashed or concurrent
//!   run** therefore fails with a loud "stale rendezvous dir" error at
//!   join time — instead of the old failure mode, where fresh ranks
//!   would poll dead address files until the full comm timeout.
//!
//! Everything is bounded by the configured timeout: a missing peer is a
//! loud "rendezvous timed out" error naming the ranks still absent.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

const TOKEN_FILE: &str = "run-token";

/// Rendezvous handle over a shared directory.
#[derive(Clone, Debug)]
pub struct Rendezvous {
    dir: PathBuf,
    world: usize,
    timeout: Duration,
    /// Shared run token; `None` disables the stale-dir stamp (callers
    /// that own a fresh private dir, e.g. unit tests and benches).
    run_token: Option<String>,
}

impl Rendezvous {
    pub fn new(dir: impl Into<PathBuf>, world: usize, timeout: Duration) -> Result<Rendezvous> {
        Self::with_token(dir, world, timeout, None)
    }

    pub fn with_token(
        dir: impl Into<PathBuf>,
        world: usize,
        timeout: Duration,
        run_token: Option<String>,
    ) -> Result<Rendezvous> {
        if world == 0 {
            bail!("comm world size must be >= 1");
        }
        if let Some(token) = &run_token {
            if token.is_empty() || token.contains(|c: char| c == '\n' || c == '\r') {
                bail!("comm run token must be a non-empty single line");
            }
        }
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating rendezvous dir {dir:?}"))?;
        Ok(Rendezvous { dir, world, timeout, run_token })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn world(&self) -> usize {
        self.world
    }

    fn claim_path(&self, rank: usize) -> PathBuf {
        self.dir.join(format!("claim-{rank}"))
    }

    fn addr_path(&self, rank: usize) -> PathBuf {
        self.dir.join(format!("addr-{rank}"))
    }

    fn token_path(&self) -> PathBuf {
        self.dir.join(TOKEN_FILE)
    }

    /// Claim a rank. `want = Some(r)` claims exactly `r` (failing if a
    /// different process got there first); `None` claims the lowest
    /// free slot atomically. With a run token configured, rank 0 stamps
    /// the directory and every other rank verifies the stamp, so claims
    /// against a stale directory fail loudly here rather than hanging
    /// in the address poll.
    pub fn claim_rank(&self, want: Option<usize>) -> Result<usize> {
        if let Some(rank) = want {
            if rank >= self.world {
                bail!("rank {rank} is out of range for world size {}", self.world);
            }
            // claim first, stamp after: stamping first would let rank 0
            // freshly stamp a dir whose claim-0 belongs to a dead run,
            // turning the failure into an unexplained "already taken"
            // (and leaving the other ranks trusting the new stamp)
            if let Err(e) = claim_file(&self.claim_path(rank)) {
                return Err(self.enrich_claim_conflict(rank, e));
            }
            self.stamp_or_verify(rank)?;
            return Ok(rank);
        }
        for rank in 0..self.world {
            if claim_file(&self.claim_path(rank)).is_ok() {
                self.stamp_or_verify(rank)?;
                return Ok(rank);
            }
        }
        if let Some(found) = self.token_mismatch() {
            bail!(
                "stale rendezvous dir {:?}: every rank slot is claimed and the run token \
                 there ({found:?}) is not this run's — a crashed run left its files behind; \
                 clear the directory or point at a fresh one",
                self.dir
            );
        }
        bail!("no free rank slot: all {} ranks are already claimed", self.world)
    }

    /// Name the true cause of a claim conflict: a stale directory when
    /// the run token says so (wrong token, or claims with no stamp at
    /// all), else the plain duplicate-claim error.
    fn enrich_claim_conflict(&self, rank: usize, err: anyhow::Error) -> anyhow::Error {
        if let Some(found) = self.token_mismatch() {
            return anyhow::anyhow!(
                "stale rendezvous dir {:?}: rank {rank}'s slot is already claimed and the \
                 run token there ({found:?}) is not this run's — a crashed run left its \
                 files behind; clear the directory or point at a fresh one",
                self.dir
            );
        }
        if self.run_token.is_some() && !self.token_path().exists() {
            return anyhow::anyhow!(
                "stale rendezvous dir {:?}? rank {rank}'s slot is already claimed but no \
                 run token is stamped — either a crashed (or pre-token) run left its files \
                 behind, or a duplicate rank {rank} raced the leader's stamp; clear the \
                 directory or point at a fresh one",
                self.dir
            );
        }
        err.context(format!("claiming comm rank {rank} (already taken?)"))
    }

    /// Rank 0 publishes the run token (atomic create-if-absent); other
    /// ranks poll for it and verify it matches their own. No-op when no
    /// token is configured.
    fn stamp_or_verify(&self, rank: usize) -> Result<()> {
        let Some(token) = &self.run_token else { return Ok(()) };
        let path = self.token_path();
        if rank == 0 {
            // write the content to a private temp file, then hard-link
            // it into place: link fails with EEXIST if a token already
            // exists, so a stale stamp is never silently overwritten
            // and readers never observe a half-written token.
            let tmp = self.dir.join(format!(".run-token.{}", std::process::id()));
            std::fs::write(&tmp, token).with_context(|| format!("writing {tmp:?}"))?;
            let linked = std::fs::hard_link(&tmp, &path);
            let _ = std::fs::remove_file(&tmp);
            match linked {
                Ok(()) => Ok(()),
                Err(_) => self.check_token(token, &path),
            }
        } else {
            // wait for rank 0's stamp (bounded), then verify
            let deadline = Instant::now() + self.timeout;
            loop {
                if path.exists() {
                    return self.check_token(token, &path);
                }
                if Instant::now() >= deadline {
                    bail!(
                        "timed out after {:?} waiting for the run token in {:?} — rank 0 \
                         never stamped it (stale rendezvous dir blocking its claim, or the \
                         leader died before rendezvous)",
                        self.timeout,
                        self.dir
                    );
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }

    fn check_token(&self, expected: &str, path: &Path) -> Result<()> {
        let found = std::fs::read_to_string(path)
            .with_context(|| format!("reading run token {path:?}"))?;
        if found.trim() == expected {
            return Ok(());
        }
        bail!(
            "stale rendezvous dir {:?}: its run token is {:?}, this run's is {expected:?} — \
             a crashed (or concurrent) run owns the directory; clear it or point at a fresh one",
            self.dir,
            found.trim()
        )
    }

    /// The stale-dir probe: `Some(found)` when a token file exists and
    /// differs from this run's token.
    fn token_mismatch(&self) -> Option<String> {
        let expected = self.run_token.as_deref()?;
        let found = std::fs::read_to_string(self.token_path()).ok()?;
        (found.trim() != expected).then(|| found.trim().to_string())
    }

    /// Publish this rank's listener address and wait for every peer's.
    /// Returns the full address table, indexed by rank.
    pub fn exchange(&self, rank: usize, addr: &str) -> Result<Vec<String>> {
        let tmp = self.dir.join(format!(".addr-{rank}.tmp"));
        std::fs::write(&tmp, addr).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, self.addr_path(rank))
            .with_context(|| format!("publishing address for rank {rank}"))?;

        let deadline = Instant::now() + self.timeout;
        let mut table = vec![None::<String>; self.world];
        loop {
            let mut missing = Vec::new();
            for (r, slot) in table.iter_mut().enumerate() {
                if slot.is_none() {
                    match std::fs::read_to_string(self.addr_path(r)) {
                        Ok(s) => *slot = Some(s.trim().to_string()),
                        Err(_) => missing.push(r),
                    }
                }
            }
            if missing.is_empty() {
                return Ok(table.into_iter().map(|s| s.expect("all slots filled")).collect());
            }
            if Instant::now() >= deadline {
                bail!(
                    "rendezvous timed out after {:?}: ranks {missing:?} never published \
                     an address under {:?}",
                    self.timeout,
                    self.dir
                );
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// Atomic create-new claim (O_EXCL): exactly one concurrent caller wins.
fn claim_file(path: &Path) -> Result<()> {
    std::fs::OpenOptions::new()
        .write(true)
        .create_new(true)
        .open(path)
        .map(|_| ())
        .with_context(|| format!("claim file {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lowrank_comm_rdzv_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn concurrent_claims_get_distinct_ranks() {
        let dir = fresh_dir("claims");
        let rdzv = Rendezvous::new(&dir, 4, Duration::from_secs(5)).unwrap();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let rdzv = rdzv.clone();
            handles.push(std::thread::spawn(move || rdzv.claim_rank(None).unwrap()));
        }
        let mut ranks: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        ranks.sort_unstable();
        assert_eq!(ranks, vec![0, 1, 2, 3]);
        // a fifth joiner finds no slot
        assert!(rdzv.claim_rank(None).is_err());
    }

    #[test]
    fn explicit_claim_conflicts_are_loud() {
        let dir = fresh_dir("explicit");
        let rdzv = Rendezvous::new(&dir, 2, Duration::from_secs(1)).unwrap();
        assert_eq!(rdzv.claim_rank(Some(1)).unwrap(), 1);
        assert!(rdzv.claim_rank(Some(1)).is_err());
        assert!(rdzv.claim_rank(Some(7)).is_err());
        assert_eq!(rdzv.claim_rank(None).unwrap(), 0);
    }

    #[test]
    fn exchange_returns_the_full_table() {
        let dir = fresh_dir("exchange");
        let rdzv = Rendezvous::new(&dir, 3, Duration::from_secs(5)).unwrap();
        let mut handles = Vec::new();
        for rank in 0..3 {
            let rdzv = rdzv.clone();
            handles.push(std::thread::spawn(move || {
                rdzv.exchange(rank, &format!("tcp://127.0.0.1:{}", 9000 + rank)).unwrap()
            }));
        }
        for h in handles {
            let table = h.join().unwrap();
            assert_eq!(
                table,
                vec![
                    "tcp://127.0.0.1:9000".to_string(),
                    "tcp://127.0.0.1:9001".to_string(),
                    "tcp://127.0.0.1:9002".to_string(),
                ]
            );
        }
    }

    #[test]
    fn missing_peer_times_out_with_the_absent_ranks_named() {
        let dir = fresh_dir("timeout");
        let rdzv = Rendezvous::new(&dir, 2, Duration::from_millis(80)).unwrap();
        let err = rdzv.exchange(0, "tcp://127.0.0.1:1").unwrap_err().to_string();
        assert!(err.contains("timed out") && err.contains("[1]"), "{err}");
    }

    #[test]
    fn tokened_claims_work_end_to_end() {
        let dir = fresh_dir("token_ok");
        let token = Some("run-A".to_string());
        let rdzv =
            Rendezvous::with_token(&dir, 3, Duration::from_secs(5), token.clone()).unwrap();
        let mut handles = Vec::new();
        for _ in 0..3 {
            let rdzv = rdzv.clone();
            handles.push(std::thread::spawn(move || rdzv.claim_rank(None).unwrap()));
        }
        let mut ranks: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        ranks.sort_unstable();
        assert_eq!(ranks, vec![0, 1, 2]);
        assert_eq!(
            std::fs::read_to_string(dir.join(TOKEN_FILE)).unwrap(),
            "run-A",
            "rank 0 stamps the dir with the run token"
        );
    }

    #[test]
    fn stale_dir_is_a_loud_error_not_a_hang() {
        let dir = fresh_dir("token_stale");
        // a "crashed run" left its full rendezvous state behind
        let old = Rendezvous::with_token(
            &dir,
            2,
            Duration::from_secs(1),
            Some("dead-run".to_string()),
        )
        .unwrap();
        assert_eq!(old.claim_rank(Some(0)).unwrap(), 0);
        std::fs::write(dir.join("addr-0"), "tcp://127.0.0.1:1").unwrap();

        let fresh = Rendezvous::with_token(
            &dir,
            2,
            Duration::from_millis(120),
            Some("new-run".to_string()),
        )
        .unwrap();
        // explicit rank 0 rejoin: the stale stamp is detected before
        // the claim-conflict can mislead
        let err = fresh.claim_rank(Some(0)).unwrap_err().to_string();
        assert!(err.contains("stale rendezvous dir"), "{err}");
        // auto-claim lands on a free slot but must refuse the stale stamp
        let err = fresh.claim_rank(None).unwrap_err().to_string();
        assert!(err.contains("stale rendezvous dir"), "{err}");
    }

    #[test]
    fn orphaned_leader_slot_times_out_with_a_stale_hint() {
        let dir = fresh_dir("token_orphan");
        // stale claim-0 but no token: the old run predates tokens or
        // crashed before stamping — rank 0 of the new run can't claim,
        // so the non-leaders' token wait must fail in bounded time
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("claim-0"), "").unwrap();
        let rdzv = Rendezvous::with_token(
            &dir,
            2,
            Duration::from_millis(100),
            Some("new-run".to_string()),
        )
        .unwrap();
        let t0 = Instant::now();
        let err = rdzv.claim_rank(Some(1)).unwrap_err().to_string();
        assert!(t0.elapsed() < Duration::from_secs(5), "token wait was unbounded");
        assert!(err.contains("stale rendezvous dir") || err.contains("run token"), "{err}");
        // rank 0 itself must not freshly stamp the dead run's dir: its
        // claim conflict names the stale dir (claims present, no stamp)
        let err = rdzv.claim_rank(Some(0)).unwrap_err().to_string();
        assert!(err.contains("stale rendezvous dir"), "{err}");
        assert!(!dir.join(TOKEN_FILE).exists(), "the conflicting claim must not be stamped");
    }

    #[test]
    fn untokened_runs_keep_the_old_behaviour() {
        let dir = fresh_dir("token_none");
        let rdzv = Rendezvous::new(&dir, 2, Duration::from_secs(1)).unwrap();
        assert_eq!(rdzv.claim_rank(Some(0)).unwrap(), 0);
        assert!(!dir.join(TOKEN_FILE).exists());
    }
}
