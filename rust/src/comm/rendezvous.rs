//! File-system rendezvous: how `world` independent processes find each
//! other, agree on ranks, and exchange listener addresses before any
//! socket is connected.
//!
//! The rendezvous root is a shared directory (the `launch` runner
//! creates a fresh one per run and exports it as `LOWRANK_COMM_RDZV`).
//! Two file families live in it:
//!
//! * `claim-<rank>` — rank assignment. A process with an explicit rank
//!   (from `LOWRANK_COMM_RANK`) claims its slot; a process without one
//!   atomically claims the lowest free slot via `create_new` (O_EXCL),
//!   so concurrent joiners can never collide on a rank.
//! * `addr-<rank>` — the claimed rank's listener address (`tcp://…` or
//!   `unix://…`), written to a temp name and renamed so readers never
//!   observe a half-written address. Every process polls until all
//!   `world` addresses exist, then returns the full table.
//!
//! Everything is bounded by the configured timeout: a missing peer is a
//! loud "rendezvous timed out" error naming the ranks still absent.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Rendezvous handle over a shared directory.
#[derive(Clone, Debug)]
pub struct Rendezvous {
    dir: PathBuf,
    world: usize,
    timeout: Duration,
}

impl Rendezvous {
    pub fn new(dir: impl Into<PathBuf>, world: usize, timeout: Duration) -> Result<Rendezvous> {
        if world == 0 {
            bail!("comm world size must be >= 1");
        }
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating rendezvous dir {dir:?}"))?;
        Ok(Rendezvous { dir, world, timeout })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn world(&self) -> usize {
        self.world
    }

    fn claim_path(&self, rank: usize) -> PathBuf {
        self.dir.join(format!("claim-{rank}"))
    }

    fn addr_path(&self, rank: usize) -> PathBuf {
        self.dir.join(format!("addr-{rank}"))
    }

    /// Claim a rank. `want = Some(r)` claims exactly `r` (failing if a
    /// different process got there first); `None` claims the lowest
    /// free slot atomically.
    pub fn claim_rank(&self, want: Option<usize>) -> Result<usize> {
        if let Some(rank) = want {
            if rank >= self.world {
                bail!("rank {rank} is out of range for world size {}", self.world);
            }
            claim_file(&self.claim_path(rank))
                .with_context(|| format!("claiming comm rank {rank} (already taken?)"))?;
            return Ok(rank);
        }
        for rank in 0..self.world {
            if claim_file(&self.claim_path(rank)).is_ok() {
                return Ok(rank);
            }
        }
        bail!("no free rank slot: all {} ranks are already claimed", self.world)
    }

    /// Publish this rank's listener address and wait for every peer's.
    /// Returns the full address table, indexed by rank.
    pub fn exchange(&self, rank: usize, addr: &str) -> Result<Vec<String>> {
        let tmp = self.dir.join(format!(".addr-{rank}.tmp"));
        std::fs::write(&tmp, addr).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, self.addr_path(rank))
            .with_context(|| format!("publishing address for rank {rank}"))?;

        let deadline = Instant::now() + self.timeout;
        let mut table = vec![None::<String>; self.world];
        loop {
            let mut missing = Vec::new();
            for (r, slot) in table.iter_mut().enumerate() {
                if slot.is_none() {
                    match std::fs::read_to_string(self.addr_path(r)) {
                        Ok(s) => *slot = Some(s.trim().to_string()),
                        Err(_) => missing.push(r),
                    }
                }
            }
            if missing.is_empty() {
                return Ok(table.into_iter().map(|s| s.expect("all slots filled")).collect());
            }
            if Instant::now() >= deadline {
                bail!(
                    "rendezvous timed out after {:?}: ranks {missing:?} never published \
                     an address under {:?}",
                    self.timeout,
                    self.dir
                );
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// Atomic create-new claim (O_EXCL): exactly one concurrent caller wins.
fn claim_file(path: &Path) -> Result<()> {
    std::fs::OpenOptions::new()
        .write(true)
        .create_new(true)
        .open(path)
        .map(|_| ())
        .with_context(|| format!("claim file {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lowrank_comm_rdzv_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn concurrent_claims_get_distinct_ranks() {
        let dir = fresh_dir("claims");
        let rdzv = Rendezvous::new(&dir, 4, Duration::from_secs(5)).unwrap();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let rdzv = rdzv.clone();
            handles.push(std::thread::spawn(move || rdzv.claim_rank(None).unwrap()));
        }
        let mut ranks: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        ranks.sort_unstable();
        assert_eq!(ranks, vec![0, 1, 2, 3]);
        // a fifth joiner finds no slot
        assert!(rdzv.claim_rank(None).is_err());
    }

    #[test]
    fn explicit_claim_conflicts_are_loud() {
        let dir = fresh_dir("explicit");
        let rdzv = Rendezvous::new(&dir, 2, Duration::from_secs(1)).unwrap();
        assert_eq!(rdzv.claim_rank(Some(1)).unwrap(), 1);
        assert!(rdzv.claim_rank(Some(1)).is_err());
        assert!(rdzv.claim_rank(Some(7)).is_err());
        assert_eq!(rdzv.claim_rank(None).unwrap(), 0);
    }

    #[test]
    fn exchange_returns_the_full_table() {
        let dir = fresh_dir("exchange");
        let rdzv = Rendezvous::new(&dir, 3, Duration::from_secs(5)).unwrap();
        let mut handles = Vec::new();
        for rank in 0..3 {
            let rdzv = rdzv.clone();
            handles.push(std::thread::spawn(move || {
                rdzv.exchange(rank, &format!("tcp://127.0.0.1:{}", 9000 + rank)).unwrap()
            }));
        }
        for h in handles {
            let table = h.join().unwrap();
            assert_eq!(
                table,
                vec![
                    "tcp://127.0.0.1:9000".to_string(),
                    "tcp://127.0.0.1:9001".to_string(),
                    "tcp://127.0.0.1:9002".to_string(),
                ]
            );
        }
    }

    #[test]
    fn missing_peer_times_out_with_the_absent_ranks_named() {
        let dir = fresh_dir("timeout");
        let rdzv = Rendezvous::new(&dir, 2, Duration::from_millis(80)).unwrap();
        let err = rdzv.exchange(0, "tcp://127.0.0.1:1").unwrap_err().to_string();
        assert!(err.contains("timed out") && err.contains("[1]"), "{err}");
    }
}
