//! Socket transport for the comm subsystem: TCP (loopback or real
//! network) and Unix-domain sockets behind one [`Conn`] / [`Listener`]
//! pair, with explicit read/write timeouts so a dead peer surfaces as
//! an error, never a hang.
//!
//! All reads and writes go through `&Conn` (the standard library
//! implements `Read`/`Write` for `&TcpStream` / `&UnixStream`), so one
//! connection can be sending on a helper thread while the owning thread
//! receives — the full-duplex overlap the ring collectives rely on.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::obs::metrics;

/// Which socket family a run uses. Unix-domain is the default for
/// single-host `launch` trees (lower latency, no port allocation); TCP
/// works everywhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    Tcp,
    #[cfg(unix)]
    Unix,
}

impl TransportKind {
    /// Parse `"tcp"` / `"unix"`. On non-Unix platforms `"unix"` is
    /// rejected at parse time rather than failing at bind time.
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s {
            "tcp" => Ok(TransportKind::Tcp),
            #[cfg(unix)]
            "unix" => Ok(TransportKind::Unix),
            other => bail!("unknown comm transport {other:?} (expected tcp or unix)"),
        }
    }

    /// Platform default: Unix-domain where available, else TCP.
    pub fn default_for_host() -> TransportKind {
        #[cfg(unix)]
        {
            TransportKind::Unix
        }
        #[cfg(not(unix))]
        {
            TransportKind::Tcp
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Tcp => "tcp",
            #[cfg(unix)]
            TransportKind::Unix => "unix",
        }
    }
}

/// A parsed peer address — splitting parse from dial keeps permanent
/// errors (bad address) out of the transient-retry loop.
#[derive(Clone, Debug)]
enum PeerAddr {
    Tcp(std::net::SocketAddr),
    #[cfg(unix)]
    Unix(PathBuf),
}

impl PeerAddr {
    fn parse(addr: &str) -> Result<PeerAddr> {
        if let Some(rest) = addr.strip_prefix("tcp://") {
            let sock = rest
                .parse()
                .with_context(|| format!("bad tcp peer address {rest:?}"))?;
            return Ok(PeerAddr::Tcp(sock));
        }
        #[cfg(unix)]
        if let Some(path) = addr.strip_prefix("unix://") {
            return Ok(PeerAddr::Unix(PathBuf::from(path)));
        }
        bail!("unparseable comm peer address {addr:?} (expected tcp://host:port or unix://path)")
    }

    fn dial(&self, io_timeout: Duration) -> Result<Conn> {
        match self {
            PeerAddr::Tcp(sock) => {
                let stream = TcpStream::connect_timeout(sock, io_timeout)?;
                stream.set_nodelay(true)?;
                Ok(Conn::Tcp(stream))
            }
            #[cfg(unix)]
            PeerAddr::Unix(path) => {
                let stream = UnixStream::connect(path)?;
                Ok(Conn::Unix(stream))
            }
        }
    }
}

/// One established peer connection.
#[derive(Debug)]
pub enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    /// Dial a peer address (as published through the rendezvous),
    /// retrying connection attempts until `deadline` — the peer's
    /// listener is bound before its address is published, so retries
    /// only cover transient connect races, not an open-ended wait. A
    /// malformed address is permanent and fails immediately.
    pub fn connect(addr: &str, deadline: Instant, io_timeout: Duration) -> Result<Conn> {
        let target = PeerAddr::parse(addr)?;
        loop {
            let attempt = target.dial(io_timeout);
            match attempt {
                Ok(conn) => {
                    conn.set_timeouts(io_timeout)?;
                    return Ok(conn);
                }
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e).with_context(|| format!("connecting to comm peer {addr}"));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }
    }

    /// Apply read/write timeouts — the bound that turns a dead peer
    /// into an error instead of a hang.
    pub fn set_timeouts(&self, timeout: Duration) -> Result<()> {
        match self {
            Conn::Tcp(s) => {
                s.set_read_timeout(Some(timeout))?;
                s.set_write_timeout(Some(timeout))?;
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                s.set_read_timeout(Some(timeout))?;
                s.set_write_timeout(Some(timeout))?;
            }
        }
        Ok(())
    }

    /// Blocking full write through a shared reference (full-duplex with
    /// concurrent reads — `Write` is implemented for `&TcpStream` /
    /// `&UnixStream`). Timeouts and closed peers surface as errors.
    pub fn write_all(&self, buf: &[u8]) -> Result<()> {
        let res = match self {
            Conn::Tcp(s) => {
                let mut w: &TcpStream = s;
                w.write_all(buf)
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                let mut w: &UnixStream = s;
                w.write_all(buf)
            }
        };
        if res.is_ok() {
            metrics::STREAM_SENT.add(buf.len() as u64);
        }
        res.map_err(map_io_err).context("comm send")
    }

    /// Blocking full read through a shared reference.
    pub fn read_exact(&self, buf: &mut [u8]) -> Result<()> {
        let res = match self {
            Conn::Tcp(s) => {
                let mut r: &TcpStream = s;
                r.read_exact(buf)
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                let mut r: &UnixStream = s;
                r.read_exact(buf)
            }
        };
        if res.is_ok() {
            metrics::STREAM_RECV.add(buf.len() as u64);
        }
        res.map_err(map_io_err).context("comm recv")
    }
}

/// Normalize the two timeout flavors the OS reports into one message
/// the fault tests (and operators) can recognize. Peer-flavored
/// failures also notify the run-health monitor so a configured
/// blackbox can capture the flight recorder before the error
/// propagates up and aborts the rank.
fn map_io_err(e: std::io::Error) -> anyhow::Error {
    let err = match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            anyhow::anyhow!("timed out waiting for comm peer (peer dead or stalled?): {e}")
        }
        std::io::ErrorKind::UnexpectedEof => {
            anyhow::anyhow!("comm peer closed the connection mid-message (truncated frame): {e}")
        }
        _ => anyhow::anyhow!(e),
    };
    crate::obs::monitor::note_comm_error(&err.to_string());
    err
}

/// A bound, not-yet-connected local endpoint.
#[derive(Debug)]
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    /// Bind an ephemeral local endpoint: TCP on `127.0.0.1:0`, Unix on
    /// `<dir>/rank-<rank>.sock` (any stale socket file is removed
    /// first). Returns the listener plus the `tcp://` / `unix://`
    /// address string to publish through the rendezvous.
    pub fn bind(kind: TransportKind, dir: &Path, rank: usize) -> Result<(Listener, String)> {
        match kind {
            TransportKind::Tcp => {
                let l = TcpListener::bind("127.0.0.1:0").context("binding comm tcp listener")?;
                let addr = format!("tcp://{}", l.local_addr()?);
                Ok((Listener::Tcp(l), addr))
            }
            #[cfg(unix)]
            TransportKind::Unix => {
                let path = dir.join(format!("rank-{rank}.sock"));
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)
                    .with_context(|| format!("binding comm unix listener {path:?}"))?;
                let addr = format!("unix://{}", path.display());
                Ok((Listener::Unix(l, path), addr))
            }
        }
    }

    /// Accept one connection, polling until `deadline` (listeners have
    /// no native accept timeout). The accepted stream is switched back
    /// to blocking mode with `io_timeout` reads/writes.
    pub fn accept(&self, deadline: Instant, io_timeout: Duration) -> Result<Conn> {
        self.set_nonblocking(true)?;
        let conn = loop {
            let attempt = match self {
                Listener::Tcp(l) => l.accept().map(|(s, _)| {
                    let _ = s.set_nodelay(true);
                    Conn::Tcp(s)
                }),
                #[cfg(unix)]
                Listener::Unix(l, _) => l.accept().map(|(s, _)| Conn::Unix(s)),
            };
            match attempt {
                Ok(conn) => break conn,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!("timed out waiting for a comm peer to connect");
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(e).context("accepting comm connection"),
            }
        };
        self.set_nonblocking(false)?;
        match &conn {
            Conn::Tcp(s) => s.set_nonblocking(false)?,
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(false)?,
        }
        conn.set_timeouts(io_timeout)?;
        Ok(conn)
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb)?,
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }
}

#[cfg(unix)]
impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair(kind: TransportKind) -> (Conn, Conn) {
        let dir = std::env::temp_dir().join(format!("lowrank_comm_transport_{}", kind.name()));
        std::fs::create_dir_all(&dir).unwrap();
        let (listener, addr) = Listener::bind(kind, &dir, 0).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let io = Duration::from_secs(5);
        let handle = std::thread::spawn(move || Conn::connect(&addr, deadline, io).unwrap());
        let accepted = listener.accept(deadline, io).unwrap();
        (handle.join().unwrap(), accepted)
    }

    #[test]
    fn tcp_roundtrip() {
        let (a, b) = pair(TransportKind::Tcp);
        a.write_all(b"hello over tcp").unwrap();
        let mut buf = [0u8; 14];
        b.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hello over tcp");
    }

    #[cfg(unix)]
    #[test]
    fn unix_roundtrip() {
        let (a, b) = pair(TransportKind::Unix);
        b.write_all(b"hi").unwrap();
        let mut buf = [0u8; 2];
        a.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hi");
    }

    #[test]
    fn read_times_out_instead_of_hanging() {
        let (a, _b) = pair(TransportKind::Tcp);
        a.set_timeouts(Duration::from_millis(50)).unwrap();
        let mut buf = [0u8; 1];
        let err = a.read_exact(&mut buf).unwrap_err().to_string();
        let root = format!("{:#}", a.read_exact(&mut buf).unwrap_err());
        assert!(err.contains("recv") || root.contains("timed out"), "{err} / {root}");
    }

    #[test]
    fn peer_drop_is_an_error_not_a_hang() {
        let (a, b) = pair(TransportKind::Tcp);
        drop(b);
        let mut buf = [0u8; 8];
        assert!(a.read_exact(&mut buf).is_err());
    }

    #[test]
    fn accept_timeout_is_bounded() {
        let dir = std::env::temp_dir().join("lowrank_comm_transport_accept");
        std::fs::create_dir_all(&dir).unwrap();
        let (listener, _addr) = Listener::bind(TransportKind::Tcp, &dir, 0).unwrap();
        let deadline = Instant::now() + Duration::from_millis(60);
        let err = listener
            .accept(deadline, Duration::from_secs(1))
            .unwrap_err()
            .to_string();
        assert!(err.contains("timed out"), "{err}");
    }

    #[test]
    fn bad_address_is_rejected() {
        let deadline = Instant::now();
        assert!(Conn::connect("carrier-pigeon://coop", deadline, Duration::from_secs(1)).is_err());
    }
}
