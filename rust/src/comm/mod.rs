//! `comm` — the multi-process collective communication subsystem.
//!
//! The DDP story stops being an in-process simulation here: training
//! processes rendezvous over the filesystem, connect a full socket mesh
//! (TCP or Unix-domain), and run real collectives — `allreduce_mean`,
//! `broadcast`, `all_gather`, `barrier` — over a self-validating wire
//! format borrowed from the checkpoint codec (magic + dtype + CRC-32,
//! [`wire`]). Low-rank training is exactly the workload where this
//! pays: the lifted gradients `dB ∈ ℝ^{m×r}` are r/n of the full
//! gradient, so collective bandwidth (not memory) is the scaling lever
//! — and the wire pushes the same lever twice more:
//!
//! * **The dtype lane** ([`WireDtype`], `--comm-dtype`/
//!   `LOWRANK_COMM_DTYPE`): all-reduce payloads travel as `f32`
//!   (bit-exact) or `bf16` (round-to-nearest-even on send, exact
//!   widening on receive — half the bytes per element). All reduction
//!   arithmetic stays f32 on the kernel pool; contributions are
//!   rounded once at the source and the reduced vector once at the
//!   end, so compressed ring ≡ compressed tree bitwise and a
//!   mixed-dtype world is rejected in the connect handshake.
//! * **The slot pipeline** ([`crate::coordinator::Collective::allreduce_mean_slots`]):
//!   the ring all-reduce is split into exchange / chunk-reduce / gather
//!   phases ([`Communicator::ring_exchange`], [`RingPending::reduce`],
//!   [`Communicator::ring_gather`]), so the trainer overlaps slot k's
//!   local reduce on the kernel pool with slot k+1's exchange on the
//!   sockets — same arithmetic, a bounded-window schedule that hides
//!   most of the wire latency at LLaMA-proxy m·r sizes.
//!
//! * [`transport`] — [`Conn`]/[`Listener`] over TCP and Unix sockets,
//!   with read/write timeouts so a dead peer is an error, not a hang.
//! * [`rendezvous`] — file rendezvous: atomic rank claims (O_EXCL),
//!   address exchange, and a per-launch run token so a directory left
//!   behind by a crashed run is a loud "stale rendezvous dir" error.
//! * [`wire`] — length-prefixed, CRC-verified frames in the
//!   `ckpt::codec` framing style; chunked payload streaming; the
//!   f32/bf16 dtype lane with checked length encodes.
//! * [`collective`] — the [`Communicator`]: chunked-ring (whole or
//!   phase-split) and pairing-tree all-reduce, broadcast, all-gather,
//!   barrier.
//! * [`launch`] — the torchrun-style local runner behind
//!   `lowrank-sge launch --nproc N …`; the first failing rank
//!   terminates the survivors immediately.
//!
//! # Determinism contract
//!
//! The combine order of every reduction is a pure function of (world
//! size, payload length) and — on the f32 lane — matches the
//! in-process [`crate::coordinator::allreduce_mean_with`] pairing tree
//! exactly: ring ≡ tree ≡ in-process, bitwise. On the bf16 lane the
//! combine order is the same pairing tree over the source-rounded
//! contributions, so ring ≡ tree bitwise there too (in-process parity
//! is an f32-lane contract; compression is opt-in). Results are
//! independent of message-arrival timing and thread count, and
//! `world == 1` is bitwise the single-process serial run in either
//! lane. See [`collective`] for the construction.

pub mod collective;
pub mod launch;
pub mod rendezvous;
pub mod transport;
pub mod wire;

pub use collective::{Algorithm, CommConfig, Communicator, RingPending, RING_MIN_ELEMS};
pub use launch::{run_launch, LaunchOptions};
pub use rendezvous::Rendezvous;
pub use transport::{Conn, Listener, TransportKind};
pub use wire::WireDtype;
