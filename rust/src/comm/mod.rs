//! `comm` — the multi-process collective communication subsystem.
//!
//! The DDP story stops being an in-process simulation here: training
//! processes rendezvous over the filesystem, connect a full socket mesh
//! (TCP or Unix-domain), and run real collectives — `allreduce_mean`,
//! `broadcast`, `all_gather`, `barrier` — over a self-validating wire
//! format borrowed from the checkpoint codec (magic + dtype + CRC-32,
//! [`wire`]). Low-rank training is exactly the workload where this
//! pays: the lifted gradients `dB ∈ ℝ^{m×r}` are r/n of the full
//! gradient, so collective bandwidth (not memory) is the scaling lever.
//!
//! * [`transport`] — [`Conn`]/[`Listener`] over TCP and Unix sockets,
//!   with read/write timeouts so a dead peer is an error, not a hang.
//! * [`rendezvous`] — file rendezvous: atomic rank claims (O_EXCL) and
//!   address exchange under one shared directory.
//! * [`wire`] — length-prefixed, CRC-verified frames in the
//!   `ckpt::codec` framing style; chunked payload streaming.
//! * [`collective`] — the [`Communicator`]: chunked-ring and
//!   pairing-tree all-reduce, broadcast, all-gather, barrier.
//! * [`launch`] — the torchrun-style local runner behind
//!   `lowrank-sge launch --nproc N …`.
//!
//! # Determinism contract
//!
//! The combine order of every reduction is a pure function of (world
//! size, payload length) and matches the in-process
//! [`crate::coordinator::allreduce_mean_with`] pairing tree exactly —
//! so ring ≡ tree ≡ in-process, bitwise; results are independent of
//! message-arrival timing and thread count; and `world == 1` is
//! bitwise the single-process serial run. See [`collective`] for the
//! construction.

pub mod collective;
pub mod launch;
pub mod rendezvous;
pub mod transport;
pub mod wire;

pub use collective::{Algorithm, CommConfig, Communicator, RING_MIN_ELEMS};
pub use launch::{run_launch, LaunchOptions};
pub use rendezvous::Rendezvous;
pub use transport::{Conn, Listener, TransportKind};
