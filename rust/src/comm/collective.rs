//! The [`Communicator`]: rank/world identity plus the collectives —
//! `allreduce_sum` / `allreduce_mean` (ring and tree), `broadcast`,
//! `all_gather`, `barrier` — over the full-mesh socket connections the
//! rendezvous established.
//!
//! # Determinism contract (extends the in-process one across processes)
//!
//! The combine order of every reduction is a **pure function of (world
//! size, payload length)** — never of thread count, arrival timing, or
//! transport:
//!
//! * The **tree** algorithm is the stride-doubling pairing tree of
//!   [`crate::coordinator::allreduce_mean_with`] verbatim: at gap g,
//!   rank r with `r % 2g == 0` folds rank r+g's payload into its own
//!   (`data += remote`, the same [`crate::kernel::add_assign`]), so the
//!   rank-0 total carries the identical association — then the total is
//!   broadcast back down the reverse tree.
//! * The **ring** algorithm partitions the payload into `world`
//!   contiguous chunks (bounds `i·len/world`), ring-offset-exchanges
//!   chunk copies (step s: send to rank+s, receive from rank−s, full
//!   duplex via a long-lived per-peer sender thread), locally reduces the `world`
//!   copies of the owned chunk **with the same pairing tree in rank
//!   order on the kernel pool**, and ring all-gathers the reduced
//!   chunks. Per element the association is identical to the tree, so
//!   ring ≡ tree ≡ in-process, bitwise. The three phases are exposed
//!   separately ([`Communicator::ring_exchange`] /
//!   [`RingPending::reduce`] / [`Communicator::ring_gather`]) so the
//!   trainer's slot pipeline can overlap slot k's chunk reduce with
//!   slot k+1's exchange — same arithmetic, different schedule.
//!
//! # The compressed lane (`WireDtype::Bf16`)
//!
//! With `--comm-dtype bf16` the all-reduce payloads travel as bfloat16
//! while **all arithmetic stays f32 on the kernel pool**. The semantics
//! are algorithm-independent by construction: every rank's contribution
//! is rounded to the bf16 grid once at the source (round-to-nearest-
//! even), the contributions are summed in exact f32 with the pairing
//! tree *in rank order*, and the reduced vector is rounded once more so
//! every rank — including the one that did the arithmetic — holds the
//! identical widened-bf16 bits. The ring implements this with its
//! single-hop chunk exchange unchanged; the tree switches to a
//! flat-gather schedule (every rank sends its rounded contribution
//! straight to rank 0, which reduces in rank order and releases the
//! result down the binomial broadcast tree) because re-compressing the
//! hierarchical *partial sums* would change the value per hop and break
//! compressed-ring ≡ compressed-tree. Hence ring ≡ tree bitwise in
//! both lanes, and the f32 lane is byte-identical to the uncompressed
//! protocol. `broadcast`, `all_gather`, `barrier`, and scalar
//! reductions routed through [`Communicator::allreduce_sum_f32_lane`]
//! (the trainers' step-loss mean) are control-path traffic and always
//! travel f32.
//!
//! At `world == 1` every collective is the identity (no wire, no
//! rounding), so a 1-process comm run is bitwise the in-process serial
//! run in either lane. Every receive validates frame kind, sequence
//! number, chunk order, and wire dtype — a peer that desyncs, corrupts,
//! compresses differently, or dies produces a loud error within the
//! configured timeout, never a silent wrong answer and never a hang.
//!
//! SPMD discipline: all ranks must issue the same collectives in the
//! same order (the sequence number pins this down at the protocol
//! level), with the same algorithm and wire dtype (the dtype is
//! verified in the connect handshake, so a mixed-dtype world fails at
//! startup, not mid-training).

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::rendezvous::Rendezvous;
use super::transport::{Conn, Listener, TransportKind};
use super::wire::{self, Kind, WireDtype};
use crate::obs;

/// Which reduction algorithm a communicator uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Chunked ring: bandwidth-optimal (2·(w−1)/w of the payload per
    /// rank each way) — the right choice for large lifted gradients.
    Ring,
    /// Pairing tree: latency-optimal (log₂ w rounds) — the right
    /// choice for small head gradients and scalars.
    Tree,
    /// Pick per call by payload length (a pure function of the length,
    /// so determinism is unaffected).
    Auto,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s {
            "ring" => Algorithm::Ring,
            "tree" => Algorithm::Tree,
            "auto" => Algorithm::Auto,
            other => bail!("unknown comm algorithm {other:?} (expected ring, tree, or auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Ring => "ring",
            Algorithm::Tree => "tree",
            Algorithm::Auto => "auto",
        }
    }

    /// The single routing predicate: does a payload of `len` elements
    /// ride the ring (vs the tree)? A pure function of the length, and
    /// the one definition both the serial all-reduce and the trainer's
    /// slot pipeline consult — their bitwise serial ≡ pipelined
    /// contract depends on routing each slot identically.
    pub fn routes_to_ring(&self, len: usize) -> bool {
        match self {
            Algorithm::Ring => true,
            Algorithm::Tree => false,
            Algorithm::Auto => len >= RING_MIN_ELEMS,
        }
    }
}

/// `Auto` switches from tree to ring at this payload length.
pub const RING_MIN_ELEMS: usize = 8192;

/// How a [`Communicator`] is built (usually from the `launch` env; see
/// [`Communicator::from_env`]).
#[derive(Clone, Debug)]
pub struct CommConfig {
    pub world: usize,
    /// Explicit rank, or `None` to claim the lowest free slot.
    pub rank: Option<usize>,
    pub transport: TransportKind,
    pub rdzv_dir: PathBuf,
    /// Bounds rendezvous waiting, connection setup, and every
    /// per-message send/receive.
    pub timeout: Duration,
    pub algo: Algorithm,
    /// Wire dtype of the all-reduce payloads (`F32` = bit-exact,
    /// `Bf16` = 2 bytes/element). Must match on every rank — verified
    /// in the connect handshake.
    pub wire_dtype: WireDtype,
    /// Run token stamped into the rendezvous dir (rank 0 writes, the
    /// rest verify) so a dir left over from a crashed run is a loud
    /// "stale rendezvous dir" error instead of a hung poll loop.
    /// `None` skips the stamp (single-run test/bench dirs).
    pub run_token: Option<String>,
}

/// A connected member of a multi-process collective group.
#[derive(Debug)]
pub struct Communicator {
    rank: usize,
    world: usize,
    /// Full mesh, indexed by peer rank (`None` at our own slot).
    peers: Vec<Option<Conn>>,
    algo: Algorithm,
    dtype: WireDtype,
    /// Collective sequence number — every rank's n-th collective call
    /// tags its frames with n, so cross-collective desync is detected.
    seq: u64,
    /// Rank 0's receive buffers for the bf16 flat-gather tree, reused
    /// across calls so the per-step tree slots stay allocation-free in
    /// steady state (mirrors the f32 tree's lazy `scratch`).
    gather_scratch: Vec<Vec<f32>>,
    /// Long-lived sender threads, indexed by peer rank and spawned
    /// lazily on the first full-duplex exchange with that peer. The
    /// slot-pipelined ring issues many small exchange steps; queueing
    /// the send on a persistent thread instead of spawning a scoped one
    /// per step saves the ~10 µs spawn cost each time.
    senders: Vec<Option<PeerSender>>,
}

/// A type- and lifetime-erased send queued on a [`PeerSender`] (see
/// [`PeerSender::submit`] for the soundness argument).
type SendJob = Box<dyn FnOnce() + Send + 'static>;

/// Completion state shared between a queued send and its
/// [`SendTicket`]: the result slot plus the condvar that announces it.
type SendState = Arc<(Mutex<Option<Result<()>>>, Condvar)>;

/// A long-lived sender thread for one peer connection. Full-duplex
/// exchange steps queue their outbound transfer here and drain the
/// inbound link on the calling thread — the same deadlock-free schedule
/// the old per-step scoped spawn gave, without the spawn.
#[derive(Debug)]
struct PeerSender {
    /// `None` only during drop (taking it closes the worker's queue).
    tx: Option<mpsc::Sender<SendJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Handle to one in-flight queued send. [`Self::wait`] blocks until the
/// transfer finished and yields its result; dropping the ticket without
/// waiting **also blocks** until the transfer finished — an early `?`
/// return on the receive side must not release buffers the sender
/// thread is still reading.
struct SendTicket {
    state: SendState,
    waited: bool,
}

impl SendTicket {
    fn wait(mut self) -> Result<()> {
        self.waited = true;
        let (lock, cvar) = &*self.state;
        let mut slot = lock.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(res) = slot.take() {
                return res;
            }
            slot = cvar.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl Drop for SendTicket {
    fn drop(&mut self) {
        if self.waited {
            return;
        }
        let (lock, cvar) = &*self.state;
        let mut slot = lock.lock().unwrap_or_else(|e| e.into_inner());
        while slot.is_none() {
            slot = cvar.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl PeerSender {
    fn spawn(peer: usize) -> PeerSender {
        let (tx, rx) = mpsc::channel::<SendJob>();
        let handle = std::thread::Builder::new()
            .name(format!("comm-send-{peer}"))
            .spawn(move || {
                // runs until the communicator drops the sending half
                while let Ok(job) = rx.recv() {
                    job();
                }
            })
            .expect("spawning comm sender thread");
        PeerSender { tx: Some(tx), handle: Some(handle) }
    }

    /// Queue one send on the worker thread. The closure may borrow the
    /// caller's connection and payload; erasing those lifetimes to
    /// `'static` is sound because the returned ticket — including its
    /// `Drop` — blocks until the worker has finished running the
    /// closure, so every borrow strictly outlives its use (the same
    /// latch argument as `KernelPool::run`'s scoped tasks).
    fn submit<'env, F>(&self, f: F) -> SendTicket
    where
        F: FnOnce() -> Result<()> + Send + 'env,
    {
        let state: SendState = Arc::new((Mutex::new(None), Condvar::new()));
        let worker_state = Arc::clone(&state);
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            let res = f();
            let (lock, cvar) = &*worker_state;
            *lock.lock().unwrap_or_else(|e| e.into_inner()) = Some(res);
            cvar.notify_all();
        });
        // SAFETY: lifetime erasure only — the ticket's wait/Drop blocks
        // until the job has run, upholding every borrow in `f`.
        let job: SendJob = unsafe { std::mem::transmute(job) };
        self.tx
            .as_ref()
            .expect("PeerSender used during drop")
            .send(job)
            .expect("comm sender thread exited while the communicator is alive");
        SendTicket { state, waited: false }
    }
}

impl Drop for PeerSender {
    fn drop(&mut self) {
        // closing the queue ends the worker's recv loop; join so no
        // send can outlive the connection it borrows
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// An in-flight ring all-reduce between its exchange and gather phases.
///
/// [`Communicator::ring_exchange`] fills `contrib` with the `world`
/// copies of this rank's owned chunk (rank order, own copy included);
/// [`RingPending::reduce`] folds them with the pairing tree on a kernel
/// pool — deliberately *without* touching the communicator, so the
/// reduce can run on a helper thread while the communicator drives the
/// next slot's exchange; [`Communicator::ring_gather`] then circulates
/// the reduced chunk. Dropping a pending ring without gathering desyncs
/// the collective sequence — always complete the triple.
#[derive(Debug)]
pub struct RingPending {
    seq_gather: u64,
    /// The wire lane captured at exchange time — the gather must ride
    /// the same lane the exchange advertised, whatever the
    /// communicator's configured lane is by the time it runs (the
    /// split phases may interleave other collectives, e.g. an
    /// f32-lane scalar reduce).
    dtype: WireDtype,
    /// Chunk bounds, a pure function of (world, len).
    bounds: Vec<usize>,
    /// The `world` copies of the owned chunk, indexed by source rank;
    /// after [`Self::reduce`], slot 0 holds the reduced chunk and the
    /// rest are pairing-tree scratch.
    contrib: Vec<Vec<f32>>,
    reduced: bool,
}

impl RingPending {
    /// Fold the chunk copies with the fixed pairing tree in rank order
    /// (bitwise-identical at any pool size). Must run exactly once,
    /// before [`Communicator::ring_gather`].
    pub fn reduce(&mut self, pool: &crate::kernel::KernelPool) {
        assert!(!self.reduced, "RingPending::reduce called twice");
        let _span = obs::span("comm", "ring_reduce");
        crate::kernel::tree_sum_vecs(pool, &mut self.contrib);
        self.reduced = true;
    }
}

impl Communicator {
    /// Rendezvous and build the full connection mesh: every pair of
    /// ranks shares one socket. Rank i dials every j < i and identifies
    /// itself with a hello frame carrying its rank and wire dtype; j
    /// accepts, verifies the dtype matches its own, and answers with
    /// its own hello — so a world whose ranks disagree on
    /// `--comm-dtype` fails loudly on both sides of the first
    /// connection, before any gradient moves.
    pub fn connect(cfg: &CommConfig) -> Result<Communicator> {
        let _span = obs::span("comm", "connect");
        if cfg.world == 0 {
            bail!("comm world size must be >= 1");
        }
        let rdzv = Rendezvous::with_token(
            &cfg.rdzv_dir,
            cfg.world,
            cfg.timeout,
            cfg.run_token.clone(),
        )?;
        let rank = rdzv.claim_rank(cfg.rank)?;
        let deadline = Instant::now() + cfg.timeout;
        let (listener, addr) = Listener::bind(cfg.transport, rdzv.dir(), rank)?;
        let table = rdzv.exchange(rank, &addr)?;
        let dtype = cfg.wire_dtype;

        let mut peers: Vec<Option<Conn>> = (0..cfg.world).map(|_| None).collect();
        for (r, peer_addr) in table.iter().enumerate().take(rank) {
            let conn = Conn::connect(peer_addr, deadline, cfg.timeout)
                .with_context(|| format!("rank {rank} dialing rank {r}"))?;
            send_hello(&conn, rank, dtype)?;
            let ack = wire::recv_frame(&conn)
                .with_context(|| format!("rank {rank} reading rank {r}'s comm hello ack"))?;
            if ack.kind != Kind::Hello {
                bail!("comm handshake desync: expected hello ack, got {:?}", ack.kind);
            }
            if ack.part as usize != r {
                bail!("comm hello ack from rank {} on the connection to rank {r}", ack.part);
            }
            check_hello_dtype(ack.seq, dtype, r)?;
            peers[r] = Some(conn);
        }
        for _ in rank + 1..cfg.world {
            let conn = listener.accept(deadline, cfg.timeout)?;
            let hello = wire::recv_frame(&conn).context("reading comm hello")?;
            if hello.kind != Kind::Hello {
                bail!("comm handshake desync: expected hello, got {:?}", hello.kind);
            }
            let peer = hello.part as usize;
            if peer <= rank || peer >= cfg.world {
                bail!("comm hello from unexpected rank {peer} (we are rank {rank})");
            }
            if peers[peer].is_some() {
                bail!("duplicate comm connection from rank {peer}");
            }
            check_hello_dtype(hello.seq, dtype, peer)?;
            send_hello(&conn, rank, dtype)?;
            peers[peer] = Some(conn);
        }
        let senders = (0..cfg.world).map(|_| None).collect();
        Ok(Communicator {
            rank,
            world: cfg.world,
            peers,
            algo: cfg.algo,
            dtype,
            seq: 0,
            gather_scratch: Vec::new(),
            senders,
        })
    }

    /// Build from the `launch` runner's environment. Returns `None`
    /// when `LOWRANK_COMM_RDZV` is unset — the single-process default.
    ///
    /// Env contract (all set by `lowrank-sge launch`):
    /// `LOWRANK_COMM_RDZV` (rendezvous dir), `LOWRANK_COMM_WORLD`,
    /// `LOWRANK_COMM_RANK` (optional — lowest free slot when absent),
    /// `LOWRANK_COMM_TRANSPORT` (`tcp`|`unix`), `LOWRANK_COMM_TIMEOUT_MS`,
    /// `LOWRANK_COMM_ALGO` (`ring`|`tree`|`auto`), `LOWRANK_COMM_DTYPE`
    /// (`f32`|`bf16`), `LOWRANK_COMM_TOKEN` (run token, optional).
    pub fn from_env() -> Result<Option<Communicator>> {
        Self::from_env_with(None)
    }

    /// [`Self::from_env`] with an explicit wire-dtype override (a
    /// subcommand's own `--comm-dtype`) that replaces the env-derived
    /// lane **before** connect — so the handshake verifies the lane the
    /// collectives will actually use, and a mixed-dtype world still
    /// fails at startup rather than at the first gradient frame.
    pub fn from_env_with(dtype_override: Option<WireDtype>) -> Result<Option<Communicator>> {
        let Ok(rdzv_dir) = std::env::var("LOWRANK_COMM_RDZV") else {
            return Ok(None);
        };
        let world: usize = std::env::var("LOWRANK_COMM_WORLD")
            .context("LOWRANK_COMM_RDZV is set but LOWRANK_COMM_WORLD is not")?
            .parse()
            .context("LOWRANK_COMM_WORLD must be a positive integer")?;
        let rank = match std::env::var("LOWRANK_COMM_RANK") {
            Ok(s) => Some(s.parse::<usize>().context("LOWRANK_COMM_RANK must be an integer")?),
            Err(_) => None,
        };
        let transport = match std::env::var("LOWRANK_COMM_TRANSPORT") {
            Ok(s) => TransportKind::parse(&s)?,
            Err(_) => TransportKind::default_for_host(),
        };
        let timeout_ms: u64 = match std::env::var("LOWRANK_COMM_TIMEOUT_MS") {
            Ok(s) => s.parse().context("LOWRANK_COMM_TIMEOUT_MS must be an integer")?,
            Err(_) => 60_000,
        };
        let algo = match std::env::var("LOWRANK_COMM_ALGO") {
            Ok(s) => Algorithm::parse(&s)?,
            Err(_) => Algorithm::Auto,
        };
        let cfg = CommConfig {
            world,
            rank,
            transport,
            rdzv_dir: PathBuf::from(rdzv_dir),
            timeout: Duration::from_millis(timeout_ms.max(1)),
            algo,
            wire_dtype: match dtype_override {
                Some(dtype) => dtype,
                None => WireDtype::from_env()?,
            },
            run_token: std::env::var("LOWRANK_COMM_TOKEN").ok(),
        };
        Communicator::connect(&cfg).map(Some)
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn algorithm(&self) -> Algorithm {
        self.algo
    }

    pub fn set_algorithm(&mut self, algo: Algorithm) {
        self.algo = algo;
    }

    /// The lane the connect handshake verified. Immutable after
    /// connect by design: a post-connect switch would un-verify the
    /// mixed-dtype protection, so there deliberately is no setter —
    /// per-reduction lane control goes through
    /// [`Self::allreduce_sum_f32_lane`], and subcommand overrides
    /// thread into [`Self::from_env_with`] *before* connect.
    pub fn wire_dtype(&self) -> WireDtype {
        self.dtype
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    fn peer(&self, rank: usize) -> Result<&Conn> {
        self.peers
            .get(rank)
            .and_then(|c| c.as_ref())
            .with_context(|| format!("no comm connection to rank {rank}"))
    }

    /// Spawn the long-lived sender thread for `rank` if it does not
    /// exist yet (a missing connection fails before anything spawns).
    fn ensure_sender(&mut self, rank: usize) -> Result<()> {
        if self.senders[rank].is_none() {
            self.peer(rank)?;
            self.senders[rank] = Some(PeerSender::spawn(rank));
        }
        Ok(())
    }

    fn sender(&self, rank: usize) -> &PeerSender {
        self.senders[rank].as_ref().expect("ensure_sender must run before sender")
    }

    /// In-place sum across all ranks with the configured algorithm;
    /// every rank ends with the identical (bitwise) total.
    pub fn allreduce_sum(&mut self, data: &mut [f32]) -> Result<()> {
        self.allreduce_sum_with(self.algo, data)
    }

    /// In-place sum with an explicit algorithm (the determinism tests
    /// pin ring ≡ tree — and, on the f32 lane, ≡ in-process — with
    /// this).
    pub fn allreduce_sum_with(&mut self, algo: Algorithm, data: &mut [f32]) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        if algo.routes_to_ring(data.len()) {
            self.ring_allreduce(data)
        } else {
            self.tree_allreduce(data)
        }
    }

    /// In-place sum pinned to the f32 lane regardless of the configured
    /// wire dtype — for control-path reductions (the step-loss scalar,
    /// health counters) where compressing a handful of bytes buys
    /// nothing and rounding a logged metric costs real precision. SPMD:
    /// every rank must route the same reduction through the same lane
    /// (trivially true when all call sites use this method).
    pub fn allreduce_sum_f32_lane(&mut self, data: &mut [f32]) -> Result<()> {
        let lane = self.dtype;
        self.dtype = WireDtype::F32;
        let res = self.allreduce_sum(data);
        self.dtype = lane;
        res
    }

    /// All-reduce mean: the cross-process generalization of
    /// [`crate::coordinator::allreduce_mean`] — sum with the pairing
    /// tree order, then one scale by 1/world on the kernel pool.
    pub fn allreduce_mean(&mut self, data: &mut [f32]) -> Result<()> {
        self.allreduce_sum(data)?;
        if self.world > 1 {
            let pool = crate::kernel::global();
            crate::kernel::scale(&pool, data, 1.0 / self.world as f32);
        }
        Ok(())
    }

    /// Broadcast `data` from `root` to every rank (binomial tree over
    /// root-relative ranks; always the f32 lane).
    pub fn broadcast(&mut self, data: &mut [f32], root: usize) -> Result<()> {
        if root >= self.world {
            bail!("broadcast root {root} out of range for world {}", self.world);
        }
        if self.world == 1 {
            return Ok(());
        }
        let _span = obs::span("comm", "broadcast");
        let seq = self.next_seq();
        let (rank, world) = (self.rank, self.world);
        let rel = (rank + world - root) % world;
        if rel != 0 {
            let parent = (tree_parent(rel) + root) % world;
            wire::recv_f32s_into(self.peer(parent)?, seq, data, WireDtype::F32)?;
        }
        for &child_rel in tree_children(rel, world).iter().rev() {
            let child = (child_rel + root) % world;
            wire::send_f32s(self.peer(child)?, seq, data, WireDtype::F32)?;
        }
        Ok(())
    }

    /// Gather every rank's equal-length contribution into
    /// `out[rank·len .. (rank+1)·len]` on all ranks (ring schedule;
    /// always the f32 lane).
    pub fn all_gather(&mut self, mine: &[f32], out: &mut [f32]) -> Result<()> {
        let k = mine.len();
        if out.len() != k * self.world {
            bail!(
                "all_gather output has {} elements, expected {} (world {} × {k})",
                out.len(),
                k * self.world,
                self.world
            );
        }
        let (rank, world) = (self.rank, self.world);
        out[rank * k..(rank + 1) * k].copy_from_slice(mine);
        if world == 1 {
            return Ok(());
        }
        let _span = obs::span("comm", "all_gather");
        let seq = self.next_seq();
        for s in 1..world {
            let dst = (rank + s) % world;
            let src = (rank + world - s) % world;
            self.ensure_sender(dst)?;
            let dst_conn = self.peer(dst)?;
            let src_conn = self.peer(src)?;
            let recv_slice = &mut out[src * k..(src + 1) * k];
            let ticket = self
                .sender(dst)
                .submit(|| wire::send_f32s(dst_conn, seq, mine, WireDtype::F32));
            let recv_res = wire::recv_f32s_into(src_conn, seq, recv_slice, WireDtype::F32);
            ticket.wait()?;
            recv_res?;
        }
        Ok(())
    }

    /// Block until every rank has reached this barrier (token reduce up
    /// the pairing tree, release broadcast back down).
    pub fn barrier(&mut self) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let _span = obs::span("comm", "barrier");
        let seq = self.next_seq();
        let (rank, world) = (self.rank, self.world);
        let mut gap = 1;
        while gap < world {
            if rank % (2 * gap) == 0 {
                let src = rank + gap;
                if src < world {
                    self.expect_barrier(src, seq)?;
                }
            } else {
                let parent = self.peer(rank - gap)?;
                wire::send_frame(parent, Kind::Barrier, seq, 0, &[], WireDtype::F32)?;
                break;
            }
            gap *= 2;
        }
        if rank != 0 {
            self.expect_barrier(tree_parent(rank), seq)?;
        }
        for &child in tree_children(rank, world).iter().rev() {
            wire::send_frame(self.peer(child)?, Kind::Barrier, seq, 0, &[], WireDtype::F32)?;
        }
        Ok(())
    }

    fn expect_barrier(&self, from: usize, seq: u64) -> Result<()> {
        let frame = wire::recv_frame(self.peer(from)?)?;
        if frame.kind != Kind::Barrier || frame.seq != seq {
            bail!(
                "collective protocol desync at barrier: got {:?} seq {} from rank {from}, \
                 expected barrier seq {seq}",
                frame.kind,
                frame.seq
            );
        }
        Ok(())
    }

    /// Phase 1 of the chunked ring: round the payload to the wire grid
    /// (bf16 lane only — the f32 lane is untouched), then ring-offset
    /// exchange chunk copies so this rank holds all `world`
    /// contributions to its owned chunk. Two sequence numbers are
    /// consumed (exchange + the eventual gather), so interleaving the
    /// phases of several collectives keeps a deterministic frame
    /// schedule. Requires `world > 1`.
    pub fn ring_exchange(&mut self, data: &mut [f32]) -> Result<RingPending> {
        debug_assert!(self.world > 1, "ring_exchange is meaningless at world == 1");
        let _span = obs::span("comm", "ring_exchange");
        let seq_x = self.next_seq();
        let seq_g = self.next_seq();
        let dtype = self.dtype;
        if dtype == WireDtype::Bf16 {
            // quantize at the source: chunk sends below are then
            // lossless, and the local contribution enters the reduce
            // with the same bits every peer receives
            wire::quantize_bf16(data);
        }
        let (rank, world) = (self.rank, self.world);
        let len = data.len();
        // chunk bounds are a pure function of (world, len)
        let bounds: Vec<usize> = (0..=world).map(|i| i * len / world).collect();
        let own = bounds[rank]..bounds[rank + 1];
        let own_len = own.len();

        // step s sends our copy of rank (rank+s)'s chunk and receives
        // rank (rank−s)'s copy of ours, full duplex.
        let mut copies: Vec<Option<Vec<f32>>> = (0..world).map(|_| None).collect();
        for s in 1..world {
            let dst = (rank + s) % world;
            let src = (rank + world - s) % world;
            let send_chunk = &data[bounds[dst]..bounds[dst + 1]];
            let mut buf = vec![0.0f32; own_len];
            self.ensure_sender(dst)?;
            let dst_conn = self.peer(dst)?;
            let src_conn = self.peer(src)?;
            let ticket = self
                .sender(dst)
                .submit(|| wire::send_f32s(dst_conn, seq_x, send_chunk, dtype));
            let recv_res = wire::recv_f32s_into(src_conn, seq_x, &mut buf, dtype);
            ticket.wait()?;
            recv_res?;
            copies[src] = Some(buf);
        }
        let contrib: Vec<Vec<f32>> = (0..world)
            .map(|r| {
                if r == rank {
                    data[own.clone()].to_vec()
                } else {
                    copies[r].take().expect("exchange filled every peer slot")
                }
            })
            .collect();
        Ok(RingPending { seq_gather: seq_g, dtype, bounds, contrib, reduced: false })
    }

    /// Phase 3 of the chunked ring: circulate the reduced chunk
    /// ([`RingPending::reduce`] must have run) and fill `data` with
    /// every rank's reduced chunk. On the bf16 lane the reduced chunk
    /// is rounded once before it circulates, so the owner and every
    /// receiver end with identical bits.
    pub fn ring_gather(&mut self, pending: RingPending, data: &mut [f32]) -> Result<()> {
        let _span = obs::span("comm", "ring_gather");
        let RingPending { seq_gather: seq, dtype, bounds, mut contrib, reduced } = pending;
        assert!(reduced, "ring_gather called before RingPending::reduce");
        let (rank, world) = (self.rank, self.world);
        if bounds.len() != world + 1 || bounds[world] != data.len() {
            bail!(
                "ring_gather buffer has {} elements but the exchange covered {}",
                data.len(),
                bounds[world]
            );
        }
        let mut own_copy = std::mem::take(&mut contrib[0]);
        if dtype == WireDtype::Bf16 {
            wire::quantize_bf16(&mut own_copy);
        }
        data[bounds[rank]..bounds[rank + 1]].copy_from_slice(&own_copy);
        for s in 1..world {
            let dst = (rank + s) % world;
            let src = (rank + world - s) % world;
            self.ensure_sender(dst)?;
            let dst_conn = self.peer(dst)?;
            let src_conn = self.peer(src)?;
            let recv_slice = &mut data[bounds[src]..bounds[src + 1]];
            let ticket = self
                .sender(dst)
                .submit(|| wire::send_f32s(dst_conn, seq, &own_copy, dtype));
            let recv_res = wire::recv_f32s_into(src_conn, seq, recv_slice, dtype);
            ticket.wait()?;
            recv_res?;
        }
        Ok(())
    }

    /// The serial ring all-reduce: exchange, reduce on the global pool,
    /// gather — the same three phases the slot pipeline interleaves.
    fn ring_allreduce(&mut self, data: &mut [f32]) -> Result<()> {
        let mut pending = self.ring_exchange(data)?;
        pending.reduce(&crate::kernel::global());
        self.ring_gather(pending, data)
    }

    fn tree_allreduce(&mut self, data: &mut [f32]) -> Result<()> {
        match self.dtype {
            WireDtype::F32 => self.tree_allreduce_f32(data),
            WireDtype::Bf16 => self.tree_allreduce_bf16(data),
        }
    }

    /// Stride-doubling pairing tree (identical association to the
    /// in-process `allreduce_mean_with`), then release broadcast of the
    /// rank-0 total. f32 lane: partial sums travel bit-exact.
    fn tree_allreduce_f32(&mut self, data: &mut [f32]) -> Result<()> {
        let _span = obs::span("comm", "tree_allreduce");
        let seq = self.next_seq();
        let (rank, world) = (self.rank, self.world);
        let pool = crate::kernel::global();
        // allocated lazily at the first receive: leaf ranks (half the
        // world) only ever send and never pay for the scratch
        let mut scratch: Vec<f32> = Vec::new();
        let mut gap = 1;
        while gap < world {
            if rank % (2 * gap) == 0 {
                let src = rank + gap;
                if src < world {
                    if scratch.len() != data.len() {
                        scratch.resize(data.len(), 0.0);
                    }
                    wire::recv_f32s_into(self.peer(src)?, seq, &mut scratch, WireDtype::F32)?;
                    crate::kernel::add_assign(&pool, data, &scratch);
                }
            } else {
                // this rank's partial is folded into rank − gap; it
                // waits for the release broadcast below
                wire::send_f32s(self.peer(rank - gap)?, seq, data, WireDtype::F32)?;
                break;
            }
            gap *= 2;
        }
        if rank != 0 {
            wire::recv_f32s_into(self.peer(tree_parent(rank))?, seq, data, WireDtype::F32)?;
        }
        for &child in tree_children(rank, world).iter().rev() {
            wire::send_f32s(self.peer(child)?, seq, data, WireDtype::F32)?;
        }
        Ok(())
    }

    /// bf16 lane of the tree: flat-gather the rounded contributions to
    /// rank 0 (single hop each — hierarchical partial sums would need
    /// lossy re-compression per hop and break ring ≡ tree), reduce them
    /// in rank order with the same pairing tree the ring uses, round
    /// the total once, and release it down the binomial broadcast tree
    /// (lossless: the payload is already on the bf16 grid).
    fn tree_allreduce_bf16(&mut self, data: &mut [f32]) -> Result<()> {
        let _span = obs::span("comm", "tree_allreduce_bf16");
        let seq_gather = self.next_seq();
        let seq_bcast = self.next_seq();
        let (rank, world) = (self.rank, self.world);
        wire::quantize_bf16(data);
        if rank == 0 {
            let pool = crate::kernel::global();
            // persistent contribution slots (taken, refilled, returned)
            // so steady-state tree slots allocate nothing per step
            let mut contrib = std::mem::take(&mut self.gather_scratch);
            contrib.resize_with(world, Vec::new);
            contrib[0].clear();
            contrib[0].extend_from_slice(data);
            // drain every peer concurrently (one scoped receiver per
            // connection): all senders transmit at once, so no rank's
            // write ever stalls behind another rank's transfer long
            // enough to trip the per-message timeout. Arrival timing
            // cannot leak into the result — each receiver fills its own
            // rank-indexed slot and the reduce below runs in rank order.
            let data_len = data.len();
            let this = &*self;
            std::thread::scope(|scope| -> Result<()> {
                let mut handles = Vec::with_capacity(world - 1);
                for (r, buf) in contrib.iter_mut().enumerate().skip(1) {
                    handles.push(scope.spawn(move || -> Result<()> {
                        buf.resize(data_len, 0.0);
                        wire::recv_f32s_into(this.peer(r)?, seq_gather, buf, WireDtype::Bf16)
                    }));
                }
                for h in handles {
                    h.join()
                        .map_err(|_| anyhow::anyhow!("comm receiver thread panicked"))??;
                }
                Ok(())
            })?;
            crate::kernel::tree_sum_vecs(&pool, &mut contrib);
            data.copy_from_slice(&contrib[0]);
            wire::quantize_bf16(data);
            self.gather_scratch = contrib;
        } else {
            wire::send_f32s(self.peer(0)?, seq_gather, data, WireDtype::Bf16)?;
            wire::recv_f32s_into(
                self.peer(tree_parent(rank))?,
                seq_bcast,
                data,
                WireDtype::Bf16,
            )?;
        }
        for &child in tree_children(rank, world).iter().rev() {
            wire::send_f32s(self.peer(child)?, seq_bcast, data, WireDtype::Bf16)?;
        }
        Ok(())
    }
}

/// Send the connect handshake frame: `part` carries the sender's rank,
/// `seq` the sender's wire-dtype tag.
fn send_hello(conn: &Conn, rank: usize, dtype: WireDtype) -> Result<()> {
    wire::send_frame(conn, Kind::Hello, dtype.tag() as u64, rank as u32, &[], WireDtype::F32)
}

/// Verify a hello's advertised wire dtype against our own.
fn check_hello_dtype(advertised: u64, ours: WireDtype, peer: usize) -> Result<()> {
    if advertised == ours.tag() as u64 {
        return Ok(());
    }
    let theirs = u8::try_from(advertised)
        .ok()
        .and_then(|t| WireDtype::from_tag(t).ok())
        .map(|d| d.name())
        .unwrap_or("an unknown dtype");
    bail!(
        "comm wire dtype mismatch: rank {peer} speaks {theirs}, this rank speaks {} — \
         set --comm-dtype/LOWRANK_COMM_DTYPE identically on every rank",
        ours.name()
    )
}

/// Parent of `rank` in the stride-doubling pairing tree: the rank it
/// sends its partial to (and receives the release broadcast from).
fn tree_parent(rank: usize) -> usize {
    debug_assert!(rank > 0);
    rank - (rank & rank.wrapping_neg())
}

/// Children of `rank`, in ascending-gap (reduce receive) order; the
/// release broadcast walks them in reverse.
fn tree_children(rank: usize, world: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut gap = 1;
    while gap < world {
        if rank % (2 * gap) != 0 {
            break;
        }
        if rank + gap < world {
            out.push(rank + gap);
        }
        gap *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_topology_matches_the_pairing_order() {
        // world 4: 1→0 and 3→2 at gap 1, then 2→0 at gap 2
        assert_eq!(tree_parent(1), 0);
        assert_eq!(tree_parent(2), 0);
        assert_eq!(tree_parent(3), 2);
        assert_eq!(tree_children(0, 4), vec![1, 2]);
        assert_eq!(tree_children(2, 4), vec![3]);
        assert_eq!(tree_children(1, 4), Vec::<usize>::new());
        // world 3: no partner for rank 2 at gap 1; it folds at gap 2
        assert_eq!(tree_children(0, 3), vec![1, 2]);
        assert_eq!(tree_parent(2), 0);
        // world 6: rank 4 receives 5, then folds into 0 at gap 4
        assert_eq!(tree_children(4, 6), vec![5]);
        assert_eq!(tree_parent(4), 0);
        assert_eq!(tree_children(0, 6), vec![1, 2, 4]);
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in [Algorithm::Ring, Algorithm::Tree, Algorithm::Auto] {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
        assert!(Algorithm::parse("carrier-pigeon").is_err());
    }

    #[test]
    fn routing_predicate_is_length_pure() {
        assert!(Algorithm::Ring.routes_to_ring(1));
        assert!(!Algorithm::Tree.routes_to_ring(1 << 20));
        assert!(!Algorithm::Auto.routes_to_ring(RING_MIN_ELEMS - 1));
        assert!(Algorithm::Auto.routes_to_ring(RING_MIN_ELEMS));
    }

    #[test]
    fn hello_dtype_check_is_symmetric_and_loud() {
        assert!(check_hello_dtype(WireDtype::F32.tag() as u64, WireDtype::F32, 1).is_ok());
        assert!(check_hello_dtype(WireDtype::Bf16.tag() as u64, WireDtype::Bf16, 1).is_ok());
        let err = check_hello_dtype(WireDtype::Bf16.tag() as u64, WireDtype::F32, 3)
            .unwrap_err()
            .to_string();
        assert!(err.contains("dtype mismatch") && err.contains("rank 3"), "{err}");
        let err = check_hello_dtype(200, WireDtype::F32, 1).unwrap_err().to_string();
        assert!(err.contains("unknown dtype"), "{err}");
    }
}
