//! The [`Communicator`]: rank/world identity plus the collectives —
//! `allreduce_sum` / `allreduce_mean` (ring and tree), `broadcast`,
//! `all_gather`, `barrier` — over the full-mesh socket connections the
//! rendezvous established.
//!
//! # Determinism contract (extends the in-process one across processes)
//!
//! The combine order of every reduction is a **pure function of (world
//! size, payload length)** — never of thread count, arrival timing, or
//! transport:
//!
//! * The **tree** algorithm is the stride-doubling pairing tree of
//!   [`crate::coordinator::allreduce_mean_with`] verbatim: at gap g,
//!   rank r with `r % 2g == 0` folds rank r+g's payload into its own
//!   (`data += remote`, the same [`crate::kernel::add_assign`]), so the
//!   rank-0 total carries the identical association — then the total is
//!   broadcast back down the reverse tree.
//! * The **ring** algorithm partitions the payload into `world`
//!   contiguous chunks (bounds `i·len/world`), ring-offset-exchanges
//!   chunk copies (step s: send to rank+s, receive from rank−s, full
//!   duplex via a helper send thread), locally reduces the `world`
//!   copies of the owned chunk **with the same pairing tree in rank
//!   order on the kernel pool**, and ring all-gathers the reduced
//!   chunks. Per element the association is identical to the tree, so
//!   ring ≡ tree ≡ in-process, bitwise.
//!
//! At `world == 1` every collective is the identity, so a 1-process
//! comm run is bitwise the in-process serial run. Every receive
//! validates frame kind, sequence number, and chunk order — a peer that
//! desyncs, corrupts, or dies produces a loud error within the
//! configured timeout, never a silent wrong answer and never a hang.
//!
//! SPMD discipline: all ranks must issue the same collectives in the
//! same order (the sequence number pins this down at the protocol
//! level).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::rendezvous::Rendezvous;
use super::transport::{Conn, Listener, TransportKind};
use super::wire::{self, Kind};

/// Which reduction algorithm a communicator uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Chunked ring: bandwidth-optimal (2·(w−1)/w of the payload per
    /// rank each way) — the right choice for large lifted gradients.
    Ring,
    /// Pairing tree: latency-optimal (log₂ w rounds) — the right
    /// choice for small head gradients and scalars.
    Tree,
    /// Pick per call by payload length (a pure function of the length,
    /// so determinism is unaffected).
    Auto,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s {
            "ring" => Algorithm::Ring,
            "tree" => Algorithm::Tree,
            "auto" => Algorithm::Auto,
            other => bail!("unknown comm algorithm {other:?} (expected ring, tree, or auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Ring => "ring",
            Algorithm::Tree => "tree",
            Algorithm::Auto => "auto",
        }
    }
}

/// `Auto` switches from tree to ring at this payload length.
pub const RING_MIN_ELEMS: usize = 8192;

/// How a [`Communicator`] is built (usually from the `launch` env; see
/// [`Communicator::from_env`]).
#[derive(Clone, Debug)]
pub struct CommConfig {
    pub world: usize,
    /// Explicit rank, or `None` to claim the lowest free slot.
    pub rank: Option<usize>,
    pub transport: TransportKind,
    pub rdzv_dir: PathBuf,
    /// Bounds rendezvous waiting, connection setup, and every
    /// per-message send/receive.
    pub timeout: Duration,
    pub algo: Algorithm,
}

/// A connected member of a multi-process collective group.
#[derive(Debug)]
pub struct Communicator {
    rank: usize,
    world: usize,
    /// Full mesh, indexed by peer rank (`None` at our own slot).
    peers: Vec<Option<Conn>>,
    algo: Algorithm,
    /// Collective sequence number — every rank's n-th collective call
    /// tags its frames with n, so cross-collective desync is detected.
    seq: u64,
}

impl Communicator {
    /// Rendezvous and build the full connection mesh: every pair of
    /// ranks shares one socket (rank i dials every j < i and identifies
    /// itself with a hello frame; j accepts and indexes the connection
    /// by the hello's rank).
    pub fn connect(cfg: &CommConfig) -> Result<Communicator> {
        if cfg.world == 0 {
            bail!("comm world size must be >= 1");
        }
        let rdzv = Rendezvous::new(&cfg.rdzv_dir, cfg.world, cfg.timeout)?;
        let rank = rdzv.claim_rank(cfg.rank)?;
        let deadline = Instant::now() + cfg.timeout;
        let (listener, addr) = Listener::bind(cfg.transport, rdzv.dir(), rank)?;
        let table = rdzv.exchange(rank, &addr)?;

        let mut peers: Vec<Option<Conn>> = (0..cfg.world).map(|_| None).collect();
        for (r, peer_addr) in table.iter().enumerate().take(rank) {
            let conn = Conn::connect(peer_addr, deadline, cfg.timeout)
                .with_context(|| format!("rank {rank} dialing rank {r}"))?;
            wire::send_frame(&conn, Kind::Hello, 0, rank as u32, &[])?;
            peers[r] = Some(conn);
        }
        for _ in rank + 1..cfg.world {
            let conn = listener.accept(deadline, cfg.timeout)?;
            let hello = wire::recv_frame(&conn).context("reading comm hello")?;
            if hello.kind != Kind::Hello {
                bail!("comm handshake desync: expected hello, got {:?}", hello.kind);
            }
            let peer = hello.part as usize;
            if peer <= rank || peer >= cfg.world {
                bail!("comm hello from unexpected rank {peer} (we are rank {rank})");
            }
            if peers[peer].is_some() {
                bail!("duplicate comm connection from rank {peer}");
            }
            peers[peer] = Some(conn);
        }
        Ok(Communicator { rank, world: cfg.world, peers, algo: cfg.algo, seq: 0 })
    }

    /// Build from the `launch` runner's environment. Returns `None`
    /// when `LOWRANK_COMM_RDZV` is unset — the single-process default.
    ///
    /// Env contract (all set by `lowrank-sge launch`):
    /// `LOWRANK_COMM_RDZV` (rendezvous dir), `LOWRANK_COMM_WORLD`,
    /// `LOWRANK_COMM_RANK` (optional — lowest free slot when absent),
    /// `LOWRANK_COMM_TRANSPORT` (`tcp`|`unix`), `LOWRANK_COMM_TIMEOUT_MS`,
    /// `LOWRANK_COMM_ALGO` (`ring`|`tree`|`auto`).
    pub fn from_env() -> Result<Option<Communicator>> {
        let Ok(rdzv_dir) = std::env::var("LOWRANK_COMM_RDZV") else {
            return Ok(None);
        };
        let world: usize = std::env::var("LOWRANK_COMM_WORLD")
            .context("LOWRANK_COMM_RDZV is set but LOWRANK_COMM_WORLD is not")?
            .parse()
            .context("LOWRANK_COMM_WORLD must be a positive integer")?;
        let rank = match std::env::var("LOWRANK_COMM_RANK") {
            Ok(s) => Some(s.parse::<usize>().context("LOWRANK_COMM_RANK must be an integer")?),
            Err(_) => None,
        };
        let transport = match std::env::var("LOWRANK_COMM_TRANSPORT") {
            Ok(s) => TransportKind::parse(&s)?,
            Err(_) => TransportKind::default_for_host(),
        };
        let timeout_ms: u64 = match std::env::var("LOWRANK_COMM_TIMEOUT_MS") {
            Ok(s) => s.parse().context("LOWRANK_COMM_TIMEOUT_MS must be an integer")?,
            Err(_) => 60_000,
        };
        let algo = match std::env::var("LOWRANK_COMM_ALGO") {
            Ok(s) => Algorithm::parse(&s)?,
            Err(_) => Algorithm::Auto,
        };
        let cfg = CommConfig {
            world,
            rank,
            transport,
            rdzv_dir: PathBuf::from(rdzv_dir),
            timeout: Duration::from_millis(timeout_ms.max(1)),
            algo,
        };
        Communicator::connect(&cfg).map(Some)
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn algorithm(&self) -> Algorithm {
        self.algo
    }

    pub fn set_algorithm(&mut self, algo: Algorithm) {
        self.algo = algo;
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    fn peer(&self, rank: usize) -> Result<&Conn> {
        self.peers
            .get(rank)
            .and_then(|c| c.as_ref())
            .with_context(|| format!("no comm connection to rank {rank}"))
    }

    /// In-place sum across all ranks with the configured algorithm;
    /// every rank ends with the identical (bitwise) total.
    pub fn allreduce_sum(&mut self, data: &mut [f32]) -> Result<()> {
        self.allreduce_sum_with(self.algo, data)
    }

    /// In-place sum with an explicit algorithm (the determinism tests
    /// pin ring ≡ tree ≡ in-process with this).
    pub fn allreduce_sum_with(&mut self, algo: Algorithm, data: &mut [f32]) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let seq = self.next_seq();
        let use_ring = match algo {
            Algorithm::Ring => true,
            Algorithm::Tree => false,
            Algorithm::Auto => data.len() >= RING_MIN_ELEMS,
        };
        if use_ring {
            self.ring_allreduce(seq, data)
        } else {
            self.tree_allreduce(seq, data)
        }
    }

    /// All-reduce mean: the cross-process generalization of
    /// [`crate::coordinator::allreduce_mean`] — sum with the pairing
    /// tree order, then one scale by 1/world on the kernel pool.
    pub fn allreduce_mean(&mut self, data: &mut [f32]) -> Result<()> {
        self.allreduce_sum(data)?;
        if self.world > 1 {
            let pool = crate::kernel::global();
            crate::kernel::scale(&pool, data, 1.0 / self.world as f32);
        }
        Ok(())
    }

    /// Broadcast `data` from `root` to every rank (binomial tree over
    /// root-relative ranks).
    pub fn broadcast(&mut self, data: &mut [f32], root: usize) -> Result<()> {
        if root >= self.world {
            bail!("broadcast root {root} out of range for world {}", self.world);
        }
        if self.world == 1 {
            return Ok(());
        }
        let seq = self.next_seq();
        let (rank, world) = (self.rank, self.world);
        let rel = (rank + world - root) % world;
        if rel != 0 {
            let parent = (tree_parent(rel) + root) % world;
            wire::recv_f32s_into(self.peer(parent)?, seq, data)?;
        }
        for &child_rel in tree_children(rel, world).iter().rev() {
            let child = (child_rel + root) % world;
            wire::send_f32s(self.peer(child)?, seq, data)?;
        }
        Ok(())
    }

    /// Gather every rank's equal-length contribution into
    /// `out[rank·len .. (rank+1)·len]` on all ranks (ring schedule).
    pub fn all_gather(&mut self, mine: &[f32], out: &mut [f32]) -> Result<()> {
        let k = mine.len();
        if out.len() != k * self.world {
            bail!(
                "all_gather output has {} elements, expected {} (world {} × {k})",
                out.len(),
                k * self.world,
                self.world
            );
        }
        let (rank, world) = (self.rank, self.world);
        out[rank * k..(rank + 1) * k].copy_from_slice(mine);
        if world == 1 {
            return Ok(());
        }
        let seq = self.next_seq();
        for s in 1..world {
            let dst = (rank + s) % world;
            let src = (rank + world - s) % world;
            let dst_conn = self.peer(dst)?;
            let src_conn = self.peer(src)?;
            let recv_slice = &mut out[src * k..(src + 1) * k];
            both_ways(
                || wire::send_f32s(dst_conn, seq, mine),
                || wire::recv_f32s_into(src_conn, seq, recv_slice),
            )?;
        }
        Ok(())
    }

    /// Block until every rank has reached this barrier (token reduce up
    /// the pairing tree, release broadcast back down).
    pub fn barrier(&mut self) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let seq = self.next_seq();
        let (rank, world) = (self.rank, self.world);
        let mut gap = 1;
        while gap < world {
            if rank % (2 * gap) == 0 {
                let src = rank + gap;
                if src < world {
                    self.expect_barrier(src, seq)?;
                }
            } else {
                wire::send_frame(self.peer(rank - gap)?, Kind::Barrier, seq, 0, &[])?;
                break;
            }
            gap *= 2;
        }
        if rank != 0 {
            self.expect_barrier(tree_parent(rank), seq)?;
        }
        for &child in tree_children(rank, world).iter().rev() {
            wire::send_frame(self.peer(child)?, Kind::Barrier, seq, 0, &[])?;
        }
        Ok(())
    }

    fn expect_barrier(&self, from: usize, seq: u64) -> Result<()> {
        let frame = wire::recv_frame(self.peer(from)?)?;
        if frame.kind != Kind::Barrier || frame.seq != seq {
            bail!(
                "collective protocol desync at barrier: got {:?} seq {} from rank {from}, \
                 expected barrier seq {seq}",
                frame.kind,
                frame.seq
            );
        }
        Ok(())
    }

    /// Stride-doubling pairing tree (identical association to the
    /// in-process `allreduce_mean_with`), then release broadcast of the
    /// rank-0 total.
    fn tree_allreduce(&self, seq: u64, data: &mut [f32]) -> Result<()> {
        let (rank, world) = (self.rank, self.world);
        let pool = crate::kernel::global();
        // allocated lazily at the first receive: leaf ranks (half the
        // world) only ever send and never pay for the scratch
        let mut scratch: Vec<f32> = Vec::new();
        let mut gap = 1;
        while gap < world {
            if rank % (2 * gap) == 0 {
                let src = rank + gap;
                if src < world {
                    if scratch.len() != data.len() {
                        scratch.resize(data.len(), 0.0);
                    }
                    wire::recv_f32s_into(self.peer(src)?, seq, &mut scratch)?;
                    crate::kernel::add_assign(&pool, data, &scratch);
                }
            } else {
                // this rank's partial is folded into rank − gap; it
                // waits for the release broadcast below
                wire::send_f32s(self.peer(rank - gap)?, seq, data)?;
                break;
            }
            gap *= 2;
        }
        if rank != 0 {
            wire::recv_f32s_into(self.peer(tree_parent(rank))?, seq, data)?;
        }
        for &child in tree_children(rank, world).iter().rev() {
            wire::send_f32s(self.peer(child)?, seq, data)?;
        }
        Ok(())
    }

    /// Chunked ring: ring-offset exchange of chunk copies, local
    /// pairing-tree reduce of the owned chunk on the kernel pool, ring
    /// all-gather of the reduced chunks. Bitwise identical to
    /// [`Self::tree_allreduce`] (see module docs).
    fn ring_allreduce(&self, seq: u64, data: &mut [f32]) -> Result<()> {
        let (rank, world) = (self.rank, self.world);
        let len = data.len();
        // chunk bounds are a pure function of (world, len)
        let bounds: Vec<usize> = (0..=world).map(|i| i * len / world).collect();
        let own = bounds[rank]..bounds[rank + 1];
        let own_len = own.len();
        let pool = crate::kernel::global();

        // phase 1 — exchange: step s sends our copy of rank (rank+s)'s
        // chunk and receives rank (rank−s)'s copy of ours, full duplex.
        let mut copies: Vec<Option<Vec<f32>>> = (0..world).map(|_| None).collect();
        for s in 1..world {
            let dst = (rank + s) % world;
            let src = (rank + world - s) % world;
            let send_chunk = &data[bounds[dst]..bounds[dst + 1]];
            let mut buf = vec![0.0f32; own_len];
            let dst_conn = self.peer(dst)?;
            let src_conn = self.peer(src)?;
            both_ways(
                || wire::send_f32s(dst_conn, seq, send_chunk),
                || wire::recv_f32s_into(src_conn, seq, &mut buf),
            )?;
            copies[src] = Some(buf);
        }

        // phase 2 — reduce the world copies of our chunk in rank order
        // with the pairing tree on the kernel pool: elementwise the
        // same association as the full-vector tree.
        let mut contrib: Vec<Vec<f32>> = (0..world)
            .map(|r| {
                if r == rank {
                    data[own.clone()].to_vec()
                } else {
                    copies[r].take().expect("phase 1 filled every peer slot")
                }
            })
            .collect();
        crate::kernel::tree_sum_vecs(&pool, &mut contrib);
        data[own.clone()].copy_from_slice(&contrib[0]);

        // phase 3 — all-gather the reduced chunks around the ring.
        let own_copy = std::mem::take(&mut contrib[0]);
        for s in 1..world {
            let dst = (rank + s) % world;
            let src = (rank + world - s) % world;
            let dst_conn = self.peer(dst)?;
            let src_conn = self.peer(src)?;
            let recv_slice = &mut data[bounds[src]..bounds[src + 1]];
            both_ways(
                || wire::send_f32s(dst_conn, seq, &own_copy),
                || wire::recv_f32s_into(src_conn, seq, recv_slice),
            )?;
        }
        Ok(())
    }
}

/// Run a send and a receive concurrently (the send on a scoped helper
/// thread) so every rank is always draining its inbound link while its
/// outbound one fills — the schedule stays deadlock-free at any payload
/// size, independent of socket buffer depth.
///
/// The per-call thread spawn (~10 µs) is a deliberate simplicity
/// tradeoff: it keeps the exchange logic free of persistent sender
/// state. If `benches/allreduce.rs` ever shows it dominating at small
/// payloads, a long-lived sender thread per peer is the follow-on
/// (ROADMAP: overlapped per-slot reduction).
fn both_ways<S, R>(send: S, recv: R) -> Result<()>
where
    S: FnOnce() -> Result<()> + Send,
    R: FnOnce() -> Result<()>,
{
    std::thread::scope(|scope| {
        let sender = scope.spawn(send);
        let recv_res = recv();
        let send_res = sender
            .join()
            .map_err(|_| anyhow::anyhow!("comm sender thread panicked"))?;
        send_res?;
        recv_res
    })
}

/// Parent of `rank` in the stride-doubling pairing tree: the rank it
/// sends its partial to (and receives the release broadcast from).
fn tree_parent(rank: usize) -> usize {
    debug_assert!(rank > 0);
    rank - (rank & rank.wrapping_neg())
}

/// Children of `rank`, in ascending-gap (reduce receive) order; the
/// release broadcast walks them in reverse.
fn tree_children(rank: usize, world: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut gap = 1;
    while gap < world {
        if rank % (2 * gap) != 0 {
            break;
        }
        if rank + gap < world {
            out.push(rank + gap);
        }
        gap *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_topology_matches_the_pairing_order() {
        // world 4: 1→0 and 3→2 at gap 1, then 2→0 at gap 2
        assert_eq!(tree_parent(1), 0);
        assert_eq!(tree_parent(2), 0);
        assert_eq!(tree_parent(3), 2);
        assert_eq!(tree_children(0, 4), vec![1, 2]);
        assert_eq!(tree_children(2, 4), vec![3]);
        assert_eq!(tree_children(1, 4), Vec::<usize>::new());
        // world 3: no partner for rank 2 at gap 1; it folds at gap 2
        assert_eq!(tree_children(0, 3), vec![1, 2]);
        assert_eq!(tree_parent(2), 0);
        // world 6: rank 4 receives 5, then folds into 0 at gap 4
        assert_eq!(tree_children(4, 6), vec![5]);
        assert_eq!(tree_parent(4), 0);
        assert_eq!(tree_children(0, 6), vec![1, 2, 4]);
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in [Algorithm::Ring, Algorithm::Tree, Algorithm::Auto] {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
        assert!(Algorithm::parse("carrier-pigeon").is_err());
    }
}
