//! The comm wire format — self-validating frames in the `ckpt::codec`
//! framing style (magic + dtype tag + trailing CRC-32, every field
//! length-prefixed and bounds-checked), built on the same
//! [`crate::ckpt::crc32`] implementation the checkpoint shards use.
//!
//! One frame on the stream:
//!
//! ```text
//! offset  size  field
//! 0       4     body length u32 LE (everything below; caps at MAX_BODY)
//! --- body (CRC-covered) ---
//! 0       4     magic  b"LRCM"
//! 4       4     version u32 LE (currently 2)
//! 8       1     kind  (0 = hello, 1 = data, 2 = barrier)
//! 9       1     dtype (0 = f32, 1 = bf16, 255 = none)
//! 10      8     seq  u64 LE — collective sequence number
//!                             (hello: the sender's wire-dtype tag)
//! 18      4     part u32 LE — chunk index within the collective
//!                             (hello: the sender's rank)
//! 22      4     element count u32 LE
//! 26      w·n   payload, little-endian; w = 4 (f32) or 2 (bf16)
//! --- trailer ---
//!         4     CRC-32 (IEEE) of the whole body
//! ```
//!
//! # The dtype lane
//!
//! Data frames carry their payload in one of two wire dtypes
//! ([`WireDtype`]): `F32` is the bit-exact lane (NaN-preserving,
//! lossless); `Bf16` is the compressed lane — each f32 is rounded to
//! bfloat16 (truncate with round-to-nearest-even, [`f32_to_bf16`]) on
//! send and widened back (exact: low mantissa bits zero-filled,
//! [`bf16_to_f32`]) on receive, halving the bytes on the wire. All
//! *arithmetic* stays f32 on the kernel pool; only the transport is
//! narrowed. The `dtype` header byte versions the lane: a peer that
//! does not speak a tag rejects the frame loudly ("dtype tag 1,
//! expected 0"), never misparses the payload.
//!
//! A truncated stream fails `read_exact` with a loud "truncated frame"
//! error; a corrupted body fails the CRC check; a frame from a
//! desynchronized peer fails the kind/seq/part validation in
//! [`crate::comm::collective`]; an oversized payload fails the checked
//! length encode *before* anything is written. Nothing is ever silently
//! resized, truncated, or skipped — a bad byte on the wire is an error,
//! not a hang and not a wrong gradient.

use anyhow::{anyhow, bail, Context, Result};

use super::transport::Conn;
use crate::ckpt::crc32::crc32;
use crate::kernel::simd;
use crate::obs::metrics;

pub const MAGIC: [u8; 4] = *b"LRCM";
/// Protocol version. 2 = the bf16 dtype lane plus the two-way connect
/// handshake (hello + ack). Version-1 builds never answered the ack,
/// so without this bump a mixed-build world would stall for the full
/// comm timeout instead of failing loudly — a version-1 peer now
/// rejects the very first frame with "unsupported comm frame version".
pub const VERSION: u32 = 2;

/// Sanity cap on one frame body: a length prefix past this is protocol
/// corruption, not data (collectives chunk payloads far below it).
pub const MAX_BODY: usize = 64 << 20;

/// Data frames carry at most this many elements; larger payloads
/// stream as a `part`-numbered frame sequence so the receiver can fold
/// chunks into the reduction while later chunks are still in flight.
pub const MAX_DATA_ELEMS: usize = 1 << 16;

/// Frame kinds (`kind` byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Connection handshake; `part` carries the sender's rank and `seq`
    /// the sender's wire-dtype tag (mixed-dtype worlds fail at connect).
    Hello,
    /// A payload chunk of a collective.
    Data,
    /// Zero-payload synchronization token.
    Barrier,
}

impl Kind {
    fn tag(self) -> u8 {
        match self {
            Kind::Hello => 0,
            Kind::Data => 1,
            Kind::Barrier => 2,
        }
    }

    fn from_tag(tag: u8) -> Result<Kind> {
        Ok(match tag {
            0 => Kind::Hello,
            1 => Kind::Data,
            2 => Kind::Barrier,
            other => bail!("unknown comm frame kind {other}"),
        })
    }
}

/// Payload encoding of a data frame — the wire compression lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireDtype {
    /// 4 bytes/element, bit-exact.
    F32,
    /// 2 bytes/element: f32 → bfloat16 round-to-nearest-even on send,
    /// exact widening on receive. Halves collective bandwidth.
    Bf16,
}

impl WireDtype {
    pub fn parse(s: &str) -> Result<WireDtype> {
        Ok(match s {
            "f32" => WireDtype::F32,
            "bf16" => WireDtype::Bf16,
            other => bail!("unknown comm wire dtype {other:?} (expected f32 or bf16)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            WireDtype::F32 => "f32",
            WireDtype::Bf16 => "bf16",
        }
    }

    /// The frame-header `dtype` byte for data frames of this lane.
    pub fn tag(self) -> u8 {
        match self {
            WireDtype::F32 => DTYPE_F32,
            WireDtype::Bf16 => DTYPE_BF16,
        }
    }

    pub fn from_tag(tag: u8) -> Result<WireDtype> {
        Ok(match tag {
            DTYPE_F32 => WireDtype::F32,
            DTYPE_BF16 => WireDtype::Bf16,
            other => bail!(
                "unknown comm data dtype tag {other} \
                 (this build speaks f32 = {DTYPE_F32} and bf16 = {DTYPE_BF16})"
            ),
        })
    }

    /// Bytes per payload element on the wire.
    pub fn elem_bytes(self) -> usize {
        match self {
            WireDtype::F32 => 4,
            WireDtype::Bf16 => 2,
        }
    }

    /// The `LOWRANK_COMM_DTYPE` env contract (`f32` | `bf16`, default
    /// `f32` when unset) — set for every rank by `lowrank-sge launch
    /// --comm-dtype`.
    pub fn from_env() -> Result<WireDtype> {
        match std::env::var("LOWRANK_COMM_DTYPE") {
            Ok(s) => WireDtype::parse(&s).context("bad LOWRANK_COMM_DTYPE"),
            Err(_) => Ok(WireDtype::F32),
        }
    }
}

const DTYPE_F32: u8 = 0;
const DTYPE_BF16: u8 = 1;
const DTYPE_NONE: u8 = 255;

/// Metrics lane for one frame's bytes, keyed by the raw header dtype
/// byte: data frames land on their wire-dtype lane, everything else
/// (hello/barrier, unknown tags) on the control lane. No-ops while the
/// metrics registry is disabled.
#[inline]
fn count_wire_bytes(sent: bool, dtype_byte: u8, bytes: usize) {
    let c = match (sent, dtype_byte) {
        (true, DTYPE_F32) => &metrics::WIRE_SENT_F32,
        (true, DTYPE_BF16) => &metrics::WIRE_SENT_BF16,
        (true, _) => &metrics::WIRE_SENT_CTRL,
        (false, DTYPE_F32) => &metrics::WIRE_RECV_F32,
        (false, DTYPE_BF16) => &metrics::WIRE_RECV_BF16,
        (false, _) => &metrics::WIRE_RECV_CTRL,
    };
    c.add(bytes as u64);
    if sent { &metrics::FRAMES_SENT } else { &metrics::FRAMES_RECV }.add(1);
}

/// f32 → bfloat16 bits, truncating with round-to-nearest-even (the
/// hardware convention). Sign and exponent survive exactly: ±0, ±∞,
/// and every subnormal round to their nearest bf16 neighbour, and NaNs
/// stay NaN (a mantissa bit is forced so a NaN whose high mantissa
/// bits are zero cannot quiet to ∞). The canonical definition (and the
/// 8-wide batch kernels the frame codec uses) lives in
/// [`crate::kernel::simd`]; this re-export keeps the wire API stable.
pub fn f32_to_bf16(x: f32) -> u16 {
    simd::f32_to_bf16(x)
}

/// bfloat16 bits → f32, exactly (low mantissa bits zero-filled).
pub fn bf16_to_f32(b: u16) -> f32 {
    simd::bf16_to_f32(b)
}

/// Round one f32 through bf16 and back — the value a `Bf16` receive
/// reconstructs. Idempotent: re-rounding an already-rounded value is
/// the identity, so re-sending a quantized payload is lossless.
pub fn bf16_round(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

/// Quantize a buffer in place to the bf16-representable grid
/// (elementwise, order-free — deterministic at any thread count).
/// Vectorized 8-wide where the dispatch allows; every backend computes
/// identical bits.
pub fn quantize_bf16(data: &mut [f32]) {
    simd::quantize_bf16_batch(data);
}

/// Elements per stack-buffered conversion block in the frame codec:
/// big enough to amortize the batch-kernel call, small enough to stay
/// comfortably on the stack (512 B as u16, 1 KB as f32).
const BF16_BLOCK: usize = 256;

/// A decoded frame header + payload (payload widened to f32 whatever
/// the wire dtype was).
#[derive(Debug)]
pub struct Frame {
    pub kind: Kind,
    pub seq: u64,
    pub part: u32,
    pub payload: Vec<f32>,
}

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Checked narrowing for the u32 wire length fields: a count that does
/// not fit is a loud error *before* any byte hits the stream — an
/// unchecked `as u32` here would silently truncate the field and
/// desync every frame after it.
fn checked_wire_u32(n: usize, what: &str) -> Result<u32> {
    u32::try_from(n).map_err(|_| {
        anyhow!("comm frame {what} {n} exceeds the u32 wire field — payload too large")
    })
}

/// Append one frame body (magic … CRC trailer, no length prefix) to
/// `out`; the CRC covers exactly the appended bytes. Non-data kinds
/// must carry an empty payload and are tagged dtype-none.
fn encode_body_into(
    out: &mut Vec<u8>,
    kind: Kind,
    seq: u64,
    part: u32,
    payload: &[f32],
    dtype: WireDtype,
) -> Result<()> {
    if kind != Kind::Data && !payload.is_empty() {
        bail!("comm frame kind {kind:?} cannot carry a payload");
    }
    let count = checked_wire_u32(payload.len(), "element count")?;
    let start = out.len();
    out.reserve(30 + dtype.elem_bytes() * payload.len());
    out.extend_from_slice(&MAGIC);
    put_u32(out, VERSION);
    out.push(kind.tag());
    out.push(if kind == Kind::Data { dtype.tag() } else { DTYPE_NONE });
    out.extend_from_slice(&seq.to_le_bytes());
    put_u32(out, part);
    put_u32(out, count);
    match dtype {
        WireDtype::F32 => {
            for v in payload {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        WireDtype::Bf16 => {
            // convert through the 8-wide batch kernel in stack-buffered
            // blocks instead of a scalar round per element
            let mut lanes = [0u16; BF16_BLOCK];
            let mut bytes = [0u8; 2 * BF16_BLOCK];
            for chunk in payload.chunks(BF16_BLOCK) {
                let lanes = &mut lanes[..chunk.len()];
                simd::f32_to_bf16_batch(chunk, lanes);
                for (dst, b) in bytes.chunks_exact_mut(2).zip(lanes.iter()) {
                    dst.copy_from_slice(&b.to_le_bytes());
                }
                out.extend_from_slice(&bytes[..2 * chunk.len()]);
            }
        }
    }
    let crc = crc32(&out[start..]);
    put_u32(out, crc);
    Ok(())
}

/// Encode one frame body (magic … CRC trailer, no length prefix).
pub fn encode_body(
    kind: Kind,
    seq: u64,
    part: u32,
    payload: &[f32],
    dtype: WireDtype,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    encode_body_into(&mut out, kind, seq, part, payload, dtype)?;
    Ok(out)
}

/// A validated frame header (payload bytes returned alongside).
#[derive(Clone, Copy, Debug)]
struct Header {
    kind: Kind,
    /// Raw dtype byte (`DTYPE_NONE` on non-data frames).
    dtype: u8,
    seq: u64,
    part: u32,
}

/// CRC-verify and structurally validate one frame body; returns the
/// header plus the raw little-endian payload bytes — the zero-copy
/// core both [`decode_body`] and [`recv_f32s_into`] share.
fn split_verified(body: &[u8]) -> Result<(Header, &[u8])> {
    // magic(4) version(4) kind(1) dtype(1) seq(8) part(4) count(4) crc(4)
    const MIN: usize = 30;
    if body.len() < MIN {
        bail!("truncated comm frame: {} bytes is below the minimum", body.len());
    }
    let (inner, trailer) = body.split_at(body.len() - 4);
    let stored = u32::from_le_bytes(trailer.try_into().unwrap());
    let actual = crc32(inner);
    if stored != actual {
        bail!(
            "CRC32 mismatch in comm frame: stored {stored:#010x}, computed {actual:#010x} \
             — the frame was corrupted in transit"
        );
    }
    if inner[0..4] != MAGIC {
        bail!("bad magic: not a lowrank-sge comm frame");
    }
    let version = u32::from_le_bytes(inner[4..8].try_into().unwrap());
    if version != VERSION {
        bail!("unsupported comm frame version {version} (expected {VERSION})");
    }
    let kind = Kind::from_tag(inner[8])?;
    let dtype = inner[9];
    let seq = u64::from_le_bytes(inner[10..18].try_into().unwrap());
    let part = u32::from_le_bytes(inner[18..22].try_into().unwrap());
    let count = u32::from_le_bytes(inner[22..26].try_into().unwrap()) as usize;
    let elem_bytes = if kind == Kind::Data {
        // unknown tags (a future lane, or a peer newer than this build)
        // are a loud rejection, not a misparse
        WireDtype::from_tag(dtype)?.elem_bytes()
    } else {
        if dtype != DTYPE_NONE {
            bail!("comm frame kind {kind:?} has dtype tag {dtype}, expected {DTYPE_NONE}");
        }
        4
    };
    let payload_bytes = inner.len() - 26;
    if payload_bytes != elem_bytes * count {
        bail!(
            "comm frame length mismatch: {count} elements declared ({elem_bytes} bytes each), \
             {payload_bytes} payload bytes"
        );
    }
    Ok((Header { kind, dtype, seq, part }, &inner[26..]))
}

/// Widen the raw payload bytes of a verified data frame into `out`
/// (`out.len()` must equal the frame's element count).
fn widen_payload(dtype: WireDtype, payload_bytes: &[u8], out: &mut [f32]) {
    match dtype {
        WireDtype::F32 => {
            for (dst, src) in out.iter_mut().zip(payload_bytes.chunks_exact(4)) {
                *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
            }
        }
        WireDtype::Bf16 => {
            // stack-buffered blocks through the 8-wide widen kernel
            let mut lanes = [0u16; BF16_BLOCK];
            for (dst_block, src_block) in
                out.chunks_mut(BF16_BLOCK).zip(payload_bytes.chunks(2 * BF16_BLOCK))
            {
                let lanes = &mut lanes[..dst_block.len()];
                for (l, src) in lanes.iter_mut().zip(src_block.chunks_exact(2)) {
                    *l = u16::from_le_bytes([src[0], src[1]]);
                }
                simd::bf16_to_f32_batch(lanes, dst_block);
            }
        }
    }
}

/// Decode and fully validate one frame body.
pub fn decode_body(body: &[u8]) -> Result<Frame> {
    let (h, payload_bytes) = split_verified(body)?;
    let payload = if h.kind == Kind::Data {
        let dtype = WireDtype::from_tag(h.dtype)?;
        let mut out = vec![0.0f32; payload_bytes.len() / dtype.elem_bytes()];
        widen_payload(dtype, payload_bytes, &mut out);
        out
    } else {
        Vec::new()
    };
    Ok(Frame { kind: h.kind, seq: h.seq, part: h.part, payload })
}

/// Write one length-prefixed frame to a connection. The prefix is
/// reserved up front in the same buffer, so the payload is materialized
/// exactly once before the single write.
pub fn send_frame(
    conn: &Conn,
    kind: Kind,
    seq: u64,
    part: u32,
    payload: &[f32],
    dtype: WireDtype,
) -> Result<()> {
    let mut msg = Vec::with_capacity(34 + dtype.elem_bytes() * payload.len());
    msg.extend_from_slice(&[0u8; 4]); // length prefix, patched below
    encode_body_into(&mut msg, kind, seq, part, payload, dtype)?;
    let body_len = checked_wire_u32(msg.len() - 4, "body length")?;
    msg[..4].copy_from_slice(&body_len.to_le_bytes());
    count_wire_bytes(true, if kind == Kind::Data { dtype.tag() } else { DTYPE_NONE }, msg.len());
    conn.write_all(&msg)
        .with_context(|| format!("sending comm frame (kind {kind:?}, seq {seq}, part {part})"))
}

/// Read one length-prefixed frame from a connection, verifying CRC and
/// structure. A peer that disappears mid-frame yields a "truncated
/// frame" / timeout error, never a partial payload.
pub fn recv_frame(conn: &Conn) -> Result<Frame> {
    let mut len_buf = [0u8; 4];
    conn.read_exact(&mut len_buf)
        .context("receiving comm frame header")?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_BODY {
        bail!("comm frame length {len} exceeds the {MAX_BODY}-byte cap — protocol corruption");
    }
    let mut body = vec![0u8; len];
    conn.read_exact(&mut body)
        .context("receiving comm frame body (truncated frame?)")?;
    // lane from the raw header dtype byte (magic 4 + version 4 + kind 1);
    // validation happens in decode_body — for accounting the claim is fine
    let lane = if body.len() > 9 && body[8] == Kind::Data.tag() { body[9] } else { DTYPE_NONE };
    count_wire_bytes(false, lane, 4 + body.len());
    decode_body(&body)
}

/// Stream a payload as a `part`-numbered sequence of data frames in the
/// given wire dtype. Zero-length payloads send nothing (both sides know
/// the length). With `Bf16` each element is rounded to nearest-even on
/// the way out; sending an already-quantized buffer is lossless.
pub fn send_f32s(conn: &Conn, seq: u64, data: &[f32], dtype: WireDtype) -> Result<()> {
    for (part, chunk) in data.chunks(MAX_DATA_ELEMS).enumerate() {
        send_frame(conn, Kind::Data, seq, part as u32, chunk, dtype)?;
    }
    Ok(())
}

/// Receive a payload streamed by [`send_f32s`] into `out`, validating
/// the collective sequence number, chunk order, and wire dtype frame by
/// frame — a peer configured with a different `LOWRANK_COMM_DTYPE` is a
/// loud dtype-mismatch error, never a misparsed gradient.
///
/// One byte buffer is reused across all chunks and the payload is
/// decoded straight into `out` — no per-chunk `Vec<f32>` on the
/// bandwidth-critical all-reduce path.
pub fn recv_f32s_into(conn: &Conn, seq: u64, out: &mut [f32], dtype: WireDtype) -> Result<()> {
    let mut filled = 0usize;
    let mut part = 0u32;
    let mut body = Vec::new();
    while filled < out.len() {
        let mut len_buf = [0u8; 4];
        conn.read_exact(&mut len_buf)
            .context("receiving comm frame header")?;
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > MAX_BODY {
            bail!("comm frame length {len} exceeds the {MAX_BODY}-byte cap — protocol corruption");
        }
        body.resize(len, 0);
        conn.read_exact(&mut body)
            .context("receiving comm frame body (truncated frame?)")?;
        count_wire_bytes(false, dtype.tag(), 4 + body.len());
        let (h, payload_bytes) = split_verified(&body)?;
        if h.kind != Kind::Data {
            bail!("collective protocol desync: expected data frame, got {:?}", h.kind);
        }
        if h.dtype != dtype.tag() {
            bail!(
                "comm wire dtype mismatch: peer sent dtype tag {} but this rank speaks {} \
                 (tag {}) — set --comm-dtype/LOWRANK_COMM_DTYPE identically on every rank",
                h.dtype,
                dtype.name(),
                dtype.tag()
            );
        }
        if h.seq != seq || h.part != part {
            bail!(
                "collective protocol desync: expected seq {seq} part {part}, \
                 got seq {} part {}",
                h.seq,
                h.part
            );
        }
        let want = (out.len() - filled).min(MAX_DATA_ELEMS);
        if payload_bytes.len() != dtype.elem_bytes() * want {
            bail!(
                "collective protocol desync: expected {want}-element chunk, got {} elements",
                payload_bytes.len() / dtype.elem_bytes()
            );
        }
        widen_payload(dtype, payload_bytes, &mut out[filled..filled + want]);
        filled += want;
        part += 1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn body_roundtrip_preserves_every_bit() {
        let payload = vec![1.0f32, -0.0, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE, 3e38];
        let body = encode_body(Kind::Data, 77, 3, &payload, WireDtype::F32).unwrap();
        let frame = decode_body(&body).unwrap();
        assert_eq!(frame.kind, Kind::Data);
        assert_eq!((frame.seq, frame.part), (77, 3));
        for (a, b) in payload.iter().zip(&frame.payload) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_body_roundtrip_is_the_rounded_value() {
        let payload = vec![1.0f32, -2.5, 0.1, 1e-3, -3.0e38, 65536.0 + 1.0];
        let body = encode_body(Kind::Data, 9, 0, &payload, WireDtype::Bf16).unwrap();
        // the wire really is 2 bytes/element
        assert_eq!(body.len(), 30 + 2 * payload.len());
        let frame = decode_body(&body).unwrap();
        for (a, b) in payload.iter().zip(&frame.payload) {
            assert_eq!(bf16_round(*a).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_rounding_semantics() {
        // exact values survive the round trip bit-for-bit
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0, f32::INFINITY, f32::NEG_INFINITY] {
            assert_eq!(bf16_round(v).to_bits(), v.to_bits(), "{v} not preserved");
        }
        // ±0 keep their sign bit
        assert_eq!(f32_to_bf16(-0.0), 0x8000);
        assert_eq!(f32_to_bf16(0.0), 0x0000);
        // NaN stays NaN — including one whose high mantissa bits are 0,
        // which naive truncation would quiet to ∞
        let sneaky_nan = f32::from_bits(0x7F80_0001);
        assert!(sneaky_nan.is_nan());
        assert!(bf16_to_f32(f32_to_bf16(sneaky_nan)).is_nan());
        assert!(bf16_round(f32::NAN).is_nan());
        // subnormals: representable ones survive, others round to a
        // neighbouring subnormal (never to a garbage normal)
        let sub = f32::from_bits(0x0001_0000); // a bf16-representable subnormal
        assert_eq!(bf16_round(sub).to_bits(), sub.to_bits());
        let tiny = f32::MIN_POSITIVE / 2.0; // subnormal in f32
        let r = bf16_round(tiny);
        assert!(r == 0.0 || (r > 0.0 && r < f32::MIN_POSITIVE), "subnormal rounded to {r}");
        // round-to-nearest-even at a tie: 1 + 2^-8 is exactly between
        // 1.0 and the next bf16 (1 + 2^-7); the even mantissa (1.0) wins
        assert_eq!(bf16_round(1.0 + 2f32.powi(-8)), 1.0);
        // ... and 1 + 3·2^-8 ties upward to the even 1 + 2^-6
        assert_eq!(bf16_round(1.0 + 3.0 * 2f32.powi(-8)), 1.0 + 2f32.powi(-6));
        // rounding is deterministic
        for i in 0..1000u32 {
            let v = f32::from_bits(0x3F80_0000 + i * 7919);
            assert_eq!(f32_to_bf16(v), f32_to_bf16(v));
        }
        // idempotent: the grid is closed under re-rounding
        for v in [0.1f32, 3.7e-5, -123.456, 8.5e30] {
            let once = bf16_round(v);
            assert_eq!(bf16_round(once).to_bits(), once.to_bits());
        }
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        for dtype in [WireDtype::F32, WireDtype::Bf16] {
            let body = encode_body(Kind::Data, 5, 0, &[1.5, -2.5, 0.25], dtype).unwrap();
            for i in 0..body.len() {
                let mut bad = body.clone();
                bad[i] ^= 0x20;
                assert!(
                    decode_body(&bad).is_err(),
                    "flip at byte {i} not detected ({})",
                    dtype.name()
                );
            }
        }
    }

    #[test]
    fn truncation_is_detected_at_every_length() {
        let body = encode_body(Kind::Barrier, 9, 0, &[], WireDtype::F32).unwrap();
        for cut in 0..body.len() {
            assert!(decode_body(&body[..cut]).is_err(), "truncation to {cut} not detected");
        }
    }

    #[test]
    fn non_data_frames_reject_payloads() {
        assert!(encode_body(Kind::Barrier, 1, 0, &[1.0], WireDtype::F32).is_err());
        // hand-build a barrier frame claiming an f32 dtype tag
        let mut body = encode_body(Kind::Barrier, 1, 0, &[], WireDtype::F32).unwrap();
        body[9] = DTYPE_F32; // dtype = f32 on a barrier frame
        let n = body.len();
        let crc = crc32(&body[..n - 4]);
        body[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_body(&body).unwrap_err().to_string();
        assert!(err.contains("dtype"), "{err}");
    }

    #[test]
    fn unknown_dtype_tag_is_rejected_loudly() {
        let mut body = encode_body(Kind::Data, 3, 0, &[1.0, 2.0], WireDtype::F32).unwrap();
        body[9] = 7; // a lane this build does not speak
        let n = body.len();
        let crc = crc32(&body[..n - 4]);
        body[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_body(&body).unwrap_err().to_string();
        assert!(err.contains("dtype tag 7"), "{err}");
    }

    #[test]
    fn oversized_length_fields_are_checked_errors_at_the_boundary() {
        // the u32 field boundary itself (no 16 GiB allocation needed —
        // the check is pure arithmetic)
        assert_eq!(checked_wire_u32(u32::MAX as usize, "element count").unwrap(), u32::MAX);
        let err = checked_wire_u32(u32::MAX as usize + 1, "element count")
            .unwrap_err()
            .to_string();
        assert!(err.contains("element count") && err.contains("u32"), "{err}");
        let err = checked_wire_u32(usize::MAX, "body length").unwrap_err().to_string();
        assert!(err.contains("body length"), "{err}");
    }
}
