//! The comm wire format — self-validating frames in the `ckpt::codec`
//! framing style (magic + dtype tag + trailing CRC-32, every field
//! length-prefixed and bounds-checked), built on the same
//! [`crate::ckpt::crc32`] implementation the checkpoint shards use.
//!
//! One frame on the stream:
//!
//! ```text
//! offset  size  field
//! 0       4     body length u32 LE (everything below; caps at MAX_BODY)
//! --- body (CRC-covered) ---
//! 0       4     magic  b"LRCM"
//! 4       4     version u32 LE (currently 1)
//! 8       1     kind  (0 = hello, 1 = data, 2 = barrier)
//! 9       1     dtype (0 = f32, 255 = none)
//! 10      8     seq  u64 LE — collective sequence number
//! 18      4     part u32 LE — chunk index within the collective
//!                             (hello: the sender's rank)
//! 22      4     element count u32 LE
//! 26      4·n   payload, little-endian f32 (bit-exact, NaN-preserving)
//! --- trailer ---
//!         4     CRC-32 (IEEE) of the whole body
//! ```
//!
//! A truncated stream fails `read_exact` with a loud "truncated frame"
//! error; a corrupted body fails the CRC check; a frame from a
//! desynchronized peer fails the kind/seq/part validation in
//! [`crate::comm::collective`]. Nothing is ever silently resized or
//! skipped — a bad byte on the wire is an error, not a hang and not a
//! wrong gradient.

use anyhow::{bail, Context, Result};

use super::transport::Conn;
use crate::ckpt::crc32::crc32;

pub const MAGIC: [u8; 4] = *b"LRCM";
pub const VERSION: u32 = 1;

/// Sanity cap on one frame body: a length prefix past this is protocol
/// corruption, not data (collectives chunk payloads far below it).
pub const MAX_BODY: usize = 64 << 20;

/// Data frames carry at most this many f32 elements; larger payloads
/// stream as a `part`-numbered frame sequence so the receiver can fold
/// chunks into the reduction while later chunks are still in flight.
pub const MAX_DATA_ELEMS: usize = 1 << 16;

/// Frame kinds (`kind` byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Connection handshake; `part` carries the sender's rank.
    Hello,
    /// A payload chunk of a collective.
    Data,
    /// Zero-payload synchronization token.
    Barrier,
}

impl Kind {
    fn tag(self) -> u8 {
        match self {
            Kind::Hello => 0,
            Kind::Data => 1,
            Kind::Barrier => 2,
        }
    }

    fn from_tag(tag: u8) -> Result<Kind> {
        Ok(match tag {
            0 => Kind::Hello,
            1 => Kind::Data,
            2 => Kind::Barrier,
            other => bail!("unknown comm frame kind {other}"),
        })
    }
}

const DTYPE_F32: u8 = 0;
const DTYPE_NONE: u8 = 255;

/// A decoded frame header + payload.
#[derive(Debug)]
pub struct Frame {
    pub kind: Kind,
    pub seq: u64,
    pub part: u32,
    pub payload: Vec<f32>,
}

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append one frame body (magic … CRC trailer, no length prefix) to
/// `out`; the CRC covers exactly the appended bytes.
fn encode_body_into(out: &mut Vec<u8>, kind: Kind, seq: u64, part: u32, payload: &[f32]) {
    let start = out.len();
    out.reserve(30 + 4 * payload.len());
    out.extend_from_slice(&MAGIC);
    put_u32(out, VERSION);
    out.push(kind.tag());
    out.push(if kind == Kind::Data { DTYPE_F32 } else { DTYPE_NONE });
    out.extend_from_slice(&seq.to_le_bytes());
    put_u32(out, part);
    put_u32(out, payload.len() as u32);
    for v in payload {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let crc = crc32(&out[start..]);
    put_u32(out, crc);
}

/// Encode one frame body (magic … CRC trailer, no length prefix).
pub fn encode_body(kind: Kind, seq: u64, part: u32, payload: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    encode_body_into(&mut out, kind, seq, part, payload);
    out
}

/// A validated frame header (payload bytes returned alongside).
#[derive(Clone, Copy, Debug)]
struct Header {
    kind: Kind,
    seq: u64,
    part: u32,
}

/// CRC-verify and structurally validate one frame body; returns the
/// header plus the raw little-endian payload bytes — the zero-copy
/// core both [`decode_body`] and [`recv_f32s_into`] share.
fn split_verified(body: &[u8]) -> Result<(Header, &[u8])> {
    // magic(4) version(4) kind(1) dtype(1) seq(8) part(4) count(4) crc(4)
    const MIN: usize = 30;
    if body.len() < MIN {
        bail!("truncated comm frame: {} bytes is below the minimum", body.len());
    }
    let (inner, trailer) = body.split_at(body.len() - 4);
    let stored = u32::from_le_bytes(trailer.try_into().unwrap());
    let actual = crc32(inner);
    if stored != actual {
        bail!(
            "CRC32 mismatch in comm frame: stored {stored:#010x}, computed {actual:#010x} \
             — the frame was corrupted in transit"
        );
    }
    if inner[0..4] != MAGIC {
        bail!("bad magic: not a lowrank-sge comm frame");
    }
    let version = u32::from_le_bytes(inner[4..8].try_into().unwrap());
    if version != VERSION {
        bail!("unsupported comm frame version {version} (expected {VERSION})");
    }
    let kind = Kind::from_tag(inner[8])?;
    let dtype = inner[9];
    let seq = u64::from_le_bytes(inner[10..18].try_into().unwrap());
    let part = u32::from_le_bytes(inner[18..22].try_into().unwrap());
    let count = u32::from_le_bytes(inner[22..26].try_into().unwrap()) as usize;
    let expected_dtype = if kind == Kind::Data { DTYPE_F32 } else { DTYPE_NONE };
    if dtype != expected_dtype {
        bail!("comm frame kind {kind:?} has dtype tag {dtype}, expected {expected_dtype}");
    }
    let payload_bytes = inner.len() - 26;
    if payload_bytes != 4 * count {
        bail!(
            "comm frame length mismatch: {count} elements declared, {payload_bytes} payload bytes"
        );
    }
    Ok((Header { kind, seq, part }, &inner[26..]))
}

/// Decode and fully validate one frame body.
pub fn decode_body(body: &[u8]) -> Result<Frame> {
    let (h, payload_bytes) = split_verified(body)?;
    let payload = payload_bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Frame { kind: h.kind, seq: h.seq, part: h.part, payload })
}

/// Write one length-prefixed frame to a connection. The prefix is
/// reserved up front in the same buffer, so the payload is materialized
/// exactly once before the single write.
pub fn send_frame(conn: &Conn, kind: Kind, seq: u64, part: u32, payload: &[f32]) -> Result<()> {
    let mut msg = Vec::with_capacity(34 + 4 * payload.len());
    msg.extend_from_slice(&[0u8; 4]); // length prefix, patched below
    encode_body_into(&mut msg, kind, seq, part, payload);
    let body_len = (msg.len() - 4) as u32;
    msg[..4].copy_from_slice(&body_len.to_le_bytes());
    conn.write_all(&msg)
        .with_context(|| format!("sending comm frame (kind {kind:?}, seq {seq}, part {part})"))
}

/// Read one length-prefixed frame from a connection, verifying CRC and
/// structure. A peer that disappears mid-frame yields a "truncated
/// frame" / timeout error, never a partial payload.
pub fn recv_frame(conn: &Conn) -> Result<Frame> {
    let mut len_buf = [0u8; 4];
    conn.read_exact(&mut len_buf)
        .context("receiving comm frame header")?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_BODY {
        bail!("comm frame length {len} exceeds the {MAX_BODY}-byte cap — protocol corruption");
    }
    let mut body = vec![0u8; len];
    conn.read_exact(&mut body)
        .context("receiving comm frame body (truncated frame?)")?;
    decode_body(&body)
}

/// Stream a payload as a `part`-numbered sequence of data frames.
/// Zero-length payloads send nothing (both sides know the length).
pub fn send_f32s(conn: &Conn, seq: u64, data: &[f32]) -> Result<()> {
    for (part, chunk) in data.chunks(MAX_DATA_ELEMS).enumerate() {
        send_frame(conn, Kind::Data, seq, part as u32, chunk)?;
    }
    Ok(())
}

/// Receive a payload streamed by [`send_f32s`] into `out`, validating
/// the collective sequence number and chunk order frame by frame.
///
/// One byte buffer is reused across all chunks and the payload is
/// decoded straight into `out` — no per-chunk `Vec<f32>` on the
/// bandwidth-critical all-reduce path.
pub fn recv_f32s_into(conn: &Conn, seq: u64, out: &mut [f32]) -> Result<()> {
    let mut filled = 0usize;
    let mut part = 0u32;
    let mut body = Vec::new();
    while filled < out.len() {
        let mut len_buf = [0u8; 4];
        conn.read_exact(&mut len_buf)
            .context("receiving comm frame header")?;
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > MAX_BODY {
            bail!("comm frame length {len} exceeds the {MAX_BODY}-byte cap — protocol corruption");
        }
        body.resize(len, 0);
        conn.read_exact(&mut body)
            .context("receiving comm frame body (truncated frame?)")?;
        let (h, payload_bytes) = split_verified(&body)?;
        if h.kind != Kind::Data {
            bail!("collective protocol desync: expected data frame, got {:?}", h.kind);
        }
        if h.seq != seq || h.part != part {
            bail!(
                "collective protocol desync: expected seq {seq} part {part}, \
                 got seq {} part {}",
                h.seq,
                h.part
            );
        }
        let want = (out.len() - filled).min(MAX_DATA_ELEMS);
        if payload_bytes.len() != 4 * want {
            bail!(
                "collective protocol desync: expected {want}-element chunk, got {} elements",
                payload_bytes.len() / 4
            );
        }
        for (dst, src) in out[filled..filled + want]
            .iter_mut()
            .zip(payload_bytes.chunks_exact(4))
        {
            *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
        }
        filled += want;
        part += 1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn body_roundtrip_preserves_every_bit() {
        let payload = vec![1.0f32, -0.0, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE, 3e38];
        let body = encode_body(Kind::Data, 77, 3, &payload);
        let frame = decode_body(&body).unwrap();
        assert_eq!(frame.kind, Kind::Data);
        assert_eq!((frame.seq, frame.part), (77, 3));
        for (a, b) in payload.iter().zip(&frame.payload) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let body = encode_body(Kind::Data, 5, 0, &[1.5, -2.5, 0.25]);
        for i in 0..body.len() {
            let mut bad = body.clone();
            bad[i] ^= 0x20;
            assert!(decode_body(&bad).is_err(), "flip at byte {i} not detected");
        }
    }

    #[test]
    fn truncation_is_detected_at_every_length() {
        let body = encode_body(Kind::Barrier, 9, 0, &[]);
        for cut in 0..body.len() {
            assert!(decode_body(&body[..cut]).is_err(), "truncation to {cut} not detected");
        }
    }

    #[test]
    fn non_data_frames_reject_payloads() {
        // hand-build a barrier frame claiming an f32 payload
        let mut body = encode_body(Kind::Barrier, 1, 0, &[]);
        body[9] = 0; // dtype = f32 on a barrier frame
        let n = body.len();
        let crc = crc32(&body[..n - 4]);
        body[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_body(&body).unwrap_err().to_string();
        assert!(err.contains("dtype"), "{err}");
    }
}
