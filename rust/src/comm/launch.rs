//! The `launch` runner — torchrun-style local multi-process spawner.
//!
//! `lowrank-sge launch --nproc N <subcommand …>` re-executes the current
//! binary N times with the child argv, wiring each child into one
//! collective group through the env-var rendezvous
//! ([`crate::comm::Communicator::from_env`]): a fresh rendezvous
//! directory, explicit ranks 0..N, shared world size / transport /
//! timeout. Child stdout/stderr are line-multiplexed onto the parent's
//! with a `[rank r]` prefix, and the first non-zero child exit status
//! is propagated as the runner's own.
//!
//! Everything else (threads, checkpoint flags, config files) passes
//! through untouched — the children parse the exact argv the operator
//! wrote after `launch`'s own flags.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Context, Result};

use super::transport::TransportKind;

/// Options of the runner itself (everything before the child command).
#[derive(Clone, Debug)]
pub struct LaunchOptions {
    /// Number of ranks to spawn.
    pub nproc: usize,
    pub transport: TransportKind,
    /// Rendezvous directory; a fresh per-launch temp dir when `None`.
    pub rdzv_dir: Option<PathBuf>,
    /// Comm timeout handed to the children (`LOWRANK_COMM_TIMEOUT_MS`).
    pub timeout_ms: u64,
    /// Collective algorithm override (`ring`|`tree`|`auto`).
    pub algo: Option<String>,
}

impl Default for LaunchOptions {
    fn default() -> Self {
        LaunchOptions {
            nproc: 2,
            transport: TransportKind::default_for_host(),
            rdzv_dir: None,
            timeout_ms: 120_000,
            algo: None,
        }
    }
}

/// Distinguishes concurrent launches inside one parent process.
static LAUNCH_COUNTER: AtomicUsize = AtomicUsize::new(0);

/// Spawn `nproc` ranks of the current binary running `child_args`,
/// multiplex their output, and return the first non-zero exit code in
/// rank order (0 when every rank succeeded).
pub fn run_launch(opts: &LaunchOptions, child_args: &[String]) -> Result<i32> {
    if opts.nproc == 0 {
        bail!("launch: --nproc must be >= 1");
    }
    if child_args.is_empty() {
        bail!("launch: missing child command (e.g. `launch --nproc 2 pretrain --steps 100`)");
    }
    let exe = std::env::current_exe().context("resolving the lowrank-sge binary path")?;
    // The rendezvous must start empty: stale claim/addr files from a
    // previous run would assign ranks from a dead world. Our own temp
    // dir is safe to clear; an operator-supplied dir is NOT ours to
    // wipe — refuse a non-empty one instead of destroying its contents.
    let rdzv = match &opts.rdzv_dir {
        Some(d) => {
            std::fs::create_dir_all(d).with_context(|| format!("creating {d:?}"))?;
            let occupied = std::fs::read_dir(d)
                .with_context(|| format!("listing {d:?}"))?
                .next()
                .is_some();
            if occupied {
                bail!(
                    "launch: --rdzv-dir {d:?} is not empty — point it at a fresh directory \
                     (stale rendezvous files would corrupt rank assignment)"
                );
            }
            d.clone()
        }
        None => {
            let d = std::env::temp_dir().join(format!(
                "lowrank-launch-{}-{}",
                std::process::id(),
                LAUNCH_COUNTER.fetch_add(1, Ordering::SeqCst)
            ));
            if d.exists() {
                std::fs::remove_dir_all(&d).with_context(|| format!("clearing stale {d:?}"))?;
            }
            std::fs::create_dir_all(&d)?;
            d
        }
    };

    let mut children = Vec::with_capacity(opts.nproc);
    for rank in 0..opts.nproc {
        let mut cmd = Command::new(&exe);
        cmd.args(child_args)
            .env("LOWRANK_COMM_RDZV", &rdzv)
            .env("LOWRANK_COMM_WORLD", opts.nproc.to_string())
            .env("LOWRANK_COMM_RANK", rank.to_string())
            .env("LOWRANK_COMM_TRANSPORT", opts.transport.name())
            .env("LOWRANK_COMM_TIMEOUT_MS", opts.timeout_ms.to_string())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped());
        if let Some(algo) = &opts.algo {
            cmd.env("LOWRANK_COMM_ALGO", algo);
        }
        let mut child = cmd
            .spawn()
            .with_context(|| format!("spawning rank {rank} ({})", exe.display()))?;
        let out_pump = pump(child.stdout.take().expect("piped stdout"), rank, false);
        let err_pump = pump(child.stderr.take().expect("piped stderr"), rank, true);
        children.push((rank, child, out_pump, err_pump));
    }

    let mut first_failure = 0i32;
    for (rank, mut child, out_pump, err_pump) in children {
        let status = child
            .wait()
            .with_context(|| format!("waiting for rank {rank}"))?;
        let _ = out_pump.join();
        let _ = err_pump.join();
        if !status.success() && first_failure == 0 {
            // signal-killed children have no code; report a generic 101
            first_failure = status.code().unwrap_or(101);
            eprintln!("launch: rank {rank} exited with {status}");
        }
    }
    // only our own temp dir is removed; an operator-supplied dir keeps
    // its (now-stale) rendezvous files for post-mortem inspection
    if opts.rdzv_dir.is_none() {
        let _ = std::fs::remove_dir_all(&rdzv);
    }
    Ok(first_failure)
}

/// Forward one child stream line-by-line with a `[rank r]` prefix.
fn pump(
    stream: impl std::io::Read + Send + 'static,
    rank: usize,
    is_err: bool,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if is_err {
                eprintln!("[rank {rank}] {line}");
            } else {
                println!("[rank {rank}] {line}");
            }
        }
    })
}
