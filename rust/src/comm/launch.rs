//! The `launch` runner — torchrun-style local multi-process spawner.
//!
//! `lowrank-sge launch --nproc N <subcommand …>` re-executes the current
//! binary N times with the child argv, wiring each child into one
//! collective group through the env-var rendezvous
//! ([`crate::comm::Communicator::from_env`]): a fresh rendezvous
//! directory stamped with a per-launch run token, explicit ranks 0..N,
//! shared world size / transport / timeout / wire dtype. Child
//! stdout/stderr are line-multiplexed onto the parent's with a
//! `[rank r]` prefix, and the first non-zero child exit status is
//! propagated as the runner's own.
//!
//! Failure is fast, not quiet: the runner polls **all** ranks, and the
//! moment any rank exits non-zero it terminates the survivors and
//! returns — a rank that dies before rendezvous no longer leaves its
//! peers polling a dead address table until the full comm timeout (the
//! old runner waited on children strictly in rank order, so rank 0
//! could sit in that poll for minutes before the real failure was even
//! observed). The first non-zero status, earliest-exit first and
//! lowest-rank first within a poll sweep, still wins.
//!
//! Everything else (threads, checkpoint flags, config files) passes
//! through untouched — the children parse the exact argv the operator
//! wrote after `launch`'s own flags.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::transport::TransportKind;

/// Options of the runner itself (everything before the child command).
#[derive(Clone, Debug)]
pub struct LaunchOptions {
    /// Number of ranks to spawn.
    pub nproc: usize,
    pub transport: TransportKind,
    /// Rendezvous directory; a fresh per-launch temp dir when `None`.
    pub rdzv_dir: Option<PathBuf>,
    /// Comm timeout handed to the children (`LOWRANK_COMM_TIMEOUT_MS`).
    pub timeout_ms: u64,
    /// Collective algorithm override (`ring`|`tree`|`auto`).
    pub algo: Option<String>,
    /// Wire dtype override (`f32`|`bf16`), handed to the children as
    /// `LOWRANK_COMM_DTYPE`; `None` leaves the children's environment
    /// (and therefore the f32 default) in charge.
    pub comm_dtype: Option<String>,
}

impl Default for LaunchOptions {
    fn default() -> Self {
        LaunchOptions {
            nproc: 2,
            transport: TransportKind::default_for_host(),
            rdzv_dir: None,
            timeout_ms: 120_000,
            algo: None,
            comm_dtype: None,
        }
    }
}

/// Distinguishes concurrent launches inside one parent process.
static LAUNCH_COUNTER: AtomicUsize = AtomicUsize::new(0);

/// One spawned rank: its process handle plus the output pump threads
/// that must be joined after it exits.
type RankSlot = (usize, Child, JoinHandle<()>, JoinHandle<()>);

/// Spawn `nproc` ranks of the current binary running `child_args`,
/// multiplex their output, and return the first non-zero exit code
/// (0 when every rank succeeded). On the first failure the surviving
/// ranks are killed immediately.
pub fn run_launch(opts: &LaunchOptions, child_args: &[String]) -> Result<i32> {
    if opts.nproc == 0 {
        bail!("launch: --nproc must be >= 1");
    }
    if child_args.is_empty() {
        bail!("launch: missing child command (e.g. `launch --nproc 2 pretrain --steps 100`)");
    }
    let exe = std::env::current_exe().context("resolving the lowrank-sge binary path")?;
    let launch_id = LAUNCH_COUNTER.fetch_add(1, Ordering::SeqCst);
    // The rendezvous must start empty: stale claim/addr files from a
    // previous run would assign ranks from a dead world. Our own temp
    // dir is safe to clear; an operator-supplied dir is NOT ours to
    // wipe — refuse a non-empty one instead of destroying its contents.
    let rdzv = match &opts.rdzv_dir {
        Some(d) => {
            std::fs::create_dir_all(d).with_context(|| format!("creating {d:?}"))?;
            let occupied = std::fs::read_dir(d)
                .with_context(|| format!("listing {d:?}"))?
                .next()
                .is_some();
            if occupied {
                bail!(
                    "launch: --rdzv-dir {d:?} is not empty — point it at a fresh directory \
                     (stale rendezvous files would corrupt rank assignment)"
                );
            }
            d.clone()
        }
        None => {
            let d = std::env::temp_dir().join(format!(
                "lowrank-launch-{}-{launch_id}",
                std::process::id()
            ));
            if d.exists() {
                std::fs::remove_dir_all(&d).with_context(|| format!("clearing stale {d:?}"))?;
            }
            std::fs::create_dir_all(&d)?;
            d
        }
    };
    // The per-launch run token: rank 0 stamps the rendezvous dir with
    // it and the other ranks verify, so this world can never mistake a
    // dead run's rendezvous files for its own.
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let run_token = format!("launch-{}-{launch_id}-{nanos:x}", std::process::id());

    let mut slots: Vec<Option<RankSlot>> = Vec::with_capacity(opts.nproc);
    let result = spawn_and_reap(opts, child_args, &exe, &rdzv, &run_token, &mut slots);
    if result.is_err() {
        // a runner-side failure (spawn error, wait error) must not
        // orphan live ranks into the comm-timeout address poll — the
        // same fast-termination contract a failing child gets
        kill_and_reap(&mut slots);
    }
    // only our own temp dir is removed (on success *and* error); an
    // operator-supplied dir keeps its (now-stale) rendezvous files for
    // post-mortem inspection
    if opts.rdzv_dir.is_none() {
        let _ = std::fs::remove_dir_all(&rdzv);
    }
    result
}

/// Spawn every rank, then reap in poll sweeps over all of them, so a
/// failure anywhere is observed within one sweep no matter which ranks
/// are still alive. On `Err` the caller kills whatever is left in
/// `slots`.
fn spawn_and_reap(
    opts: &LaunchOptions,
    child_args: &[String],
    exe: &std::path::Path,
    rdzv: &std::path::Path,
    run_token: &str,
    slots: &mut Vec<Option<RankSlot>>,
) -> Result<i32> {
    for rank in 0..opts.nproc {
        let mut cmd = Command::new(exe);
        cmd.args(child_args)
            .env("LOWRANK_COMM_RDZV", rdzv)
            .env("LOWRANK_COMM_WORLD", opts.nproc.to_string())
            .env("LOWRANK_COMM_RANK", rank.to_string())
            .env("LOWRANK_COMM_TRANSPORT", opts.transport.name())
            .env("LOWRANK_COMM_TIMEOUT_MS", opts.timeout_ms.to_string())
            .env("LOWRANK_COMM_TOKEN", run_token)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped());
        if let Some(algo) = &opts.algo {
            cmd.env("LOWRANK_COMM_ALGO", algo);
        }
        if let Some(dtype) = &opts.comm_dtype {
            cmd.env("LOWRANK_COMM_DTYPE", dtype);
        }
        let mut child = cmd
            .spawn()
            .with_context(|| format!("spawning rank {rank} ({})", exe.display()))?;
        let out_pump = pump(child.stdout.take().expect("piped stdout"), rank, false);
        let err_pump = pump(child.stderr.take().expect("piped stderr"), rank, true);
        slots.push(Some((rank, child, out_pump, err_pump)));
    }

    let mut first_failure = 0i32;
    let mut live = slots.len();
    while live > 0 {
        let mut reaped = false;
        let mut failed: Option<usize> = None;
        for slot in slots.iter_mut() {
            let finished = match slot.as_mut() {
                Some((rank, child, _, _)) => child
                    .try_wait()
                    .with_context(|| format!("waiting for rank {rank}"))?,
                None => None,
            };
            let Some(status) = finished else { continue };
            let (rank, _child, out_pump, err_pump) = slot.take().expect("slot was live");
            let _ = out_pump.join();
            let _ = err_pump.join();
            live -= 1;
            reaped = true;
            if !status.success() && first_failure == 0 {
                // signal-killed children have no code; report a generic 101
                first_failure = status.code().unwrap_or(101);
                failed = Some(rank);
                eprintln!("launch: rank {rank} exited with {status}");
            }
        }
        if let Some(rank) = failed {
            if live > 0 {
                eprintln!(
                    "launch: terminating {live} surviving rank(s) after rank {rank}'s failure"
                );
                for slot in slots.iter_mut() {
                    if let Some((_, child, _, _)) = slot.as_mut() {
                        let _ = child.kill();
                    }
                }
                // killed children are reaped by the next sweeps; their
                // signal exits never overwrite the original failure code
            }
        }
        if !reaped && live > 0 {
            std::thread::sleep(Duration::from_millis(15));
        }
    }
    Ok(first_failure)
}

/// Terminate and reap every rank still in `slots` (best effort — the
/// runner is already on an error path).
fn kill_and_reap(slots: &mut [Option<RankSlot>]) {
    for slot in slots.iter_mut() {
        if let Some((_, child, _, _)) = slot.as_mut() {
            let _ = child.kill();
        }
    }
    for slot in slots.iter_mut() {
        if let Some((_, mut child, out_pump, err_pump)) = slot.take() {
            let _ = child.wait();
            let _ = out_pump.join();
            let _ = err_pump.join();
        }
    }
}

/// Forward one child stream line-by-line with a `[rank r]` prefix.
fn pump(
    stream: impl std::io::Read + Send + 'static,
    rank: usize,
    is_err: bool,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if is_err {
                eprintln!("[rank {rank}] {line}");
            } else {
                println!("[rank {rank}] {line}");
            }
        }
    })
}
