//! Run-time model state: parameter store, f32 tensor math for the lift,
//! and the Table-2 memory accounting.
//!
//! * [`tensor`] — the f32 hot-path entry points (rank-r lift
//!   ΔΘ = B·Vᵀ, once per K steps, and the ZO update direction), now
//!   thin wrappers over the shared [`crate::kernel`] GEMM substrate —
//!   no standalone dense loops live here. Everything heavier runs
//!   inside the PJRT artifacts.
//! * [`store`] — [`ParamStore`]: the ordered set of named parameter
//!   tensors matching an artifact manifest's `params` slots, loadable
//!   from the `artifacts/init/<tag>/` dumps so Rust and Python agree on
//!   Θ₀ bit-for-bit.
//! * [`memory`] — the analytical peak-memory model that regenerates
//!   Table 2 at true RoBERTa-large scale and audits the proxy runs.

mod memory;
mod store;
mod tensor;

pub use memory::{MemoryBreakdown, MemoryModel, TrainMethod};
pub use store::{MutManyScratch, ParamStore};
pub use tensor::{gemm_nt_f32, lift_into, zo_update_into};
