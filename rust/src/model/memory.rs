//! Analytical peak-memory model — regenerates Table 2.
//!
//! The paper reports measured peak GPU memory for RoBERTa-large
//! fine-tuning under four methods (GB): Vanilla IPA 16.7, LowRank-IPA
//! 14.3, Vanilla LR 5.49, LowRank-LR 3.83. We cannot measure GPU peaks
//! on this machine, so we model the allocation inventory from first
//! principles and evaluate it at the true RoBERTa-large dimensions; the
//! claim under reproduction is the *ordering and the ratio structure*
//! (BP-family ≫ LR-family; low-rank < full within each family), plus
//! absolute totals in the right ballpark.
//!
//! Inventory per method (elements × 4 bytes, f32):
//!
//! | component        | Vanilla IPA | LowRank-IPA | Vanilla LR | LowRank-LR |
//! |------------------|-------------|-------------|------------|------------|
//! | weights          | all         | all         | all        | all        |
//! | gradients        | all         | B: m·r (+full for embed/norms) | — | — |
//! | Adam states (×2) | all         | same as its gradients | — | B only |
//! | saved activations| full BP set | BP set with per-matmul inputs projected n→r | — | — |
//! | live forward set | (⊂ activations) | (⊂) | yes | yes (projected) |
//! | perturbations    | —           | —           | streamed (1 largest matrix) | Z: m·r + V: n·r |
//! | logits           | yes         | yes         | yes        | yes        |

/// Architecture + workload dimensions.
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    pub layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub rank: usize,
    /// MLP matrices per layer: 2 for GELU-MLP (RoBERTa), 3 for SwiGLU.
    pub mlp_matrices: usize,
    pub bytes_per_el: usize,
}

/// Training method rows of Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMethod {
    VanillaIpa,
    LowRankIpa,
    VanillaLr,
    LowRankLr,
}

impl TrainMethod {
    pub const ALL: [TrainMethod; 4] = [
        TrainMethod::VanillaIpa,
        TrainMethod::LowRankIpa,
        TrainMethod::VanillaLr,
        TrainMethod::LowRankLr,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TrainMethod::VanillaIpa => "Vanilla IPA",
            TrainMethod::LowRankIpa => "LowRank-IPA",
            TrainMethod::VanillaLr => "Vanilla LR",
            TrainMethod::LowRankLr => "LowRank-LR",
        }
    }
}

/// Byte counts per component.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryBreakdown {
    pub weights: usize,
    pub gradients: usize,
    pub optimizer_state: usize,
    pub activations: usize,
    pub perturbations: usize,
    pub logits: usize,
}

impl MemoryBreakdown {
    pub fn total(&self) -> usize {
        self.weights
            + self.gradients
            + self.optimizer_state
            + self.activations
            + self.perturbations
            + self.logits
    }

    pub fn total_gb(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0 * 1024.0)
    }
}

impl MemoryModel {
    /// True RoBERTa-large dimensions with the paper's fine-tuning batch
    /// (64) and a 128-token context.
    pub fn roberta_large() -> Self {
        MemoryModel {
            layers: 24,
            d_model: 1024,
            d_ff: 4096,
            heads: 16,
            vocab: 50265,
            seq: 128,
            batch: 64,
            rank: 4,
            mlp_matrices: 2,
            bytes_per_el: 4,
        }
    }

    /// Our CPU-proxy classifier (matches python/compile/model.py
    /// CLF_CONFIG).
    pub fn clf_proxy() -> Self {
        MemoryModel {
            layers: 3,
            d_model: 128,
            d_ff: 384,
            heads: 4,
            vocab: 4096,
            seq: 32,
            batch: 16,
            rank: 4,
            mlp_matrices: 3,
            bytes_per_el: 4,
        }
    }

    fn tokens(&self) -> usize {
        self.batch * self.seq
    }

    /// Total parameter elements.
    pub fn param_elements(&self) -> usize {
        self.vocab * self.d_model + self.matrix_elements() + self.norm_elements()
    }

    /// Elements in the reparameterizable 2-D matrices.
    fn matrix_elements(&self) -> usize {
        let attn = 4 * self.d_model * self.d_model;
        let mlp = self.mlp_matrices * self.d_model * self.d_ff;
        self.layers * (attn + mlp)
    }

    fn norm_elements(&self) -> usize {
        (2 * self.layers + 1) * self.d_model
    }

    /// Σ over matrices of m·r (the B/gradient/optimizer footprint of the
    /// low-rank methods).
    fn lowrank_b_elements(&self) -> usize {
        // attn matrices have m = d; SwiGLU w1/w3 have m = ff, w2 has m = d
        let attn = 4 * self.d_model;
        let mlp = if self.mlp_matrices == 3 {
            2 * self.d_ff + self.d_model
        } else {
            self.d_ff + self.d_model
        };
        self.layers * (attn + mlp) * self.rank
    }

    /// Σ over matrices of n·r (the V footprint).
    fn lowrank_v_elements(&self) -> usize {
        let attn = 4 * self.d_model;
        let mlp = if self.mlp_matrices == 3 {
            2 * self.d_model + self.d_ff
        } else {
            self.d_model + self.d_ff
        };
        self.layers * (attn + mlp) * self.rank
    }

    /// Full-BP saved-activation elements: per layer ~4 d-sized tensors
    /// (norm output / qkv input, attention context, wo input, mlp input),
    /// 2 ff-sized (gate·up product and one factor), attention probs.
    fn bp_activation_elements(&self) -> usize {
        let t = self.tokens();
        let per_layer =
            4 * t * self.d_model + 2 * t * self.d_ff + self.batch * self.heads * self.seq * self.seq;
        self.layers * per_layer + t * self.d_model // embedding output
    }

    /// Activation elements for LowRank-IPA. The estimator *could* store
    /// the weight-gradient inputs projected (x·V is r-dim, §4.2), but
    /// the paper's measured Table 2 shows the 16.7 → 14.3 GB drop is
    /// almost exactly the gradient + optimizer-state saving — i.e. the
    /// framework still keeps the full BP activation set (the backward
    /// graph for dx needs most of it). We model that faithfully.
    fn lowrank_bp_activation_elements(&self) -> usize {
        self.bp_activation_elements()
    }

    /// Forward-only live set (LR family): the residual stream plus the
    /// widest transient of one layer — no cross-layer accumulation.
    fn forward_live_elements(&self) -> usize {
        let t = self.tokens();
        t * self.d_model + t * self.d_ff + self.batch * self.heads * self.seq * self.seq
    }

    pub fn logits_elements(&self) -> usize {
        self.tokens() * self.vocab
    }

    /// The Table-2 row for a method.
    pub fn breakdown(&self, method: TrainMethod) -> MemoryBreakdown {
        let b = self.bytes_per_el;
        let weights = self.param_elements() * b;
        let logits = self.logits_elements() * b;
        match method {
            TrainMethod::VanillaIpa => MemoryBreakdown {
                weights,
                gradients: self.param_elements() * b,
                optimizer_state: 2 * self.param_elements() * b,
                activations: self.bp_activation_elements() * b,
                perturbations: 0,
                logits,
            },
            TrainMethod::LowRankIpa => {
                let grad_el = self.lowrank_b_elements()
                    + self.vocab * self.d_model
                    + self.norm_elements();
                MemoryBreakdown {
                    weights: weights + self.lowrank_v_elements() * b,
                    gradients: grad_el * b,
                    optimizer_state: 2 * grad_el * b,
                    activations: self.lowrank_bp_activation_elements() * b,
                    perturbations: 0,
                    logits,
                }
            }
            TrainMethod::VanillaLr => {
                // The full-rank antithetic perturbation Θ ± σZ
                // materializes Z for every matrix (our clf_zo_full
                // artifact takes them as inputs; the paper's measured
                // 5.49 − 3.83 ≈ 1.7 GB gap is exactly this Z set).
                MemoryBreakdown {
                    weights,
                    gradients: 0,
                    optimizer_state: 0,
                    activations: self.forward_live_elements() * b,
                    perturbations: self.matrix_elements() * b,
                    logits,
                }
            }
            TrainMethod::LowRankLr => MemoryBreakdown {
                weights: weights + self.lowrank_v_elements() * b,
                gradients: 0,
                optimizer_state: 2 * self.lowrank_b_elements() * b,
                activations: self.forward_live_elements() * b,
                perturbations: self.lowrank_b_elements() * b,
                logits,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roberta_param_count_matches_known_size() {
        let m = MemoryModel::roberta_large();
        let p = m.param_elements();
        // RoBERTa-large ≈ 355M parameters
        assert!((p as f64 - 355e6).abs() / 355e6 < 0.03, "params = {p}");
    }

    #[test]
    fn table2_ordering_reproduced() {
        let m = MemoryModel::roberta_large();
        let gb: Vec<f64> = TrainMethod::ALL
            .iter()
            .map(|&meth| m.breakdown(meth).total_gb())
            .collect();
        // Vanilla IPA > LowRank-IPA > Vanilla LR > LowRank-LR
        assert!(gb[0] > gb[1], "{gb:?}");
        assert!(gb[1] > gb[2], "{gb:?}");
        assert!(gb[2] > gb[3], "{gb:?}");
    }

    #[test]
    fn table2_magnitudes_in_paper_ballpark() {
        // Paper: 16.7 / 14.3 / 5.49 / 3.83 GB. The model should land
        // within a factor ~1.6 of each (measured peaks include allocator
        // and framework overheads we do not model).
        let m = MemoryModel::roberta_large();
        let paper = [16.7, 14.3, 5.49, 3.83];
        for (meth, want) in TrainMethod::ALL.iter().zip(paper) {
            let got = m.breakdown(*meth).total_gb();
            let ratio = got / want;
            assert!(
                (0.4..2.0).contains(&ratio),
                "{}: model {got:.2} GB vs paper {want} GB",
                meth.name()
            );
        }
    }

    #[test]
    fn bp_family_dominated_by_activations_and_states() {
        let m = MemoryModel::roberta_large();
        let bd = m.breakdown(TrainMethod::VanillaIpa);
        assert!(bd.activations + bd.optimizer_state > bd.weights);
    }

    #[test]
    fn lr_family_has_no_gradient_memory() {
        let m = MemoryModel::roberta_large();
        for meth in [TrainMethod::VanillaLr, TrainMethod::LowRankLr] {
            let bd = m.breakdown(meth);
            assert_eq!(bd.gradients, 0, "{}", meth.name());
        }
    }

    #[test]
    fn lowrank_optimizer_state_scales_with_r_not_n() {
        let mut m = MemoryModel::roberta_large();
        let s1 = m.breakdown(TrainMethod::LowRankLr).optimizer_state;
        m.rank *= 4;
        let s2 = m.breakdown(TrainMethod::LowRankLr).optimizer_state;
        assert!((s2 as f64 / s1 as f64 - 4.0).abs() < 1e-9);
        // and it is tiny relative to full Adam
        let full = m.breakdown(TrainMethod::VanillaIpa).optimizer_state;
        assert!(s2 * 20 < full);
    }

    #[test]
    fn proxy_model_consistent() {
        let m = MemoryModel::clf_proxy();
        let bd = m.breakdown(TrainMethod::LowRankLr);
        assert!(bd.total() > 0);
        assert!(
            m.breakdown(TrainMethod::VanillaIpa).total() > bd.total(),
            "ordering must hold at proxy scale too"
        );
    }
}
