//! Parameter store: the ordered, named set of f32/i32 tensors matching
//! an artifact manifest's `params[...]` input slots.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{ArtifactManifest, HostTensor, TensorSpec};
#[cfg(test)]
use crate::runtime::DType;

/// Ordered parameter set. Order matches the `params` prefix slots of the
/// artifact the store was built for, so `tensors()` can be spliced
/// directly into the input vector.
pub struct ParamStore {
    specs: Vec<TensorSpec>,
    tensors: Vec<HostTensor>,
}

/// Reusable workspace for [`ParamStore::f32_mut_many_with`]: owns the
/// validation mask and the view staging vector, so a caller that keeps
/// one of these across steps (the estimator engine's per-step fan-out)
/// performs no heap allocation once the capacities have warmed up.
#[derive(Default)]
pub struct MutManyScratch {
    wanted: Vec<bool>,
    /// Empty whenever no `f32_mut_many_with` call is on the stack; only
    /// its capacity persists. The `'static` element lifetime is a
    /// placeholder — see the SAFETY notes in `f32_mut_many_with`.
    views: Vec<&'static mut [f32]>,
}

impl MutManyScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ParamStore {
    /// Load Θ₀ from an `artifacts/init/<tag>/` dump, validated against
    /// the manifest's `params` slots.
    pub fn load_init(artifacts_dir: &Path, tag: &str, manifest: &ArtifactManifest) -> Result<Self> {
        let specs: Vec<TensorSpec> = manifest
            .inputs
            .iter()
            .filter(|s| s.name.starts_with("params"))
            .cloned()
            .collect();
        if specs.is_empty() {
            bail!("manifest {} has no params inputs", manifest.name);
        }
        let dir = artifacts_dir.join("init").join(tag);
        let mut tensors = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let path = dir.join(format!("p_{i:03}.bin"));
            let t = HostTensor::from_bin_file(&path, spec)
                .with_context(|| format!("loading init param {} ({})", i, spec.name))?;
            tensors.push(t);
        }
        Ok(ParamStore { specs, tensors })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total trainable element count.
    pub fn num_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.num_elements()).sum()
    }

    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    pub fn tensors(&self) -> &[HostTensor] {
        &self.tensors
    }

    /// Position within the store (not the artifact) of a named param.
    pub fn position(&self, name_suffix: &str) -> Option<usize> {
        self.specs.iter().position(|s| s.name.ends_with(name_suffix))
    }

    /// Mutable f32 view of param `i`.
    pub fn f32_mut(&mut self, i: usize) -> Result<&mut [f32]> {
        self.tensors[i].as_f32_mut()
    }

    pub fn f32(&self, i: usize) -> Result<&[f32]> {
        self.tensors[i].as_f32()
    }

    /// Disjoint mutable f32 views of several store positions at once —
    /// the borrow split the coordinator's per-slot fan-out needs to
    /// update every matrix in parallel. Positions must be unique; the
    /// returned views are in `positions` order.
    pub fn f32_mut_many(&mut self, positions: &[usize]) -> Result<Vec<&mut [f32]>> {
        let len = self.tensors.len();
        let mut wanted = vec![false; len];
        for &p in positions {
            if p >= len {
                bail!("param position {p} out of range (store has {len})");
            }
            if wanted[p] {
                bail!("duplicate param position {p} in f32_mut_many");
            }
            wanted[p] = true;
        }
        let mut views: Vec<Option<&mut [f32]>> = self
            .tensors
            .iter_mut()
            .enumerate()
            .map(|(i, t)| if wanted[i] { t.as_f32_mut().ok() } else { None })
            .collect();
        positions
            .iter()
            .map(|&p| {
                views[p]
                    .take()
                    .with_context(|| format!("param {p} is not an f32 tensor"))
            })
            .collect()
    }

    /// Workspace-reusing variant of [`f32_mut_many`](Self::f32_mut_many):
    /// the disjoint views are staged in `scratch` (in `positions` order)
    /// and lent to `f` for the duration of the call. A caller that holds
    /// its [`MutManyScratch`] across steps allocates nothing here once
    /// the scratch capacities have warmed up — the reusable-workspace
    /// route of the engine's zero-allocation contract.
    ///
    /// `f` may drain or reorder the staged vector freely; it is cleared
    /// when the call returns (on the error paths too).
    pub fn f32_mut_many_with<R>(
        &mut self,
        positions: &[usize],
        scratch: &mut MutManyScratch,
        f: impl FnOnce(&mut Vec<&mut [f32]>) -> R,
    ) -> Result<R> {
        let len = self.tensors.len();
        scratch.wanted.clear();
        scratch.wanted.resize(len, false);
        for &p in positions {
            if p >= len {
                bail!("param position {p} out of range (store has {len})");
            }
            if scratch.wanted[p] {
                bail!("duplicate param position {p} in f32_mut_many_with");
            }
            scratch.wanted[p] = true;
        }
        // SAFETY: `scratch.views` is empty at rest — only its capacity
        // survives between calls. Retyping the placeholder `'static`
        // element lifetime to this call's borrow is sound because the
        // vector is filled and emptied entirely inside the call: the
        // guard clears it before the `&mut self` borrow ends (on unwind
        // too), and `f`'s higher-ranked signature keeps any element
        // lifetime from escaping into its return value.
        let views: &mut Vec<&mut [f32]> = unsafe { std::mem::transmute(&mut scratch.views) };
        struct ClearOnExit<'a, 'v>(&'a mut Vec<&'v mut [f32]>);
        impl Drop for ClearOnExit<'_, '_> {
            fn drop(&mut self) {
                self.0.clear();
            }
        }
        let mut guard = ClearOnExit(views);
        let base = self.tensors.as_mut_ptr();
        for &p in positions {
            // SAFETY: positions are unique (checked above), so each
            // tensor is borrowed at most once; every view dies with the
            // guard, inside this call's `&mut self` borrow.
            let t = unsafe { &mut *base.add(p) };
            guard
                .0
                .push(t.as_f32_mut().with_context(|| format!("param {p} is not an f32 tensor"))?);
        }
        Ok(f(&mut *guard.0))
    }

    pub fn shape(&self, i: usize) -> &[usize] {
        &self.specs[i].shape
    }

    pub fn name(&self, i: usize) -> &str {
        &self.specs[i].name
    }

    /// Save a checkpoint (same binary layout as the init dumps).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut lines = Vec::new();
        for (i, (spec, t)) in self.specs.iter().zip(&self.tensors).enumerate() {
            let bytes: Vec<u8> = match t {
                HostTensor::F32 { data, .. } => {
                    data.iter().flat_map(|v| v.to_le_bytes()).collect()
                }
                HostTensor::I32 { data, .. } => {
                    data.iter().flat_map(|v| v.to_le_bytes()).collect()
                }
            };
            std::fs::write(dir.join(format!("p_{i:03}.bin")), bytes)?;
            let shape = if spec.shape.is_empty() {
                "scalar".to_string()
            } else {
                spec.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
            };
            lines.push(format!("param {i} {} {} {shape}", spec.name, spec.dtype.tag()));
        }
        std::fs::write(dir.join("params.txt"), lines.join("\n") + "\n")?;
        Ok(())
    }

    /// Load a checkpoint previously written by [`save`] (or aot.py).
    pub fn load_checkpoint(dir: &Path, reference: &ParamStore) -> Result<Self> {
        let mut tensors = Vec::with_capacity(reference.specs.len());
        for (i, spec) in reference.specs.iter().enumerate() {
            let t = HostTensor::from_bin_file(&dir.join(format!("p_{i:03}.bin")), spec)?;
            tensors.push(t);
        }
        Ok(ParamStore { specs: reference.specs.clone(), tensors })
    }

    /// Total parameter bytes (f32).
    pub fn byte_size(&self) -> usize {
        self.specs.iter().map(|s| s.byte_len()).sum()
    }

    /// Sanity check: all values finite.
    pub fn assert_finite(&self) -> Result<()> {
        for (spec, t) in self.specs.iter().zip(&self.tensors) {
            if let Ok(data) = t.as_f32() {
                if data.iter().any(|v| !v.is_finite()) {
                    bail!("non-finite values in param {}", spec.name);
                }
            }
        }
        Ok(())
    }

    /// Overwrite param `i` (used by tests and the checkpoint path).
    pub fn set(&mut self, i: usize, t: HostTensor) -> Result<()> {
        t.check_spec(&self.specs[i])?;
        self.tensors[i] = t;
        Ok(())
    }

    /// Copy-on-write checkout: a new store whose tensors share the
    /// originals' `Arc` payloads. Cost is O(tensor count), not
    /// O(bytes); each tenant's first mutating access to a tensor
    /// unshares just that tensor (`Arc::make_mut` inside
    /// [`HostTensor::as_f32_mut`]). This is what lets N concurrent
    /// serve jobs start from one cached base model without N copies of
    /// the weights.
    pub fn cow_clone(&self) -> ParamStore {
        ParamStore { specs: self.specs.clone(), tensors: self.tensors.clone() }
    }

    /// Assemble a store directly from specs + tensors (validated
    /// pairwise). Used by the engine golden tests and the allocation
    /// benches, which need stores without an `artifacts/` tree.
    pub fn from_parts(specs: Vec<TensorSpec>, tensors: Vec<HostTensor>) -> Result<Self> {
        if specs.len() != tensors.len() {
            bail!("{} specs but {} tensors", specs.len(), tensors.len());
        }
        for (spec, t) in specs.iter().zip(&tensors) {
            t.check_spec(spec)
                .with_context(|| format!("from_parts tensor {}", spec.name))?;
        }
        Ok(ParamStore { specs, tensors })
    }

    #[cfg(test)]
    pub(crate) fn for_test(specs: Vec<TensorSpec>, tensors: Vec<HostTensor>) -> Self {
        ParamStore { specs, tensors }
    }
}

/// Checkpointing: every parameter tensor under its manifest name. A
/// restore must see exactly the tensors this store already holds (same
/// names, dtypes, shapes) — a checkpoint from a different scale or
/// artifact is rejected, never partially applied.
impl crate::ckpt::Checkpointable for ParamStore {
    fn state_dict(&self) -> crate::ckpt::StateDict {
        let mut sd = crate::ckpt::StateDict::new();
        for (spec, t) in self.specs.iter().zip(&self.tensors) {
            sd.put_tensor(spec.name.as_str(), t.clone());
        }
        sd
    }

    fn load_state(&mut self, sd: &crate::ckpt::StateDict) -> Result<()> {
        if sd.len() != self.specs.len() {
            bail!(
                "param checkpoint has {} tensors, store expects {}",
                sd.len(),
                self.specs.len()
            );
        }
        let mut fresh = Vec::with_capacity(self.specs.len());
        for spec in &self.specs {
            let t = sd.tensor(&spec.name)?;
            t.check_spec(spec)
                .with_context(|| format!("param checkpoint tensor {}", spec.name))?;
            fresh.push(t.clone());
        }
        self.tensors = fresh;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_store() -> ParamStore {
        let specs = vec![
            TensorSpec { index: 0, name: "params[embed]".into(), dtype: DType::F32, shape: vec![4, 2] },
            TensorSpec { index: 1, name: "params[layer0.wq]".into(), dtype: DType::F32, shape: vec![2, 2] },
        ];
        let tensors = vec![
            HostTensor::f32(vec![4, 2], (0..8).map(|i| i as f32).collect()),
            HostTensor::f32(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]),
        ];
        ParamStore::for_test(specs, tensors)
    }

    #[test]
    fn lookup_and_sizes() {
        let s = toy_store();
        assert_eq!(s.len(), 2);
        assert_eq!(s.num_elements(), 12);
        assert_eq!(s.byte_size(), 48);
        assert_eq!(s.position("wq]"), Some(1));
        assert_eq!(s.position("nope"), None);
        assert_eq!(s.shape(0), &[4, 2]);
    }

    #[test]
    fn save_and_reload_roundtrip() {
        let mut s = toy_store();
        s.f32_mut(1).unwrap()[0] = 42.0;
        let dir = std::env::temp_dir().join("lowrank_sge_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        s.save(&dir).unwrap();
        let restored = ParamStore::load_checkpoint(&dir, &s).unwrap();
        assert_eq!(restored.f32(1).unwrap()[0], 42.0);
        assert_eq!(restored.f32(0).unwrap(), s.f32(0).unwrap());
    }

    #[test]
    fn checkpointable_roundtrip_is_bit_exact_and_validated() {
        use crate::ckpt::Checkpointable;
        let mut src = toy_store();
        src.f32_mut(0).unwrap()[5] = -1.25e-30;
        src.f32_mut(1).unwrap()[2] = f32::MIN_POSITIVE;
        let sd = src.state_dict();
        let mut dst = toy_store();
        dst.load_state(&sd).unwrap();
        for i in 0..src.len() {
            for (a, b) in src.f32(i).unwrap().iter().zip(dst.f32(i).unwrap()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // shape mismatch rejected without partial application
        let bad_specs = vec![
            TensorSpec { index: 0, name: "params[embed]".into(), dtype: DType::F32, shape: vec![2, 4] },
            TensorSpec { index: 1, name: "params[layer0.wq]".into(), dtype: DType::F32, shape: vec![2, 2] },
        ];
        let bad_tensors = vec![
            HostTensor::f32(vec![2, 4], vec![0.0; 8]),
            HostTensor::f32(vec![2, 2], vec![0.0; 4]),
        ];
        let mut other = ParamStore::for_test(bad_specs, bad_tensors);
        assert!(other.load_state(&sd).is_err());
    }

    #[test]
    fn f32_mut_many_returns_disjoint_views_in_order() {
        let mut s = toy_store();
        {
            let views = s.f32_mut_many(&[1, 0]).unwrap();
            assert_eq!(views.len(), 2);
            assert_eq!(views[0].len(), 4); // position 1 first
            assert_eq!(views[1].len(), 8);
        }
        assert!(s.f32_mut_many(&[0, 0]).is_err(), "duplicates rejected");
        assert!(s.f32_mut_many(&[9]).is_err(), "out of range rejected");
    }

    #[test]
    fn f32_mut_many_with_stages_views_and_clears_scratch() {
        let mut s = toy_store();
        let mut scratch = MutManyScratch::new();
        let lens = s
            .f32_mut_many_with(&[1, 0], &mut scratch, |views| {
                views.iter().map(|v| v.len()).collect::<Vec<_>>()
            })
            .unwrap();
        assert_eq!(lens, vec![4, 8], "views come in `positions` order");
        // same rejections as f32_mut_many, scratch reusable afterwards
        assert!(s.f32_mut_many_with(&[0, 0], &mut scratch, |_| ()).is_err());
        assert!(s.f32_mut_many_with(&[9], &mut scratch, |_| ()).is_err());
        // writes through the staged views land in the store
        s.f32_mut_many_with(&[0], &mut scratch, |views| views[0][0] = 7.5).unwrap();
        assert_eq!(s.f32(0).unwrap()[0], 7.5);
    }

    #[test]
    fn finite_check_catches_nan() {
        let mut s = toy_store();
        s.f32_mut(0).unwrap()[3] = f32::NAN;
        assert!(s.assert_finite().is_err());
    }

    #[test]
    fn set_validates_spec() {
        let mut s = toy_store();
        assert!(s.set(1, HostTensor::f32(vec![2, 2], vec![0.0; 4])).is_ok());
        assert!(s.set(1, HostTensor::f32(vec![4], vec![0.0; 4])).is_err());
    }
}
